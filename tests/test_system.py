"""End-to-end behaviour: training improves loss; checkpoint/resume determinism;
serving engine produces consistent generations."""

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.configs.base import ParallelConfig, TrainConfig
from repro.data.pipeline import SyntheticLM
from repro.models.transformer import Model
from repro.serve.engine import Request, ServeEngine
from repro.train.trainer import Trainer

# JAX compile-heavy: excluded from the fast tier (pytest -m "not slow")
pytestmark = pytest.mark.slow


def test_training_improves_loss(tmp_path):
    cfg = get_config("qwen3-14b").reduced(num_layers=2, d_model=128, d_ff=256)
    model = Model(cfg)
    tcfg = TrainConfig(steps=30, checkpoint_dir=str(tmp_path), checkpoint_every=10,
                       log_every=100)
    tr = Trainer(model, ParallelConfig(), tcfg)
    state = tr.init_state()
    data = SyntheticLM(cfg.vocab_size, 64, 8)
    state, hist = tr.fit(state, data, steps=30)
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert np.isfinite(hist[-1]["loss"])


def test_checkpoint_resume_deterministic(tmp_path):
    cfg = get_config("starcoder2-3b").reduced()
    model = Model(cfg)
    tcfg = TrainConfig(steps=12, checkpoint_dir=str(tmp_path), checkpoint_every=4,
                       log_every=100)
    data = SyntheticLM(cfg.vocab_size, 32, 4)
    tr = Trainer(model, ParallelConfig(), tcfg)
    state = tr.init_state()
    state, _ = tr.fit(state, data, steps=8)
    # resume from the step-8 checkpoint in a fresh trainer FIRST (the
    # continuation below writes later checkpoints into the same dir)
    tr2 = Trainer(model, ParallelConfig(), tcfg)
    state2, step = tr2.resume()
    assert step == 8
    state_cont, hist_cont = tr.fit(state, data, steps=4, start_step=8)
    state2, hist_res = tr2.fit(state2, data, steps=4, start_step=8)
    assert abs(hist_cont[-1]["loss"] - hist_res[-1]["loss"]) < 1e-5


def test_straggler_watchdog():
    from repro.train.trainer import StragglerWatchdog

    wd = StragglerWatchdog(factor=2.0)
    for _ in range(5):
        assert not wd.observe(0.1)
    assert wd.observe(0.5)  # 5x the EMA
    assert wd.slow_steps == 1


def test_serve_engine_batched():
    cfg = get_config("musicgen-large").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_len=64, slots=3)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                    max_new_tokens=6) for _ in range(7)]
    done = engine.serve(reqs)
    assert all(r.done and len(r.out_tokens) == 6 for r in done)


def test_serve_generate_matches_decode_loop():
    cfg = get_config("qwen3-14b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_len=48, slots=2)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 16).astype(np.int32) for _ in range(2)]
    outs = engine.generate(prompts, max_new_tokens=5)
    # manual greedy loop
    import jax.numpy as jnp

    toks = jnp.asarray(np.stack(prompts))
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len=48))(
        params, {"tokens": toks}
    )
    for t in range(5):
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(2):
            assert int(nxt[i]) == outs[i][t]
        logits, cache = jax.jit(lambda p, c, x: model.decode_step(p, c, x))(
            params, cache, nxt[:, None]
        )
