"""repro.obs: streaming-vs-exact percentile parity, tracer level gating,
tracing-is-observational parity with untraced runs (including the pinned
autoscaler), golden trace digests for the stable `repro.obs/1` schema,
the structural validator, Chrome export invariants, the offline report's
metric parity with `summarize_cluster`, and trace-vs-billing consistency
(t0/horizon, provisioned extents == replica-hours)."""

import json
from collections import Counter

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.hardware import H100_SXM
from repro.obs import (
    NULL_TRACER,
    PCTS,
    P2Quantile,
    StreamingQuantiles,
    Tracer,
    WindowedAggregator,
    analyze,
    csv_rows,
    make_tracer,
    pct_key,
    percentile_summary,
    read_jsonl,
    to_chrome,
    validate_trace,
    write_jsonl,
    write_trace,
)
from repro.obs.__main__ import main as obs_main
from repro.sim import (
    LengthDist,
    SchedConfig,
    ServingCostModel,
    Workload,
    simulate,
    summarize_records,
)
from repro.cluster import (
    AutoscaleConfig,
    ClusterSpec,
    ReplicaSpec,
    provisioning_summary,
    simulate_cluster,
    summarize_cluster,
)

CFG = get_config("qwen3_14b")


def _sig6(x):
    return float(f"{x:.6g}")


def _wl(**kw):
    base = dict(
        qps=50.0, num_requests=24, arrival="poisson",
        prompt=LengthDist("lognormal", 96, 0.4, lo=8, hi=512),
        output=LengthDist("lognormal", 24, 0.4, lo=2, hi=128), seed=0,
    )
    base.update(kw)
    return Workload(**base)


def _spec(pools, **kw):
    sched = SchedConfig(slots=8)
    return ClusterSpec(
        replicas=tuple(ReplicaSpec(hw="h100", pool=p, sched=sched, ctx_quantum=32)
                       for p in pools),
        **kw)


def _autoscaled_run(tracer=None):
    """The golden autoscaled scenario: diurnal traffic over a rate-policy
    fleet that scales up AND back down, so the trace covers warmup, drain,
    scale.up/scale.down/replica.retired, and autoscale decisions."""
    wl = _wl(qps=20.0, num_requests=120, arrival="diurnal",
             diurnal_period=8.0, diurnal_amp=0.9)
    asc = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=4,
                          interval=0.5, warmup=0.4, target_qps_per_replica=8.0)
    return simulate_cluster(wl.generate(), CFG, _spec(["mixed", "mixed"]),
                            autoscale=asc, tracer=tracer)


# ------------------------------------------------------------- quantiles
def test_percentile_summary_matches_numpy_and_key_convention():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(0.0, 1.0, size=500)
    out = percentile_summary(xs, "ttft")
    assert set(out) == {"ttft_p50", "ttft_p95", "ttft_p99", "ttft_p99.9",
                        "ttft_mean"}
    for p in PCTS:
        assert out[pct_key("ttft", p)] == float(np.percentile(xs, p))
    assert out["ttft_mean"] == pytest.approx(xs.mean())
    assert percentile_summary([], "x")["x_p50"] == 0.0


def test_summarize_records_routes_through_shared_convention():
    """Satellite: one percentile convention — `summarize_records` reports
    the shared PCTS set (incl. p99.9) with numpy-exact values."""
    reqs = _wl().generate()
    res = simulate(reqs, ServingCostModel(CFG, H100_SXM, ctx_quantum=32),
                   SchedConfig(slots=8))
    s = summarize_records(res.records)
    ttfts = [r.ttft for r in res.records]
    for p in PCTS:
        assert pct_key("ttft", p) in s
        assert s[pct_key("ttft", p)] == float(np.percentile(ttfts, p))


def test_streaming_exact_when_tail_covers_all_ranks():
    """n <= tail_k: every quantile is answered from the exact reservoir."""
    rng = np.random.default_rng(1)
    xs = rng.lognormal(0.0, 0.8, size=1000)
    sq = StreamingQuantiles()  # tail_k=1024 >= n
    for x in xs:
        sq.add(x)
    for p in PCTS:
        assert sq.quantile(p) == pytest.approx(float(np.percentile(xs, p)),
                                               rel=1e-12)
    assert sq.n == 1000 and sq.min == xs.min() and sq.max == xs.max()


def test_streaming_within_half_percent_on_lognormal():
    """Satellite regression bound: streaming vs exact within 0.5% on a
    lognormal stream larger than the tail reservoir (p50 runs on P²; the
    tail percentiles stay exact because their ranks are reservoir-resident)."""
    rng = np.random.default_rng(2)
    xs = rng.lognormal(0.0, 1.0, size=20_000)
    sq = StreamingQuantiles(tail_k=1024)
    for x in xs:
        sq.add(x)
    for p in PCTS:
        exact = float(np.percentile(xs, p))
        assert abs(sq.quantile(p) - exact) / exact < 0.005, p
    # and the SLO-gating tail is EXACT, not merely close
    for p in (99, 99.9):
        assert sq.quantile(p) == pytest.approx(float(np.percentile(xs, p)),
                                               rel=1e-12)


def test_p2_exact_for_tiny_streams():
    q = P2Quantile(0.5)
    for x in (5.0, 1.0, 3.0):
        q.add(x)
    assert q.value() == 3.0
    with pytest.raises(ValueError):
        P2Quantile(1.5)


def test_windowed_aggregator():
    agg = WindowedAggregator(1.0)
    for t, v in [(0.1, 2.0), (0.9, 4.0), (1.5, 10.0)]:
        agg.add(t, "queue", v)
    rows = agg.rows()
    assert len(rows) == 2
    assert rows[0]["queue_n"] == 2 and rows[0]["queue_mean"] == 3.0
    assert rows[0]["queue_min"] == 2.0 and rows[0]["queue_last"] == 4.0
    assert rows[1]["t0"] == 1.0 and rows[1]["queue_max"] == 10.0
    with pytest.raises(ValueError):
        WindowedAggregator(0.0)


# ---------------------------------------------------------------- tracer
def test_levels_and_gating():
    assert make_tracer("off") is NULL_TRACER
    assert make_tracer(None) is NULL_TRACER
    assert not NULL_TRACER.enabled and not NULL_TRACER.wants("summary")
    tr = Tracer("replica")
    assert tr.wants("summary") and tr.wants("replica") and not tr.wants("request")
    with pytest.raises(ValueError):
        Tracer("off")
    with pytest.raises(ValueError):
        Tracer("verbose")


def test_validator_catches_synthetic_violations():
    ok = [{"ev": "span", "name": "provisioned", "t0": 0.0, "t1": 2.0, "track": "r0"},
          {"ev": "span", "name": "warmup", "t0": 0.0, "t1": 0.5, "track": "r0"},
          {"ev": "span", "name": "queued", "t0": 0.1, "t1": 0.2, "track": "r0",
           "rid": 1},
          {"ev": "instant", "name": "request.complete", "t": 0.9, "track": "r0",
           "rid": 1}]
    assert validate_trace(ok) == []
    # reversed span
    bad = [{"ev": "span", "name": "prefill", "t0": 2.0, "t1": 1.0, "track": "r0",
            "rid": 7},
           {"ev": "instant", "name": "request.complete", "t": 2.0, "rid": 7}]
    assert any("ends before it starts" in p for p in validate_trace(bad))
    # structural spans that overlap without nesting
    bad = [{"ev": "span", "name": "provisioned", "t0": 0.0, "t1": 2.0, "track": "r0"},
           {"ev": "span", "name": "drain", "t0": 1.0, "t1": 3.0, "track": "r0"}]
    assert any("without nesting" in p for p in validate_trace(bad))
    # a traced rid with no terminal, and one with two
    bad = [{"ev": "span", "name": "queued", "t0": 0.0, "t1": 1.0, "rid": 1},
           {"ev": "instant", "name": "request.complete", "t": 1.0, "rid": 2},
           {"ev": "instant", "name": "request.shed", "t": 2.0, "rid": 2}]
    probs = validate_trace(bad)
    assert any("rid 1" in p and "none" in p for p in probs)
    assert any("rid 2" in p for p in probs)
    # phase spans out of order
    bad = [{"ev": "span", "name": "decode", "t0": 5.0, "t1": 6.0, "rid": 3},
           {"ev": "span", "name": "queued", "t0": 0.0, "t1": 1.0, "rid": 3},
           {"ev": "instant", "name": "request.complete", "t": 6.0, "rid": 3}]
    assert any("out of order" in p for p in validate_trace(bad))


# ----------------------------------------------- tracing is observational
@pytest.mark.parametrize("pools", [["mixed", "mixed"], ["prefill", "decode"]])
def test_tracing_never_perturbs_the_schedule(pools):
    reqs = _wl().generate()
    plain = simulate_cluster(reqs, CFG, _spec(pools))
    traced = simulate_cluster(reqs, CFG, _spec(pools), tracer=Tracer("request"))
    key = lambda c: [(r.rid, r.admitted, r.first_token, r.finish)
                     for r in sorted(c.records, key=lambda r: r.rid)]
    assert key(plain) == key(traced)
    assert summarize_cluster(plain) == summarize_cluster(traced)


def test_tracing_preserves_autoscaled_schedule():
    plain = _autoscaled_run()
    traced = _autoscaled_run(tracer=Tracer("request"))
    assert plain.replica_spans == traced.replica_spans
    assert [(r.rid, r.finish) for r in plain.records] == \
           [(r.rid, r.finish) for r in traced.records]


# -------------------------------------------------- golden trace digests
def _digest(tr):
    counts = Counter((e["ev"], e["name"]) for e in tr.events)
    return {
        "events": {f"{ev}:{name}": n for (ev, name), n in sorted(counts.items())},
        "horizon": _sig6(tr.meta["horizon"]),
        "span_s": _sig6(sum(e["t1"] - e["t0"] for e in tr.events
                            if e["ev"] == "span")),
    }


GOLDEN_COLOCATED = {
    "events": {"counter:busy_s": 151, "counter:kv_used": 151,
               "counter:live": 151, "counter:queue": 151,
               "instant:dispatch": 24, "instant:request.complete": 24,
               "span:decode": 24, "span:prefill": 24,
               "span:provisioned": 2, "span:queued": 24},
    "horizon": 1.07383, "span_s": 11.1009,
}
GOLDEN_DISAGG = {
    "events": {"counter:busy_s": 108, "counter:kv_used": 108,
               "counter:live": 108, "counter:queue": 108,
               "instant:dispatch": 24, "instant:request.complete": 24,
               "span:decode": 24, "span:decode_wait": 24, "span:handoff": 24,
               "span:prefill": 24, "span:provisioned": 2, "span:queued": 24},
    "horizon": 1.09883, "span_s": 10.8456,
}
GOLDEN_AUTOSCALED = {
    "events": {"counter:busy_s": 1344, "counter:kv_used": 1344,
               "counter:live": 1344, "counter:queue": 1344,
               "instant:autoscale.decision": 15, "instant:dispatch": 120,
               "instant:replica.retired": 2, "instant:request.complete": 120,
               "instant:scale.down": 2, "instant:scale.up": 2,
               "span:decode": 120, "span:drain": 2, "span:prefill": 120,
               "span:provisioned": 4, "span:queued": 120, "span:warmup": 2},
    "horizon": 7.57777, "span_s": 63.8061,
}


@pytest.mark.parametrize("label,golden", [
    ("colocated", GOLDEN_COLOCATED),
    ("disaggregated", GOLDEN_DISAGG),
    ("autoscaled", GOLDEN_AUTOSCALED),
])
def test_golden_trace_digest(label, golden):
    """Schema-stability pin: the exact event mix (and 6-sig-fig timing
    aggregates) a `repro.obs/1` trace of each canonical scenario contains.
    A diff here means the trace schema or the simulator's event emission
    changed — update the digest deliberately, with a CHANGES.md note."""
    tr = Tracer("request")
    if label == "autoscaled":
        _autoscaled_run(tracer=tr)
    else:
        pools = ["mixed", "mixed"] if label == "colocated" else ["prefill", "decode"]
        simulate_cluster(_wl().generate(), CFG, _spec(pools), tracer=tr)
    assert validate_trace(tr.events) == []
    assert _digest(tr) == golden


def test_trace_levels_strictly_nest_event_sets():
    reqs = _wl().generate()
    sizes = {}
    for level in ("summary", "replica", "request"):
        tr = Tracer(level)
        simulate_cluster(reqs, CFG, _spec(["prefill", "decode"]), tracer=tr)
        sizes[level] = len(tr.events)
    assert 0 <= sizes["summary"] < sizes["replica"] < sizes["request"]


# ----------------------------------------------------------------- export
def test_chrome_export_invariants():
    tr = Tracer("request")
    cres = _autoscaled_run(tracer=tr)
    doc = to_chrome(tr.events, tr.meta)
    doc = json.loads(json.dumps(doc))  # must be JSON-serializable
    evs = doc["traceEvents"]
    # async begin/end balance per (cat, id)
    bal = Counter()
    for e in evs:
        if e.get("ph") == "b":
            bal[(e["cat"], e["id"])] += 1
        elif e.get("ph") == "e":
            bal[(e["cat"], e["id"])] -= 1
    assert bal and all(v == 0 for v in bal.values())
    # one named thread per track: cluster + every provisioned replica
    threads = {e["args"]["name"] for e in evs
               if e.get("ph") == "M" and e["name"] == "thread_name"}
    assert "cluster" in threads
    assert len(threads) == 1 + len(cres.replica_specs)
    # only structural spans become X events; counters fold track into name
    assert {e["name"] for e in evs if e.get("ph") == "X"} <= \
        {"provisioned", "warmup", "drain"}
    assert all("/" in e["name"] for e in evs if e.get("ph") == "C")
    assert doc["otherData"]["schema"] == "repro.obs/1"


def test_jsonl_roundtrip_and_suffix_dispatch(tmp_path):
    tr = Tracer("request")
    simulate_cluster(_wl().generate(), CFG, _spec(["mixed"]), tracer=tr)
    p = tmp_path / "t.jsonl"
    assert write_trace(tr.events, p, tr.meta) == "jsonl"
    meta, events = read_jsonl(p)
    assert meta["schema"] == "repro.obs/1"
    assert meta["horizon"] == tr.meta["horizon"]
    assert events == json.loads(json.dumps(tr.events))
    assert write_trace(tr.events, tmp_path / "t.json", tr.meta) == "chrome"
    assert write_trace(tr.events, tmp_path / "t.csv", tr.meta) == "csv"


def test_csv_rows_window_counters():
    tr = Tracer("replica")
    simulate_cluster(_wl().generate(), CFG, _spec(["mixed", "mixed"]), tracer=tr)
    rows = csv_rows(tr.events, window=0.25)
    assert rows and {"t0", "t1", "track", "series", "n", "mean", "min", "max",
                     "last"} <= set(rows[0])
    assert {r["series"] for r in rows} >= {"busy_s", "kv_used", "live", "queue"}
    assert all(r["t1"] - r["t0"] == pytest.approx(0.25) for r in rows)


# ----------------------------------------------------------------- report
def test_report_reproduces_summarize_cluster_from_trace_alone(tmp_path):
    """Acceptance: `repro.obs report` on a JSONL trace reproduces the
    simulator's own TTFT p50/p99 with no access to the record list."""
    tr = Tracer("request")
    cres = _autoscaled_run(tracer=tr)
    s = summarize_cluster(cres)
    p = tmp_path / "t.jsonl"
    write_jsonl(tr.events, p, tr.meta)
    meta, events = read_jsonl(p)
    rep = analyze(events, meta)
    assert rep["problems"] == []
    assert rep["summary"]["n_complete"] == len(cres.records)
    for key in ("ttft_p50", "ttft_p99", "e2e_p50", "e2e_p99"):
        assert rep["summary"][key] == pytest.approx(s[key], rel=1e-9), key
    # autoscaler explanations survive the roundtrip
    assert rep["decisions"] and all("policy" in d and "want" in d
                                    for d in rep["decisions"])
    assert {o["op"] for o in rep["scale_ops"]} >= {"scale.up", "scale.down",
                                                   "replica.retired"}


def test_report_phase_breakdown_sums_to_e2e():
    tr = Tracer("request")
    simulate_cluster(_wl().generate(), CFG, _spec(["prefill", "decode"]),
                     tracer=tr)
    rep = analyze(tr.events, tr.meta)
    for r in rep["slowest"]:
        total = sum(r["phases"].values())
        assert total == pytest.approx(r["e2e"], rel=1e-6)


def test_obs_cli_report_and_validate(tmp_path, capsys):
    tr = Tracer("request")
    simulate_cluster(_wl().generate(), CFG, _spec(["mixed"]), tracer=tr)
    p = tmp_path / "t.jsonl"
    write_jsonl(tr.events, p, tr.meta)
    assert obs_main([ "report", str(p), "--validate-only"]) == 0
    assert obs_main(["report", str(p)]) == 0
    out = capsys.readouterr().out
    assert "latency (ms)" in out and "per-replica utilization" in out
    # a corrupted trace (terminal removed) fails validation with exit 1
    events = [e for e in tr.events
              if not (e.get("name") == "request.complete" and e.get("rid") == 0)]
    bad = tmp_path / "bad.jsonl"
    write_jsonl(events, bad, tr.meta)
    assert obs_main(["report", str(bad), "--validate-only"]) == 1


# -------------------------------------------- billing / horizon consistency
def test_trace_extents_match_billing_and_horizon():
    """Satellite bugfix pin: summarize_cluster and provisioning_summary
    report the same t0/horizon, the static-peak counterfactual bills over
    that same window, and the trace's provisioned track extents sum to
    exactly `replica_hours`."""
    tr = Tracer("request")
    cres = _autoscaled_run(tracer=tr)
    s = summarize_cluster(cres)
    prov = provisioning_summary(cres)
    assert (s["t0"], s["horizon"]) == (prov["t0"], prov["horizon"])
    assert cres.span == cres.horizon - cres.t0
    assert prov["replica_hours_static_peak"] == pytest.approx(
        cres.peak_replicas * cres.span / 3600.0)
    prov_extent = sum(e["t1"] - e["t0"] for e in tr.events
                      if e["ev"] == "span" and e["name"] == "provisioned")
    assert prov_extent == pytest.approx(cres.replica_hours * 3600.0, rel=1e-12)
    assert tr.meta["t0"] == cres.t0 and tr.meta["horizon"] == cres.horizon


def test_prefix_cache_trace_wiring():
    """A cached, churning fleet records cache-resident bytes and the
    invalidation that a drain inflicts on the cache's warmth."""
    from repro.cluster import PrefixCacheConfig
    wl = _wl(qps=20.0, num_requests=120, arrival="diurnal",
             diurnal_period=8.0, diurnal_amp=0.9, num_sessions=6,
             num_prefix_groups=3, prefix=LengthDist("fixed", 48.0))
    spec = ClusterSpec(
        replicas=tuple(ReplicaSpec(hw="h100", pool="mixed",
                                   sched=SchedConfig(slots=8), ctx_quantum=32)
                       for _ in range(2)),
        router="affinity",
        prefix_cache=PrefixCacheConfig(budget_frac=0.001, ttl=5.0))
    asc = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=4,
                          interval=0.5, warmup=0.4, target_qps_per_replica=8.0)
    tr = Tracer("request")
    simulate_cluster(wl.generate(), CFG, spec, autoscale=asc, tracer=tr)
    assert validate_trace(tr.events) == []
    assert any(e.get("name") == "cache_bytes" for e in tr.events)
    invs = [e for e in tr.events if e.get("name") == "cache.invalidate"]
    assert invs and all("dropped_bytes" in e["attrs"] for e in invs)


def test_static_fleet_savings_frac_is_zero():
    """With the shared horizon, a static fleet's actual bill equals its
    static-peak counterfactual exactly — savings can no longer go negative
    from the makespan-vs-horizon mismatch."""
    cres = simulate_cluster(_wl().generate(), CFG, _spec(["mixed", "mixed"]))
    prov = provisioning_summary(cres)
    assert prov["replica_hours"] == pytest.approx(
        prov["replica_hours_static_peak"])
    assert prov["savings_frac"] == pytest.approx(0.0, abs=1e-12)


# ----------------------------- satellite: windowed-aggregator robustness
def test_windowed_aggregator_tolerates_out_of_order():
    """Late/early observations land in the window their own timestamp
    selects, and `_last` tracks the latest-t sample, not the latest
    add()."""
    agg = WindowedAggregator(1.0)
    agg.add(2.5, "q", 10.0)
    agg.add(0.5, "q", 1.0)   # arrives late; lands in window 0
    agg.add(2.1, "q", 3.0)   # earlier t within window 2: last stays 10
    rows = agg.rows()
    assert [r["t0"] for r in rows] == [0.0, 2.0]
    assert rows[0]["q_n"] == 1 and rows[0]["q_last"] == 1.0
    assert rows[1]["q_n"] == 2 and rows[1]["q_min"] == 3.0
    assert rows[1]["q_last"] == 10.0


def test_windowed_aggregator_emits_gap_rows():
    agg = WindowedAggregator(1.0)
    agg.add(0.5, "q", 1.0)
    agg.add(3.5, "q", 2.0)
    rows = agg.rows(fill_gaps=True)
    assert [r["t0"] for r in rows] == [0.0, 1.0, 2.0, 3.0]
    assert rows[1] == {"t0": 1.0, "t1": 2.0, "gap": True}
    assert rows[2] == {"t0": 2.0, "t1": 3.0, "gap": True}
    assert len(agg.rows()) == 2  # default stays sparse
    assert WindowedAggregator(1.0).rows(fill_gaps=True) == []


def test_windowed_aggregator_range_stats():
    agg = WindowedAggregator(0.5)
    for i in range(10):
        agg.add(0.5 * i + 0.25, "bad", float(i % 2))
    assert agg.range_stats("bad", 0.0, 5.0) == {"n": 10, "sum": 5.0}
    assert agg.range_stats("bad", 1.0, 2.0) == {"n": 2, "sum": 1.0}
    assert agg.range_stats("bad", 10.0, 12.0) == {"n": 0, "sum": 0.0}


def test_csv_gap_rows_keep_time_axis_contiguous():
    events = [
        {"ev": "counter", "name": "q", "t": 0.1, "value": 1.0, "track": "r0"},
        {"ev": "counter", "name": "q", "t": 2.6, "value": 2.0, "track": "r0"},
    ]
    rows = csv_rows(events, window=1.0)
    assert [r["t0"] for r in rows] == [0.0, 1.0, 2.0]
    gap = rows[1]
    assert gap["n"] == 0 and gap["mean"] == "" and gap["series"] == "q"


# ------------------------------- satellite: quantile-sketch edge cases
def test_p2_constant_stream_is_exact():
    q = P2Quantile(0.99)
    for _ in range(100):
        q.add(3.0)
    assert q.value() == 3.0
    sq = StreamingQuantiles()
    for _ in range(50):
        sq.add(1.25)
    for p in (50, 95, 99, 99.9):
        assert sq.quantile(p) == 1.25
    assert sq.mean == 1.25


def test_p2_tiny_streams_are_numpy_exact():
    for n in (0, 1, 2, 3, 4, 5):
        q = P2Quantile(0.5)
        xs = [float(7 - i) for i in range(n)]
        for x in xs:
            q.add(x)
        want = float(np.percentile(xs, 50)) if xs else 0.0
        assert q.value() == want, n


def test_streaming_duplicate_heavy_input():
    """A stream drawn from a tiny value set (heavy duplicates) must stay
    within the sketch's tolerance and produce plausible values."""
    rng = np.random.default_rng(3)
    xs = rng.choice([0.1, 0.2, 0.3], size=5000, p=[0.9, 0.09, 0.01])
    sq = StreamingQuantiles()
    for x in xs:
        sq.add(float(x))
    assert sq.quantile(99.9) == float(np.percentile(xs, 99.9))  # exact tail
    assert abs(sq.quantile(50) - float(np.percentile(xs, 50))) <= 0.1
    assert 0.1 <= sq.quantile(50) <= 0.3


def test_pct_key_formatting():
    assert pct_key("ttft", 99) == "ttft_p99"
    assert pct_key("ttft", 99.0) == "ttft_p99"
    assert pct_key("ttft", 99.9) == "ttft_p99.9"
    assert pct_key("e2e", 50) == "e2e_p50"
    out = percentile_summary([1.0], "x", pcts=(99, 99.9))
    assert set(out) == {"x_p99", "x_p99.9", "x_mean"}


# --------------------------------- satellite: deterministic report topk
def test_report_topk_ties_break_by_rid():
    events = [
        {"ev": "instant", "name": "request.complete", "t": 1.0, "track": "r0",
         "rid": rid, "attrs": {"ttft": 0.1, "tpot": 0.01, "e2e": 1.0}}
        for rid in (5, 1, 9, 3)
    ]
    rep = analyze(events, {"horizon": 2.0}, topk=3)
    assert [r["rid"] for r in rep["slowest"]] == [1, 3, 5]


# --------------------------------- satellite: counter downsampling
def test_tracer_counter_dt_downsamples_per_series():
    tr = Tracer("replica", counter_dt=1.0)
    for i in range(10):
        tr.counter("queue", 0.25 * i, float(i), "r0")   # every 0.25s
        tr.counter("kv_used", 0.25 * i, float(i), "r0")
    tr.counter("queue", 0.0, 0.0, "r1")  # other track: independent budget
    qs = [e for e in tr.events if e["name"] == "queue" and e["track"] == "r0"]
    assert [e["t"] for e in qs] == [0.0, 1.0, 2.0]
    assert len([e for e in tr.events if e["name"] == "kv_used"]) == 3
    assert len([e for e in tr.events if e["track"] == "r1"]) == 1
    # dt=0 (the default) keeps every sample
    tr0 = Tracer("replica")
    for i in range(10):
        tr0.counter("queue", 0.25 * i, float(i), "r0")
    assert len(tr0.events) == 10


def test_tracer_sink_sees_events_and_sink_emits_are_recorded():
    class Sink:
        def __init__(self):
            self.seen = []
            self.tr = None

        def bind(self, tracer):
            self.tr = tracer

        def on_event(self, ev):
            self.seen.append(ev["name"])
            if ev["name"] == "ping":
                # sink-emitted events are recorded but not re-dispatched
                self.tr.instant("pong", ev["t"])

    tr = Tracer("request")
    sink = Sink()
    tr.add_sink(sink)
    tr.instant("ping", 1.0)
    assert sink.seen == ["ping"]
    assert [e["name"] for e in tr.events] == ["ping", "pong"]
    # keep_events=False: sink-only mode records nothing
    tr2 = Tracer("request", keep_events=False)
    tr2.add_sink(sink)
    tr2.instant("ping", 2.0)
    assert tr2.events == [] and sink.seen == ["ping", "ping"]
