"""Scale-tier properties of the vectorized engine (slow tier).

Fleet-scale runs are exactly where a vectorized refactor can go subtly
wrong — a dropped wake-up, a double-harvest, a KV ledger that drifts
under autoscale churn and crashes. Differential parity (see
`test_engine_parity.py`) pins small configurations bit-for-bit against
the reference engine; these tests pin the *invariants* at sizes where
running the reference oracle would be too slow, across multiple seeds:

  * conservation — every generated request is accounted for exactly
    once: completed + shed + lost == generated, with no duplicate
    completions;
  * KV capacity — no replica's peak KV ledger ever exceeds its budget;
  * causality — per-record timestamps stay ordered.
"""

import pytest

from repro.configs import get_config
from repro.sim import LengthDist, SchedConfig, Workload
from repro.cluster import (
    AutoscaleConfig,
    ChaosConfig,
    ClusterSpec,
    ReplicaSpec,
    simulate_cluster,
    summarize_cluster,
)

CFG = get_config("qwen3_14b")
REPLICAS = 200
REQUESTS = 100_000


def _fleet_run(seed: int):
    reqs = Workload(
        qps=REPLICAS * 6.0, num_requests=REQUESTS, arrival="diurnal",
        prompt=LengthDist("lognormal", 96, 0.4, lo=8, hi=512),
        output=LengthDist("lognormal", 48, 0.4, lo=4, hi=256),
        seed=seed).generate()
    spec = ClusterSpec(
        replicas=tuple(
            ReplicaSpec(pool="mixed", sched=SchedConfig(slots=16),
                        ctx_quantum=32)
            for _ in range(REPLICAS)),
        chaos=ChaosConfig(seed=seed, horizon=30.0, crash_rate=0.02,
                          straggler_rate=0.05))
    autoscale = AutoscaleConfig(policy="rate", min_replicas=REPLICAS // 2,
                                max_replicas=REPLICAS, interval=5.0)
    cres = simulate_cluster(reqs, CFG, spec, autoscale=autoscale,
                            engine="vectorized")
    return reqs, cres


@pytest.mark.slow
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_fleet_scale_conservation_and_kv(seed):
    reqs, cres = _fleet_run(seed)
    # conservation: exactly-once accounting over the full request set
    done = [r.rid for r in cres.records]
    assert len(done) == len(set(done)), "request completed twice"
    assert len(done) + len(cres.shed) + cres.requests_lost == len(reqs)
    # KV-capacity invariant per replica, including crashed/drained ones
    for rep in cres.replica_results:
        assert rep.peak_kv <= rep.kv_capacity
    # causality on every completed record
    for r in cres.records:
        assert r.finish >= r.first_token >= r.admitted >= r.arrival
    # the summary must roll up without error at this size
    s = summarize_cluster(cres, slo_ttft=1.0, slo_tpot=0.1)
    assert s["iterations"] > REQUESTS  # at least one step per request
