"""repro.cluster.prefixcache: bit-for-bit parity of the infinite-budget
cache with the legacy unconditional `hit_frac` discount, budget/hit
invariants across seeds, LRU + TTL eviction mechanics, cross-session
prefix sharing, drain invalidation (autoscale churn pays a re-warm
cost), router state pruning on retire, shared-prefix workload
generation, and 6-sig-fig goldens for the cache-aware affinity summary."""

import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.sim import LengthDist, SchedConfig, SimRequest, Workload
from repro.cluster import (
    AutoscaleConfig,
    ClusterSpec,
    PrefixCacheConfig,
    ReplicaPrefixCache,
    ReplicaSpec,
    ReplicaView,
    make_router,
    simulate_cluster,
    summarize_cluster,
)
from repro.cluster.cluster import _ClusterEngine
from repro.cluster.prefixcache import prefix_cap, prefix_key

CFG = get_config("qwen3_14b")
INF_CACHE = PrefixCacheConfig(budget_bytes=math.inf, ttl=None)


def _wl(**kw):
    base = dict(
        qps=50.0, num_requests=40, arrival="poisson",
        prompt=LengthDist("lognormal", 96, 0.4, lo=8, hi=512),
        output=LengthDist("lognormal", 24, 0.4, lo=2, hi=128),
        seed=0, num_sessions=6,
    )
    base.update(kw)
    return Workload(**base)


def _spec(pools, *, sched=None, router="affinity", **kw):
    sched = sched or SchedConfig(slots=8)
    return ClusterSpec(
        replicas=tuple(ReplicaSpec(hw="h100", pool=p, sched=sched,
                                   ctx_quantum=32) for p in pools),
        router=router, **kw)


def _records_key(cres):
    return [(r.rid, r.admitted, r.first_token, r.finish)
            for r in sorted(cres.records, key=lambda r: r.rid)]


class _UnitCost:
    """Stub cost model: 1 byte per resident token (unit arithmetic)."""

    def kv_bytes(self, ctx, *, exact=False):
        return float(max(int(ctx), 0))


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("pools", [["mixed"] * 3,
                                   ["prefill", "prefill", "decode", "decode"]])
@pytest.mark.parametrize("hit_frac", [0.5, 0.9])
def test_infinite_cache_reproduces_unconditional_discount(pools, hit_frac):
    # the acceptance contract: an infinite-budget, no-TTL cache with
    # per-session prefix groups IS the legacy hit_frac affinity router —
    # same assignments, same records, same summary, same hit count
    reqs = _wl().generate()
    legacy = simulate_cluster(reqs, CFG, _spec(pools, hit_frac=hit_frac))
    cached = simulate_cluster(
        reqs, CFG, _spec(pools, hit_frac=hit_frac, prefix_cache=INF_CACHE))
    assert cached.assignments == legacy.assignments
    assert _records_key(cached) == _records_key(legacy)
    assert cached.prefix_hits == legacy.prefix_hits
    sa = summarize_cluster(legacy, slo_ttft=2.0, slo_tpot=0.05)
    sb = summarize_cluster(cached, slo_ttft=2.0, slo_tpot=0.05)
    for k in ("ttft_p95", "tpot_p95", "goodput_frac", "tokens_per_s",
              "iterations", "preemptions", "prefix_hits"):
        assert sb[k] == sa[k], k
    # the cache never evicted or expired anything
    assert cached.cache_stats["evictions_lru"] == 0
    assert cached.cache_stats["evictions_ttl"] == 0


# -------------------------------------------------------- cache mechanics
def test_lru_eviction_under_byte_budget():
    c = ReplicaPrefixCache(budget=100.0, ttl=None, cost=_UnitCost())
    g = [SimRequest(i, 0.0, 200, 4, prefix_group=i, prefix_len=40)
         for i in range(4)]
    assert c.use(g[0], 1.0, 0.5) == 0  # cold miss inserts group 0
    assert c.use(g[1], 2.0, 0.5) == 0
    assert c.used_bytes == 80.0
    assert c.use(g[2], 3.0, 0.5) == 0  # 120 > 100: evicts LRU (group 0)
    assert c.evictions_lru == 1 and c.used_bytes == 80.0
    assert c.use(g[0], 4.0, 0.5) == 0  # group 0 is gone -> miss again
    assert c.use(g[2], 5.0, 0.5) == 40  # group 2 survived (recently used)
    assert c.peak_bytes <= c.budget


def test_ttl_expiry_and_recency_refresh():
    c = ReplicaPrefixCache(budget=1e9, ttl=10.0, cost=_UnitCost())
    req = SimRequest(0, 0.0, 200, 4, prefix_group=1, prefix_len=64)
    c.use(req, 0.0, 0.5)
    assert c.resident_tokens(req, 9.0, 0.5) == 64  # within TTL
    assert c.resident_tokens(req, 11.0, 0.5) == 0  # expired (read-only)
    c.commit(req, 8.0)  # prefill completion refreshes recency
    assert c.resident_tokens(req, 17.0, 0.5) == 64
    assert c.use(req, 30.0, 0.5) == 0  # expired for real: swept + re-inserted
    assert c.evictions_ttl == 1


def test_oversized_prefix_is_rejected_not_inserted():
    c = ReplicaPrefixCache(budget=32.0, ttl=None, cost=_UnitCost())
    req = SimRequest(0, 0.0, 200, 4, prefix_group=0, prefix_len=64)
    assert c.use(req, 0.0, 0.5) == 0
    assert c.rejected == 1 and c.used_bytes == 0.0
    assert c.resident_tokens(req, 1.0, 0.5) == 0


def test_session_entries_pin_whole_context():
    # a session entry models the conversation KV staying resident: the
    # follow-up's hit is capped by its OWN hit_frac share, whatever the
    # earlier turn's prompt was (what makes infinite-budget parity exact)
    c = ReplicaPrefixCache(budget=1e9, ttl=None, cost=_UnitCost())
    c.use(SimRequest(0, 0.0, 10, 4, session=3), 0.0, 0.5)
    big = SimRequest(1, 0.0, 1000, 4, session=3)
    assert c.use(big, 1.0, 0.5) == 500  # int(1000 * 0.5), not 10


# ---------------------------------------------------------- property tests
def test_budget_and_hit_invariants_across_seeds():
    # resident bytes never exceed the budget, and a hit never exceeds the
    # request's own cacheable prefix or the tokens actually resident at
    # lookup time — across seeds, budgets, and TTLs
    for seed in range(6):
        rng = np.random.default_rng(seed)
        budget = float(rng.integers(50, 400))
        ttl = None if seed % 2 else float(rng.integers(2, 20))
        c = ReplicaPrefixCache(budget=budget, ttl=ttl, cost=_UnitCost())
        t = 0.0
        for i in range(300):
            t += float(rng.exponential(1.0))
            prompt = int(rng.integers(1, 300))
            if rng.random() < 0.5:
                req = SimRequest(i, t, prompt, 4,
                                 prefix_group=int(rng.integers(0, 8)),
                                 prefix_len=min(int(rng.integers(0, 200)),
                                                prompt - 1))
            else:
                req = SimRequest(i, t, prompt, 4,
                                 session=int(rng.integers(0, 8)))
            resident = c.resident_tokens(req, t, 0.5)
            hit = c.use(req, t, 0.5)
            assert hit == resident  # use() realizes exactly what was resident
            assert hit <= prefix_cap(req, 0.5) <= max(prompt - 1, 0)
            assert c.used_bytes <= c.budget + 1e-9
            assert c.peak_bytes <= c.budget + 1e-9
            if rng.random() < 0.05:
                c.invalidate()
                assert c.used_bytes == 0.0 and not c.entries


def test_cluster_run_respects_per_replica_budgets_across_seeds():
    for seed in (0, 1, 2):
        reqs = _wl(seed=seed, num_requests=48, num_sessions=4,
                   num_prefix_groups=3,
                   prefix=LengthDist("fixed", 64.0)).generate()
        pc = PrefixCacheConfig(budget_frac=0.001, ttl=1.0)
        cres = simulate_cluster(reqs, CFG, _spec(["mixed"] * 3, prefix_cache=pc))
        for st in cres.cache_stats["per_replica"].values():
            assert st["peak_resident_bytes"] <= st["budget_bytes"] + 1e-6
        # the carve-out shrank the live-sequence budget, and it still held
        for rep in cres.replica_results:
            assert rep.peak_kv <= rep.kv_capacity
        assert sorted(r.rid for r in cres.records) == list(range(48))


# -------------------------------------------------- cross-session sharing
def test_prefix_group_shared_across_sessions():
    # two sessions share one system prompt: the second session's FIRST
    # request is steered to the warm replica and skips the group prefix —
    # impossible under the per-session legacy model
    reqs = [
        SimRequest(0, 0.00, 256, 2, session=0, prefix_group=0, prefix_len=128),
        SimRequest(1, 0.01, 300, 2, session=1, prefix_group=0, prefix_len=128),
    ]
    cres = simulate_cluster(
        reqs, CFG, _spec(["mixed"] * 2, prefix_cache=INF_CACHE))
    assert cres.assignments[1] == cres.assignments[0]  # steered to warmth
    assert cres.prefix_hits == 1
    assert cres.cache_stats["hit_tokens"] == 128
    # legacy model: different sessions never share
    legacy = simulate_cluster(reqs, CFG, _spec(["mixed"] * 2))
    assert legacy.prefix_hits == 0


def test_finite_budget_loses_hits_vs_infinite():
    reqs = _wl(num_requests=60, num_sessions=8, num_prefix_groups=4,
               prefix=LengthDist("fixed", 64.0)).generate()
    inf = simulate_cluster(reqs, CFG, _spec(["mixed"] * 2,
                                            prefix_cache=INF_CACHE))
    tiny = simulate_cluster(
        reqs, CFG,
        _spec(["mixed"] * 2,
              prefix_cache=PrefixCacheConfig(budget_frac=0.0005)))
    assert tiny.cache_stats["evictions_lru"] > 0
    assert tiny.cache_stats["hit_tokens"] < inf.cache_stats["hit_tokens"]


# ------------------------------------------------- drain / retire semantics
def _drain_run(prefix_cache):
    # a burst (scale-up) then silence with a lone straggler: the rate
    # tracker drains the extra replicas once the burst passes, so at
    # least one accepting replica drains mid-run
    reqs = [SimRequest(i, 0.1 * i, 96, 16, session=i % 20) for i in range(40)]
    reqs.append(SimRequest(40, 30.0, 96, 4, session=0))
    asc = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=4,
                          interval=0.25, window=1.0,
                          target_qps_per_replica=4.0, warmup=0.5)
    spec = _spec(["mixed"], prefix_cache=prefix_cache)
    eng = _ClusterEngine(spec, CFG, asc, {})
    eng.run(sorted(reqs, key=lambda r: (r.arrival, r.rid)))
    return eng, eng.result()


def test_drain_invalidates_cache_and_rewarms():
    eng, cres = _drain_run(INF_CACHE)
    drains = [e for e in cres.scale_events if e["action"] == "drain"]
    assert drains, "scenario must actually drain a replica"
    assert cres.cache_stats["invalidations"] >= 1
    # invalidated replicas hold nothing; sessions re-warm elsewhere
    for i, cache in eng.pcache.caches.items():
        if eng.reps[i].retired >= 0 or eng.reps[i].draining:
            assert not cache.entries
    assert sorted(r.rid for r in cres.records) == list(range(41))
    # hit accounting is per SERVED request: drain requeues retract the
    # count from the dispatch whose prefill never ran (all 41 requests
    # carry a session, so each is counted exactly once)
    cs = cres.cache_stats
    assert cs["hits"] + cs["misses"] == 41


def test_routers_prune_state_on_retire():
    # the lifecycle hook: retired replicas vanish from AffinityRouter._home
    eng, cres = _drain_run(INF_CACHE)
    retired = {i for i, rep in enumerate(eng.reps) if rep.retired >= 0}
    assert retired, "scenario must actually retire a replica"
    assert not retired & set(eng.router._home.values())
    assert eng.router._home, "live sessions stay pinned"


def test_on_retire_hooks_prune_router_state_directly():
    views = [ReplicaView(i, 0.0, 0, 0, 0.0, 1.0) for i in range(3)]
    aff = make_router("affinity", hit_frac=0.5)
    for s, reqid in ((0, 0), (1, 1)):
        aff.pick(SimRequest(reqid, 0.0, 64, 2, session=s), views[s:s + 1])
    assert set(aff._home.values()) == {0, 1}
    aff.on_retire(0)
    assert aff._home == {1: 1}  # session 0's pin went with the replica
    debt = make_router("slo_debt", slo_ttft=1.0, debt_window=10.0)
    debt.observe(0, 1.0, 5.0)
    debt.observe(2, 1.0, 5.0)
    assert set(debt._obs) == {0, 2}
    debt.on_retire(0)
    assert set(debt._obs) == {2}
    debt.on_retire(7)  # unknown idx is a no-op
    base = make_router("jsq")
    base.on_retire(0)  # stateless policies ignore the hook


# ------------------------------------------------------ workload generation
def test_prefix_groups_do_not_perturb_base_stream():
    # adding prefix groups draws AFTER everything else: arrivals, lengths,
    # sessions, and SLOs are bit-identical to the group-free spec
    plain = _wl(slo_ttft=(1.0, 2.0)).generate()
    grouped = _wl(slo_ttft=(1.0, 2.0), num_prefix_groups=4,
                  prefix=LengthDist("lognormal", 128.0, 0.5)).generate()
    for a, b in zip(plain, grouped):
        assert (a.arrival, a.prompt, a.output, a.session, a.slo_ttft) == \
            (b.arrival, b.prompt, b.output, b.session, b.slo_ttft)
        assert (a.prefix_group, a.prefix_len) == (-1, 0)
        assert 0 <= b.prefix_group < 4
        assert 0 <= b.prefix_len <= b.prompt - 1
    # one prefix length per GROUP, deterministic in the seed
    by_group = {}
    for r in grouped:
        by_group.setdefault(r.prefix_group, set()).add(
            r.prefix_len if r.prefix_len < r.prompt - 1 else "capped")
    assert grouped == _wl(slo_ttft=(1.0, 2.0), num_prefix_groups=4,
                          prefix=LengthDist("lognormal", 128.0, 0.5)).generate()


def test_trace_replay_parses_prefix_fields(tmp_path):
    p = tmp_path / "trace.jsonl"
    p.write_text(
        '{"arrival": 0.0, "prompt": 100, "output": 4, "prefix_group": 2, '
        '"prefix_len": 64}\n'
        '{"arrival": 0.5, "prompt": 10, "output": 4, "prefix_group": 2, '
        '"prefix_len": 64}\n'
        '{"arrival": 1.0, "prompt": 50, "output": 4}\n')
    reqs = Workload(trace_path=str(p)).generate()
    assert (reqs[0].prefix_group, reqs[0].prefix_len) == (2, 64)
    assert (reqs[1].prefix_group, reqs[1].prefix_len) == (2, 9)  # capped
    assert (reqs[2].prefix_group, reqs[2].prefix_len) == (-1, 0)


def test_prefix_key_and_cap_precedence():
    r = SimRequest(0, 0.0, 100, 4, session=3, prefix_group=5, prefix_len=30)
    assert prefix_key(r) == ("g", 5)  # explicit group wins over session
    assert prefix_cap(r, 0.9) == 30
    s = SimRequest(1, 0.0, 100, 4, session=3)
    assert prefix_key(s) == ("s", 3)
    assert prefix_cap(s, 0.9) == 90
    assert prefix_key(SimRequest(2, 0.0, 100, 4)) is None
    assert prefix_cap(SimRequest(3, 0.0, 1, 4, session=3), 0.9) == 0


# --------------------------------------------------------------- validation
def test_prefix_cache_config_validation():
    with pytest.raises(ValueError, match="budget_frac"):
        PrefixCacheConfig(budget_frac=1.0).validate()
    with pytest.raises(ValueError, match="budget_bytes"):
        PrefixCacheConfig(budget_bytes=-1.0).validate()
    with pytest.raises(ValueError, match="ttl"):
        PrefixCacheConfig(ttl=0.0).validate()
    PrefixCacheConfig(budget_frac=0.0).validate()  # 0 = cache disabled
    assert INF_CACHE.infinite
    assert not PrefixCacheConfig(budget_frac=0.5).infinite
    assert PrefixCacheConfig(budget_bytes=1e9).budget_for(5e9) == 1e9
    assert PrefixCacheConfig(budget_frac=0.2).budget_for(5e9) == 1e9
    static = SchedConfig(policy="static", slots=8)
    with pytest.raises(ValueError, match="mid-stream"):
        simulate_cluster([], CFG, _spec(["mixed"], sched=static, router="jsq",
                                        prefix_cache=INF_CACHE))


# --------------------------------------------------------- golden regression
def _sig6(x: float) -> float:
    return float(f"{x:.6g}")


def test_golden_cache_aware_affinity_summary_pinned():
    # fixed-seed cache-aware runs pinned to 6 significant figures: catches
    # silent drift in cache/eviction/carve-out arithmetic that behavioral
    # tests cannot see. If a deliberate model change moves these, re-pin
    # in the same PR and say why in the commit message.
    reqs = _wl(num_requests=48, num_sessions=6, num_prefix_groups=3,
               prefix=LengthDist("fixed", 64.0)).generate()
    pc = PrefixCacheConfig(budget_frac=0.0005, ttl=5.0)
    golden = {
        ("mixed", "mixed"): dict(
            ttft_p50=0.0437866,
            ttft_p95=0.344535,
            tpot_p50=0.01464,
            tpot_p95=0.0172001,
            e2e_mean=0.435831,
            tokens_per_s=621.098,
            goodput_frac=1.0,
            makespan_s=1.83868,
            cache_hit_tokens=1974.0,
            cache_hit_rate=0.666667,
            cache_resident_gb=0.0209715,
            cache_evictions=12.0,
            prefix_hits=32.0,
        ),
        ("prefill", "decode"): dict(
            ttft_p50=0.0129687,
            ttft_p95=0.0283952,
            tpot_p50=0.0171579,
            tpot_p95=0.0376659,
            e2e_mean=0.504154,
            tokens_per_s=583.706,
            goodput_frac=0.979167,
            makespan_s=1.95646,
            cache_hit_tokens=2138.0,
            cache_hit_rate=0.729167,
            cache_resident_gb=0.0209715,
            cache_evictions=11.0,
            prefix_hits=35.0,
        ),
    }
    for pools, want in golden.items():
        cres = simulate_cluster(reqs, CFG, _spec(list(pools), prefix_cache=pc))
        s = summarize_cluster(cres, slo_ttft=2.0, slo_tpot=0.05)
        got = {k: _sig6(s[k]) for k in want}
        assert got == want, f"golden drift for pools={pools}"
