"""repro.cluster: single-replica parity with `repro.sim.simulate`, request
conservation across replicas/pools under preemption, router determinism,
disaggregated KV-transfer pricing, and the capacity planner."""

import pytest

from repro.configs import get_config
from repro.core import comm as C
from repro.core.hardware import H100_SXM
from repro.sim import (
    LengthDist,
    ReplicaSim,
    SchedConfig,
    ServingCostModel,
    SimRequest,
    Workload,
    simulate,
)
from repro.cluster import (
    ClusterSpec,
    ReplicaSpec,
    ReplicaView,
    make_router,
    plan_capacity,
    pool_summaries,
    simulate_cluster,
    summarize_cluster,
)

CFG = get_config("qwen3_14b")


def _wl(**kw):
    base = dict(
        qps=50.0, num_requests=24, arrival="poisson",
        prompt=LengthDist("lognormal", 96, 0.4, lo=8, hi=512),
        output=LengthDist("lognormal", 24, 0.4, lo=2, hi=128), seed=0,
    )
    base.update(kw)
    return Workload(**base)


def _spec(pools, *, sched=None, router="jsq", hw="h100", **kw):
    sched = sched or SchedConfig(slots=8)
    return ClusterSpec(
        replicas=tuple(ReplicaSpec(hw=hw, pool=p, sched=sched, ctx_quantum=32)
                       for p in pools),
        router=router, **kw)


# ------------------------------------------------------- single-replica parity
@pytest.mark.parametrize("policy", ["static", "continuous", "chunked"])
def test_single_replica_cluster_matches_simulate(policy):
    reqs = _wl().generate()
    sc = SchedConfig(policy=policy, slots=8)
    direct = simulate(reqs, ServingCostModel(CFG, H100_SXM, ctx_quantum=32), sc)
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"], sched=sc))
    assert cres.mode == "colocated"
    [rep] = cres.replica_results
    assert rep.iterations == direct.iterations
    assert rep.decode_steps == direct.decode_steps
    assert rep.peak_kv == direct.peak_kv
    assert rep.admit_order == direct.admit_order
    got = sorted(cres.records, key=lambda r: r.rid)
    want = sorted(direct.records, key=lambda r: r.rid)
    for a, b in zip(got, want):
        assert (a.admitted, a.first_token, a.finish) == \
            (b.admitted, b.first_token, b.finish)


# ------------------------------------------------------------- conservation
@pytest.mark.parametrize("pools", [
    ["mixed"] * 3,
    ["prefill", "decode", "decode"],
    ["prefill", "prefill", "decode"],
])
def test_cluster_request_conservation_under_pressure(pools):
    # KV budgets tight enough to force queueing/preemption on the serving
    # pools; every request must still finish exactly once, in causal order
    reqs = _wl(num_requests=20, qps=200.0).generate()
    cost = ServingCostModel(CFG, H100_SXM, ctx_quantum=32)
    cap = 3.0 * max(cost.kv_bytes(r.prompt + r.output) for r in reqs)
    sc = SchedConfig(slots=8, kv_capacity=cap)
    cres = simulate_cluster(reqs, CFG, _spec(pools, sched=sc))
    assert sorted(r.rid for r in cres.records) == list(range(20))
    for r in cres.records:
        assert r.finish >= r.first_token >= r.arrival
        assert r.admitted >= r.arrival
    for rep in cres.replica_results:
        assert rep.peak_kv <= rep.kv_capacity
    # every request was assigned, and stage records cover every rid once
    assert set(cres.assignments) == set(range(20))
    staged = sorted(rec.rid for rep in cres.replica_results
                    for rec in rep.records if rec.prompt > 0)
    if cres.mode == "colocated":
        assert staged == list(range(20))


def test_preemption_exercised_in_cluster():
    reqs = _wl(num_requests=20, qps=500.0,
               prompt=LengthDist("lognormal", 128, 0.5, lo=16, hi=512),
               output=LengthDist("lognormal", 64, 0.5, lo=8, hi=256)).generate()
    cost = ServingCostModel(CFG, H100_SXM, ctx_quantum=32)
    cap = 2.5 * max(cost.kv_bytes(r.prompt + r.output) for r in reqs)
    sc = SchedConfig(slots=8, kv_capacity=cap)
    cres = simulate_cluster(reqs, CFG, _spec(["prefill", "decode"], sched=sc))
    assert sum(r.preemptions for r in cres.replica_results) > 0
    assert sorted(r.rid for r in cres.records) == list(range(20))
    assert all(r.finish >= r.first_token >= r.arrival for r in cres.records)


# ------------------------------------------------------------------- routing
def test_router_determinism_under_fixed_seed():
    reqs = _wl(num_requests=32, num_sessions=4).generate()
    for router in ("round_robin", "jsq", "least_kv", "affinity"):
        a = simulate_cluster(reqs, CFG, _spec(["mixed"] * 3, router=router))
        b = simulate_cluster(reqs, CFG, _spec(["mixed"] * 3, router=router))
        assert a.assignments == b.assignments
        assert [(r.first_token, r.finish) for r in a.records] == \
            [(r.first_token, r.finish) for r in b.records]


def test_round_robin_cycles():
    reqs = [SimRequest(i, float(i), 32, 2) for i in range(8)]
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"] * 4, router="round_robin"))
    assert [cres.assignments[i][0] for i in range(8)] == [0, 1, 2, 3, 0, 1, 2, 3]


def test_jsq_spreads_simultaneous_arrivals():
    reqs = [SimRequest(i, 0.0, 64, 4) for i in range(4)]
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"] * 4, router="jsq"))
    assert sorted(cres.assignments[i][0] for i in range(4)) == [0, 1, 2, 3]


def test_affinity_pins_sessions_and_discounts_prefill():
    # same session keeps landing on its home replica and prefill gets cheaper
    reqs = [SimRequest(i, float(i) * 0.001, 256, 2, session=i % 2)
            for i in range(10)]
    cres = simulate_cluster(
        reqs, CFG, _spec(["mixed"] * 2, router="affinity", hit_frac=0.5))
    homes = {s: {cres.assignments[r.rid][0] for r in reqs if r.session == s}
             for s in (0, 1)}
    assert all(len(h) == 1 for h in homes.values())
    assert cres.prefix_hits == 8  # all but the first request of each session
    # the modeled discount: a prefix-cached push prefills only the suffix
    cost = ServingCostModel(CFG, H100_SXM, ctx_quantum=1)
    cold = ReplicaSim(cost, SchedConfig(slots=1))
    cold.push(SimRequest(0, 0.0, 256, 2))
    warm = ReplicaSim(cost, SchedConfig(slots=1))
    warm.push(SimRequest(0, 0.0, 256, 2), cached=128)
    cold.run(), warm.run()
    assert warm.res.records[0].ttft < cold.res.records[0].ttft


# ------------------------------------------------------ disaggregated pricing
def test_disagg_prices_nonzero_p2p_transfer():
    reqs = _wl(num_requests=16, qps=20.0).generate()
    spec = _spec(["prefill", "decode"])
    cres = simulate_cluster(reqs, CFG, spec)
    multi = [r for r in reqs if r.output > 1]
    assert cres.xfer_count == len(multi)
    assert cres.xfer_seconds > 0
    cost = ServingCostModel(CFG, H100_SXM, ctx_quantum=32)
    net = cost.hw.net[-1]
    want_bytes = sum(cost.kv_handoff_bytes(r.prompt) for r in multi)
    assert cres.xfer_bytes == pytest.approx(want_bytes)
    assert cres.xfer_seconds == pytest.approx(
        sum(C.p2p(cost.kv_handoff_bytes(r.prompt), net) for r in multi))
    s = summarize_cluster(cres, slo_ttft=2.0, slo_tpot=0.05)
    assert s["xfer_share"] > 0
    pools = pool_summaries(cres)
    assert set(pools) == {"prefill", "decode"}
    assert pools["prefill"]["requests"] == 16  # every request prefills once
    assert pools["decode"]["requests"] == len(multi)


def test_disagg_transfer_gap_appears_between_first_and_second_token():
    # one request, one replica per pool: the decode stage cannot begin
    # before prefill finish + the p2p transfer time
    req = SimRequest(0, 0.0, 512, 8)
    cres = simulate_cluster([req], CFG, _spec(["prefill", "decode"]))
    [rec] = cres.records
    cost = ServingCostModel(CFG, H100_SXM, ctx_quantum=32)
    dt = C.p2p(cost.kv_handoff_bytes(512), cost.hw.net[-1])
    decode_rec = cres.replica_results[1].records[0]
    assert decode_rec.arrival == pytest.approx(rec.first_token + dt)
    assert rec.finish > rec.first_token + dt


def test_heterogeneous_replicas_prefer_faster_hardware_equally_loaded():
    # an H100 replica drains faster than an A100 one, so JSQ sends it more
    reqs = _wl(num_requests=32, qps=100.0).generate()
    spec = ClusterSpec(replicas=(
        ReplicaSpec(hw="a100", pool="mixed", sched=SchedConfig(slots=8),
                    ctx_quantum=32),
        ReplicaSpec(hw="h100", pool="mixed", sched=SchedConfig(slots=8),
                    ctx_quantum=32),
    ))
    cres = simulate_cluster(reqs, CFG, spec)
    counts = [0, 0]
    for i, _ in cres.assignments.values():
        counts[i] += 1
    assert counts[1] > counts[0]


# ---------------------------------------------------------------- validation
def test_static_replicas_reject_midstream_entry():
    # static batching can't resume from cached state: the push fails fast
    # and the cluster combinations that require it are refused up front
    cost = ServingCostModel(CFG, H100_SXM, ctx_quantum=32)
    sim = ReplicaSim(cost, SchedConfig(policy="static"))
    with pytest.raises(ValueError, match="mid-stream"):
        sim.push(SimRequest(0, 0.0, 64, 4), cached=32)
    static = SchedConfig(policy="static", slots=8)
    with pytest.raises(ValueError, match="handoff"):
        simulate_cluster([], CFG, _spec(["prefill", "decode"], sched=static))
    with pytest.raises(ValueError, match="affinity"):
        simulate_cluster([], CFG,
                         _spec(["mixed"] * 2, sched=static, router="affinity"))
    # static colocated without prefix discounts remains supported
    reqs = _wl(num_requests=8).generate()
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"] * 2, sched=static))
    assert sorted(r.rid for r in cres.records) == list(range(8))


def test_cluster_pool_validation():
    with pytest.raises(ValueError, match="decode"):
        simulate_cluster([], CFG, _spec(["prefill", "prefill"]))
    with pytest.raises(ValueError, match="mixed"):
        simulate_cluster([], CFG, _spec(["mixed", "prefill", "decode"]))
    with pytest.raises(ValueError, match="at least one replica"):
        simulate_cluster([], CFG, ClusterSpec(replicas=()))
    with pytest.raises(ValueError, match="unknown router"):
        make_router("random")


# ---------------------------------------------------------- router coverage
def test_affinity_hit_accounting_and_single_token_prompt_cap():
    router = make_router("affinity", hit_frac=0.9)
    views = [ReplicaView(i, 0.0, 0, 0, 0.0, 1.0) for i in range(2)]
    # sessionless requests never hit and never pin
    assert router.pick(SimRequest(0, 0.0, 64, 2, session=-1), views) == (0, 0)
    assert (router.hits, router.misses) == (0, 1)
    # first request of a session pins, follow-ups hit
    assert router.pick(SimRequest(1, 0.0, 100, 2, session=7), views) == (0, 0)
    assert (router.hits, router.misses) == (0, 2)
    idx, cached = router.pick(SimRequest(2, 0.0, 100, 2, session=7), views)
    assert (idx, cached) == (0, 90)
    assert (router.hits, router.misses) == (1, 2)
    # a 1-token prompt can never be fully cached: the final prompt token
    # must run to produce the first logits -> cached caps at prompt - 1 = 0.
    # A 0-token discount is NOT a hit, even though placement followed home
    # (the hit counter reports realized discounts, not placement affinity)
    idx, cached = router.pick(SimRequest(3, 0.0, 1, 2, session=7), views)
    assert (idx, cached) == (0, 0)
    assert (router.hits, router.misses) == (1, 3)
    # 2-token prompt at hit_frac=0.9: int(1.8) = 1 <= prompt - 1
    assert router.pick(SimRequest(4, 0.0, 2, 2, session=7), views) == (0, 1)
    assert router.hits == 2
    # a home replica that left the eligible set is a miss and re-pins
    assert router.pick(SimRequest(5, 0.0, 100, 2, session=7), views[1:])[0] == 1
    assert router.misses == 4


def test_affinity_zero_token_discount_counts_as_miss():
    # regression (PR 5): pick() used to count a hit whenever placement
    # followed home, even when the discount resolved to 0 cached tokens
    # (int(prompt * hit_frac) == 0), inflating the reported hit rate
    router = make_router("affinity", hit_frac=0.1)
    views = [ReplicaView(i, 0.0, 0, 0, 0.0, 1.0) for i in range(2)]
    router.pick(SimRequest(0, 0.0, 8, 2, session=3), views)  # pins
    # int(4 * 0.1) == 0: home followed, but nothing was actually skipped
    idx, cached = router.pick(SimRequest(1, 0.0, 4, 2, session=3), views)
    assert (idx, cached) == (0, 0)
    assert (router.hits, router.misses) == (0, 2)
    # a request with a real discount still counts
    idx, cached = router.pick(SimRequest(2, 0.0, 40, 2, session=3), views)
    assert (idx, cached) == (0, 4)
    assert (router.hits, router.misses) == (1, 2)


def test_slo_debt_router_feedback_steers_traffic():
    router = make_router("slo_debt", slo_ttft=1.0, debt_window=100.0)
    views = [ReplicaView(0, 10.0, 5, 5, 0.0, 1.0),  # deeper queue, clean
             ReplicaView(1, 10.0, 0, 0, 0.0, 1.0)]  # empty, but indebted
    # without feedback it degenerates to JSQ: the empty replica wins
    assert router.pick(SimRequest(0, 10.0, 64, 2), views)[0] == 1
    router.observe(1, t=9.0, ttft=5.0)  # replica 1 blew its deadline
    router.observe(0, t=9.0, ttft=0.2)
    assert router.debt(1, 10.0) == 1.0 and router.debt(0, 10.0) == 0.0
    assert router.pick(SimRequest(1, 10.0, 64, 2), views)[0] == 0
    # debt expires out of the rolling window
    assert router.debt(1, 9.0 + 101.0) == 0.0


def test_slo_debt_router_in_cluster_is_deterministic():
    reqs = _wl(num_requests=32, qps=100.0).generate()
    a = simulate_cluster(reqs, CFG, _spec(["mixed"] * 3, router="slo_debt"))
    b = simulate_cluster(reqs, CFG, _spec(["mixed"] * 3, router="slo_debt"))
    assert a.assignments == b.assignments
    assert sorted(r.rid for r in a.records) == list(range(32))


# ---------------------------------------------------------- golden regression
def _sig6(x: float) -> float:
    return float(f"{x:.6g}")


def test_golden_summary_metrics_pinned():
    # fixed-seed run with metrics pinned to 6 significant figures: catches
    # silent cost-model/scheduler drift that behavioral tests cannot see.
    # If a deliberate model change moves these, re-pin them in the same PR
    # and say why in the commit message.
    reqs = _wl().generate()
    golden = {
        ("mixed", "mixed"): dict(
            ttft_p50=0.032202, ttft_p95=0.0527687,
            tpot_p50=0.0137339, tpot_p95=0.0167422,
            e2e_mean=0.37305, tokens_per_s=574.404,
            goodput_frac=1.0, makespan_s=1.06023,
            peak_kv=168919000.0, xfer_gb=0.0),
        ("prefill", "decode"): dict(
            ttft_p50=0.01491, ttft_p95=0.0290749,
            tpot_p50=0.0135294, tpot_p95=0.0192364,
            e2e_mean=0.360331, tokens_per_s=561.169,
            goodput_frac=1.0, makespan_s=1.08523,
            peak_kv=194806000.0, xfer_gb=0.410092),
    }
    for pools, want in golden.items():
        cres = simulate_cluster(reqs, CFG, _spec(list(pools)))
        s = summarize_cluster(cres, slo_ttft=2.0, slo_tpot=0.05)
        got = {k: _sig6(s[k]) for k in want if k != "peak_kv"}
        got["peak_kv"] = _sig6(max(r.peak_kv for r in cres.replica_results))
        assert got == want, f"golden drift for pools={pools}"


# ------------------------------------------------------------------- planner
def test_planner_honors_sched_config():
    # the sweep must price the scheduler it was asked to plan for: one slot
    # per replica serializes requests, so attainment collapses vs 8 slots
    wl = _wl(num_requests=16)
    kw = dict(qps=16.0, slo_ttft=1.0, slo_tpot=0.05, attainment=0.95,
              max_replicas=1, modes=("colocated",), ctx_quantum=32)
    wide = plan_capacity(CFG, wl, sched=SchedConfig(slots=8), **kw)
    narrow = plan_capacity(CFG, wl, sched=SchedConfig(slots=1), **kw)
    assert narrow["rows"][0]["goodput_frac"] < wide["rows"][0]["goodput_frac"]


def test_capacity_planner_finds_cheapest_feasible():
    wl = _wl(num_requests=24)
    plan = plan_capacity(
        CFG, wl, qps=8.0, slo_ttft=5.0, slo_tpot=0.05, attainment=0.9,
        max_replicas=3, modes=("colocated",), ctx_quantum=32,
        sched=SchedConfig(slots=8))
    assert plan["best"] is not None
    best = plan["best"]
    assert best["feasible"] and best["goodput_frac"] >= 0.9
    # cheapest means no feasible row is cheaper
    for r in plan["rows"]:
        if r["feasible"]:
            assert best["cost_per_hr"] <= r["cost_per_hr"]
    # cost scales with replica count x tp x $/dev-hr
    one = next(r for r in plan["rows"] if r["replicas"] == 1)
    assert one["cost_per_hr"] == pytest.approx(3.9)


def test_capacity_planner_reports_infeasible_when_slo_impossible():
    wl = _wl(num_requests=12)
    plan = plan_capacity(
        CFG, wl, qps=50.0, slo_ttft=1e-6, slo_tpot=1e-9, attainment=0.99,
        max_replicas=2, modes=("colocated",), ctx_quantum=32)
    assert plan["best"] is None
    assert all(not r["feasible"] for r in plan["rows"])
