"""Validation against the paper's published numbers (EXPERIMENTS.md §Validation).

Thresholds are deliberately looser than the paper's own fitted errors (we
calibrate three scalar factors, the paper fits per-kernel utilization
clusters) but tight enough to catch regressions in the model."""

import numpy as np

from repro.core.hardware import A100_80G, H100_SXM
from repro.core.paper_data import GPT_CONFIGS, LLAMA2_CONFIGS, TABLE1, TABLE2, TABLE4
from repro.core.parallelism import Mapping
from repro.core.predict import gemm_table, inference_latency, train_step_time


def test_table1_training_errors():
    errs = []
    for r in TABLE1:
        cfg = GPT_CONFIGS[r.model]
        m = Mapping(dp=r.dp, tp=r.tp, pp=r.pp, sp=r.sp, microbatch=1,
                    recompute=r.recompute,
                    schedule="interleaved" if r.pp > 1 else "1f1b", vpp=2)
        t = train_step_time(cfg, A100_80G, m, global_batch=r.batch, seq=2048).total
        errs.append(abs(t - r.t_ref) / r.t_ref)
    assert np.mean(errs) < 0.12, np.mean(errs)  # paper: mostly < 10%
    assert max(errs) < 0.20, max(errs)


def test_table2_inference_errors():
    errs = []
    for r in TABLE2:
        cfg = LLAMA2_CONFIGS[r.model]
        for hw, tref in ((A100_80G, r.t_a100_ms), (H100_SXM, r.t_h100_ms)):
            t = inference_latency(cfg, hw, tp=r.tp, batch=1, prompt=200, gen=200).total
            errs.append(abs(t * 1e3 - tref) / tref)
    assert np.mean(errs) < 0.15, np.mean(errs)  # paper: < 13% per row
    assert max(errs) < 0.35, max(errs)


def test_table4_bound_types_match():
    """Every GEMM's compute/memory classification must match the paper."""
    from benchmarks.paper_tables import _T4_MAP

    cfg = LLAMA2_CONFIGS["llama2-13b"]
    for hw, col in ((A100_80G, "a"), (H100_SXM, "h")):
        ts = {t.name: t for t in gemm_table(cfg, hw, tp=1, batch=1, S=200, decode=False)}
        for gemm, t_a, b_a, t_h, b_h in TABLE4:
            want = b_a if col == "a" else b_h
            ops = [ts[n] for n in _T4_MAP[gemm] if n in ts]
            got = "compute" if all(o.bound == "compute" for o in ops) else "memory"
            assert got == want, (hw.name, gemm, got, want)


def test_inference_scales_poorly_with_gpus():
    """Paper §4.3: decode scaling 1->8 GPUs is far from linear."""
    cfg = LLAMA2_CONFIGS["llama2-7b"]
    t1 = inference_latency(cfg, A100_80G, tp=1, batch=1, prompt=200, gen=200).total
    t8 = inference_latency(cfg, A100_80G, tp=8, batch=1, prompt=200, gen=200).total
    speedup = t1 / t8
    assert 1.0 < speedup < 4.0  # NVIDIA measured ~1.85x


def test_dse_saturation_trend():
    """Fig 6: node scaling saturates beyond N5; HBM2->HBM2E is a big jump."""
    from repro.core.dse import optimize_node

    cfg = GPT_CONFIGS["gpt-7b"]
    m = Mapping(dp=64, tp=4, pp=4, sp=True, microbatch=1, recompute="selective")
    t = {
        node: optimize_node(cfg, node, "HBM2", "NDR-x8", mapping=m, global_batch=512,
                            seq=2048).time
        for node in ("N12", "N5", "N1")
    }
    early_gain = t["N12"] / t["N5"]
    late_gain = t["N5"] / t["N1"]
    assert early_gain > 1.5
    assert late_gain < early_gain  # saturation
    t_2e = optimize_node(cfg, "N5", "HBM2E", "NDR-x8", mapping=m, global_batch=512,
                         seq=2048).time
    assert t["N5"] / t_2e > 1.1  # HBM2->HBM2E gain
