"""Optimus analytical-core properties: roofline, comm (eq 3/4), memory
(eq 1/2), KV cache (§3.5), planner — plus hypothesis property tests."""


import pytest
from hypkit import given, settings, st

from repro.configs import get_config
from repro.core import comm as C
from repro.core.hardware import A100_80G, NVLINK3
from repro.core.kvcache import kv_cache_bytes, recurrent_state_bytes
from repro.core.memory import activation_memory, training_memory
from repro.core.paper_data import GPT_CONFIGS, LLAMA2_CONFIGS
from repro.core.parallelism import Mapping
from repro.core.planner import plan
from repro.core.predict import inference_latency, train_step_time
from repro.core.roofline import GEMM, MemOp, gemm_time, op_time


# ------------------------------------------------------------------- roofline
def test_fat_gemm_is_compute_bound():
    t = gemm_time(A100_80G, GEMM("fat", 4096, 4096, 4096))
    assert t.bound == "compute"


def test_gemv_is_memory_bound():
    t = gemm_time(A100_80G, GEMM("gemv", 1, 4096, 4096))
    assert t.bound == "memory"
    # dram term = weight bytes / derated bw (paper's GEMV utilization factor)
    expect = t.dram_bytes / (A100_80G.dram.bw * A100_80G.gemv_dram_util)
    assert abs(t.t_dram - expect) < 1e-9


def test_time_is_max_of_terms():
    t = gemm_time(A100_80G, GEMM("x", 512, 512, 512))
    assert abs(t.t - max(t.t_compute, t.t_dram, t.t_l2)) < 1e-12


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 8192), n=st.integers(64, 8192), k=st.integers(64, 8192),
)
def test_gemm_time_monotone_in_flops(m, n, k):
    t1 = gemm_time(A100_80G, GEMM("a", m, n, k)).t
    t2 = gemm_time(A100_80G, GEMM("b", 2 * m, n, k)).t
    assert t2 >= t1 * 0.999


def test_memop_bandwidth_bound():
    op = MemOp("norm", 1e9)
    t = op_time(A100_80G, op)
    assert t.bound == "memory"
    assert abs(t.t - 1e9 / (A100_80G.dram.bw * A100_80G.dram.util)) < 1e-9


# ----------------------------------------------------------------- comm model
def test_ring_allreduce_eq3():
    K, N = 1e9, 8
    net = NVLINK3
    expect = 2 * K * (N - 1) / (N * net.bw * net.util) + 2 * net.latency * (N - 1)
    assert abs(C.ring_allreduce(K, N, net) - expect) < 1e-12


def test_tree_allreduce_eq4_latency_log():
    K, N = 1e3, 8  # tiny volume: latency-dominated
    ring = C.ring_allreduce(K, N, NVLINK3)
    tree = C.tree_allreduce(K, N, NVLINK3)
    assert tree < ring  # 2*l*log2(8)=6l < 2*l*7=14l
    assert abs((tree - C.tree_allreduce(0, N, NVLINK3)) - 2e3 * (N - 1) / (N * NVLINK3.bw * NVLINK3.util)) < 1e-9


def test_allreduce_single_device_free():
    assert C.ring_allreduce(1e9, 1, NVLINK3) == 0.0


@settings(max_examples=20, deadline=None)
@given(K=st.floats(1e3, 1e10), N=st.integers(2, 512))
def test_ring_bandwidth_term_bounded(K, N):
    # bandwidth term approaches 2K/BW from below as N grows (bw-optimality)
    t = C.ring_allreduce(K, N, NVLINK3) - 2 * NVLINK3.latency * (N - 1)
    assert t <= 2 * K / (NVLINK3.bw * NVLINK3.util) + 1e-9


# --------------------------------------------------------------- memory model
def test_recompute_ordering():
    cfg = GPT_CONFIGS["gpt-22b"]
    kw = dict(b=4, s=2048, tp=8, sp=False)
    a_none = activation_memory(cfg, recompute="none", **kw)
    a_sel = activation_memory(cfg, recompute="selective", **kw)
    a_full = activation_memory(cfg, recompute="full", **kw)
    assert a_full < a_sel < a_none


def test_eq1_full_recompute_formula():
    cfg = GPT_CONFIGS["gpt-22b"]
    from repro.core.memory import activation_per_layer

    t = activation_per_layer(cfg, 4, 2048, 8, False)
    a_tot = t["tp_region"] + t["seq_region"] + t["scores"] + t["moe"]
    expect = cfg.num_layers * t["A_inp"] + (a_tot - t["A_inp"])  # N_ckp = L
    got = activation_memory(cfg, 4, 2048, 8, False, "full")
    assert abs(got - expect) < 1.0


def test_sp_divides_norm_region():
    cfg = GPT_CONFIGS["gpt-175b"]
    no_sp = activation_memory(cfg, 1, 2048, 8, False, "selective")
    sp = activation_memory(cfg, 1, 2048, 8, True, "selective")
    assert sp < no_sp


def test_training_memory_fig4_scale():
    """GPT-175B tp8/pp8 with full recompute must fit A100-80G (paper Fig 4)."""
    cfg = GPT_CONFIGS["gpt-175b"]
    mb = training_memory(cfg, global_batch=64, seq=2048, dp=1, tp=8, pp=8,
                         sp=False, microbatch=1, recompute="full")
    assert mb.total < 80e9
    mb_none = training_memory(cfg, global_batch=64, seq=2048, dp=1, tp=8, pp=8,
                              sp=False, microbatch=1, recompute="none", schedule="gpipe")
    assert mb_none.total > 80e9  # paper: no-recompute does not fit


# ------------------------------------------------------------------- KV cache
def test_kv_cache_paper_formula_mha():
    cfg = LLAMA2_CONFIGS["llama2-13b"]  # MHA: kv_dim == d_model
    got = kv_cache_bytes(cfg, batch=16, context=400)
    expect = 2 * 16 * 400 * 2 * cfg.num_layers * cfg.d_model
    assert got == expect


def test_kv_cache_gqa_and_window():
    cfg = get_config("h2o_danube_1p8b")
    assert kv_cache_bytes(cfg, 1, 524288) == kv_cache_bytes(cfg, 1, cfg.sliding_window)
    full = get_config("qwen3_14b")
    assert kv_cache_bytes(full, 1, 1000) < 2 * 1 * 1000 * 2 * full.num_layers * full.d_model


def test_ssm_state_constant_in_context():
    cfg = get_config("rwkv6_7b")
    assert kv_cache_bytes(cfg, 4, 10**6) == 0.0
    assert recurrent_state_bytes(cfg, 4) > 0


# --------------------------------------------------------------------- predict
def test_decode_memory_bound_scaling():
    """More compute does not help decode (paper §6.2's headline insight)."""
    cfg = LLAMA2_CONFIGS["llama2-13b"]
    t_a100 = inference_latency(cfg, A100_80G, tp=1, batch=1, prompt=200, gen=200)
    fast = A100_80G.with_dram("HBM2e", A100_80G.dram.bw)  # same mem
    import dataclasses

    fast = dataclasses.replace(fast, flops={k: v * 3 for k, v in fast.flops.items()})
    t_fast = inference_latency(cfg, fast, tp=1, batch=1, prompt=200, gen=200)
    assert t_fast.parts["decode_compute"] > 0.9 * t_a100.parts["decode_compute"]


def test_train_recompute_costs_time():
    cfg = GPT_CONFIGS["gpt-22b"]
    m_sel = Mapping(dp=1, tp=8, pp=1, sp=True, recompute="selective")
    m_full = Mapping(dp=1, tp=8, pp=1, sp=True, recompute="full")
    t_sel = train_step_time(cfg, A100_80G, m_sel, global_batch=4, seq=2048).total
    t_full = train_step_time(cfg, A100_80G, m_full, global_batch=4, seq=2048).total
    assert t_full > t_sel  # paper: full recompute ~doubles forward time


# --------------------------------------------------------------------- planner
def test_planner_feasible_and_sorted():
    plans = plan(GPT_CONFIGS["gpt-175b"], A100_80G, 64, global_batch=64, seq=2048,
                 max_tp=8)
    assert plans and all(p.fits for p in plans)
    times = [p.time for p in plans]
    assert times == sorted(times)
    for p in plans:
        assert p.mapping.devices == 64


def test_planner_oom_raises():
    with pytest.raises(ValueError):
        plan(GPT_CONFIGS["gpt-1008b"], A100_80G, 8, global_batch=8, seq=2048, max_tp=8)
