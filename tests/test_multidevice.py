"""Multi-device tests (subprocess: device count must be set before jax init).

Covers: sharded training == single-device numerics, multi-pod mesh train step,
elastic checkpoint reshard (1 device save -> 8 device restore)."""

import os
import subprocess
import sys
import textwrap

import pytest

# JAX compile-heavy: excluded from the fast tier (pytest -m "not slow")
pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str, devices: int = 8, timeout: int = 420):
    script = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, {SRC!r})
        """
    ) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=timeout)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr[-3000:]}"
    return res.stdout


def test_sharded_train_matches_single_device():
    out = _run(
        """
        import jax, numpy as np
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig, TrainConfig
        from repro.data.pipeline import SyntheticLM
        from repro.launch.mesh import make_mesh
        from repro.models.transformer import Model
        from repro.parallel.axes import make_rules
        from repro.train.trainer import Trainer

        cfg = get_config("qwen3-14b").reduced()
        model = Model(cfg)
        data = SyntheticLM(cfg.vocab_size, 64, 8)
        tcfg = TrainConfig(steps=3, log_every=100)

        # single-device reference
        tr0 = Trainer(model, ParallelConfig(), tcfg)
        s0 = tr0.init_state()
        s0, h0 = tr0.fit(s0, data, steps=3)

        # (data=2, model=4) sharded
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = make_rules(dp=("data",), tp=("model",))
        tr1 = Trainer(model, ParallelConfig(), tcfg, mesh=mesh, rules=rules)
        s1 = tr1.init_state()
        s1, h1 = tr1.fit(s1, data, steps=3)
        for a, b in zip(h0, h1):
            assert abs(a["loss"] - b["loss"]) < 2e-3, (a["loss"], b["loss"])
        print("SHARDED_MATCH", h0[-1]["loss"], h1[-1]["loss"])
        """
    )
    assert "SHARDED_MATCH" in out


def test_multipod_mesh_train_step():
    out = _run(
        """
        import jax
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig, TrainConfig
        from repro.data.pipeline import SyntheticLM
        from repro.launch.mesh import make_mesh
        from repro.models.transformer import Model
        from repro.parallel.axes import make_rules
        from repro.train.trainer import Trainer

        cfg = get_config("deepseek-moe-16b").reduced()
        model = Model(cfg)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = make_rules(dp=("pod", "data"), tp=("model",))
        tr = Trainer(model, ParallelConfig(microbatches=2), TrainConfig(steps=2, log_every=100),
                     mesh=mesh, rules=rules)
        state = tr.init_state()
        data = SyntheticLM(cfg.vocab_size, 32, 8)
        state, hist = tr.fit(state, data, steps=2)
        assert all(h["loss"] > 0 for h in hist)
        print("MULTIPOD_OK", hist[-1]["loss"])
        """
    )
    assert "MULTIPOD_OK" in out


def test_elastic_checkpoint_reshard(tmp_path):
    # save on 1 device
    _run(
        f"""
        import jax, jax.numpy as jnp
        from repro.checkpoint.checkpoint import CheckpointManager
        m = CheckpointManager({str(tmp_path)!r})
        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        m.save(1, tree, async_=False)
        print("SAVED")
        """,
        devices=1,
    )
    # restore sharded on 8 devices
    out = _run(
        f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.checkpoint import CheckpointManager
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        m = CheckpointManager({str(tmp_path)!r})
        target = {{"w": jnp.zeros((8, 8), jnp.float32)}}
        shardings = {{"w": NamedSharding(mesh, P("data", "model"))}}
        tree, step = m.restore(target, shardings=shardings)
        assert step == 1
        assert len(tree["w"].sharding.device_set) == 8
        np.testing.assert_array_equal(np.asarray(tree["w"]).ravel(), np.arange(64))
        print("RESHARD_OK")
        """
    )
    assert "RESHARD_OK" in out


def test_grad_compression_under_mesh():
    out = _run(
        """
        import jax
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig, TrainConfig
        from repro.data.pipeline import SyntheticLM
        from repro.launch.mesh import make_mesh
        from repro.models.transformer import Model
        from repro.parallel.axes import make_rules
        from repro.train.trainer import Trainer

        cfg = get_config("starcoder2-3b").reduced()
        model = Model(cfg)
        mesh = make_mesh((4, 2), ("data", "model"))
        rules = make_rules(dp=("data",), tp=("model",))
        tr = Trainer(model, ParallelConfig(grad_compress=True),
                     TrainConfig(steps=4, log_every=100), mesh=mesh, rules=rules)
        state = tr.init_state()
        data = SyntheticLM(cfg.vocab_size, 32, 8)
        state, hist = tr.fit(state, data, steps=4)
        assert hist[-1]["loss"] < hist[0]["loss"] + 0.1
        print("COMPRESS_OK", hist[0]["loss"], hist[-1]["loss"])
        """
    )
    assert "COMPRESS_OK" in out
