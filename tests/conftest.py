import os
import sys

# tests must see exactly 1 device (the dry-run alone uses 512 host devices)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
