"""repro.sim: deterministic workload generation, scheduler invariants
(KV capacity, FCFS admission, conservation under preemption), and the
single-request consistency contract with `inference_latency`."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.hardware import H100_SXM
from repro.core.predict import inference_latency, train_step_time
from repro.core.parallelism import Mapping
from repro.core.paper_data import GPT_CONFIGS
from repro.sim import (
    LengthDist,
    ReplicaSim,
    SchedConfig,
    ServingCostModel,
    SimRequest,
    Workload,
    dominates,
    pareto_sweep,
    simulate,
    summarize,
)

from hypkit import given, settings, st


def _cost(name="qwen3_14b", **kw):
    return ServingCostModel(get_config(name), H100_SXM, **kw)


def _wl(**kw):
    base = dict(
        qps=50.0, num_requests=24, arrival="poisson",
        prompt=LengthDist("lognormal", 96, 0.4, lo=8, hi=512),
        output=LengthDist("lognormal", 24, 0.4, lo=2, hi=128), seed=0,
    )
    base.update(kw)
    return Workload(**base)


# ---------------------------------------------------------------- workload gen
def test_workload_deterministic_per_seed():
    a, b = _wl().generate(), _wl().generate()
    assert a == b
    c = _wl(seed=1).generate()
    assert a != c


@pytest.mark.parametrize("arrival", ["constant", "poisson", "bursty"])
def test_arrival_mean_rate(arrival):
    wl = _wl(arrival=arrival, num_requests=2000, qps=10.0)
    reqs = wl.generate()
    mean_gap = reqs[-1].arrival / len(reqs)
    assert mean_gap == pytest.approx(0.1, rel=0.15)
    assert all(b.arrival >= a.arrival for a, b in zip(reqs, reqs[1:]))


def test_lognormal_lengths_clamped_and_mean():
    xs = LengthDist("lognormal", 100, 0.5, lo=10, hi=400).sample(
        np.random.default_rng(0), 4000)
    assert xs.min() >= 10 and xs.max() <= 400
    assert np.mean(xs) == pytest.approx(100, rel=0.1)


def test_diurnal_arrivals_track_envelope():
    # thinning must concentrate arrivals where the envelope peaks: compare
    # arrival counts in the high vs low half-cycle of one compressed day
    wl = _wl(arrival="diurnal", num_requests=2000, qps=20.0,
             diurnal_period=100.0, diurnal_amp=0.9)
    reqs = wl.generate()
    assert all(b.arrival >= a.arrival for a, b in zip(reqs, reqs[1:]))
    assert wl.generate() == reqs  # deterministic per seed
    hi = sum(1 for r in reqs if (r.arrival % 100.0) < 50.0)  # sin > 0 half
    lo = sum(1 for r in reqs if 50.0 <= (r.arrival % 100.0))
    assert hi > 2 * lo
    # envelope accessor matches the analytic form
    assert wl.rate_at(25.0) == pytest.approx(20.0 * 1.9)  # peak (sin = 1)
    assert wl.rate_at(75.0) == pytest.approx(20.0 * 0.1)  # trough (sin = -1)
    assert _wl().rate_at(123.0) == 50.0  # constant-rate specs: just qps


def test_diurnal_mean_rate_over_full_cycles():
    # over whole periods the thinned process keeps the configured mean qps
    wl = _wl(arrival="diurnal", num_requests=4000, qps=40.0,
             diurnal_period=10.0, diurnal_amp=0.8)
    reqs = wl.generate()
    span = reqs[-1].arrival
    cycles = int(span / 10.0)
    n_whole = sum(1 for r in reqs if r.arrival <= cycles * 10.0)
    assert n_whole / (cycles * 10.0) == pytest.approx(40.0, rel=0.1)


def test_diurnal_validation():
    with pytest.raises(ValueError, match="diurnal_amp"):
        _wl(arrival="diurnal", diurnal_amp=1.5).generate()
    with pytest.raises(ValueError, match="diurnal_period"):
        _wl(arrival="diurnal", diurnal_period=0.0).generate()


def test_rate_envelope_replay(tmp_path):
    p = tmp_path / "rates.jsonl"
    p.write_text(
        '{"t": 0.0, "qps": 50.0}\n'
        '{"time": 10.0, "rate": 50.0}\n'  # aliases accepted
        '{"t": 10.0001, "qps": 0.5}\n'
        '{"t": 30.0, "qps": 0.5}\n'
    )
    wl = _wl(arrival="envelope", rate_path=str(p), num_requests=400)
    reqs = wl.generate()
    assert all(b.arrival >= a.arrival for a, b in zip(reqs, reqs[1:]))
    assert wl.rate_at(5.0) == pytest.approx(50.0)
    assert wl.rate_at(20.0) == pytest.approx(0.5)
    early = sum(1 for r in reqs if r.arrival <= 10.0)
    # the step down by 100x must show up as a step down in arrival density
    in_tail = sum(1 for r in reqs if 10.0 < r.arrival <= 30.0)
    assert early > 10 * max(in_tail, 1)
    # held constant beyond the last breakpoint: still generates
    assert len(reqs) == 400


def test_rate_envelope_validation(tmp_path):
    with pytest.raises(ValueError, match="rate_path"):
        _wl(arrival="envelope").generate()
    empty = tmp_path / "empty.jsonl"
    empty.write_text("\n")
    with pytest.raises(ValueError, match="empty"):
        _wl(arrival="envelope", rate_path=str(empty)).generate()
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"t": 0.0}\n')
    with pytest.raises(ValueError, match="t/qps"):
        _wl(arrival="envelope", rate_path=str(bad)).generate()
    neg = tmp_path / "neg.jsonl"
    neg.write_text('{"t": 0.0, "qps": -1.0}\n')
    with pytest.raises(ValueError, match="negative"):
        _wl(arrival="envelope", rate_path=str(neg)).generate()
    # a zero TAIL is held forever -> generation could never finish; a zero
    # rate inside the envelope is fine (thinning skips the quiet valley)
    tail0 = tmp_path / "tail0.jsonl"
    tail0.write_text('{"t": 0.0, "qps": 20.0}\n{"t": 1.0, "qps": 0.0}\n')
    with pytest.raises(ValueError, match="ends at rate 0"):
        _wl(arrival="envelope", rate_path=str(tail0)).generate()
    valley = tmp_path / "valley.jsonl"
    valley.write_text('{"t": 0.0, "qps": 20.0}\n{"t": 1.0, "qps": 0.0}\n'
                      '{"t": 2.0, "qps": 20.0}\n')
    reqs = _wl(arrival="envelope", rate_path=str(valley),
               num_requests=50).generate()
    assert len(reqs) == 50
    ok = tmp_path / "ok.jsonl"
    ok.write_text('{"t": 0.0, "qps": 8.0}\n')
    with pytest.raises(ValueError, match="substreams"):
        _wl(arrival="envelope", rate_path=str(ok)).substreams(2)


def test_trace_replay(tmp_path):
    p = tmp_path / "trace.jsonl"
    p.write_text(
        '{"arrival": 0.0, "prompt": 10, "output": 4}\n'
        '{"arrival_s": 0.5, "prompt_tokens": 20, "output_tokens": 6}\n'
        '{"arrival": 0.9, "prompt": 0, "output": 0}\n'  # clamped to 1/1
    )
    reqs = Workload(trace_path=str(p)).generate()
    assert [(r.arrival, r.prompt, r.output) for r in reqs] == [
        (0.0, 10, 4), (0.5, 20, 6), (0.9, 1, 1)]


# ---------------------------------------------------- scheduler: basic shapes
@pytest.mark.parametrize("policy", ["static", "continuous", "chunked"])
def test_all_requests_complete(policy):
    cost = _cost()
    res = simulate(_wl().generate(), cost, SchedConfig(policy=policy, slots=4))
    for r in res.records:
        assert r.finish >= r.first_token >= r.arrival
        assert r.admitted >= r.arrival
    assert res.peak_kv <= res.kv_capacity


def test_fcfs_admission_order():
    reqs = _wl(num_requests=40).generate()
    res = simulate(reqs, _cost(), SchedConfig(policy="continuous", slots=3))
    expect = [r.rid for r in sorted(reqs, key=lambda r: (r.arrival, r.rid))]
    assert res.admit_order == expect


def test_request_larger_than_budget_rejected():
    cost = _cost()
    with pytest.raises(ValueError, match="never be served"):
        simulate([SimRequest(0, 0.0, 100, 10)], cost,
                 SchedConfig(kv_capacity=cost.kv_bytes(50)))


def test_degenerate_requests_rejected():
    cost = _cost()
    for bad in (SimRequest(0, 0.0, 0, 4), SimRequest(0, 0.0, 16, 0)):
        with pytest.raises(ValueError, match="must be >= 1"):
            simulate([bad], cost, SchedConfig())


def test_concurrent_admissions_prefill_as_one_batch():
    # two prompts admitted in the same iteration are priced as ONE padded
    # batch prefill (engine semantics), not a sequential sum
    cost = ServingCostModel(get_config("qwen3_14b"), H100_SXM, ctx_quantum=1)
    reqs = [SimRequest(i, 0.0, 256, 2) for i in range(2)]
    res = simulate(reqs, cost, SchedConfig(policy="continuous", slots=2))
    expect = cost.prefill_time(256, ctx_end=256, batch=2)
    for r in res.records:
        assert r.ttft == pytest.approx(expect)


def test_chunked_prefill_head_charged_once():
    cost = ServingCostModel(get_config("qwen3_14b"), H100_SXM, ctx_quantum=1)
    res = simulate([SimRequest(0, 0.0, 512, 2)], cost,
                   SchedConfig(policy="chunked", slots=1, token_budget=256))
    expect = (cost.prefill_time(256, ctx_end=256, with_head=False)
              + cost.prefill_time(256, ctx_end=512, with_head=True))
    assert res.records[0].ttft == pytest.approx(expect)
    # the head flag actually prices the LM head
    assert cost.prefill_time(256, ctx_end=512, with_head=True) > \
        cost.prefill_time(256, ctx_end=512, with_head=False)


def test_degenerate_sched_configs_fail_fast():
    cost = _cost()
    reqs = [SimRequest(0, 0.0, 16, 4)]
    with pytest.raises(ValueError, match="token_budget"):
        simulate(reqs, cost, SchedConfig(policy="chunked", token_budget=0))
    with pytest.raises(ValueError, match="slots"):
        simulate(reqs, cost, SchedConfig(slots=0))


def test_admission_reserves_projected_kv():
    # 8 simultaneous arrivals into a budget that fits ~2.5 requests: admission
    # must stop at the reservation limit instead of mass-admitting everything
    # and churning through spurious preemptions
    cost = _cost()
    reqs = [SimRequest(i, 0.0, 128, 8) for i in range(8)]
    cap = 2.5 * cost.kv_bytes(128 + 8)
    res = simulate(reqs, cost, SchedConfig(policy="continuous", slots=8,
                                           kv_capacity=cap))
    assert res.preemptions == 0
    assert res.peak_kv <= cap
    admits = sorted(r.admitted for r in res.records)
    assert admits[0] < admits[-1]  # admissions staggered, not all at t=0


def test_static_prefill_only_batch_counts_kv():
    cost = _cost()
    reqs = [SimRequest(i, 0.0, 256, 1) for i in range(4)]
    res = simulate(reqs, cost, SchedConfig(policy="static", slots=4))
    assert res.peak_kv == pytest.approx(4 * cost.kv_bytes(256))


# ------------------------------------------- KV invariant + preemption across seeds
def _tight_run(seed, policy="continuous", qps=100.0):
    cost = _cost()
    reqs = _wl(seed=seed, num_requests=16, qps=qps,
               prompt=LengthDist("lognormal", 128, 0.5, lo=16, hi=512),
               output=LengthDist("lognormal", 64, 0.5, lo=8, hi=256)).generate()
    cap = 3.0 * max(cost.kv_bytes(r.prompt + r.output) for r in reqs)
    sc = SchedConfig(policy=policy, slots=8, kv_capacity=cap)
    return simulate(reqs, cost, sc), cap


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("policy", ["continuous", "chunked"])
def test_kv_invariant_and_conservation_under_pressure(seed, policy):
    res, cap = _tight_run(seed, policy)
    assert res.peak_kv <= cap  # hard capacity invariant
    # conservation: every admitted request completes (preempted ones resume)
    assert all(r.finish >= 0 for r in res.records)
    assert sorted(r.rid for r in res.records) == list(range(16))


def test_preemption_exercised_and_counted():
    # at least one seed in the sweep must actually hit the preemption path
    assert any(_tight_run(s)[0].preemptions > 0 for s in range(6))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), qps=st.floats(5.0, 200.0))
def test_kv_invariant_property(seed, qps):
    res, cap = _tight_run(seed, qps=qps)
    assert res.peak_kv <= cap
    assert all(r.finish >= 0 for r in res.records)


# ------------------------------------------------- continuous dominates static
def test_continuous_dominates_static_at_equal_kv():
    cost = _cost(ctx_quantum=16)
    reqs = _wl(num_requests=32, qps=30.0).generate()
    rows = pareto_sweep(reqs, cost, policies=("static", "continuous"),
                        slot_counts=(2, 4, 8))
    by = {(r["policy"], r["slots"]): r for r in rows}
    for slots in (2, 4, 8):
        assert dominates(by[("continuous", slots)], by[("static", slots)])


# -------------------------------------------- consistency with inference_latency
@pytest.mark.parametrize("tp", [1, 2])
@pytest.mark.parametrize("config", ["qwen3_14b", "h2o_danube_1p8b"])
def test_single_request_matches_inference_latency(config, tp):
    cfg = get_config(config)
    prompt, gen = 512, 64
    bd = inference_latency(cfg, H100_SXM, tp=tp, batch=1, prompt=prompt, gen=gen)
    cost = ServingCostModel(cfg, H100_SXM, tp=tp, ctx_quantum=1)
    res = simulate([SimRequest(0, 0.0, prompt, gen)], cost,
                   SchedConfig(policy="continuous", slots=1))
    r = res.records[0]
    assert r.ttft == pytest.approx(bd.ttft, rel=0.01)
    assert r.tpot == pytest.approx(bd.tpot, rel=0.01)
    assert res.decode_steps == gen - 1


# ---------------------------------------------------- Breakdown SLO properties
def test_breakdown_ttft_tpot_partition():
    cfg = get_config("qwen3_14b")
    bd = inference_latency(cfg, H100_SXM, tp=1, batch=1, prompt=256, gen=32)
    assert bd.ttft > 0 and bd.tpot > 0
    assert bd.ttft + bd.decode_total == pytest.approx(bd.total)
    assert bd.tpot == pytest.approx(bd.decode_total / 32)


def test_breakdown_train_has_no_slo_terms():
    bd = train_step_time(GPT_CONFIGS["gpt-22b"], H100_SXM,
                         Mapping(dp=1, tp=8, pp=1, sp=True),
                         global_batch=4, seq=2048)
    assert bd.ttft == 0.0 and bd.tpot == 0.0


# ------------------------------------------------------------- EDF admission
def test_edf_admits_tightest_deadline_first():
    # four simultaneous arrivals with deadlines tightening as rid grows:
    # FCFS admits by rid, EDF by deadline (reversed)
    reqs = [SimRequest(i, 0.0, 64, 4, slo_ttft=2.0 - 0.4 * i) for i in range(4)]
    fcfs = simulate(reqs, _cost(), SchedConfig(policy="continuous", slots=4))
    assert fcfs.admit_order == [0, 1, 2, 3]
    edf = simulate(reqs, _cost(), SchedConfig(policy="continuous", slots=4,
                                              admission="edf"))
    assert edf.admit_order == [3, 2, 1, 0]


def test_edf_uniform_deadlines_match_fcfs():
    # with one SLO class, EDF degenerates to FCFS — same schedule exactly
    reqs = _wl(num_requests=16).generate()
    a = simulate(reqs, _cost(), SchedConfig(slots=4))
    b = simulate(reqs, _cost(), SchedConfig(slots=4, admission="edf"))
    assert a.admit_order == b.admit_order
    assert [(r.first_token, r.finish) for r in a.records] == \
        [(r.first_token, r.finish) for r in b.records]


def test_edf_improves_tight_class_goodput():
    # a 20%-tight / 80%-loose SLO mix under backlog: EDF must serve the
    # tight class no later (on average) than FCFS does
    reqs = _wl(num_requests=32, qps=200.0,
               slo_ttft=(0.5, 4.0, 4.0, 4.0, 4.0)).generate()
    tight = {r.rid for r in reqs if r.slo_ttft == 0.5}
    assert tight and len(tight) < len(reqs)
    fcfs = simulate(reqs, _cost(), SchedConfig(slots=2))
    edf = simulate(reqs, _cost(), SchedConfig(slots=2, admission="edf"))
    mean = lambda res: np.mean([r.ttft for r in res.records if r.rid in tight])
    assert mean(edf) <= mean(fcfs) + 1e-9


def test_edf_equal_deadline_tie_break_deterministic():
    # equal slo_ttft and equal arrivals: EDF's (deadline, arrival, rid) key
    # falls back to rid order — simultaneous same-class requests admit FCFS,
    # identically on every run
    reqs = [SimRequest(i, 0.0, 64, 4, slo_ttft=1.0) for i in range(6)]
    runs = [simulate(reqs, _cost(), SchedConfig(policy="continuous", slots=2,
                                                admission="edf"))
            for _ in range(2)]
    assert runs[0].admit_order == runs[1].admit_order == list(range(6))
    assert [(r.first_token, r.finish) for r in runs[0].records] == \
        [(r.first_token, r.finish) for r in runs[1].records]
    # same deadline from different (arrival, slo) pairs: earlier arrival wins
    mixed = [SimRequest(0, 0.5, 64, 4, slo_ttft=1.0),
             SimRequest(1, 0.0, 64, 4, slo_ttft=1.5)]
    res = simulate(mixed, _cost(), SchedConfig(policy="continuous", slots=1,
                                               admission="edf"))
    assert res.admit_order == [1, 0]


# ------------------------------------------------------------ pending eviction
def test_evict_pending_returns_only_untouched_requests():
    # graceful-drain contract: queued-never-admitted requests come back out
    # (records withdrawn), while admitted/preempted work stays put
    cost = _cost()
    sim = ReplicaSim(cost, SchedConfig(policy="continuous", slots=1))
    sim.push(SimRequest(0, 0.0, 64, 8))
    sim.push(SimRequest(1, 0.0, 64, 4))
    sim.push(SimRequest(2, 0.0, 64, 4))
    sim.step()  # admits rid 0 into the single slot; 1-2 stay pending
    evicted = sim.evict_pending()
    assert [r.rid for r in evicted] == [1, 2]
    assert {r.rid for r in sim.res.records} == {0}
    done = sim.run()
    assert [r.rid for r in done] == [0]
    # evicted rids were fully withdrawn: re-pushing them is legal
    sim.push(SimRequest(1, 0.0, 64, 4))
    assert sorted(r.rid for r in sim.run()) == [1]


def test_evict_pending_keeps_preempted_requests():
    # a preempted request (KV dropped, tokens already emitted) is in-flight
    # work, not an untouched arrival: drains must finish it locally
    cost = _cost()
    cap = 2.5 * cost.kv_bytes(128 + 64)
    sim = ReplicaSim(cost, SchedConfig(policy="continuous", slots=8,
                                       kv_capacity=cap))
    for i in range(6):
        sim.push(SimRequest(i, 0.0, 128, 64))
    while sim.res.preemptions == 0 and sim.has_work:
        sim.step()
    assert sim.res.preemptions > 0
    evicted = sim.evict_pending()
    # whatever stayed queued was already touched (admitted at least once)
    assert all(r.rec.admitted >= 0 for r in sim._pending)
    done_rids = {r.rid for r in sim.res.records}
    assert done_rids | {r.rid for r in evicted} == set(range(6))
    sim.run()
    assert all(r.finish >= 0 for r in sim.res.records)


def test_unknown_admission_rejected():
    with pytest.raises(ValueError, match="admission"):
        simulate([SimRequest(0, 0.0, 8, 2)], _cost(),
                 SchedConfig(admission="lifo"))


# ------------------------------------------------------------------ paged KV
def test_paged_kv_rounds_up_and_reports_waste():
    paged = _cost(kv_block_tokens=64)
    flat = _cost()
    assert paged.kv_bytes(1) == paged.kv_bytes(64) == flat.kv_bytes(64)
    assert paged.kv_bytes(65) == flat.kv_bytes(128)
    assert paged.kv_bytes(65, exact=True) == flat.kv_bytes(65)
    reqs = _wl(num_requests=8).generate()
    res = simulate(reqs, paged, SchedConfig(slots=4))
    assert res.peak_kv_waste > 0
    assert res.peak_kv <= res.kv_capacity
    s = summarize(res)
    assert 0 < s["kv_waste_frac"] < 1
    # contiguous accounting reports zero waste
    assert simulate(reqs, flat, SchedConfig(slots=4)).peak_kv_waste == 0.0


def test_paged_kv_admits_fewer_at_tight_capacity():
    # page rounding inflates per-sequence footprint, so a budget sized for
    # N exact sequences fits fewer paged ones — visible as extra queueing
    paged = _cost(kv_block_tokens=64)
    flat = _cost()
    reqs = [SimRequest(i, 0.0, 33, 4) for i in range(8)]
    cap = 4.0 * flat.kv_bytes(33 + 4)
    sc = SchedConfig(slots=8, kv_capacity=cap)
    res_flat = simulate(reqs, flat, sc)
    res_paged = simulate(reqs, paged, sc)
    admitted_at_0 = lambda res: sum(1 for r in res.records if r.admitted == 0.0)
    assert admitted_at_0(res_paged) < admitted_at_0(res_flat)


# -------------------------------------------------------------- stream splitting
def test_substreams_decorrelated_and_conserving():
    wl = _wl(num_requests=25, qps=40.0)
    subs = wl.substreams(4)
    assert len(subs) == 4
    assert sum(s.num_requests for s in subs) == 25
    assert all(s.qps == pytest.approx(10.0) for s in subs)
    seeds = [s.seed for s in subs]
    assert len(set(seeds)) == 4  # spawned, not seed+i
    streams = [tuple((r.prompt, r.output) for r in s.generate()) for s in subs]
    assert len(set(streams)) == 4  # pairwise-distinct request streams
    # deterministic: same parent spec -> same shards
    again = wl.substreams(4)
    assert [s.seed for s in again] == seeds


# ----------------------------------------------------------------- metrics agg
def test_dominates_total_and_partial_orders():
    mk = lambda tok, e2e: {"tokens_per_s": tok, "e2e_p95": e2e}
    assert dominates(mk(100, 1.0), mk(90, 2.0))  # better on both
    assert dominates(mk(100, 1.0), mk(100, 2.0))  # tie on one, better on other
    assert dominates(mk(100, 1.0), mk(90, 1.0))
    assert not dominates(mk(90, 2.0), mk(100, 1.0))  # worse on both
    assert not dominates(mk(100, 1.0), mk(100, 1.0))  # equal: no strict win
    assert not dominates(mk(100, 2.0), mk(90, 1.0))  # trade-off: incomparable
    assert not dominates(mk(90, 1.0), mk(100, 2.0))


def test_chunked_in_default_pareto_sweep():
    cost = _cost(ctx_quantum=32)
    reqs = _wl(num_requests=12).generate()
    rows = pareto_sweep(reqs, cost, slot_counts=(2, 4))
    assert {r["policy"] for r in rows} == {"static", "continuous", "chunked"}
    assert any(r["pareto"] for r in rows)


def test_summarize_goodput_and_throughput():
    cost = _cost()
    reqs = _wl(num_requests=12, qps=20.0).generate()
    res = simulate(reqs, cost, SchedConfig(policy="continuous", slots=4))
    s = summarize(res, slo_ttft=1e9, slo_tpot=1e9)
    assert s["goodput_frac"] == 1.0  # infinite SLOs: everything is goodput
    assert s["tokens_per_s"] == pytest.approx(
        sum(r.output for r in reqs) / res.makespan)
    tight = summarize(res, slo_ttft=1e-9)
    assert tight["goodput_frac"] == 0.0


# ----------------------------------------------------- envelope lookahead
def test_peak_rate_diurnal_matches_dense_sampling():
    wl = Workload(qps=10.0, arrival="diurnal", diurnal_period=100.0,
                  diurnal_amp=0.5)
    for t0, t1 in [(0, 10), (10, 40), (30, 80), (95, 130), (60, 70)]:
        ref = max(wl.rate_at(t) for t in np.linspace(t0, t1, 4001))
        assert wl.peak_rate(t0, t1) == pytest.approx(ref, rel=1e-4)
    # the crest (t = period/4 = 25) inside the window -> exact peak
    assert wl.peak_rate(20.0, 30.0) == pytest.approx(15.0)
    # degenerate window -> pointwise rate
    assert wl.peak_rate(7.0, 7.0) == pytest.approx(wl.rate_at(7.0))
    with pytest.raises(ValueError):
        wl.peak_rate(5.0, 1.0)


def test_peak_rate_envelope_and_flat(tmp_path):
    import json

    path = tmp_path / "rates.jsonl"
    rows = [{"t": 0, "qps": 4}, {"t": 10, "qps": 20}, {"t": 20, "qps": 6}]
    path.write_text("\n".join(json.dumps(r) for r in rows) + "\n")
    wl = Workload(arrival="envelope", rate_path=str(path))
    assert wl.peak_rate(0.0, 20.0) == pytest.approx(20.0)  # breakpoint inside
    assert wl.peak_rate(12.0, 20.0) == pytest.approx(wl.rate_at(12.0))
    assert wl.peak_rate(0.0, 5.0) == pytest.approx(wl.rate_at(5.0))
    # past the last breakpoint the envelope holds its tail value
    assert wl.peak_rate(25.0, 90.0) == pytest.approx(6.0)
    # flat arrival processes report the constant rate
    assert Workload(qps=7.0, arrival="poisson").peak_rate(0.0, 100.0) == 7.0


def test_evict_pending_include_staged_rehands_handoffs():
    # the decode-drain contract: never-admitted handoff-staged requests
    # (cached/generated KV) stay put by default but come out with
    # include_staged=True; admitted work stays in either mode
    cost = _cost()
    sim = ReplicaSim(cost, SchedConfig(policy="continuous", slots=1))
    sim.push(SimRequest(0, 0.0, 64, 8), cached=64, generated=1)
    sim.push(SimRequest(1, 0.0, 64, 4), cached=64, generated=1)
    sim.push(SimRequest(2, 0.0, 64, 4))
    sim.step()  # admits rid 0; 1 (staged) and 2 (fresh) stay pending
    assert [r.rid for r in sim.evict_pending()] == [2]  # staged kept
    evicted = sim.evict_pending(include_staged=True)
    assert [r.rid for r in evicted] == [1]
    assert {r.rid for r in sim.res.records} == {0}
    done = sim.run()
    assert [r.rid for r in done] == [0]
