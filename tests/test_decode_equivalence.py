"""The serving invariant: prefill-then-decode must reproduce the full forward
pass token-for-token, for every architecture family (attention KV caches,
SWA ring buffers, Mamba2 recurrent state, RWKV6 wkv state, MoE routing)."""

import dataclasses

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models.transformer import Model

# JAX compile-heavy: excluded from the fast tier (pytest -m "not slow")
pytestmark = pytest.mark.slow

CASES = [
    "qwen3_14b",  # GQA + qk_norm
    "h2o_danube_1p8b",  # SWA ring buffer
    "rwkv6_7b",  # wkv state
    "zamba2_1p2b",  # mamba2 + shared attn
    "musicgen_large",  # MHA
]


def _full_logits(m, params, batch):
    x, _ = m.forward(params, batch)
    return np.asarray(m._head(params, x))


@pytest.mark.parametrize("arch", CASES)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if arch == "h2o_danube_1p8b":
        cfg = dataclasses.replace(cfg, sliding_window=16)
    m = Model(cfg)
    key = jax.random.PRNGKey(1)
    B, S, S0 = 2, 48, 32
    params = m.init(key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    ref = _full_logits(m, params, {"tokens": tokens})

    logits, cache = jax.jit(lambda p, b: m.prefill(p, b, max_len=S))(
        params, {"tokens": tokens[:, :S0]}
    )
    errs = [np.abs(np.asarray(logits) - ref[:, S0 - 1]).max()]
    dec = jax.jit(lambda p, c, t: m.decode_step(p, c, t))
    for t in range(S0, S):
        logits, cache = dec(params, cache, tokens[:, t : t + 1])
        errs.append(np.abs(np.asarray(logits) - ref[:, t]).max())
    assert max(errs) < 2e-3, (arch, max(errs))


def test_moe_prefill_decode_dropless():
    """With dropless capacity, MoE decode must match the full pass exactly;
    with tight capacity they may differ (token-priority dropping is
    batch-dependent) — both behaviours are asserted."""
    base = get_config("deepseek_moe_16b").reduced()
    cfg = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, capacity_factor=float(base.moe.num_experts))
    )
    m = Model(cfg)
    key = jax.random.PRNGKey(1)
    B, S, S0 = 2, 48, 32
    params = m.init(key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    ref = _full_logits(m, params, {"tokens": tokens})
    logits, cache = jax.jit(lambda p, b: m.prefill(p, b, max_len=S))(
        params, {"tokens": tokens[:, :S0]}
    )
    errs = [np.abs(np.asarray(logits) - ref[:, S0 - 1]).max()]
    dec = jax.jit(lambda p, c, t: m.decode_step(p, c, t))
    for t in range(S0, S):
        logits, cache = dec(params, cache, tokens[:, t : t + 1])
        errs.append(np.abs(np.asarray(logits) - ref[:, t]).max())
    assert max(errs) < 2e-3, max(errs)
