"""Per-arch smoke tests: every assigned architecture, reduced config, one
forward/train/prefill/decode step on CPU with shape + finiteness asserts."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, input_specs, applicable, SHAPES
from repro.models.transformer import Model

# JAX compile-heavy: excluded from the fast tier (pytest -m "not slow")
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_train_step(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 64
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.input_mode == "embeds":
        batch = {"embeds": 0.02 * jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model)),
                 "labels": tokens}
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b, remat="selective"))(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.jit(jax.grad(lambda p: model.loss(p, batch)[0]))(params)
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 48
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.input_mode == "embeds":
        batch = {"embeds": 0.02 * jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model))}
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len=S + 4))(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))
    logits2, cache = jax.jit(lambda p, c, t: model.decode_step(p, c, t))(
        params, cache, tokens[:, :1]
    )
    assert logits2.shape == (B, cfg.vocab_size)
    assert int(cache["pos"]) == S + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    for name, shape in SHAPES.items():
        ok, reason = applicable(cfg, shape)
        if name == "long_500k":
            assert ok == cfg.sub_quadratic, (arch, reason)
        if not ok:
            continue
        spec = input_specs(cfg, shape)
        assert spec, (arch, name)
        if shape.kind == "decode":
            assert spec["tokens"].shape == (shape.global_batch, 1)
        elif cfg.input_mode == "embeds":
            assert spec["embeds"].shape == (shape.global_batch, shape.seq_len, cfg.d_model)
        else:
            assert spec["tokens"].shape == (shape.global_batch, shape.seq_len)


def test_full_configs_match_assignment():
    """Exact published numbers from the assignment table."""
    expect = {
        "zamba2_1p2b": (38, 2048, 32, 32, 8192, 32000),
        "rwkv6_7b": (32, 4096, 64, 64, 14336, 65536),
        "qwen3_14b": (40, 5120, 40, 8, 17408, 151936),
        "starcoder2_3b": (30, 3072, 24, 2, 12288, 49152),
        "h2o_danube_1p8b": (24, 2560, 32, 8, 6912, 32000),
        "minitron_8b": (32, 4096, 32, 8, 16384, 256000),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "deepseek_moe_16b": (28, 2048, 16, 16, 1408, 102400),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
        "pixtral_12b": (40, 5120, 32, 8, 14336, 131072),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), arch
    assert get_config("arctic_480b").moe.num_experts == 128
    assert get_config("arctic_480b").moe.top_k == 2
    assert get_config("deepseek_moe_16b").moe.num_experts == 64
    assert get_config("deepseek_moe_16b").moe.top_k == 6
    assert get_config("deepseek_moe_16b").moe.num_shared_experts == 2
    assert get_config("h2o_danube_1p8b").sliding_window == 4096
    assert get_config("zamba2_1p2b").ssm.d_state == 64
