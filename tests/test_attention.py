"""Attention paths: chunked == dense (incl. SWA), flash-VJP values + grads."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.attention import _chunked_attention, _dense_attention
from repro.models.flash_vjp import flash_attention_vjp

# JAX compile-heavy: excluded from the fast tier (pytest -m "not slow")
pytestmark = pytest.mark.slow


def _rand(key, *shape):
    return 0.3 * jax.random.normal(key, shape, jnp.float32)


@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("chunk", [16, 32])
def test_chunked_matches_dense(window, chunk):
    key = jax.random.PRNGKey(0)
    B, S, Hkv, G, dh = 2, 96, 2, 3, 16
    q = _rand(key, B, S, Hkv, G, dh)
    k = _rand(jax.random.fold_in(key, 1), B, S, Hkv, dh)
    v = _rand(jax.random.fold_in(key, 2), B, S, Hkv, dh)
    pos = jnp.arange(S)
    ref = _dense_attention(q, k, v, pos, pos, window)
    for differentiable in (False, True):
        out = _chunked_attention(q, k, v, window, chunk, differentiable=differentiable)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("window", [None, 40])
def test_flash_vjp_value_and_grads(window):
    key = jax.random.PRNGKey(3)
    B, S, Hkv, G, dh, chunk = 1, 96, 1, 4, 16, 32
    q = _rand(key, B, S, Hkv, G, dh)
    k = _rand(jax.random.fold_in(key, 1), B, S, Hkv, dh)
    v = _rand(jax.random.fold_in(key, 2), B, S, Hkv, dh)
    pos = jnp.arange(S)
    f1 = lambda q, k, v: (flash_attention_vjp(q, k, v, window, chunk) ** 2).sum()
    f2 = lambda q, k, v: (_dense_attention(q, k, v, pos, pos, window) ** 2).sum()
    v1, g1 = jax.value_and_grad(f1, argnums=(0, 1, 2))(q, k, v)
    v2, g2 = jax.value_and_grad(f2, argnums=(0, 1, 2))(q, k, v)
    assert abs(float(v1) - float(v2)) / abs(float(v2)) < 1e-5
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_decode_swa_ring_buffer_positions():
    """Ring-buffer decode must attend exactly the last `window` tokens."""

    from repro.configs import get_config
    from repro.models.transformer import Model

    cfg = get_config("h2o_danube_1p8b").reduced(sliding_window=8)
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    B, S = 1, 40
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    x, _ = m.forward(params, {"tokens": tokens})
    ref = np.asarray(m._head(params, x))
    logits, cache = jax.jit(lambda p, b: m.prefill(p, b, max_len=S))(
        params, {"tokens": tokens[:, :24]}
    )
    dec = jax.jit(lambda p, c, t: m.decode_step(p, c, t))
    errs = []
    for t in range(24, S):
        logits, cache = dec(params, cache, tokens[:, t : t + 1])
        errs.append(np.abs(np.asarray(logits) - ref[:, t]).max())
    # cache holds only 8 slots yet matches the full-window forward exactly
    assert cache["layers"]["kv"]["k"].shape[2] == 8
    assert max(errs) < 2e-3
