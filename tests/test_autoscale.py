"""repro.cluster.autoscale: pinned-bounds parity with the static cluster
(reactive, predictive, and pool-aware modes), request conservation
(exactly-once completed-or-shed) across scale-ups, drains, and retries,
warmup/drain semantics, shedding, the reactive signals, the predictive
M/G/1 policy's lead over the ramp, independent pool scaling, and
provisioning economics vs static peak."""

from dataclasses import replace

import pytest

from repro.configs import get_config
from repro.core.hardware import H100_SXM
from repro.sim import LengthDist, SchedConfig, ServingCostModel, SimRequest, Workload
from repro.cluster import (
    AutoscaleConfig,
    Autoscaler,
    ClusterSpec,
    ReplicaSpec,
    provisioning_summary,
    seed_predictive,
    simulate_cluster,
    summarize_cluster,
)

CFG = get_config("qwen3_14b")


def _wl(**kw):
    base = dict(
        qps=30.0, num_requests=60, arrival="diurnal",
        diurnal_period=20.0, diurnal_amp=0.9,
        prompt=LengthDist("lognormal", 96, 0.4, lo=8, hi=512),
        output=LengthDist("lognormal", 24, 0.4, lo=2, hi=128), seed=0,
    )
    base.update(kw)
    return Workload(**base)


def _spec(pools, *, sched=None, **kw):
    sched = sched or SchedConfig(slots=8)
    return ClusterSpec(
        replicas=tuple(ReplicaSpec(hw="h100", pool=p, sched=sched, ctx_quantum=32)
                       for p in pools),
        **kw)


def _records_key(cres):
    return [(r.rid, r.admitted, r.first_token, r.finish)
            for r in sorted(cres.records, key=lambda r: r.rid)]


# ------------------------------------------------------------ pinned parity
def _pinned_autoscale(kind: str, pools: list[str], wl: Workload):
    """A control loop whose bounds pin the fleet at the template size."""
    n = len(pools)
    if kind == "rate":
        return AutoscaleConfig(min_replicas=n, max_replicas=n,
                               interval=0.5, warmup=1.0)
    if kind == "predictive":
        return seed_predictive(
            AutoscaleConfig(min_replicas=n, max_replicas=n,
                            interval=0.5, warmup=1.0), wl)
    # pool-aware: each pool pinned at its own template count, on the
    # pool-native policies
    counts = {p: pools.count(p) for p in dict.fromkeys(pools)}
    policy = {"mixed": "queue_wait", "prefill": "queue_wait",
              "decode": "kv_tpot"}
    return {p: AutoscaleConfig(policy=policy[p], min_replicas=c,
                               max_replicas=c, interval=0.5, warmup=1.0)
            for p, c in counts.items()}


@pytest.mark.parametrize("kind", ["rate", "predictive", "pool"])
@pytest.mark.parametrize("pools", [["mixed"] * 3,
                                   ["prefill", "decode", "decode"]])
def test_pinned_bounds_reproduce_static_cluster_exactly(pools, kind):
    # min == max == N: the control loop ticks but never acts, and every
    # record is bit-identical to the static N-replica cluster — for the
    # reactive fleet-wide loop, the predictive policy, and independent
    # per-pool loops alike
    wl = _wl()
    reqs = wl.generate()
    static = simulate_cluster(reqs, CFG, _spec(pools))
    pinned = simulate_cluster(reqs, CFG, _spec(pools),
                              autoscale=_pinned_autoscale(kind, pools, wl))
    assert _records_key(pinned) == _records_key(static)
    assert pinned.assignments == static.assignments
    assert pinned.scale_events == []
    assert [r.iterations for r in pinned.replica_results] == \
        [r.iterations for r in static.replica_results]


# ------------------------------------------------------------- conservation
@pytest.mark.parametrize("seed", range(4))
def test_conservation_across_scaling_and_shedding(seed):
    # scale-ups, scale-down drains, retries, and shedding together: every
    # generated request is EXACTLY once completed or shed
    reqs = _wl(seed=seed, num_requests=80, qps=60.0).generate()
    spec = _spec(["mixed"] * 2, shed_depth=10, retry_after=0.2, max_retries=1)
    asc = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=4,
                          interval=0.5, window=2.0, target_qps_per_replica=10.0,
                          warmup=0.5)
    cres = simulate_cluster(reqs, CFG, spec, autoscale=asc)
    done = sorted(r.rid for r in cres.records)
    shed = sorted(r.rid for r in cres.shed)
    assert sorted(done + shed) == list(range(80))  # exactly-once, no overlap
    for r in cres.records:
        assert r.finish >= r.first_token >= r.arrival
        assert r.admitted >= r.arrival
    for rep in cres.replica_results:
        assert rep.peak_kv <= rep.kv_capacity


def test_conservation_with_preemption_and_drain():
    # tight KV forces preemption while the fleet is also draining down
    cost = ServingCostModel(CFG, H100_SXM, ctx_quantum=32)
    reqs = _wl(num_requests=40, qps=80.0,
               prompt=LengthDist("lognormal", 128, 0.5, lo=16, hi=512),
               output=LengthDist("lognormal", 64, 0.5, lo=8, hi=256)).generate()
    cap = 3.0 * max(cost.kv_bytes(r.prompt + r.output) for r in reqs)
    sc = SchedConfig(slots=8, kv_capacity=cap)
    asc = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=3,
                          interval=0.5, window=2.0, target_qps_per_replica=15.0,
                          warmup=0.3)
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"] * 2, sched=sc),
                            autoscale=asc)
    assert sorted(r.rid for r in cres.records) == list(range(40))
    assert sum(r.preemptions for r in cres.replica_results) > 0


# ----------------------------------------------------------- fleet dynamics
def _burst_then_quiet(n_burst=40, quiet_at=30.0):
    reqs = [SimRequest(i, 0.02 * i, 96, 16) for i in range(n_burst)]
    reqs.append(SimRequest(n_burst, quiet_at, 96, 4))  # lone straggler
    return reqs


def test_scale_up_waits_for_warmup():
    # new replicas take no traffic before `ready`; their first admission
    # happens at or after the warmup completes
    reqs = _burst_then_quiet()
    asc = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=4,
                          interval=0.25, window=1.0, target_qps_per_replica=5.0,
                          warmup=2.0)
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"]), autoscale=asc)
    adds = [ev for ev in cres.scale_events if ev["action"] == "add"]
    assert adds, "burst must trigger scale-up"
    for ev in adds:
        assert ev["ready"] == pytest.approx(ev["t"] + 2.0)
        recs = cres.replica_results[ev["replica"]].records
        for rec in recs:
            assert rec.admitted >= ev["ready"]


def test_warmup_priced_from_weight_bytes():
    cost = ServingCostModel(CFG, H100_SXM)
    asc = AutoscaleConfig(host_bw=64e9)
    assert asc.warmup_seconds(cost) == pytest.approx(cost.weight_bytes / 64e9)
    # a tp=2 replica loads half the bytes per device -> half the warmup
    cost2 = ServingCostModel(CFG, H100_SXM, tp=2)
    assert asc.warmup_seconds(cost2) == pytest.approx(
        asc.warmup_seconds(cost) / 2)
    assert AutoscaleConfig(warmup=7.5).warmup_seconds(cost) == 7.5


def test_scale_down_drains_gracefully():
    # after the burst the fleet shrinks; drained replicas stop billing
    # before the run ends and never abandon admitted work
    reqs = _burst_then_quiet()
    asc = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=4,
                          interval=0.25, window=1.0, target_qps_per_replica=5.0,
                          warmup=0.25)
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"]), autoscale=asc)
    drains = [ev for ev in cres.scale_events if ev["action"] == "drain"]
    assert drains, "quiet tail must trigger scale-down"
    end = max(e for _, e in cres.replica_spans)
    drained = {ev["replica"] for ev in drains}
    for i in drained:
        s, e = cres.replica_spans[i]
        assert e < end  # billing stopped early
        for rec in cres.replica_results[i].records:
            assert rec.finish >= 0  # nothing abandoned
    assert sorted(r.rid for r in cres.records) == [r.rid for r in reqs]
    # conservation of billing: hours equal the span sum, peak bounded
    assert cres.replica_hours == pytest.approx(
        sum(e - s for s, e in cres.replica_spans) / 3600.0)
    assert 1 <= cres.peak_replicas <= 4


def test_no_phantom_spawn_after_work_finishes():
    # the rate signal's rolling window outlives the trace: a control tick
    # firing after the last request completed must not spawn a replica
    # that never serves (it would bill a negative/garbage span)
    reqs = _wl(num_requests=60, qps=40.0, arrival="poisson").generate()
    asc = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=4,
                          interval=5.0, window=15.0,
                          target_qps_per_replica=8.0, warmup=1.0)
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"]), autoscale=asc)
    assert all(e >= s for s, e in cres.replica_spans)
    assert cres.replica_hours >= 0.0
    prov = provisioning_summary(cres)
    assert prov["cost_usd"] >= 0.0 and prov["savings_frac"] <= 1.0
    # every spawned replica either served something or was billed a
    # non-negative warmup stub — none appear after the run went idle
    last_finish = max(r.finish for r in cres.records)
    for ev in cres.scale_events:
        if ev["action"] == "add":
            assert ev["t"] <= last_finish


def test_provisioning_summary_beats_static_peak_on_diurnal():
    # the acceptance headline: SLO met with measurably fewer replica-hours
    wl = _wl(num_requests=400, qps=20.0, diurnal_period=40.0,
             prompt=LengthDist("lognormal", 256, 0.4, lo=16, hi=2048),
             output=LengthDist("lognormal", 64, 0.4, lo=4, hi=512))
    reqs = wl.generate()
    cache = {}
    asc = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=5,
                          interval=1.5, window=5.0, target_qps_per_replica=8.0)
    dyn = simulate_cluster(reqs, CFG, _spec(["mixed"] * 2), autoscale=asc,
                           _cost_cache=cache)
    s = summarize_cluster(dyn, slo_ttft=2.0)
    prov = provisioning_summary(dyn)
    assert s["goodput_frac"] >= 0.9  # SLO substantially met
    assert prov["replica_hours"] < 0.9 * prov["replica_hours_static_peak"]
    assert prov["cost_usd"] < prov["cost_usd_static_peak"]
    assert 0.0 < prov["savings_frac"] < 1.0


# ------------------------------------------------------------ load shedding
def test_shedding_bounds_depth_and_drops_after_retries():
    reqs = [SimRequest(i, 0.0, 96, 16) for i in range(30)]
    spec = _spec(["mixed"], shed_depth=5, retry_after=0.1, max_retries=0)
    cres = simulate_cluster(reqs, CFG, spec)
    assert len(cres.shed) == 25  # depth 5, 30 simultaneous arrivals
    assert cres.retries == 0
    assert len(cres.records) == 5
    s = summarize_cluster(cres)
    assert s["shed"] == 25 and s["shed_frac"] == pytest.approx(25 / 30)


def test_retries_can_succeed_after_backoff():
    # one slow burst: retried arrivals land once the queue drains below the
    # threshold, and their TTFT includes the backoff they paid
    reqs = [SimRequest(i, 0.0, 96, 8) for i in range(8)]
    spec = _spec(["mixed"], shed_depth=6, retry_after=0.5, max_retries=8)
    cres = simulate_cluster(reqs, CFG, spec)
    assert cres.retries > 0
    assert len(cres.records) == 8 and not cres.shed  # all eventually served
    retried = [r for r in cres.records if r.admitted - r.arrival >= 0.5]
    assert retried
    assert all(r.first_token >= r.arrival + 0.5 for r in retried)


def test_shed_disabled_by_default():
    reqs = [SimRequest(i, 0.0, 96, 8) for i in range(30)]
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"]))
    assert not cres.shed and cres.retries == 0
    assert len(cres.records) == 30


# ------------------------------------------------------------------ signals
def test_autoscaler_rate_tracking_and_clamping():
    asc = AutoscaleConfig(policy="rate", min_replicas=2, max_replicas=5,
                          interval=1.0, window=10.0, target_qps_per_replica=4.0)
    sc = Autoscaler(asc)
    for i in range(100):
        sc.observe_arrival(i * 0.1)  # 10 qps over [0, 10)
    assert sc.observed_rate(10.0) == pytest.approx(10.0, rel=0.05)
    assert sc.desired(10.0, provisioned=2) == 3  # ceil(10/4)
    for i in range(400):
        sc.observe_arrival(10.0 + i * 0.01)  # 100 qps burst
    assert sc.desired(14.0, provisioned=3) == 5  # clamped at max
    assert sc.desired(60.0, provisioned=5) == 2  # window empty -> min


def test_autoscaler_slo_debt_hysteresis():
    asc = AutoscaleConfig(policy="slo_debt", min_replicas=1, max_replicas=8,
                          window=10.0, slo_ttft=1.0, debt_hi=0.2, debt_lo=0.05)
    sc = Autoscaler(asc)
    for i in range(10):
        sc.observe_ttft(5.0, ttft=2.0 if i < 3 else 0.1)  # 30% violations
    assert sc.slo_debt(5.0) == pytest.approx(0.3)
    assert sc.desired(5.0, provisioned=3) == 4  # above hi -> grow
    sc2 = Autoscaler(asc)
    for _ in range(50):
        sc2.observe_ttft(5.0, ttft=0.1)
    assert sc2.desired(5.0, provisioned=3) == 2  # below lo -> shrink
    sc3 = Autoscaler(asc)
    for i in range(10):
        sc3.observe_ttft(5.0, ttft=2.0 if i < 1 else 0.1)  # 10%: in band
    assert sc3.desired(5.0, provisioned=3) == 3


def test_slo_debt_signal_includes_shed_retry_backoff():
    # the debt signal must see the END-TO-END TTFT (backoff included), not
    # the replica-local wait after re-dispatch — otherwise a fleet in SLO
    # breach purely from shedding backoff would never scale up
    reqs = [SimRequest(i, 0.0, 96, 8) for i in range(12)]
    spec = _spec(["mixed"], shed_depth=4, retry_after=1.0, max_retries=8)
    asc = AutoscaleConfig(policy="slo_debt", min_replicas=1, max_replicas=4,
                          interval=0.5, window=10.0, slo_ttft=0.5,
                          debt_hi=0.05, warmup=0.25)
    cres = simulate_cluster(reqs, CFG, spec, autoscale=asc)
    breached = sum(1 for r in cres.records if r.ttft > 0.5)
    assert breached > 0  # the backoff alone blows the 0.5s deadline
    assert any(ev["action"] == "add" for ev in cres.scale_events)


def test_slo_debt_policy_scales_up_under_violation():
    reqs = _wl(num_requests=80, qps=60.0, arrival="poisson").generate()
    asc = AutoscaleConfig(policy="slo_debt", min_replicas=1, max_replicas=4,
                          interval=0.5, window=3.0, slo_ttft=0.5,
                          debt_hi=0.1, warmup=0.25)
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"]), autoscale=asc)
    assert any(ev["action"] == "add" for ev in cres.scale_events)
    assert sorted(r.rid for r in cres.records) == list(range(80))


def test_autoscale_config_validation():
    for bad in (dict(policy="magic"), dict(min_replicas=0),
                dict(min_replicas=3, max_replicas=2), dict(interval=0.0),
                dict(target_qps_per_replica=0.0), dict(warmup=-1.0),
                dict(debt_lo=0.5, debt_hi=0.1), dict(host_bw=0.0)):
        with pytest.raises(ValueError):
            AutoscaleConfig(**bad).validate()


def test_cluster_spec_shed_validation():
    with pytest.raises(ValueError, match="shed_depth"):
        _spec(["mixed"], shed_depth=0).validate()
    with pytest.raises(ValueError, match="retry_after"):
        _spec(["mixed"], shed_depth=2, retry_after=0.0).validate()


def test_slo_debt_expires_across_idle_gaps_in_cluster():
    # an idle replica's own clock stops; dispatch-time view clamping must
    # let old debt fall out of the rolling window, so a replica that blew
    # its SLO long ago is forgiven once the window has passed
    early = [SimRequest(i, 0.0, 256, 32) for i in range(6)]  # overload r0+r1
    late = [SimRequest(6, 500.0, 64, 2)]  # long idle gap >> debt_window
    spec = _spec(["mixed"] * 2, router="slo_debt",
                 router_slo_ttft=1e-6, debt_window=30.0)
    cres = simulate_cluster(early + late, CFG, spec)
    # the late request routes by depth (both clean), i.e. to replica 0 —
    # not away from whichever replica carried the stale violations
    assert cres.assignments[6][0] == 0
    assert sorted(r.rid for r in cres.records) == list(range(7))


def test_disaggregated_autoscale_rejects_unachievable_bounds():
    reqs = _wl(num_requests=4).generate()
    with pytest.raises(ValueError, match="max_replicas >= 2"):
        simulate_cluster(reqs, CFG, _spec(["prefill", "decode"]),
                         autoscale=AutoscaleConfig(min_replicas=1,
                                                   max_replicas=1))


# -------------------------------------------------- disaggregated autoscale
def test_disaggregated_autoscale_keeps_pool_ratio_and_conserves():
    reqs = _wl(num_requests=60, qps=40.0).generate()
    asc = AutoscaleConfig(policy="rate", min_replicas=2, max_replicas=6,
                          interval=0.5, window=2.0, target_qps_per_replica=8.0,
                          warmup=0.5)
    cres = simulate_cluster(reqs, CFG, _spec(["prefill", "decode"]),
                            autoscale=asc)
    assert sorted(r.rid for r in cres.records) == list(range(60))
    # both pools always have at least one provisioned member
    for pool in ("prefill", "decode"):
        assert any(p == pool for p in cres.replica_pools)
    # prefill stage + (multi-token) decode stage cover every request
    multi = [r for r in reqs if r.output > 1]
    assert cres.xfer_count == len(multi)


# --------------------------------------------------------- predictive policy
def test_predicted_wait_pollaczek_khinchine():
    asc = AutoscaleConfig(policy="predictive", min_replicas=1, max_replicas=8,
                          service_time=0.2, service_cv2=1.0)
    sc = Autoscaler(asc)
    # rho = 2 qps * 0.2 s = 0.4 on one replica: Wq = .4 * 1 * .2 / .6
    assert sc.predicted_wait(2.0, 1) == pytest.approx(0.4 * 0.2 / 0.6)
    # n scales the per-replica rate down
    assert sc.predicted_wait(4.0, 2) == pytest.approx(sc.predicted_wait(2.0, 1))
    # saturation -> infinite wait
    assert sc.predicted_wait(5.0, 1) == float("inf")
    # deterministic service (cv2=0) halves the M/M/1 wait
    det = Autoscaler(replace(asc, service_cv2=0.0))
    assert det.predicted_wait(2.0, 1) == pytest.approx(0.2 * 0.4 / 0.6 / 2)


def test_predictive_desired_sizes_for_envelope_peak():
    wl = Workload(qps=10.0, arrival="diurnal", diurnal_period=100.0,
                  diurnal_amp=0.8)
    asc = AutoscaleConfig(policy="predictive", min_replicas=1, max_replicas=10,
                          interval=1.0, service_time=0.2, target_wait=0.2,
                          envelope=wl.peak_rate, lookahead=20.0)
    far = Autoscaler(asc).desired(5.0, 1)  # horizon covers the t=25 crest
    near = Autoscaler(replace(asc, lookahead=1e-6)).desired(5.0, 1)
    assert far > near  # the lookahead provisions for the crest ahead
    # smallest n meeting the wait budget at the horizon peak (18 qps)
    sc = Autoscaler(asc)
    want = sc.desired(5.0, 1)
    assert want < 10  # the budget is reachable inside the bounds
    assert sc.predicted_wait(18.0, want) <= 0.2
    assert sc.predicted_wait(18.0, want - 1) > 0.2
    # an empty envelope window (overnight) falls to min_replicas
    assert Autoscaler(asc).desired(70.0, 5) >= 1


def test_predictive_needs_service_time():
    asc = AutoscaleConfig(policy="predictive")
    with pytest.raises(ValueError, match="service_time"):
        Autoscaler(asc)
    cost = ServingCostModel(CFG, H100_SXM, ctx_quantum=32)
    sc = Autoscaler(asc, cost=cost, sched=SchedConfig(slots=8))
    assert sc.service_time > 0  # priced from the cost model


def test_effective_service_time_pool_variants():
    cost = ServingCostModel(CFG, H100_SXM, ctx_quantum=32)
    asc = AutoscaleConfig(mean_prompt=256, mean_output=64)
    sched = SchedConfig(slots=8)
    pre = asc.effective_service_time(cost, sched, "prefill")
    dec = asc.effective_service_time(cost, sched, "decode")
    mix = asc.effective_service_time(cost, sched, "mixed")
    # prefill pays the whole prompt serially; the batched pools amortize
    assert pre == pytest.approx(cost.prefill_time(256))
    assert mix > dec  # mixed adds the prefill share on top of decode
    assert mix == pytest.approx(pre / 8 + dec, rel=1e-6)
    # explicit override wins
    assert replace(asc, service_time=0.5).effective_service_time(
        cost, sched, "mixed") == 0.5


def test_seed_predictive_from_workload_and_requests():
    wl = _wl()
    reqs = wl.generate()
    asc = seed_predictive(AutoscaleConfig(), wl, reqs)
    assert asc.policy == "predictive"
    assert asc.envelope.__self__ is wl  # bound to the workload's peak_rate
    assert asc.envelope(0.0, 10.0) == wl.peak_rate(0.0, 10.0)
    assert asc.mean_prompt == pytest.approx(
        sum(r.prompt for r in reqs) / len(reqs))
    # without requests the spec's distribution means are used
    asc2 = seed_predictive(AutoscaleConfig(), wl)
    assert asc2.mean_prompt == wl.prompt.mean


def test_predictive_leads_ramp_by_warmup():
    # the acceptance assertion: under a slow 2 s warmup, predictive
    # scale-ups fire at least a warmup BEFORE the envelope crest, so the
    # capacity is accepting by the time the peak arrives
    warmup = 2.0
    wl = _wl(qps=20.0, num_requests=300, diurnal_period=40.0,
             prompt=LengthDist("lognormal", 256, 0.4, lo=16, hi=2048),
             output=LengthDist("lognormal", 64, 0.4, lo=4, hi=512))
    reqs = wl.generate()
    t_peak = wl.diurnal_period / 4  # sin crest of the first day
    asc = seed_predictive(
        AutoscaleConfig(min_replicas=2, max_replicas=5, interval=0.5,
                        window=5.0, warmup=warmup), wl, reqs)
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"] * 2), autoscale=asc)
    adds = [ev for ev in cres.scale_events
            if ev["action"] == "add" and ev["t"] <= t_peak]
    assert adds, "the ramp must trigger predictive scale-up"
    assert min(ev["t"] for ev in adds) <= t_peak - warmup
    for ev in adds:  # ordered early enough to be READY by the crest
        assert ev["ready"] <= t_peak
    assert sorted(r.rid for r in cres.records) == list(range(300))


# -------------------------------------------------------- pool-aware scaling
def test_queue_wait_policy_hysteresis():
    asc = AutoscaleConfig(policy="queue_wait", min_replicas=1, max_replicas=8,
                          window=10.0, wait_hi=0.5, wait_lo=0.1)
    sc = Autoscaler(asc)
    for i in range(10):
        sc.observe_wait(5.0, 1.0)  # mean wait 1.0 > hi
    assert sc.queue_wait(5.0) == pytest.approx(1.0)
    assert sc.desired(5.0, 3) == 4
    sc2 = Autoscaler(asc)
    for i in range(10):
        sc2.observe_wait(5.0, 0.01)  # below lo -> shrink
    assert sc2.desired(5.0, 3) == 2
    sc3 = Autoscaler(asc)
    for i in range(10):
        sc3.observe_wait(5.0, 0.3)  # inside the band -> hold
    assert sc3.desired(5.0, 3) == 3
    assert Autoscaler(asc).desired(5.0, 1) == 1  # empty window: hold at min


def test_kv_tpot_policy_signals():
    asc = AutoscaleConfig(policy="kv_tpot", min_replicas=1, max_replicas=8,
                          window=10.0, slo_tpot=0.05, debt_hi=0.2,
                          debt_lo=0.02, kv_hi=0.85, kv_lo=0.40)
    sc = Autoscaler(asc)
    assert sc.desired(5.0, 3, kv_frac=0.9) == 4  # KV pressure alone
    for i in range(10):
        sc.observe_tpot(5.0, 0.2 if i < 3 else 0.01)  # 30% violations
    assert sc.tpot_debt(5.0) == pytest.approx(0.3)
    assert sc.desired(5.0, 3, kv_frac=0.5) == 4  # TPOT debt alone
    sc2 = Autoscaler(asc)
    for _ in range(10):
        sc2.observe_tpot(5.0, 0.01)
    assert sc2.desired(5.0, 3, kv_frac=0.2) == 2  # both low -> shrink
    assert sc2.desired(5.0, 3, kv_frac=0.6) == 3  # KV in band -> hold


def test_pool_aware_scales_bottleneck_pool_only():
    # prefill-heavy stream: the prefill pool grows, the decode pool holds
    # its floor — the template ratio would have grown both
    wl = _wl(qps=6.0, num_requests=80, diurnal_period=40.0, diurnal_amp=0.8,
             prompt=LengthDist("lognormal", 2048, 0.3, lo=256, hi=6144),
             output=LengthDist("lognormal", 16, 0.4, lo=2, hi=64))
    reqs = wl.generate()
    base = AutoscaleConfig(min_replicas=1, max_replicas=4, interval=0.5,
                           window=3.0, warmup=0.5)
    pa = {"prefill": seed_predictive(base, wl, reqs),
          "decode": replace(base, policy="kv_tpot")}
    cres = simulate_cluster(reqs, CFG, _spec(["prefill", "decode"]),
                            autoscale=pa)
    adds = [ev for ev in cres.scale_events if ev["action"] == "add"]
    assert adds and all(ev["pool"] == "prefill" for ev in adds)
    assert sorted(r.rid for r in cres.records) == list(range(80))
    prov = provisioning_summary(cres)
    assert set(prov["pools"]) == {"prefill", "decode"}
    assert prov["pools"]["prefill"]["peak_replicas"] > \
        prov["pools"]["decode"]["peak_replicas"]
    # per-pool billing partitions the fleet bill exactly
    assert sum(p["replica_hours"] for p in prov["pools"].values()) == \
        pytest.approx(prov["replica_hours"])


def test_decode_pool_drain_rehands_pending_handoffs():
    # the mid-handoff shrink: a decode replica drains while staged
    # handoffs sit in its queue; they re-route to the survivors (paying a
    # second p2p hop) and every request still completes exactly once
    reqs = [SimRequest(i, 0.001 * i, 64, 8) for i in range(24)]
    spec = _spec(["prefill", "decode", "decode"],
                 sched=SchedConfig(slots=2))
    pa = {"decode": AutoscaleConfig(
        policy="kv_tpot", min_replicas=1, max_replicas=2, interval=0.15,
        window=5.0, warmup=0.1, slo_tpot=1e9, kv_hi=1.0, kv_lo=1.0,
        debt_hi=1.0, debt_lo=1.0)}  # always asks to shrink
    cres = simulate_cluster(reqs, CFG, spec, autoscale=pa)
    drains = [ev for ev in cres.scale_events if ev["action"] == "drain"]
    assert drains and all(ev["pool"] == "decode" for ev in drains)
    assert sorted(r.rid for r in cres.records) == list(range(24))
    for r in cres.records:
        assert r.finish >= r.first_token >= r.arrival
    # re-routed handoffs paid extra transfer hops
    assert cres.xfer_count > 24
    # the prefill pool was never touched (no scaler attached)
    assert all(ev["pool"] != "prefill" for ev in cres.scale_events)


def test_pool_autoscale_validation():
    reqs = _wl(num_requests=4).generate()
    with pytest.raises(ValueError, match="names pool"):
        simulate_cluster(reqs, CFG, _spec(["mixed"]),
                         autoscale={"prefill": AutoscaleConfig()})
    with pytest.raises(ValueError, match="AutoscaleConfig"):
        simulate_cluster(reqs, CFG, _spec(["mixed"]),
                         autoscale={"mixed": "rate"})


def test_autoscale_config_new_field_validation():
    for bad in (dict(lookahead=0.0), dict(target_wait=-1.0),
                dict(service_time=0.0), dict(service_cv2=-0.1),
                dict(mean_prompt=0), dict(wait_lo=0.5, wait_hi=0.1),
                dict(slo_tpot=0.0), dict(kv_lo=0.9, kv_hi=0.5),
                dict(kv_hi=1.5)):
        with pytest.raises(ValueError):
            AutoscaleConfig(**bad).validate()


# ------------------------------------------------------- shed-aware economics
def test_provisioning_summary_prices_shedding():
    reqs = [SimRequest(i, 0.0, 96, 16) for i in range(30)]
    spec = _spec(["mixed"], shed_depth=5, retry_after=0.1, max_retries=0)
    cres = simulate_cluster(reqs, CFG, spec)
    prov = provisioning_summary(cres, shed_cost_usd=0.01)
    assert prov["shed"] == 25
    assert prov["shed_cost_usd"] == pytest.approx(0.25)
    assert prov["cost_usd_total"] == pytest.approx(
        prov["cost_usd"] + 0.25)
    # free drops keep the old totals
    free = provisioning_summary(cres)
    assert free["shed_cost_usd"] == 0.0
    assert free["cost_usd_total"] == pytest.approx(free["cost_usd"])


# ---------------------------------------------------------- golden regression
def _sig6(x: float) -> float:
    return float(f"{x:.6g}")


def test_golden_autoscale_modes_pinned():
    # fixed-seed predictive and pool-aware runs with summary metrics
    # pinned to 6 significant figures: catches silent policy/engine drift
    # behavioral tests cannot see. If a deliberate change moves these,
    # re-pin them in the same PR and say why in the commit message.
    wl = _wl(qps=60.0, num_requests=240,
             prompt=LengthDist("lognormal", 192, 0.4, lo=16, hi=1024))
    reqs = wl.generate()
    keys = ("ttft_p50", "ttft_p95", "tpot_p50", "tpot_p95", "e2e_mean",
            "tokens_per_s", "goodput_frac", "makespan_s")

    asc = seed_predictive(
        AutoscaleConfig(min_replicas=1, max_replicas=4, interval=0.5,
                        window=2.0, warmup=0.5), wl, reqs)
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"]), autoscale=asc)
    s = summarize_cluster(cres, slo_ttft=2.0, slo_tpot=0.05)
    got = {k: _sig6(s[k]) for k in keys}
    assert got == dict(
        ttft_p50=0.452514, ttft_p95=1.30541,
        tpot_p50=0.0175626, tpot_p95=0.0204003,
        e2e_mean=0.871226, tokens_per_s=1292.04,
        goodput_frac=1.0, makespan_s=4.32882), "predictive golden drift"
    assert s["scale_events"] == 3 and s["peak_replicas"] == 4
    assert _sig6(provisioning_summary(cres)["replica_hours"]) == 0.00439977

    base = AutoscaleConfig(min_replicas=1, max_replicas=3, interval=0.5,
                           window=2.0, warmup=0.5)
    pa = {"prefill": replace(base, policy="queue_wait",
                             wait_hi=0.1, wait_lo=0.02),
          "decode": replace(base, policy="kv_tpot",
                            kv_hi=0.02, kv_lo=0.001)}
    cres = simulate_cluster(reqs, CFG, _spec(["prefill", "decode"]),
                            autoscale=pa)
    s = summarize_cluster(cres, slo_ttft=2.0, slo_tpot=0.05)
    got = {k: _sig6(s[k]) for k in keys}
    assert got == dict(
        ttft_p50=0.457187, ttft_p95=1.4918,
        tpot_p50=0.0418892, tpot_p95=0.131398,
        e2e_mean=1.65825, tokens_per_s=875.385,
        goodput_frac=0.545833, makespan_s=6.38919), "pool-aware golden drift"
    assert s["scale_events"] == 4 and s["peak_replicas"] == 6
    assert _sig6(provisioning_summary(cres)["replica_hours"]) == 0.00788081
