"""repro.cluster.autoscale: pinned-bounds parity with the static cluster,
request conservation (exactly-once completed-or-shed) across scale-ups,
drains, and retries, warmup/drain semantics, shedding, the SLO-debt
signals, and provisioning economics vs static peak."""

import pytest

from repro.configs import get_config
from repro.core.hardware import H100_SXM
from repro.sim import LengthDist, SchedConfig, ServingCostModel, SimRequest, Workload
from repro.cluster import (
    AutoscaleConfig,
    Autoscaler,
    ClusterSpec,
    ReplicaSpec,
    provisioning_summary,
    simulate_cluster,
    summarize_cluster,
)

CFG = get_config("qwen3_14b")


def _wl(**kw):
    base = dict(
        qps=30.0, num_requests=60, arrival="diurnal",
        diurnal_period=20.0, diurnal_amp=0.9,
        prompt=LengthDist("lognormal", 96, 0.4, lo=8, hi=512),
        output=LengthDist("lognormal", 24, 0.4, lo=2, hi=128), seed=0,
    )
    base.update(kw)
    return Workload(**base)


def _spec(pools, *, sched=None, **kw):
    sched = sched or SchedConfig(slots=8)
    return ClusterSpec(
        replicas=tuple(ReplicaSpec(hw="h100", pool=p, sched=sched, ctx_quantum=32)
                       for p in pools),
        **kw)


def _records_key(cres):
    return [(r.rid, r.admitted, r.first_token, r.finish)
            for r in sorted(cres.records, key=lambda r: r.rid)]


# ------------------------------------------------------------ pinned parity
@pytest.mark.parametrize("pools", [["mixed"] * 3,
                                   ["prefill", "decode", "decode"]])
def test_pinned_bounds_reproduce_static_cluster_exactly(pools):
    # min == max == N: the control loop ticks but never acts, and every
    # record is bit-identical to the static N-replica cluster
    reqs = _wl().generate()
    n = len(pools)
    static = simulate_cluster(reqs, CFG, _spec(pools))
    pinned = simulate_cluster(
        reqs, CFG, _spec(pools),
        autoscale=AutoscaleConfig(min_replicas=n, max_replicas=n,
                                  interval=0.5, warmup=1.0))
    assert _records_key(pinned) == _records_key(static)
    assert pinned.assignments == static.assignments
    assert pinned.scale_events == []
    assert [r.iterations for r in pinned.replica_results] == \
        [r.iterations for r in static.replica_results]


# ------------------------------------------------------------- conservation
@pytest.mark.parametrize("seed", range(4))
def test_conservation_across_scaling_and_shedding(seed):
    # scale-ups, scale-down drains, retries, and shedding together: every
    # generated request is EXACTLY once completed or shed
    reqs = _wl(seed=seed, num_requests=80, qps=60.0).generate()
    spec = _spec(["mixed"] * 2, shed_depth=10, retry_after=0.2, max_retries=1)
    asc = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=4,
                          interval=0.5, window=2.0, target_qps_per_replica=10.0,
                          warmup=0.5)
    cres = simulate_cluster(reqs, CFG, spec, autoscale=asc)
    done = sorted(r.rid for r in cres.records)
    shed = sorted(r.rid for r in cres.shed)
    assert sorted(done + shed) == list(range(80))  # exactly-once, no overlap
    for r in cres.records:
        assert r.finish >= r.first_token >= r.arrival
        assert r.admitted >= r.arrival
    for rep in cres.replica_results:
        assert rep.peak_kv <= rep.kv_capacity


def test_conservation_with_preemption_and_drain():
    # tight KV forces preemption while the fleet is also draining down
    cost = ServingCostModel(CFG, H100_SXM, ctx_quantum=32)
    reqs = _wl(num_requests=40, qps=80.0,
               prompt=LengthDist("lognormal", 128, 0.5, lo=16, hi=512),
               output=LengthDist("lognormal", 64, 0.5, lo=8, hi=256)).generate()
    cap = 3.0 * max(cost.kv_bytes(r.prompt + r.output) for r in reqs)
    sc = SchedConfig(slots=8, kv_capacity=cap)
    asc = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=3,
                          interval=0.5, window=2.0, target_qps_per_replica=15.0,
                          warmup=0.3)
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"] * 2, sched=sc),
                            autoscale=asc)
    assert sorted(r.rid for r in cres.records) == list(range(40))
    assert sum(r.preemptions for r in cres.replica_results) > 0


# ----------------------------------------------------------- fleet dynamics
def _burst_then_quiet(n_burst=40, quiet_at=30.0):
    reqs = [SimRequest(i, 0.02 * i, 96, 16) for i in range(n_burst)]
    reqs.append(SimRequest(n_burst, quiet_at, 96, 4))  # lone straggler
    return reqs


def test_scale_up_waits_for_warmup():
    # new replicas take no traffic before `ready`; their first admission
    # happens at or after the warmup completes
    reqs = _burst_then_quiet()
    asc = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=4,
                          interval=0.25, window=1.0, target_qps_per_replica=5.0,
                          warmup=2.0)
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"]), autoscale=asc)
    adds = [ev for ev in cres.scale_events if ev["action"] == "add"]
    assert adds, "burst must trigger scale-up"
    for ev in adds:
        assert ev["ready"] == pytest.approx(ev["t"] + 2.0)
        recs = cres.replica_results[ev["replica"]].records
        for rec in recs:
            assert rec.admitted >= ev["ready"]


def test_warmup_priced_from_weight_bytes():
    cost = ServingCostModel(CFG, H100_SXM)
    asc = AutoscaleConfig(host_bw=64e9)
    assert asc.warmup_seconds(cost) == pytest.approx(cost.weight_bytes / 64e9)
    # a tp=2 replica loads half the bytes per device -> half the warmup
    cost2 = ServingCostModel(CFG, H100_SXM, tp=2)
    assert asc.warmup_seconds(cost2) == pytest.approx(
        asc.warmup_seconds(cost) / 2)
    assert AutoscaleConfig(warmup=7.5).warmup_seconds(cost) == 7.5


def test_scale_down_drains_gracefully():
    # after the burst the fleet shrinks; drained replicas stop billing
    # before the run ends and never abandon admitted work
    reqs = _burst_then_quiet()
    asc = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=4,
                          interval=0.25, window=1.0, target_qps_per_replica=5.0,
                          warmup=0.25)
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"]), autoscale=asc)
    drains = [ev for ev in cres.scale_events if ev["action"] == "drain"]
    assert drains, "quiet tail must trigger scale-down"
    end = max(e for _, e in cres.replica_spans)
    drained = {ev["replica"] for ev in drains}
    for i in drained:
        s, e = cres.replica_spans[i]
        assert e < end  # billing stopped early
        for rec in cres.replica_results[i].records:
            assert rec.finish >= 0  # nothing abandoned
    assert sorted(r.rid for r in cres.records) == [r.rid for r in reqs]
    # conservation of billing: hours equal the span sum, peak bounded
    assert cres.replica_hours == pytest.approx(
        sum(e - s for s, e in cres.replica_spans) / 3600.0)
    assert 1 <= cres.peak_replicas <= 4


def test_no_phantom_spawn_after_work_finishes():
    # the rate signal's rolling window outlives the trace: a control tick
    # firing after the last request completed must not spawn a replica
    # that never serves (it would bill a negative/garbage span)
    reqs = _wl(num_requests=60, qps=40.0, arrival="poisson").generate()
    asc = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=4,
                          interval=5.0, window=15.0,
                          target_qps_per_replica=8.0, warmup=1.0)
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"]), autoscale=asc)
    assert all(e >= s for s, e in cres.replica_spans)
    assert cres.replica_hours >= 0.0
    prov = provisioning_summary(cres)
    assert prov["cost_usd"] >= 0.0 and prov["savings_frac"] <= 1.0
    # every spawned replica either served something or was billed a
    # non-negative warmup stub — none appear after the run went idle
    last_finish = max(r.finish for r in cres.records)
    for ev in cres.scale_events:
        if ev["action"] == "add":
            assert ev["t"] <= last_finish


def test_provisioning_summary_beats_static_peak_on_diurnal():
    # the acceptance headline: SLO met with measurably fewer replica-hours
    wl = _wl(num_requests=400, qps=20.0, diurnal_period=40.0,
             prompt=LengthDist("lognormal", 256, 0.4, lo=16, hi=2048),
             output=LengthDist("lognormal", 64, 0.4, lo=4, hi=512))
    reqs = wl.generate()
    cache = {}
    asc = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=5,
                          interval=1.5, window=5.0, target_qps_per_replica=8.0)
    dyn = simulate_cluster(reqs, CFG, _spec(["mixed"] * 2), autoscale=asc,
                           _cost_cache=cache)
    s = summarize_cluster(dyn, slo_ttft=2.0)
    prov = provisioning_summary(dyn)
    assert s["goodput_frac"] >= 0.9  # SLO substantially met
    assert prov["replica_hours"] < 0.9 * prov["replica_hours_static_peak"]
    assert prov["cost_usd"] < prov["cost_usd_static_peak"]
    assert 0.0 < prov["savings_frac"] < 1.0


# ------------------------------------------------------------ load shedding
def test_shedding_bounds_depth_and_drops_after_retries():
    reqs = [SimRequest(i, 0.0, 96, 16) for i in range(30)]
    spec = _spec(["mixed"], shed_depth=5, retry_after=0.1, max_retries=0)
    cres = simulate_cluster(reqs, CFG, spec)
    assert len(cres.shed) == 25  # depth 5, 30 simultaneous arrivals
    assert cres.retries == 0
    assert len(cres.records) == 5
    s = summarize_cluster(cres)
    assert s["shed"] == 25 and s["shed_frac"] == pytest.approx(25 / 30)


def test_retries_can_succeed_after_backoff():
    # one slow burst: retried arrivals land once the queue drains below the
    # threshold, and their TTFT includes the backoff they paid
    reqs = [SimRequest(i, 0.0, 96, 8) for i in range(8)]
    spec = _spec(["mixed"], shed_depth=6, retry_after=0.5, max_retries=8)
    cres = simulate_cluster(reqs, CFG, spec)
    assert cres.retries > 0
    assert len(cres.records) == 8 and not cres.shed  # all eventually served
    retried = [r for r in cres.records if r.admitted - r.arrival >= 0.5]
    assert retried
    assert all(r.first_token >= r.arrival + 0.5 for r in retried)


def test_shed_disabled_by_default():
    reqs = [SimRequest(i, 0.0, 96, 8) for i in range(30)]
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"]))
    assert not cres.shed and cres.retries == 0
    assert len(cres.records) == 30


# ------------------------------------------------------------------ signals
def test_autoscaler_rate_tracking_and_clamping():
    asc = AutoscaleConfig(policy="rate", min_replicas=2, max_replicas=5,
                          interval=1.0, window=10.0, target_qps_per_replica=4.0)
    sc = Autoscaler(asc)
    for i in range(100):
        sc.observe_arrival(i * 0.1)  # 10 qps over [0, 10)
    assert sc.observed_rate(10.0) == pytest.approx(10.0, rel=0.05)
    assert sc.desired(10.0, provisioned=2) == 3  # ceil(10/4)
    for i in range(400):
        sc.observe_arrival(10.0 + i * 0.01)  # 100 qps burst
    assert sc.desired(14.0, provisioned=3) == 5  # clamped at max
    assert sc.desired(60.0, provisioned=5) == 2  # window empty -> min


def test_autoscaler_slo_debt_hysteresis():
    asc = AutoscaleConfig(policy="slo_debt", min_replicas=1, max_replicas=8,
                          window=10.0, slo_ttft=1.0, debt_hi=0.2, debt_lo=0.05)
    sc = Autoscaler(asc)
    for i in range(10):
        sc.observe_ttft(5.0, ttft=2.0 if i < 3 else 0.1)  # 30% violations
    assert sc.slo_debt(5.0) == pytest.approx(0.3)
    assert sc.desired(5.0, provisioned=3) == 4  # above hi -> grow
    sc2 = Autoscaler(asc)
    for _ in range(50):
        sc2.observe_ttft(5.0, ttft=0.1)
    assert sc2.desired(5.0, provisioned=3) == 2  # below lo -> shrink
    sc3 = Autoscaler(asc)
    for i in range(10):
        sc3.observe_ttft(5.0, ttft=2.0 if i < 1 else 0.1)  # 10%: in band
    assert sc3.desired(5.0, provisioned=3) == 3


def test_slo_debt_signal_includes_shed_retry_backoff():
    # the debt signal must see the END-TO-END TTFT (backoff included), not
    # the replica-local wait after re-dispatch — otherwise a fleet in SLO
    # breach purely from shedding backoff would never scale up
    reqs = [SimRequest(i, 0.0, 96, 8) for i in range(12)]
    spec = _spec(["mixed"], shed_depth=4, retry_after=1.0, max_retries=8)
    asc = AutoscaleConfig(policy="slo_debt", min_replicas=1, max_replicas=4,
                          interval=0.5, window=10.0, slo_ttft=0.5,
                          debt_hi=0.05, warmup=0.25)
    cres = simulate_cluster(reqs, CFG, spec, autoscale=asc)
    breached = sum(1 for r in cres.records if r.ttft > 0.5)
    assert breached > 0  # the backoff alone blows the 0.5s deadline
    assert any(ev["action"] == "add" for ev in cres.scale_events)


def test_slo_debt_policy_scales_up_under_violation():
    reqs = _wl(num_requests=80, qps=60.0, arrival="poisson").generate()
    asc = AutoscaleConfig(policy="slo_debt", min_replicas=1, max_replicas=4,
                          interval=0.5, window=3.0, slo_ttft=0.5,
                          debt_hi=0.1, warmup=0.25)
    cres = simulate_cluster(reqs, CFG, _spec(["mixed"]), autoscale=asc)
    assert any(ev["action"] == "add" for ev in cres.scale_events)
    assert sorted(r.rid for r in cres.records) == list(range(80))


def test_autoscale_config_validation():
    for bad in (dict(policy="magic"), dict(min_replicas=0),
                dict(min_replicas=3, max_replicas=2), dict(interval=0.0),
                dict(target_qps_per_replica=0.0), dict(warmup=-1.0),
                dict(debt_lo=0.5, debt_hi=0.1), dict(host_bw=0.0)):
        with pytest.raises(ValueError):
            AutoscaleConfig(**bad).validate()


def test_cluster_spec_shed_validation():
    with pytest.raises(ValueError, match="shed_depth"):
        _spec(["mixed"], shed_depth=0).validate()
    with pytest.raises(ValueError, match="retry_after"):
        _spec(["mixed"], shed_depth=2, retry_after=0.0).validate()


def test_slo_debt_expires_across_idle_gaps_in_cluster():
    # an idle replica's own clock stops; dispatch-time view clamping must
    # let old debt fall out of the rolling window, so a replica that blew
    # its SLO long ago is forgiven once the window has passed
    early = [SimRequest(i, 0.0, 256, 32) for i in range(6)]  # overload r0+r1
    late = [SimRequest(6, 500.0, 64, 2)]  # long idle gap >> debt_window
    spec = _spec(["mixed"] * 2, router="slo_debt",
                 router_slo_ttft=1e-6, debt_window=30.0)
    cres = simulate_cluster(early + late, CFG, spec)
    # the late request routes by depth (both clean), i.e. to replica 0 —
    # not away from whichever replica carried the stale violations
    assert cres.assignments[6][0] == 0
    assert sorted(r.rid for r in cres.records) == list(range(7))


def test_disaggregated_autoscale_rejects_unachievable_bounds():
    reqs = _wl(num_requests=4).generate()
    with pytest.raises(ValueError, match="max_replicas >= 2"):
        simulate_cluster(reqs, CFG, _spec(["prefill", "decode"]),
                         autoscale=AutoscaleConfig(min_replicas=1,
                                                   max_replicas=1))


# -------------------------------------------------- disaggregated autoscale
def test_disaggregated_autoscale_keeps_pool_ratio_and_conserves():
    reqs = _wl(num_requests=60, qps=40.0).generate()
    asc = AutoscaleConfig(policy="rate", min_replicas=2, max_replicas=6,
                          interval=0.5, window=2.0, target_qps_per_replica=8.0,
                          warmup=0.5)
    cres = simulate_cluster(reqs, CFG, _spec(["prefill", "decode"]),
                            autoscale=asc)
    assert sorted(r.rid for r in cres.records) == list(range(60))
    # both pools always have at least one provisioned member
    for pool in ("prefill", "decode"):
        assert any(p == pool for p in cres.replica_pools)
    # prefill stage + (multi-token) decode stage cover every request
    multi = [r for r in reqs if r.output > 1]
    assert cres.xfer_count == len(multi)
