"""Substrate tests: optimizer (incl 8-bit), checkpointing, data pipeline,
gradient compression, HLO collective parsing."""

import os

import numpy as np
import pytest
from hypkit import given, settings, st

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import TrainConfig
from repro.core.hlo import collective_summary, collective_traffic_bytes, parse_collectives
from repro.data.pipeline import MemmapCorpus, Prefetcher, SyntheticLM, pack_documents
from repro.parallel.compression import compress_gradients
from repro.train.optimizer import (
    _dequantize,
    _quantize,
    adamw_init,
    adamw_update,
    lr_schedule,
)

# JAX compile-heavy: excluded from the fast tier (pytest -m "not slow")
pytestmark = pytest.mark.slow


# ------------------------------------------------------------------ optimizer
def _quad_params():
    return {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.ones((4, 8)) * 2.0}


@pytest.mark.parametrize("opt", ["adamw", "adamw8bit"])
def test_adamw_reduces_quadratic(opt):
    tcfg = TrainConfig(optimizer=opt, learning_rate=0.05, warmup_steps=0, steps=100,
                       weight_decay=0.0, grad_clip=0.0)
    params = _quad_params()
    state = adamw_init(params, tcfg)

    def loss(p):
        return sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(p))

    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, grads, state, tcfg)
    assert float(loss(params)) < 0.2 * l0


def test_8bit_state_tracks_fp32():
    t32 = TrainConfig(optimizer="adamw", learning_rate=0.01, warmup_steps=0, grad_clip=0.0, weight_decay=0.0)
    t8 = TrainConfig(optimizer="adamw8bit", learning_rate=0.01, warmup_steps=0, grad_clip=0.0, weight_decay=0.0)
    p32, p8 = _quad_params(), _quad_params()
    s32, s8 = adamw_init(p32, t32), adamw_init(p8, t8)

    def loss(p):
        return sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(p))

    for _ in range(10):
        p32, s32, _ = adamw_update(p32, jax.grad(loss)(p32), s32, t32)
        p8, s8, _ = adamw_update(p8, jax.grad(loss)(p8), s8, t8)
    for a, b in zip(jax.tree.leaves(p32), jax.tree.leaves(p8)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0.05)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=4, max_size=64))
def test_quantize_roundtrip_error_bound(vals):
    x = jnp.array(vals, jnp.float32).reshape(1, -1)
    q = _quantize(x)
    err = np.abs(np.asarray(_dequantize(q)) - np.asarray(x)).max()
    assert err <= float(jnp.abs(x).max()) / 127.0 + 1e-6


def test_lr_schedule_shape():
    tcfg = TrainConfig(steps=100, warmup_steps=10, learning_rate=1e-3)
    assert float(lr_schedule(tcfg, 0)) < 1e-4
    assert abs(float(lr_schedule(tcfg, 10)) - 1e-3) < 1e-9
    assert float(lr_schedule(tcfg, 100)) < float(lr_schedule(tcfg, 50))


# ------------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for s in (1, 2, 3):
        mgr.save(s, jax.tree.map(lambda x: x * s, tree), async_=True)
    mgr.wait()
    assert mgr.steps() == [2, 3]  # keep=2 GC'd step 1
    restored, step = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]) * 3)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.ones((2, 2))}, async_=False)
    with pytest.raises(ValueError):
        mgr.restore({"a": jnp.ones((3, 3))})


def test_checkpoint_atomic_layout(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, {"x": jnp.ones(3)}, async_=False)
    d = os.path.join(str(tmp_path), "step_00000007")
    assert os.path.exists(os.path.join(d, "manifest.json"))
    assert os.path.exists(os.path.join(d, "arrays.npz"))
    assert not any(p.endswith(".tmp") for p in os.listdir(str(tmp_path)))


# ------------------------------------------------------------------------ data
def test_synthetic_deterministic():
    a = SyntheticLM(1000, 32, 8, seed=3).batch(5)
    b = SyntheticLM(1000, 32, 8, seed=3).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(1000, 32, 8, seed=4).batch(5)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_hosts_get_disjoint_shards(tmp_path):
    path = str(tmp_path / "toks.bin")
    np.arange(33 * 64, dtype=np.int32).tofile(path)
    h0 = MemmapCorpus(path, seq_len=32, global_batch=4, num_hosts=2, host_id=0)
    h1 = MemmapCorpus(path, seq_len=32, global_batch=4, num_hosts=2, host_id=1)
    b0, b1 = h0.batch(0), h1.batch(0)
    rows0 = {tuple(r) for r in b0["tokens"]}
    rows1 = {tuple(r) for r in b1["tokens"]}
    assert not rows0 & rows1
    # deterministic across steps and epochs
    np.testing.assert_array_equal(h0.batch(3)["tokens"], h0.batch(3)["tokens"])


def test_pack_documents():
    rows = pack_documents([[1, 2, 3], [4, 5], [6, 7, 8, 9]], seq_len=4, eos=0)
    flat = rows.reshape(-1)
    assert rows.shape[1] == 5
    assert list(flat[:6]) == [1, 2, 3, 0, 4, 5]


def test_prefetcher_order_and_stop():
    out = list(Prefetcher(iter(range(7))))
    assert out == list(range(7))


# ------------------------------------------------------- gradient compression
def test_compress_error_feedback_lossless_in_total():
    g = {"w": jnp.array([[0.5, -1.0], [2.0, 0.25]], jnp.float32)}
    deq, err = compress_gradients(g)
    total = jax.tree.map(lambda a, b: a + b, deq, err)
    np.testing.assert_allclose(np.asarray(total["w"]), np.asarray(g["w"]), atol=1e-6)


def test_compress_error_decays_with_feedback():
    g = {"w": jnp.array([1.0, 1e-3, -2.0], jnp.float32)}
    _, e1 = compress_gradients(g)
    deq2, e2 = compress_gradients(g, e1)
    # two applications reproduce 2x the gradient to within one quantum
    total = np.asarray(jax.tree.leaves(e2)[0]) + 0  # residual stays bounded
    assert np.abs(total).max() <= 2 * float(jnp.abs(g["w"]).max()) / 127 + 1e-6


# ----------------------------------------------------------------- HLO parsing
SAMPLE_HLO = """
ENTRY %main (a: f32[16,64]) -> f32[16,64] {
  %ar1 = f32[16,64]{1,0} all-reduce(%x), replica_groups={}, metadata={op_name="jit(f)/while/body/dot_general"}
  %ag = bf16[4,128]{1,0} all-gather(%y), metadata={op_name="jit(f)/top/reshape"}
  %a2a = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%z, %w), metadata={op_name="jit(f)/while/body/while/body/moe"}
}
"""


def test_parse_collectives_and_depths():
    ops = parse_collectives(SAMPLE_HLO)
    kinds = {o.kind: o for o in ops}
    assert kinds["all-reduce"].loop_depth == 1
    assert kinds["all-reduce"].bytes == 16 * 64 * 4
    assert kinds["all-gather"].loop_depth == 0
    assert kinds["all-gather"].bytes == 4 * 128 * 2
    assert kinds["all-to-all"].loop_depth == 2
    assert kinds["all-to-all"].bytes == 2 * 8 * 8 * 4


def test_collective_traffic_multipliers():
    s = collective_summary(SAMPLE_HLO)
    total = collective_traffic_bytes(s, {1: 10, 2: 100})
    expect = 4 * 128 * 2 + 16 * 64 * 4 * 10 + 2 * 8 * 8 * 4 * 100
    assert total == expect
