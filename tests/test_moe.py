"""MoE invariants: dropless == dense-loop oracle, capacity accounting,
gate normalization, aux losses. Property-based over router inputs."""

import dataclasses

import numpy as np
import pytest
from hypkit import given, settings, st

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.moe import apply_moe, apply_moe_dense_reference, capacity, moe_defs
from repro.models.params import init_params

# JAX compile-heavy: excluded from the fast tier (pytest -m "not slow")
pytestmark = pytest.mark.slow


def _setup(E=8, k=2, cf=8.0, d=32, ff=16, shared=0, dense_res=False):
    base = get_config("deepseek_moe_16b").reduced()
    moe = dataclasses.replace(
        base.moe, num_experts=E, top_k=k, capacity_factor=cf, d_ff=ff,
        num_shared_experts=shared, dense_residual=dense_res,
        dense_d_ff=ff if dense_res else 0, first_k_dense=0,
    )
    cfg = dataclasses.replace(base, moe=moe, d_model=d)
    params = init_params(moe_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


@pytest.mark.parametrize("shared,dense_res", [(0, False), (2, False), (0, True)])
def test_dropless_matches_dense_reference(shared, dense_res):
    cfg, params = _setup(cf=8.0, shared=shared, dense_res=dense_res)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model))
    y, aux = jax.jit(lambda p, x: apply_moe(cfg, p, x))(params, x)
    y_ref = apply_moe_dense_reference(cfg, params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
    assert float(aux["moe_drop_frac"]) == 0.0


def test_capacity_dropping_reported():
    cfg, params = _setup(cf=0.26, E=8, k=2)  # tight capacity forces drops
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y, aux = jax.jit(lambda p, x: apply_moe(cfg, p, x))(params, x)
    assert 0.0 < float(aux["moe_drop_frac"]) < 1.0
    assert np.all(np.isfinite(np.asarray(y)))


def test_capacity_formula():
    cfg, _ = _setup()
    m = cfg.moe
    assert capacity(m, 128) == int(m.capacity_factor * 128 * m.top_k / m.num_experts)
    assert capacity(dataclasses.replace(m, capacity_factor=1e-6), 128) == m.top_k


@settings(max_examples=15, deadline=None)
@given(
    T=st.integers(4, 32),
    E=st.sampled_from([4, 8]),
    k=st.integers(1, 3),
    seed=st.integers(0, 2**16),
)
def test_moe_output_finite_and_bounded(T, E, k, seed):
    cfg, params = _setup(E=E, k=k, cf=2.0)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(seed), (1, T, cfg.d_model))
    y, aux = apply_moe(cfg, params, x)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))
    assert 0.0 <= float(aux["moe_drop_frac"]) <= 1.0
    assert float(aux["moe_lb_loss"]) >= 0.99  # LB loss >= 1 at optimum balance
