"""Differential parity: `engine="vectorized"` against `engine="reference"`.

The vectorized engine is a performance refactor, not a remodel: for every
supported configuration it must reproduce the reference event loop's
output. These tests drive the same workload through both engines and
compare summaries, per-request records, dispatch assignments,
per-replica results, and — when traced — the full event stream.

Everything is compared with `==`, i.e. **bit-for-bit**. No float
tolerance is used anywhere, deliberately: the vectorized fast paths are
restricted to transformations whose float-operation order is identical
to the reference loop's (`np.cumsum` over step durations matches
sequential `now += dt` additions; the scalar small-window path performs
those same additions directly; batched fleet advances split chunks at
exactly the event boundaries the reference merge observes), so even
accumulated clocks reproduce to the last ulp. A tolerance here would
only mask a semantic divergence, which is precisely what this harness
exists to catch.
"""

from dataclasses import asdict

import pytest

from repro.configs import get_config
from repro.core.hardware import H100_SXM
from repro.sim import (
    ENGINES,
    LengthDist,
    SchedConfig,
    ServingCostModel,
    Workload,
    simulate,
)
from repro.cluster import (
    AutoscaleConfig,
    ChaosConfig,
    ClusterSpec,
    PrefixCacheConfig,
    ReplicaSpec,
    simulate_cluster,
    summarize_cluster,
)
from repro.cluster.chaos import AdmissionConfig
from repro.obs import Tracer

CFG = get_config("qwen3_14b")
COST = ServingCostModel(CFG, H100_SXM, ctx_quantum=32)


def _wl(**kw):
    base = dict(
        qps=60.0, num_requests=36, arrival="poisson",
        prompt=LengthDist("lognormal", 96, 0.4, lo=8, hi=512),
        output=LengthDist("lognormal", 24, 0.4, lo=2, hi=128), seed=0,
    )
    base.update(kw)
    return Workload(**base).generate()


def _spec(pools, *, sched=None, router="jsq", **kw):
    sched = sched or SchedConfig(slots=8)
    return ClusterSpec(
        replicas=tuple(ReplicaSpec(pool=p, sched=sched, ctx_quantum=32)
                       for p in pools),
        router=router, **kw)


def _tight(reqs, factor=3.0, **kw):
    cap = factor * max(COST.kv_bytes(r.prompt + r.output) for r in reqs)
    return SchedConfig(slots=8, kv_capacity=cap, **kw)


def _run_both(reqs, spec, *, autoscale=None, traced=False):
    """Run the identical configuration under each engine and return the
    comparable artifacts keyed by engine name."""
    out = {}
    for eng in ENGINES:
        tracer = Tracer("replica") if traced else None
        cres = simulate_cluster(reqs, CFG, spec, autoscale=autoscale,
                                engine=eng, tracer=tracer)
        out[eng] = {
            "summary": summarize_cluster(cres, slo_ttft=1.0, slo_tpot=0.1),
            "assignments": cres.assignments,
            "records": [asdict(r) for r in cres.records],
            "replicas": [(r.iterations, r.decode_steps, r.peak_kv, r.busy_s,
                          r.preemptions, r.peak_kv_waste, r.admit_order)
                         for r in cres.replica_results],
            "trace": tracer.events if traced else None,
        }
    return out


def _assert_identical(out):
    vec, ref = out["vectorized"], out["reference"]
    for part in ("summary", "assignments", "records", "replicas", "trace"):
        assert vec[part] == ref[part], f"engines diverge in {part}"


# ------------------------------------------------------------ the full matrix
# colocated/disagg x static/autoscaled x chaos on/off x prefix-cache on/off
# x traced/untraced: every cell must be bit-identical across engines.
_POOLS = {"colocated": ["mixed"] * 3,
          "disagg": ["prefill", "prefill", "decode"]}


@pytest.mark.parametrize("traced", [False, True], ids=["untraced", "traced"])
@pytest.mark.parametrize("pcache", [False, True], ids=["nocache", "pcache"])
@pytest.mark.parametrize("chaos", [False, True], ids=["calm", "chaos"])
@pytest.mark.parametrize("scaled", [False, True], ids=["static", "autoscaled"])
@pytest.mark.parametrize("mode", ["colocated", "disagg"])
def test_engine_parity_matrix(mode, scaled, chaos, pcache, traced):
    kw = {}
    if chaos:
        kw["chaos"] = ChaosConfig(seed=5, horizon=40.0, crash_rate=0.06,
                                  straggler_rate=0.1, link_rate=0.05)
    if pcache:
        # shared-prefix sessions + affinity routing make the cache do work
        kw["router"] = "affinity"
        kw["prefix_cache"] = PrefixCacheConfig(budget_frac=0.05)
        reqs = _wl(num_sessions=6)
    else:
        reqs = _wl()
    autoscale = None
    if scaled:
        autoscale = AutoscaleConfig(policy="rate", min_replicas=2,
                                    max_replicas=6, interval=2.0)
    out = _run_both(reqs, _spec(_POOLS[mode], **kw),
                    autoscale=autoscale, traced=traced)
    _assert_identical(out)


# --------------------------------------------------- policy/router edge cover
# Configurations that stress specific fast paths in the vectorized engine:
# each router's tie-breaking, KV-pressure preemption, shed+retry, the
# admission front door, EDF ordering under chunked prefill.
def _case(name, reqs, spec, autoscale=None):
    return pytest.param(reqs, spec, autoscale, id=name)


def _edge_cases():
    reqs = _wl()
    hot = _wl(qps=300.0, num_requests=48)
    sess = _wl(num_sessions=6)
    return [
        _case("router-rr", reqs, _spec(["mixed"] * 3, router="round_robin")),
        _case("router-leastkv-tightkv", reqs,
              _spec(["mixed"] * 3, sched=_tight(reqs), router="least_kv")),
        _case("router-affinity", sess, _spec(["mixed"] * 3, router="affinity")),
        _case("router-slodebt", reqs, _spec(["mixed"] * 3, router="slo_debt")),
        _case("edf-chunked", reqs,
              _spec(["mixed"] * 3, sched=SchedConfig(
                  slots=8, policy="chunked", token_budget=128,
                  admission="edf"))),
        _case("disagg-tightkv", reqs,
              _spec(["prefill", "decode", "decode"], sched=_tight(reqs, 2.5))),
        _case("shed-retry", hot,
              _spec(["mixed"] * 2, sched=_tight(reqs), shed_depth=6)),
        _case("admission-door", hot,
              _spec(["mixed"] * 2, admission=AdmissionConfig(
                  rate=30.0, burst=10, queue_depth=8))),
        _case("pool-autoscale", _wl(qps=40.0, num_requests=48),
              _spec(["prefill", "decode"]),
              {"prefill": AutoscaleConfig(policy="rate", min_replicas=1,
                                          max_replicas=4, interval=2.0),
               "decode": AutoscaleConfig(policy="kv_tpot", min_replicas=1,
                                         max_replicas=4, interval=3.0)}),
    ]


@pytest.mark.parametrize("reqs,spec,autoscale", _edge_cases())
def test_engine_parity_edges(reqs, spec, autoscale):
    _assert_identical(_run_both(reqs, spec, autoscale=autoscale))


# ----------------------------------------------------- single-replica engine
@pytest.mark.parametrize("policy", ["continuous", "chunked"])
def test_simulate_engine_parity(policy):
    reqs = _wl(num_requests=48, qps=100.0)
    sc = SchedConfig(policy=policy, slots=8, token_budget=192)
    vec = simulate(reqs, COST, sc, engine="vectorized")
    ref = simulate(reqs, COST, sc, engine="reference")
    assert [asdict(r) for r in vec.records] == [asdict(r) for r in ref.records]
    assert (vec.iterations, vec.decode_steps, vec.peak_kv, vec.busy_s,
            vec.preemptions, vec.admit_order) == \
        (ref.iterations, ref.decode_steps, ref.peak_kv, ref.busy_s,
         ref.preemptions, ref.admit_order)


def test_simulate_engine_parity_straggler_window():
    reqs = _wl(num_requests=32, qps=100.0)
    vec = simulate(reqs, COST, SchedConfig(slots=8), engine="vectorized",
                   slowdown=(3.0, 0.1, 0.5))
    ref = simulate(reqs, COST, SchedConfig(slots=8), engine="reference",
                   slowdown=(3.0, 0.1, 0.5))
    assert [asdict(r) for r in vec.records] == [asdict(r) for r in ref.records]


def test_static_policy_falls_back_to_reference():
    # static batching is a cold path: both engine names must agree because
    # the factory maps them to the same exact implementation
    reqs = _wl(num_requests=24)
    sc = SchedConfig(policy="static", slots=8)
    vec = simulate(reqs, COST, sc, engine="vectorized")
    ref = simulate(reqs, COST, sc, engine="reference")
    assert [asdict(r) for r in vec.records] == [asdict(r) for r in ref.records]


def test_unknown_engine_rejected():
    reqs = _wl(num_requests=4)
    with pytest.raises(ValueError, match="unknown engine"):
        simulate(reqs, COST, SchedConfig(), engine="warp")
    with pytest.raises(ValueError, match="unknown engine"):
        simulate_cluster(reqs, CFG, _spec(["mixed"]), engine="warp")
