"""Fixture: P-series purity violations (P201/P202/P204).

Never imported — the P202 dataclass would raise at class-definition time,
which is exactly the hazard the rule documents. Linted under a synthetic
`src/repro/cluster/...` path by tests/test_lint.py.
"""

from dataclasses import dataclass


def accumulate(x, acc=[]):  # P201: mutable default shared across calls
    """Appends to a default list that outlives the call."""
    acc.append(x)
    return acc


@dataclass
class SweepConfig:
    name: str = "sweep"
    points: dict = {}  # P202: use field(default_factory=dict)


def retune(cfg, gain):
    """Writes a new gain into the caller's config object."""
    cfg.gain = gain  # P204: mutates a shared config in place
    return cfg
