"""Fixture: a fully compliant module -> ZERO findings."""

import numpy as np


def sample(seed, count):
    """Draw `count` uniform samples in [0, 1) (dimensionless fractions)."""
    rng = np.random.default_rng(seed)
    return [float(x) for x in rng.random(count)]
