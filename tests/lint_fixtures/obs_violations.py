"""Fixture: P203 observational-write violation.

Linted under a synthetic `src/repro/obs/...` path by tests/test_lint.py.
Writes to `st` are exempt (annotated with a type this module defines);
the write to the unannotated `engine` parameter is the violation.
"""


class _LocalState:
    count: int = 0


def observe(engine, st: _LocalState):
    st.count = 1  # exempt: module-own state object
    engine.traced = True  # P203: writes into the observed engine
