"""Fixture: every pragma form suppressing a real violation -> ZERO findings.

Exercises same-line `disable=`, `disable-next=` (including its
skip-over-comments behavior), and `disable-file=`.
"""
# lint: disable-file=D104

import numpy as np


def seeded_elsewhere():
    """Each violation below is individually suppressed."""
    rng = np.random.default_rng()  # lint: disable=D101 -- fixture: same-line
    # lint: disable-next=U303 -- fixture: next-line form; the comment
    # between pragma and statement is skipped on purpose
    exact = rng.random() == 0.5
    return {id(rng): exact}  # D104 suppressed file-wide
