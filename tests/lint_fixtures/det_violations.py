"""Fixture: D-series determinism violations, one marker comment per code.

Never imported — tests/test_lint.py lints this SOURCE under a synthetic
`src/repro/sim/...` path so the subpackage-scoped rules apply. Expected
findings live in tests/lint_fixtures/expected.json.
"""

import heapq
import random
import time
import uuid

import numpy as np


def unseeded():
    """Draws entropy three forbidden ways."""
    rng = np.random.default_rng()  # D101: no seed -> OS entropy
    token = uuid.uuid4()  # D101: ambient entropy
    jitter = random.random()  # D101: shared global RNG stream
    return rng, token, jitter


def wall_clock():
    """Reads the host clock from inside the simulator."""
    return time.perf_counter()  # D102: wall clock in a deterministic layer


def unordered(pending, table):
    """Feeds set iteration order into order-sensitive constructs."""
    for item in set(pending):  # D103: iterating a set
        del item
    order = list({x for x in table})  # D103: freezes set-comp order
    best = max(table.values(), key=lambda v: v[0])  # D103: keyed, ties unstable
    heap = []
    for item in set(pending) | {0}:  # D103: set-union iteration
        heapq.heappush(heap, item)  # D103: heap order inherits set order
    return order, best, heap


def identity_keys(requests):
    """Keys a mapping on object addresses."""
    return {id(r): r for r in requests}  # D104: address-dependent key
