"""Fixture: U-series surface violations (U301/U302/U303).

Linted under a synthetic `src/repro/sim/...` path by tests/test_lint.py.
"""


def price(duration_s, rate):  # U301: public, no docstring at all
    return duration_s * rate


def ratio(num_tokens, window_s):
    """Share of the window spent decoding."""  # U301: no unit vocabulary
    return num_tokens / window_s


def risky():
    """Guarded parse that eats every failure."""
    try:
        return 1
    except:  # noqa: E722  # U302: bare except
        return 0


def is_idle(util):
    """True when utilization (fraction of capacity) is exactly zero."""
    return util == 0.0  # U303: float-literal equality
