"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm, rmsnorm_residual
from repro.kernels.rmsnorm.ref import rmsnorm_ref, rmsnorm_residual_ref

# JAX compile-heavy: excluded from the fast tier (pytest -m "not slow")
pytestmark = pytest.mark.slow

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 3e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,S,dh,win",
    [
        (2, 4, 4, 256, 64, None),  # MHA
        (1, 8, 2, 256, 128, None),  # GQA 4:1
        (2, 4, 2, 384, 64, 128),  # GQA + sliding window
        (1, 2, 1, 300, 32, None),  # non-multiple seq (padding path)
    ],
)
def test_flash_attention_vs_oracle(dtype, B, Hq, Hkv, S, dh, win):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, dh), jnp.float32).astype(dtype)
    out = flash_attention_bhsd(q, k, v, window=win)
    ref = attention_ref(q, k, v, window=win)
    err = np.abs(np.asarray(out, np.float32) - np.asarray(ref, np.float32)).max()
    assert err < TOL[dtype], err


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hkv,G,T,dh,nv",
    [
        (2, 4, 2, 512, 64, 300),
        (1, 2, 6, 1024, 128, 1024),
        (2, 8, 1, 512, 64, 1),  # single valid slot
        (1, 2, 4, 600, 32, 77),  # non-multiple cache (padding path)
    ],
)
def test_decode_attention_vs_oracle(dtype, B, Hkv, G, T, dh, nv):
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hkv, G, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, T, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, T, dh), jnp.float32).astype(dtype)
    out = decode_attention(q, k, v, jnp.int32(nv))
    ref = decode_attention_ref(q, k, v, jnp.int32(nv))
    err = np.abs(np.asarray(out, np.float32) - np.asarray(ref, np.float32)).max()
    assert err < TOL[dtype], err


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,D", [(256, 512), (300, 256), (64, 1024)])
def test_rmsnorm_vs_oracle(dtype, T, D):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (T, D), jnp.float32).astype(dtype)
    res = jax.random.normal(jax.random.fold_in(key, 1), (T, D), jnp.float32).astype(dtype)
    sc = jax.random.normal(jax.random.fold_in(key, 2), (D,), jnp.float32)
    err = np.abs(
        np.asarray(rmsnorm(x, sc), np.float32) - np.asarray(rmsnorm_ref(x, sc), np.float32)
    ).max()
    assert err < TOL[dtype]
    y1, r1 = rmsnorm_residual(x, res, sc)
    y2, r2 = rmsnorm_residual_ref(x, res, sc)
    for a, b in ((y1, y2), (r1, r2)):
        assert np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).max() < TOL[dtype]


def test_flash_attention_matches_model_layout_wrapper():
    from repro.kernels.flash_attention.ops import flash_attention

    key = jax.random.PRNGKey(3)
    B, S, Hkv, G, dh = 1, 128, 2, 2, 32
    q = jax.random.normal(key, (B, S, Hkv, G, dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, dh), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, dh), jnp.float32)
    out = flash_attention(q, k, v)
    from repro.models.attention import _dense_attention

    pos = jnp.arange(S)
    ref = _dense_attention(q, k, v, pos, pos, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
