"""repro.cluster.chaos: seeded fault injection (crashes, stragglers, link
degradation, correlated node failures), the admission front door (GCRA
token bucket + circuit breaker), shed-retry backoff/jitter, the
empty-pool dispatch guard, the horizon conservation sweep, chaos-off
bit-parity with the fault-free engine, and the planner's N-loss mode."""

import pytest

from repro.configs import get_config
from repro.core.hardware import H100_SXM
from repro.obs import make_tracer, validate_trace
from repro.sim import (
    LengthDist,
    SchedConfig,
    ServingCostModel,
    Workload,
    simulate,
)
from repro.cluster import (
    AdmissionConfig,
    AutoscaleConfig,
    Autoscaler,
    ChaosConfig,
    ChaosEvent,
    ClusterSpec,
    PrefixCacheConfig,
    ReplicaSpec,
    plan_capacity,
    simulate_cluster,
    summarize_cluster,
)
from repro.cluster.chaos import CircuitBreaker, TokenBucket, pick_victims

CFG = get_config("qwen3_14b")


def _wl(**kw):
    base = dict(
        qps=50.0, num_requests=40, arrival="poisson",
        prompt=LengthDist("lognormal", 96, 0.4, lo=8, hi=512),
        output=LengthDist("lognormal", 24, 0.4, lo=2, hi=128), seed=0,
    )
    base.update(kw)
    return Workload(**base)


def _spec(pools, *, sched=None, **kw):
    sched = sched or SchedConfig(slots=8)
    return ClusterSpec(
        replicas=tuple(ReplicaSpec(hw="h100", pool=p, sched=sched,
                                   ctx_quantum=32)
                       for p in pools),
        **kw)


def _records_key(cres):
    return [(r.rid, r.admitted, r.first_token, r.finish)
            for r in sorted(cres.records, key=lambda r: r.rid)]


def _conserved(cres, n):
    rids = sorted([r.rid for r in cres.records] + [r.rid for r in cres.shed])
    assert rids == list(range(n)), "exactly-once conservation violated"


# ------------------------------------------------------------- the schedule
def test_chaos_schedule_is_deterministic():
    cfg = ChaosConfig(seed=3, horizon=60.0, crash_rate=0.1,
                      straggler_rate=0.2, link_rate=0.05,
                      node_failure_rate=0.02)
    assert cfg.schedule() == cfg.schedule()
    assert cfg.schedule()  # nonzero rates over 60s: expect events
    # a different seed produces a different timeline
    other = ChaosConfig(seed=4, horizon=60.0, crash_rate=0.1,
                        straggler_rate=0.2, link_rate=0.05,
                        node_failure_rate=0.02)
    assert cfg.schedule() != other.schedule()


def test_chaos_kind_streams_are_independent():
    # adding stragglers must not perturb the crash timeline (per-kind
    # SeedSequence spawns — the Workload.substreams idiom)
    base = ChaosConfig(seed=1, horizon=120.0, crash_rate=0.08)
    more = ChaosConfig(seed=1, horizon=120.0, crash_rate=0.08,
                       straggler_rate=0.5, link_rate=0.3)
    crashes = [e for e in base.schedule() if e.kind == "crash"]
    crashes2 = [e for e in more.schedule() if e.kind == "crash"]
    assert crashes == crashes2


def test_chaos_script_events_merge_in_time_order():
    cfg = ChaosConfig(script=(ChaosEvent(5.0, "crash", picks=(0.5,)),
                              ChaosEvent(1.0, "link", factor=2.0,
                                         duration=3.0)))
    assert cfg.enabled
    sched = cfg.schedule()
    assert [e.t for e in sched] == [1.0, 5.0]


def test_chaos_event_validation():
    with pytest.raises(ValueError):
        ChaosEvent(1.0, "meteor").validate()
    with pytest.raises(ValueError):
        ChaosEvent(1.0, "straggler", factor=0.5).validate()
    with pytest.raises(ValueError):
        ChaosConfig(crash_rate=-1.0).validate()
    with pytest.raises(ValueError):
        ChaosConfig(straggler_slowdown=(0.5, 2.0),
                    straggler_rate=0.1).validate()


def test_pick_victims_without_replacement():
    assert pick_victims((0.0, 0.0), [4, 7, 9], 2) == [4, 7]
    assert pick_victims((0.99, 0.99), [4, 7, 9], 2) == [9, 7]
    assert pick_victims((0.5,), [], 1) == []
    assert pick_victims((0.5, 0.5, 0.5), [1], 3) == [1]


# ------------------------------------------------------- chaos-off bit parity
@pytest.mark.parametrize("pools", [["mixed"] * 2,
                                   ["prefill", "decode", "decode"]])
@pytest.mark.parametrize("autoscaled", [False, True])
def test_chaos_off_is_bit_identical(pools, autoscaled):
    # a zero-rate ChaosConfig draws no RNG and adds nothing to the event
    # merge: the run is bit-identical to chaos=None, static or autoscaled
    reqs = _wl().generate()
    asc = (AutoscaleConfig(min_replicas=1, max_replicas=4, interval=0.5,
                           warmup=0.5) if autoscaled else None)
    plain = simulate_cluster(reqs, CFG, _spec(pools), autoscale=asc)
    chaosless = simulate_cluster(reqs, CFG, _spec(pools, chaos=ChaosConfig()),
                                 autoscale=asc)
    assert _records_key(plain) == _records_key(chaosless)
    assert plain.assignments == chaosless.assignments
    assert plain.scale_events == chaosless.scale_events
    assert [r.iterations for r in plain.replica_results] == \
        [r.iterations for r in chaosless.replica_results]
    assert chaosless.chaos_stats is None  # zero rates: chaos is OFF


def test_chaos_run_is_deterministic():
    # same seed => identical schedule and bit-identical ClusterResult
    reqs = _wl(num_requests=60, qps=60.0).generate()
    spec = _spec(["mixed"] * 3,
                 chaos=ChaosConfig(seed=7, horizon=10.0, crash_rate=0.08,
                                   straggler_rate=0.15, link_rate=0.1))
    a = simulate_cluster(reqs, CFG, spec)
    b = simulate_cluster(reqs, CFG, spec)
    assert _records_key(a) == _records_key(b)
    assert a.assignments == b.assignments
    assert a.chaos_stats == b.chaos_stats
    assert a.scale_events == b.scale_events


# ----------------------------------------------------------------- crashes
def test_crash_mid_run_displaces_and_re_prefills():
    reqs = _wl().generate()
    spec = _spec(["mixed"] * 2,
                 chaos=ChaosConfig(script=(
                     ChaosEvent(0.2, "crash", picks=(0.1,)),)))
    cres = simulate_cluster(reqs, CFG, spec)
    _conserved(cres, len(reqs))
    ch = cres.chaos_stats
    assert ch["crashes"] == 1
    assert ch["displaced"] > 0
    assert ch["re_prefill_tokens"] > 0  # no prefix cache: full re-prefill
    assert ch["restored_tokens"] == 0
    assert ch["recovery_s_max"] > 0.0
    # the crashed replica stopped billing at the crash instant
    crash_ev = [e for e in cres.scale_events if e["action"] == "crash"]
    assert len(crash_ev) == 1
    i = crash_ev[0]["replica"]
    assert cres.replica_spans[i][1] == pytest.approx(0.2)


def test_crash_mid_decode_disaggregated_conserves_and_reprefills():
    # a decode-pool crash loses KV that already crossed the interconnect:
    # the displaced requests re-enter at the PREFILL pool and re-prefill
    reqs = _wl().generate()
    spec = _spec(["prefill", "decode", "decode"],
                 chaos=ChaosConfig(script=(
                     ChaosEvent(0.3, "crash", picks=(0.99,)),)))
    cres = simulate_cluster(reqs, CFG, spec)
    _conserved(cres, len(reqs))
    ch = cres.chaos_stats
    assert ch["crashes"] == 1
    assert ch["displaced"] > 0 and ch["re_prefill_tokens"] > 0
    for r in cres.records:
        assert r.finish >= r.first_token >= r.arrival


def test_node_failure_kills_a_group():
    reqs = _wl().generate()
    spec = _spec(["mixed"] * 4,
                 chaos=ChaosConfig(script=(
                     ChaosEvent(0.2, "node_failure", count=2,
                                picks=(0.9, 0.9)),)))
    cres = simulate_cluster(reqs, CFG, spec)
    _conserved(cres, len(reqs))
    assert cres.chaos_stats["crashes"] == 2
    assert sum(1 for e in cres.scale_events if e["action"] == "crash") == 2


def test_crash_traced_run_has_valid_lifecycle():
    # crash instants, displacement, and re-dispatch must keep every rid's
    # trace well-formed: exactly one terminal, ordered phase spans
    reqs = _wl().generate()
    spec = _spec(["mixed"] * 2,
                 chaos=ChaosConfig(script=(
                     ChaosEvent(0.2, "crash", picks=(0.1,)),)))
    tracer = make_tracer("request")
    cres = simulate_cluster(reqs, CFG, spec, tracer=tracer)
    _conserved(cres, len(reqs))
    assert validate_trace(tracer.events) == []
    names = {e.get("name") for e in tracer.events}
    assert "replica.crash" in names


def test_prefix_cache_restore_vs_re_prefill():
    # two replicas share a hot prefix group; one crashes. With the
    # modeled prefix cache, displaced requests restore the prefix from
    # the SURVIVOR's cache; without it they re-prefill from scratch.
    wl = _wl(num_requests=60, qps=60.0, num_prefix_groups=1,
             prefix=LengthDist("fixed", 256))
    reqs = wl.generate()
    script = (ChaosEvent(0.5, "crash", picks=(0.1,)),)
    with_cache = simulate_cluster(
        reqs, CFG, _spec(["mixed"] * 2,
                         prefix_cache=PrefixCacheConfig(budget_frac=0.2),
                         chaos=ChaosConfig(script=script)))
    without = simulate_cluster(
        reqs, CFG, _spec(["mixed"] * 2, chaos=ChaosConfig(script=script)))
    _conserved(with_cache, len(reqs))
    _conserved(without, len(reqs))
    assert with_cache.chaos_stats["restored_tokens"] > 0
    assert without.chaos_stats["restored_tokens"] == 0
    assert without.chaos_stats["re_prefill_tokens"] > 0
    # restored tokens are exactly the prompt work the survivor skipped
    wc = with_cache.chaos_stats
    assert wc["re_prefill_tokens"] + wc["restored_tokens"] >= wc["displaced"]


# ------------------------------------------------------ stragglers and links
def test_straggler_window_stretches_iterations():
    cost = ServingCostModel(CFG, H100_SXM, ctx_quantum=32)
    # saturated arrivals: the engine never idles, so stretching every
    # iteration by 3x stretches the makespan by 3x (idle gaps would not
    # be stretched — only priced work is)
    reqs = _wl(num_requests=30, qps=1e5).generate()
    sc = SchedConfig(slots=8)
    base = simulate(reqs, cost, sc)
    slow = simulate(reqs, cost, sc, slowdown=(3.0, 0.0, 1e9))
    end_base = max(r.finish for r in base.records)
    end_slow = max(r.finish for r in slow.records)
    assert end_slow == pytest.approx(3.0 * end_base, rel=1e-3)
    # a window that opens after the run ends changes nothing
    idle = simulate(reqs, cost, sc, slowdown=(3.0, end_base + 1.0, 10.0))
    assert [r.finish for r in idle.records] == [r.finish for r in base.records]


def test_straggler_set_slowdown_validates_and_merges():
    cost = ServingCostModel(CFG, H100_SXM, ctx_quantum=32)
    from repro.sim import ReplicaSim
    sim = ReplicaSim(cost, SchedConfig(slots=8))
    with pytest.raises(ValueError):
        sim.set_slowdown(0.5, 10.0)
    sim.set_slowdown(2.0, 10.0, start=0.0)
    sim.set_slowdown(4.0, 6.0, start=2.0)  # overlap: merged, worst factor
    assert sim._slow_factor == 4.0
    assert (sim._slow_from, sim._slow_until) == (0.0, 10.0)


def test_cluster_straggler_event_slows_one_replica():
    reqs = _wl(num_requests=60, qps=60.0).generate()
    base = simulate_cluster(reqs, CFG, _spec(["mixed"] * 2))
    slow = simulate_cluster(
        reqs, CFG, _spec(["mixed"] * 2, chaos=ChaosConfig(script=(
            ChaosEvent(0.0, "straggler", factor=8.0, duration=5.0,
                       picks=(0.0,)),))))
    _conserved(slow, len(reqs))
    assert slow.chaos_stats["stragglers"] == 1
    assert (max(r.finish for r in slow.records)
            > max(r.finish for r in base.records))


def test_link_degradation_stretches_handoffs():
    reqs = _wl().generate()
    base = simulate_cluster(reqs, CFG, _spec(["prefill", "decode"]))
    slow = simulate_cluster(
        reqs, CFG, _spec(["prefill", "decode"], chaos=ChaosConfig(script=(
            ChaosEvent(0.0, "link", factor=5.0, duration=1e9),))))
    _conserved(slow, len(reqs))
    assert slow.chaos_stats["link_degrades"] == 1
    assert slow.xfer_count == base.xfer_count
    assert slow.xfer_seconds == pytest.approx(5.0 * base.xfer_seconds,
                                              rel=1e-9)


# ------------------------------------------------- empty pools and the sweep
def test_sole_replica_crash_static_fleet_loses_remaining_arrivals():
    # the empty-pool guard: a dead un-recoverable pool sheds instead of
    # crashing on min() over an empty view list
    reqs = _wl().generate()
    cres = simulate_cluster(
        reqs, CFG, _spec(["mixed"], chaos=ChaosConfig(script=(
            ChaosEvent(0.1, "crash", picks=(0.0,)),))))
    _conserved(cres, len(reqs))
    assert cres.requests_lost > 0
    assert len(cres.shed) == cres.requests_lost
    assert len(cres.records) + len(cres.shed) == len(reqs)


def test_sole_replica_crash_autoscaled_fleet_recovers():
    # with a control loop the pool is recoverable: arrivals stall, a
    # replacement spawns, and every request still completes exactly once
    reqs = _wl().generate()
    asc = AutoscaleConfig(min_replicas=1, max_replicas=2, interval=0.5,
                          warmup=0.5)
    cres = simulate_cluster(
        reqs, CFG, _spec(["mixed"], chaos=ChaosConfig(script=(
            ChaosEvent(0.1, "crash", picks=(0.0,)),))),
        autoscale=asc)
    _conserved(cres, len(reqs))
    assert not cres.shed  # all recovered
    assert any(e["action"] == "add" for e in cres.scale_events)
    assert cres.chaos_stats["stalls"] > 0


def test_decode_pool_crash_with_pool_floor():
    # killing decode replicas mid-stream: parked handoffs re-route once
    # capacity exists, or are lost when the pool can never recover
    reqs = _wl().generate()
    cres = simulate_cluster(
        reqs, CFG, _spec(["prefill", "decode"], chaos=ChaosConfig(script=(
            ChaosEvent(0.3, "crash", picks=(0.99,)),))))
    _conserved(cres, len(reqs))
    assert cres.requests_lost > 0  # the only decode replica died


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("pools", [["mixed"] * 2,
                                   ["prefill", "decode", "decode"]])
@pytest.mark.parametrize("chaos_on", [False, True])
def test_conservation_property_seeds_modes_chaos(seed, pools, chaos_on):
    # the exactly-once invariant holds across seeds, organizations, shed/
    # retry pressure, and fault injection (the retry-heap horizon sweep)
    n = 50
    reqs = _wl(seed=seed, num_requests=n, qps=80.0).generate()
    chaos = (ChaosConfig(seed=seed, horizon=8.0, crash_rate=0.15,
                         straggler_rate=0.2) if chaos_on else None)
    spec = _spec(pools, shed_depth=8, retry_after=0.2, max_retries=2,
                 chaos=chaos)
    cres = simulate_cluster(reqs, CFG, spec)
    _conserved(cres, n)
    if chaos_on and cres.chaos_stats["crashes"]:
        assert cres.requests_lost <= len(cres.shed)


# ------------------------------------------------------- shed-retry backoff
def _herd_spec(**kw):
    return _spec(["mixed"], sched=SchedConfig(slots=4),
                 shed_depth=4, retry_after=0.25, max_retries=4, **kw)


def test_thundering_herd_regression():
    # a burst that sheds together must not retry together: with the
    # legacy fixed delay every member of a shed burst waits the SAME
    # 0.25 s, so the burst re-arrives intact and re-sheds in lockstep;
    # exponential backoff + jitter disperses it, and fewer requests are
    # dropped on the same overload trace
    reqs = _wl(arrival="bursty", qps=120.0, num_requests=80).generate()
    tr_l = make_tracer("summary")
    legacy = simulate_cluster(
        reqs, CFG, _herd_spec(retry_backoff=1.0, retry_jitter=0.0),
        tracer=tr_l)
    tr_j = make_tracer("summary")
    jittered = simulate_cluster(reqs, CFG, _herd_spec(), tracer=tr_j)
    _conserved(legacy, len(reqs))
    _conserved(jittered, len(reqs))

    def delays(tr):
        return [e["attrs"]["retry_at"] - e["t"] for e in tr.events
                if e.get("name") == "request.retry"]

    d_l, d_j = delays(tr_l), delays(tr_j)
    assert d_l and d_j
    # legacy: one fixed delay for every retry -> the burst stays in phase
    assert {round(d, 9) for d in d_l} == {0.25}
    # jittered: every retry waits a distinct, growing delay
    assert len({round(d, 9) for d in d_j}) == len(d_j)
    assert max(d_j) > 0.25
    # de-synchronized retries drop fewer requests on the same trace:
    # more of the offered load completes, and with a TTFT SLO generous
    # enough to admit backed-off retries, more completes WITHIN SLO
    assert len(jittered.shed) < len(legacy.shed)
    assert len(jittered.records) > len(legacy.records)
    s_l = summarize_cluster(legacy, slo_ttft=10.0, slo_tpot=0.05)
    s_j = summarize_cluster(jittered, slo_ttft=10.0, slo_tpot=0.05)
    assert (s_j["goodput_frac"] * len(jittered.records)
            > s_l["goodput_frac"] * len(legacy.records))


def test_legacy_backoff_settings_reproduce_fixed_delay():
    # retry_backoff=1, retry_jitter=0 is the exact legacy schedule: every
    # retry at t + retry_after, zero RNG draws
    reqs = _wl(qps=150.0, num_requests=60).generate()
    spec = _herd_spec(retry_backoff=1.0, retry_jitter=0.0)
    tr = make_tracer("summary")
    simulate_cluster(reqs, CFG, spec, tracer=tr)
    retries = [e for e in tr.events if e.get("name") == "request.retry"]
    assert retries  # the trace did overload
    for e in retries:
        assert e["attrs"]["retry_at"] == pytest.approx(e["t"] + 0.25)


def test_backoff_grows_exponentially_and_jitters_upward():
    reqs = _wl(qps=150.0, num_requests=60).generate()
    tr = make_tracer("summary")
    simulate_cluster(reqs, CFG, _herd_spec(retry_jitter=0.3), tracer=tr)
    for e in tr.events:
        if e.get("name") == "request.retry":
            base = 0.25 * 2.0 ** (e["attrs"]["attempt"] - 1)
            delay = e["attrs"]["retry_at"] - e["t"]
            assert base <= delay <= base * 1.3 + 1e-12


# ---------------------------------------------------------- admission door
def test_token_bucket_gcra_exact():
    tb = TokenBucket(AdmissionConfig(rate=1.0, burst=2, queue_depth=1))
    assert tb.offer(0, 0.0) == 0.0  # burst slot
    assert tb.offer(1, 0.0) == 0.0  # burst slot
    assert tb.offer(2, 0.0) == 1.0  # door-queued to conformance time
    assert tb.offer(3, 0.0) is None  # queue full: shed
    st = tb.stats()
    assert (st["door_admitted"], st["door_delayed"], st["door_shed"]) \
        == (3, 1, 1)
    # after draining, capacity returns
    assert tb.offer(4, 10.0) == 10.0


def test_token_bucket_door_in_cluster():
    reqs = _wl(qps=100.0, num_requests=60).generate()
    cres = simulate_cluster(
        reqs, CFG, _spec(["mixed"], admission=AdmissionConfig(
            policy="token_bucket", rate=20.0, burst=4, queue_depth=2)))
    _conserved(cres, len(reqs))
    ad = cres.admission_stats
    assert ad["door_shed"] > 0 and ad["door_admitted"] > 0
    assert ad["door_admitted"] + ad["door_shed"] == len(reqs)
    assert len(cres.shed) == ad["door_shed"]  # door sheds, backend keeps up
    assert cres.requests_lost == 0  # overload is not an availability loss


def test_circuit_breaker_state_machine():
    cfg = AdmissionConfig(policy="breaker", window=10.0, fail_thresh=0.5,
                          min_samples=4, cooloff=2.0, probes=2)
    br = CircuitBreaker(cfg)
    # feed terminal failures until past min_samples
    for i, t in enumerate((0.1, 0.2, 0.3, 0.4)):
        assert br.offer(i, t) == t
        br.observe(i, t, ok=False)
    assert br.offer(10, 0.5) is None  # tripped OPEN
    assert br.state == "open"
    assert br.offer(11, 1.0) is None  # still cooling off
    assert br.offer(12, 2.6) == 2.6  # HALF_OPEN: probe 1
    assert br.offer(13, 2.7) == 2.7  # probe 2
    assert br.offer(14, 2.8) is None  # probes outstanding: held
    br.observe(12, 3.0, ok=True)
    br.observe(13, 3.1, ok=True)
    assert br.state == "closed"  # all probes succeeded
    assert br.offer(15, 3.2) == 3.2
    st = br.stats()
    assert st["breaker_opens"] == 1 and st["breaker_state"] == "closed"


def test_circuit_breaker_probe_failure_reopens():
    cfg = AdmissionConfig(policy="breaker", window=10.0, fail_thresh=0.5,
                          min_samples=2, cooloff=1.0, probes=1)
    br = CircuitBreaker(cfg)
    for i, t in enumerate((0.1, 0.2)):
        br.offer(i, t)
        br.observe(i, t, ok=False)
    assert br.offer(5, 0.3) is None and br.state == "open"
    assert br.offer(6, 1.5) == 1.5  # probe
    br.observe(6, 1.6, ok=False)  # probe fails
    assert br.state == "open"
    assert br.stats()["breaker_opens"] == 2


def test_breaker_door_in_cluster_opens_under_collapse():
    # one slot-starved replica + hard shedding: failures trip the door,
    # which then sheds at arrival instead of letting retries pile up
    reqs = _wl(qps=150.0, num_requests=80).generate()
    cres = simulate_cluster(
        reqs, CFG, _spec(["mixed"], sched=SchedConfig(slots=2),
                         shed_depth=2, retry_after=0.2, max_retries=1,
                         admission=AdmissionConfig(
                             policy="breaker", window=5.0, fail_thresh=0.5,
                             min_samples=5, cooloff=1.0, probes=2)))
    _conserved(cres, len(reqs))
    assert cres.admission_stats["breaker_opens"] >= 1
    assert cres.admission_stats["door_shed"] > 0


def test_admission_config_validation():
    with pytest.raises(ValueError):
        AdmissionConfig(policy="bouncer").validate()
    with pytest.raises(ValueError):
        AdmissionConfig(policy="token_bucket", rate=0.0).validate()
    with pytest.raises(ValueError):
        AdmissionConfig(policy="breaker", fail_thresh=1.5).validate()
    AdmissionConfig(policy="token_bucket", rate=5.0).validate()


# -------------------------------------------------- planner N-loss + spare
def test_plan_capacity_loss_tolerance_sizes_bigger():
    wl = _wl(num_requests=60)
    steady = plan_capacity(CFG, wl, qps=40.0, slo_ttft=2.0, slo_tpot=0.1,
                           attainment=0.9, sched=SchedConfig(slots=8),
                           ctx_quantum=32, max_replicas=5,
                           modes=("colocated",))
    resilient = plan_capacity(CFG, wl, qps=40.0, slo_ttft=2.0, slo_tpot=0.1,
                              attainment=0.9, sched=SchedConfig(slots=8),
                              ctx_quantum=32, max_replicas=5,
                              modes=("colocated",), loss_tolerance=1)
    assert steady["best"] is not None and resilient["best"] is not None
    assert resilient["best"]["replicas"] >= steady["best"]["replicas"] + 1
    assert resilient["best"]["goodput_frac_loss"] >= 0.9
    assert resilient["loss_tolerance"] == 1
    # a 1-replica fleet can never survive losing 1
    one = [r for r in resilient["rows"] if r["replicas"] == 1]
    assert all(r["goodput_frac_loss"] == 0.0 for r in one)


def test_plan_capacity_loss_tolerance_disagg_pool_floor():
    # the adversary can empty a 1-replica pool: every 2-replica disagg
    # candidate fails the loss gate outright
    wl = _wl(num_requests=40)
    plan = plan_capacity(CFG, wl, qps=20.0, slo_ttft=2.0, slo_tpot=0.1,
                         attainment=0.9, sched=SchedConfig(slots=8),
                         ctx_quantum=32, max_replicas=4,
                         modes=("disaggregated",), loss_tolerance=1,
                         early_stop=False)
    for r in plan["rows"]:
        if r["prefill"] <= 1 or r["decode"] <= 1:
            assert r.get("goodput_frac_loss", 0.0) == 0.0


def test_autoscale_spare_adds_headroom():
    cost = ServingCostModel(CFG, H100_SXM, ctx_quantum=32)
    asc = AutoscaleConfig(min_replicas=1, max_replicas=8, spare=2)
    sc = Autoscaler(asc, cost=cost, sched=SchedConfig(slots=8), pool="mixed")
    # no observed traffic: the policy asks for 0, spares lift it to 2
    assert sc.desired(10.0, 1) == 2
    with pytest.raises(ValueError):
        AutoscaleConfig(spare=-1).validate()


# ---------------------------------------------------------------- goldens
def _sig6(x: float) -> float:
    return float(f"{x:.6g}")


def test_chaos_summary_golden():
    # 6-sig-fig pin of one scripted chaos trace: crash + straggler + link
    # on the disaggregated fleet. Catches accidental schedule or
    # accounting drift in the fault-injection path.
    reqs = _wl().generate()
    spec = _spec(["prefill", "decode", "decode"],
                 chaos=ChaosConfig(script=(
                     ChaosEvent(0.1, "link", factor=3.0, duration=2.0),
                     ChaosEvent(0.2, "straggler", factor=2.0, duration=1.0,
                                picks=(0.0,)),
                     ChaosEvent(0.3, "crash", picks=(0.99,)),)))
    cres = simulate_cluster(reqs, CFG, spec)
    _conserved(cres, len(reqs))
    s = summarize_cluster(cres, slo_ttft=2.0, slo_tpot=0.05)
    got = {k: _sig6(s[k]) for k in
           ("ttft_p95", "tpot_p95", "goodput_frac", "tokens_per_s",
            "recovery_s_mean")}
    got["re_prefill_tokens"] = s["re_prefill_tokens"]
    got["requests_lost"] = s["requests_lost"]
    got["chaos_crashes"] = s["chaos_crashes"]
    assert got == PINNED_CHAOS_SUMMARY


PINNED_CHAOS_SUMMARY = {
    "ttft_p95": 0.317871,
    "tpot_p95": 0.0289603,
    "goodput_frac": 1.0,
    "tokens_per_s": 536.038,
    "recovery_s_mean": 0.623245,
    "re_prefill_tokens": 387,
    "requests_lost": 0,
    "chaos_crashes": 1,
}
