"""Hypothesis if available; otherwise stand-ins that register each property
test as SKIPPED (visible in the pytest summary) instead of silently dropping
it, while the rest of the module keeps running. Usage:

    from hypkit import given, settings, st
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub(*a, **k):
                pass

            stub.__name__ = f.__name__
            stub.__doc__ = f.__doc__
            return stub

        return deco

    def settings(*_a, **_k):
        return lambda f: f

    class _AnyStrategy:
        """st.integers(...), st.floats(...), ... -> inert placeholders."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()
