"""Hypothesis if available; otherwise a deterministic seeded-sampling
fallback so property tests RUN everywhere instead of skipping.

Usage stays `from hypkit import given, settings, st`. With hypothesis
installed (CI installs requirements-dev.txt) you get the real engine —
shrinking, the example database, adaptive generation. Without it, the
fallback draws `max_examples` pseudo-random examples from the declared
strategies with an `np.random.default_rng` seeded from the test's name,
so local runs are reproducible, hit the same assertions, and leave zero
permanently-skipped placeholders in the fast tier.

Only the strategy surface this repo uses is implemented: `st.integers`,
`st.floats`, `st.sampled_from`, `st.booleans`, `st.lists`. Adding a test
that needs more either extends `_Strategies` below or installs
hypothesis.
"""

import zlib

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as np

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        """A sampler: draw(rng) -> one example."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, allow_nan=False, allow_infinity=False):
            lo, hi = float(min_value), float(max_value)

            def draw(rng):
                # mix uniform draws with the interval edges: boundary
                # values are where float properties usually break
                r = rng.random()
                if r < 0.05:
                    return lo
                if r < 0.10:
                    return hi
                return float(lo + (hi - lo) * rng.random())

            return _Strategy(draw)

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(
                lambda rng: options[int(rng.integers(len(options)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.draw(rng) for _ in range(n)]

            return _Strategy(draw)

        def __getattr__(self, name):
            raise NotImplementedError(
                f"hypkit fallback has no strategy {name!r}; extend "
                "tests/hypkit.py or install hypothesis")

    st = _Strategies()

    def given(*arg_strategies, **kw_strategies):
        def deco(f):
            # NOT functools.wraps: __wrapped__ would make pytest resolve
            # the original signature and demand fixtures for m/n/k/...
            def runner(*fixed_args, **fixed_kwargs):
                n = getattr(runner, "_hypkit_max_examples",
                            _DEFAULT_MAX_EXAMPLES)
                # seed from the test name: reproducible run to run, but
                # different tests explore different streams
                rng = np.random.default_rng(
                    zlib.crc32(f.__qualname__.encode()))
                for _ in range(n):
                    args = [s.draw(rng) for s in arg_strategies]
                    kwargs = {k: s.draw(rng)
                              for k, s in kw_strategies.items()}
                    f(*fixed_args, *args, **fixed_kwargs, **kwargs)

            runner.__name__ = f.__name__
            runner.__doc__ = f.__doc__
            runner.__module__ = f.__module__
            return runner

        return deco

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        def deco(f):
            f._hypkit_max_examples = max_examples
            return f

        return deco
