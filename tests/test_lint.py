"""Tests for repro.lint: rule findings, pragmas, baseline, and the CLI gate.

The fixture modules under tests/lint_fixtures/ are never imported — their
SOURCE is linted under synthetic src/repro/<subpackage>/ paths so the
subpackage-scoped rules (D102, P203, U301) apply. The golden findings
live in tests/lint_fixtures/expected.json.
"""

import json
from pathlib import Path

import pytest

from repro.lint import all_rules, lint_file, lint_paths
from repro.lint.__main__ import main
from repro.lint.baseline import (
    BASELINE_VERSION,
    DEFAULT_BASELINE,
    load_baseline,
    new_findings,
    write_baseline,
)
from repro.lint.report import render_json, render_text

REPO = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).parent / "lint_fixtures"

# fixture file -> synthetic path scoping the subpackage-sensitive rules
FIXTURE_PATHS = {
    "det_violations.py": "src/repro/sim/det_violations.py",
    "purity_violations.py": "src/repro/cluster/purity_violations.py",
    "obs_violations.py": "src/repro/obs/obs_violations.py",
    "surface_violations.py": "src/repro/sim/surface_violations.py",
    "pragmas.py": "src/repro/sim/pragmas.py",
    "clean.py": "src/repro/sim/clean.py",
    "e001_syntax.py.txt": "src/repro/sim/e001_syntax.py",
}


def lint_fixture(name):
    src = (FIXTURES / name).read_text()
    return lint_file(FIXTURE_PATHS[name], source=src)


# ---------------------------------------------------------------- findings


def test_golden_expected_findings():
    """Every fixture produces exactly the checked-in (line, code) set."""
    expected = json.loads((FIXTURES / "expected.json").read_text())
    assert set(expected) == set(FIXTURE_PATHS), "expected.json out of sync"
    for name, want in expected.items():
        got = [[f.line, f.code] for f in lint_fixture(name)]
        assert got == want, f"{name}: {got} != {want}"


def test_fixtures_cover_every_rule_code():
    """The fixture corpus exercises the full rule catalog (plus E001)."""
    codes = {f.code for name in FIXTURE_PATHS for f in lint_fixture(name)}
    assert codes == {r.code for r in all_rules()} | {"E001"}


def test_clean_and_pragma_fixtures_are_clean():
    assert lint_fixture("clean.py") == []
    assert lint_fixture("pragmas.py") == []


def test_rules_are_documented_and_unique():
    rules = all_rules()
    assert len({r.code for r in rules}) == len(rules)
    for r in rules:
        assert r.summary and r.rationale, f"{r.code} lacks catalog text"


def test_test_files_are_exempt():
    """Default `applies` skips test files — float == is fine in tests."""
    src = "assert ttft == 0.25\n"
    assert lint_file("tests/test_something.py", source=src) == []
    assert lint_file("src/repro/sim/x.py", source=src) != []


# ----------------------------------------------------------------- pragmas


def test_pragma_wrong_code_does_not_suppress():
    src = "import numpy as np\nr = np.random.default_rng()  # lint: disable=U303\n"
    found = lint_file("src/repro/sim/x.py", source=src)
    assert [f.code for f in found] == ["D101"]


def test_pragma_disable_next_skips_comment_lines():
    src = (
        "# lint: disable-next=D104\n"
        "# another comment in between\n"
        "k = id(object())\n"
    )
    assert lint_file("src/repro/sim/x.py", source=src) == []


def test_pragma_disable_file():
    src = "# lint: disable-file=D104\nk = id(object())\nj = id(list())\n"
    assert lint_file("src/repro/sim/x.py", source=src) == []


def test_select_and_ignore_prefixes():
    found = lint_fixture("det_violations.py")
    only_d = lint_file(FIXTURE_PATHS["det_violations.py"],
                       source=(FIXTURES / "det_violations.py").read_text(),
                       select="D101,D102")
    assert {f.code for f in only_d} == {"D101", "D102"}
    no_d = lint_file(FIXTURE_PATHS["det_violations.py"],
                     source=(FIXTURES / "det_violations.py").read_text(),
                     ignore="D")
    assert not any(f.code.startswith("D") for f in no_d)
    assert len(found) > len(only_d)


# ---------------------------------------------------------------- baseline


def test_baseline_roundtrip_absorbs_findings(tmp_path):
    findings = lint_fixture("det_violations.py")
    assert findings
    bl_path = tmp_path / "bl.json"
    write_baseline(findings, bl_path)
    assert new_findings(findings, load_baseline(bl_path)) == []


def test_baseline_is_line_number_invariant(tmp_path):
    """Shifting an offending line (unrelated edits) must not break the gate."""
    src = "import numpy as np\nr = np.random.default_rng()\n"
    shifted = "# a new leading comment\n" + src
    bl_path = tmp_path / "bl.json"
    write_baseline(lint_file("src/repro/sim/x.py", source=src), bl_path)
    later = lint_file("src/repro/sim/x.py", source=shifted)
    assert new_findings(later, load_baseline(bl_path)) == []


def test_baseline_counts_cap_duplicates(tmp_path):
    """A second identical offending line exceeds the baselined count."""
    one = "r = id(object())\n"
    bl_path = tmp_path / "bl.json"
    write_baseline(lint_file("src/repro/sim/x.py", source=one), bl_path)
    two = one + one
    leftover = new_findings(lint_file("src/repro/sim/x.py", source=two),
                            load_baseline(bl_path))
    assert [f.code for f in leftover] == ["D104"]


def test_baseline_version_mismatch_raises(tmp_path):
    p = tmp_path / "bl.json"
    p.write_text(json.dumps({"version": 999, "findings": {}}))
    with pytest.raises(ValueError, match="version"):
        load_baseline(p)


def test_missing_baseline_is_empty(tmp_path):
    assert load_baseline(tmp_path / "nope.json") == {}


# --------------------------------------------------------------- reporters


def test_render_text_and_json_shape():
    findings = lint_fixture("surface_violations.py")
    text = render_text(findings)
    assert "U302" in text and "finding(s)" in text
    data = json.loads(render_json(findings))
    assert all(set(d) >= {"path", "line", "col", "code", "message"}
               for d in data)
    assert [d["code"] for d in data] == [f.code for f in findings]


# ---------------------------------------------------------------- CLI gate


def _write_violation(tmp_path):
    """A seeded synthetic violation, as the CI gate would see it."""
    bad = tmp_path / "bad.py"
    bad.write_text("import numpy as np\nr = np.random.default_rng()\n")
    return bad


def test_cli_fails_on_synthetic_violation(tmp_path, capsys):
    """The acceptance criterion: the gate exits 1 on a fresh violation."""
    bad = _write_violation(tmp_path)
    rc = main([str(bad), "--no-baseline"])
    assert rc == 1
    out = capsys.readouterr().out
    assert "D101" in out and "default_rng" in out


def test_cli_clean_exits_zero(tmp_path, capsys):
    good = tmp_path / "good.py"
    good.write_text('"""Nothing to see."""\n')
    assert main([str(good), "--check"]) == 0
    assert "clean" in capsys.readouterr().out


def test_cli_write_baseline_then_gate(tmp_path, capsys):
    """--write-baseline absorbs today's findings; the gate then passes."""
    bad = _write_violation(tmp_path)
    bl = tmp_path / "bl.json"
    assert main([str(bad), "--baseline", str(bl), "--write-baseline"]) == 0
    assert main([str(bad), "--baseline", str(bl), "--check"]) == 0
    assert main([str(bad), "--baseline", str(bl), "--no-baseline"]) == 1
    capsys.readouterr()


def test_cli_json_format(tmp_path, capsys):
    bad = _write_violation(tmp_path)
    rc = main([str(bad), "--no-baseline", "--format", "json"])
    assert rc == 1
    data = json.loads(capsys.readouterr().out)
    assert data[0]["code"] == "D101"


def test_cli_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for r in all_rules():
        assert r.code in out


# ------------------------------------------------------------ live tree


def test_live_tree_clean_modulo_baseline():
    """src/repro/ itself passes the gate against the checked-in baseline.

    This is the same check scripts/verify.sh and the CI lint job run; a
    failure here means a new contract violation landed without a fix,
    pragma, or deliberate baseline update.
    """
    findings = lint_paths([REPO / "src" / "repro"])
    baseline = load_baseline(REPO / DEFAULT_BASELINE)
    fresh = new_findings(findings, baseline)
    assert fresh == [], render_text(fresh)


def test_shipped_baseline_stays_near_empty():
    """The baseline is accepted LEGACY, not a dumping ground (<= 10)."""
    baseline = load_baseline(REPO / DEFAULT_BASELINE)
    assert sum(baseline.values()) <= 10
