"""repro.obs.monitor / diff / dashboard: live SLO monitoring is
observational (monitored runs reproduce unmonitored schedules exactly and
add only slo.*/alert.*/anomaly.* instants to the trace), the online
monitor agrees with its offline replay bit-for-bit, burn-rate alerts fire
fast-burn before slow-burn on an overload burst, `summarize_cluster`
gains the SLO columns, the trace diff passes on seed-only changes and
fails (non-zero CLI exit) on a degraded run, and the HTML dashboard is a
parseable self-contained page."""

import html.parser

import numpy as np
import pytest

from repro.configs import get_config
from repro.obs import (
    SLO,
    SLOMonitor,
    Tracer,
    diff_traces,
    make_slos,
    read_jsonl,
    regressions,
    render_html,
    replay,
    write_jsonl,
)
from repro.obs.__main__ import main as obs_main
from repro.sim import LengthDist, SchedConfig, Workload
from repro.cluster import (
    AutoscaleConfig,
    ClusterSpec,
    ReplicaSpec,
    simulate_cluster,
    summarize_cluster,
)

CFG = get_config("qwen3_14b")


def _wl(**kw):
    base = dict(
        qps=50.0, num_requests=24, arrival="poisson",
        prompt=LengthDist("lognormal", 96, 0.4, lo=8, hi=512),
        output=LengthDist("lognormal", 24, 0.4, lo=2, hi=128), seed=0,
    )
    base.update(kw)
    return Workload(**base)


def _spec(pools, **kw):
    sched = SchedConfig(slots=8)
    return ClusterSpec(
        replicas=tuple(ReplicaSpec(hw="h100", pool=p, sched=sched,
                                   ctx_quantum=32) for p in pools),
        **kw)


def _autoscale():
    return AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=4,
                           interval=0.5, warmup=0.4,
                           target_qps_per_replica=8.0)


def _diurnal_reqs(seed=0):
    return _wl(qps=20.0, num_requests=120, arrival="diurnal",
               diurnal_period=8.0, diurnal_amp=0.9, seed=seed).generate()


def _monitor(window=0.5):
    return SLOMonitor(make_slos(slo_ttft=0.5, slo_goodput=0.99,
                                window=window))


SCENARIOS = {
    "colocated": dict(pools=["mixed", "mixed"], autoscale=None),
    "disaggregated": dict(pools=["prefill", "decode"], autoscale=None),
    "autoscaled": dict(pools=["mixed", "mixed"], autoscale=_autoscale()),
}


def _run(label, *, tracer=None, monitor=None):
    sc = SCENARIOS[label]
    reqs = _diurnal_reqs() if sc["autoscale"] else _wl().generate()
    return simulate_cluster(reqs, CFG, _spec(sc["pools"]),
                            autoscale=sc["autoscale"], tracer=tracer,
                            monitor=monitor)


# ----------------------------------------------------- observational SLO
@pytest.mark.parametrize("label", list(SCENARIOS))
def test_monitoring_never_perturbs_the_schedule(label):
    """Acceptance: attaching the monitor (which force-creates an internal
    sink-only tracer when none was given) changes no request timing."""
    plain = _run(label)
    mon = _run(label, monitor=_monitor())
    key = lambda c: [(r.rid, r.admitted, r.first_token, r.finish)
                     for r in sorted(c.records, key=lambda r: r.rid)]
    assert key(plain) == key(mon)
    assert plain.replica_spans == mon.replica_spans
    assert mon.slo is not None and plain.slo is None


@pytest.mark.parametrize("label", list(SCENARIOS))
def test_monitored_trace_adds_only_monitor_instants(label):
    """Acceptance: the golden event mix gains only slo.window / alert.* /
    anomaly.* instants — every pre-existing (kind, name) count is
    untouched."""
    from collections import Counter
    plain_tr, mon_tr = Tracer("request"), Tracer("request")
    _run(label, tracer=plain_tr)
    _run(label, tracer=mon_tr, monitor=_monitor())
    mix = lambda tr: Counter((e["ev"], e["name"]) for e in tr.events)
    a, b = mix(plain_tr), mix(mon_tr)
    assert {k: v for k, v in b.items() if k in a} == dict(a)
    extra = {name for (ev, name) in set(b) - set(a)}
    assert extra and all(
        n == "slo.window" or n.startswith(("alert.", "anomaly."))
        for n in extra), extra


# -------------------------------------------------- online == offline
def test_online_monitor_equals_offline_replay_exactly():
    tr = Tracer("request")
    slos = make_slos(slo_ttft=0.5, slo_goodput=0.99, window=2.0)
    mon = SLOMonitor(slos)
    cres = _run("autoscaled", tracer=tr, monitor=mon)
    offline = replay(tr.meta, tr.events, slos)
    assert cres.slo == offline


def test_windowed_ttft_p99_matches_offline_recompute():
    """The monitor's per-window TTFT p99 equals a numpy recompute over the
    same window's terminal events (exact: window n <= the tail
    reservoir)."""
    tr = Tracer("request")
    mon = SLOMonitor(make_slos(slo_ttft=0.5, window=2.0))
    _run("autoscaled", tracer=tr, monitor=mon)
    samples: dict[int, list[float]] = {}
    for ev in tr.events:
        if ev.get("ev") == "instant" and ev["name"] == "request.complete":
            samples.setdefault(int(ev["t"] // 2.0), []).append(
                ev["attrs"]["ttft"])
    rows = mon.result()["slos"][0]["windows"]
    judged = [w for w in rows if w["ok"] is not None]
    assert judged
    for w in judged:
        k = int(w["t0"] // 2.0)
        assert w["n"] == len(samples[k])
        assert w["value"] == pytest.approx(
            float(np.percentile(samples[k], 99)), rel=1e-9)


def test_goodput_counts_latency_misses_and_sheds_as_bad():
    """Goodput's definition: completed AND within every latency SLO. A
    completed-but-slow request and a shed both burn goodput budget."""
    tr = Tracer("summary")
    mon = SLOMonitor(make_slos(slo_ttft=0.5, slo_goodput=0.99, window=10.0))
    tr.add_sink(mon)
    for i in range(8):
        tr.instant("request.complete", float(i), rid=i, ttft=0.1, tpot=0.01,
                   e2e=0.2)
    tr.instant("request.complete", 8.0, rid=8, ttft=3.0, tpot=0.01, e2e=3.2)
    tr.instant("request.shed", 9.0, rid=9)
    mon.finish(10.0)
    res = mon.result()
    gp = [s for s in res["slos"] if s["name"].startswith("goodput")][0]
    lat = [s for s in res["slos"] if s["name"].startswith("ttft")][0]
    assert gp["n"] == 10 and gp["bad"] == 2  # slow + shed
    assert lat["n"] == 9 and lat["bad"] == 1  # the shed has no latency


# ------------------------------------------------------ burn-rate alerts
def _burst_monitor(window=4.0):
    """20s healthy TTFT then 20s grossly violating: the canonical
    fast-burn-then-slow-burn overload."""
    tr = Tracer("summary")
    mon = SLOMonitor(make_slos(slo_ttft=0.5, window=window))
    tr.add_sink(mon)
    t, i = 0.0, 0
    while t < 40.0:
        ttft = 0.1 if t < 20.0 else 2.0
        tr.instant("request.complete", t, rid=i, ttft=ttft, tpot=0.01,
                   e2e=ttft + 0.5)
        t += 1.0 / 3.0
        i += 1
    mon.finish(40.0)
    return tr, mon


def test_fast_burn_fires_before_slow_burn():
    _, mon = _burst_monitor()
    res = mon.result()
    firing = {a["rule"]: a["t"] for a in res["alerts"]
              if a["state"] == "firing"}
    assert {"fast_burn", "slow_burn"} <= set(firing)
    assert firing["fast_burn"] < firing["slow_burn"]
    assert res["alerts_fired"] == 2
    # every firing transition crossed both burn windows' thresholds
    for a in res["alerts"]:
        if a["state"] == "firing":
            assert a["burn_long"] >= a["burn_threshold"]
            assert a["burn_short"] >= a["burn_threshold"]


def test_time_in_violation_is_union_of_violated_windows():
    _, mon = _burst_monitor()
    res = mon.result()
    viol = [(w["t0"], w["t1"]) for s in res["slos"] for w in s["windows"]
            if w["ok"] is False]
    assert viol
    assert res["time_in_violation"] == pytest.approx(
        sum(t1 - t0 for t0, t1 in viol))  # windows of one SLO never overlap
    assert res["time_in_violation"] == pytest.approx(20.0)


def test_alert_resolves_when_the_burst_ends():
    tr = Tracer("summary")
    mon = SLOMonitor(make_slos(slo_ttft=0.5, window=4.0))
    tr.add_sink(mon)
    t, i = 0.0, 0
    while t < 60.0:
        ttft = 2.0 if 10.0 <= t < 20.0 else 0.1
        tr.instant("request.complete", t, rid=i, ttft=ttft, tpot=0.01,
                   e2e=ttft + 0.5)
        t += 1.0 / 3.0
        i += 1
    mon.finish(60.0)
    states = [a["state"] for a in mon.result()["alerts"]
              if a["rule"] == "fast_burn"]
    assert states == ["pending", "firing", "resolved"]


def test_slo_spec_validation_and_names():
    assert SLO("ttft", 0.5).name == "ttft_p99<=0.5s"
    assert SLO("goodput", 0.99).name == "goodput>=0.99"
    with pytest.raises(ValueError):
        SLO("goodput", 1.5)
    with pytest.raises(ValueError):
        SLO("ttft", 0.5, window=0.0)
    assert make_slos() == ()
    assert len(make_slos(slo_ttft=1.0, slo_goodput=0.99)) == 2


def test_finish_is_idempotent():
    _, mon = _burst_monitor()
    first = mon.result()
    mon.finish(40.0)
    assert mon.result() == first


# ------------------------------------------------------- summary columns
def test_summarize_cluster_gains_slo_columns():
    cres = _run("autoscaled", monitor=_monitor(window=2.0))
    s = summarize_cluster(cres)
    for col in ("time_in_violation", "alerts_fired", "budget_burn",
                "anomalies"):
        assert col in s, col
    assert s["time_in_violation"] >= 0.0
    plain = summarize_cluster(_run("autoscaled"))
    assert "time_in_violation" not in plain


def test_anomaly_detector_flags_the_burst_onset():
    """A replica queue that sits flat then spikes produces an
    anomaly.queue instant at the spike, not during the flat phase."""
    tr = Tracer("replica")
    mon = SLOMonitor(make_slos(slo_ttft=10.0, window=10.0))
    tr.add_sink(mon)
    for i in range(60):
        tr.counter("queue", 0.5 * i, 4.0 + (i % 2), "r0")
    tr.counter("queue", 30.5, 400.0, "r0")
    mon.finish(31.0)
    an = mon.result()["anomalies"]
    assert [a for a in an if a["t"] == 30.5 and a["series"] == "queue"]
    assert not [a for a in an if a["t"] < 30.0]


# ------------------------------------------------------------------ diff
def _traced_jsonl(tmp_path, name, *, seed=0, max_replicas=4):
    tr = Tracer("request")
    asc = AutoscaleConfig(policy="rate", min_replicas=1,
                          max_replicas=max_replicas, interval=0.5,
                          warmup=0.4, target_qps_per_replica=8.0)
    simulate_cluster(_diurnal_reqs(seed=seed), CFG, _spec(["mixed", "mixed"]),
                     autoscale=asc, tracer=tr,
                     monitor=SLOMonitor(make_slos(slo_ttft=0.5,
                                                  window=2.0)))
    p = tmp_path / name
    write_jsonl(tr.events, p, tr.meta)
    return p


def test_diff_passes_on_seed_only_change(tmp_path):
    """Acceptance: two runs differing only in workload seed stay within
    the default tolerances."""
    a = _traced_jsonl(tmp_path, "a.jsonl", seed=0)
    b = _traced_jsonl(tmp_path, "b.jsonl", seed=7)
    diff = diff_traces(read_jsonl(a), read_jsonl(b))
    assert regressions(diff) == []
    assert obs_main(["diff", str(a), str(b)]) == 0


def test_diff_fails_on_degraded_run(tmp_path, capsys):
    """Acceptance: halving the replica cap under the same load regresses
    past the gate -> non-zero CLI exit."""
    a = _traced_jsonl(tmp_path, "a.jsonl", max_replicas=4)
    b = _traced_jsonl(tmp_path, "b.jsonl", max_replicas=1)
    diff = diff_traces(read_jsonl(a), read_jsonl(b))
    assert regressions(diff)
    assert obs_main(["diff", str(a), str(b)]) == 1
    assert "REGRESSIONS" in capsys.readouterr().out


def test_diff_self_is_clean_and_fail_on_overrides(tmp_path, capsys):
    a = _traced_jsonl(tmp_path, "a.jsonl")
    diff = diff_traces(read_jsonl(a), read_jsonl(a))
    assert diff["event_mix"] == {}
    assert diff["scaling"]["first_divergence"] is None
    assert regressions(diff) == []
    # a tightened override still passes on the identical trace ...
    assert obs_main(["diff", str(a), str(a), "--fail-on",
                     "ttft_p99=0.0001"]) == 0
    # ... and an unknown metric is an error, not a silent no-op
    with pytest.raises(KeyError):
        regressions(diff, {"no_such_metric": 1.0})
    capsys.readouterr()


# ------------------------------------------------------------- dashboard
class _HTMLCheck(html.parser.HTMLParser):
    def __init__(self):
        super().__init__()
        self.tags = []

    def handle_starttag(self, tag, attrs):
        self.tags.append(tag)


def test_dashboard_renders_selfcontained_html(tmp_path, capsys):
    tr = Tracer("request")
    _run("autoscaled", tracer=tr, monitor=_monitor(window=2.0))
    doc = render_html(tr.events, tr.meta)
    assert len(doc) > 5000
    p = _HTMLCheck()
    p.feed(doc)
    assert p.tags.count("svg") >= 3  # arrivals, ribbon, replicas at least
    assert "viz-root" in doc and "<script" not in doc
    assert "NaN" not in doc
    # and through the CLI: --html writes the same page
    trace = tmp_path / "t.jsonl"
    write_jsonl(tr.events, trace, tr.meta)
    out_html = tmp_path / "dash.html"
    assert obs_main(["report", str(trace), "--html", str(out_html),
                     "--slo-ttft", "0.5", "--slo-window", "2"]) == 0
    assert "offline SLO replay:" in capsys.readouterr().out
    assert out_html.read_text().startswith("<!DOCTYPE html>")


def test_dashboard_degrades_on_summary_level_trace():
    _, mon = None, None
    tr, mon = _burst_monitor()
    doc = render_html(tr.events, tr.meta if tr.meta else {"horizon": 40.0})
    assert "alert ribbon" in doc  # the burst fired, the ribbon renders
    assert "viz-root" in doc
