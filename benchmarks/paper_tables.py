"""Benchmarks reproducing the paper's tables/figures with the analytic model.

Each `bench_*` returns rows of (name, us_per_call, derived) where `derived`
carries the validation quantity (relative error, bound-match, speedup...).
"""

from __future__ import annotations

from repro.core.dse import NODES, build_chip, optimize_node
from repro.core.hardware import (
    A100_80G,
    B200,
    DRAM_TECH,
    H100_SXM,
    H200,
    HardwareSpec,
    NDR_IB,
    NVLINK4,
    NVS5_NET,
    NVS_NET,
)
from repro.core.memory import training_memory
from repro.core.paper_data import (
    FIG5_SYSTEMS,
    GPT_CONFIGS,
    LLAMA2_CONFIGS,
    TABLE1,
    TABLE2,
    TABLE4,
)
from repro.core.parallelism import Mapping
from repro.core.predict import gemm_table, inference_latency, train_step_time


# --------------------------------------------------------------------- Table 1
def bench_table1():
    rows = []
    for r in TABLE1:
        cfg = GPT_CONFIGS[r.model]
        m = Mapping(dp=r.dp, tp=r.tp, pp=r.pp, sp=r.sp, microbatch=1,
                    recompute=r.recompute,
                    schedule="interleaved" if r.pp > 1 else "1f1b", vpp=2)
        t = train_step_time(cfg, A100_80G, m, global_batch=r.batch, seq=2048).total
        err = 100.0 * (t - r.t_ref) / r.t_ref
        rows.append(
            (f"table1/{r.model}-g{r.gpus}-{r.recompute}", t * 1e6, f"dE={err:+.1f}%")
        )
    return rows


# --------------------------------------------------------------------- Table 2
def bench_table2():
    rows = []
    for r in TABLE2:
        cfg = LLAMA2_CONFIGS[r.model]
        for hw, tref in ((A100_80G, r.t_a100_ms), (H100_SXM, r.t_h100_ms)):
            t = inference_latency(cfg, hw, tp=r.tp, batch=1, prompt=200, gen=200).total
            err = 100.0 * (t * 1e3 - tref) / tref
            rows.append(
                (f"table2/{r.model}-tp{r.tp}-{hw.name}", t * 1e6, f"dE={err:+.1f}%")
            )
    return rows


# --------------------------------------------------------------------- Table 4
_T4_MAP = {"qkv_proj": ("q_proj", "kv_proj"), "qk": ("qk",), "av": ("av",),
           "o_proj": ("o_proj",), "mlp_up": ("mlp_up", "mlp_gate"),
           "mlp_down": ("mlp_down",)}


def bench_table4():
    cfg = LLAMA2_CONFIGS["llama2-13b"]
    rows = []
    for hw, col in ((A100_80G, 1), (H100_SXM, 3)):
        ts = gemm_table(cfg, hw, tp=1, batch=1, S=200, decode=False)
        by_name = {t.name: t for t in ts}
        n_match = 0
        for gemm, t_a, b_a, t_h, b_h in TABLE4:
            want = b_a if col == 1 else b_h
            ops = [by_name[n] for n in _T4_MAP[gemm] if n in by_name]
            t_us = sum(o.t for o in ops) * 1e6
            # paper classes: compute vs memory (we fold l2 into memory)
            got = "compute" if all(o.bound == "compute" for o in ops) else "memory"
            ok = got == want
            n_match += ok
            rows.append(
                (f"table4/{hw.name}/{gemm}", t_us, f"bound={got}/{want}:{'OK' if ok else 'X'}")
            )
        rows.append((f"table4/{hw.name}/match", 0.0, f"{n_match}/6"))
    return rows


# ----------------------------------------------------------------------- Fig 4
def bench_fig4():
    rows = []
    for model, gpus, batch, tp, pp in (
        ("gpt-22b", 8, 4, 8, 1),
        ("gpt-175b", 64, 64, 8, 8),
        ("gpt-530b", 280, 280, 8, 35),
    ):
        cfg = GPT_CONFIGS[model]
        for rec in ("none", "selective", "full"):
            mb = training_memory(
                cfg, global_batch=batch, seq=2048, dp=1, tp=tp, pp=pp, sp=False,
                microbatch=1, recompute=rec,
            )
            rows.append(
                (f"fig4/{model}/{rec}", 0.0,
                 f"mem={mb.total / 2**30:.1f}GiB(act={mb.activations / 2**30:.1f})")
            )
    return rows


# ----------------------------------------------------------------------- Fig 5
def _fig5_hw(chip: str, net: str) -> HardwareSpec:
    base = {"a100": A100_80G, "h100": H100_SXM, "h200": H200, "b200": B200}[chip]
    # transformer-engine precision per generation (paper §5.2): H100/H200 FP8,
    # B200 FP4 — modeled as the effective GEMM rate + 1-byte operands
    if chip in ("h100", "h200"):
        base = HardwareSpec(base.name, {**base.flops, "bf16": base.flops["fp8"]},
                            base.mem, base.net, base.compute_util, base.gemv_dram_util)
    if chip == "b200":
        base = HardwareSpec(base.name, {**base.flops, "bf16": base.flops["fp4"]},
                            base.mem, base.net, base.compute_util, base.gemv_dram_util)
    nets = {"hdr": base.net[1], "ndr": NDR_IB, "nvs": NVS_NET, "nvs5": NVS5_NET}
    if net == "hdr":
        from repro.core.hardware import HDR_IB

        inter = HDR_IB
    else:
        inter = nets[net]
    return base.with_net(inter=inter)


def bench_fig5():
    cfg = GPT_CONFIGS["gpt-175b"]
    times = {}
    for label, chip, net, batch, _ in FIG5_SYSTEMS:
        hw = _fig5_hw(chip, net)
        prec = 2 if chip == "a100" else 1
        # paper-faithful: the paper's model does NOT overlap the DP gradient
        # all-reduce with backward (dp_overlap=0) — that un-hidden inter-node
        # term is exactly what makes NVS vs NDR a 2x+ lever in Fig 5
        m = Mapping(dp=128, tp=8, pp=8, sp=True, microbatch=1, recompute="selective",
                    schedule="interleaved", vpp=2, prec=prec, dp_overlap=0.0)
        t = train_step_time(cfg, hw, m, global_batch=batch, seq=2048).total
        # larger-batch runs amortize bubble+DP: report per-1024-sequences time
        times[label] = t * (1024 / batch)
    ref = times["B200-NVS-L"]
    rows = []
    for label, t in times.items():
        rows.append((f"fig5/{label}", t * 1e6, f"speedup_vs_A100={times['A100-HDR'] / t:.1f}x"))
    rows.append(("fig5/A100->B200-NVS-L", 0.0, f"{times['A100-HDR'] / ref:.1f}x (paper ~35x)"))
    return rows


# ----------------------------------------------------------------------- Fig 6
def bench_fig6():
    cfg = GPT_CONFIGS["gpt-7b"]
    m = Mapping(dp=64, tp=4, pp=4, sp=True, microbatch=1, recompute="selective")
    rows = []
    for dram in ("HBM2", "HBM2E", "HBM3", "HBM4"):
        for node in NODES:
            p = optimize_node(cfg, node, dram, "NDR-x8", mapping=m, global_batch=512,
                              seq=2048)
            rows.append((f"fig6/{dram}/{node}", p.time * 1e6, f"f_core={p.f_core:.2f}"))
    for net in ("NDR-x8", "XDR-x8", "GDR-x8"):
        p = optimize_node(cfg, "N2", "HBM3", net, mapping=m, global_batch=512, seq=2048)
        rows.append((f"fig6/net/{net}@N2", p.time * 1e6, f"f_core={p.f_core:.2f}"))
    return rows


# ----------------------------------------------------------------------- Fig 7
def bench_fig7():
    cfg = GPT_CONFIGS["gpt-7b"]
    rows = []
    for dram in ("HBM2", "HBM3", "HBM4"):
        hw = build_chip("N2", 0.5, dram, "NDR-x8")
        ts = [t for t in gemm_table(cfg, hw, tp=4, batch=128, S=2048, decode=False)]
        tot = sum(t.t for t in ts)
        frac = {b: sum(t.t for t in ts if t.bound == b) / tot for b in
                ("compute", "memory", "l2")}
        rows.append(
            (f"fig7/{dram}@N2", tot * 1e6,
             f"compute={frac['compute']:.0%},mem={frac['memory']:.0%},l2={frac['l2']:.0%}")
        )
    return rows


# ----------------------------------------------------------------------- Fig 8
def bench_fig8():
    cfg = LLAMA2_CONFIGS["llama2-13b"]
    rows = []
    from repro.core.kvcache import kv_cache_bytes
    from repro.core.operators import total_param_count

    for hw in (A100_80G, H100_SXM):
        for B in (1, 16):
            ts = gemm_table(cfg, hw, tp=1, batch=B, S=200, decode=False)
            gemms = [t for t in ts if t.flops > 0]
            tot = sum(t.t for t in gemms)
            comp = sum(t.t for t in gemms if t.bound == "compute") / tot
            rows.append((f"fig8/{hw.name}/B{B}/prefill", tot * 1e6,
                         f"compute_frac={comp:.0%}"))
            dts = gemm_table(cfg, hw, tp=1, batch=B, S=400, decode=True)
            dcomp = [t for t in dts if t.bound == "compute" and t.flops > 0]
            rows.append((f"fig8/{hw.name}/B{B}/decode", sum(t.t for t in dts) * 1e6,
                         f"n_compute_bound={len(dcomp)} (expect 0)"))
        rows.append(
            (f"fig8/{hw.name}/inset", 0.0,
             f"weights={total_param_count(cfg) * 2 / 2**30:.1f}GiB,"
             f"kv(B=16)={kv_cache_bytes(cfg, 16, 400) / 2**30:.2f}GiB")
        )
    return rows


# ----------------------------------------------------------------------- Fig 9
def bench_fig9():
    cfg = LLAMA2_CONFIGS["llama2-13b"]
    rows = []
    order = ["GDR6", "HBM2", "HBM2E", "HBM3", "HBM3E", "HBMX"]
    for n_gpu in (2, 8):
        prev = None
        for dram in order:
            hw = A100_80G.with_dram(dram, DRAM_TECH[dram])
            t = inference_latency(cfg, hw, tp=n_gpu, batch=1, prompt=200, gen=200).total
            gain = "" if prev is None else f"gain={prev / t:.2f}x"
            rows.append((f"fig9/{n_gpu}gpu/{dram}", t * 1e6, gain))
            prev = t
        # HBMX + NVLink4 (paper: ~12% comm gain)
        hw = A100_80G.with_dram("HBMX", DRAM_TECH["HBMX"]).with_net(intra=NVLINK4)
        t = inference_latency(cfg, hw, tp=n_gpu, batch=1, prompt=200, gen=200).total
        rows.append((f"fig9/{n_gpu}gpu/HBMX+NV4", t * 1e6, f"vs_NV3={prev / t:.2f}x"))
    return rows


ALL = {
    "table1": bench_table1,
    "table2": bench_table2,
    "table4": bench_table4,
    "fig4": bench_fig4,
    "fig5": bench_fig5,
    "fig6": bench_fig6,
    "fig7": bench_fig7,
    "fig8": bench_fig8,
    "fig9": bench_fig9,
}
