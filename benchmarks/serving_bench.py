"""Serving-simulation suite: SLO metrics per scheduler policy, the
static-vs-continuous domination check, and the single-request consistency
contract with `inference_latency`. Rows follow the harness convention
(name, us_per_call, derived)."""

from __future__ import annotations

from repro.configs import get_config
from repro.core.hardware import H100_SXM
from repro.core.predict import inference_latency
from repro.sim import (
    LengthDist,
    POLICIES,
    SchedConfig,
    ServingCostModel,
    SimRequest,
    Workload,
    dominates,
    pareto_sweep,
    simulate,
    summarize,
)


def bench_serving():
    cfg = get_config("qwen3_14b")
    cost = ServingCostModel(cfg, H100_SXM, tp=1, ctx_quantum=16)
    wl = Workload(
        name="serving-smoke", qps=12.0, num_requests=64, arrival="poisson",
        prompt=LengthDist("lognormal", 256, 0.4, lo=16, hi=2048),
        output=LengthDist("lognormal", 64, 0.4, lo=4, hi=512), seed=0,
    )
    reqs = wl.generate()
    rows = []
    for policy in POLICIES:
        s = summarize(
            simulate(reqs, cost, SchedConfig(policy=policy, slots=8)),
            slo_ttft=2.0, slo_tpot=0.05,
        )
        rows.append((
            f"serving/{policy}-qps{wl.qps:g}",
            s["e2e_p50"] * 1e6,
            f"tok/s={s['tokens_per_s']:.0f}"
            f";ttft_p95={s['ttft_p95'] * 1e3:.0f}ms"
            f";tpot_p95={s['tpot_p95'] * 1e3:.1f}ms"
            f";goodput={s['goodput_frac']:.2f}"
            f";preempt={s['preemptions']}",
        ))

    # continuous must dominate static at every matched (slots, KV) point
    sweep = pareto_sweep(reqs, cost, policies=("static", "continuous"),
                         slot_counts=(2, 4, 8))
    by = {(r["policy"], r["slots"]): r for r in sweep}
    dom = all(dominates(by[("continuous", n)], by[("static", n)]) for n in (2, 4, 8))
    best = max(sweep, key=lambda r: r["tokens_per_s"])
    rows.append((
        "serving/continuous_vs_static",
        best["e2e_p95"] * 1e6,
        f"dominates={dom};best={best['policy']}x{best['slots']}"
        f"@{best['tokens_per_s']:.0f}tok/s",
    ))

    # single-request sim must reproduce inference_latency's TTFT/TPOT
    prompt, gen = 512, 64
    bd = inference_latency(cfg, H100_SXM, tp=1, batch=1, prompt=prompt, gen=gen)
    exact = ServingCostModel(cfg, H100_SXM, tp=1, ctx_quantum=1)
    r = simulate([SimRequest(0, 0.0, prompt, gen)], exact,
                 SchedConfig(policy="continuous", slots=1)).records[0]
    d_ttft = 100.0 * (r.ttft - bd.ttft) / bd.ttft
    d_tpot = 100.0 * (r.tpot - bd.tpot) / bd.tpot
    rows.append((
        "serving/single_req_consistency",
        r.ttft * 1e6,
        f"dTTFT={d_ttft:+.2f}%;dTPOT={d_tpot:+.2f}%",
    ))
    return rows
