# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: every paper table/figure + the §Roofline table + kernel
microbenches. Usage: PYTHONPATH=src python -m benchmarks.run [names...]"""

from __future__ import annotations

import argparse
import time


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(prog="python -m benchmarks.run",
                                description=__doc__)
    p.add_argument("suites", nargs="*", metavar="suite",
                   help="suite names to run (default: all): paper tables, "
                        "roofline, serving, cluster, autoscale, and "
                        "'kernels' (which additionally JIT-compiles the "
                        "jax/pallas kernels; it is imported lazily so the "
                        "other suites don't pay for it)")
    p.add_argument("--list", action="store_true", dest="list_suites",
                   help="print the available suite names and exit")
    return p


def _bench_kernels():
    # lazy: pulls in the whole jax/pallas kernel stack, which the
    # analytical suites (and --list) must not pay for
    from benchmarks.kernels_bench import bench_kernels
    return bench_kernels()


def _suites() -> dict:
    from benchmarks.autoscale_bench import bench_autoscale
    from benchmarks.cluster_bench import bench_cluster
    from benchmarks.paper_tables import ALL
    from benchmarks.roofline import bench_roofline
    from benchmarks.serving_bench import bench_serving
    from benchmarks.sim_speed_bench import bench_sim_speed

    suites = dict(ALL)
    suites["roofline"] = bench_roofline
    suites["kernels"] = _bench_kernels
    suites["serving"] = bench_serving
    suites["cluster"] = bench_cluster
    suites["autoscale"] = bench_autoscale
    suites["sim_speed"] = bench_sim_speed
    return suites


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    suites = _suites()
    if args.list_suites:
        print("\n".join(suites))
        return
    wanted = args.suites or list(suites)
    print("name,us_per_call,derived")
    failures = 0
    for name in wanted:
        fn = suites[name]
        t0 = time.time()
        try:
            for row, us, derived in fn():
                print(f"{row},{us:.1f},{derived}")
        except Exception as e:  # keep the harness running; report the suite
            failures += 1
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}")
        finally:
            print(f"{name}/_elapsed,{(time.time() - t0) * 1e6:.0f},")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
