# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: every paper table/figure + the §Roofline table + kernel
microbenches. Usage: PYTHONPATH=src python -m benchmarks.run [names...]"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks.autoscale_bench import bench_autoscale
    from benchmarks.cluster_bench import bench_cluster
    from benchmarks.kernels_bench import bench_kernels
    from benchmarks.paper_tables import ALL
    from benchmarks.roofline import bench_roofline
    from benchmarks.serving_bench import bench_serving

    suites = dict(ALL)
    suites["roofline"] = bench_roofline
    suites["kernels"] = bench_kernels
    suites["serving"] = bench_serving
    suites["cluster"] = bench_cluster
    suites["autoscale"] = bench_autoscale

    wanted = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    failures = 0
    for name in wanted:
        fn = suites[name]
        t0 = time.time()
        try:
            for row, us, derived in fn():
                print(f"{row},{us:.1f},{derived}")
        except Exception as e:  # keep the harness running; report the suite
            failures += 1
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}")
        finally:
            print(f"{name}/_elapsed,{(time.time() - t0) * 1e6:.0f},")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
