"""§Roofline: three-term roofline per (arch x shape) cell from the dry-run.

  compute    = analytic per-device FLOPs / (197 TFLOP/s bf16)
  memory     = modeled per-device HBM bytes / 819 GB/s
  collective = per-device collective bytes (HLO inventory x known trip counts)
               / 50 GB/s ICI

Dominant term = bottleneck; MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D
(inference); roofline fraction = ideal-compute-time / dominant-term (how close
the cell could get to pure-MXU time at this sharding).

HLO-derived raw numbers (cost_analysis; loop bodies counted once) are included
as a cross-check column. Reads experiments/dryrun/*.json (single-pod cells).
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.core.cellcost import cell_cost
from repro.models.transformer import Model

PEAK = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


# mirror of launch.dryrun train policies (importing dryrun would set the
# 512-device XLA flag inside the benchmark process)
_TRAIN_MICRO = {
    "arctic_480b": 16, "deepseek_moe_16b": 8, "rwkv6_7b": 2, "zamba2_1p2b": 2,
}
_TRAIN_MICRO_DEFAULT = 2


def _trip_counts(arch: str, shape_name: str) -> dict[int, int]:
    """Loop-depth -> multiplier for collective traffic (known static trips).

    Loop nesting per step kind: train = microbatch scan > layer scan >
    attention chunk scans; prefill/decode = layer scan > chunk scans. Depth-1
    collectives in a train step are *per-microbatch* (the fwd/bwd layer scans
    are depth 2) — using L here overcounted traffic ~20x in the first pass.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = Model(cfg)
    if cfg.family == "hybrid":
        L = cfg.num_layers // (cfg.attn_every or cfg.num_layers)
    else:
        L = max(model.n_scan(), 1)
    nq = max(shape.seq_len // cfg.attn_chunk, 1)
    if shape.kind == "train":
        m = _TRAIN_MICRO.get(arch, _TRAIN_MICRO_DEFAULT)
        if m > 1:
            return {1: m, 2: m * L, 3: m * L * 2, 4: m * L * nq}
        return {1: L, 2: L * 2, 3: L * nq}
    if shape.kind == "prefill":
        return {1: L, 2: L * nq, 3: L * nq}
    return {1: L, 2: L, 3: L}


def load_records(mesh: str = "pod16x16") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def analytic_collective_bytes(cfg, shape, *, dp: int = 16, tp: int = 16,
                              prec: int = 2) -> float:
    """Per-device collective bytes from the paper's comm model (eq. 3 volumes):
    Megatron TP all-reduces (2/layer fwd, +2 bwd for train; SP keeps volume),
    DP gradient reduce-scatter + param all-gather (ZeRO-1), MoE dispatch a2a.
    Consistent with the analytic compute/memory terms; the HLO inventory is
    recorded alongside as a (conservative, loop-attribution) upper bound."""
    from repro.core.operators import total_param_count

    B, S = shape.global_batch, shape.seq_len
    B_loc = max(B // dp, 1)
    L = cfg.num_layers
    rt = (tp - 1) / tp
    rd = (dp - 1) / dp
    n_ar = 4.0 if shape.kind == "train" else 2.0  # per layer (fwd[+bwd])
    if shape.kind == "decode":
        tok_bytes = B_loc * 1 * cfg.d_model * prec
        ctx_ar = 0.0
        if B < dp:  # context-parallel softmax partial reductions
            ctx_ar = L * 2 * B * cfg.num_heads * 4 * 2 * rd
        coll = L * n_ar * 2 * tok_bytes * rt + ctx_ar
    else:
        act_bytes = B_loc * S * cfg.d_model * prec
        coll = L * n_ar * 2.0 * act_bytes * rt
        if cfg.moe is not None:
            # dispatch + combine row exchange (a2a-equivalent volume)
            coll += L * 2 * (B_loc * S * cfg.moe.top_k * cfg.d_model * prec) * rt
    if shape.kind == "train":
        P_dev = total_param_count(cfg) / tp
        coll += 2 * 2.0 * P_dev * prec * rd  # grad RS + param AG (ZeRO-1)
    return coll


def roofline_row(rec: dict) -> dict | None:
    if rec["status"] != "ok":
        return None
    arch, shape_name = rec["arch"], rec["shape"]
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cc = cell_cost(cfg, shape, opt_8bit=(arch == "arctic_480b"))

    t_compute = cc.flops_per_device / PEAK
    t_memory = cc.dram_bytes_per_device / HBM_BW

    trips = _trip_counts(arch, shape_name)
    hlo_coll_bytes = 0.0
    for op in rec["collectives"]["ops"]:
        mult = trips.get(op["loop_depth"], 1) if op["loop_depth"] else 1
        hlo_coll_bytes += op["bytes"] * mult
    coll_bytes = analytic_collective_bytes(cfg, shape)
    t_coll = coll_bytes / ICI_BW

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_ideal = cc.model_flops_global / (CHIPS * PEAK)
    frac = t_ideal / max(terms[dominant], 1e-30)
    return {
        "arch": arch,
        "shape": shape_name,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": cc.model_flops_global,
        "useful_ratio": cc.model_flops_global / max(cc.flops_per_device * CHIPS, 1e-30),
        "roofline_fraction": frac,
        "hlo_flops_raw": rec["cost"]["flops_per_device_raw"],
        "peak_gib": rec["memory"]["peak_bytes_per_device"] / 2**30,
        "coll_bytes": coll_bytes,
        "hlo_coll_bytes_upper": hlo_coll_bytes,
    }


def bench_roofline():
    rows = []
    table = []
    for rec in load_records():
        r = roofline_row(rec)
        if r is None:
            continue
        table.append(r)
        rows.append(
            (
                f"roofline/{r['arch']}/{r['shape']}",
                max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]) * 1e6,
                f"dom={r['dominant']},frac={r['roofline_fraction']:.2f},"
                f"useful={r['useful_ratio']:.2f}",
            )
        )
    out = os.path.join(DRYRUN_DIR, "..", "roofline_table.json")
    with open(out, "w") as fh:
        json.dump(table, fh, indent=1)
    return rows
