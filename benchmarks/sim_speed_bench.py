"""Simulation-speed suite: sim-steps/second for both engine cores.

Pins the fleet-simulation hot path so speedups (and regressions) are
measurable, not vibes. Each size runs the same diurnal trace through
`simulate_cluster` and reports wall time, total scheduler iterations,
and steps/second:

  * small  —    8 replicas,   2k requests: both engines; this is the CI
    gate config (fast enough to run on every push).
  * medium —  100 replicas,  20k requests: both engines.
  * large  — 1000 replicas, 10⁶ requests: the ROADMAP item-3 target
    ("1000-replica, 10⁶-request diurnal traces in minutes"). The
    vectorized engine runs the full trace; the reference engine's
    steps/second is measured on a truncated stream (its per-step cost is
    dominated by O(replicas) candidate scans, so the rate is independent
    of trace length — running all 10⁶ requests through it takes hours
    and measures nothing new).

CLI (also wired into `python -m benchmarks.run sim_speed` at small size):

    PYTHONPATH=src python -m benchmarks.sim_speed_bench --sizes small \
        --json BENCH_sim_speed.json --gate benchmarks/sim_speed_baseline.json

`--gate` compares the vectorized engine's steps/second against a
checked-in baseline and exits nonzero on a >30% regression (tunable via
`--regression-frac`); `--update-baseline` refreshes the baseline file.
"""

from __future__ import annotations

import argparse
import json
import platform
import time

from repro.configs import get_config
from repro.sim import LengthDist, SchedConfig, Workload
from repro.cluster import ClusterSpec, ReplicaSpec, simulate_cluster

# per-size: fleet size, request count per engine (None = skip the engine)
SIZES = {
    "small": dict(replicas=8, requests={"vectorized": 2_000,
                                        "reference": 2_000}),
    "medium": dict(replicas=100, requests={"vectorized": 20_000,
                                           "reference": 20_000}),
    "large": dict(replicas=1_000, requests={"vectorized": 1_000_000,
                                            "reference": 50_000}),
}
GATE_ENGINE = "vectorized"
GATE_SIZE = "small"


def _workload(replicas: int, requests: int) -> list:
    return Workload(
        name="sim-speed", qps=replicas * 6.0, num_requests=requests,
        arrival="diurnal",
        prompt=LengthDist("lognormal", 96, 0.4, lo=8, hi=512),
        output=LengthDist("lognormal", 48, 0.4, lo=4, hi=256),
        seed=1).generate()


def _fleet(replicas: int) -> ClusterSpec:
    return ClusterSpec(replicas=tuple(
        ReplicaSpec(pool="mixed", sched=SchedConfig(slots=16), ctx_quantum=32)
        for _ in range(replicas)))


def run_size(size: str, engines=None) -> dict:
    """Run one size; returns {engine: {wall_s, iterations, steps_per_s,
    replicas, requests, completed}}."""
    conf = SIZES[size]
    out: dict = {}
    for engine, n in conf["requests"].items():
        if engines is not None and engine not in engines:
            continue
        reqs = _workload(conf["replicas"], n)
        spec = _fleet(conf["replicas"])
        t0 = time.perf_counter()
        cres = simulate_cluster(reqs, get_config("qwen3_14b"), spec,
                                engine=engine)
        wall = time.perf_counter() - t0
        iters = sum(r.iterations for r in cres.replica_results)
        out[engine] = {
            "replicas": conf["replicas"], "requests": n,
            "completed": len(cres.records), "wall_s": round(wall, 3),
            "iterations": iters,
            "steps_per_s": round(iters / wall, 1),
        }
    return out


def bench_sim_speed():
    """`benchmarks.run` suite entry: the small config on both engines,
    harness row convention (name, us_per_call, derived)."""
    rows = []
    res = run_size(GATE_SIZE)
    for engine, r in res.items():
        rows.append((
            f"sim_speed/{GATE_SIZE}-{engine}",
            r["wall_s"] * 1e6,
            f"steps_per_s={r['steps_per_s']:.0f};iters={r['iterations']}"
            f";replicas={r['replicas']};requests={r['requests']}",
        ))
    if len(res) == 2:
        speedup = (res["vectorized"]["steps_per_s"]
                   / res["reference"]["steps_per_s"])
        rows.append((f"sim_speed/{GATE_SIZE}-speedup", 0.0,
                     f"vectorized_over_reference={speedup:.2f}x"))
    return rows


def check_gate(results: dict, baseline_path: str, frac: float) -> list[str]:
    """Compare vectorized steps/s against the checked-in baseline;
    returns a list of failure messages (empty = pass)."""
    with open(baseline_path) as f:
        base = json.load(f)
    fails = []
    for size, engines in results.items():
        want = base.get("sizes", {}).get(size, {}).get(GATE_ENGINE)
        got = engines.get(GATE_ENGINE)
        if not want or not got:
            continue
        floor = want["steps_per_s"] * (1.0 - frac)
        if got["steps_per_s"] < floor:
            fails.append(
                f"sim_speed regression [{size}/{GATE_ENGINE}]: "
                f"{got['steps_per_s']:.0f} steps/s < floor {floor:.0f} "
                f"(baseline {want['steps_per_s']:.0f}, "
                f"allowed -{frac:.0%})")
    return fails


def main(argv=None) -> None:
    p = argparse.ArgumentParser(prog="python -m benchmarks.sim_speed_bench",
                                description=__doc__)
    p.add_argument("--sizes", default="small",
                   help=f"comma-separated sizes from {sorted(SIZES)}")
    p.add_argument("--engines", default=None,
                   help="restrict to these engines (comma-separated)")
    p.add_argument("--json", default="BENCH_sim_speed.json", dest="json_path",
                   help="write results here ('' to skip)")
    p.add_argument("--gate", default=None,
                   help="baseline JSON to gate against (fail on regression)")
    p.add_argument("--regression-frac", type=float, default=0.30,
                   help="allowed steps/s drop vs baseline before failing")
    p.add_argument("--update-baseline", default=None,
                   help="write/refresh this baseline JSON from the run")
    args = p.parse_args(argv)

    sizes = [s.strip() for s in args.sizes.split(",") if s.strip()]
    engines = ([e.strip() for e in args.engines.split(",") if e.strip()]
               if args.engines else None)
    results: dict = {}
    for size in sizes:
        if size not in SIZES:
            raise SystemExit(f"unknown size {size!r}; choose from "
                             f"{sorted(SIZES)}")
        results[size] = run_size(size, engines)
        for engine, r in results[size].items():
            print(f"{size:>6} {engine:<11} R={r['replicas']:<5} "
                  f"N={r['requests']:<8} {r['wall_s']:>8.2f}s  "
                  f"iters={r['iterations']:<9} "
                  f"{r['steps_per_s']:>10,.0f} steps/s")
        both = results[size]
        if "vectorized" in both and "reference" in both:
            ratio = (both["vectorized"]["steps_per_s"]
                     / both["reference"]["steps_per_s"])
            print(f"{size:>6} speedup     vectorized/reference = {ratio:.2f}x")

    payload = {"bench": "sim_speed", "platform": platform.platform(),
               "python": platform.python_version(), "sizes": results}
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json_path}")
    if args.update_baseline:
        with open(args.update_baseline, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# baseline updated: {args.update_baseline}")
    if args.gate:
        fails = check_gate(results, args.gate, args.regression_frac)
        for msg in fails:
            print(msg)
        if fails:
            raise SystemExit(1)
        print(f"# gate ok (>= {1 - args.regression_frac:.0%} of baseline "
              f"steps/s)")


if __name__ == "__main__":
    main()
