"""Kernel microbenchmarks: wall time of the jnp reference path on CPU (the
Pallas kernels themselves are TPU-targeted; interpret mode is correctness-only,
so the jnp oracle provides the timed baseline) + analytic TPU-v5e projections
for the kernel's shapes from the roofline model."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.hardware import TPU_V5E
from repro.core.roofline import GEMM, MemOp, op_time
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def _time(f, *args, n=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / n


def bench_kernels():
    rows = []
    key = jax.random.PRNGKey(0)
    # flash attention ref (CPU wall) + v5e analytic projection
    B, Hq, Hkv, S, dh = 1, 8, 2, 1024, 128
    q = jax.random.normal(key, (B, Hq, S, dh), jnp.float32)
    k = jax.random.normal(key, (B, Hkv, S, dh), jnp.float32)
    v = jax.random.normal(key, (B, Hkv, S, dh), jnp.float32)
    f = jax.jit(lambda a, b, c: attention_ref(a, b, c))
    t = _time(f, q, k, v)
    proj = (
        op_time(TPU_V5E, GEMM("qk", S, S, dh, batch=B * Hq, weight_reuse=False)).t
        + op_time(TPU_V5E, GEMM("av", S, dh, S, batch=B * Hq, weight_reuse=False)).t
    )
    rows.append((f"kernel/flash_attention/S{S}", t * 1e6, f"v5e_proj_us={proj * 1e6:.0f}"))

    T, D = 4096, 4096
    x = jax.random.normal(key, (T, D), jnp.float32)
    sc = jnp.ones((D,), jnp.float32)
    f = jax.jit(lambda a, b: rmsnorm_ref(a, b))
    t = _time(f, x, sc)
    proj = op_time(TPU_V5E, MemOp("rmsnorm", 2 * T * D * 2)).t
    rows.append((f"kernel/rmsnorm/{T}x{D}", t * 1e6, f"v5e_proj_us={proj * 1e6:.0f}"))
    return rows
