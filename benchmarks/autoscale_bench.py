"""Autoscaling suite: dynamic fleet vs static peak provisioning on a
diurnal trace, the reactive (rate / slo_debt) vs predictive (M/G/1
envelope) policies, pool-aware prefill/decode scaling vs the template
ratio, load shedding under a burst, and the pinned-bounds parity
contract with the static cluster. Rows follow the harness convention
(name, us_per_call, derived)."""

from __future__ import annotations

from dataclasses import replace

from repro.configs import get_config
from repro.sim import LengthDist, SchedConfig, Workload
from repro.cluster import (
    AutoscaleConfig,
    ClusterSpec,
    ReplicaSpec,
    provisioning_summary,
    seed_predictive,
    simulate_cluster,
    summarize_cluster,
)

SLO = dict(slo_ttft=2.0, slo_tpot=0.05)


def _spec(n, slots=8, **kw):
    return ClusterSpec(replicas=tuple(
        ReplicaSpec(pool="mixed", sched=SchedConfig(slots=slots),
                    ctx_quantum=32) for _ in range(n)), **kw)


def bench_autoscale():
    cfg = get_config("qwen3_14b")
    wl = Workload(
        name="diurnal-smoke", qps=24.0, num_requests=360, arrival="diurnal",
        diurnal_period=30.0, diurnal_amp=0.9,
        prompt=LengthDist("lognormal", 256, 0.4, lo=16, hi=2048),
        output=LengthDist("lognormal", 64, 0.4, lo=4, hi=512), seed=0,
    )
    reqs = wl.generate()
    cache: dict = {}
    rows = []

    # static peak fleet vs the autoscaled fleet on the same diurnal stream
    peak = simulate_cluster(reqs, cfg, _spec(5), _cost_cache=cache)
    s_peak = summarize_cluster(peak, **SLO)
    for policy in ("rate", "slo_debt", "predictive"):
        asc = AutoscaleConfig(policy=policy, min_replicas=1, max_replicas=5,
                              interval=1.0, window=4.0,
                              target_qps_per_replica=8.0, slo_ttft=2.0,
                              warmup=1.0)
        if policy == "predictive":
            asc = seed_predictive(asc, wl, reqs)
        cres = simulate_cluster(reqs, cfg, _spec(2), autoscale=asc,
                                _cost_cache=cache)
        s = summarize_cluster(cres, **SLO)
        prov = provisioning_summary(cres)
        rows.append((
            f"autoscale/{policy}-diurnal",
            s["e2e_p50"] * 1e6,
            f"goodput={s['goodput_frac']:.2f}"
            f";peak_repl={s['peak_replicas']}"
            f";repl_s={prov['replica_hours'] * 3600:.0f}"
            f";static_repl_s={prov['replica_hours_static_peak'] * 3600:.0f}"
            f";saved={prov['savings_frac']:.2f}"
            f";events={s['scale_events']}",
        ))
    rows.append((
        "autoscale/static-peak-5r",
        s_peak["e2e_p50"] * 1e6,
        f"goodput={s_peak['goodput_frac']:.2f}"
        f";repl_s={peak.replica_hours * 3600:.0f}",
    ))

    # pool-aware disaggregated scaling on a prefill-heavy stream: prefill
    # scales on admission wait, decode on KV + TPOT pressure
    wl_pf = Workload(
        name="prefill-heavy", qps=6.0, num_requests=180, arrival="diurnal",
        diurnal_period=30.0, diurnal_amp=0.8,
        prompt=LengthDist("lognormal", 2048, 0.3, lo=256, hi=6144),
        output=LengthDist("lognormal", 16, 0.4, lo=2, hi=64), seed=0,
    )
    reqs_pf = wl_pf.generate()
    disagg = ClusterSpec(replicas=tuple(
        ReplicaSpec(pool=p, sched=SchedConfig(slots=8), ctx_quantum=32)
        for p in ("prefill", "decode")))
    base = AutoscaleConfig(min_replicas=1, max_replicas=6, interval=1.0,
                           window=3.0, warmup=0.5)
    pool_asc = {"prefill": seed_predictive(base, wl_pf, reqs_pf),
                "decode": replace(base, policy="kv_tpot")}
    cres = simulate_cluster(reqs_pf, cfg, disagg, autoscale=pool_asc,
                            _cost_cache=cache)
    s = summarize_cluster(cres, **SLO)
    prov = provisioning_summary(cres)
    pool_s = ";".join(
        f"{p}_repl_s={v['replica_hours'] * 3600:.0f}"
        for p, v in prov["pools"].items())
    rows.append((
        "autoscale/pool-aware-disagg",
        s["e2e_p50"] * 1e6,
        f"goodput={s['goodput_frac']:.2f}"
        f";repl_s={prov['replica_hours'] * 3600:.0f};{pool_s}"
        f";events={s['scale_events']}",
    ))

    # load shedding bounds queueing when the fleet cannot grow
    shed_spec = _spec(2, shed_depth=12, retry_after=0.25, max_retries=2)
    cres = simulate_cluster(reqs, cfg, shed_spec, _cost_cache=cache)
    s = summarize_cluster(cres, **SLO)
    rows.append((
        "autoscale/shed-2r",
        s["e2e_p50"] * 1e6,
        f"shed={s['shed']};shed_frac={s['shed_frac']:.2f}"
        f";retries={s['retries']};goodput={s['goodput_frac']:.2f}",
    ))

    # pinned bounds must reproduce the static cluster exactly
    pin = AutoscaleConfig(min_replicas=3, max_replicas=3, interval=1.0)
    a = simulate_cluster(reqs, cfg, _spec(3), _cost_cache=cache)
    b = simulate_cluster(reqs, cfg, _spec(3), autoscale=pin, _cost_cache=cache)
    exact = all(
        (x.admitted, x.first_token, x.finish)
        == (y.admitted, y.first_token, y.finish)
        for x, y in zip(sorted(a.records, key=lambda r: r.rid),
                        sorted(b.records, key=lambda r: r.rid)))
    rows.append((
        "autoscale/pinned_bounds_parity",
        a.makespan * 1e6,
        f"exact={exact}",
    ))
    return rows
