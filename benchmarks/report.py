"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

  PYTHONPATH=src python -m benchmarks.report > experiments/report.md
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import DRYRUN_DIR, load_records, roofline_row


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def dryrun_table() -> str:
    lines = [
        "| arch | shape | mesh | compile s | peak GiB/dev (raw) | peak GiB/dev (TPU-adj) | colls/layer | status |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        r = json.load(open(f))
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - | - | skipped (n/a) |"
            )
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - | - | ERROR |"
            )
            continue
        m = r["memory"]
        loop_ops = sum(o["count"] for o in r["collectives"]["ops"] if o["loop_depth"])
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} | "
            f"{fmt_bytes(m['peak_bytes_per_device'])} | "
            f"{fmt_bytes(m.get('peak_bytes_tpu_adjusted', m['peak_bytes_per_device']))} | "
            f"{loop_ops} | ok |"
        )
    return "\n".join(lines)


def roofline_table() -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_records():
        r = roofline_row(rec)
        if r is None:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['model_flops']:.2e} | {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} |"
        )
    return "\n".join(lines)


def main():
    print("## Dry-run cells\n")
    print(dryrun_table())
    print("\n## Roofline (single-pod 16x16, per step)\n")
    print(roofline_table())


if __name__ == "__main__":
    main()
