"""Cluster-simulation suite: colocated vs disaggregated at matched QPS,
router policy comparison, a heterogeneous A100+H100 fleet, the modeled
prefix cache (finite vs infinite budget) under shared-prefix traffic, and
the single-replica parity contract with `repro.sim.simulate`. Rows follow
the harness convention (name, us_per_call, derived)."""

from __future__ import annotations

import math
import time

from repro.configs import get_config
from repro.obs import Tracer
from repro.core.hardware import H100_SXM
from repro.sim import LengthDist, SchedConfig, ServingCostModel, Workload, simulate
from repro.cluster import (
    ChaosConfig,
    ClusterSpec,
    PrefixCacheConfig,
    ReplicaSpec,
    simulate_cluster,
    summarize_cluster,
)

SLO = dict(slo_ttft=2.0, slo_tpot=0.05)


def _best_of(n, fn):
    """Best-of-n wall time for `fn()` (seconds) — the standard way to
    measure a deterministic simulation without scheduler noise."""
    best = math.inf
    for _ in range(n):
        t = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t)
    return best


def _spec(pools, hw="h100", slots=8, ctx_quantum=32):
    return ClusterSpec(replicas=tuple(
        ReplicaSpec(hw=hw if isinstance(hw, str) else hw[i % len(hw)],
                    pool=p, sched=SchedConfig(slots=slots),
                    ctx_quantum=ctx_quantum)
        for i, p in enumerate(pools)))


def bench_cluster():
    cfg = get_config("qwen3_14b")
    wl = Workload(
        name="cluster-smoke", qps=24.0, num_requests=48, arrival="poisson",
        prompt=LengthDist("lognormal", 256, 0.4, lo=16, hi=2048),
        output=LengthDist("lognormal", 64, 0.4, lo=4, hi=512), seed=0,
    )
    reqs = wl.generate()
    cache: dict = {}
    rows = []

    # colocated vs disaggregated, same fleet size, same stream
    for label, pools in (("colocated-4r", ["mixed"] * 4),
                         ("disagg-2p2d", ["prefill"] * 2 + ["decode"] * 2)):
        s = summarize_cluster(
            simulate_cluster(reqs, cfg, _spec(pools), _cost_cache=cache), **SLO)
        rows.append((
            f"cluster/{label}-qps{wl.qps:g}",
            s["e2e_p50"] * 1e6,
            f"tok/s={s['tokens_per_s']:.0f}"
            f";ttft_p95={s['ttft_p95'] * 1e3:.0f}ms"
            f";tpot_p95={s['tpot_p95'] * 1e3:.1f}ms"
            f";goodput={s['goodput_frac']:.2f}"
            f";xfer_share={s['xfer_share']:.4f}",
        ))

    # router policy comparison on the colocated fleet
    for router in ("round_robin", "jsq"):
        spec = ClusterSpec(replicas=_spec(["mixed"] * 4).replicas, router=router)
        s = summarize_cluster(simulate_cluster(reqs, cfg, spec,
                                               _cost_cache=cache), **SLO)
        rows.append((
            f"cluster/router-{router}",
            s["ttft_p95"] * 1e6,
            f"ttft_p95={s['ttft_p95'] * 1e3:.0f}ms;goodput={s['goodput_frac']:.2f}",
        ))

    # heterogeneous fleet: A100 + H100 colocated pair
    s = summarize_cluster(
        simulate_cluster(reqs, cfg, _spec(["mixed"] * 2, hw=("a100", "h100")),
                         _cost_cache=cache), **SLO)
    rows.append((
        "cluster/hetero-a100+h100",
        s["e2e_p50"] * 1e6,
        f"tok/s={s['tokens_per_s']:.0f};goodput={s['goodput_frac']:.2f}",
    ))

    # modeled prefix cache under shared-prefix session traffic: infinite
    # budget (== the legacy unconditional discount, pinned-parity anchor)
    # vs a finite LRU+TTL budget that actually evicts
    pwl = Workload(
        name="cluster-prefix", qps=24.0, num_requests=48, arrival="poisson",
        prompt=LengthDist("lognormal", 256, 0.4, lo=16, hi=2048),
        output=LengthDist("lognormal", 64, 0.4, lo=4, hi=512), seed=0,
        num_sessions=6, num_prefix_groups=3, prefix=LengthDist("fixed", 96.0))
    preqs = pwl.generate()
    for label, pc in (
            ("infinite", PrefixCacheConfig(budget_bytes=math.inf)),
            ("finite", PrefixCacheConfig(budget_frac=0.0005, ttl=5.0))):
        spec = ClusterSpec(replicas=_spec(["mixed"] * 4).replicas,
                           router="affinity", prefix_cache=pc)
        s = summarize_cluster(simulate_cluster(preqs, cfg, spec,
                                               _cost_cache=cache), **SLO)
        rows.append((
            f"cluster/prefix-cache-{label}",
            s["ttft_p95"] * 1e6,
            f"ttft_p95={s['ttft_p95'] * 1e3:.0f}ms"
            f";hit_tokens={s['cache_hit_tokens']}"
            f";hit_rate={s['cache_hit_rate']:.2f}"
            f";evictions={s['cache_evictions']}"
            f";goodput={s['goodput_frac']:.2f}",
        ))

    # tracer overhead: the same colocated run untraced (NULL_TRACER fast
    # path) vs fully traced at request level — the acceptance bound is
    # <2% overhead when tracing is off vs the pre-tracer baseline, which
    # the hoisted-boolean gating makes indistinguishable from untraced
    t_off = _best_of(3, lambda: simulate_cluster(
        reqs, cfg, _spec(["mixed"] * 4), _cost_cache=cache))
    tr_holder = []

    def _traced():
        tr = Tracer("request")
        simulate_cluster(reqs, cfg, _spec(["mixed"] * 4), tracer=tr,
                         _cost_cache=cache)
        tr_holder.append(len(tr.events))
    t_on = _best_of(3, _traced)

    # counter downsampling: the same traced run with counter_dt=1.0s —
    # per-iteration counters collapse to at most one sample per
    # (track, series) per second, shrinking the event log
    def _traced_dt():
        tr = Tracer("request", counter_dt=1.0)
        simulate_cluster(reqs, cfg, _spec(["mixed"] * 4), tracer=tr,
                         _cost_cache=cache)
        tr_holder.append(len(tr.events))
    t_dt = _best_of(3, _traced_dt)
    rows.append((
        "cluster/tracer-overhead",
        t_off * 1e6,
        f"traced_us={t_on * 1e6:.0f}"
        f";overhead={t_on / t_off - 1.0:+.1%}"
        f";events={tr_holder[0]}"
        f";counter_dt1_us={t_dt * 1e6:.0f}"
        f";counter_dt1_events={tr_holder[-1]}",
    ))

    # chaos overhead: the fault-injection plumbing must be free when no
    # faults are configured — a zero-rate ChaosConfig draws no RNG, adds
    # nothing to the event merge, and stays bit-identical to chaos=None
    t_plain = _best_of(3, lambda: simulate_cluster(
        reqs, cfg, _spec(["mixed"] * 4), _cost_cache=cache))
    chaosless = ClusterSpec(replicas=_spec(["mixed"] * 4).replicas,
                            chaos=ChaosConfig())
    t_chaosless = _best_of(3, lambda: simulate_cluster(
        reqs, cfg, chaosless, _cost_cache=cache))
    chaos_spec = ClusterSpec(
        replicas=_spec(["mixed"] * 4).replicas,
        chaos=ChaosConfig(seed=9, horizon=10.0, crash_rate=0.1,
                          straggler_rate=0.2, link_rate=0.1))
    s = summarize_cluster(simulate_cluster(reqs, cfg, chaos_spec,
                                           _cost_cache=cache), **SLO)
    rows.append((
        "cluster/chaos-overhead",
        t_plain * 1e6,
        f"chaos_off_us={t_chaosless * 1e6:.0f}"
        f";overhead={t_chaosless / t_plain - 1.0:+.1%}"
        f";chaos_on_goodput={s['goodput_frac']:.2f}"
        f";crashes={s['chaos_crashes']}"
        f";lost={s['requests_lost']}",
    ))

    # single-replica cluster must equal repro.sim.simulate exactly
    cost = ServingCostModel(cfg, H100_SXM, ctx_quantum=32)
    direct = simulate(reqs, cost, SchedConfig(slots=8))
    cres = simulate_cluster(reqs, cfg, _spec(["mixed"]))
    exact = all(
        a.first_token == b.first_token and a.finish == b.finish
        for a, b in zip(direct.records, sorted(cres.records, key=lambda r: r.rid)))
    rows.append((
        "cluster/single_replica_parity",
        direct.makespan * 1e6,
        f"exact={exact}",
    ))
    return rows
