"""Self-contained HTML dashboard for one trace: `repro.obs report --html`.

Renders a single HTML page with inline SVG and zero JS/external deps —
shareable as one file, viewable offline, diffable in review:

  * stat tiles — requests/completions, TTFT p99, goodput, alerts fired,
    time in violation;
  * arrival-rate and replica-count timelines (the workload vs the fleet
    that served it);
  * TTFT percentile ribbons (p50/p95/p99 per window, ordinal blue ramp);
  * an alert ribbon aligned to the scaling timeline — pending/firing
    episodes drawn in status colors directly under the replica-count
    chart, so "when did the fleet react" and "when did the monitor know"
    sit on one shared time axis;
  * per-replica utilization strips (windowed busy fraction, sequential
    blue ramp) — present when the trace carries replica-level counters.

Charts degrade gracefully with trace level: a summary-level trace gets
tiles + whatever timelines its events can feed. Colors are defined once
as CSS custom properties (light + dark values; dark mode via
`prefers-color-scheme` and a `data-theme` override) and referenced by
role, so the page adapts without JS. A collapsible data table mirrors
the windowed values for non-visual reading.
"""

from __future__ import annotations

import html as _html
import math

from .quantiles import percentile_summary
from .report import analyze

# layout constants (px)
_W = 920          # drawable width incl. margins
_ML, _MR = 52, 14  # left/right margins (y tick labels live left)
_CH = 120          # timeline chart plot height
_STRIP = 16        # per-replica utilization strip height

# sequential blue ramp (light->dark) for the utilization heat strips;
# shared across modes — magnitude encoding, anchored at "near zero
# recedes toward the surface"
_SEQ = ("#cde2fb", "#9ec5f4", "#6da7ec", "#3987e5", "#256abf", "#184f95",
        "#0d366b")

_STATUS = {"pending": "var(--warning)", "firing": "var(--critical)",
           "resolved": "var(--good)"}

_CSS = """
.viz-root {
  color-scheme: light;
  --page:           #f9f9f7;
  --surface-1:      #fcfcfb;
  --text-primary:   #0b0b0b;
  --text-secondary: #52514e;
  --text-muted:     #898781;
  --gridline:       #e1e0d9;
  --baseline:       #c3c2b7;
  --border:         rgba(11,11,11,0.10);
  --series-1:       #2a78d6;
  --series-2:       #eb6834;
  --p50:            #86b6ef;
  --p95:            #2a78d6;
  --p99:            #104281;
  --good:           #0ca30c;
  --warning:        #fab219;
  --critical:       #d03b3b;
  background: var(--page);
  color: var(--text-primary);
  font-family: system-ui, -apple-system, "Segoe UI", sans-serif;
  margin: 0; padding: 20px;
}
@media (prefers-color-scheme: dark) {
  :root:where(:not([data-theme="light"])) .viz-root {
    color-scheme: dark;
    --page:           #0d0d0d;
    --surface-1:      #1a1a19;
    --text-primary:   #ffffff;
    --text-secondary: #c3c2b7;
    --text-muted:     #898781;
    --gridline:       #2c2c2a;
    --baseline:       #383835;
    --border:         rgba(255,255,255,0.10);
    --series-1:       #3987e5;
    --series-2:       #d95926;
    --p50:            #9ec5f4;
    --p95:            #3987e5;
    --p99:            #184f95;
  }
}
:root[data-theme="dark"] .viz-root {
  color-scheme: dark;
  --page:           #0d0d0d;
  --surface-1:      #1a1a19;
  --text-primary:   #ffffff;
  --text-secondary: #c3c2b7;
  --text-muted:     #898781;
  --gridline:       #2c2c2a;
  --baseline:       #383835;
  --border:         rgba(255,255,255,0.10);
  --series-1:       #3987e5;
  --series-2:       #d95926;
  --p50:            #9ec5f4;
  --p95:            #3987e5;
  --p99:            #184f95;
}
.viz-root h1 { font-size: 18px; font-weight: 600; margin: 0 0 4px; }
.viz-root .sub { color: var(--text-muted); font-size: 12px; margin: 0 0 16px; }
.viz-root .tiles { display: flex; flex-wrap: wrap; gap: 12px; margin: 0 0 18px; }
.viz-root .tile { background: var(--surface-1); border: 1px solid var(--border);
  border-radius: 8px; padding: 10px 14px; min-width: 110px; }
.viz-root .tile .label { color: var(--text-secondary); font-size: 11px; }
.viz-root .tile .value { font-size: 22px; font-weight: 600; }
.viz-root .card { background: var(--surface-1); border: 1px solid var(--border);
  border-radius: 8px; padding: 12px 14px; margin: 0 0 14px; max-width: 960px; }
.viz-root .card h2 { font-size: 13px; font-weight: 600;
  color: var(--text-secondary); margin: 0 0 6px; }
.viz-root .legend { font-size: 11px; color: var(--text-secondary);
  margin: 2px 0 6px; }
.viz-root .legend span.key { display: inline-block; width: 14px; height: 3px;
  border-radius: 2px; margin: 0 4px 2px 10px; vertical-align: middle; }
.viz-root svg text { font-family: inherit; font-size: 10px;
  fill: var(--text-muted); }
.viz-root svg .tick { font-variant-numeric: tabular-nums; }
.viz-root table { border-collapse: collapse; font-size: 11px; }
.viz-root th, .viz-root td { padding: 2px 10px 2px 0; text-align: right;
  font-variant-numeric: tabular-nums; color: var(--text-secondary); }
.viz-root th { color: var(--text-muted); font-weight: 500; }
.viz-root details summary { font-size: 12px; color: var(--text-muted);
  cursor: pointer; }
"""


def _esc(s) -> str:
    return _html.escape(str(s), quote=True)


def _nice_ticks(lo: float, hi: float, n: int = 5) -> list[float]:
    """Clean tick values spanning [lo, hi] (roughly n of them)."""
    if hi <= lo:
        return [lo]
    raw = (hi - lo) / max(n, 1)
    mag = 10.0 ** math.floor(math.log10(raw))
    step = next((m * mag for m in (1.0, 2.0, 2.5, 5.0, 10.0)
                 if m * mag >= raw), 10.0 * mag)
    t = math.ceil(lo / step) * step
    out = []
    while t <= hi + 1e-9 * step:
        out.append(round(t, 10))
        t += step
    return out or [lo]


def _fmt_t(v: float) -> str:
    return f"{v:g}s"


class _Scale:
    def __init__(self, lo, hi, a, b):
        self.lo, self.hi, self.a, self.b = lo, hi, a, b
        self.k = (b - a) / (hi - lo) if hi > lo else 0.0

    def __call__(self, v: float) -> float:
        return self.a + (v - self.lo) * self.k


def _svg_open(height: int) -> str:
    return (f'<svg viewBox="0 0 {_W} {height}" width="100%" '
            f'height="{height}" role="img">')


def _axes(x: _Scale, y: _Scale, h: int, *, y_fmt="{:g}") -> list[str]:
    """Hairline gridlines + tick labels + baseline for one plot area."""
    out = []
    for tv in _nice_ticks(y.lo, y.hi, 4):
        py = y(tv)
        out.append(f'<line x1="{_ML}" y1="{py:.1f}" x2="{_W - _MR}" '
                   f'y2="{py:.1f}" stroke="var(--gridline)" stroke-width="1"/>')
        out.append(f'<text class="tick" x="{_ML - 6}" y="{py + 3:.1f}" '
                   f'text-anchor="end">{y_fmt.format(tv)}</text>')
    for tv in _nice_ticks(x.lo, x.hi, 6):
        px = x(tv)
        out.append(f'<text class="tick" x="{px:.1f}" y="{h - 4}" '
                   f'text-anchor="middle">{_fmt_t(tv)}</text>')
    base = y(y.lo)
    out.append(f'<line x1="{_ML}" y1="{base:.1f}" x2="{_W - _MR}" '
               f'y2="{base:.1f}" stroke="var(--baseline)" stroke-width="1"/>')
    return out


def _path(pts) -> str:
    return "M" + " L".join(f"{x:.2f},{y:.2f}" for x, y in pts)


def _line_chart(title, series, t0, t1, *, unit="", legend=None,
                step=False) -> str:
    """One timeline card. `series` = [(label, css-color, [(t, v), ...])]."""
    pts_all = [v for _, _, pts in series for _, v in pts]
    if not pts_all:
        return ""
    h = _CH + 34
    vmax = max(pts_all)
    vmax = vmax if vmax > 0 else 1.0
    x = _Scale(t0, t1, _ML, _W - _MR)
    y = _Scale(0.0, vmax * 1.08, _CH + 8, 12)
    out = ['<div class="card">', f"<h2>{_esc(title)}</h2>"]
    if legend and len(series) > 1:
        out.append('<div class="legend">' + "".join(
            f'<span class="key" style="background:{c}"></span>{_esc(lbl)}'
            for lbl, c, _ in series) + "</div>")
    out.append(_svg_open(h))
    out.extend(_axes(x, y, h))
    for label, color, pts in series:
        if not pts:
            continue
        if step:
            spts = []
            for i, (t, v) in enumerate(pts):
                if i:
                    spts.append((x(t), y(pts[i - 1][1])))
                spts.append((x(t), y(v)))
            spts.append((x(t1), y(pts[-1][1])))
            d = _path(spts)
        else:
            d = _path([(x(t), y(v)) for t, v in pts])
        # area wash under the line (series hue at ~10% opacity)
        base = y(0.0)
        first_x = x(pts[0][0])
        out.append(f'<path d="{d} L{x(t1) if step else x(pts[-1][0]):.2f},'
                   f'{base:.2f} L{first_x:.2f},{base:.2f} Z" fill="{color}" '
                   f'opacity="0.1" stroke="none"/>')
        out.append(f'<path d="{d}" fill="none" stroke="{color}" '
                   f'stroke-width="2" stroke-linejoin="round" '
                   f'stroke-linecap="round"><title>{_esc(label)}{unit}'
                   f'</title></path>')
    out.append("</svg></div>")
    return "\n".join(out)


def _ribbon_chart(title, wins, t0, t1) -> str:
    """Percentile ribbon: p50/p95/p99 lines over the p50..p99 band.
    `wins` = [(tmid, p50, p95, p99), ...]."""
    if not wins:
        return ""
    h = _CH + 34
    vmax = max(w[3] for w in wins)
    vmax = vmax if vmax > 0 else 1.0
    x = _Scale(t0, t1, _ML, _W - _MR)
    y = _Scale(0.0, vmax * 1.08, _CH + 8, 12)
    keys = [("p50", "var(--p50)"), ("p95", "var(--p95)"), ("p99", "var(--p99)")]
    out = ['<div class="card">', f"<h2>{_esc(title)}</h2>",
           '<div class="legend">' + "".join(
               f'<span class="key" style="background:{c}"></span>{k}'
               for k, c in keys) + "</div>",
           _svg_open(h)]
    out.extend(_axes(x, y, h, y_fmt="{:.3g}"))
    band = ([(x(t), y(p99)) for t, _, _, p99 in wins]
            + [(x(t), y(p50)) for t, p50, _, _ in reversed(wins)])
    out.append(f'<path d="{_path(band)} Z" fill="var(--p95)" opacity="0.1" '
               'stroke="none"/>')
    for i, (k, c) in enumerate(keys):
        pts = [(x(w[0]), y(w[1 + i])) for w in wins]
        out.append(f'<path d="{_path(pts)}" fill="none" stroke="{c}" '
                   f'stroke-width="2" stroke-linejoin="round" '
                   f'stroke-linecap="round"><title>{k}</title></path>')
    out.append("</svg></div>")
    return "\n".join(out)


def _alert_ribbon(alerts, t0, t1, horizon) -> str:
    """Pending/firing episodes per (slo, rule) as status-colored bars on
    the shared time axis (icon+label carried by the row label + title)."""
    if not alerts:
        return ""
    lanes: dict[tuple, list] = {}
    for a in sorted(alerts, key=lambda a: a["t"]):
        lanes.setdefault((a.get("slo", "?"), a.get("rule", "?")), []).append(a)
    row_h, pad = 18, 22
    h = pad + len(lanes) * row_h + 22
    x = _Scale(t0, t1, _ML + 150, _W - _MR)
    out = ['<div class="card">', "<h2>alert ribbon (aligned to the scaling "
           "timeline above)</h2>", _svg_open(h)]
    for tv in _nice_ticks(t0, t1, 6):
        out.append(f'<text class="tick" x="{x(tv):.1f}" y="{h - 4}" '
                   f'text-anchor="middle">{_fmt_t(tv)}</text>')
    for i, ((slo, rule), trans) in enumerate(sorted(lanes.items())):
        yy = pad + i * row_h
        out.append(f'<text x="{_ML}" y="{yy + 9:.1f}">'
                   f'{_esc(rule)} · {_esc(slo)}</text>')
        state, since = None, None
        segs = []
        for a in trans:
            if a["state"] in ("pending", "firing"):
                if state is not None and a["state"] != state:
                    segs.append((since, a["t"], state))
                if state != a["state"]:
                    state, since = a["state"], a["t"]
            elif a["state"] == "resolved" and state is not None:
                segs.append((since, a["t"], state))
                state = None
        if state is not None:
            segs.append((since, horizon, state))
        for s0, s1, st in segs:
            out.append(
                f'<rect x="{x(s0):.1f}" y="{yy + 1}" '
                f'width="{max(x(s1) - x(s0), 2):.1f}" height="{row_h - 6}" '
                f'rx="3" fill="{_STATUS[st]}">'
                f'<title>{_esc(st)}: {s0:.2f}s – {s1:.2f}s</title></rect>')
    out.append("</svg></div>")
    return "\n".join(out)


def _util_strips(util_wins, t0, t1) -> str:
    """Per-replica windowed busy fraction as heat strips (sequential blue
    ramp; lightest = idle)."""
    if not util_wins:
        return ""
    tracks = sorted(util_wins)
    pad = 8
    h = pad + len(tracks) * (_STRIP + 4) + 22
    x = _Scale(t0, t1, _ML + 100, _W - _MR)
    out = ['<div class="card">', "<h2>per-replica utilization "
           "(windowed busy fraction)</h2>", _svg_open(h)]
    for tv in _nice_ticks(t0, t1, 6):
        out.append(f'<text class="tick" x="{x(tv):.1f}" y="{h - 4}" '
                   f'text-anchor="middle">{_fmt_t(tv)}</text>')
    for i, track in enumerate(tracks):
        yy = pad + i * (_STRIP + 4)
        out.append(f'<text x="{_ML}" y="{yy + _STRIP - 4}">{_esc(track)}</text>')
        for (w0, w1, frac) in util_wins[track]:
            c = _SEQ[min(int(max(frac, 0.0) * len(_SEQ)), len(_SEQ) - 1)]
            out.append(
                f'<rect x="{x(w0):.2f}" y="{yy}" '
                f'width="{max(x(w1) - x(w0) - 1, 1):.2f}" height="{_STRIP}" '
                f'fill="{c}"><title>{_esc(track)} {w0:.1f}–{w1:.1f}s: '
                f'{frac:.0%} busy</title></rect>')
    out.append("</svg></div>")
    return "\n".join(out)


def _tile(label, value) -> str:
    return (f'<div class="tile"><div class="label">{_esc(label)}</div>'
            f'<div class="value">{_esc(value)}</div></div>')


def _window_width(span: float) -> float:
    """~48 windows across the span, rounded to a tidy width."""
    if span <= 0:
        return 1.0
    raw = span / 48.0
    mag = 10.0 ** math.floor(math.log10(raw))
    return next((m * mag for m in (1.0, 2.0, 2.5, 5.0, 10.0)
                 if m * mag >= raw), raw)


def render_html(events, meta=None, *, rep=None, title="repro.obs trace") -> str:
    """Render the dashboard page for one event stream; returns the full
    HTML document as a string."""
    meta = dict(meta or {})
    if rep is None:
        rep = analyze(events, meta)
    s = rep["summary"]
    t0 = float(meta.get("t0", 0.0))
    horizon = float(meta.get("horizon", 0.0))
    if horizon <= t0:
        ts = [ev.get("t", ev.get("t1", 0.0)) for ev in events]
        horizon = max(ts) if ts else t0 + 1.0
    w = _window_width(horizon - t0)

    # ---- series extraction (one pass) --------------------------------
    arr_n: dict[int, int] = {}       # window -> arrivals
    ttft_w: dict[int, list] = {}     # window -> ttft samples
    busy: dict[str, list] = {}       # track -> [(t, busy_s)]
    prov: list[tuple[float, float]] = []
    alerts = []
    for ev in events:
        kind, name = ev.get("ev"), ev.get("name")
        if kind == "instant":
            t = ev["t"]
            if name == "request.complete":
                at = ev.get("attrs", {})
                arr_t = t - at["e2e"] if "e2e" in at else t
                arr_n[int((arr_t - t0) // w)] = arr_n.get(int((arr_t - t0) // w), 0) + 1
                if at.get("ttft") is not None:
                    ttft_w.setdefault(int((t - t0) // w), []).append(at["ttft"])
            elif name in ("request.shed", "request.drop"):
                arr_n[int((t - t0) // w)] = arr_n.get(int((t - t0) // w), 0) + 1
            elif name.startswith("alert."):
                alerts.append({"t": t, "state": name.split(".", 1)[1],
                               **dict(ev.get("attrs", ()))})
        elif kind == "span" and name == "provisioned":
            prov.append((ev["t0"], ev["t1"]))
        elif kind == "counter" and name == "busy_s":
            busy.setdefault(ev.get("track", ""), []).append((ev["t"], ev["value"]))

    arr_pts = [(t0 + (k + 0.5) * w, n / w) for k, n in sorted(arr_n.items())]

    # replica count step function from provisioned span edges
    edges = sorted([(s_, +1) for s_, _ in prov] + [(e_, -1) for _, e_ in prov])
    rep_pts, cur = [], 0
    for t, d in edges:
        cur += d
        rep_pts.append((t, cur))

    ribbon = []
    for k, vals in sorted(ttft_w.items()):
        p = percentile_summary(vals, "v", pcts=(50, 95, 99))
        ribbon.append((t0 + (k + 0.5) * w, p["v_p50"], p["v_p95"], p["v_p99"]))

    util_wins: dict[str, list] = {}
    for track, samples in busy.items():
        samples.sort()
        wins, prev_t, prev_b = [], None, None
        for t, b in samples:
            if prev_t is not None and t > prev_t:
                k0, k1 = (prev_t - t0) // w, (t - t0) // w
                frac = (b - prev_b) / (t - prev_t)
                if not wins or wins[-1][0] != k0:
                    wins.append([k0, 0.0, 0.0])
                wins[-1][1] += (b - prev_b)
                wins[-1][2] = max(wins[-1][2], frac)
            prev_t, prev_b = t, b
        util_wins[track] = [(t0 + k * w, t0 + (k + 1) * w, min(acc / w, 1.0))
                            for k, acc, _ in wins]

    # ---- page --------------------------------------------------------
    n = max(s["n_requests"], 1)
    fired = sum(1 for a in rep.get("alerts", ()) if a.get("state") == "firing")
    tiv = sum((x["t"] - x["t0"]) for x in rep.get("slo_windows", ())
              if x.get("ok") is False)
    tiles = [
        _tile("requests", s["n_requests"]),
        _tile("completed", s["n_complete"]),
        _tile("shed + dropped", s["n_shed"] + s["n_drop"]),
        _tile("TTFT p99", f"{s['ttft_p99'] * 1e3:,.0f} ms"),
        _tile("e2e p99", f"{s['e2e_p99'] * 1e3:,.0f} ms"),
        _tile("completion", f"{s['n_complete'] / n:.1%}"),
    ]
    if rep.get("slo_windows") or rep.get("alerts"):
        tiles.append(_tile("alerts fired", fired))
        tiles.append(_tile("time in violation", f"{tiv:g} s"))

    charts = [
        _line_chart("arrival rate (req/s, windowed)",
                    [("arrivals", "var(--series-1)", arr_pts)], t0, horizon),
        _ribbon_chart("TTFT percentiles per window (s)", ribbon, t0, horizon),
        _line_chart("provisioned replicas",
                    [("replicas", "var(--series-2)", rep_pts)], t0, horizon,
                    step=True),
        _alert_ribbon(alerts, t0, horizon, horizon),
        _util_strips(util_wins, t0, horizon),
    ]

    # table view: the windowed ribbon + arrival numbers, for non-visual
    # reading of the same data the charts draw
    table = ["<details><summary>data table (windowed)</summary>",
             "<table><tr><th>t0 (s)</th><th>arrivals/s</th><th>ttft p50</th>"
             "<th>ttft p95</th><th>ttft p99</th></tr>"]
    rib_by_k = {int((t - t0) / w - 0.5): (a, b, c) for t, a, b, c in ribbon}
    for k in sorted(set(arr_n) | set(rib_by_k)):
        r = rib_by_k.get(k)
        table.append(
            f"<tr><td>{t0 + k * w:g}</td>"
            f"<td>{arr_n.get(k, 0) / w:.2f}</td>"
            + ("".join(f"<td>{v:.4f}</td>" for v in r) if r
               else "<td>-</td><td>-</td><td>-</td>") + "</tr>")
    table.append("</table></details>")

    sub = (f"schema {_esc(meta.get('schema', '?'))} · "
           f"mode {_esc(meta.get('mode', '?'))} · "
           f"horizon {horizon:g}s · {len(events)} events")
    doc = ["<!DOCTYPE html>", '<html lang="en"><head>',
           '<meta charset="utf-8"/>',
           '<meta name="viewport" content="width=device-width, '
           'initial-scale=1"/>',
           f"<title>{_esc(title)}</title>",
           f"<style>{_CSS}</style>", "</head>",
           '<body class="viz-root">',
           f"<h1>{_esc(title)}</h1>", f'<p class="sub">{sub}</p>',
           '<div class="tiles">', *tiles, "</div>",
           *[c for c in charts if c],
           '<div class="card">', *table, "</div>",
           "</body></html>"]
    return "\n".join(doc)
