"""Offline trace analysis: `python -m repro.obs report <trace.jsonl>`.

Rebuilds serving metrics from a JSONL trace alone — no simulator state —
which is the calibration contract from ROADMAP item 5: anything that
emits this schema (the sim today, a real engine later) gets the same
analysis. Three sections:

  * latency summary — TTFT/TPOT/E2E percentiles fed through
    `StreamingQuantiles` (one pass over terminal events, no record list),
    reproducing `summarize_cluster`'s exact p50/p99 at trace sizes where
    the tail reservoir covers the ranks;
  * top-k slowest requests with their per-phase time breakdown
    (queued/prefill/handoff/decode_wait/decode), the "why was this one
    slow" view;
  * per-replica utilization (busy seconds vs provisioned extent, from
    the `busy_s` counter and `provisioned` spans) and the
    scaling-decision timeline (every `autoscale.decision` with the policy
    inputs that drove it, plus `scale.up`/`scale.down`/retire outcomes).
"""

from __future__ import annotations

from .export import read_jsonl
from .quantiles import StreamingQuantiles, percentile_summary
from .tracer import TERMINALS, validate_trace

PHASES = ("queued", "prefill", "handoff", "decode_wait", "decode")


def analyze(events, meta=None, *, topk: int = 10) -> dict:
    """Digest an event stream into the report's data model (plain dicts,
    render-agnostic — tests consume this directly)."""
    meta = dict(meta or {})
    phase_by_rid: dict[object, dict[str, float]] = {}
    span_bounds: dict[object, list[float]] = {}
    prefill_track: dict[object, str] = {}
    requests: list[dict] = []
    busy: dict[str, float] = {}
    provisioned: dict[str, float] = {}
    completed_per_track: dict[str, int] = {}
    decisions: list[dict] = []
    scale_ops: list[dict] = []
    counts: dict[str, int] = {}
    slo_windows: list[dict] = []
    alerts: list[dict] = []
    anomalies: list[dict] = []

    for ev in events:
        kind = ev.get("ev")
        name = ev.get("name")
        if kind == "span":
            rid = ev.get("rid")
            if rid is not None and name in PHASES:
                dur = ev["t1"] - ev["t0"]
                phase_by_rid.setdefault(rid, {})
                phase_by_rid[rid][name] = phase_by_rid[rid].get(name, 0.0) + dur
                b = span_bounds.setdefault(rid, [ev["t0"], ev["t1"]])
                b[0] = min(b[0], ev["t0"])
                b[1] = max(b[1], ev["t1"])
                if name == "prefill":
                    prefill_track[rid] = ev.get("track", "")
            elif name == "provisioned":
                track = ev.get("track", "")
                provisioned[track] = provisioned.get(track, 0.0) + (ev["t1"] - ev["t0"])
        elif kind == "instant":
            if name in TERMINALS:
                counts[name] = counts.get(name, 0) + 1
                rid = ev.get("rid")
                at = dict(ev.get("attrs", ()))
                row = {"rid": rid, "t": ev["t"], "outcome": name.split(".")[1],
                       "track": ev.get("track", ""),
                       "ttft": at.get("ttft"), "tpot": at.get("tpot"),
                       "e2e": at.get("e2e")}
                requests.append(row)
                if name == "request.complete":
                    tr = ev.get("track", "")
                    completed_per_track[tr] = completed_per_track.get(tr, 0) + 1
            elif name == "autoscale.decision":
                decisions.append({"t": ev["t"], **dict(ev.get("attrs", ()))})
            elif name in ("scale.up", "scale.down", "scale.cancel",
                          "replica.retired"):
                scale_ops.append({"t": ev["t"], "op": name,
                                  "track": ev.get("track", ""),
                                  **dict(ev.get("attrs", ()))})
            elif name == "slo.window":
                slo_windows.append({"t": ev["t"], **dict(ev.get("attrs", ()))})
            elif name.startswith("alert."):
                alerts.append({"t": ev["t"], "state": name.split(".", 1)[1],
                               **dict(ev.get("attrs", ()))})
            elif name.startswith("anomaly."):
                anomalies.append({"t": ev["t"], "series": name.split(".", 1)[1],
                                  "track": ev.get("track", ""),
                                  **dict(ev.get("attrs", ()))})
        elif kind == "counter" and name == "busy_s":
            # cumulative counter: the last sample is the total
            tr = ev.get("track", "")
            busy[tr] = max(busy.get(tr, 0.0), ev["value"])

    # phase spans may arrive before OR after a rid's terminal (live
    # terminals precede the post-run span emission), so resolve phases
    # only after the full pass
    for row in requests:
        rid = row["rid"]
        row["phases"] = phase_by_rid.get(rid, {})
        if row["e2e"] is None and rid in span_bounds:
            row["e2e"] = span_bounds[rid][1] - span_bounds[rid][0]
        if row["ttft"] is None and "prefill" in row["phases"]:
            row["ttft"] = (row["phases"].get("queued", 0.0)
                           + row["phases"]["prefill"])

    summary: dict = {"n_requests": len(requests)}
    for key in ("ttft", "tpot", "e2e"):
        sq = StreamingQuantiles()
        for r in requests:
            if r["outcome"] == "complete" and r[key] is not None:
                sq.add(r[key])
        summary.update(sq.summary(key))
        summary[f"{key}_n"] = sq.n
    for term in TERMINALS:
        summary[term.replace("request.", "n_")] = counts.get(term, 0)

    phase_stats: dict[str, dict] = {}
    for ph in PHASES:
        vals = [d[ph] for d in phase_by_rid.values() if ph in d]
        if vals:
            phase_stats[ph] = percentile_summary(vals, ph)
            phase_stats[ph][f"{ph}_n"] = len(vals)

    done = [r for r in requests if r["outcome"] == "complete" and r["e2e"] is not None]
    # e2e ties break by rid so --topk output is stable across runs/platforms
    slowest = sorted(done, key=lambda r: (-r["e2e"], r["rid"]))[:topk]

    tracks = sorted(set(provisioned) | set(busy) | set(completed_per_track))
    util = []
    for tr in tracks:
        span = provisioned.get(tr, 0.0)
        b = busy.get(tr, 0.0)
        util.append({"track": tr or "cluster", "provisioned_s": span,
                     "busy_s": b, "util": (b / span) if span > 0 else 0.0,
                     "completed": completed_per_track.get(tr, 0)})

    return {"meta": meta, "summary": summary, "slowest": slowest,
            "phase_stats": phase_stats, "replicas": util,
            "decisions": decisions, "scale_ops": scale_ops,
            "slo_windows": slo_windows, "alerts": alerts,
            "anomalies": anomalies, "problems": validate_trace(events)}


def _fmt_ms(x) -> str:
    return f"{x * 1e3:9.2f}" if x is not None else "        -"


def render(rep: dict) -> str:
    """Render an `analyze()` result as the human-readable report text."""
    out: list[str] = []
    meta, s = rep["meta"], rep["summary"]
    head = f"trace: schema={meta.get('schema', '?')}"
    if "horizon" in meta:
        head += f"  origin={meta.get('t0', 0.0):g}s  horizon={meta['horizon']:g}s"
    out.append(head)
    out.append(f"requests: {s['n_requests']}  completed={s['n_complete']}  "
               f"shed={s['n_shed']}  dropped={s['n_drop']}")
    out.append("")
    out.append("latency (ms)        p50       p95       p99     p99.9      mean")
    for key in ("ttft", "tpot", "e2e"):
        row = "  ".join(_fmt_ms(s[f"{key}_p{p:g}"]) for p in (50, 95, 99, 99.9))
        out.append(f"  {key:<12}{row}  {_fmt_ms(s[f'{key}_mean'])}")
    if rep["slowest"]:
        out.append("")
        out.append(f"top {len(rep['slowest'])} slowest requests (s):")
        out.append("  rid        e2e     ttft   queued  prefill  handoff  "
                   "dec_wait   decode  replica")
        for r in rep["slowest"]:
            ph = r["phases"]
            out.append(
                f"  {str(r['rid']):<6}{r['e2e']:>8.3f} {r['ttft'] or 0.0:>8.3f}"
                f" {ph.get('queued', 0.0):>8.3f} {ph.get('prefill', 0.0):>8.3f}"
                f" {ph.get('handoff', 0.0):>8.3f} {ph.get('decode_wait', 0.0):>9.3f}"
                f" {ph.get('decode', 0.0):>8.3f}  {r['track']}")
    if rep["replicas"]:
        out.append("")
        out.append("per-replica utilization:")
        out.append("  replica           prov_s    busy_s   util  completed")
        for u in rep["replicas"]:
            out.append(f"  {u['track']:<16}{u['provisioned_s']:>8.2f}"
                       f"  {u['busy_s']:>8.2f}  {u['util']:>5.1%}"
                       f"  {u['completed']:>9d}")
    if rep["decisions"] or rep["scale_ops"]:
        out.append("")
        out.append("scaling timeline:")
        timeline = ([{"kind": "decision", **d} for d in rep["decisions"]]
                    + [{"kind": "op", **o} for o in rep["scale_ops"]])
        timeline.sort(key=lambda e: e["t"])
        for e in timeline:
            if e["kind"] == "op":
                out.append(f"  t={e['t']:>8.2f}s  {e['op']:<10} "
                           f"pool={e.get('pool', '-')} {e.get('track', '')}")
            else:
                inputs = "  ".join(
                    f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in e.items()
                    if k not in ("kind", "t", "policy", "pool"))
                out.append(f"  t={e['t']:>8.2f}s  decision   "
                           f"pool={e.get('pool', '-')} "
                           f"policy={e.get('policy', '?')}  {inputs}")
    if rep.get("slo_windows"):
        out.append("")
        out.append("SLO compliance (tumbling windows):")
        by_slo: dict[str, list[dict]] = {}
        for w in rep["slo_windows"]:
            by_slo.setdefault(w.get("slo", "?"), []).append(w)
        for slo, wins in by_slo.items():
            judged = [w for w in wins if w.get("ok") is not None]
            viol = [w for w in judged if w.get("ok") is False]
            tail = wins[-1]
            out.append(
                f"  {slo:<24} windows={len(judged)} violated={len(viol)}  "
                f"budget_remaining={tail.get('budget_remaining', 0.0):.1%}")
    if rep.get("alerts"):
        out.append("")
        out.append("alert timeline:")
        for a in rep["alerts"]:
            out.append(
                f"  t={a['t']:>8.2f}s  {a['state']:<9} {a.get('rule', '?'):<10}"
                f" slo={a.get('slo', '?')}  burn={a.get('burn_long', 0.0):.1f}"
                f"/{a.get('burn_short', 0.0):.1f}"
                f" (>= {a.get('burn_threshold', 0.0):g})")
    if rep.get("anomalies"):
        out.append("")
        out.append(f"anomalies ({len(rep['anomalies'])}):")
        for a in rep["anomalies"][:20]:
            out.append(f"  t={a['t']:>8.2f}s  {a['series']:<10} "
                       f"{a.get('track', '')}  value={a.get('value', 0.0):.3g} "
                       f"z={a.get('z', 0.0):+.1f}")
    if rep["problems"]:
        out.append("")
        out.append(f"TRACE PROBLEMS ({len(rep['problems'])}):")
        for p in rep["problems"][:20]:
            out.append(f"  ! {p}")
    return "\n".join(out)


def report_file(path, *, topk: int = 10) -> str:
    """Load a JSONL trace and render its report (the CLI entry point)."""
    meta, events = read_jsonl(path)
    return render(analyze(events, meta, topk=topk))
