"""repro.obs — observability for the serving simulators.

Event tracing (`Tracer`, spans/instants/counters with trace levels),
streaming percentiles (`StreamingQuantiles`, P² body + exact tails),
windowed aggregation, trace exporters (Chrome trace-event JSON for
Perfetto, JSONL event log, windowed CSV), and an offline report analyzer
(`python -m repro.obs report trace.jsonl`).

See docs/observability.md for the event schema and workflow.
"""

from .quantiles import (PCTS, P2Quantile, StreamingQuantiles,
                        WindowedAggregator, pct_key, percentile_summary)
from .tracer import (LEVELS, NULL_TRACER, STRUCTURAL_SPANS, TERMINALS,
                     NullTracer, Tracer, make_tracer, validate_trace)
from .export import (csv_rows, read_jsonl, to_chrome, write_chrome,
                     write_csv, write_jsonl, write_trace)
from .report import analyze, render, report_file

__all__ = [
    "PCTS", "P2Quantile", "StreamingQuantiles", "WindowedAggregator",
    "pct_key", "percentile_summary",
    "LEVELS", "NULL_TRACER", "STRUCTURAL_SPANS", "TERMINALS",
    "NullTracer", "Tracer", "make_tracer", "validate_trace",
    "csv_rows", "read_jsonl", "to_chrome", "write_chrome", "write_csv",
    "write_jsonl", "write_trace",
    "analyze", "render", "report_file",
]
