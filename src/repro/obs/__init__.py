"""repro.obs — observability for the serving simulators.

Event tracing (`Tracer`, spans/instants/counters with trace levels and a
sink API for online subscribers), streaming percentiles
(`StreamingQuantiles`, P² body + exact tails), windowed aggregation,
trace exporters (Chrome trace-event JSON for Perfetto, JSONL event log,
windowed CSV), a live SLO monitor (`SLOMonitor`: burn-rate alerts +
anomaly detection at sim time), an offline report analyzer
(`python -m repro.obs report trace.jsonl`, `--html` dashboard), and a
trace-to-trace diff / CI gate (`python -m repro.obs diff a b`).

See docs/observability.md for the event schema and workflow.
"""

from .quantiles import (PCTS, P2Quantile, StreamingQuantiles,
                        WindowedAggregator, pct_key, percentile_summary)
from .tracer import (LEVELS, NULL_TRACER, STRUCTURAL_SPANS, TERMINALS,
                     NullTracer, Tracer, make_tracer, validate_trace)
from .export import (csv_rows, read_jsonl, to_chrome, write_chrome,
                     write_csv, write_jsonl, write_trace)
from .report import analyze, render, report_file
from .monitor import (SLO, AnomalyConfig, BurnRateRule, SLOMonitor,
                      default_rules, make_slos, replay)
from .diff import (DEFAULT_THRESHOLDS, diff_traces, parse_fail_on,
                   regressions, render_diff)
from .dashboard import render_html

__all__ = [
    "PCTS", "P2Quantile", "StreamingQuantiles", "WindowedAggregator",
    "pct_key", "percentile_summary",
    "LEVELS", "NULL_TRACER", "STRUCTURAL_SPANS", "TERMINALS",
    "NullTracer", "Tracer", "make_tracer", "validate_trace",
    "csv_rows", "read_jsonl", "to_chrome", "write_chrome", "write_csv",
    "write_jsonl", "write_trace",
    "analyze", "render", "report_file",
    "SLO", "AnomalyConfig", "BurnRateRule", "SLOMonitor", "default_rules",
    "make_slos", "replay",
    "DEFAULT_THRESHOLDS", "diff_traces", "parse_fail_on", "regressions",
    "render_diff",
    "render_html",
]
