"""Trace-to-trace comparison: `python -m repro.obs diff a.jsonl b.jsonl`.

Compares two recorded traces along the axes a capacity review actually
argues about — latency percentiles, completion/shed mix, per-phase time,
event mix, the scaling timeline, and the alert timeline — and turns the
comparison into a CI gate: `--fail-on metric=tolerance` overrides the
default thresholds, and any metric of trace B that regresses past its
tolerance relative to trace A makes the CLI exit non-zero. Checked-in
golden baseline traces plus this gate give trace-level regression
coverage that summary-metric assertions can't (a schedule change that
leaves p50 alone still shifts the event mix or the scaling timeline).

Thresholds are one-sided — only the *worse* direction trips them
(latency up, completion down, shed up, more alerts firing) — and the
defaults are deliberately loose so that two runs differing only in
workload seed pass while a genuinely degraded run (half the replica cap,
an overload burst) fails; tighten per-metric via `--fail-on` where a
baseline is stable enough to afford it.
"""

from __future__ import annotations

from collections import Counter

from .report import PHASES, analyze

# one-sided tolerances: relative for latency (fraction of A's value the
# B value may exceed it by), absolute for fractions/counts
DEFAULT_THRESHOLDS = {
    "ttft_p50": 0.75, "ttft_p99": 0.75, "tpot_p99": 0.75,
    "e2e_p50": 0.75, "e2e_p99": 0.75,
    "completion_frac": 0.05, "shed_frac": 0.05, "drop_frac": 0.05,
}

# metrics where bigger is better (regression = decrease); everything else
# regresses upward
_HIGHER_BETTER = ("completion_frac",)
# absolute-delta metrics (fractions and counts); the rest compare relative
_ABSOLUTE = ("completion_frac", "shed_frac", "drop_frac", "alerts_firing",
             "time_in_violation", "anomalies", "scale_ops")


def _metrics(rep: dict) -> dict:
    """Flatten an `analyze()` result into the comparable scalar metrics."""
    s = rep["summary"]
    n = max(s["n_requests"], 1)
    m = {k: s[k] for k in s if k.startswith(("ttft_", "tpot_", "e2e_"))
         and not k.endswith("_n")}
    m["completion_frac"] = s["n_complete"] / n
    m["shed_frac"] = s["n_shed"] / n
    m["drop_frac"] = s["n_drop"] / n
    m["scale_ops"] = len(rep["scale_ops"])
    m["alerts_firing"] = sum(1 for a in rep["alerts"] if a["state"] == "firing")
    m["anomalies"] = len(rep["anomalies"])
    m["time_in_violation"] = sum(
        (w["t"] - w["t0"]) for w in rep["slo_windows"] if w.get("ok") is False)
    return m


def _event_mix(events) -> Counter:
    return Counter((ev.get("ev"), ev.get("name")) for ev in events)


def diff_traces(a: tuple, b: tuple) -> dict:
    """Compare two `(meta, events)` traces; returns the diff data model
    (plain dicts — `render_diff` draws it, `regressions` gates on it)."""
    meta_a, events_a = a
    meta_b, events_b = b
    ra, rb = analyze(events_a, meta_a), analyze(events_b, meta_b)
    ma, mb = _metrics(ra), _metrics(rb)

    summary = {}
    for k in ma:
        va, vb = ma[k], mb.get(k, 0.0)
        summary[k] = {"a": va, "b": vb, "delta": vb - va,
                      "rel": (vb - va) / va if va else None}

    phases = {}
    for ph in PHASES:
        pa, pb = ra["phase_stats"].get(ph), rb["phase_stats"].get(ph)
        if pa is None and pb is None:
            continue
        row = {}
        for p in (50, 99):
            va = pa[f"{ph}_p{p:g}"] if pa else 0.0
            vb = pb[f"{ph}_p{p:g}"] if pb else 0.0
            row[f"p{p:g}"] = {"a": va, "b": vb, "delta": vb - va}
        phases[ph] = row

    mix_a, mix_b = _event_mix(events_a), _event_mix(events_b)
    event_mix = {f"{kind}:{name}": {"a": mix_a.get((kind, name), 0),
                                    "b": mix_b.get((kind, name), 0)}
                 for kind, name in sorted(set(mix_a) | set(mix_b))
                 if mix_a.get((kind, name)) != mix_b.get((kind, name))}

    ops_a = [(o["op"], o["t"]) for o in ra["scale_ops"]]
    ops_b = [(o["op"], o["t"]) for o in rb["scale_ops"]]
    first_div = None
    for i, (oa, ob) in enumerate(zip(ops_a, ops_b)):
        if oa[0] != ob[0]:
            first_div = {"index": i, "a": oa, "b": ob}
            break
    if first_div is None and len(ops_a) != len(ops_b):
        i = min(len(ops_a), len(ops_b))
        first_div = {"index": i,
                     "a": ops_a[i] if i < len(ops_a) else None,
                     "b": ops_b[i] if i < len(ops_b) else None}
    scaling = {
        "ops": {op: {"a": Counter(o for o, _ in ops_a)[op],
                     "b": Counter(o for o, _ in ops_b)[op]}
                for op in sorted({o for o, _ in ops_a} | {o for o, _ in ops_b})},
        "replicas": {"a": len(ra["replicas"]), "b": len(rb["replicas"])},
        "first_divergence": first_div,
    }

    def first_firing(rep):
        ts = [a["t"] for a in rep["alerts"] if a["state"] == "firing"]
        return min(ts) if ts else None
    alerts = {
        "counts": {st: {"a": sum(1 for x in ra["alerts"] if x["state"] == st),
                        "b": sum(1 for x in rb["alerts"] if x["state"] == st)}
                   for st in ("pending", "firing", "resolved")},
        "first_firing": {"a": first_firing(ra), "b": first_firing(rb)},
    }

    return {"summary": summary, "phases": phases, "event_mix": event_mix,
            "scaling": scaling, "alerts": alerts,
            "meta": {"a": meta_a, "b": meta_b}}


def regressions(diff: dict, thresholds: dict | None = None) -> list[str]:
    """One string per metric of trace B that regressed past its tolerance
    (empty == B is no worse than A). Only metrics named in `thresholds`
    are gated; unknown metric names raise (a misspelled `--fail-on` must
    not silently gate nothing)."""
    thresholds = DEFAULT_THRESHOLDS if thresholds is None else thresholds
    out = []
    for metric, tol in thresholds.items():
        row = diff["summary"].get(metric)
        if row is None:
            raise KeyError(f"unknown diff metric {metric!r}; known: "
                           f"{sorted(diff['summary'])}")
        va, vb = row["a"], row["b"]
        if metric in _HIGHER_BETTER:
            worse = va - vb
        else:
            worse = vb - va
        if metric not in _ABSOLUTE:
            if va <= 0:
                continue  # no baseline signal to compare against
            worse /= va
        if worse > tol:
            kind = "abs" if metric in _ABSOLUTE else "rel"
            out.append(f"{metric}: a={va:.6g} b={vb:.6g} "
                       f"({kind} change {worse:+.3g} > tolerance {tol:g})")
    return out


def parse_fail_on(spec: str | None) -> dict:
    """`--fail-on "ttft_p99=0.2,completion_frac=0.01"` -> thresholds dict
    merged over the defaults (None/'' -> defaults unchanged)."""
    out = dict(DEFAULT_THRESHOLDS)
    if not spec:
        return out
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"--fail-on entry {part!r} is not metric=tolerance")
        k, v = part.split("=", 1)
        out[k.strip()] = float(v)
    return out


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def render_diff(diff: dict, problems: list[str] | None = None) -> str:
    """Human-readable diff text (the CLI's stdout)."""
    out = ["trace diff (a -> b)", ""]
    out.append("summary metrics:")
    out.append(f"  {'metric':<18}{'a':>12}{'b':>12}{'delta':>12}{'rel':>9}")
    for k, row in diff["summary"].items():
        rel = f"{row['rel']:+.1%}" if row["rel"] is not None else "-"
        out.append(f"  {k:<18}{_fmt(row['a']):>12}{_fmt(row['b']):>12}"
                   f"{_fmt(row['delta']):>12}{rel:>9}")
    if diff["phases"]:
        out.append("")
        out.append("per-phase percentiles (s):")
        out.append(f"  {'phase':<12}{'p50 a':>10}{'p50 b':>10}"
                   f"{'p99 a':>10}{'p99 b':>10}")
        for ph, row in diff["phases"].items():
            out.append(f"  {ph:<12}{row['p50']['a']:>10.4f}{row['p50']['b']:>10.4f}"
                       f"{row['p99']['a']:>10.4f}{row['p99']['b']:>10.4f}")
    if diff["event_mix"]:
        out.append("")
        out.append(f"event-mix deltas ({len(diff['event_mix'])} kinds differ):")
        for key, row in list(diff["event_mix"].items())[:25]:
            out.append(f"  {key:<32}{row['a']:>8} -> {row['b']}")
    sc = diff["scaling"]
    out.append("")
    out.append(f"scaling: replicas {sc['replicas']['a']} -> "
               f"{sc['replicas']['b']}")
    for op, row in sc["ops"].items():
        out.append(f"  {op:<16}{row['a']:>8} -> {row['b']}")
    fd = sc["first_divergence"]
    if fd is not None:
        out.append(f"  first divergence at op #{fd['index']}: "
                   f"a={fd['a']} b={fd['b']}")
    al = diff["alerts"]
    if any(r["a"] or r["b"] for r in al["counts"].values()):
        out.append("")
        out.append("alerts:")
        for st, row in al["counts"].items():
            out.append(f"  {st:<10}{row['a']:>8} -> {row['b']}")
        ff = al["first_firing"]
        out.append(f"  first firing: a={_fmt(ff['a'])}s b={_fmt(ff['b'])}s")
    if problems is not None:
        out.append("")
        if problems:
            out.append(f"REGRESSIONS ({len(problems)}):")
            out.extend(f"  ! {p}" for p in problems)
        else:
            out.append("no regressions: b is within tolerance of a")
    return "\n".join(out)
