"""Online SLO monitoring: burn-rate alerts and anomaly detection at sim time.

`SLOMonitor` is a `Tracer` sink (see `tracer.Tracer.add_sink`): it consumes
trace events the moment the engine emits them — terminal instants feed SLO
compliance, counter samples feed anomaly detectors — with no second pass
over the event list. Everything it computes is emitted back into the trace
(`slo.window`, `alert.*`, `anomaly.*` instants), so Perfetto, the report,
and the dashboard all show *when the system knew* it was in trouble.

SLO model (SRE-style, in simulated time)
----------------------------------------
An `SLO` reduces to a bad-event predicate plus an error budget:

  * latency objective `metric_p{pct} <= threshold` — a completed request
    is *bad* when its metric exceeds the threshold; the implied budget is
    `1 - pct/100` (p99 permits 1% bad).
  * `goodput >= threshold` — a request is *bad* when it is shed/dropped
    or misses any configured latency objective; budget is `1 - threshold`.

Compliance is evaluated over tumbling windows of width `SLO.window`
(`StreamingQuantiles` per window — exact percentiles at these sizes), and
the **burn rate** over rolling windows is `bad_frac / budget`: burn 1.0
spends the budget exactly at the sustainable rate, burn N spends it N×
too fast.

Each SLO carries multi-window multi-burn-rate alert rules (`BurnRateRule`;
defaults scale the classic fast/slow pair to the SLO window `W`):

  * `fast_burn` — long `W`,  short `W/6`, burn >= 14.4
  * `slow_burn` — long `4W`, short `W/2`, burn >= 6

A rule trips when *both* its windows exceed the burn threshold (the short
window makes alerts resolve quickly once the incident ends), walking the
lifecycle `pending -> firing -> resolved`, each transition emitted as an
`alert.{state}` instant carrying the rule, both window burn values, and
the budget remaining. Rolling-window bad counts ride a bucketed
`WindowedAggregator` (bucket = `W / buckets_per_window`); rules are
evaluated at bucket boundaries, so detection latency is one bucket.

Anomaly detection
-----------------
Per (replica, series) EWMA z-score detectors over the counter timelines —
queue depth, KV occupancy, and busy fraction (derived from the cumulative
`busy_s` counter) — flag straggler/overload onset as `anomaly.{series}`
instants, with hysteresis (an episode ends only when |z| falls below half
the onset threshold) so a flapping series emits one onset, not hundreds.

Determinism: the monitor is pure arithmetic over the event stream, so a
seeded run produces an identical alert timeline, and `replay()` over the
recorded trace reproduces the online result exactly (the online/offline
agreement test pins this).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from .quantiles import StreamingQuantiles, WindowedAggregator
from .tracer import TERMINALS


@dataclass(frozen=True)
class SLO:
    """One objective: a latency percentile gate (`metric` in
    ttft/tpot/e2e with `pct`) or `metric="goodput"` (`pct` ignored).
    `threshold` is seconds for latency metrics, a fraction in (0, 1] for
    goodput. `window` is the tumbling compliance window in simulated
    seconds."""

    metric: str
    threshold: float
    pct: float | None = 99.0
    window: float = 30.0

    def __post_init__(self):
        if self.metric not in ("ttft", "tpot", "e2e", "goodput"):
            raise ValueError(f"unknown SLO metric {self.metric!r}")
        if self.window <= 0:
            raise ValueError("SLO window must be positive")
        if self.metric == "goodput" and not 0.0 < self.threshold <= 1.0:
            raise ValueError("goodput threshold must be a fraction in (0, 1]")

    @property
    def name(self) -> str:
        if self.metric == "goodput":
            return f"goodput>={self.threshold:g}"
        return f"{self.metric}_p{self.pct:g}<={self.threshold:g}s"

    @property
    def budget_frac(self) -> float:
        """Tolerable bad-event fraction implied by the objective."""
        if self.metric == "goodput":
            frac = 1.0 - self.threshold
        else:
            frac = 1.0 - (self.pct if self.pct is not None else 99.0) / 100.0
        return max(frac, 1e-6)


@dataclass(frozen=True)
class BurnRateRule:
    """One multi-window burn-rate alert rule: trips when the error-budget
    burn rate over BOTH the long and short rolling windows is >= `burn`;
    stays pending for `for_s` simulated seconds before firing."""

    name: str
    long_window: float
    short_window: float
    burn: float
    for_s: float = 0.0


def default_rules(window: float) -> tuple[BurnRateRule, ...]:
    """The SRE fast/slow burn pair scaled to the SLO window."""
    return (
        BurnRateRule("fast_burn", long_window=window, short_window=window / 6.0,
                     burn=14.4),
        BurnRateRule("slow_burn", long_window=4.0 * window, short_window=window / 2.0,
                     burn=6.0),
    )


@dataclass(frozen=True)
class AnomalyConfig:
    """EWMA z-score anomaly detection over counter series. `alpha` is the
    EWMA weight, `z` the onset threshold (episodes end below `z/2`),
    `warmup` the samples a series must accumulate before it may flag."""

    series: tuple[str, ...] = ("queue", "kv_used", "busy_frac")
    alpha: float = 0.08
    z: float = 4.0
    warmup: int = 24


def make_slos(*, slo_ttft: float | None = None, slo_goodput: float | None = None,
              window: float = 30.0, pct: float = 99.0) -> tuple[SLO, ...]:
    """CLI helper: the `--slo-ttft/--slo-goodput/--slo-window` flags ->
    SLO tuple (empty when neither objective is given)."""
    slos = []
    if slo_ttft is not None:
        slos.append(SLO("ttft", slo_ttft, pct=pct, window=window))
    if slo_goodput is not None:
        slos.append(SLO("goodput", slo_goodput, pct=None, window=window))
    return tuple(slos)


class _Ewma:
    """Online EWMA mean/variance with z-score hysteresis for one
    (track, series) pair."""

    __slots__ = ("mean", "var", "n", "active")

    def __init__(self):
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.active = False

    def update(self, x: float, alpha: float, z_on: float, warmup: int):
        """Feed one sample; returns the z-score if this sample *starts* an
        anomalous episode, else None."""
        onset = None
        if self.n >= warmup:
            std = math.sqrt(self.var)
            if std > 1e-9:
                z = (x - self.mean) / std
                if not self.active and abs(z) >= z_on:
                    self.active = True
                    onset = z
                elif self.active and abs(z) < z_on / 2.0:
                    self.active = False
        self.n += 1
        d = x - self.mean
        self.mean += alpha * d
        self.var = (1.0 - alpha) * (self.var + alpha * d * d)
        return onset


class _SloState:
    """Per-SLO mutable state: tumbling compliance windows, bucketed bad
    counts for the rolling burn windows, cumulative budget accounting,
    and per-rule alert state machines."""

    def __init__(self, slo: SLO, rules, buckets_per_window: int):
        self.slo = slo
        self.rules = tuple(rules)
        self.dt = slo.window / buckets_per_window  # burn-bucket width
        self.buckets = WindowedAggregator(self.dt)
        self.open: dict[int, dict] = {}  # window idx -> {"sq"/"n"/"bad"}
        self.next_close: int | None = None  # lowest unclosed window idx
        self.last_bucket: int | None = None  # last rule-eval bucket
        self.n = 0  # cumulative eligible events
        self.bad = 0
        self.windows: list[dict] = []  # closed-window rows
        self.time_in_violation = 0.0
        # rule name -> [state, pending_since]
        self.alert: dict[str, list] = {r.name: ["ok", 0.0] for r in self.rules}

    @property
    def budget_consumed(self) -> float:
        if self.n == 0:
            return 0.0
        return (self.bad / self.n) / self.slo.budget_frac


class SLOMonitor:
    """Online SLO/burn-rate/anomaly monitor; attach with
    `tracer.add_sink(monitor)` (or pass `monitor=` to the engines, which
    do the wiring). After the run, `finish(horizon)` closes open windows
    and `result()` returns the summary dict `summarize_cluster` and the
    report consume."""

    def __init__(self, slos, *, rules=None, anomaly: AnomalyConfig | None = AnomalyConfig(),
                 buckets_per_window: int = 24):
        self.slos = tuple(slos)
        self._states = [
            _SloState(s, default_rules(s.window) if rules is None else rules,
                      buckets_per_window)
            for s in self.slos]
        self._latency_gates = [s for s in self.slos if s.metric != "goodput"]
        self.anomaly_cfg = anomaly
        self._detectors: dict[tuple[str, str], _Ewma] = {}
        self._busy_prev: dict[str, tuple[float, float]] = {}  # track -> (t, busy_s)
        self.alerts: list[dict] = []  # lifecycle transitions, time-ordered
        self.anomalies: list[dict] = []
        self.alerts_fired = 0
        self._tracer = None
        self._finished = False

    # -- tracer sink protocol -------------------------------------------
    def bind(self, tracer) -> None:
        self._tracer = tracer

    def _instant(self, name, t, track="", **attrs) -> None:
        tr = self._tracer
        if tr is not None and tr.wants("summary"):
            tr.instant(name, t, track=track, **attrs)

    def on_event(self, ev: dict) -> None:
        kind = ev.get("ev")
        if kind == "instant":
            name = ev["name"]
            if name in TERMINALS:
                self._on_terminal(name, ev)
        elif kind == "counter":
            cfg = self.anomaly_cfg
            if cfg is not None:
                self._on_counter(ev, cfg)

    # -- SLO compliance --------------------------------------------------
    def _on_terminal(self, name: str, ev: dict) -> None:
        t = ev["t"]
        attrs = ev.get("attrs", {})
        completed = name == "request.complete"
        good_latency = True
        if completed:
            for s in self._latency_gates:
                v = attrs.get(s.metric)
                if v is not None and v > s.threshold:
                    good_latency = False
                    break
        for st in self._states:
            slo = st.slo
            if slo.metric == "goodput":
                bad = (not completed) or (not good_latency)
                self._feed(st, t, None, bad)
            elif completed:
                v = attrs.get(slo.metric)
                if v is not None:
                    self._feed(st, t, float(v), v > slo.threshold)

    def _feed(self, st: _SloState, t: float, value: float | None, bad: bool) -> None:
        slo = st.slo
        k = int(math.floor(t / slo.window))
        if st.next_close is not None and k < st.next_close:
            win = None  # late event for an already-closed window: count it
            # toward the cumulative budget below, but never re-open
        else:
            win = st.open.get(k)
            if win is None:
                win = st.open[k] = {"n": 0, "bad": 0,
                                    "sq": None if value is None else
                                    StreamingQuantiles(pcts=(slo.pct,))}
                if st.next_close is None:
                    st.next_close = k
        if win is not None:
            win["n"] += 1
            win["bad"] += int(bad)
            if value is not None and win["sq"] is not None:
                win["sq"].add(value)
        st.n += 1
        st.bad += int(bad)
        st.buckets.add(t, "bad", 1.0 if bad else 0.0)
        self._advance(st, t)

    def _advance(self, st: _SloState, clock: float) -> None:
        """Close every tumbling window that ended before `clock` and run
        the alert rules at each crossed burn-bucket boundary."""
        slo = st.slo
        if st.next_close is not None:
            while (st.next_close + 1) * slo.window <= clock:
                self._close_window(st, st.next_close)
                st.next_close += 1
        b = int(math.floor(clock / st.dt))
        if st.last_bucket is None:
            st.last_bucket = b - 1
        while st.last_bucket < b:
            st.last_bucket += 1
            self._eval_rules(st, st.last_bucket * st.dt)

    def _close_window(self, st: _SloState, k: int) -> None:
        slo = st.slo
        t0, t1 = k * slo.window, (k + 1) * slo.window
        win = st.open.pop(k, None)
        n = win["n"] if win else 0
        bad = win["bad"] if win else 0
        if n == 0:
            value, ok, burn = None, None, 0.0
        else:
            if slo.metric == "goodput":
                value = 1.0 - bad / n
                ok = value >= slo.threshold
            else:
                value = win["sq"].quantile(slo.pct)
                ok = value <= slo.threshold
            burn = (bad / n) / slo.budget_frac
        if ok is False:
            st.time_in_violation += slo.window
        row = {"slo": slo.name, "t0": t0, "t1": t1, "n": n, "bad": bad,
               "value": value, "ok": ok, "burn": burn,
               "budget_remaining": 1.0 - st.budget_consumed}
        st.windows.append(row)
        self._instant("slo.window", t1, slo=slo.name, t0=t0, n=n, bad=bad,
                      value=value, threshold=slo.threshold, ok=ok, burn=burn,
                      budget_remaining=row["budget_remaining"])

    def _burn(self, st: _SloState, t: float, window: float) -> float:
        r = st.buckets.range_stats("bad", t - window, t)
        if r["n"] == 0:
            return 0.0
        return (r["sum"] / r["n"]) / st.slo.budget_frac

    def _eval_rules(self, st: _SloState, t: float) -> None:
        for rule in st.rules:
            burn_long = self._burn(st, t, rule.long_window)
            burn_short = self._burn(st, t, rule.short_window)
            cond = burn_long >= rule.burn and burn_short >= rule.burn
            state = st.alert[rule.name]
            if cond:
                if state[0] == "ok":
                    state[0], state[1] = "pending", t
                    self._transition(st, rule, "pending", t, burn_long, burn_short)
                if state[0] == "pending" and t - state[1] >= rule.for_s:
                    state[0] = "firing"
                    self.alerts_fired += 1
                    self._transition(st, rule, "firing", t, burn_long, burn_short)
            else:
                if state[0] == "firing":
                    self._transition(st, rule, "resolved", t, burn_long, burn_short)
                state[0] = "ok"

    def _transition(self, st, rule, to_state, t, burn_long, burn_short) -> None:
        rec = {"t": t, "state": to_state, "rule": rule.name, "slo": st.slo.name,
               "burn_long": burn_long, "burn_short": burn_short,
               "burn_threshold": rule.burn,
               "budget_remaining": 1.0 - st.budget_consumed}
        self.alerts.append(rec)
        self._instant(f"alert.{to_state}", t, rule=rule.name, slo=st.slo.name,
                      burn_long=burn_long, burn_short=burn_short,
                      burn_threshold=rule.burn,
                      budget_remaining=rec["budget_remaining"])

    # -- anomaly detection ----------------------------------------------
    def _on_counter(self, ev: dict, cfg: AnomalyConfig) -> None:
        name, track, t = ev["name"], ev.get("track", ""), ev["t"]
        if name == "busy_s" and "busy_frac" in cfg.series:
            prev = self._busy_prev.get(track)
            self._busy_prev[track] = (t, ev["value"])
            if prev is None or t <= prev[0]:
                return
            name, value = "busy_frac", (ev["value"] - prev[1]) / (t - prev[0])
        elif name in cfg.series:
            value = ev["value"]
        else:
            return
        det = self._detectors.get((track, name))
        if det is None:
            det = self._detectors[(track, name)] = _Ewma()
        z = det.update(value, cfg.alpha, cfg.z, cfg.warmup)
        if z is not None:
            self.anomalies.append({"t": t, "track": track, "series": name,
                                   "value": value, "z": z})
            self._instant(f"anomaly.{name}", t, track=track, value=value,
                          z=z, mean=det.mean)

    # -- end of run ------------------------------------------------------
    def finish(self, horizon: float) -> None:
        """Close remaining windows and run a final rule evaluation at the
        run horizon. Idempotent."""
        if self._finished:
            return
        self._finished = True
        for st in self._states:
            if st.next_close is not None:
                while st.open and st.next_close <= max(st.open):
                    self._close_window(st, st.next_close)
                    st.next_close += 1
            self._advance(st, horizon)

    def result(self) -> dict:
        """Summary dict: per-SLO compliance, budget accounting, alert and
        anomaly timelines, and the roll-up columns `summarize_cluster`
        surfaces (`time_in_violation` is the union across SLOs)."""
        slo_rows = []
        violated: list[tuple[float, float]] = []
        for st in self._states:
            slo = st.slo
            slo_rows.append({
                "name": slo.name, "metric": slo.metric, "pct": slo.pct,
                "threshold": slo.threshold, "window": slo.window,
                "budget_frac": slo.budget_frac,
                "n": st.n, "bad": st.bad,
                "bad_frac": st.bad / st.n if st.n else 0.0,
                "budget_consumed": st.budget_consumed,
                "budget_remaining": 1.0 - st.budget_consumed,
                "time_in_violation": st.time_in_violation,
                "windows": list(st.windows),
            })
            violated.extend((w["t0"], w["t1"]) for w in st.windows
                            if w["ok"] is False)
        return {
            "slos": slo_rows,
            "alerts": list(self.alerts),
            "alerts_fired": self.alerts_fired,
            "anomalies": list(self.anomalies),
            "time_in_violation": _union_len(violated),
            "budget_burn": max((r["budget_consumed"] for r in slo_rows),
                               default=0.0),
        }


def _union_len(intervals) -> float:
    """Total length of the union of (t0, t1) intervals."""
    total, end = 0.0, -math.inf
    for t0, t1 in sorted(intervals):
        if t1 <= end:
            continue
        total += t1 - max(t0, end)
        end = t1
    return total


def replay(meta: dict, events, slos, *, rules=None,
           anomaly: AnomalyConfig | None = AnomalyConfig(),
           buckets_per_window: int = 24) -> dict:
    """Offline recompute: run an `SLOMonitor` over a recorded trace and
    return its `result()`. Events are sorted by time first (recorded
    traces may interleave post-run span emission), which is exactly the
    order the online monitor saw, so `replay` on a monitored run's own
    trace reproduces the online result bit-for-bit — the online/offline
    agreement contract."""
    mon = SLOMonitor(slos, rules=rules, anomaly=anomaly,
                     buckets_per_window=buckets_per_window)
    horizon = meta.get("horizon", 0.0)
    for ev in sorted(events, key=_ev_time):
        mon.on_event(ev)
        horizon = max(horizon, _ev_time(ev))
    mon.finish(horizon)
    return mon.result()


def _ev_time(ev: dict) -> float:
    t = ev.get("t")
    if t is None:
        t = ev.get("t1", ev.get("t0", 0.0))
    return t
