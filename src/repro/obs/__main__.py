"""CLI: offline trace analysis.

    python -m repro.obs report trace.jsonl [--topk 10] [--validate-only]

Consumes the JSONL trace format written by `--trace out.jsonl` on
`python -m repro.sim` / `python -m repro.cluster` (schema repro.obs/1)
and prints the latency summary, slowest-request breakdown, per-replica
utilization, and scaling-decision timeline. `--validate-only` runs just
the structural validator and exits non-zero on problems (the CI gate).
"""

from __future__ import annotations

import argparse
import sys

from .export import read_jsonl
from .report import analyze, render
from .tracer import validate_trace


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Offline analysis of repro.obs JSONL traces.")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rep = sub.add_parser(
        "report", help="summarize a JSONL trace: latency percentiles, "
        "slowest requests, per-replica utilization, scaling timeline")
    rep.add_argument("trace", help="path to a .jsonl trace written by --trace")
    rep.add_argument("--topk", type=int, default=10,
                     help="how many slowest requests to show (default 10)")
    rep.add_argument("--validate-only", action="store_true",
                     help="only run the structural trace validator; exit "
                     "non-zero if the trace is malformed")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    meta, events = read_jsonl(args.trace)
    if args.validate_only:
        problems = validate_trace(events)
        if problems:
            for p in problems:
                print(f"! {p}", file=sys.stderr)
            return 1
        print(f"ok: {len(events)} events, schema {meta.get('schema', '?')}")
        return 0
    print(render(analyze(events, meta, topk=args.topk)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
