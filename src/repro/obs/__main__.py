"""CLI: offline trace analysis.

    python -m repro.obs report trace.jsonl [--topk 10] [--validate-only]
                                           [--html out.html]
                                           [--slo-ttft S --slo-goodput F
                                            --slo-window W]
    python -m repro.obs diff a.jsonl b.jsonl [--fail-on metric=tol,...]

Consumes the JSONL trace format written by `--trace out.jsonl` on
`python -m repro.sim` / `python -m repro.cluster` (schema repro.obs/1).

`report` prints the latency summary, slowest-request breakdown,
per-replica utilization, scaling timeline, and (when the trace was
monitored) the SLO-compliance and alert sections; `--slo-ttft` /
`--slo-goodput` replay the online monitor offline over the recorded
trace (the online-vs-offline agreement path); `--html` additionally
renders the self-contained dashboard page. `--validate-only` runs just
the structural validator and exits non-zero on problems (a CI gate).

`diff` compares two traces (percentiles, event mix, scaling and alert
timelines) and exits non-zero when trace B regresses past the `--fail-on`
thresholds — the trace-regression CI gate against golden baselines.
"""

from __future__ import annotations

import argparse
import sys

from .diff import diff_traces, parse_fail_on, regressions, render_diff
from .export import read_jsonl
from .monitor import make_slos, replay
from .report import analyze, render
from .tracer import validate_trace


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Offline analysis of repro.obs JSONL traces.")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rep = sub.add_parser(
        "report", help="summarize a JSONL trace: latency percentiles, "
        "slowest requests, per-replica utilization, scaling timeline, "
        "SLO/alert sections, optional HTML dashboard")
    rep.add_argument("trace", help="path to a .jsonl trace written by --trace")
    rep.add_argument("--topk", type=int, default=10,
                     help="how many slowest requests to show (default 10)")
    rep.add_argument("--validate-only", action="store_true",
                     help="only run the structural trace validator; exit "
                     "non-zero if the trace is malformed")
    rep.add_argument("--html", metavar="PATH", default=None,
                     help="also render the self-contained HTML dashboard "
                     "(inline SVG, no JS) to PATH")
    rep.add_argument("--slo-ttft", type=float, default=None,
                     help="replay the SLO monitor offline: TTFT p99 "
                     "objective in seconds")
    rep.add_argument("--slo-goodput", type=float, default=None,
                     help="offline-replay goodput objective as a fraction "
                     "(e.g. 0.99)")
    rep.add_argument("--slo-window", type=float, default=30.0,
                     help="SLO compliance window in simulated seconds "
                     "(default 30)")
    dif = sub.add_parser(
        "diff", help="compare two JSONL traces (latency, phases, event "
        "mix, scaling + alert timelines); non-zero exit on regression")
    dif.add_argument("trace_a", help="baseline trace (.jsonl)")
    dif.add_argument("trace_b", help="candidate trace (.jsonl)")
    dif.add_argument("--fail-on", default=None, metavar="SPEC",
                     help="comma-separated metric=tolerance overrides "
                     "merged over the defaults, e.g. "
                     "'ttft_p99=0.2,completion_frac=0.01'")
    return ap


def _cmd_report(args) -> int:
    meta, events = read_jsonl(args.trace)
    if args.validate_only:
        problems = validate_trace(events)
        if problems:
            for p in problems:
                print(f"! {p}", file=sys.stderr)
            return 1
        print(f"ok: {len(events)} events, schema {meta.get('schema', '?')}")
        return 0
    rep = analyze(events, meta, topk=args.topk)
    print(render(rep))
    slos = make_slos(slo_ttft=args.slo_ttft, slo_goodput=args.slo_goodput,
                     window=args.slo_window)
    if slos:
        res = replay(meta, events, slos)
        print()
        print("offline SLO replay:")
        for s in res["slos"]:
            print(f"  {s['name']:<24} n={s['n']} bad={s['bad']} "
                  f"budget_consumed={s['budget_consumed']:.1%} "
                  f"time_in_violation={s['time_in_violation']:g}s")
        print(f"  alerts fired={res['alerts_fired']}  "
              f"time_in_violation={res['time_in_violation']:g}s")
    if args.html:
        from .dashboard import render_html
        html = render_html(events, meta, rep=rep)
        with open(args.html, "w", encoding="utf-8") as fh:
            fh.write(html)
        print(f"\nwrote dashboard: {args.html}")
    return 0


def _cmd_diff(args) -> int:
    a = read_jsonl(args.trace_a)
    b = read_jsonl(args.trace_b)
    diff = diff_traces(a, b)
    problems = regressions(diff, parse_fail_on(args.fail_on))
    print(render_diff(diff, problems))
    return 1 if problems else 0


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.cmd == "diff":
        return _cmd_diff(args)
    return _cmd_report(args)


if __name__ == "__main__":
    raise SystemExit(main())
