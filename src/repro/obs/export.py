"""Trace exporters: Chrome trace-event JSON, JSONL event log, windowed CSV.

Three renderings of the same `Tracer.events` stream:

  * `to_chrome` / `write_chrome` — Chrome trace-event format, loadable in
    Perfetto (https://ui.perfetto.dev) or `chrome://tracing`. Each track
    (cluster, one per replica) becomes a named thread; structural spans
    (`provisioned`/`warmup`/`drain`) are complete ("X") events, which
    Chrome requires to nest per thread; request lifecycle spans overlap
    freely on a replica so they are exported as async ("b"/"e") events
    keyed by request id; counters are "C" events and render as area
    charts. Timestamps are microseconds, matching the format spec.
  * `write_jsonl` / `read_jsonl` — the raw event dicts, one JSON object
    per line, preceded by a meta header line carrying the schema version
    and the run's time origin/horizon. This is the schema-stable format
    the offline analyzer (`python -m repro.obs report`) consumes and the
    golden trace test pins.
  * `write_csv` — counter timelines windowed through
    `WindowedAggregator` into long-format rows
    (`t0,t1,track,series,n,mean,min,max,last`), ready for pandas or a
    spreadsheet; empty windows between data emit explicit `n=0` gap rows
    so the time axis is contiguous.

`write_trace` picks the format from the path suffix: `.jsonl` → JSONL,
`.csv` → CSV, anything else → Chrome JSON.
"""

from __future__ import annotations

import csv
import io
import json
import math

from .quantiles import WindowedAggregator
from .tracer import STRUCTURAL_SPANS

_US = 1e6  # trace times are seconds; Chrome wants microseconds


def _track_ids(events) -> dict[str, int]:
    """Stable track -> tid map: cluster-scope track '' is tid 0, the rest
    sorted by name (replica names sort r0, r1, ... within a pool)."""
    tracks = {ev.get("track", "") for ev in events if ev.get("ev") != "meta"}
    tracks.add("")
    ordered = [""] + sorted(t for t in tracks if t)
    return {t: i for i, t in enumerate(ordered)}

def to_chrome(events, meta=None) -> dict:
    """Render an event stream as a Chrome trace-event JSON object
    (`{"traceEvents": [...], "displayTimeUnit": "ms", ...}`)."""
    tids = _track_ids(events)
    out = [{"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
            "args": {"name": "repro serving sim"}}]
    for track, tid in tids.items():
        out.append({"ph": "M", "name": "thread_name", "pid": 0, "tid": tid,
                    "args": {"name": track or "cluster"}})
        out.append({"ph": "M", "name": "thread_sort_index", "pid": 0,
                    "tid": tid, "args": {"sort_index": tid}})
    for ev in events:
        kind = ev.get("ev")
        if kind == "meta":
            continue
        track = ev.get("track", "")
        tid = tids[track]
        name = ev["name"]
        args = dict(ev.get("attrs", ()))
        if "rid" in ev:
            args["rid"] = ev["rid"]
        if kind == "span":
            ts, dur = ev["t0"] * _US, max(ev["t1"] - ev["t0"], 0.0) * _US
            if "rid" in ev and name not in STRUCTURAL_SPANS:
                # request phases overlap within a track -> async events,
                # grouped per request by id
                common = {"cat": "request", "id": str(ev["rid"]), "pid": 0,
                          "tid": tid}
                out.append({"ph": "b", "name": name, "ts": ts, "args": args,
                            **common})
                out.append({"ph": "e", "name": name, "ts": ts + dur, **common})
            else:
                out.append({"ph": "X", "name": name, "ts": ts, "dur": dur,
                            "pid": 0, "tid": tid, "args": args})
        elif kind == "instant":
            out.append({"ph": "i", "name": name, "ts": ev["t"] * _US, "s": "t",
                        "pid": 0, "tid": tid, "args": args})
        elif kind == "counter":
            # one counter chart per (track, series); Chrome keys counters
            # by (pid, name), so the track is folded into the name
            cname = f"{track or 'cluster'}/{name}"
            out.append({"ph": "C", "name": cname, "ts": ev["t"] * _US,
                        "pid": 0, "tid": tid, "args": {name: ev["value"]}})
    trace = {"traceEvents": out, "displayTimeUnit": "ms"}
    if meta:
        trace["otherData"] = dict(meta)
    return trace


def write_chrome(events, path, meta=None) -> None:
    with open(path, "w") as f:
        json.dump(to_chrome(events, meta), f)
        f.write("\n")


def write_jsonl(events, path, meta=None) -> None:
    """Raw event log: a meta header line, then one event JSON per line."""
    with open(path, "w") as f:
        head = {"ev": "meta", "schema": "repro.obs/1"}
        if meta:
            head.update(meta)
            head["schema"] = "repro.obs/1"
        f.write(json.dumps(head) + "\n")
        for ev in events:
            if ev.get("ev") != "meta":
                f.write(json.dumps(ev) + "\n")


def read_jsonl(path) -> tuple[dict, list[dict]]:
    """Load a JSONL trace -> (meta, events). Tolerates a missing header
    (returns an empty meta dict)."""
    meta: dict = {}
    events: list[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            ev = json.loads(line)
            if ev.get("ev") == "meta":
                meta = {k: v for k, v in ev.items() if k != "ev"}
            else:
                events.append(ev)
    return meta, events


def csv_rows(events, window: float = 1.0) -> list[dict]:
    """Window the counter timelines: long-format rows
    `t0,t1,track,series,n,mean,min,max,last`, sorted by (t0, track,
    series). Span/instant events are not windowed — use JSONL for those."""
    aggs: dict[str, WindowedAggregator] = {}
    for ev in events:
        if ev.get("ev") != "counter":
            continue
        track = ev.get("track", "")
        agg = aggs.get(track)
        if agg is None:
            agg = aggs[track] = WindowedAggregator(window)
        agg.add(ev["t"], ev["name"], ev["value"])
    rows: list[dict] = []
    for track, agg in aggs.items():
        wrows = agg.rows(fill_gaps=True)
        # a gap row (empty window) still emits one n=0 row per series the
        # track carries, so the exported time axis is contiguous
        all_series = sorted({k.rsplit("_", 1)[0] for wrow in wrows
                             for k in wrow if k not in ("t0", "t1", "gap")})
        for wrow in wrows:
            if wrow.get("gap"):
                for s in all_series:
                    rows.append({"t0": wrow["t0"], "t1": wrow["t1"],
                                 "track": track or "cluster", "series": s,
                                 "n": 0, "mean": "", "min": "", "max": "",
                                 "last": ""})
                continue
            series = sorted({k.rsplit("_", 1)[0] for k in wrow
                             if k not in ("t0", "t1")})
            for s in series:
                rows.append({"t0": wrow["t0"], "t1": wrow["t1"],
                             "track": track or "cluster", "series": s,
                             "n": wrow[f"{s}_n"], "mean": wrow[f"{s}_mean"],
                             "min": wrow[f"{s}_min"], "max": wrow[f"{s}_max"],
                             "last": wrow[f"{s}_last"]})
    rows.sort(key=lambda r: (r["t0"], r["track"], r["series"]))
    return rows


def write_csv(events, path, window: float = 1.0) -> None:
    rows = csv_rows(events, window)
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=["t0", "t1", "track", "series", "n",
                                        "mean", "min", "max", "last"])
    w.writeheader()
    for r in rows:
        w.writerow(r)
    with open(path, "w") as f:
        f.write(buf.getvalue())


def write_trace(events, path, meta=None, *, window: float = 1.0) -> str:
    """Export `events` to `path`, picking the format from the suffix
    (.jsonl -> JSONL log, .csv -> windowed CSV, else Chrome JSON).
    Returns the format written ('jsonl' | 'csv' | 'chrome')."""
    p = str(path)
    if p.endswith(".jsonl"):
        write_jsonl(events, p, meta)
        return "jsonl"
    if p.endswith(".csv"):
        if meta and meta.get("horizon"):
            # aim for ~100 windows across the horizon, rounded to a tidy width
            span = float(meta["horizon"]) - float(meta.get("t0", 0.0))
            if span > 0:
                window = max(10.0 ** math.floor(math.log10(max(span / 100.0, 1e-9))), 1e-9)
        write_csv(events, p, window)
        return "csv"
    write_chrome(events, p, meta)
    return "chrome"
