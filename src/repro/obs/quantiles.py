"""Streaming percentiles and windowed aggregation.

The fleet-scale engine (ROADMAP item 3) targets 10^6-request traces;
retaining a per-request record list just to compute p99.9 at the end is
exactly the memory pattern that caps it. This module provides the
bounded-memory alternatives, and `repro.sim.metrics.summarize_records`
routes its exact percentiles through the same convention so the two can
never drift apart on key names or interpolation:

  * `percentile_summary` — the ONE exact percentile helper (numpy linear
    interpolation, the `np.percentile` default) every summary dict uses,
    with the shared `PCTS` convention (p50/p95/p99/p99.9).
  * `P2Quantile` — the classic P-squared online estimator (Jain & Chlamtac
    1985): one quantile in O(1) memory, five markers adjusted by a
    piecewise-parabolic fit.
  * `StreamingQuantiles` — the production estimator: P² for the body plus
    an EXACT top-k tail reservoir, so the tail quantiles a serving SLO
    actually gates on (p99, p99.9) are computed exactly (identical to
    `np.percentile`) whenever the tail rank falls inside the reservoir —
    for the default `tail_k=1024` that is p99 up to ~100k samples and
    p99.9 up to ~1M — and fall back to P² beyond it. Memory is O(tail_k)
    regardless of stream length.
  * `WindowedAggregator` — fixed-width time windows accumulating
    count/mean/min/max per named series: the rolling aggregate behind the
    CSV time-series exporter (`repro.obs.export.write_csv`).

All estimators are deterministic functions of the insertion order, so
seeded simulations produce identical summaries run-to-run.
"""

from __future__ import annotations

import heapq
import math

import numpy as np

# the percentile convention every summary dict shares (keys are rendered
# with %g, so 99.9 -> "p99.9" and 50 -> "p50")
PCTS = (50, 95, 99, 99.9)


def pct_key(name: str, p: float) -> str:
    """The summary-dict key for percentile `p` of series `name`
    (`pct_key("ttft", 99.9) == "ttft_p99.9"`)."""
    return f"{name}_p{p:g}"


def percentile_summary(xs, name: str, pcts=PCTS) -> dict:
    """Exact percentile + mean dict for one series: `{name}_p{p}` for each
    `p` in `pcts` plus `{name}_mean` (all 0.0 for an empty series). This is
    the single exact-percentile code path — `summarize_records` and every
    other summary dict route through it so interpolation and key naming
    cannot drift."""
    xs = np.asarray(xs, dtype=float)
    out = {}
    for p in pcts:
        out[pct_key(name, p)] = float(np.percentile(xs, p)) if len(xs) else 0.0
    out[f"{name}_mean"] = float(xs.mean()) if len(xs) else 0.0
    return out


class P2Quantile:
    """P-squared single-quantile estimator (Jain & Chlamtac 1985).

    Five markers track (min, q/2-ish, q, (1+q)/2-ish, max); marker heights
    are adjusted toward their desired positions with a piecewise-parabolic
    (P²) fit, falling back to linear when the parabola would break
    monotonicity. O(1) memory, O(1) per observation."""

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError("quantile must be in (0, 1)")
        self.q = float(q)
        self.n = 0
        self._h: list[float] = []  # marker heights (first 5 obs, then fixed)
        self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]  # actual marker positions
        self._want = [1.0, 1.0 + 2 * q, 1.0 + 4 * q, 3.0 + 2 * q, 5.0]
        self._dwant = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0]

    def add(self, x: float) -> None:
        self.n += 1
        h = self._h
        if self.n <= 5:
            h.append(float(x))
            h.sort()
            return
        # locate the cell k: h[k] <= x < h[k+1], clamping the extremes
        if x < h[0]:
            h[0] = float(x)
            k = 0
        elif x >= h[4]:
            h[4] = float(x)
            k = 3
        else:
            k = 0
            while k < 3 and x >= h[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            self._pos[i] += 1.0
        for i in range(5):
            self._want[i] += self._dwant[i]
        # adjust the three interior markers toward their desired positions
        for i in (1, 2, 3):
            d = self._want[i] - self._pos[i]
            pos, prev, nxt = self._pos[i], self._pos[i - 1], self._pos[i + 1]
            if (d >= 1.0 and nxt - pos > 1.0) or (d <= -1.0 and prev - pos < -1.0):
                s = 1.0 if d >= 1.0 else -1.0
                hp = h[i] + s / (nxt - prev) * (
                    (pos - prev + s) * (h[i + 1] - h[i]) / (nxt - pos)
                    + (nxt - pos - s) * (h[i] - h[i - 1]) / (pos - prev))
                if h[i - 1] < hp < h[i + 1]:
                    h[i] = hp  # parabolic
                else:  # linear fallback preserves monotonicity
                    j = i + int(s)
                    h[i] += s * (h[j] - h[i]) / (self._pos[j] - pos)
                self._pos[i] += s

    def value(self) -> float:
        """Current estimate (exact for n <= 5; 0.0 before any data)."""
        if not self._h:
            return 0.0
        if self.n <= 5:
            # exact: numpy linear interpolation over the sorted sample
            return float(np.percentile(self._h, self.q * 100.0))
        return self._h[2]


class StreamingQuantiles:
    """Multi-quantile streaming summary with exact tails.

    `add()` feeds one observation; `quantile(p)` / `summary(name)` read.
    Internally each requested percentile runs a `P2Quantile`, and a
    min-heap reservoir retains the largest `tail_k` observations. A read
    whose rank lands inside the reservoir (every quantile when
    `n <= tail_k`; otherwise the top `tail_k` ranks — p99.9 up to
    n ~= 1000 * tail_k) is answered EXACTLY with numpy's linear
    interpolation, so small-to-medium traces reproduce `np.percentile`
    bit-for-bit and only genuinely huge streams pay the P² approximation,
    and then only for body quantiles the tail can't cover."""

    def __init__(self, pcts=PCTS, tail_k: int = 1024):
        if tail_k < 2:
            raise ValueError("tail_k must be >= 2")
        self.pcts = tuple(pcts)
        self.tail_k = int(tail_k)
        self._p2 = {p: P2Quantile(p / 100.0) for p in self.pcts}
        self._tail: list[float] = []  # min-heap of the largest tail_k
        self.n = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def add(self, x: float) -> None:
        x = float(x)
        self.n += 1
        self.total += x
        self.min = min(self.min, x)
        self.max = max(self.max, x)
        for est in self._p2.values():
            est.add(x)
        if len(self._tail) < self.tail_k:
            heapq.heappush(self._tail, x)
        elif x > self._tail[0]:
            heapq.heapreplace(self._tail, x)

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def quantile(self, p: float) -> float:
        """Percentile `p` in [0, 100]: exact when its rank falls inside
        the tail reservoir, P² estimate otherwise."""
        if self.n == 0:
            return 0.0
        pos = (p / 100.0) * (self.n - 1)  # numpy 'linear' rank
        first_tail_rank = self.n - len(self._tail)
        if pos >= first_tail_rank or self.n <= len(self._tail):
            tail = sorted(self._tail)
            i = pos - first_tail_rank
            lo = max(int(math.floor(i)), 0)
            hi = min(int(math.ceil(i)), len(tail) - 1)
            return tail[lo] + (i - lo) * (tail[hi] - tail[lo])
        est = self._p2.get(p)
        v = est.value() if est is not None else P2Quantile(p / 100.0).value()
        return min(max(v, self.min), self.max)

    def summary(self, name: str) -> dict:
        """Same key shape as `percentile_summary` (and exactly equal to it
        whenever every requested rank is tail-resident)."""
        out = {pct_key(name, p): self.quantile(p) for p in self.pcts}
        out[f"{name}_mean"] = self.mean
        return out


class WindowedAggregator:
    """Fixed-width time-window aggregation of named series.

    `add(t, name, value)` buckets the observation into window
    `floor(t / dt)`; `rows()` returns one dict per non-empty window
    (sorted by time) with `t0`/`t1` bounds and, per series seen in it,
    `{name}_n/_mean/_min/_max/_last`. This is the rolling aggregate the
    CSV time-series exporter renders, and the bounded-memory substitute
    for keeping raw counter timelines at fleet scale.

    Out-of-order timestamps are safe: observations land in the window
    their own `t` selects (buckets are dict-keyed, never "current"), and
    `_last` tracks the latest-`t` observation rather than the latest
    `add()` call. `rows(fill_gaps=True)` additionally emits a bare
    `{"t0", "t1", "gap": True}` row for every empty window between the
    first and last non-empty one, so downstream time axes (CSV export,
    the dashboard) stay contiguous."""

    def __init__(self, dt: float):
        if dt <= 0:
            raise ValueError("window width dt must be positive")
        self.dt = float(dt)
        # (window index, series) -> [n, sum, min, max, last_t, last_value]
        self._w: dict[tuple[int, str], list] = {}

    def add(self, t: float, name: str, value: float) -> None:
        key = (int(math.floor(t / self.dt)), name)
        cell = self._w.get(key)
        v = float(value)
        if cell is None:
            self._w[key] = [1, v, v, v, t, v]
            return
        cell[0] += 1
        cell[1] += v
        cell[2] = min(cell[2], v)
        cell[3] = max(cell[3], v)
        if t >= cell[4]:
            cell[4], cell[5] = t, v

    def rows(self, *, fill_gaps: bool = False) -> list[dict]:
        wins: dict[int, dict] = {}
        for (w, name), (n, s, lo, hi, _, last) in sorted(self._w.items()):
            row = wins.setdefault(w, {"t0": w * self.dt, "t1": (w + 1) * self.dt})
            row[f"{name}_n"] = n
            row[f"{name}_mean"] = s / n
            row[f"{name}_min"] = lo
            row[f"{name}_max"] = hi
            row[f"{name}_last"] = last
        if not wins:
            return []
        if fill_gaps:
            lo, hi = min(wins), max(wins)
            return [wins.get(w, {"t0": w * self.dt, "t1": (w + 1) * self.dt,
                                 "gap": True})
                    for w in range(lo, hi + 1)]
        return [wins[w] for w in sorted(wins)]

    def range_stats(self, name: str, t0: float, t1: float) -> dict:
        """Count and sum of series `name` over the buckets overlapping
        `[t0, t1)`. Bucket-granular: partial buckets at the edges are
        counted whole, so callers that align `t0`/`t1` to multiples of
        `dt` (the SLO monitor's burn-rate windows) get exact totals."""
        k0 = int(math.floor(t0 / self.dt))
        k1 = int(math.ceil(t1 / self.dt))
        n, s = 0, 0.0
        for k in range(k0, k1):
            cell = self._w.get((k, name))
            if cell is not None:
                n += cell[0]
                s += cell[1]
        return {"n": n, "sum": s}
