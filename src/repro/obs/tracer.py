"""Event tracing for the serving simulators.

A `Tracer` collects three kinds of events while a simulation runs:

  * **spans** — named intervals `[t0, t1]` on a track (a replica, a pool,
    or the cluster), optionally tied to a request id: request lifecycle
    phases (`queued`, `prefill`, `handoff`, `decode_wait`, `decode`) and
    replica structural phases (`provisioned`, `warmup`, `drain`).
  * **instants** — point events with attributes: dispatch/shed/retry
    decisions (with the router's explanation), autoscaler decisions (with
    the policy's inputs), preemptions, cache invalidations, and fault
    injection (`replica.crash`, `chaos.straggler`, `chaos.link_degrade`,
    `chaos.node_failure`, `request.stall` — see `repro.cluster.chaos`).
  * **counters** — numeric timelines sampled as the sim steps: queue
    depth, live batch slots, KV occupancy, cache-resident bytes,
    cumulative busy seconds.

Everything is observational: a traced run and an untraced run execute the
identical schedule (tested by `tests/test_obs.py`), so tracing can never
perturb the pinned-autoscaler bit-parity contract.

A `Tracer` can also stream its events to **sinks** (`add_sink`): objects
with an `on_event(ev)` method that consume each event the moment it is
emitted, at sim time — the substrate the online SLO monitor
(`repro.obs.monitor`) is built on, with no second pass over the event
list. A sink may emit events of its own back into the tracer (alert
instants, window evaluations); those are appended to the stream but not
re-dispatched to sinks, so sink cascades cannot recurse. Construct with
`keep_events=False` to run sinks without retaining the event list (live
monitoring without recording), and `counter_dt=x` to downsample counter
timelines to at most one sample per `x` simulated seconds per
(track, series) — the knob that keeps replica-level traces of long
diurnal runs bounded.

Trace levels are ordered `off < summary < replica < request`; call sites
gate on `tracer.wants(level)` (usually hoisted into a local boolean) so
the disabled path costs one attribute read. The module-level `NULL_TRACER`
is the shared no-op default — engines take `tracer=None` and substitute
it, so hot loops never branch on `None`.

Event dict schema (`repro.obs/1`, stable — golden-tested):

    {"ev": "span",    "name", "t0", "t1", "track", ["rid"], ["attrs"]}
    {"ev": "instant", "name", "t",  "track", ["rid"], ["attrs"]}
    {"ev": "counter", "name", "t",  "track", "value"}

`rid` is present only on request-scoped events; `attrs` is a flat dict of
JSON scalars. Times are simulated seconds from the trace origin.
"""

from __future__ import annotations

LEVELS = ("off", "summary", "replica", "request")

# terminal instants: every traced request must end in exactly one
TERMINALS = ("request.complete", "request.shed", "request.drop")

# span names that structurally nest on a replica track (exported as
# Chrome X events); request-phase spans overlap freely and are exported
# as async events instead
STRUCTURAL_SPANS = ("provisioned", "warmup", "drain")


class NullTracer:
    """Zero-cost stand-in when tracing is off: every emit is a no-op and
    `wants()` is always False, so gated call sites skip event assembly
    entirely."""

    enabled = False
    level = "off"
    events: tuple = ()
    meta: dict = {}

    def wants(self, level: str) -> bool:
        return False

    def span(self, name, t0, t1, track="", rid=None, **attrs) -> None:
        pass

    def instant(self, name, t, track="", rid=None, **attrs) -> None:
        pass

    def counter(self, name, t, value, track="") -> None:
        pass


NULL_TRACER = NullTracer()


class Tracer:
    """In-memory event collector for one simulation run.

    `level` sets the verbosity ceiling: `summary` keeps cluster-scope
    events (scale/autoscale decisions, terminal/shed/retry instants),
    `replica` adds per-replica structural spans and counter timelines,
    `request` adds per-request lifecycle spans and dispatch explanations.
    Emit methods do not re-check the level — call sites gate with
    `wants()`, which keeps the hot path a single hoisted boolean.

    `sinks` (or `add_sink`) registers online consumers — objects with
    `on_event(ev)` — that see each event as it is emitted. Events a sink
    emits back through the tracer are recorded but not re-dispatched.
    `keep_events=False` drops the in-memory event list (sink-only mode);
    `counter_dt > 0` keeps at most one counter sample per (track, name)
    per `counter_dt` simulated seconds."""

    enabled = True

    def __init__(self, level: str = "request", *, sinks=(), keep_events: bool = True,
                 counter_dt: float = 0.0):
        if level not in LEVELS:
            raise ValueError(f"unknown trace level {level!r}; expected one of {LEVELS}")
        if level == "off":
            raise ValueError("level 'off' means no tracer; use NULL_TRACER")
        self.level = level
        self._rank = LEVELS.index(level)
        self.events: list[dict] = []
        self.meta: dict = {"schema": "repro.obs/1"}
        self.keep_events = bool(keep_events)
        self.counter_dt = float(counter_dt)
        self._last_counter: dict[tuple[str, str], float] = {}
        self._sinks: list = []
        self._dispatching = False
        for s in sinks:
            self.add_sink(s)

    def add_sink(self, sink) -> None:
        """Register an online event consumer. If the sink has a `bind`
        method it is called with this tracer so the sink can emit events
        of its own (e.g. the SLO monitor's `alert.*` instants)."""
        self._sinks.append(sink)
        bind = getattr(sink, "bind", None)
        if bind is not None:
            bind(self)

    def _emit(self, ev: dict) -> None:
        if self.keep_events:
            self.events.append(ev)
        if self._sinks and not self._dispatching:
            # events emitted *by* a sink (alert instants) are recorded
            # above but never fed back into sinks — no recursion
            self._dispatching = True
            try:
                for s in self._sinks:
                    s.on_event(ev)
            finally:
                self._dispatching = False

    def wants(self, level: str) -> bool:
        """True when events at `level` should be emitted under this
        tracer's verbosity ceiling."""
        return LEVELS.index(level) <= self._rank

    def span(self, name, t0, t1, track="", rid=None, **attrs) -> None:
        ev = {"ev": "span", "name": name, "t0": float(t0), "t1": float(t1),
              "track": track}
        if rid is not None:
            ev["rid"] = rid
        if attrs:
            ev["attrs"] = attrs
        self._emit(ev)

    def instant(self, name, t, track="", rid=None, **attrs) -> None:
        ev = {"ev": "instant", "name": name, "t": float(t), "track": track}
        if rid is not None:
            ev["rid"] = rid
        if attrs:
            ev["attrs"] = attrs
        self._emit(ev)

    def counter(self, name, t, value, track="") -> None:
        if self.counter_dt > 0.0:
            key = (track, name)
            last = self._last_counter.get(key)
            if last is not None and t - last < self.counter_dt:
                return
            self._last_counter[key] = t
        self._emit({"ev": "counter", "name": name, "t": float(t),
                    "value": float(value), "track": track})


def make_tracer(level: str | None, *, counter_dt: float = 0.0):
    """Level string (or None/'off') -> tracer instance. The CLI-facing
    constructor: `make_tracer('off') is NULL_TRACER`."""
    if level is None or level == "off":
        return NULL_TRACER
    return Tracer(level, counter_dt=counter_dt)


def validate_trace(events) -> list[str]:
    """Structural validation of a trace event stream; returns a list of
    problem strings (empty == valid). Checks:

      * every event carries its schema-required keys and `t0 <= t1`;
      * structural spans (`provisioned`/`warmup`/`drain`) nest properly
        per track — intervals either contain one another or are disjoint;
      * per request id, phase spans are time-ordered (each phase starts no
        earlier than the previous phase's start) and every rid that
        appears terminates in exactly one of `request.complete` /
        `request.shed` / `request.drop`.
    """
    problems: list[str] = []
    by_track: dict[str, list[tuple[float, float, str]]] = {}
    rid_spans: dict[object, list[tuple[float, float, str]]] = {}
    rid_terms: dict[object, list[str]] = {}

    for i, ev in enumerate(events):
        kind = ev.get("ev")
        if kind == "meta":
            continue
        name = ev.get("name")
        if kind == "span":
            t0, t1 = ev.get("t0"), ev.get("t1")
            if t0 is None or t1 is None:
                problems.append(f"event {i}: span {name!r} missing t0/t1")
                continue
            if t1 < t0:
                problems.append(f"event {i}: span {name!r} ends before it starts "
                                f"({t0} > {t1})")
            if "rid" in ev:
                rid_spans.setdefault(ev["rid"], []).append((t0, t1, name))
            elif name in STRUCTURAL_SPANS:
                by_track.setdefault(ev.get("track", ""), []).append((t0, t1, name))
        elif kind == "instant":
            if ev.get("t") is None:
                problems.append(f"event {i}: instant {name!r} missing t")
            if name in TERMINALS:
                if "rid" not in ev:
                    problems.append(f"event {i}: terminal {name!r} missing rid")
                else:
                    rid_terms.setdefault(ev["rid"], []).append(name)
        elif kind == "counter":
            if ev.get("t") is None or ev.get("value") is None:
                problems.append(f"event {i}: counter {name!r} missing t/value")
        else:
            problems.append(f"event {i}: unknown ev kind {kind!r}")

    # structural spans must nest (contain or be disjoint) per track
    for track, spans in by_track.items():
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: list[tuple[float, float, str]] = []
        for t0, t1, name in spans:
            while stack and t0 >= stack[-1][1] - 1e-12:
                stack.pop()
            if stack and t1 > stack[-1][1] + 1e-12:
                problems.append(
                    f"track {track!r}: span {name!r} [{t0:.6g},{t1:.6g}] "
                    f"overlaps {stack[-1][2]!r} [{stack[-1][0]:.6g},{stack[-1][1]:.6g}] "
                    "without nesting")
            stack.append((t0, t1, name))

    # request phases are time-ordered; every traced rid has one terminal
    for rid, spans in rid_spans.items():
        starts = [t0 for t0, _, _ in spans]
        if any(b < a - 1e-9 for a, b in zip(starts, starts[1:])):
            problems.append(f"rid {rid!r}: phase spans out of order")
    for rid in sorted(set(rid_spans) | set(rid_terms)):
        terms = rid_terms.get(rid, [])
        if len(terms) != 1:
            problems.append(
                f"rid {rid!r}: expected exactly one terminal event, got "
                f"{terms if terms else 'none'}")
    return problems
