"""Single-source-of-truth parameter definitions.

Model code builds a pytree of `PD` descriptors (shape + logical sharding +
initializer). The same tree is consumed twice:
  * `init_params`  — materialize arrays (per-leaf folded keys, deterministic),
  * `param_specs`  — the matching pytree of PartitionSpec for pjit shardings.

This guarantees the sharding tree can never drift from the parameter tree.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel.axes import logical_spec


@dataclass(frozen=True)
class PD:
    """Parameter definition: shape, logical axes per dim, initializer."""

    shape: tuple[int, ...]
    logical: tuple[Any, ...]  # one logical name (or None / tuple) per dim
    init: str = "normal"  # normal | zeros | ones | constant
    stddev: float = 0.02
    constant: float = 0.0
    dtype: Any = None  # override param dtype (e.g. fp32 for norms/states)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def stacked(pd: PD, n: int) -> PD:
    """Add a leading layer-stack axis (unsharded) for scan-over-layers."""
    return PD(
        shape=(n, *pd.shape),
        logical=(None, *pd.logical),
        init=pd.init,
        stddev=pd.stddev,
        constant=pd.constant,
        dtype=pd.dtype,
    )


def _materialize(pd: PD, key, default_dtype) -> jax.Array:
    dtype = pd.dtype or default_dtype
    if pd.init == "zeros":
        return jnp.zeros(pd.shape, dtype)
    if pd.init == "ones":
        return jnp.ones(pd.shape, dtype)
    if pd.init == "constant":
        return jnp.full(pd.shape, pd.constant, dtype)
    if pd.init == "normal":
        return (jax.random.normal(key, pd.shape, jnp.float32) * pd.stddev).astype(dtype)
    if pd.init == "uniform":  # U(-c, c)
        return (
            jax.random.uniform(key, pd.shape, jnp.float32, -pd.constant, pd.constant)
        ).astype(dtype)
    raise ValueError(f"unknown init {pd.init}")


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def init_params(defs, key, default_dtype=jnp.float32):
    """Materialize a PD tree into a parameter pytree (deterministic per path)."""

    def make(path, pd: PD):
        # crc32, not hash(): python string hashing is salted per-process and
        # would break cross-process determinism of initialization.
        leaf_key = jax.random.fold_in(key, zlib.crc32(_path_str(path).encode()) & 0x7FFFFFFF)
        return _materialize(pd, leaf_key, default_dtype)

    return jax.tree_util.tree_map_with_path(make, defs, is_leaf=lambda x: isinstance(x, PD))


def param_specs(defs):
    """PartitionSpec pytree matching a PD tree (resolved via current rules)."""
    return jax.tree.map(
        lambda pd: logical_spec(*pd.logical), defs, is_leaf=lambda x: isinstance(x, PD)
    )


def param_shapes(defs, default_dtype=jnp.float32):
    """ShapeDtypeStruct pytree for AOT lowering without allocation."""
    return jax.tree.map(
        lambda pd: jax.ShapeDtypeStruct(pd.shape, pd.dtype or default_dtype),
        defs,
        is_leaf=lambda x: isinstance(x, PD),
    )


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, PD))
    total = 0
    for pd in leaves:
        n = 1
        for s in pd.shape:
            n *= s
        total += n
    return total
