"""Mamba2 (SSD) block: chunked-scan sequence path + O(1) recurrent decode.

Chunked state-space duality algorithm (Mamba2 paper): the sequence is split
into chunks of length Q; within a chunk the recurrence is computed as a masked
(quadratic in Q) matmul; across chunks a linear scan carries the (H, P, N)
state. This is the TPU-native formulation — all intra-chunk work is MXU
einsums; the inter-chunk scan is O(S/Q).

Shapes: d_inner = expand*d_model, H = d_inner/head_dim heads, state N, groups G
(B/C shared across heads in a group, GQA-style).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import PD
from repro.parallel.axes import shard


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, H, conv_dim


def mamba2_defs(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H, conv_dim = dims(cfg)
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + H  # z, x, B, C, dt
    return {
        "w_in": PD((d, d_in_proj), (None, "tp"), stddev=0.02),
        "conv_w": PD((conv_dim, s.conv_width), ("tp", None), stddev=0.1),
        "conv_b": PD((conv_dim,), ("tp",), init="zeros"),
        "a_log": PD((H,), ("tp",), init="constant", constant=0.5, dtype=jnp.float32),
        "d_skip": PD((H,), ("tp",), init="ones", dtype=jnp.float32),
        "dt_bias": PD((H,), ("tp",), init="zeros", dtype=jnp.float32),
        "norm": PD((d_inner,), ("tp",), init="ones", dtype=jnp.float32),
        "w_out": PD((d_inner, d), ("tp", None), stddev=0.02),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    s = cfg.ssm
    d_inner, H, _ = dims(cfg)
    gn = s.n_groups * s.d_state
    z, xc, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + gn, 2 * d_inner + 2 * gn], axis=-1
    )
    return z, xc, Bm, Cm, dt


def _gated_norm(scale: jax.Array, y: jax.Array, z: jax.Array, eps=1e-5) -> jax.Array:
    dt = y.dtype
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + eps) * scale).astype(dt)


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., Q) -> L (..., Q, Q) with L[i, j] = sum_{k=j+1..i} a_k, -inf j>i."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]  # (..., i, j)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, d, -jnp.inf)


def mamba2_seq(cfg: ModelConfig, p: dict, x: jax.Array, chunk: int = 256):
    """Full-sequence SSD. x: (B, S, D) -> (y (B, S, D), final_state dict)."""
    s = cfg.ssm
    d_inner, H, conv_dim = dims(cfg)
    G, N, P = s.n_groups, s.d_state, s.head_dim
    B_, S, _ = x.shape
    Q = min(chunk, S)
    S0 = S
    pad = (Q - S % Q) % Q  # zero-contribution padding: x=0, dt=0 (see below)
    dt_c = x.dtype

    zxbcdt = x @ p["w_in"].astype(dt_c)
    z, xc, Bm, Cm, dtr = _split_proj(cfg, zxbcdt)

    # causal conv over (x, B, C) concatenated
    xbc_raw = jnp.concatenate([xc, Bm, Cm], axis=-1)  # (B, S, conv_dim)
    conv_in = jnp.pad(xbc_raw, ((0, 0), (s.conv_width - 1, 0), (0, 0)))
    # depthwise causal conv via stacked shifts (width is tiny, typically 4)
    conv = sum(
        conv_in[:, i : i + S, :] * p["conv_w"].astype(dt_c)[None, None, :, i].reshape(1, 1, -1)
        for i in range(s.conv_width)
    )
    xbc = jax.nn.silu(conv + p["conv_b"].astype(dt_c))

    xh, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + G * N], axis=-1)
    xh = xh.reshape(B_, S, H, P)
    Bm = Bm.reshape(B_, S, G, N)
    Cm = Cm.reshape(B_, S, G, N)
    xh = shard(xh, "dp", None, "tp", None)

    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])  # (B, S, H)
    A = -jnp.exp(p["a_log"])  # (H,)

    if pad:
        # pad x with zeros (no input contribution) and dt with zeros (decay=1)
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    dA = dt * A  # (B, S, H), negative

    # chunked layout
    xh = xh.reshape(B_, nc, Q, H, P)
    Br = Bm.reshape(B_, nc, Q, G, N)
    Cr = Cm.reshape(B_, nc, Q, G, N)
    dA = dA.reshape(B_, nc, Q, H)
    dt = dt.reshape(B_, nc, Q, H)
    hpg = H // G

    # ---- intra-chunk (quadratic in Q) ----
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2)))  # (B, nc, H, Q, Q)
    scores = jnp.einsum("bcign,bcjgn->bcgij", Cr, Br)  # (B, nc, G, Q, Q)
    scores = jnp.repeat(scores, hpg, axis=2)  # broadcast groups -> heads
    M = scores * Lmat * jnp.moveaxis(dt, -1, -2)[..., None, :]  # weight by dt_j
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", M.astype(dt_c), xh)

    # ---- chunk-local states ----
    cum = jnp.cumsum(dA, axis=2)  # (B, nc, Q, H)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B, nc, Q, H)
    Bh = jnp.repeat(Br, hpg, axis=3)  # (B, nc, Q, H, N)
    s_loc = jnp.einsum(
        "bcjhn,bcjh,bcjhp->bchnp", Bh.astype(jnp.float32), decay_to_end * dt, xh.astype(jnp.float32)
    )  # (B, nc, H, N, P)

    # ---- inter-chunk scan ----
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B, nc, H)

    def step(state, inp):
        s_c, decay_c = inp  # (B, H, N, P), (B, H)
        y_prev_state = state  # state entering this chunk
        new = decay_c[..., None, None] * state + s_c
        return new, y_prev_state

    init = jnp.zeros((B_, H, N, P), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        step, init, (jnp.moveaxis(s_loc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B, nc, H, N, P)

    Ch = jnp.repeat(Cr, hpg, axis=3)  # (B, nc, Q, H, N)
    y_inter = jnp.einsum(
        "bcihn,bchnp,bcih->bcihp", Ch.astype(jnp.float32), prev_states, jnp.exp(cum)
    ).astype(dt_c)

    y = y_intra + y_inter + xh * p["d_skip"].astype(dt_c)[None, None, :, None]
    y = y.reshape(B_, S, d_inner)[:, :S0]  # drop padding
    y = _gated_norm(p["norm"], y, z)
    out = y @ p["w_out"].astype(dt_c)
    out = shard(out, "dp", "sp", None)

    # conv cache: last (W-1) post-proj pre-conv inputs
    conv_state = jnp.moveaxis(xbc_raw[:, S - (s.conv_width - 1) :, :], 1, 2)
    state = {"ssd": final_state, "conv": conv_state}
    return out, state


def mamba2_decode(cfg: ModelConfig, p: dict, x: jax.Array, state: dict):
    """One-token recurrent step. x: (B, 1, D) -> (y (B, 1, D), new state)."""
    s = cfg.ssm
    d_inner, H, conv_dim = dims(cfg)
    G, N, P = s.n_groups, s.d_state, s.head_dim
    B_ = x.shape[0]
    dt_c = x.dtype
    hpg = H // G

    zxbcdt = x[:, 0] @ p["w_in"].astype(dt_c)  # (B, d_in_proj)
    z, xc, Bm, Cm, dtr = _split_proj(cfg, zxbcdt)

    xbc = jnp.concatenate([xc, Bm, Cm], axis=-1)  # (B, conv_dim)
    window = jnp.concatenate([state["conv"], xbc[:, :, None]], axis=-1)  # (B, conv_dim, W)
    conv = jnp.einsum("bcw,cw->bc", window, p["conv_w"].astype(dt_c)) + p["conv_b"].astype(dt_c)
    xbc = jax.nn.silu(conv)
    new_conv = window[:, :, 1:]

    xh, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + G * N], axis=-1)
    xh = xh.reshape(B_, H, P)
    Bm = jnp.repeat(Bm.reshape(B_, G, N), hpg, axis=1)  # (B, H, N)
    Cm = jnp.repeat(Cm.reshape(B_, G, N), hpg, axis=1)

    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * A)  # (B, H)

    S_ = state["ssd"]  # (B, H, N, P) fp32
    S_ = da[..., None, None] * S_ + jnp.einsum(
        "bhn,bh,bhp->bhnp", Bm.astype(jnp.float32), dt, xh.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhnp->bhp", Cm.astype(jnp.float32), S_).astype(dt_c)
    y = y + xh * p["d_skip"].astype(dt_c)[None, :, None]
    y = y.reshape(B_, d_inner)
    y = _gated_norm(p["norm"], y, z)
    out = (y @ p["w_out"].astype(dt_c))[:, None, :]
    return out, {"ssd": S_, "conv": new_conv}


def init_mamba2_state(cfg: ModelConfig, batch: int) -> dict:
    s = cfg.ssm
    d_inner, H, conv_dim = dims(cfg)
    return {
        "ssd": jnp.zeros((batch, H, s.d_state, s.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, conv_dim, s.conv_width - 1), cfg.compute_dtype),
    }


def mamba2_state_specs(cfg: ModelConfig):
    return {"ssd": ("dp", "tp", None, None), "conv": ("dp", "tp", None)}
