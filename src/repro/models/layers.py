"""Shared layers: norms, rotary embeddings, activations, embedding/lm-head."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import PD
from repro.parallel.axes import shard


# ---------------------------------------------------------------- activations
def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu2":  # squared ReLU (Nemotron/minitron, RWKV channel-mix)
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name}")


# ---------------------------------------------------------------------- norms
def norm_defs(d: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": PD((d,), (None,), init="ones", dtype=jnp.float32)}
    if kind == "layernorm":
        return {
            "scale": PD((d,), (None,), init="ones", dtype=jnp.float32),
            "bias": PD((d,), (None,), init="zeros", dtype=jnp.float32),
        }
    raise ValueError(kind)


def apply_norm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm / LayerNorm in fp32, cast back to input dtype."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(dtype)


def rms_head_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMSNorm over the trailing head_dim (qwen3 qk_norm)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(dtype)


def group_norm_heads(p: dict, x: jax.Array, eps: float = 64e-5) -> jax.Array:
    """Per-head GroupNorm over head_dim (RWKV ln_x). x: (..., H, dh)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    h, dh = x.shape[-2], x.shape[-1]
    y = y * p["scale"].reshape(h, dh) + p["bias"].reshape(h, dh)
    return y.astype(dtype)


# ----------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, dh); positions: (S,) or (B, S) absolute token positions.

    Uses the half-split convention (rotate_half), matching Llama-family models.
    Odd head_dims (none assigned) are unsupported by construction.
    """
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (dh/2,)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, dh/2)
        ang = ang[None, :, None, :]  # (1, S, 1, dh/2)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, dh/2)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- embeddings
def embed_defs(vocab: int, d: int) -> dict:
    # vocab-sharded over tp (Megatron-style embedding parallelism)
    return {"tok": PD((vocab, d), ("tp", None), init="normal", stddev=0.02)}


def embed_lookup(p: dict, tokens: jax.Array, dtype) -> jax.Array:
    emb = p["tok"].astype(dtype)
    x = jnp.take(emb, tokens, axis=0)
    return shard(x, "dp", "sp", None)


def head_defs(d: int, vocab: int) -> dict:
    return {"w": PD((d, vocab), (None, "tp"), init="normal", stddev=0.02)}


def lm_logits(p: dict, x: jax.Array, dtype) -> jax.Array:
    """x: (..., d) -> (..., vocab), vocab-sharded."""
    w = p["w"].astype(dtype)
    logits = x @ w
    return shard(logits, "dp", None, "tp") if logits.ndim == 3 else shard(logits, "dp", "tp")
