"""Mixture-of-Experts with capacity-bounded gather/scatter dispatch (EP).

Design notes (TPU adaptation, see DESIGN.md §6):
  * No giant one-hot dispatch einsums (GShard-style (T, E, C) one-hot matmuls
    cost T*D*E*C flops — hundreds of times the useful expert flops at our
    shapes). Instead: sort assignments by expert, compute the position of each
    assignment within its expert via cumulative counts, and scatter rows into a
    static (E, C+1, D) buffer (slot C is the overflow scratch row, so dropped
    tokens never need dynamic shapes).
  * Expert dim shards over the `ep` (= tp) mesh axes — expert parallelism;
    capacity dim shards over `dp`. The scatter/gather between token-sharded and
    expert-sharded layouts is exactly the all-to-all the paper's communication
    model accounts for.
  * Supports deepseek-style shared experts (always-on) and arctic-style dense
    residual branch; fine-grained expert ff widths.

Aux outputs: load-balancing loss (Switch-style) + router z-loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoECfg
from repro.models.layers import activation
from repro.models.mlp import apply_mlp, mlp_defs
from repro.models.params import PD
from repro.parallel.axes import shard


def moe_defs(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    s = 0.02
    # shard_ff_dp: experts additionally sharded over data on the ffn dim
    # (ZeRO-3-style; transient per-layer all-gather at use)
    ff_ax = "zero" if m.shard_ff_dp else None
    defs = {
        "router": PD((d, m.num_experts), (None, None), stddev=s, dtype=jnp.float32),
        # experts: E x (d -> ff -> d), expert dim sharded over ep
        "wi": PD((m.num_experts, d, m.d_ff), ("ep", None, ff_ax), stddev=s),
        "wo": PD((m.num_experts, m.d_ff, d), ("ep", ff_ax, None), stddev=s),
    }
    if cfg.gated_mlp:
        defs["wg"] = PD((m.num_experts, d, m.d_ff), ("ep", None, ff_ax), stddev=s)
    if m.num_shared_experts:
        defs["shared"] = mlp_defs(d, m.d_ff * m.num_shared_experts, cfg.gated_mlp)
    if m.dense_residual:
        defs["dense"] = mlp_defs(d, m.dense_d_ff or m.d_ff, cfg.gated_mlp)
    return defs


def capacity(m: MoECfg, tokens: int) -> int:
    """Static per-expert capacity."""
    c = int(m.capacity_factor * tokens * m.top_k / m.num_experts)
    return max(c, m.top_k)


def _n_groups(T: int) -> int:
    """Dispatch groups = data-shard count (GShard-style grouping): routing,
    sort and scatter/gather happen *within* a group, so under GSPMD every
    gather is a batched gather with the group dim sharded over dp — no
    replicated (T, D) operands (the global-argsort formulation made XLA
    all-gather the token table per device; see EXPERIMENTS.md §Perf)."""
    from repro.parallel.axes import axes_size

    g = max(axes_size("dp"), 1)
    while T % g:
        g -= 1
    return g


def _moe_group(cfg: ModelConfig, p: dict, xf: jax.Array, C: int):
    """Dispatch/compute/combine for one token group. xf: (Tg, D)."""
    m = cfg.moe
    E, K = m.num_experts, m.top_k
    dt = xf.dtype
    act = activation(cfg.act)
    Tg = xf.shape[0]

    logits = xf.astype(jnp.float32) @ p["router"]  # (Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, topi = jax.lax.top_k(probs, K)
    if m.norm_topk:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    A = Tg * K
    flat_e = topi.reshape(A)
    order = jnp.argsort(flat_e, stable=True)  # token-priority within expert
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(A) - starts[sorted_e]
    keep = pos_in_e < C
    slot = jnp.where(keep, pos_in_e, C)  # C = overflow scratch row
    token_of = order // K

    buf = jnp.zeros((E, C + 1, xf.shape[1]), dt)
    buf = buf.at[sorted_e, slot].set(xf[token_of], mode="drop")
    h = buf[:, :C]

    up = jnp.einsum("ecd,edf->ecf", h, p["wi"].astype(dt))
    if "wg" in p:
        up = act(jnp.einsum("ecd,edf->ecf", h, p["wg"].astype(dt))) * up
    else:
        up = act(up)
    out = jnp.einsum("ecf,efd->ecd", up, p["wo"].astype(dt))
    out = jnp.concatenate([out, jnp.zeros((E, 1, xf.shape[1]), dt)], axis=1)

    vals = out[sorted_e, slot]  # (A, D); dropped -> zeros row
    w = (gate.reshape(A)[order] * keep).astype(dt)
    y = jnp.zeros((Tg, xf.shape[1]), dt).at[token_of].add(vals * w[:, None])

    stats = {
        "me": probs.mean(axis=0),
        "ce": counts.astype(jnp.float32) / A,
        "z": jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
        "drop": jnp.clip(1.0 - keep.mean(), 0.0, 1.0),
    }
    return y, stats


def apply_moe(cfg: ModelConfig, p: dict, x: jax.Array):
    """x: (B, S, D) -> (y (B, S, D), aux dict with load-balance metrics)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E = m.num_experts
    G = _n_groups(T)
    Tg = T // G
    C = capacity(m, Tg)

    xg = x.reshape(G, Tg, D)
    xg = shard(xg, "dp", None, None)

    # vmapped per-group dispatch: batched scatters/gathers with the group dim
    # sharded over dp; expert dim of the buffers shards over ep
    expert_p = {k: p[k] for k in ("router", "wi", "wo", "wg") if k in p}

    def one(xf):
        return _moe_group(cfg, expert_p, xf, C)

    yg, stats = jax.vmap(one)(xg)
    yg = shard(yg, "dp", None, None)
    y = yg.reshape(T, D)

    xf = x.reshape(T, D)
    if "shared" in p:
        y = y + apply_mlp(p["shared"], xf, cfg.act)
    if "dense" in p:
        y = y + apply_mlp(p["dense"], xf, cfg.act)

    me = stats["me"].mean(axis=0)
    ce = stats["ce"].mean(axis=0)
    aux = {
        "moe_lb_loss": E * jnp.sum(me * ce),
        "moe_z_loss": stats["z"].mean(),
        "moe_drop_frac": stats["drop"].mean(),
    }
    return y.reshape(B, S, D), aux


def apply_moe_dense_reference(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """O(T*E) oracle: every expert on every token, masked by gates (tests only)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    act = activation(cfg.act)
    dt = x.dtype
    xf = x.reshape(T, D)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, topi = jax.lax.top_k(probs, m.top_k)
    if m.norm_topk:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    dense_gate = jnp.zeros((T, m.num_experts), jnp.float32)
    dense_gate = dense_gate.at[jnp.arange(T)[:, None], topi].set(gate)
    up = jnp.einsum("td,edf->tef", xf, p["wi"].astype(dt))
    if "wg" in p:
        up = act(jnp.einsum("td,edf->tef", xf, p["wg"].astype(dt))) * up
    else:
        up = act(up)
    out = jnp.einsum("tef,efd->ted", up, p["wo"].astype(dt))
    y = jnp.einsum("ted,te->td", out, dense_gate.astype(dt))
    if "shared" in p:
        y = y + apply_mlp(p["shared"], xf, cfg.act)
    if "dense" in p:
        y = y + apply_mlp(p["dense"], xf, cfg.act)
    return y.reshape(B, S, D)
