"""Memory-efficient differentiable chunked attention (flash-attention VJP).

Why this exists: differentiating a scan whose body materializes (chunk, chunk)
fp32 score blocks makes JAX save every block for the backward pass — O(S^2)
residuals per layer, which is exactly what flash attention exists to avoid.
This custom_vjp saves only (q, k, v, out, LSE) — O(S*d) — and *recomputes*
the probability blocks during backward (Dao et al.'s dq/dk/dv recurrences),
so 32k-token training steps fit HBM. This is the jnp twin of the Pallas
kernel in repro/kernels/flash_attention (same blocking, same residuals).

Layouts: q (B, S, Hkv, G, dh); k, v (B, S, Hkv, dh). Causal, optional window.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _blocks(x, chunk, axis=1):
    B = x.shape[0]
    n = x.shape[axis] // chunk
    new = x.shape[:axis] + (n, chunk) + x.shape[axis + 1 :]
    return jnp.moveaxis(x.reshape(new), axis, 0)


def _mask(qi, ki, chunk, window):
    q_pos = qi * chunk + jnp.arange(chunk)
    k_pos = ki * chunk + jnp.arange(chunk)
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention_vjp(q, k, v, window, chunk):
    out, _ = _fwd(q, k, v, window, chunk)
    return out


def _fwd(q, k, v, window, chunk):
    B, S, Hkv, G, dh = q.shape
    nq = S // chunk
    scale = dh**-0.5
    qb = _blocks(q, chunk)  # (nq, B, chunk, Hkv, G, dh)

    def q_step(_, inp):
        qc, qi = inp

        def kv_step(carry, ki):
            m, l, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(k, ki * chunk, chunk, 1)
            vc = jax.lax.dynamic_slice_in_dim(v, ki * chunk, chunk, 1)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            s = jnp.where(_mask(qi, ki, chunk, window)[None, None, None], s, NEG_INF)
            m2 = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m2[..., None])
            corr = jnp.exp(m - m2)
            l2 = l * corr + p.sum(-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc)
            return (m2, l2, acc * corr[..., None].astype(acc.dtype) + pv), None

        init = (
            jnp.full((B, Hkv, G, chunk), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, G, chunk), jnp.float32),
            jnp.zeros((B, Hkv, G, chunk, dh), v.dtype),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nq))
        o = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))  # (B, Hkv, G, chunk)
        return None, (jnp.moveaxis(o, 3, 1), lse)

    _, (ob, lseb) = jax.lax.scan(q_step, None, (qb, jnp.arange(nq)))
    out = jnp.moveaxis(ob, 0, 1).reshape(B, S, Hkv, G, dh)
    lse = jnp.moveaxis(lseb, 0, 3).reshape(B, Hkv, G, S)
    return out, lse


def _fwd_rule(q, k, v, window, chunk):
    out, lse = _fwd(q, k, v, window, chunk)
    return out, (q, k, v, out, lse)


def _bwd_rule(window, chunk, res, do):
    q, k, v, out, lse = res
    B, S, Hkv, G, dh = q.shape
    nq = S // chunk
    scale = dh**-0.5
    # D_i = rowsum(dO * O)
    delta = jnp.einsum("bqhgd,bqhgd->bhgq", do.astype(jnp.float32),
                       out.astype(jnp.float32))

    qb = _blocks(q, chunk)
    dob = _blocks(do, chunk)
    lseb = jnp.moveaxis(lse.reshape(B, Hkv, G, nq, chunk), 3, 0)
    deltab = jnp.moveaxis(delta.reshape(B, Hkv, G, nq, chunk), 3, 0)

    def q_step(carry, inp):
        dk_acc, dv_acc = carry
        qc, doc, lsec, dc, qi = inp

        def kv_step(carry2, ki):
            dq_c, dk_a, dv_a = carry2
            kc = jax.lax.dynamic_slice_in_dim(k, ki * chunk, chunk, 1)
            vc = jax.lax.dynamic_slice_in_dim(v, ki * chunk, chunk, 1)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            s = jnp.where(_mask(qi, ki, chunk, window)[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lsec[..., None])  # (B,Hkv,G,cq,ck)
            dv_blk = jnp.einsum("bhgqk,bqhgd->bkhd", p, doc.astype(jnp.float32))
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", doc.astype(jnp.float32),
                            vc.astype(jnp.float32))
            ds = p * (dp - dc[..., None]) * scale
            dq_blk = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kc.astype(jnp.float32))
            dk_blk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qc.astype(jnp.float32))
            dk_a = jax.lax.dynamic_update_slice_in_dim(
                dk_a, jax.lax.dynamic_slice_in_dim(dk_a, ki * chunk, chunk, 1) + dk_blk,
                ki * chunk, 1)
            dv_a = jax.lax.dynamic_update_slice_in_dim(
                dv_a, jax.lax.dynamic_slice_in_dim(dv_a, ki * chunk, chunk, 1) + dv_blk,
                ki * chunk, 1)
            return (dq_c + dq_blk, dk_a, dv_a), None

        init_dq = jnp.zeros((B, chunk, Hkv, G, dh), jnp.float32)
        (dq_c, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (init_dq, dk_acc, dv_acc), jnp.arange(nq)
        )
        return (dk_acc, dv_acc), dq_c

    zeros_kv = jnp.zeros((B, S, Hkv, dh), jnp.float32)
    (dk, dv), dqb = jax.lax.scan(
        q_step, (zeros_kv, zeros_kv),
        (qb, dob, lseb, deltab, jnp.arange(nq)),
    )
    dq = jnp.moveaxis(dqb, 0, 1).reshape(B, S, Hkv, G, dh)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_vjp.defvjp(_fwd_rule, _bwd_rule)
