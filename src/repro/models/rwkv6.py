"""RWKV6 "Finch": data-dependent-decay time-mix + squared-ReLU channel-mix.

Attention-free: per-head (dh x dh) matrix state, O(1) per-token decode, no KV
cache — the long-context-decode case the assignment calls out. Sequence
processing uses a chunk-parallel formulation of the linear recurrence
(wkv state checkpointed per chunk, intra-chunk computed as masked matmuls) so
training/prefill are MXU-friendly rather than a length-S serial scan.

Faithful-lite simplifications (recorded in DESIGN.md): the 5-way ddlerp
token-shift LoRA and decay LoRA follow the RWKV6 structure with configurable
inner dims; gating/norm layout matches the published block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import group_norm_heads
from repro.models.params import PD
from repro.parallel.axes import shard


def heads(cfg: ModelConfig):
    dh = cfg.ssm.head_dim
    return cfg.d_model // dh, dh


def rwkv6_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    s = cfg.ssm
    H, dh = heads(cfg)
    k = s.mix_dim
    r = s.decay_lora
    sd = 0.02
    return {
        "tm": {  # time mix
            "maa_x": PD((d,), (None,), init="zeros"),
            "maa": PD((5, d), (None, None), init="zeros"),  # w,k,v,r,g base mixes
            "mix_w1": PD((d, 5 * k), (None, None), stddev=sd),
            "mix_w2": PD((5, k, d), (None, None, None), stddev=sd),
            "w0": PD((d,), ("tp",), init="constant", constant=-4.0, dtype=jnp.float32),
            "w_a": PD((d, r), (None, None), stddev=sd),
            "w_b": PD((r, d), (None, "tp"), stddev=sd),
            "wr": PD((d, d), (None, "tp"), stddev=sd),
            "wk": PD((d, d), (None, "tp"), stddev=sd),
            "wv": PD((d, d), (None, "tp"), stddev=sd),
            "wg": PD((d, d), (None, "tp"), stddev=sd),
            "wo": PD((d, d), ("tp", None), stddev=sd),
            "u": PD((H, dh), ("tp", None), stddev=sd, dtype=jnp.float32),  # bonus
            "ln_x": {
                "scale": PD((d,), ("tp",), init="ones", dtype=jnp.float32),
                "bias": PD((d,), ("tp",), init="zeros", dtype=jnp.float32),
            },
        },
        "cm": {  # channel mix
            "mu_k": PD((d,), (None,), init="zeros"),
            "mu_r": PD((d,), (None,), init="zeros"),
            "wk": PD((d, cfg.d_ff), (None, "tp"), stddev=sd),
            "wv": PD((cfg.d_ff, d), ("tp", None), stddev=sd),
            "wr": PD((d, d), (None, "tp"), stddev=sd),
        },
    }


def _ddlerp(p: dict, x: jax.Array, xprev: jax.Array):
    """Data-dependent 5-way token-shift interpolation -> (xw, xk, xv, xr, xg)."""
    xx = xprev - x
    base = x + xx * p["maa_x"].astype(x.dtype)
    k5 = jnp.tanh(base @ p["mix_w1"].astype(x.dtype))  # (..., 5k)
    k5 = k5.reshape(*k5.shape[:-1], 5, p["mix_w2"].shape[1])
    mixes = jnp.einsum("...fk,fkd->...fd", k5, p["mix_w2"].astype(x.dtype))
    mixes = mixes + p["maa"].astype(x.dtype)
    out = x[..., None, :] + xx[..., None, :] * mixes  # (..., 5, d)
    return tuple(out[..., i, :] for i in range(5))


def _decay(p: dict, xw: jax.Array) -> jax.Array:
    """Per-channel decay in (0,1): w = exp(-exp(w0 + lora(xw)))."""
    lora = jnp.tanh(xw @ p["w_a"].astype(xw.dtype)) @ p["w_b"].astype(xw.dtype)
    return jnp.exp(-jnp.exp(p["w0"] + lora.astype(jnp.float32)))


def _wkv_chunk_scan(r, k, v, w, u, chunk: int, init_state=None):
    """Chunk-scan linear recurrence with data-dependent per-channel decay.

    r,k,w: (B,S,H,K); v: (B,S,H,V); u: (H,K). All fp32.
    State S_t (H,K,V): S_t = diag(w_t) S_{t-1} + k_t v_t^T;
    out_t = r_t (S_{t-1} + diag(u) k_t v_t^T).
    Returns out (B,S,H,V), final state (B,H,K,V).

    Stability: every exponent used is a *backward* cumulative log-decay
    difference (<= 0), so exp never overflows regardless of how fast the
    learned decay is — this is why the intra-chunk decay matrix is built
    per-channel (D[i,j,k] = exp(cum_{i-1,k} - cum_{j,k}), j < i) instead of
    the factored r*exp(cum) / k*exp(-cum) trick, which overflows for
    fast-decaying channels. Chunk is kept small (<=32) to bound the (Q,Q,K)
    block.
    """
    B, S, H, K = k.shape
    V = v.shape[-1]
    Q = min(chunk, S)
    S0 = S
    if S % Q:  # pad with identity steps: w=1 (no decay), k=v=0 (no contribution)
        pad = Q - S % Q
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, z), jnp.pad(k, z), jnp.pad(v, z)
        w = jnp.pad(w, z, constant_values=1.0)
        S = S + pad
    nc = S // Q
    rr = jnp.moveaxis(r.reshape(B, nc, Q, H, K), 1, 0)  # (nc,B,Q,H,K)
    kk = jnp.moveaxis(k.reshape(B, nc, Q, H, K), 1, 0)
    vv = jnp.moveaxis(v.reshape(B, nc, Q, H, V), 1, 0)
    ww = jnp.moveaxis(w.reshape(B, nc, Q, H, K), 1, 0)
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)  # j < i (strict)

    def step(state, inp):
        rc, kc, vc, wc = inp  # (B,Q,H,K) ...
        logw = jnp.log(jnp.maximum(wc, 1e-38))  # <= 0
        cum = jnp.cumsum(logw, axis=1)  # (B,Q,H,K), decreasing
        cum_prev = cum - logw  # log prod_{t<i} w_t
        # intra-chunk: D[i,j] = exp(cum_prev_i - cum_j) for j<i (exponent <= 0)
        d = cum_prev[:, :, None] - cum[:, None, :]  # (B,i,j,H,K)
        d = jnp.where(mask[None, :, :, None, None], d, -jnp.inf)
        att_v = jnp.einsum("bihk,bjhk,bijhk->bihj", rc, kc, jnp.exp(d))
        y = jnp.einsum("bihj,bjhv->bihv", att_v, vc)
        # bonus (current token)
        bonus = jnp.einsum("bihk,hk,bihk->bih", rc, u, kc)
        y = y + bonus[..., None] * vc
        # entering-state contribution: exponent cum_prev <= 0
        y = y + jnp.einsum("bihk,bhkv->bihv", rc * jnp.exp(cum_prev), state)
        # state update: exponents cum_Q - cum_j <= 0 and cum_Q <= 0
        k_tail = kc * jnp.exp(cum[:, -1:, :, :] - cum)
        s_loc = jnp.einsum("bjhk,bjhv->bhkv", k_tail, vc)
        new = jnp.exp(cum[:, -1])[..., None] * state + s_loc
        return new, y

    init = jnp.zeros((B, H, K, V), jnp.float32) if init_state is None else init_state
    final, ys = jax.lax.scan(step, init, (rr, kk, vv, ww))
    out = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, V)
    return out[:, :S0], final


def time_mix_seq(cfg: ModelConfig, p: dict, x: jax.Array, chunk: int = 32):
    """x: (B, S, D) -> (out, state dict). Sequence path."""
    H, dh = heads(cfg)
    B, S, D = x.shape
    dt = x.dtype
    xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :S]
    xw, xk, xv, xr, xg = _ddlerp(p, x, xprev)
    r = (xr @ p["wr"].astype(dt)).reshape(B, S, H, dh)
    k = (xk @ p["wk"].astype(dt)).reshape(B, S, H, dh)
    v = (xv @ p["wv"].astype(dt)).reshape(B, S, H, dh)
    g = jax.nn.silu(xg @ p["wg"].astype(dt))
    w = _decay(p, xw).reshape(B, S, H, dh)  # fp32
    r = shard(r, "dp", None, "tp", None)
    k = shard(k, "dp", None, "tp", None)
    v = shard(v, "dp", None, "tp", None)

    out, state = _wkv_chunk_scan(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), w, p["u"], chunk
    )
    out = group_norm_heads(p["ln_x"], out.astype(dt))
    out = (out.reshape(B, S, D) * g) @ p["wo"].astype(dt)
    return shard(out, "dp", "sp", None), {"wkv": state, "shift": x[:, -1]}


def time_mix_decode(cfg: ModelConfig, p: dict, x: jax.Array, state: dict):
    """x: (B, 1, D); state: {wkv (B,H,dh,dh) fp32, shift (B, D)}."""
    H, dh = heads(cfg)
    B, _, D = x.shape
    dt = x.dtype
    xt = x[:, 0]
    xw, xk, xv, xr, xg = _ddlerp(p, xt, state["shift"])
    r = (xr @ p["wr"].astype(dt)).reshape(B, H, dh).astype(jnp.float32)
    k = (xk @ p["wk"].astype(dt)).reshape(B, H, dh).astype(jnp.float32)
    v = (xv @ p["wv"].astype(dt)).reshape(B, H, dh).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"].astype(dt))
    w = _decay(p, xw).reshape(B, H, dh)

    S_ = state["wkv"]  # (B,H,K,V)
    a = jnp.einsum("bhk,bhv->bhkv", k, v)
    o = jnp.einsum("bhk,bhkv->bhv", r, S_ + p["u"][None, :, :, None] * a)
    S_ = w[..., None] * S_ + a
    o = group_norm_heads(p["ln_x"], o.astype(dt)[:, None].reshape(B, 1, H, dh))
    out = (o.reshape(B, D) * g) @ p["wo"].astype(dt)
    return out[:, None, :], {"wkv": S_, "shift": xt}


def channel_mix_seq(cfg: ModelConfig, p: dict, x: jax.Array):
    B, S, D = x.shape
    dt = x.dtype
    xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :S]
    return _channel_mix(p, x, xprev, dt), {"shift": x[:, -1]}


def channel_mix_decode(cfg: ModelConfig, p: dict, x: jax.Array, state: dict):
    xt = x[:, 0]
    out = _channel_mix(p, xt, state["shift"], x.dtype)
    return out[:, None, :] if out.ndim == 2 else out, {"shift": xt}


def _channel_mix(p: dict, x, xprev, dt):
    xx = xprev - x
    xk = x + xx * p["mu_k"].astype(dt)
    xr = x + xx * p["mu_r"].astype(dt)
    vk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt))) @ p["wv"].astype(dt)
    out = jax.nn.sigmoid(xr @ p["wr"].astype(dt)) * vk
    return shard(out, "dp", "sp", None) if out.ndim == 3 else out


def init_rwkv6_state(cfg: ModelConfig, batch: int) -> dict:
    H, dh = heads(cfg)
    D = cfg.d_model
    return {
        "tm": {
            "wkv": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "shift": jnp.zeros((batch, D), cfg.compute_dtype),
        },
        "cm": {"shift": jnp.zeros((batch, D), cfg.compute_dtype)},
    }


def rwkv6_state_specs(cfg: ModelConfig):
    return {
        "tm": {"wkv": ("dp", "tp", None, None), "shift": ("dp", None)},
        "cm": {"shift": ("dp", None)},
    }
