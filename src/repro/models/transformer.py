"""Model assembly: scan-over-layers transformer supporting all assigned families.

Families:
  dense / audio / vlm : [norm -> GQA attn -> norm -> MLP] x L
  moe                 : MLP replaced by routed MoE (+ shared experts / dense
                        residual); optional leading dense layers (deepseek)
  ssm (rwkv6)         : [ln -> time-mix -> ln -> channel-mix] x L
  hybrid (zamba2)     : Mamba2 backbone with a *shared* attn+MLP block applied
                        every `attn_every` layers (python-loop assembly, so the
                        shared block's KV caches exist only where it is applied)

Execution modes: train/forward (no cache), prefill (returns decode cache),
decode (one token, O(1) state/KV updates).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba2, moe, rwkv6
from repro.models.layers import (
    apply_norm,
    embed_defs,
    embed_lookup,
    head_defs,
    lm_logits,
    norm_defs,
)
from repro.models.mlp import apply_mlp, mlp_defs
from repro.models.params import PD, init_params, param_specs, param_shapes, stacked
from repro.parallel.axes import shard

AUX_KEYS = ("moe_lb_loss", "moe_z_loss", "moe_drop_frac")


def zero_aux() -> dict:
    return {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}


# ------------------------------------------------------------------ layer defs
def _dense_layer_defs(cfg: ModelConfig, moe_layer: bool) -> dict:
    d = {
        "ln1": norm_defs(cfg.d_model, cfg.norm),
        "attn": attn.attn_defs(cfg),
        "ln2": norm_defs(cfg.d_model, cfg.norm),
    }
    if moe_layer:
        d["moe"] = moe.moe_defs(cfg)
    else:
        ff = cfg.moe.dense_d_ff if (cfg.moe and cfg.moe.dense_d_ff) else cfg.d_ff
        d["mlp"] = mlp_defs(cfg.d_model, ff, cfg.gated_mlp)
    return d


def _rwkv_layer_defs(cfg: ModelConfig) -> dict:
    r = rwkv6.rwkv6_defs(cfg)
    return {
        "ln1": norm_defs(cfg.d_model, "layernorm"),
        "tm": r["tm"],
        "ln2": norm_defs(cfg.d_model, "layernorm"),
        "cm": r["cm"],
    }


def _mamba_layer_defs(cfg: ModelConfig) -> dict:
    return {"ln1": norm_defs(cfg.d_model, cfg.norm), "mamba": mamba2.mamba2_defs(cfg)}


def _shared_block_defs(cfg: ModelConfig) -> dict:
    return {
        "ln1": norm_defs(cfg.d_model, cfg.norm),
        "attn": attn.attn_defs(cfg),
        "ln2": norm_defs(cfg.d_model, cfg.norm),
        "mlp": mlp_defs(cfg.d_model, cfg.d_ff, cfg.gated_mlp),
    }


# ----------------------------------------------------------------- layer apply
def _apply_dense_layer(cfg, p, x, mode, cache=None, pos=None, max_len=0, cp=False):
    h = apply_norm(p["ln1"], x)
    new_cache: dict[str, Any] = {}
    if mode == "train":
        a = attn.self_attention(cfg, p["attn"], h)
    elif mode == "prefill":
        a, kv = attn.prefill_attention(cfg, p["attn"], h, max_len, cp=cp)
        new_cache["kv"] = kv
    else:  # decode
        a, kv = attn.decode_attention(cfg, p["attn"], h, cache["kv"], pos, cp=cp)
        new_cache["kv"] = kv
    x = x + a
    h = apply_norm(p["ln2"], x)
    if "moe" in p:
        m, aux = moe.apply_moe(cfg, p["moe"], h)
    else:
        m, aux = apply_mlp(p["mlp"], h, cfg.act), zero_aux()
    x = x + m
    x = shard(x, "dp", "sp", None)
    return x, new_cache, aux


def _apply_rwkv_layer(cfg, p, x, mode, cache=None):
    h = apply_norm(p["ln1"], x)
    if mode == "decode":
        a, tm_state = rwkv6.time_mix_decode(cfg, p["tm"], h, cache["tm"])
    else:
        a, tm_state = rwkv6.time_mix_seq(cfg, p["tm"], h)
    x = x + a
    h = apply_norm(p["ln2"], x)
    if mode == "decode":
        c, cm_state = rwkv6.channel_mix_decode(cfg, p["cm"], h, cache["cm"])
    else:
        c, cm_state = rwkv6.channel_mix_seq(cfg, p["cm"], h)
    x = x + c
    x = shard(x, "dp", "sp", None)
    return x, {"tm": tm_state, "cm": cm_state}


def _apply_mamba_layer(cfg, p, x, mode, cache=None):
    h = apply_norm(p["ln1"], x)
    if mode == "decode":
        m, state = mamba2.mamba2_decode(cfg, p["mamba"], h, cache)
    else:
        m, state = mamba2.mamba2_seq(cfg, p["mamba"], h)
    x = shard(x + m, "dp", "sp", None)
    return x, state


def _apply_shared_block(cfg, p, x, mode, cache=None, pos=None, max_len=0, cp=False):
    h = apply_norm(p["ln1"], x)
    new_cache = None
    if mode == "train":
        a = attn.self_attention(cfg, p["attn"], h)
    elif mode == "prefill":
        a, new_cache = attn.prefill_attention(cfg, p["attn"], h, max_len, cp=cp)
    else:
        a, new_cache = attn.decode_attention(cfg, p["attn"], h, cache, pos, cp=cp)
    x = x + a
    x = x + apply_mlp(p["mlp"], apply_norm(p["ln2"], x), cfg.act)
    return shard(x, "dp", "sp", None), new_cache


# ----------------------------------------------------------------------- Model
class Model:
    """Functional model wrapper: params are explicit pytrees."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.is_hybrid = cfg.family == "hybrid"
        self.is_rwkv = cfg.ssm is not None and cfg.ssm.kind == "rwkv6"
        self.is_mamba = cfg.ssm is not None and cfg.ssm.kind == "mamba2"

    # ------------------------------------------------------------- param defs
    def _layer_defs(self, idx: int) -> dict:
        cfg = self.cfg
        if self.is_rwkv:
            return _rwkv_layer_defs(cfg)
        if self.is_mamba:  # hybrid backbone or pure mamba
            return _mamba_layer_defs(cfg)
        moe_layer = cfg.moe is not None and idx >= cfg.moe.first_k_dense
        return _dense_layer_defs(cfg, moe_layer)

    def n_scan(self) -> int:
        cfg = self.cfg
        if self.is_hybrid:
            return 0
        return cfg.num_layers - (cfg.moe.first_k_dense if cfg.moe else 0)

    def shared_positions(self) -> list[int]:
        cfg = self.cfg
        if not self.is_hybrid or not cfg.attn_every:
            return []
        return [i for i in range(cfg.num_layers) if i % cfg.attn_every == 0]

    def _hybrid_split(self, layers):
        """Split the (L, ...) layer stack into scanned segments + python tail.

        Segment = [shared attn+MLP block, then attn_every mamba layers]; the
        shared block's weights are closure constants, so scanning segments is
        exact and cuts compile time ~attn_every-fold vs an unrolled loop.
        """
        cfg = self.cfg
        k = cfg.attn_every
        n_seg = cfg.num_layers // k
        n_tail = cfg.num_layers - n_seg * k
        seg = jax.tree.map(lambda a: a[: n_seg * k].reshape(n_seg, k, *a.shape[1:]), layers)
        tail = jax.tree.map(lambda a: a[n_seg * k :], layers)
        return seg, tail, n_seg, n_tail

    def param_defs(self) -> dict:
        cfg = self.cfg
        defs: dict[str, Any] = {
            "embed": embed_defs(cfg.vocab_size, cfg.d_model),
            "final_norm": norm_defs(cfg.d_model, cfg.norm),
        }
        if not cfg.tie_embeddings:
            defs["lm_head"] = head_defs(cfg.d_model, cfg.vocab_size)
        if self.is_hybrid:
            defs["layers"] = jax.tree.map(
                lambda pd: stacked(pd, cfg.num_layers),
                self._layer_defs(0),
                is_leaf=lambda x: isinstance(x, PD),
            )
            defs["shared"] = _shared_block_defs(cfg)
        else:
            n_head = cfg.moe.first_k_dense if cfg.moe else 0
            if n_head:
                defs["head_layers"] = {str(i): self._layer_defs(i) for i in range(n_head)}
            defs["layers"] = jax.tree.map(
                lambda pd: stacked(pd, self.n_scan()),
                self._layer_defs(n_head),
                is_leaf=lambda x: isinstance(x, PD),
            )
        return defs

    def init(self, key) -> dict:
        return init_params(self.param_defs(), key, self.cfg.pdtype)

    def pspecs(self):
        return param_specs(self.param_defs())

    def pshapes(self):
        return param_shapes(self.param_defs(), self.cfg.pdtype)

    def param_count(self) -> int:
        from repro.models.params import count_params

        return count_params(self.param_defs())

    # ------------------------------------------------------------ embeddings
    def _inputs_to_hidden(self, params, batch) -> jax.Array:
        cfg = self.cfg
        if cfg.input_mode == "embeds" and "embeds" in batch:
            x = batch["embeds"].astype(cfg.compute_dtype)
        else:
            x = embed_lookup(params["embed"], batch["tokens"], cfg.compute_dtype)
        return shard(x, "dp", "sp", None)

    def _head(self, params, x) -> jax.Array:
        p = params.get("lm_head")
        if p is None:  # tied
            p = {"w": params["embed"]["tok"].T}
        return lm_logits(p, x, jnp.float32)

    # ---------------------------------------------------------------- forward
    def forward(self, params, batch, remat: str | None = None):
        """Training forward: returns (final hidden (B,S,D), aux)."""
        cfg = self.cfg
        x = self._inputs_to_hidden(params, batch)

        policy = None
        if remat and remat != "none":
            policy = (
                jax.checkpoint_policies.nothing_saveable
                if remat == "full"
                else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            )

        if self.is_hybrid:
            seg, tail, n_seg, n_tail = self._hybrid_split(params["layers"])
            k = cfg.attn_every

            def seg_body(h, lp):
                h = _apply_shared_block(cfg, params["shared"], h, "train")[0]
                for j in range(k):
                    ljp = jax.tree.map(lambda a: a[j], lp)
                    h = _apply_mamba_layer(cfg, ljp, h, "train")[0]
                return h, None

            body = seg_body
            if policy is not None:
                body = jax.checkpoint(seg_body, policy=policy, prevent_cse=False)
            if n_seg:
                x, _ = jax.lax.scan(body, x, seg)
            if n_tail:
                x = _apply_shared_block(cfg, params["shared"], x, "train")[0]
                for j in range(n_tail):
                    ljp = jax.tree.map(lambda a: a[j], tail)
                    x = _apply_mamba_layer(cfg, ljp, x, "train")[0]
            aux = zero_aux()
        else:
            head_fn = lambda hp, h: _apply_dense_layer(cfg, hp, h, "train")[0]  # noqa: E731
            if policy is not None:
                head_fn = jax.checkpoint(head_fn, policy=policy, prevent_cse=False)
            for i in range(cfg.moe.first_k_dense if cfg.moe else 0):
                x = head_fn(params["head_layers"][str(i)], x)

            def body(carry, lp):
                x, aux = carry
                if self.is_rwkv:
                    x, _ = _apply_rwkv_layer(cfg, lp, x, "train")
                    a = zero_aux()
                else:
                    x, _, a = _apply_dense_layer(cfg, lp, x, "train")
                aux = {k: aux[k] + a[k] for k in aux}
                return (x, aux), None

            if policy is not None:
                body = jax.checkpoint(body, policy=policy, prevent_cse=False)
            (x, aux), _ = jax.lax.scan(body, (x, zero_aux()), params["layers"])
            aux = {k: v / max(self.n_scan(), 1) for k, v in aux.items()}

        x = apply_norm(params["final_norm"], x)
        return x, aux

    def loss(self, params, batch, remat: str | None = None):
        """Next-token CE with sequence-chunked logits (bounds logits memory)."""
        cfg = self.cfg
        x, aux = self.forward(params, batch, remat=remat)
        labels = batch["labels"]  # (B, S), -1 = ignore
        B, S, D = x.shape
        chunk = min(cfg.loss_chunk, S)
        assert S % chunk == 0
        nc = S // chunk
        xs = jnp.moveaxis(x.reshape(B, nc, chunk, D), 1, 0)
        ys = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def chunk_loss(carry, inp):
            xc, yc = inp
            logits = self._head(params, xc)  # (B, chunk, V) fp32
            logz = jax.nn.logsumexp(logits, axis=-1)
            safe = jnp.maximum(yc, 0)
            gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
            valid = (yc >= 0).astype(jnp.float32)
            nll = ((logz - gold) * valid).sum()
            hit = ((jnp.argmax(logits, -1) == yc) * valid).sum()
            t, n, h = carry
            return (t + nll, n + valid.sum(), h + hit), None

        (tot, n, hits), _ = jax.lax.scan(chunk_loss, (0.0, 0.0, 0.0), (xs, ys))
        n = jnp.maximum(n, 1.0)
        ce = tot / n
        loss = ce
        if cfg.moe is not None:
            loss = loss + cfg.moe.aux_loss_coef * (aux["moe_lb_loss"] + aux["moe_z_loss"])
        metrics = {"loss": loss, "ce": ce, "accuracy": hits / n, **aux}
        return loss, metrics

    # ---------------------------------------------------------------- prefill
    def prefill(self, params, batch, max_len: int, cp: bool = False):
        """Returns (last-token logits (B, V), decode-ready cache)."""
        cfg = self.cfg
        x = self._inputs_to_hidden(params, batch)
        B, S, _ = x.shape

        if self.is_hybrid:
            seg, tail, n_seg, n_tail = self._hybrid_split(params["layers"])
            k = cfg.attn_every

            def seg_body(h, lp):
                h, sh = _apply_shared_block(cfg, params["shared"], h, "prefill", max_len=max_len, cp=cp)
                states = []
                for j in range(k):
                    ljp = jax.tree.map(lambda a: a[j], lp)
                    h, st = _apply_mamba_layer(cfg, ljp, h, "prefill")
                    states.append(st)
                stacked_st = jax.tree.map(lambda *a: jnp.stack(a), *states)
                return h, {"shared": sh, "mamba": stacked_st}

            cache = {}
            if n_seg:
                x, seg_caches = jax.lax.scan(seg_body, x, seg)
                cache["seg"] = seg_caches
            if n_tail:
                x, sh = _apply_shared_block(cfg, params["shared"], x, "prefill", max_len=max_len, cp=cp)
                states = []
                for j in range(n_tail):
                    ljp = jax.tree.map(lambda a: a[j], tail)
                    x, st = _apply_mamba_layer(cfg, ljp, x, "prefill")
                    states.append(st)
                cache["tail"] = {"shared": sh, "mamba": tuple(states)}
        else:
            head_caches = {}
            for i in range(cfg.moe.first_k_dense if cfg.moe else 0):
                x, c, _ = _apply_dense_layer(
                    cfg, params["head_layers"][str(i)], x, "prefill", max_len=max_len, cp=cp
                )
                head_caches[str(i)] = c

            def body(x, lp):
                if self.is_rwkv:
                    x, st = _apply_rwkv_layer(cfg, lp, x, "prefill")
                else:
                    x, st, _ = _apply_dense_layer(cfg, lp, x, "prefill", max_len=max_len, cp=cp)
                return x, st

            x, scan_caches = jax.lax.scan(body, x, params["layers"])
            cache = {"layers": scan_caches}
            if head_caches:
                cache["head_layers"] = head_caches

        x = apply_norm(params["final_norm"], x)
        logits = self._head(params, x[:, -1])  # (B, V)
        cache["pos"] = jnp.array(S, jnp.int32)
        return logits, cache

    def init_cache(self, batch_size: int, max_len: int, cp: bool = False) -> dict:
        """Zeroed cache for decode-from-scratch (or dry-run decode lowering)."""
        cfg = self.cfg
        if self.is_hybrid:
            k = cfg.attn_every
            n_seg = cfg.num_layers // k
            n_tail = cfg.num_layers - n_seg * k
            m1 = mamba2.init_mamba2_state(cfg, batch_size)
            a1 = attn.init_attn_cache(cfg, batch_size, max_len, cp=cp)
            cache = {}
            if n_seg:
                cache["seg"] = {
                    "shared": jax.tree.map(lambda a: jnp.broadcast_to(a, (n_seg, *a.shape)), a1),
                    "mamba": jax.tree.map(
                        lambda a: jnp.broadcast_to(a, (n_seg, k, *a.shape)), m1
                    ),
                }
            if n_tail:
                cache["tail"] = {
                    "shared": a1,
                    "mamba": tuple(
                        mamba2.init_mamba2_state(cfg, batch_size) for _ in range(n_tail)
                    ),
                }
        elif self.is_rwkv:
            one = rwkv6.init_rwkv6_state(cfg, batch_size)
            cache = {"layers": jax.tree.map(lambda a: jnp.broadcast_to(a, (self.n_scan(), *a.shape)), one)}
        else:
            n_head = cfg.moe.first_k_dense if cfg.moe else 0
            one = {"kv": attn.init_attn_cache(cfg, batch_size, max_len, cp=cp)}
            cache = {
                "layers": jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (self.n_scan(), *a.shape)).astype(a.dtype), one
                )
            }
            if n_head:
                cache["head_layers"] = {
                    str(i): {"kv": attn.init_attn_cache(cfg, batch_size, max_len, cp=cp)}
                    for i in range(n_head)
                }
        cache["pos"] = jnp.array(0, jnp.int32)
        return cache

    # ----------------------------------------------------------------- decode
    def decode_step(self, params, cache, tokens, cp: bool = False):
        """One autoregressive step. tokens: (B, 1) int32 -> (logits (B,V), cache)."""
        cfg = self.cfg
        pos = cache["pos"]
        x = embed_lookup(params["embed"], tokens, cfg.compute_dtype)

        if self.is_hybrid:
            seg, tail, n_seg, n_tail = self._hybrid_split(params["layers"])
            k = cfg.attn_every

            def seg_body(h, inp):
                lp, c = inp
                h, sh = _apply_shared_block(
                    cfg, params["shared"], h, "decode", cache=c["shared"], pos=pos, cp=cp
                )
                states = []
                for j in range(k):
                    ljp = jax.tree.map(lambda a: a[j], lp)
                    cj = jax.tree.map(lambda a: a[j], c["mamba"])
                    h, st = _apply_mamba_layer(cfg, ljp, h, "decode", cache=cj)
                    states.append(st)
                stacked_st = jax.tree.map(lambda *a: jnp.stack(a), *states)
                return h, {"shared": sh, "mamba": stacked_st}

            new_cache = {}
            if n_seg:
                x, new_seg = jax.lax.scan(seg_body, x, (seg, cache["seg"]))
                new_cache["seg"] = new_seg
            if n_tail:
                x, sh = _apply_shared_block(
                    cfg, params["shared"], x, "decode", cache=cache["tail"]["shared"], pos=pos, cp=cp
                )
                states = []
                for j in range(n_tail):
                    ljp = jax.tree.map(lambda a: a[j], tail)
                    x, st = _apply_mamba_layer(
                        cfg, ljp, x, "decode", cache=cache["tail"]["mamba"][j]
                    )
                    states.append(st)
                new_cache["tail"] = {"shared": sh, "mamba": tuple(states)}
        else:
            new_head = {}
            for i in range(cfg.moe.first_k_dense if cfg.moe else 0):
                x, c, _ = _apply_dense_layer(
                    cfg,
                    params["head_layers"][str(i)],
                    x,
                    "decode",
                    cache=cache["head_layers"][str(i)],
                    pos=pos,
                    cp=cp,
                )
                new_head[str(i)] = c

            def body(x, inp):
                lp, lc = inp
                if self.is_rwkv:
                    x, st = _apply_rwkv_layer(cfg, lp, x, "decode", cache=lc)
                else:
                    x, st, _ = _apply_dense_layer(cfg, lp, x, "decode", cache=lc, pos=pos, cp=cp)
                return x, st

            x, scan_caches = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
            new_cache = {"layers": scan_caches}
            if new_head:
                new_cache["head_layers"] = new_head

        x = apply_norm(params["final_norm"], x)
        logits = self._head(params, x[:, 0])
        new_cache["pos"] = pos + 1
        return logits, new_cache

    # ----------------------------------------------------- cache sharding spec
    def cache_pspecs(self, cp: bool = False):
        """PartitionSpec tree matching init_cache structure (for pjit shardings).

        Leaves are PartitionSpec (resolved under the current sharding rules);
        built by name-mapping the per-component logical spec dicts.
        """
        from repro.parallel.axes import logical_spec

        def _is_axes(t) -> bool:
            # a logical-axes tuple: entries are names, None, or tuples of names
            return isinstance(t, tuple) and all(
                isinstance(n, (str, type(None)))
                or (isinstance(n, tuple) and all(isinstance(m, str) for m in n))
                for n in t
            )

        def to_p(spec_tree):
            return jax.tree.map(lambda names: logical_spec(*names), spec_tree, is_leaf=_is_axes)

        cfg = self.cfg
        if self.is_hybrid:
            m = mamba2.mamba2_state_specs(cfg)
            a = attn.attn_cache_specs(cfg, cp=cp)
            is_t = lambda t: isinstance(t, tuple)  # noqa: E731
            k = cfg.attn_every
            n_seg = cfg.num_layers // k
            n_tail = cfg.num_layers - n_seg * k
            cache = {}
            if n_seg:
                cache["seg"] = {
                    "shared": to_p(jax.tree.map(lambda t: (None, *t), a, is_leaf=is_t)),
                    "mamba": to_p(jax.tree.map(lambda t: (None, None, *t), m, is_leaf=is_t)),
                }
            if n_tail:
                cache["tail"] = {
                    "shared": to_p(a),
                    "mamba": tuple(to_p(m) for _ in range(n_tail)),
                }
        elif self.is_rwkv:
            s = rwkv6.rwkv6_state_specs(cfg)
            stacked_s = jax.tree.map(
                lambda t: (None, *t), s, is_leaf=lambda t: isinstance(t, tuple)
            )
            cache = {"layers": to_p(stacked_s)}
        else:
            a = attn.attn_cache_specs(cfg, cp=cp)
            stacked_a = {
                "kv": to_p(
                    jax.tree.map(lambda t: (None, *t), a, is_leaf=lambda t: isinstance(t, tuple))
                )
            }
            cache = {"layers": stacked_a}
            n_head = cfg.moe.first_k_dense if cfg.moe else 0
            if n_head:
                cache["head_layers"] = {str(i): {"kv": to_p(a)} for i in range(n_head)}
        cache["pos"] = logical_spec()
        return cache

    def cache_shapes(self, batch_size: int, max_len: int, cp: bool = False):
        """ShapeDtypeStruct tree of the decode cache (no allocation; AOT)."""
        return jax.eval_shape(lambda: self.init_cache(batch_size, max_len, cp=cp))
