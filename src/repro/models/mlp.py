"""MLP block: gated (SiLU/GELU) or plain, Megatron column->row partitioned."""

from __future__ import annotations

import jax

from repro.models.layers import activation
from repro.models.params import PD
from repro.parallel.axes import shard


def mlp_defs(d: int, d_ff: int, gated: bool) -> dict:
    s = 0.02
    defs = {
        "wi": PD((d, d_ff), (None, "tp"), stddev=s),  # column-parallel
        "wo": PD((d_ff, d), ("tp", None), stddev=s),  # row-parallel
    }
    if gated:
        defs["wg"] = PD((d, d_ff), (None, "tp"), stddev=s)
    return defs


def apply_mlp(p: dict, x: jax.Array, act_name: str) -> jax.Array:
    """(B, S, D) or (T, D) -> same rank. One logical all-reduce after wo."""
    act = activation(act_name)
    dt = x.dtype
    h = x @ p["wi"].astype(dt)
    if "wg" in p:
        h = act(x @ p["wg"].astype(dt)) * h
    else:
        h = act(h)
    if x.ndim == 3:
        h = shard(h, "dp", None, "tp")
        return shard(h @ p["wo"].astype(dt), "dp", "sp", None)
    h = shard(h, "dp", "tp")
    return shard(h @ p["wo"].astype(dt), "dp", None)
