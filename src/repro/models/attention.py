"""GQA attention: dense, chunked (flash-style jnp), and decode-with-cache paths.

Why a chunked jnp path exists: at 32k+ sequence a dense (S, T) score tensor
cannot be materialized on any real device, and the dry-run's memory analysis
must prove the step *fits*. The chunked path is the TPU-native flash-attention
structure (online softmax over KV blocks) expressed with lax loops so XLA never
materializes more than (q_chunk, kv_chunk) scores; the Pallas kernel in
`repro.kernels.flash_attention` implements the same blocking in VMEM for the
real TPU target, and this path doubles as its distributed wrapper/reference.

Supports: GQA (Hq = G * Hkv), RoPE, qk-RMSNorm (qwen3), sliding-window (danube),
KV-cache prefill/decode with ring-buffer caches for SWA.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, rms_head_norm
from repro.models.params import PD
from repro.parallel.axes import shard

NEG_INF = -1e30


# ------------------------------------------------------------------ param defs
def attn_defs(cfg: ModelConfig, d_in: int | None = None) -> dict:
    d = d_in if d_in is not None else cfg.d_model
    s = 0.02
    defs = {
        "wq": PD((d, cfg.num_heads * cfg.head_dim), (None, "tp"), stddev=s),
        "wk": PD((d, cfg.num_kv_heads * cfg.head_dim), (None, "tp"), stddev=s),
        "wv": PD((d, cfg.num_kv_heads * cfg.head_dim), (None, "tp"), stddev=s),
        "wo": PD((cfg.num_heads * cfg.head_dim, d), ("tp", None), stddev=s),
    }
    if cfg.qk_norm:
        defs["q_norm"] = PD((cfg.head_dim,), (None,), init="ones", dtype=jnp.float32)
        defs["k_norm"] = PD((cfg.head_dim,), (None,), init="ones", dtype=jnp.float32)
    return defs


def _project_qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
    """x: (B, S, D) -> q (B,S,Hkv,G,dh), k/v (B,S,Hkv,dh), RoPE'd + qk-normed."""
    B, S, _ = x.shape
    Hq, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = Hq // Hkv
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, Hq, dh)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, Hkv, dh)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, Hkv, dh)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = q.reshape(B, S, Hkv, G, dh)
    # Heads shard over tp; seq stays unsharded here (Megatron SP applies only to
    # the norm/residual regions — sharding seq over the same mesh axis as heads
    # would be an illegal double use of the axis).
    q = shard(q, "dp", None, "tp", None, None)
    k = shard(k, "dp", None, "tp", None)
    v = shard(v, "dp", None, "tp", None)
    return q, k, v


# ------------------------------------------------------------- dense attention
def _dense_attention(q, k, v, q_pos, k_pos, window):
    """Reference O(S*T) attention. q: (B,S,Hkv,G,dh); k/v: (B,T,Hkv,dh)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    mask = k_pos[None, :] <= q_pos[:, None]  # causal
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out


# ----------------------------------------------------- chunked flash attention
def _chunked_attention(q, k, v, window, chunk, differentiable: bool = False):
    """Causal flash-style attention with online softmax over KV chunks.

    Never materializes more than (chunk, chunk) scores per (B, Hkv, G).
    q: (B, S, Hkv, G, dh); k, v: (B, S, Hkv, dh). Self-attention (q_pos == k_pos).

    `differentiable=True` (training): the inner KV loop is a static-bound scan
    over all chunks with masking — reverse-mode AD cannot differentiate a
    dynamic-bound fori_loop. Costs ~2x the causal-skipped flops on the score
    einsums; the Pallas kernel recovers the skip on real hardware. Inference
    paths keep the dynamic lower/upper bounds (causal + window skipping).
    """
    B, S, Hkv, G, dh = q.shape
    assert S % chunk == 0, (S, chunk)
    nq = S // chunk
    scale = dh**-0.5
    w_chunks = None if window is None else (window + chunk - 1) // chunk + 1

    qr = q.reshape(B, nq, chunk, Hkv, G, dh)

    def q_step(_, qi):
        qc = jax.lax.dynamic_index_in_dim(qr, qi, axis=1, keepdims=False)
        q_pos = qi * chunk + jnp.arange(chunk)

        def kv_block(carry, ki):
            m, l, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(k, ki * chunk, chunk, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, ki * chunk, chunk, axis=1)
            k_pos = ki * chunk + jnp.arange(chunk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc, preferred_element_type=jnp.float32)
            s = s * scale
            mask = k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m2 = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m2[..., None])
            corr = jnp.exp(m - m2)
            l2 = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc)
            acc2 = acc * corr[..., None].astype(acc.dtype) + pv
            return (m2, l2, acc2), None

        init = (
            jnp.full((B, Hkv, G, chunk), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, G, chunk), jnp.float32),
            jnp.zeros((B, Hkv, G, chunk, dh), v.dtype),
        )
        if differentiable:
            (m, l, acc), _ = jax.lax.scan(kv_block, init, jnp.arange(nq))
        else:
            body = lambda ki, c: kv_block(c, ki)[0]  # noqa: E731
            lo = 0 if w_chunks is None else jnp.maximum(0, qi + 1 - w_chunks)
            m, l, acc = jax.lax.fori_loop(lo, qi + 1, body, init)
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        # (B, Hkv, G, chunk, dh) -> (B, chunk, Hkv, G, dh)
        return None, jnp.moveaxis(out, 3, 1)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))
    # outs: (nq, B, chunk, Hkv, G, dh) -> (B, S, Hkv, G, dh)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, Hkv, G, dh)
    return out


def self_attention(cfg: ModelConfig, p: dict, x: jax.Array, positions=None) -> jax.Array:
    """Full-sequence causal attention (training: differentiable paths only)."""
    B, S, _ = x.shape
    pos = jnp.arange(S) if positions is None else positions
    q, k, v = _project_qkv(cfg, p, x, pos)
    if cfg.attn_impl == "dense" or S <= cfg.attn_chunk:
        out = _dense_attention(q, k, v, pos, pos, cfg.sliding_window)
    elif cfg.attn_impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(q, k, v, window=cfg.sliding_window)
    else:
        # flash-attention custom VJP: O(S*d) residuals, scores recomputed in
        # bwd — a scan-based differentiable path would store every (c, c)
        # fp32 score block and blow HBM at 4k+ sequal lengths
        from repro.models.flash_vjp import flash_attention_vjp

        out = flash_attention_vjp(q, k, v, cfg.sliding_window, cfg.attn_chunk)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    out = out @ p["wo"].astype(out.dtype)
    return shard(out, "dp", "sp", None)


# ----------------------------------------------------------------- KV caching
def cache_len(cfg: ModelConfig, max_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, max_len)
    return max_len


def cache_axes(cfg: ModelConfig, cp: bool = False) -> tuple:
    """Logical axes for a (B, W, Hkv, dh) KV cache under the current mesh.

    KV heads shard over tp when they divide evenly; otherwise the tp axes move
    to the cache-length dim (sequence-sharded decode attention — GSPMD turns
    the softmax into the flash-decode partial max/sum all-reduce). Without the
    fallback, a kv=8 cache on a 16-way model axis would be *replicated* 16x,
    which is what made several decode cells burst past HBM in the first sweep.
    """
    from repro.parallel.axes import axes_size

    tp = axes_size("tp")
    heads_shardable = tp > 1 and cfg.num_kv_heads % tp == 0
    if heads_shardable:
        return ("dp", "cp" if cp else None, "tp", None)
    seq = ("cp", "tp") if cp else "tp"
    return ("dp", seq, None, None)


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, cp: bool = False) -> dict:
    """Zeroed KV cache, sharded per cache_axes."""
    W = cache_len(cfg, max_len)
    shp = (batch, W, cfg.num_kv_heads, cfg.head_dim)
    ax = cache_axes(cfg, cp)
    k = shard(jnp.zeros(shp, cfg.compute_dtype), *ax)
    v = shard(jnp.zeros(shp, cfg.compute_dtype), *ax)
    return {"k": k, "v": v}


def attn_cache_specs(cfg: ModelConfig, cp: bool = False):
    ax = cache_axes(cfg, cp)
    return {"k": ax, "v": ax}


def prefill_attention(cfg: ModelConfig, p: dict, x: jax.Array, max_len: int, cp: bool = False):
    """Full-seq attention that also returns a decode-ready KV cache.

    Token t lands in cache slot t (full) or t % W (ring buffer, SWA).
    """
    B, S, _ = x.shape
    pos = jnp.arange(S)
    q, k, v = _project_qkv(cfg, p, x, pos)
    if cfg.attn_impl == "dense" or S <= cfg.attn_chunk:
        out = _dense_attention(q, k, v, pos, pos, cfg.sliding_window)
    else:
        out = _chunked_attention(q, k, v, cfg.sliding_window, cfg.attn_chunk)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    out = shard(out @ p["wo"].astype(out.dtype), "dp", None, None)

    W = cache_len(cfg, max_len)
    cache = init_attn_cache(cfg, B, max_len, cp=cp)
    if cfg.sliding_window is not None and S > W:
        # keep last W tokens, permuted into ring order (slot = t mod W)
        tail_t = jnp.arange(S - W, S)
        ck = jnp.take(k, tail_t, axis=1)
        cv = jnp.take(v, tail_t, axis=1)
        slots = jnp.argsort(tail_t % W)
        cache = {"k": jnp.take(ck, slots, axis=1), "v": jnp.take(cv, slots, axis=1)}
    else:
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1),
        }
    ax = cache_axes(cfg, cp)
    cache = {kk: shard(vv, *ax) for kk, vv in cache.items()}
    return out, cache


def decode_attention(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict, pos: jax.Array, cp: bool = False):
    """One-token decode: q over the KV cache (the paper's skinny-GEMM regime).

    x: (B, 1, D); pos: scalar int32 = index of the current token (0-based).
    Returns (out (B,1,D), updated cache).
    """
    B, _, _ = x.shape
    Hq, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = Hq // Hkv
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(cfg, p, x, positions)

    K, V = cache["k"], cache["v"]
    W = K.shape[1]
    write = pos % W if cfg.sliding_window is not None else pos
    K = jax.lax.dynamic_update_slice(K, k, (0, write, 0, 0))
    V = jax.lax.dynamic_update_slice(V, v, (0, write, 0, 0))
    ax = cache_axes(cfg, cp)
    K = shard(K, *ax)
    V = shard(V, *ax)

    slot = jnp.arange(W)
    if cfg.sliding_window is not None:
        # slot i holds token t = pos - ((pos - i) mod W); valid iff t >= 0
        t = pos - jnp.mod(pos - slot, W)
        valid = t >= 0
    else:
        valid = slot <= pos

    scale = dh**-0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, K, preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    # softmax over a (possibly context-parallel-sharded) axis: GSPMD inserts the
    # flash-decode-style partial max/sum all-reduces automatically.
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", pr.astype(V.dtype), V)
    out = out.reshape(B, 1, Hq * dh) @ p["wo"].astype(x.dtype)
    return shard(out, "dp", None, None), {"k": K, "v": V}
