"""Training memory-footprint model (§3.3, §5.1 / Fig 4).

Activation sizes follow Korthikanti et al. [14] (the paper's reference):
per layer, fp16/bf16, MHA transformer:

    A_tot = s*b*h*(34 + 5*a*s/h)   bytes

with the tensor-parallel region divided by t, and the norm/dropout regions
divided by t only under sequence parallelism. Recomputation policies:

  * none       : L * A_tot
  * selective  : eq (2) — drop the softmax/dropout score terms (5*a*s^2*b)
  * full       : eq (1) — N_ckp layer-input checkpoints + one layer's working set

Weights/optimizer: mixed-precision training (2-byte weights/grads, fp32
master+m+v = 12 bytes) -> 16 bytes/param, divided by (t*p); optimizer part
further divided by dp under ZeRO-1; 8-bit optimizer states take 2 bytes + scales.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.core.operators import total_param_count


@dataclass(frozen=True)
class MemoryBreakdown:
    weights: float
    gradients: float
    optimizer: float
    activations: float

    @property
    def total(self) -> float:
        return self.weights + self.gradients + self.optimizer + self.activations

    def as_dict(self) -> dict:
        return {
            "weights": self.weights,
            "gradients": self.gradients,
            "optimizer": self.optimizer,
            "activations": self.activations,
            "total": self.total,
        }


def activation_per_layer(cfg: ModelConfig, b: int, s: int, tp: int, sp: bool,
                         prec: int = 2) -> dict:
    """Returns the per-layer activation terms (bytes) for one microbatch."""
    h = cfg.d_model
    a = cfg.num_heads
    # paper/[14] constants generalized to the config's mlp ratio & GQA
    kv_frac = cfg.num_kv_heads / max(cfg.num_heads, 1)
    mlp_ratio = cfg.d_ff / h * (1.5 if cfg.gated_mlp else 1.0)
    # tensor-parallel region (qkv/proj/mlp activations)
    tp_region = s * b * h * prec * (2 + 2 * kv_frac + 2 + 2 * 2 * mlp_ratio + 2)
    # norm/dropout/input region (10 s b h in [14])
    seq_region = s * b * h * prec * 5
    score_terms = {
        "softmax_in": a * s * s * b * prec,  # A_sm
        "dropout_mask": a * s * s * b * 1,  # A_do_mask
        "dropout_out": a * s * s * b * prec,  # A_do_out
        "scores_extra": 2 * a * s * s * b * prec,  # QK^T + attn-dropout input
    }
    moe_bytes = 0.0
    if cfg.moe is not None:
        # dispatch buffer + gathered rows + expert hidden (capacity-based MoE)
        m = cfg.moe
        tok = s * b * m.top_k * m.capacity_factor
        moe_bytes = prec * tok * (2 * h + m.d_ff)
    tp_div = max(tp, 1)
    seq_div = tp_div if sp else 1
    return {
        "moe": moe_bytes / tp_div,
        "tp_region": tp_region / tp_div,
        "seq_region": seq_region / seq_div,
        "scores": sum(score_terms.values()) / tp_div,
        "A_sm": score_terms["softmax_in"] / tp_div,
        "A_do_mask": score_terms["dropout_mask"] / tp_div,
        "A_do_out": score_terms["dropout_out"] / tp_div,
        "A_inp": s * b * h * prec / seq_div,
    }


def activation_memory(cfg: ModelConfig, b: int, s: int, tp: int, sp: bool,
                      recompute: str, *, n_ckp: int | None = None, prec: int = 2,
                      layers: int | None = None) -> float:
    """Total activation bytes per device for one in-flight microbatch."""
    L = layers if layers is not None else cfg.num_layers
    t = activation_per_layer(cfg, b, s, tp, sp, prec)
    a_tot = t["tp_region"] + t["seq_region"] + t["scores"] + t["moe"]
    a_inp = t["A_inp"]
    if recompute == "none":
        return L * a_tot
    if recompute == "selective":
        # eq (2): A_sel = L (A_tot - (A_sm + A_do_mask + A_do_out))
        return L * (a_tot - (t["A_sm"] + t["A_do_mask"] + t["A_do_out"]))
    if recompute == "full":
        # eq (1): A_full = N_ckp A_inp + L/N_ckp (A_tot - A_inp)
        n = n_ckp or L
        return n * a_inp + (L / n) * (a_tot - a_inp)
    raise ValueError(recompute)


def weight_optimizer_memory(cfg: ModelConfig, tp: int, pp: int, dp: int = 1, *,
                            zero1: bool = False, opt_8bit: bool = False,
                            prec: int = 2) -> tuple[float, float, float]:
    """(weights, gradients, optimizer) bytes per device."""
    P = total_param_count(cfg) / (tp * pp)
    if cfg.moe is not None and cfg.moe.shard_ff_dp:
        # expert ffn weights additionally sharded over the data axes
        m = cfg.moe
        n_mm = 3 if cfg.gated_mlp else 2
        expert = m.num_experts * n_mm * cfg.d_model * m.d_ff * cfg.num_layers / (tp * pp)
        P = (P - expert) + expert / max(dp, 1)
    weights = P * prec
    grads = P * prec if not zero1 else P * 4.0 / max(dp, 1)  # fp32, ZeRO-sharded
    opt_bytes_per_param = (2.0 + 2.1) if opt_8bit else 12.0
    opt = P * opt_bytes_per_param
    if zero1:
        opt /= max(dp, 1)
    return weights, grads, opt


def training_memory(cfg: ModelConfig, *, global_batch: int, seq: int, dp: int, tp: int,
                    pp: int, sp: bool, microbatch: int, recompute: str,
                    zero1: bool = False, opt_8bit: bool = False, prec: int = 2,
                    schedule: str = "1f1b") -> MemoryBreakdown:
    w, g, o = weight_optimizer_memory(cfg, tp, pp, dp, zero1=zero1, opt_8bit=opt_8bit,
                                      prec=prec)
    layers_per_stage = max(cfg.num_layers // pp, 1)
    # in-flight microbatches: 1F1B holds p microbatches on stage 0; GPipe holds m
    m = max(global_batch // (dp * microbatch), 1)
    in_flight = min(pp, m) if schedule in ("1f1b", "interleaved") else m
    act = activation_memory(cfg, microbatch, seq, tp, sp, recompute, prec=prec,
                            layers=layers_per_stage) * in_flight
    return MemoryBreakdown(w, g, o, act)
