"""Design-space exploration (§3.6, §5.3): technology-node scaling + search.

µArch template: a chip of fixed area/power budget split between compute cores
and on-chip SRAM (L2). Logic scaling between consecutive nodes follows the
paper's iso-performance assumption [3, 29]: the same performance costs 1/1.8
the area and 1/1.3 the power — i.e. compute *density* rises 1.8x/node while
power density rises 1.8/1.3 = 1.38x/node (the dark-silicon squeeze). SRAM
density scales slower (1.4x/node — recorded assumption, SRAM scaling has
lagged logic since N7). DRAM technology and inter-node network are discrete
choices (HBM2..HBM4, NDR/XDR/GDR).

The DSE searches the area split f_core (coordinate descent with golden-section
refinement — the paper uses gradient descent; the objective is 1-D smooth here)
to minimize predicted training time. Reproduces Fig 6's saturation beyond N5
(compute-bound -> DRAM-bound) and the HBM2->HBM2E gain vs HBM3/4 network-bound
plateau, and Fig 7's bound-type shift.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.core.hardware import (
    DRAM_TECH,
    GDR_X8,
    HardwareSpec,
    MemLevel,
    NDR_X8,
    NVLINK3,
    XDR_X8,
    TB,
)
from repro.core.parallelism import Mapping
from repro.core.predict import train_step_time

NODES = ["N12", "N7", "N5", "N3", "N2", "N1.5", "N1"]
AREA_SCALE = 1.8
POWER_SCALE = 1.3
SRAM_SCALE = 1.4

# calibration anchor: N7 ~ A100 (826 mm^2, 400 W, 312 TF bf16, 40 MB L2)
_ANCHOR_NODE = 1  # N7
_AREA = 826.0  # mm^2
_POWER = 400.0  # W
_CORE_DENSITY_N7 = 312e12 / (_AREA * 0.5)  # FLOP/s per mm^2 at 50% core area
_W_PER_FLOPS_N7 = (_POWER * 0.6) / 312e12  # core W per FLOP/s at N7
_SRAM_DENSITY_N7 = 40e6 / (_AREA * 0.25)  # bytes per mm^2 at 25% L2 area
_L2_BW_PER_BYTE = 4.8 * TB / 40e6  # L2 bandwidth per byte of capacity (A100)

NETS = {"NDR-x8": NDR_X8, "XDR-x8": XDR_X8, "GDR-x8": GDR_X8}


def build_chip(node: str, f_core: float, dram: str, net: str) -> HardwareSpec:
    """Materialize a HardwareSpec from (tech node, area split, DRAM, network)."""
    k = NODES.index(node) - _ANCHOR_NODE
    core_density = _CORE_DENSITY_N7 * AREA_SCALE**k
    w_per_flops = _W_PER_FLOPS_N7 / POWER_SCALE**k
    sram_density = _SRAM_DENSITY_N7 * SRAM_SCALE**k

    f_l2 = max(1.0 - f_core - 0.25, 0.05)  # 25% fixed (PHY/NoC/misc)
    flops_area = _AREA * f_core * core_density
    flops_power = (_POWER * 0.75) / w_per_flops  # 75% of socket power to cores
    flops = min(flops_area, flops_power)

    l2_cap = _AREA * f_l2 * sram_density
    l2_bw = l2_cap * _L2_BW_PER_BYTE * min(1.0, (1.2**k))

    return HardwareSpec(
        name=f"{node}-{dram}-{net}",
        flops={"bf16": flops, "fp16": flops, "fp32": flops / 16},
        mem=(
            MemLevel(dram, 80e9, DRAM_TECH[dram], util=0.8),
            MemLevel("L2", l2_cap, l2_bw, util=0.8),
        ),
        net=(NVLINK3, NETS[net]),
        compute_util=0.61,
        gemv_dram_util=0.72,
    )


@dataclass
class DSEPoint:
    node: str
    dram: str
    net: str
    f_core: float
    time: float
    flops: float
    l2_capacity: float


def optimize_node(cfg: ModelConfig, node: str, dram: str, net: str, *,
                  mapping: Mapping, global_batch: int, seq: int,
                  iters: int = 12) -> DSEPoint:
    """Golden-section search over the core/L2 area split (§3.6's constrained
    optimization; 1-D once the budgets are fixed)."""

    def objective(f_core: float) -> float:
        hw = build_chip(node, f_core, dram, net)
        return train_step_time(cfg, hw, mapping, global_batch=global_batch, seq=seq).total

    lo, hi = 0.15, 0.72
    phi = 0.6180339887498949
    a, b = hi - phi * (hi - lo), lo + phi * (hi - lo)
    fa, fb = objective(a), objective(b)
    for _ in range(iters):
        if fa < fb:
            hi, b, fb = b, a, fa
            a = hi - phi * (hi - lo)
            fa = objective(a)
        else:
            lo, a, fa = a, b, fb
            b = lo + phi * (hi - lo)
            fb = objective(b)
    f = a if fa < fb else b
    t = min(fa, fb)
    hw = build_chip(node, f, dram, net)
    return DSEPoint(node, dram, net, f, t, hw.flops["bf16"], hw.l2.capacity)


def sweep(cfg: ModelConfig, *, mapping: Mapping, global_batch: int, seq: int,
          drams=("HBM2", "HBM2E", "HBM3", "HBM4"),
          nets=("NDR-x8", "XDR-x8", "GDR-x8"), nodes=None) -> list[DSEPoint]:
    out = []
    for node in nodes or NODES:
        for dram in drams:
            for net in nets:
                out.append(
                    optimize_node(cfg, node, dram, net, mapping=mapping,
                                  global_batch=global_batch, seq=seq)
                )
    return out
