"""repro.core — the Optimus analytical performance model (the paper's
contribution): hierarchical-roofline operator timing, parallelism + collective
models, memory-footprint models, KV-cache model, DSE, and the auto-parallelism
planner. Pure Python/numpy — importing this package never touches jax device
state (safe inside the dry-run process before XLA_FLAGS are consumed).
"""

from repro.core.hardware import HardwareSpec, get_hardware  # noqa: F401
