"""Compiled-HLO analysis: collective inventory + loop-aware cost accounting.

XLA's `compiled.cost_analysis()` counts a `while` body ONCE regardless of trip
count (verified empirically — see EXPERIMENTS.md §Methodology), and our models
deliberately use scan-over-layers / chunked-attention loops so 32k-sequence
steps fit in memory. This module therefore:

  * parses the post-SPMD HLO text into computations,
  * inventories every collective (all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute) with its result bytes,
  * marks whether each sits inside a while body (loop-resident), so the
    roofline layer can apply the *known* trip counts (num scanned layers,
    chunk counts) that the HLO itself cannot carry.

The authoritative FLOP/byte numbers for §Roofline come from the analytic
operator graph in `repro.core.operators` (the paper's own methodology); the
raw cost_analysis numbers are recorded alongside as a cross-check.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes in an HLO result type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveOp:
    kind: str
    bytes: int
    shape: str
    op_name: str
    loop_depth: int  # number of enclosing while bodies (from JAX metadata)

    @property
    def in_loop(self) -> bool:
        return self.loop_depth > 0


_OP_NAME_RE = re.compile(r'op_name="([^"]*)"')


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    """Inventory collectives using JAX-emitted metadata for loop residency.

    Every op lowered from inside a lax.scan/while carries
    `metadata={op_name=".../while/body/..."}`; the count of "while/body"
    segments gives the loop-nesting depth (e.g. a TP all-reduce inside the
    chunked-attention scan inside the layer scan has depth 2).
    """
    ops: list[CollectiveOp] = []
    for ln in hlo_text.splitlines():
        # skip async -done halves so -start/-done pairs count once
        if "-done(" in ln:
            continue
        for kind in COLLECTIVE_KINDS:
            if f" {kind}(" in ln or f" {kind}-start(" in ln:
                rhs = ln.split("=", 1)[1] if "=" in ln else ln
                shape_part = rhs.split(kind + "(")[0].split(kind + "-start(")[0]
                m = _OP_NAME_RE.search(ln)
                op_name = m.group(1) if m else ""
                ops.append(
                    CollectiveOp(
                        kind=kind,
                        bytes=_shape_bytes(shape_part),
                        shape=shape_part.strip(),
                        op_name=op_name,
                        loop_depth=op_name.count("while/body"),
                    )
                )
                break
    return ops


def collective_summary(hlo_text: str) -> dict:
    """Aggregated collective stats for a compiled module (per-device bytes)."""
    ops = parse_collectives(hlo_text)
    agg: dict[tuple[str, int], dict] = {}
    for op in ops:
        k = (op.kind, op.loop_depth)
        a = agg.setdefault(
            k, {"kind": op.kind, "loop_depth": op.loop_depth, "count": 0, "bytes": 0}
        )
        a["count"] += 1
        a["bytes"] += op.bytes
    out = [a for _, a in
           sorted(agg.items(), key=lambda kv: (-kv[1]["bytes"], kv[0]))]
    return {
        "ops": out,
        "once_bytes": sum(a["bytes"] for a in out if a["loop_depth"] == 0),
        "loop_bytes_per_iter": sum(a["bytes"] for a in out if a["loop_depth"] > 0),
        "n_ops": len(ops),
    }


def collective_traffic_bytes(summary: dict, trip_counts: dict[int, int] | int) -> int:
    """Total per-device collective bytes with loop-resident ops multiplied.

    `trip_counts`: either a single multiplier for all loop-resident ops, or a
    {depth: multiplier} map (depth-2 ops get e.g. L * n_chunks).
    """
    total = summary["once_bytes"]
    for a in summary["ops"]:
        d = a["loop_depth"]
        if d == 0:
            continue
        mult = trip_counts if isinstance(trip_counts, int) else trip_counts.get(d, 1)
        total += a["bytes"] * mult
    return int(total)
