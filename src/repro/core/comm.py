"""Collective communication models (§3.4): ring (eq. 3) and double binary
tree (eq. 4), plus derived costs for reduce-scatter / all-gather / all-to-all
and point-to-point pipeline sends.

K is the *global* data volume participating in the collective; BW is the
per-device link bandwidth; l the per-hop latency.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.hardware import NetLevel


def ring_allreduce(K: float, N: int, net: NetLevel) -> float:
    """Eq. (3): T = 2K(N-1)/(N*BW) + 2l(N-1)."""
    if N <= 1:
        return 0.0
    bw = net.bw * net.util
    return 2.0 * K * (N - 1) / (N * bw) + 2.0 * net.latency * (N - 1)


def tree_allreduce(K: float, N: int, net: NetLevel) -> float:
    """Eq. (4): double binary tree — bandwidth term of ring, log2 latency."""
    if N <= 1:
        return 0.0
    bw = net.bw * net.util
    return 2.0 * K * (N - 1) / (N * bw) + 2.0 * net.latency * math.log2(N)


def allreduce(K: float, N: int, net: NetLevel, *, algo: str = "auto") -> float:
    """Paper's policy: ring for data-intensive (training), tree when the
    latency term matters (inference's small volumes, §3.4)."""
    if algo == "ring":
        return ring_allreduce(K, N, net)
    if algo == "tree":
        return tree_allreduce(K, N, net)
    return min(ring_allreduce(K, N, net), tree_allreduce(K, N, net))


def reduce_scatter(K: float, N: int, net: NetLevel) -> float:
    if N <= 1:
        return 0.0
    bw = net.bw * net.util
    return K * (N - 1) / (N * bw) + net.latency * (N - 1)


def all_gather(K: float, N: int, net: NetLevel) -> float:
    return reduce_scatter(K, N, net)


def all_to_all(K: float, N: int, net: NetLevel) -> float:
    """Each device exchanges K/N with every peer: K(N-1)/(N*BW) + l(N-1)."""
    if N <= 1:
        return 0.0
    bw = net.bw * net.util
    return K * (N - 1) / (N * bw) + net.latency * (N - 1)


def p2p(K: float, net: NetLevel) -> float:
    """Point-to-point activation send (pipeline stage boundary)."""
    return K / (net.bw * net.util) + net.latency


@dataclass(frozen=True)
class CommEvent:
    """A collective the mapping induces, with the level it runs on."""

    name: str
    kind: str  # allreduce | reduce_scatter | all_gather | all_to_all | p2p
    bytes: float  # global volume K
    group: int  # N
    net: NetLevel
    algo: str = "auto"

    def time(self) -> float:
        if self.kind == "allreduce":
            return allreduce(self.bytes, self.group, self.net, algo=self.algo)
        if self.kind == "reduce_scatter":
            return reduce_scatter(self.bytes, self.group, self.net)
        if self.kind == "all_gather":
            return all_gather(self.bytes, self.group, self.net)
        if self.kind == "all_to_all":
            return all_to_all(self.bytes, self.group, self.net)
        if self.kind == "p2p":
            return p2p(self.bytes, self.net)
        raise ValueError(self.kind)
