"""Published data from the paper, for validation benchmarks.

Table 1  — Megatron A100 training times per batch ([28]/[14] as reported).
Table 2  — NVIDIA Llama-2 inference latencies (A100 / H100), 200+200 tokens.
Table 4  — GEMM-level bound types, Llama2-13B prefill, A100 vs H100.
Model configs: GPT family (Megatron papers), Llama-2 family.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig


def _gpt(name, L, h, a, vocab=51200) -> ModelConfig:
    return ModelConfig(
        name=name, family="dense", num_layers=L, d_model=h, num_heads=a,
        num_kv_heads=a, head_dim=h // a, d_ff=4 * h, vocab_size=vocab,
        norm="layernorm", act="gelu", gated_mlp=False,
    )


GPT_CONFIGS = {
    "gpt-7b": _gpt("gpt-7b", 32, 4096, 32),
    "gpt-22b": _gpt("gpt-22b", 48, 6144, 64),
    "gpt-175b": _gpt("gpt-175b", 96, 12288, 96),
    "gpt-310b": _gpt("gpt-310b", 96, 16384, 128),
    "gpt-530b": _gpt("gpt-530b", 105, 20480, 128),
    "gpt-1008b": _gpt("gpt-1008b", 128, 25600, 160),
}


def _llama2(name, L, h, a, kv, ff) -> ModelConfig:
    return ModelConfig(
        name=name, family="dense", num_layers=L, d_model=h, num_heads=a,
        num_kv_heads=kv, head_dim=h // a, d_ff=ff, vocab_size=32000,
        norm="rmsnorm", act="silu", gated_mlp=True,
    )


LLAMA2_CONFIGS = {
    "llama2-7b": _llama2("llama2-7b", 32, 4096, 32, 32, 11008),
    "llama2-13b": _llama2("llama2-13b", 40, 5120, 40, 40, 13824),
    "llama2-70b": _llama2("llama2-70b", 80, 8192, 64, 8, 28672),
}


@dataclass(frozen=True)
class Table1Row:
    model: str
    gpus: int
    batch: int
    dp: int
    tp: int
    pp: int
    sp: bool
    recompute: str
    t_ref: float  # seconds per batch, as published
    t_paper_pred: float  # the paper's own prediction


# seq 2048 for all rows
TABLE1 = [
    # ---- only TP and PP, full recompute ([28]) ----
    Table1Row("gpt-22b", 8, 4, 1, 8, 8 // 8, False, "full", 1.4, 1.4),
    Table1Row("gpt-175b", 64, 64, 1, 8, 8, False, "full", 18.1, 16.9),
    Table1Row("gpt-530b", 280, 280, 1, 8, 35, False, "full", 49.1, 46.8),
    Table1Row("gpt-1008b", 512, 512, 1, 8, 64, False, "full", 94.4, 87.9),
    # ---- TP, PP and SP, selective recompute ([14]) ----
    Table1Row("gpt-22b", 8, 4, 1, 8, 1, True, "selective", 1.1, 1.1),
    Table1Row("gpt-175b", 64, 64, 1, 8, 8, True, "selective", 13.8, 12.9),
    Table1Row("gpt-530b", 280, 280, 1, 8, 35, True, "selective", 37.8, 35.5),
    Table1Row("gpt-1008b", 512, 512, 1, 8, 64, True, "selective", 71.5, 69.1),
    # ---- DP, TP and PP, full recompute ([28]) ----
    Table1Row("gpt-310b", 1920, 2160, 15, 8, 16, False, "full", 37.6, 34.1),
    Table1Row("gpt-530b", 2520, 2520, 9, 8, 35, False, "full", 54.2, 51.2),
    Table1Row("gpt-1008b", 3072, 3072, 6, 8, 64, False, "full", 102.4, 100.7),
]


@dataclass(frozen=True)
class Table2Row:
    model: str
    gpus: int
    tp: int
    t_a100_ms: float
    t_a100_paper_pred: float
    t_h100_ms: float
    t_h100_paper_pred: float


# batch 1, prompt 200, gen 200 (§4.3)
TABLE2 = [
    Table2Row("llama2-70b", 8, 8, 4735, 4284, 3202, 3147),
    Table2Row("llama2-70b", 4, 4, 6403, 6019, 4116, 3986),
    Table2Row("llama2-70b", 2, 2, 10500, 10042, 6267, 6186),
    Table2Row("llama2-13b", 8, 8, 1693, 1514, 1201, 1209),
    Table2Row("llama2-13b", 4, 4, 1894, 1748, 1431, 1258),
    Table2Row("llama2-13b", 2, 2, 2499, 2492, 1717, 1617),
    Table2Row("llama2-13b", 1, 1, 3884, 4263, 2396, 2599),
    Table2Row("llama2-7b", 8, 8, 1187, 1096, 828, 899),
    Table2Row("llama2-7b", 4, 4, 1280, 1166, 924, 869),
    Table2Row("llama2-7b", 2, 2, 1544, 1526, 1143, 1016),
    Table2Row("llama2-7b", 1, 1, 2190, 2472, 1440, 1522),
]


# Table 4: GEMM bound types, Llama2-13B summarization (B=1, 200 tokens), half
# precision. Times in µs as printed in the paper.
TABLE4 = [
    # (gemm, t_a100_us, bound_a100, t_h100_us, bound_h100)
    ("qkv_proj", 82, "compute", 32, "memory"),
    ("qk", 3, "memory", 2, "memory"),
    ("av", 3, "memory", 2, "memory"),
    ("o_proj", 42, "compute", 17, "memory"),
    ("mlp_up", 216, "compute", 81, "memory"),
    ("mlp_down", 109, "compute", 42, "memory"),
]

# Fig 5: training-time scaling across GPU generations, GPT3-175B (normalized
# to B200-NVS-L = 1). Qualitative targets: ~35x A100->B200-NVS-L.
FIG5_SYSTEMS = [
    # (label, hw, net, batch, notes)
    ("A100-HDR", "a100", "hdr", 1024, ""),
    ("H100-NDR", "h100", "ndr", 1024, "~4x over A100"),
    ("H100-NVS", "h100", "nvs", 1024, ""),
    ("H200-NVS-L", "h200", "nvs", 4096, ""),
    ("B200-NDR", "b200", "ndr", 1024, ""),
    ("B200-NVS", "b200", "nvs", 1024, ""),
    ("B200-NVS-L", "b200", "nvs5", 4096, "reference"),
]
