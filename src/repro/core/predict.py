"""End-to-end performance prediction (the paper's core deliverable).

train_step_time: per-batch training time under a Mapping — per-microbatch
fwd/bwd roofline times + TP collectives (ring, eq. 3) serialized per layer,
pipeline bubble per schedule (§3.2), PP p2p sends, DP gradient all-reduce
(partially overlapped with bwd), recompute overhead (§3.3), optimizer update.

inference_latency: prefill + token-by-token generation with KV cache growth,
TP all-reduces on the latency-optimal double binary tree (eq. 4) — the term
that makes multi-GPU decode scale poorly (§4.3, §6).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.configs.base import ModelConfig
from repro.core import comm as C
from repro.core.hardware import HardwareSpec
from repro.core.operators import embedding_head_ops, layer_ops
from repro.core.parallelism import Mapping
from repro.core.roofline import GEMM, op_time, total_time


@dataclass
class Breakdown:
    parts: dict = field(default_factory=dict)
    meta: dict = field(default_factory=dict)  # inference: {"gen", "prompt", "batch"}

    @property
    def total(self) -> float:
        return sum(self.parts.values())

    @property
    def ttft(self) -> float:
        """Time-to-first-token: the prefill-side terms. This is the SLO
        definition shared with `repro.sim` and the serving benchmarks
        (0 for training breakdowns, which have no prefill terms)."""
        return sum(v for k, v in self.parts.items() if k.startswith("prefill"))

    @property
    def decode_total(self) -> float:
        """All per-generated-token terms (decode compute/comm + overhead)."""
        return sum(
            v for k, v in self.parts.items()
            if k.startswith("decode") or k == "overhead"
        )

    @property
    def tpot(self) -> float:
        """Mean time-per-output-token over the decode phase (0 when the
        breakdown carries no generation metadata, e.g. training)."""
        gen = self.meta.get("gen", 0)
        return self.decode_total / gen if gen else 0.0

    def as_dict(self) -> dict:
        return {**{k: float(v) for k, v in self.parts.items()}, "total": float(self.total)}


def _layer_fwd_time(cfg: ModelConfig, hw: HardwareSpec, B: int, S: int, tp: int,
                    prec: int, gemm_scale: float = 1.0) -> tuple[float, float]:
    """(gemm_time, memop_time) for one layer forward (per device)."""
    tg = tm = 0.0
    ops = layer_ops(cfg, B, S, S, tp, layer_idx=max(1, cfg.moe.first_k_dense if cfg.moe else 1),
                    decode=False, prec=prec)
    for op in ops:
        t = op_time(hw, op)
        if isinstance(op, GEMM):
            tg += t.t * gemm_scale
        else:
            tm += t.t
    return tg, tm


def train_step_time(cfg: ModelConfig, hw: HardwareSpec, m: Mapping, *,
                    global_batch: int, seq: int, intra_tp: bool = True) -> Breakdown:
    """Training time per batch (seconds) with component breakdown."""
    L = cfg.num_layers
    layers_per_stage = max(L // m.pp, 1)
    n_micro = max(global_batch // (m.dp * m.microbatch), 1)
    mb, S, prec, tp = m.microbatch, seq, m.prec, m.tp

    g_fwd, mem_fwd = _layer_fwd_time(cfg, hw, mb, S, tp, prec)
    t_layer_fwd = g_fwd + mem_fwd
    # bwd: dgrad+wgrad = 2x GEMM work; elementwise ~2x bytes
    t_layer_bwd = 2.0 * g_fwd + 1.5 * mem_fwd  # bwd elementwise reuse (calibrated)
    # recompute overhead (§3.3)
    if m.recompute == "full":
        t_layer_bwd += t_layer_fwd
    elif m.recompute == "selective":
        # recompute attention scores/softmax/AV only (~the score GEMMs + softmax)
        hq = max(cfg.num_heads // tp, 1)
        sc = GEMM("qk_re", S, S, cfg.head_dim, batch=mb * hq, bytes_in=prec,
                  weight_reuse=False)
        av = GEMM("av_re", S, cfg.head_dim, S, batch=mb * hq, bytes_in=prec,
                  weight_reuse=False)
        if cfg.family not in ("ssm",):
            t_layer_bwd += op_time(hw, sc).t + op_time(hw, av).t

    # TP collectives per layer (Megatron: 2 AR fwd + 2 AR bwd; SP keeps volume)
    net_tp = hw.net[0] if intra_tp else hw.net[1]
    K = mb * S * cfg.d_model * prec
    t_ar = C.allreduce(K, tp, net_tp, algo="ring") if tp > 1 else 0.0
    tp_fwd = 2.0 * t_ar
    tp_bwd = 2.0 * t_ar

    # embedding + head (+CE) on the edge stages, per microbatch
    head_ops = embedding_head_ops(cfg, mb, S, tp, prec=prec, with_loss=True)
    t_head_fwd, _ = total_time(hw, head_ops)
    t_head = 3.0 * t_head_fwd  # fwd + bwd

    t_mb_fwd = layers_per_stage * (t_layer_fwd + tp_fwd) + t_head_fwd
    t_mb_bwd = layers_per_stage * (t_layer_bwd + tp_bwd) + (t_head - t_head_fwd)
    t_steady = n_micro * (t_mb_fwd + t_mb_bwd)
    t_bubble = m.bubble_fraction(n_micro) * (t_mb_fwd + t_mb_bwd) * 1.0

    # PP p2p activation sends (per microbatch, per boundary, fwd+bwd)
    t_pp = 0.0
    if m.pp > 1:
        K_act = mb * S * cfg.d_model * prec
        # p2p sends overlap with compute in steady state; 25% residual exposed
        t_pp = 0.25 * 2.0 * (m.pp - 1) * C.p2p(K_act, hw.net[1]) * n_micro / max(m.pp, 1)

    # DP gradient all-reduce over the inter-node level, overlapped with bwd
    t_dp = 0.0
    if m.dp > 1:
        from repro.core.operators import total_param_count

        K_grad = total_param_count(cfg) * prec / (m.tp * m.pp)
        t_dp_raw = C.allreduce(K_grad, m.dp, hw.net[1], algo="ring")
        t_dp = max(t_dp_raw - m.dp_overlap * n_micro * t_mb_bwd, t_dp_raw * 0.1)

    # optimizer update: stream params+grads+opt states (memory-bound)
    from repro.core.operators import total_param_count

    P_dev = total_param_count(cfg) / (m.tp * m.pp)
    opt_bytes = P_dev * ((2 + 2 + 4.1) if m.opt_8bit else (2 + 2 + 12)) * 2  # r+w
    if m.zero1:
        opt_bytes /= max(m.dp, 1)
    t_opt = opt_bytes / (hw.dram.bw * hw.dram.util)

    return Breakdown(
        {
            "compute_fwd": n_micro * layers_per_stage * t_layer_fwd + n_micro * t_head_fwd,
            "compute_bwd": n_micro * layers_per_stage * t_layer_bwd
            + n_micro * (t_head - t_head_fwd),
            "tp_comm": n_micro * layers_per_stage * (tp_fwd + tp_bwd),
            "pipeline_bubble": t_bubble,
            "pp_comm": t_pp,
            "dp_comm": t_dp,
            "optimizer": t_opt,
        }
    )


def inference_latency(cfg: ModelConfig, hw: HardwareSpec, *, tp: int, batch: int,
                      prompt: int, gen: int, prec: int = 2,
                      per_token_overhead: float = 300e-6,
                      comm_algo: str = "tree") -> Breakdown:
    """End-to-end latency (s) for prompt summarization + `gen` generated tokens."""
    net = hw.net[0]
    d = cfg.d_model

    # ---- prefill ----
    ops = []
    for i in range(cfg.num_layers):
        ops += layer_ops(cfg, batch, prompt, prompt, tp, i, decode=False, prec=prec)
    t_prefill_comp, _ = total_time(hw, ops)
    t_head, _ = total_time(hw, embedding_head_ops(cfg, batch, 1, tp, prec=prec))
    K_pre = batch * prompt * d * prec
    n_ar_layers = _n_ar_layers(cfg)
    t_prefill_comm = 2.0 * n_ar_layers * C.allreduce(K_pre, tp, net, algo=comm_algo)
    t_prefill = t_prefill_comp + t_head + t_prefill_comm

    # ---- decode (per token; ctx grows prompt -> prompt+gen) ----
    t_dec_comp = 0.0
    K_tok = batch * d * prec
    t_ar_tok = C.allreduce(K_tok, tp, net, algo=comm_algo) if tp > 1 else 0.0
    # sample ctx at a few points and integrate (ctx-linear terms dominate)
    samples = 8
    for j in range(samples):
        ctx = prompt + (j + 0.5) * gen / samples
        ops = []
        for i in range(cfg.num_layers):
            ops += layer_ops(cfg, batch, 1, int(ctx), tp, i, decode=True, prec=prec)
        t, _ = total_time(hw, ops)
        t_dec_comp += t * (gen / samples)
    t_dec_head = gen * t_head
    t_dec_comm = gen * 2.0 * n_ar_layers * t_ar_tok
    t_overhead = gen * per_token_overhead

    return Breakdown(
        {
            "prefill_compute": t_prefill_comp + t_head,
            "prefill_comm": t_prefill_comm,
            "decode_compute": t_dec_comp + t_dec_head,
            "decode_comm": t_dec_comm,
            "overhead": t_overhead,
        },
        meta={"gen": gen, "prompt": prompt, "batch": batch},
    )


def _n_ar_layers(cfg: ModelConfig) -> float:
    """Layers with TP all-reduces (2 per layer); hybrid counts shared blocks."""
    if cfg.family == "hybrid" and cfg.attn_every:
        n_shared = len([i for i in range(cfg.num_layers) if i % cfg.attn_every == 0])
        return cfg.num_layers + 2 * n_shared
    return cfg.num_layers


def gemm_table(cfg: ModelConfig, hw: HardwareSpec, *, tp: int, batch: int, S: int,
               decode: bool, prec: int = 2) -> list:
    """Per-GEMM times + bound types for one layer — reproduces Table 4."""
    idx = max(1, cfg.moe.first_k_dense if cfg.moe else 1)
    ops = layer_ops(cfg, batch, 1 if decode else S, S, tp, idx, decode=decode, prec=prec)
    out = []
    for op in ops:
        t = op_time(hw, op)
        out.append(t)
    return out
