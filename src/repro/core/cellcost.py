"""Analytic per-device cost of a production (arch x shape) cell on TPU v5e.

This is the paper's op-graph methodology applied to our own system: exact
per-device FLOPs and modeled HBM traffic for each dry-run cell, used for the
§Roofline compute/memory terms. (XLA's cost_analysis counts loop bodies once —
see core/hlo.py — so the analytic graph is the authoritative source; the raw
HLO numbers are recorded alongside as a cross-check.)
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core.hardware import TPU_V5E
from repro.core.operators import (
    embedding_head_ops,
    layer_ops,
    model_flops,
    total_param_count,
)
from repro.core.roofline import GEMM, op_time


@dataclass(frozen=True)
class CellCost:
    flops_per_device: float
    dram_bytes_per_device: float
    model_flops_global: float
    tokens: int


def cell_cost(cfg: ModelConfig, shape: ShapeSpec, *, dp: int = 16, tp: int = 16,
              prec: int = 2, opt_8bit: bool = False) -> CellCost:
    hw = TPU_V5E
    kind = shape.kind
    B, S = shape.global_batch, shape.seq_len
    B_dev = max(B // dp, 1)

    def fwd_cost(Bq: int, Sq: int, ctx: int, decode: bool):
        fl = by = 0.0
        for i in range(cfg.num_layers):
            for op in layer_ops(cfg, Bq, Sq, ctx, tp, i, decode=decode, prec=prec):
                t = op_time(hw, op)
                fl += t.flops
                by += t.dram_bytes
        for op in embedding_head_ops(cfg, Bq, 1 if decode else Sq, tp, prec=prec,
                                     with_loss=kind == "train"):
            t = op_time(hw, op)
            fl += t.flops
            by += t.dram_bytes
        return fl, by

    if kind == "train":
        fl, by = fwd_cost(B_dev, S, S, decode=False)
        # fwd + bwd(2x) + selective recompute of attention core (~score GEMMs)
        flops = 3.0 * fl
        bytes_ = 3.0 * by
        hq = max(cfg.num_heads // tp, 1)
        if cfg.family not in ("ssm",):
            for g in (
                GEMM("qk_re", S, S, cfg.head_dim, batch=B_dev * hq, bytes_in=prec,
                     weight_reuse=False),
                GEMM("av_re", S, cfg.head_dim, S, batch=B_dev * hq, bytes_in=prec,
                     weight_reuse=False),
            ):
                t = op_time(hw, g)
                flops += cfg.num_layers * t.flops
                bytes_ += cfg.num_layers * t.dram_bytes
        # optimizer streaming
        P_dev = total_param_count(cfg) / tp
        bytes_ += P_dev * ((2 + 4 + 4.1) if opt_8bit else (2 + 4 + 12)) * 2
        tokens = B * S
        mf = model_flops(cfg, tokens, train=True)
    elif kind == "prefill":
        flops, bytes_ = fwd_cost(B_dev, S, S, decode=False)
        tokens = B * S
        mf = model_flops(cfg, tokens, train=False)
    else:  # decode: one token with ctx = S
        # context-parallel cells (B < dp) shard the KV/ctx dim over data axes
        ctx = S if B >= dp else max(S // dp, 1)
        flops, bytes_ = fwd_cost(B_dev, 1, ctx, decode=True)
        tokens = B
        mf = model_flops(cfg, tokens, train=False)
    return CellCost(flops, bytes_, mf, tokens)
