"""Transformer operator graphs (§3.1-3.2): per-device GEMM/mem-op lists for
train fwd/bwd, prefill (summarization) and decode (generation) phases, under a
Megatron TP/SP mapping.

Conventions (documented for the validation tables):
  * GEMM dims are *per-device* (already divided by TP).
  * Attention score/AV GEMMs are batched GEMMs over (batch x heads / tp).
  * Causal attention counts full S^2 score flops (the Megatron MFU convention;
    the paper's tables follow the same op-graph accounting).
  * Backward = dgrad + wgrad = 2x fwd flops per GEMM; recompute adds fwd work
    per the policy (§3.3).
  * Norm/softmax/dropout/residual are byte-counted MemOps (paper §1.2 —
    memory-bound elementwise class).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.core.roofline import GEMM, MemOp


@dataclass(frozen=True)
class Phase:
    TRAIN_FWD = "train_fwd"
    PREFILL = "prefill"
    DECODE = "decode"


def _gqa_dims(cfg: ModelConfig, tp: int):
    hq = max(cfg.num_heads // tp, 1)
    hkv = max(cfg.num_kv_heads // tp, 1)
    return hq, hkv, cfg.head_dim


def attn_ops(cfg: ModelConfig, B: int, S: int, ctx: int, tp: int, *, decode: bool,
             prec: int = 2) -> list:
    """MHA/GQA block ops. S = query length (1 for decode), ctx = key length."""
    d = cfg.d_model
    hq, hkv, dh = _gqa_dims(cfg, tp)
    if cfg.sliding_window is not None:
        ctx = min(ctx, cfg.sliding_window)
    T = B * S
    ops: list = [
        MemOp("ln1", 2 * T * d * prec + 2 * T * 4),
        GEMM("q_proj", T, hq * dh, d, bytes_in=prec),
        GEMM("kv_proj", T, 2 * hkv * dh, d, bytes_in=prec),
        # scores QK^T: batched skinny/fat GEMM over heads
        GEMM("qk", S, ctx, dh, batch=B * hq, bytes_in=prec, weight_reuse=False),
        MemOp("softmax", 3 * B * hq * S * ctx * prec),
        GEMM("av", S, dh, ctx, batch=B * hq, bytes_in=prec, weight_reuse=False),
        GEMM("o_proj", T, d, hq * dh, bytes_in=prec),
        MemOp("residual1", 3 * T * d * prec),
    ]
    if decode:
        # KV-cache read+append traffic (§3.5): the decode-phase memory tax
        ops.append(MemOp("kv_cache", 2 * B * ctx * hkv * dh * prec))
    return ops


def mlp_ops(cfg: ModelConfig, B: int, S: int, tp: int, *, d_ff: int | None = None,
            prec: int = 2) -> list:
    d = cfg.d_model
    ff = (d_ff or cfg.d_ff) // tp if (d_ff or cfg.d_ff) >= tp else 1
    T = B * S
    ops = [
        MemOp("ln2", 2 * T * d * prec + 2 * T * 4),
        GEMM("mlp_up", T, ff, d, bytes_in=prec),
    ]
    if cfg.gated_mlp:
        ops.append(GEMM("mlp_gate", T, ff, d, bytes_in=prec))
    ops += [
        MemOp("act", 2 * T * ff * prec),
        GEMM("mlp_down", T, d, ff, bytes_in=prec),
        MemOp("residual2", 3 * T * d * prec),
    ]
    return ops


def moe_ops(cfg: ModelConfig, B: int, S: int, tp: int, *, prec: int = 2) -> list:
    """Routed experts (EP over tp) + shared/dense branches (capacity-based)."""
    m = cfg.moe
    d = cfg.d_model
    T = B * S
    e_local = max(m.num_experts // tp, 1)
    cap = int(m.capacity_factor * T * m.top_k / m.num_experts)
    ops: list = [
        GEMM("router", T, m.num_experts, d, bytes_in=4),
        MemOp("dispatch", 2 * T * m.top_k * d * prec / tp),  # gather+scatter traffic
    ]
    n_mm = 3 if cfg.gated_mlp else 2
    ops.append(
        GEMM("experts", cap, m.d_ff * (n_mm - 1), d, batch=e_local, bytes_in=prec)
    )
    ops.append(GEMM("experts_down", cap, d, m.d_ff, batch=e_local, bytes_in=prec))
    if m.num_shared_experts:
        ops += mlp_ops(cfg, B, S, tp, d_ff=m.d_ff * m.num_shared_experts, prec=prec)[1:-1]
    if m.dense_residual:
        ops += mlp_ops(cfg, B, S, tp, d_ff=m.dense_d_ff or m.d_ff, prec=prec)[1:-1]
    return ops


def ssm_ops(cfg: ModelConfig, B: int, S: int, tp: int, *, decode: bool, prec: int = 2,
            chunk: int = 256) -> list:
    """Mamba2 SSD (chunked) or RWKV6 time/channel-mix op graph."""
    s = cfg.ssm
    d = cfg.d_model
    T = B * S
    if s.kind == "mamba2":
        d_inner = s.expand * d // tp
        H = max(d_inner // s.head_dim, 1)
        gn = s.n_groups * s.d_state
        proj = 2 * (s.expand * d) + 2 * gn + (s.expand * d // s.head_dim)
        ops = [
            MemOp("ln", 2 * T * d * prec),
            GEMM("in_proj", T, max(proj // tp, 1), d, bytes_in=prec),
            MemOp("conv", 3 * T * (d_inner + 2 * gn) * prec),
        ]
        if decode:
            ops += [
                MemOp("ssd_state", 2 * B * H * s.d_state * s.head_dim * 4),
                GEMM("ssd_update", 1, s.d_state * s.head_dim, 1, batch=B * H, bytes_in=4,
                     weight_reuse=False),
            ]
        else:
            Q = min(chunk, S)
            nc = max(S // Q, 1)
            ops += [
                GEMM("ssd_scores", Q, Q, s.d_state, batch=B * nc * s.n_groups,
                     bytes_in=prec, weight_reuse=False),
                GEMM("ssd_intra", Q, s.head_dim, Q, batch=B * nc * H, bytes_in=prec,
                     weight_reuse=False),
                GEMM("ssd_states", s.d_state, s.head_dim, Q, batch=B * nc * H,
                     bytes_in=prec, weight_reuse=False),
                GEMM("ssd_inter", Q, s.head_dim, s.d_state, batch=B * nc * H,
                     bytes_in=prec, weight_reuse=False),
            ]
        ops += [
            MemOp("gate_norm", 4 * T * d_inner * prec),
            GEMM("out_proj", T, d, d_inner, bytes_in=prec),
            MemOp("residual", 3 * T * d * prec),
        ]
        return ops
    # rwkv6
    dh = s.head_dim
    H = max(d // dh // tp, 1)
    dt = d // tp
    ops = [
        MemOp("ln1", 2 * T * d * prec),
        GEMM("ddlerp", T, 5 * s.mix_dim, d, bytes_in=prec),
        GEMM("rkvg", T, 4 * dt, d, bytes_in=prec),
        GEMM("decay_lora", T, s.decay_lora, d, bytes_in=prec),
    ]
    if decode:
        ops += [
            MemOp("wkv_state", 2 * B * H * dh * dh * 4),
            GEMM("wkv_update", dh, dh, 1, batch=B * H, bytes_in=4, weight_reuse=False),
        ]
    else:
        Q = 32
        nc = max(S // Q, 1)
        ops += [
            GEMM("wkv_intra", Q, Q * dh, 1, batch=B * nc * H, bytes_in=4,
                 weight_reuse=False),
            GEMM("wkv_out", Q, dh, Q, batch=B * nc * H, bytes_in=4, weight_reuse=False),
            MemOp("wkv_state_stream", B * nc * H * dh * dh * 4),
        ]
    ops += [
        GEMM("wo", T, d, dt, bytes_in=prec),
        MemOp("ln2", 2 * T * d * prec),
        GEMM("cm_k", T, cfg.d_ff // tp, d, bytes_in=prec),
        GEMM("cm_v", T, d, cfg.d_ff // tp, bytes_in=prec),
        GEMM("cm_r", T, dt, d, bytes_in=prec),
        MemOp("residuals", 6 * T * d * prec),
    ]
    return ops


def layer_ops(cfg: ModelConfig, B: int, S: int, ctx: int, tp: int, layer_idx: int, *,
              decode: bool, prec: int = 2) -> list:
    """Ops for one layer (per device)."""
    if cfg.ssm is not None and cfg.family in ("ssm", "hybrid"):
        ops = ssm_ops(cfg, B, S, tp, decode=decode, prec=prec)
        if cfg.family == "hybrid" and cfg.attn_every and layer_idx % cfg.attn_every == 0:
            ops = (
                attn_ops(cfg, B, S, ctx, tp, decode=decode, prec=prec)
                + mlp_ops(cfg, B, S, tp, prec=prec)
                + ops
            )
        return ops
    ops = attn_ops(cfg, B, S, ctx, tp, decode=decode, prec=prec)
    if cfg.moe is not None and layer_idx >= cfg.moe.first_k_dense:
        ops += moe_ops(cfg, B, S, tp, prec=prec)
    elif cfg.moe is not None:
        ops += mlp_ops(cfg, B, S, tp, d_ff=cfg.moe.dense_d_ff or cfg.d_ff, prec=prec)
    else:
        ops += mlp_ops(cfg, B, S, tp, prec=prec)
    return ops


def embedding_head_ops(cfg: ModelConfig, B: int, S: int, tp: int, *, prec: int = 2,
                       with_loss: bool = False) -> list:
    T = B * S
    d = cfg.d_model
    ops = [
        MemOp("embed_gather", T * d * prec),
        MemOp("final_norm", 2 * T * d * prec),
        GEMM("lm_head", T, max(cfg.vocab_size // tp, 1), d, bytes_in=prec),
    ]
    if with_loss:
        ops.append(MemOp("softmax_ce", 3 * T * max(cfg.vocab_size // tp, 1) * 4))
    return ops


def model_flops(cfg: ModelConfig, tokens: int, *, train: bool = True) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) — §Roofline's 'useful'
    flops. N counts active params excluding embeddings; D = tokens."""
    n = active_param_count(cfg)
    mult = 6.0 if train else 2.0
    return mult * n * tokens


def active_param_count(cfg: ModelConfig) -> float:
    """Active (per-token) non-embedding parameters."""
    d = cfg.d_model
    n = 0.0
    for i in range(cfg.num_layers):
        if cfg.ssm is not None and cfg.family in ("ssm", "hybrid"):
            s = cfg.ssm
            if s.kind == "mamba2":
                d_inner = s.expand * d
                gn = s.n_groups * s.d_state
                n += d * (2 * d_inner + 2 * gn + d_inner // s.head_dim) + d_inner * d
            else:
                n += d * (4 * d + 5 * s.mix_dim + s.decay_lora) + 2 * d * cfg.d_ff + d * d
            if cfg.family == "hybrid" and cfg.attn_every and i % cfg.attn_every == 0:
                n += _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff)
            continue
        n += _attn_params(cfg)
        if cfg.moe is not None and i >= cfg.moe.first_k_dense:
            m = cfg.moe
            n_mm = 3 if cfg.gated_mlp else 2
            n += d * m.num_experts  # router
            n += m.top_k * n_mm * d * m.d_ff  # active routed
            n += m.num_shared_experts * n_mm * d * m.d_ff
            if m.dense_residual:
                n += n_mm * d * (m.dense_d_ff or m.d_ff)
        elif cfg.moe is not None:
            n += _mlp_params(cfg, cfg.moe.dense_d_ff or cfg.d_ff)
        else:
            n += _mlp_params(cfg, cfg.d_ff)
    return n


def total_param_count(cfg: ModelConfig) -> float:
    """All parameters incl. embeddings and all experts."""
    d = cfg.d_model
    n = 2 * cfg.vocab_size * d  # embed + head
    for i in range(cfg.num_layers):
        if cfg.ssm is not None and cfg.family in ("ssm", "hybrid"):
            s = cfg.ssm
            if s.kind == "mamba2":
                d_inner = s.expand * d
                gn = s.n_groups * s.d_state
                n += d * (2 * d_inner + 2 * gn + d_inner // s.head_dim) + d_inner * d
            else:
                n += d * (4 * d + 5 * s.mix_dim + s.decay_lora) + 2 * d * cfg.d_ff + d * d
            if cfg.family == "hybrid" and cfg.attn_every and i % cfg.attn_every == 0:
                n += _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff)
            continue
        n += _attn_params(cfg)
        if cfg.moe is not None and i >= cfg.moe.first_k_dense:
            m = cfg.moe
            n_mm = 3 if cfg.gated_mlp else 2
            n += d * m.num_experts + m.num_experts * n_mm * d * m.d_ff
            n += m.num_shared_experts * n_mm * d * m.d_ff
            if m.dense_residual:
                n += n_mm * d * (m.dense_d_ff or m.d_ff)
        elif cfg.moe is not None:
            n += _mlp_params(cfg, cfg.moe.dense_d_ff or cfg.d_ff)
        else:
            n += _mlp_params(cfg, cfg.d_ff)
    return n


def _attn_params(cfg: ModelConfig) -> float:
    d = cfg.d_model
    return d * (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim + (
        cfg.num_heads * cfg.head_dim * d
    )


def _mlp_params(cfg: ModelConfig, ff: int) -> float:
    return (3 if cfg.gated_mlp else 2) * cfg.d_model * ff
