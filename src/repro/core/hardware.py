"""Hardware descriptions — the paper's "architecture abstraction layer" (§3.1).

Instead of DeepFlow's low-level technology parameters (area/cell, energy/flip),
each system is described by the high-level performance drivers the paper's
abstraction layer extracts: peak compute per dtype, a memory-level hierarchy
(capacity + bandwidth + default utilization), and a network hierarchy
(per-device algorithm bandwidth + latency + group size). This is exactly the
path the paper advocates for modeling commercial hardware whose process details
are not public.

GPU numbers follow the paper's text (§4.3, §5.2, §6.2); TPU v5e numbers follow
the repro brief (197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link ICI).

TPU adaptation note (DESIGN.md §3): the GPU hierarchy DRAM->L2 maps onto
HBM->VMEM; the NVLink/IB two-level network maps onto ICI (intra-pod torus) /
DCN (inter-pod).
"""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class MemLevel:
    name: str
    capacity: float  # bytes
    bw: float  # bytes/s
    util: float = 0.8  # default achievable fraction (paper's utilization factor)


@dataclass(frozen=True)
class NetLevel:
    name: str
    bw: float  # bytes/s per device (algorithm bandwidth, one direction)
    latency: float  # seconds per hop
    size: int  # devices inside this level (e.g. 8 per NVLink node)
    util: float = 0.85


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    flops: dict  # dtype -> peak FLOP/s (dense)
    mem: tuple  # (off-chip DRAM/HBM, on-chip L2/VMEM) — ordered far -> near
    net: tuple  # (intra-node, inter-node)
    compute_util: float = 0.55  # fat-GEMM MXU/tensor-core efficiency
    gemv_dram_util: float = 0.7  # paper §4.1: constant DRAM util factor for GEMVs

    @property
    def dram(self) -> MemLevel:
        return self.mem[0]

    @property
    def l2(self) -> MemLevel:
        return self.mem[1]

    def with_dram(self, name: str, bw: float, capacity: float | None = None):
        d = self.mem[0]
        new = MemLevel(name, capacity or d.capacity, bw, d.util)
        return replace(self, name=f"{self.name}+{name}", mem=(new, *self.mem[1:]))

    def with_net(self, intra: "NetLevel | None" = None, inter: "NetLevel | None" = None):
        return replace(self, net=(intra or self.net[0], inter or self.net[1]))


GB = 1e9
TB = 1e12
MB = 1e6

# ------------------------------------------------------------------- networks
# NVLink latencies are *collective-op* effective latencies (NCCL small-
# message all-reduce ~20-60us at 8 GPUs), not wire latencies — calibrated
# against Table 2 (the paper makes the same adjustment via eq. 4).
NVLINK3 = NetLevel("NVLink3", 300 * GB, 10e-6, 8)
NVLINK4 = NetLevel("NVLink4", 450 * GB, 8e-6, 8)
NVLINK5 = NetLevel("NVLink5", 900 * GB, 7e-6, 8)
HDR_IB = NetLevel("HDR-IB", 25 * GB, 5e-6, 10_000)  # 200 GB/s per 8-GPU node
NDR_IB = NetLevel("NDR-IB", 50 * GB, 5e-6, 10_000)  # 400 GB/s per 8-GPU node
NVS_NET = NetLevel("NVLinkSwitch", 450 * GB, 3e-6, 10_000)  # NVS system (H100/B200)
NVS5_NET = NetLevel("NVLinkSwitch5", 900 * GB, 3e-6, 10_000)

# DSE inter-node options (§5.3: per x8 node)
NDR_X8 = NetLevel("NDR-x8", 100 * GB / 8, 5e-6, 10_000)
XDR_X8 = NetLevel("XDR-x8", 200 * GB / 8, 5e-6, 10_000)
GDR_X8 = NetLevel("GDR-x8", 400 * GB / 8, 5e-6, 10_000)

# TPU v5e: 2D ICI torus (~50 GB/s/link per the brief; 2 links per axis usable
# for a ring on that axis), DCN across pods.
ICI_V5E = NetLevel("ICI", 50 * GB, 1e-6, 256, util=0.9)
DCN = NetLevel("DCN", 6.25 * GB, 10e-6, 10_000, util=0.8)

# --------------------------------------------------------------------- chips
A100_80G = HardwareSpec(
    name="A100-80G",
    flops={"fp32": 19.5e12, "tf32": 156e12, "bf16": 312e12, "fp16": 312e12, "int8": 624e12},
    mem=(
        MemLevel("HBM2e", 80e9, 1.935 * TB, util=0.8),
        MemLevel("L2", 40 * MB, 4.8 * TB, util=0.8),
    ),
    net=(NVLINK3, HDR_IB),
    compute_util=0.61,  # calibrated on Table 1 (Megatron 150-177 TF/s/GPU)
    gemv_dram_util=0.72,
)

H100_SXM = HardwareSpec(
    name="H100-SXM",
    flops={"fp32": 67e12, "tf32": 494e12, "bf16": 989e12, "fp16": 989e12, "fp8": 1979e12},
    mem=(
        MemLevel("HBM3", 80e9, 3.35 * TB, util=0.8),
        MemLevel("L2", 50 * MB, 8.0 * TB, util=0.8),
    ),
    net=(NVLINK4, NDR_IB),
    compute_util=0.47,  # H100 tensor-core util on real LLM GEMMs is lower
    gemv_dram_util=0.72,
)

H200 = HardwareSpec(
    name="H200",
    flops=dict(H100_SXM.flops),
    mem=(
        MemLevel("HBM3e", 141e9, 4.8 * TB, util=0.8),
        MemLevel("L2", 50 * MB, 8.0 * TB, util=0.8),
    ),
    net=(NVLINK4, NDR_IB),
    compute_util=0.47,
    gemv_dram_util=0.72,
)

B200 = HardwareSpec(
    name="B200",
    flops={"fp32": 80e12, "bf16": 2250e12, "fp16": 2250e12, "fp8": 4500e12, "fp4": 9000e12},
    mem=(
        MemLevel("HBM3e", 192e9, 8.0 * TB, util=0.8),
        MemLevel("L2", 126 * MB, 16.0 * TB, util=0.8),
    ),
    net=(NVLINK5, NDR_IB),
    compute_util=0.45,
    gemv_dram_util=0.72,
)

TPU_V5E = HardwareSpec(
    name="TPU-v5e",
    flops={"bf16": 197e12, "int8": 394e12, "fp32": 49e12},
    mem=(
        MemLevel("HBM", 16e9, 819e9, util=0.85),
        MemLevel("VMEM", 128 * MB, 11.0 * TB, util=0.85),
    ),
    net=(ICI_V5E, DCN),
    compute_util=0.55,
    gemv_dram_util=0.75,
)

_REGISTRY = {
    "a100": A100_80G,
    "a100-80g": A100_80G,
    "h100": H100_SXM,
    "h100-sxm": H100_SXM,
    "h200": H200,
    "b200": B200,
    "tpu-v5e": TPU_V5E,
    "v5e": TPU_V5E,
}

# DRAM technology scaling table (§5.3, §6.2 / Fig 6, Fig 9)
DRAM_TECH = {
    "GDR6": 600 * GB,
    "HBM2": 1.0 * TB,
    "HBM2E": 1.9 * TB,
    "HBM3": 2.6 * TB,
    "HBM3_inf": 3.35 * TB,  # paper's H100 inference number
    "HBM3E": 4.8 * TB,
    "HBM4": 3.3 * TB,  # paper's projected-HBM4 figure used in Fig 6
    "HBMX": 6.8 * TB,  # futuristic (§6.2)
}


def get_hardware(name: str) -> HardwareSpec:
    return _REGISTRY[name.lower()]
