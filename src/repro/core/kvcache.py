"""KV-cache size model (§3.5).

Paper formula: 2 * batch * context * precision * layers * embedding_dim.
GQA generalization: the cached dim is num_kv_heads * head_dim (= embedding dim
for MHA, smaller for GQA); sliding-window attention caps context at the window.
SSM archs replace the KV cache with O(1) recurrent state (returned separately).
"""

from __future__ import annotations

from repro.configs.base import ModelConfig


def kv_cache_bytes(cfg: ModelConfig, batch: int, context: int, precision: int = 2) -> float:
    if cfg.family == "ssm":
        return 0.0
    ctx = min(context, cfg.sliding_window) if cfg.sliding_window else context
    kv_dim = cfg.num_kv_heads * cfg.head_dim
    n_attn = len(_attn_layers(cfg))
    return 2.0 * batch * ctx * precision * n_attn * kv_dim


def recurrent_state_bytes(cfg: ModelConfig, batch: int) -> float:
    if cfg.ssm is None:
        return 0.0
    s = cfg.ssm
    if s.kind == "rwkv6":
        H = cfg.d_model // s.head_dim
        per_layer = H * s.head_dim * s.head_dim * 4 + 2 * cfg.d_model * 2
        return batch * cfg.num_layers * per_layer
    # mamba2
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    per_layer = H * s.d_state * s.head_dim * 4 + conv_dim * (s.conv_width - 1) * 2
    n_mamba = cfg.num_layers if cfg.family in ("ssm", "hybrid") else 0
    return batch * n_mamba * per_layer


def _attn_layers(cfg: ModelConfig) -> list[int]:
    if cfg.family == "hybrid":
        if not cfg.attn_every:
            return []
        k = cfg.attn_every
        n_seg = cfg.num_layers // k
        tail = cfg.num_layers - n_seg * k
        return list(range(n_seg + (1 if tail else 0)))
    if cfg.family == "ssm":
        return []
    return list(range(cfg.num_layers))
