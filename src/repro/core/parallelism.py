"""Parallelism mapping descriptor (§3.2): DP x TP x PP x SP + schedule."""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class Mapping:
    dp: int = 1
    tp: int = 1
    pp: int = 1
    sp: bool = False  # Megatron sequence parallelism (SP degree = tp)
    microbatch: int = 1  # sequences per pipeline microbatch (per replica)
    recompute: str = "selective"  # none | selective | full (§3.3)
    schedule: str = "1f1b"  # gpipe | 1f1b | interleaved (§3.2)
    vpp: int = 1  # interleave factor v (virtual pipeline stages per device)
    prec: int = 2  # training precision bytes
    zero1: bool = False
    opt_8bit: bool = False
    dp_overlap: float = 0.7  # fraction of grad all-reduce hidden under bwd

    @property
    def devices(self) -> int:
        return self.dp * self.tp * self.pp

    def describe(self) -> str:
        return (
            f"dp{self.dp}-tp{self.tp}-pp{self.pp}-sp{self.tp if self.sp else 1}"
            f"-mb{self.microbatch}-{self.recompute}-{self.schedule}"
        )

    def bubble_fraction(self, n_micro: int) -> float:
        """Pipeline bubble: (p-1)/m for GPipe/1F1B, (p-1)/(m*v) interleaved."""
        if self.pp <= 1:
            return 0.0
        if self.schedule == "interleaved":
            return (self.pp - 1) / (n_micro * max(self.vpp, 1))
        return (self.pp - 1) / n_micro

    def with_(self, **kw) -> "Mapping":
        return replace(self, **kw)
