"""Auto-parallelism planner: the paper's technique as a framework feature.

Given a model config, hardware, chip count and batch geometry, enumerate
(dp, tp, pp, sp, microbatch, recompute) mappings, filter by the §5.1 memory
model (must fit per-device HBM), and rank by predicted step time (§3.2's
mapping + the roofline/collective models). Used by `launch/train.py
--auto-plan` and validated by tests/test_planner.py.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.core.hardware import HardwareSpec
from repro.core.memory import training_memory
from repro.core.parallelism import Mapping
from repro.core.predict import train_step_time


@dataclass
class Plan:
    mapping: Mapping
    time: float
    memory: float
    fits: bool
    breakdown: dict

    def describe(self) -> str:
        fit = "fits" if self.fits else "OOM"
        return (
            f"{self.mapping.describe():48s} t={self.time * 1e3:9.1f} ms "
            f"mem={self.memory / 2**30:6.1f} GiB [{fit}]"
        )


def _divisors(n: int, cap: int | None = None) -> list[int]:
    out = [d for d in range(1, n + 1) if n % d == 0]
    return [d for d in out if cap is None or d <= cap]


def enumerate_mappings(cfg: ModelConfig, n_chips: int, global_batch: int, *,
                       max_tp: int | None = None, schedules=("1f1b",)) -> list[Mapping]:
    maps = []
    max_tp = max_tp or n_chips
    for tp in _divisors(n_chips, max_tp):
        rest = n_chips // tp
        for pp in _divisors(rest):
            if cfg.num_layers % pp:
                continue
            dp = rest // pp
            if global_batch % dp:
                continue
            per_replica = global_batch // dp
            for mb in (1, 2, 4, 8):
                if per_replica % mb:
                    continue
                for rec in ("none", "selective", "full"):
                    for sched in schedules if pp > 1 else ("1f1b",):
                        maps.append(
                            Mapping(dp=dp, tp=tp, pp=pp, sp=tp > 1, microbatch=mb,
                                    recompute=rec, schedule=sched,
                                    zero1=True)
                        )
    return maps


def plan(cfg: ModelConfig, hw: HardwareSpec, n_chips: int, *, global_batch: int,
         seq: int, top_k: int = 5, max_tp: int | None = None,
         mem_margin: float = 0.92) -> list[Plan]:
    """Returns the top_k feasible plans, best predicted step time first."""
    plans = []
    for m in enumerate_mappings(cfg, n_chips, global_batch, max_tp=max_tp):
        mem = training_memory(
            cfg, global_batch=global_batch, seq=seq, dp=m.dp, tp=m.tp, pp=m.pp,
            sp=m.sp, microbatch=m.microbatch, recompute=m.recompute,
            zero1=m.zero1, opt_8bit=m.opt_8bit, schedule=m.schedule,
        ).total
        fits = mem <= hw.dram.capacity * mem_margin
        if not fits:
            continue
        bd = train_step_time(cfg, hw, m, global_batch=global_batch, seq=seq)
        plans.append(Plan(m, bd.total, mem, fits, bd.as_dict()))
    plans.sort(key=lambda p: p.time)
    if not plans:
        raise ValueError(
            f"no feasible mapping for {cfg.name} on {n_chips} x {hw.name} "
            f"(batch {global_batch}, seq {seq}) — model does not fit"
        )
    return plans[:top_k]
