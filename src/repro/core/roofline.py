"""Hierarchical roofline timing for GEMM / GEMV / element-wise ops (§3.1).

Per DeepFlow, a kernel's time is the max over hierarchy levels of
(traffic at that level) / (achievable bandwidth), together with the pure
compute term. Traffic at the off-chip level follows a cache-blocking model:
operands stream once if the working set fits L2/VMEM, otherwise classic tiled
traffic with square tiles sized to half the near memory.

Bound types match the paper's Table 4 classification: "compute" when the
compute term dominates, "memory" (DRAM/HBM) or "l2" otherwise.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.hardware import HardwareSpec


@dataclass(frozen=True)
class GEMM:
    """batch x (m, k) @ (k, n). Weights treated as the (k, n) operand."""

    name: str
    m: int
    n: int
    k: int
    batch: int = 1
    bytes_in: int = 2  # operand precision
    bytes_out: int = 2
    weight_reuse: bool = True  # weights resident across the batch dim

    @property
    def flops(self) -> float:
        return 2.0 * self.batch * self.m * self.n * self.k


@dataclass(frozen=True)
class MemOp:
    """Bandwidth-bound op (norm, softmax, residual, dropout, cache update)."""

    name: str
    bytes: float
    flops: float = 0.0


@dataclass(frozen=True)
class OpTime:
    name: str
    t: float
    bound: str  # compute | memory | l2
    flops: float
    dram_bytes: float
    l2_bytes: float
    t_compute: float = 0.0
    t_dram: float = 0.0
    t_l2: float = 0.0


def gemm_dram_traffic(g: GEMM, l2_capacity: float) -> float:
    """Off-chip bytes for one batched GEMM under L2 cache blocking."""
    bi, bo = g.bytes_in, g.bytes_out
    a_bytes = g.m * g.k * bi
    b_bytes = g.k * g.n * bi
    c_bytes = g.m * g.n * bo
    per_batch_ws = a_bytes + b_bytes + c_bytes
    if per_batch_ws <= l2_capacity * 0.5:
        # streams once; weights shared across batch when flagged
        if g.weight_reuse and g.batch > 1:
            return g.batch * (a_bytes + c_bytes) + b_bytes
        return g.batch * per_batch_ws
    # tiled: square tiles of T x T sized to half of L2 (A-tile + B-tile)
    T = max(64, int(math.sqrt(l2_capacity * 0.5 / (2 * bi))))
    n_tiles_n = math.ceil(g.n / T)
    n_tiles_m = math.ceil(g.m / T)
    traffic = g.m * g.k * bi * n_tiles_n + g.k * g.n * bi * n_tiles_m + g.m * g.n * bo
    return g.batch * traffic


def gemm_l2_traffic(g: GEMM, mxu_tile: int = 128) -> float:
    """On-chip (L2/VMEM -> compute) bytes under a fixed MXU/tensor-core tile."""
    bi, bo = g.bytes_in, g.bytes_out
    reads = g.m * g.k * bi * math.ceil(g.n / mxu_tile) + g.k * g.n * bi * math.ceil(
        g.m / mxu_tile
    )
    return g.batch * (reads + g.m * g.n * bo)


def _dtype_key(bytes_in: int) -> str:
    return {1: "fp8", 2: "bf16", 4: "fp32"}.get(bytes_in, "bf16")


def gemm_time(hw: HardwareSpec, g: GEMM) -> OpTime:
    dt = _dtype_key(g.bytes_in)
    peak = hw.flops.get(dt) or hw.flops["bf16"]
    # skinny GEMMs don't reach fat-GEMM efficiency; ramp with the small dim
    small = min(g.m, g.n)
    eff = hw.compute_util * min(1.0, small / 128.0)
    t_compute = g.flops / (peak * max(eff, 1e-3))

    dram_b = gemm_dram_traffic(g, hw.l2.capacity)
    l2_b = gemm_l2_traffic(g)
    # memory utilization: fat GEMMs stream well; skinny ones follow the paper's
    # calibrated constant GEMV utilization factor (§4.1)
    dram_util = hw.dram.util if small >= 128 else hw.gemv_dram_util
    t_dram = dram_b / (hw.dram.bw * dram_util)
    t_l2 = l2_b / (hw.l2.bw * hw.l2.util)

    t = max(t_compute, t_dram, t_l2)
    bound = {t_compute: "compute", t_dram: "memory", t_l2: "l2"}[t]
    return OpTime(g.name, t, bound, g.flops, dram_b, l2_b, t_compute, t_dram, t_l2)


def memop_time(hw: HardwareSpec, op: MemOp) -> OpTime:
    t_dram = op.bytes / (hw.dram.bw * hw.dram.util)
    t_l2 = op.bytes / (hw.l2.bw * hw.l2.util)
    peak = hw.flops.get("fp32", hw.flops["bf16"] / 16)
    t_c = op.flops / peak if op.flops else 0.0
    t = max(t_dram, t_l2, t_c)
    bound = "memory" if t == t_dram else ("l2" if t == t_l2 else "compute")
    return OpTime(op.name, t, bound, op.flops, op.bytes, op.bytes, t_c, t_dram, t_l2)


def op_time(hw: HardwareSpec, op) -> OpTime:
    if isinstance(op, GEMM):
        return gemm_time(hw, op)
    if isinstance(op, MemOp):
        return memop_time(hw, op)
    raise TypeError(op)


def total_time(hw: HardwareSpec, ops) -> tuple[float, list[OpTime]]:
    times = [op_time(hw, op) for op in ops]
    return sum(t.t for t in times), times
