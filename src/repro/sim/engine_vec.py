"""Struct-of-arrays fast core for `ReplicaSim` (engine="vectorized").

`VecReplicaSim` executes the exact same schedule as the reference
object-per-request loop in `repro.sim.scheduler`, but holds request state
in flat parallel columns (row index = one request) and compresses pure-
decode stretches into a single vectorized window:

  * Columns (`_prompt/_output/_cached/_gen/...`) are plain Python int
    lists — the per-step mutations are scalar, and list indexing beats
    numpy item access for that; numpy enters where it pays: pricing and
    clock accumulation over a fast-forward window.
  * KV pricing goes through per-context lookup tables built once per cost
    model (`_kv_tables`) instead of calling `kv_bytes` per request per
    iteration. Table entries are produced by the same `kv_bytes` calls,
    so every looked-up float is bit-identical to the reference engine's.
  * Pure-decode fast-forward: when every live request is decoding
    (deficit == 1), no admission can fire, and no chaos window is
    pending, the next k iterations are fully determined. The window is
    priced per ctx-quantum bucket (one memoized `decode_step_time` call
    per bucket), the clock advances through `np.cumsum` — which
    accumulates strictly sequentially, so the resulting floats bit-match
    k repeated `now += dt` additions — and state jumps forward in O(B).

Bit-parity contract (pinned by tests/test_engine_parity.py): every
record field, counter, and peak produced here equals the reference
engine's output bit-for-bit. The fast-forward window is sized so it can
never skip a schedule-relevant event: it stops at the first completion
(k_complete), the first arrival that could admit (k_arr), the first
iteration that would trip the KV-capacity invariant (k_kv, binary search
on the monotone projected allocation), and the caller's time limit
(k_time). Paged-KV waste peaks are evaluated exactly at page-crossing
candidate steps (total waste strictly decreases between crossings, so
the max over the window lies on a candidate).
"""

from __future__ import annotations

import math
from collections import deque

import numpy as np

from repro.sim.costmodel import ServingCostModel
from repro.sim.scheduler import (
    _MAX_ITERATIONS,
    ReplicaSim,
    ReqRecord,
    SchedConfig,
)
from repro.sim.workload import SimRequest

_INF = math.inf
# Windows at or below this many decode steps are priced by a scalar loop
# instead of the numpy batch path: building/cumsumming the arrays costs
# tens of microseconds per call, which dwarfs a few memoized step prices.
_FF_SCALAR_K = 48


def _kv_tables(cost: ServingCostModel, upto: int) -> tuple[list, list]:
    """Per-context KV byte tables `alloc[ctx], exact[ctx]` for ctx in
    [0, upto], cached on the cost model (shared by every replica priced
    by it). Entries come straight from `cost.kv_bytes`, so lookups are
    bit-identical to direct calls. Rebuilt geometrically on growth."""
    tab = getattr(cost, "_vec_kv", None)
    if tab is not None and len(tab[0]) > upto:
        return tab
    hi = max(upto + 1, 4096)
    if tab is not None:
        hi = max(hi, 2 * len(tab[0]))
    alloc = [cost.kv_bytes(c) for c in range(hi)]
    if getattr(cost, "kv_block_tokens", 0) > 0:
        exact = [cost.kv_bytes(c, exact=True) for c in range(hi)]
    else:
        exact = alloc
    cost._vec_kv = (alloc, exact)
    return cost._vec_kv


class VecReplicaSim(ReplicaSim):
    """Drop-in `ReplicaSim` with flat columns and decode fast-forward.

    Supports the continuous and chunked policies (static batching stays
    on the reference engine — see `make_replica_sim`). Beyond the base
    API it exposes `advance_chunk(t_limit)`, which batches many engine
    iterations per call and reports completions grouped by the start
    clock of their completing iteration — what the cluster engine needs
    to release side effects in reference merge order.
    """

    def __init__(self, cost: ServingCostModel, sc: SchedConfig | None = None,
                 *, name: str = "", tracer=None):
        super().__init__(cost, sc, name=name, tracer=tracer)
        if self.sc.policy == "static":
            raise ValueError(
                "vectorized engine does not implement static batching; "
                "use engine='reference' (make_replica_sim does this for you)")
        # row-indexed columns; rows are append-only, freed logically
        self._req_col: list[SimRequest] = []
        self._rec_col: list[ReqRecord] = []
        self._rid_col: list[int] = []
        self._prompt: list[int] = []
        self._output: list[int] = []
        self._cached: list[int] = []
        self._gen: list[int] = []
        self._aseq: list[int] = []
        self._arrv: list[float] = []
        self._dl: list[float] = []  # EDF deadline (arrival + slo)
        self._pendq: deque[int] = deque()
        self._runrows: list[int] = []
        self._kvt, self._kvx = _kv_tables(cost, 0)
        self._kv_cache_val = 0.0
        self._kv_dirty = False
        # the base-class containers are unused; drop them so any code
        # path that silently depended on them fails loudly instead
        self._pending = None  # type: ignore[assignment]
        self._running = None  # type: ignore[assignment]

    # ------------------------------------------------------------- inspection
    @property
    def has_work(self) -> bool:
        """True while any request is queued or running."""
        return bool(self._pendq or self._runrows)

    @property
    def queue_len(self) -> int:
        """Requests waiting for admission (count)."""
        return len(self._pendq)

    @property
    def live(self) -> int:
        """Admitted requests currently holding KV (count)."""
        return len(self._runrows)

    @property
    def kv_used(self) -> float:
        """KV-cache bytes held by live requests right now."""
        # recomputed lazily: the cluster reads this once per routed
        # arrival (JSQ tie-breaks on it), which without the cache costs
        # O(slots) per view per arrival across the whole fleet
        if self._kv_dirty:
            kvt, cached = self._kvt, self._cached
            self._kv_cache_val = sum(kvt[cached[i]] for i in self._runrows)
            self._kv_dirty = False
        return self._kv_cache_val

    def _sample_counters(self) -> None:
        tr, t, track = self.tracer, self.now, self.name
        tr.counter("queue", t, len(self._pendq), track)
        tr.counter("live", t, self.live, track)
        tr.counter("kv_used", t, self.kv_used, track)
        tr.counter("busy_s", t, self.res.busy_s, track)

    # ---------------------------------------------------------------- enqueue
    def push(self, req: SimRequest, *, cached: int = 0, generated: int = 0) -> ReqRecord:
        """Enqueue a request; `cached`/`generated` (tokens) pre-warm its
        context for crash re-dispatch and KV handoff. Returns its record."""
        self._check_push(req, cached, generated)
        hi = req.prompt + req.output
        if len(self._kvt) <= hi:
            self._kvt, self._kvx = _kv_tables(self.cost, hi)
        rec = ReqRecord(req.rid, req.arrival, req.prompt, req.output)
        self.res.records.append(rec)
        self._rids.add(req.rid)
        row = len(self._req_col)
        self._req_col.append(req)
        self._rec_col.append(rec)
        self._rid_col.append(req.rid)
        self._prompt.append(req.prompt)
        self._output.append(req.output)
        self._cached.append(cached)
        self._gen.append(generated)
        self._aseq.append(-1)
        self._arrv.append(req.arrival)
        slo = req.slo_ttft if req.slo_ttft is not None else self.sc.slo_ttft
        self._dl.append(req.arrival + slo)
        self._pendq.append(row)
        return rec

    def kill(self) -> list[tuple[SimRequest, int, int, bool]]:
        """Crash the replica: drop all state and return the displaced
        requests as (req, cached tokens, generated tokens, started)."""
        out: list[tuple[SimRequest, int, int, bool]] = []
        for i in [*self._runrows, *self._pendq]:
            rec = self._rec_col[i]
            started = rec.admitted >= 0 or self._gen[i] > 0
            out.append((self._req_col[i], self._cached[i], self._gen[i], started))
            self.res.records.remove(rec)
            self._rids.discard(self._rid_col[i])
        self._pendq.clear()
        self._runrows.clear()
        self._kv_dirty = True
        return out

    def evict_pending(self, *, include_staged: bool = False) -> list[SimRequest]:
        """Remove and return never-admitted queued requests (drain
        re-routing); `include_staged` also evicts KV-handoff-staged ones."""
        keep: deque[int] = deque()
        out: list[SimRequest] = []
        for i in self._pendq:
            staged = self._cached[i] > 0 or self._gen[i] > 0
            if self._rec_col[i].admitted < 0 and (include_staged or not staged):
                out.append(self._req_col[i])
                self.res.records.remove(self._rec_col[i])
                self._rids.discard(self._rid_col[i])
            else:
                keep.append(i)
        self._pendq = keep
        return out

    # ------------------------------------------------------------- event loop
    def step(self) -> list[ReqRecord]:
        """One engine iteration, reference-identical (no fast-forward) —
        the traced/lockstep path."""
        if not self.has_work:
            return []
        return self._vstep()

    def run_until(self, t: float) -> list[ReqRecord]:
        """Run iterations while `now < t` (seconds; the last iteration may
        overshoot) and return records completed along the way."""
        out: list[ReqRecord] = []
        for _, recs in self.advance_chunk(t):
            out += recs
        return out

    def run(self) -> list[ReqRecord]:
        """Run until no work remains; returns all completed records."""
        out: list[ReqRecord] = []
        for _, recs in self.advance_chunk(_INF):
            out += recs
        return out

    def advance_chunk(self, t_limit: float, *, single: bool = False,
                      stop_on_done: bool = False,
                      ) -> list[tuple[float, list[ReqRecord]]]:
        """Advance while `now < t_limit` and work remains (the reference
        `run_until` loop condition — the last iteration may overshoot the
        limit). Returns `(start_clock, records)` per iteration that
        completed requests, where `start_clock` is the clock at which the
        completing iteration began — the cluster engine's merge key.
        `single=True` executes exactly one iteration (lockstep mode);
        `stop_on_done=True` stops after the first completing iteration
        (disaggregated prefill replicas: each completion creates a KV
        handoff whose ready time re-bounds the whole fleet's advance)."""
        out: list[tuple[float, list[ReqRecord]]] = []
        while self.has_work and self.now < t_limit:
            if not single:
                ffd = self._fast_forward(t_limit)
                if ffd is not None:
                    if ffd[1]:
                        out.append(ffd)
                        if stop_on_done:
                            break
                    continue
            start = self.now
            done = self._vstep()
            if done:
                out.append((start, done))
                if stop_on_done:
                    break
            if single:
                break
        return out

    # ---------------------------------------------------------- fast-forward
    def _fast_forward(self, t_limit: float):
        """Vectorize a pure-decode window; returns `(last_start, done)`
        after applying it, or None when this iteration must go through
        the exact per-step path."""
        rr = self._runrows
        if not rr or self._tr_rep:
            return None
        if self._slow_until > self.now:
            return None  # active or upcoming straggler window: step exactly
        prompt, cached, gen, output = self._prompt, self._cached, self._gen, self._output
        kvt, cap = self._kvt, self.cap
        # one fused pass: prefill-done precondition, first-step projected
        # KV, context total, and steps-to-first-completion
        alloc_1 = 0
        C0 = 0
        k_complete = None
        for i in rr:
            g = gen[i]
            c = cached[i]
            if g < 1 or prompt[i] + g - c != 1:
                return None  # someone still prefilling (or pre-first-token)
            C0 += c
            alloc_1 += kvt[c + 1]
            rem = output[i] - g
            if k_complete is None or rem < k_complete:
                k_complete = rem
        if alloc_1 > cap:
            return None  # this very step preempts: exact path handles it
        nxt_arr = None
        if self._pendq:
            # with a free slot an arrived request would admit this step;
            # with slots full, arrivals are inert until a completion, and
            # the window already ends at the first completion
            if len(rr) < self.sc.slots:
                arrv = self._arrv
                nxt_arr = min(arrv[i] for i in self._pendq)
                if nxt_arr <= self.now:
                    return None
        B = len(rr)
        cost, res = self.cost, self.res
        lim = t_limit if nxt_arr is None else min(t_limit, nxt_arr)
        # Estimate the window's step count from the first step's price.
        # Small windows (the common case inside a cluster, where the next
        # fleet event caps the chunk) go through a scalar loop: the numpy
        # path's fixed per-call cost is larger than pricing a handful of
        # steps one at a time. Both paths perform the identical sequence
        # of float adds, so the estimate only picks the cheaper route.
        k_est = k_complete
        dt1 = None
        if lim != _INF:
            dt1 = cost.decode_step_time(B, (C0 + B) / B)
            if dt1 > 0.0:
                k_est = min(k_est, int((lim - self.now) / dt1) + 1)
        if k_est <= _FF_SCALAR_K:
            now, busy_s, k = self.now, res.busy_s, 0
            last_start = now
            alloc_k = alloc_1
            while k < k_complete:
                start = now
                if start >= lim:
                    break
                j = k + 1
                if j > 1:
                    a = sum(kvt[cached[i] + j] for i in rr)
                    if a > cap:
                        break
                    alloc_k = a
                    dt = cost.decode_step_time(B, (C0 + j * B) / B)
                else:
                    # same memo key as the k_est probe above
                    dt = dt1 if dt1 is not None else cost.decode_step_time(
                        B, (C0 + B) / B)
                now = start + dt
                busy_s += dt
                last_start = start
                k += 1
            if k < 1:
                return None  # can't happen (start_1 == now < lim) — guard
            self.now = now
            res.busy_s = busy_s
        else:
            # largest k <= k_complete with projected KV within capacity
            # (projected allocation is nondecreasing in k)
            lo, hi = 1, k_complete
            while lo < hi:
                mid = (lo + hi + 1) // 2
                if sum(kvt[cached[i] + mid] for i in rr) <= cap:
                    lo = mid
                else:
                    hi = mid - 1
            k = lo
            # price steps 1..k: ctx_mean_j = (C0 + j*B)/B, one memoized
            # decode_step_time call per ctx-quantum bucket run
            js = np.arange(1, k + 1, dtype=np.int64)
            ctx_means = (C0 + js * B) / B  # int64/int64 -> float64
            q = max(cost.ctx_quantum, 1)
            ctx_q = np.maximum(np.rint(ctx_means / q).astype(np.int64) * q, 1)
            dts = np.empty(k, dtype=np.float64)
            run_starts = [0, *(np.flatnonzero(np.diff(ctx_q)) + 1).tolist()]
            for a_idx, a in enumerate(run_starts):
                b = run_starts[a_idx + 1] if a_idx + 1 < len(run_starts) else k
                dts[a:b] = cost.decode_step_time(B, float(ctx_means[a]))
            # np.cumsum accumulates left-to-right, so clocks[j] bit-matches
            # j sequential `now += dt` additions from the seeded value
            clocks = np.cumsum(np.concatenate(([self.now], dts)))
            busy = np.cumsum(np.concatenate(([res.busy_s], dts)))
            starts = clocks[:-1]
            if t_limit != _INF:
                k = min(k, int(np.searchsorted(starts, t_limit, side="left")))
            if nxt_arr is not None:
                k = min(k, int(np.searchsorted(starts, nxt_arr, side="left")))
            if k < 1:
                return None  # can't happen (start_1 == now < limits) — guard
            last_start = float(clocks[k - 1])
            self.now = float(clocks[k])
            res.busy_s = float(busy[k])
            alloc_k = sum(kvt[cached[i] + k] for i in rr)
        res.iterations += k
        res.decode_steps += k
        if res.iterations > _MAX_ITERATIONS:
            raise RuntimeError("simulation did not converge (check token_budget/kv)")
        # peak KV: projected allocation is monotone over the window, so
        # the reference per-step max reduces to the final step's value
        if alloc_k > res.peak_kv:
            res.peak_kv = alloc_k
        if self._paged:
            self._ff_waste(rr, k)
        done: list[ReqRecord] = []
        for i in rr:
            cached[i] += k
            gen[i] += k
        if k == k_complete:
            for i in [i for i in rr if gen[i] >= output[i]]:
                rec = self._rec_col[i]
                rec.finish = self.now
                rr.remove(i)
                self._rids.discard(self._rid_col[i])
                done.append(rec)
            self._kv_dirty = True
            return (last_start, done)
        self._kv_dirty = True
        return (self.now, done)

    def _ff_waste(self, rr: list[int], k: int) -> None:
        """Paged-KV waste peak over a fast-forwarded window, evaluated at
        page-crossing candidate steps (waste strictly decreases between
        crossings, so the max lies on a candidate — exact, not bounded)."""
        blk = self.cost.kv_block_tokens
        cached, kvt, kvx = self._cached, self._kvt, self._kvx
        cand = {1, k}
        for i in rr:
            j0 = (1 - cached[i]) % blk
            if j0 == 0:
                j0 = blk
            cand.update(range(j0, k + 1, blk))
        res = self.res
        for j in sorted(cand):
            alloc = sum(kvt[cached[i] + j] for i in rr)
            exact = sum(kvx[cached[i] + j] for i in rr)
            waste = alloc - exact
            if waste > res.peak_kv_waste:
                res.peak_kv_waste = waste

    # ------------------------------------------------------------- exact step
    def _next_candidate_row(self) -> int | None:
        if not self._pendq:
            return None
        if self.sc.admission == "fcfs":
            cand = self._pendq[0]
            return cand if self._arrv[cand] <= self.now else None
        best, bkey = None, None
        arrv, dl, rid = self._arrv, self._dl, self._rid_col
        for i in self._pendq:
            if arrv[i] > self.now:
                continue
            key = (dl[i], arrv[i], rid[i])
            if best is None or key < bkey:
                best, bkey = i, key
        return best

    def _vstep(self) -> list[ReqRecord]:
        """Exact port of the reference `_step_continuous` over columns —
        identical call sequence into the cost model, identical float
        expression order, identical container iteration order."""
        cost, sc, cap = self.cost, self.sc, self.cap
        rr, pendq, res = self._runrows, self._pendq, self.res
        prompt, output = self._prompt, self._output
        cached, gen, aseq = self._cached, self._gen, self._aseq
        kvt = self._kvt
        chunked = sc.policy == "chunked"
        if not rr and pendq:
            nxt = min(self._arrv[i] for i in pendq)
            if nxt > self.now:
                self.now = nxt
        # ---- admission into free slots (optimistic KV check) ----
        kv_now = sum(kvt[cached[i]] for i in rr)
        while len(rr) < sc.slots:
            c = self._next_candidate_row()
            if c is None:
                break
            need = kvt[prompt[c] + gen[c] + 1]
            if kv_now + need > cap:
                break  # blocking: later candidates must not jump the queue
            pendq.remove(c)
            rec = self._rec_col[c]
            if rec.admitted < 0:
                rec.admitted = self.now
                res.admit_order.append(self._rid_col[c])
            aseq[c] = self._admit_seq
            self._admit_seq += 1
            rr.append(c)
            kv_now += need

        # ---- plan this iteration's work ----
        def needs_prefill(i: int) -> bool:
            if gen[i] == 0:
                return cached[i] < prompt[i]
            return prompt[i] + gen[i] - cached[i] > 1

        decoders = [i for i in rr if not needs_prefill(i) and gen[i] >= 1]
        prefills: list[tuple[int, int]] = []
        if chunked:
            budget = sc.token_budget - len(decoders)
            for i in sorted((x for x in rr if needs_prefill(x)),
                            key=aseq.__getitem__):
                if budget <= 0:
                    break
                take = min(budget, prompt[i] + gen[i] - cached[i])
                prefills.append((i, take))
                budget -= take
        else:
            for i in rr:
                if needs_prefill(i):
                    prefills.append((i, prompt[i] + gen[i] - cached[i]))

        # ---- enforce the KV-capacity invariant by preempting youngest ----
        planned = {i: cached[i] for i in rr}
        for i in decoders:
            planned[i] += 1
        for i, take in prefills:
            planned[i] += take
        projected = sum(kvt[c] for c in planned.values())
        while projected > cap and len(rr) > 1:
            victim = max(rr, key=aseq.__getitem__)
            rr.remove(victim)
            if victim in decoders:
                decoders.remove(victim)
            prefills = [(i, n) for i, n in prefills if i != victim]
            del planned[victim]
            cached[victim] = 0
            self._rec_col[victim].preemptions += 1
            res.preemptions += 1
            if self._tr_req:
                self.tracer.instant("preempt", self.now, self.name,
                                    rid=self._rid_col[victim],
                                    generated=gen[victim])
            pendq.appendleft(victim)
            projected = sum(kvt[c] for c in planned.values())
        if projected > res.peak_kv:
            res.peak_kv = projected
        if self._paged:
            kvx = self._kvx
            exact = sum(kvx[c] for c in planned.values())
            if projected - exact > res.peak_kv_waste:
                res.peak_kv_waste = projected - exact

        # ---- price the iteration ----
        t_iter = 0.0
        if prefills and not chunked:
            s_pad = max(take for _, take in prefills)
            ctx_end = max(cached[i] + take for i, take in prefills)
            t_iter += cost.prefill_time(s_pad, ctx_end=ctx_end, batch=len(prefills))
        else:
            for i, take in prefills:
                t_iter += cost.prefill_time(
                    take, ctx_end=cached[i] + take,
                    with_head=cached[i] + take == prompt[i] + gen[i])
        if decoders:
            ctx_mean = sum(cached[i] + 1 for i in decoders) / len(decoders)
            t_iter += cost.decode_step_time(len(decoders), ctx_mean)
            res.decode_steps += 1
        # lint: disable-next=U303 -- exact sentinel: a priced iteration is
        # strictly positive; 0.0 means nothing was scheduled
        if t_iter == 0.0 and not pendq and not rr:
            return []
        t_iter = self._slowed(t_iter)
        self.now += t_iter
        res.iterations += 1
        res.busy_s += t_iter

        # ---- apply state transitions at iteration end ----
        done: list[ReqRecord] = []
        for i in decoders:
            cached[i] += 1
        for i, take in prefills:
            cached[i] += take
        for i in list(rr):
            if prompt[i] + gen[i] - cached[i] == 0 and gen[i] < output[i]:
                gen[i] += 1
                rec = self._rec_col[i]
                if rec.first_token < 0:
                    rec.first_token = self.now
                if gen[i] >= output[i]:
                    rec.finish = self.now
                    rr.remove(i)
                    self._rids.discard(self._rid_col[i])
                    done.append(rec)
        if res.iterations > _MAX_ITERATIONS:
            raise RuntimeError("simulation did not converge (check token_budget/kv)")
        self._kv_dirty = True  # before sampling: the counter reads kv_used
        if self._tr_rep:
            self._sample_counters()
        return done
