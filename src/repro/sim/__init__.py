"""repro.sim — analytical-cost-driven serving simulator.

The paper's inference model (§4.3, §6, Table 4) prices single-request
prefill/decode; this subsystem lifts those per-step costs into a discrete-
event simulation of a serving cluster under load, so scheduling, batching,
and KV-capacity questions can be answered without GPUs:

  * `workload`  — seeded arrival processes (constant / Poisson / bursty),
    prompt & output length distributions (fixed / lognormal), and JSONL
    trace replay. The same `Workload` spec drives the real `ServeEngine`
    via `to_engine_requests`, so simulated and executed schedules are
    comparable request-for-request.
  * `costmodel` — memoized prefill-chunk / decode-step costs built from
    `layer_ops` + `op_time` + `comm.allreduce` (the exact graphs
    `inference_latency` prices; a single-request simulation reproduces its
    TTFT/TPOT within 1%), plus §3.5 KV accounting against DRAM capacity.
  * `scheduler` — the event loop with pluggable policies: static batching,
    continuous batching, and chunked prefill under a token budget; FCFS
    admission, recompute-style preemption when KV is exhausted, and a hard
    KV-capacity invariant.
  * `metrics`   — TTFT/TPOT/e2e percentiles, goodput under SLOs, and
    throughput-latency Pareto sweeps over policies x slot counts.

CLI:

    PYTHONPATH=src python -m repro.sim --config qwen3_14b --hw h100 --qps 8

prints per-policy SLO tables and the static-vs-continuous sweep in a few
seconds. `python -m benchmarks.run serving` emits the same numbers as CSV.
"""

from repro.sim.costmodel import ServingCostModel
from repro.sim.metrics import dominates, pareto_sweep, summarize, summarize_records
from repro.sim.scheduler import (
    ADMISSIONS,
    ENGINES,
    POLICIES,
    ReplicaSim,
    ReqRecord,
    SchedConfig,
    SimResult,
    emit_record_spans,
    make_replica_sim,
    simulate,
)
from repro.sim.workload import LengthDist, SimRequest, Workload, to_engine_requests

__all__ = [
    "ADMISSIONS",
    "ENGINES",
    "LengthDist",
    "POLICIES",
    "ReplicaSim",
    "ReqRecord",
    "SchedConfig",
    "ServingCostModel",
    "SimRequest",
    "SimResult",
    "Workload",
    "dominates",
    "make_replica_sim",
    "emit_record_spans",
    "pareto_sweep",
    "simulate",
    "summarize",
    "summarize_records",
    "to_engine_requests",
]
