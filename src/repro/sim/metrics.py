"""SLO metrics and throughput-latency sweeps for simulated serving runs.

One metric vocabulary shared with `Breakdown.ttft`/`.tpot` in
`repro.core.predict`: TTFT is the prefill-side wait to the first emitted
token, TPOT the mean inter-token gap after it. Goodput counts only the
requests that met every configured SLO (the inference-perf convention),
normalized by makespan.

`summarize_records` aggregates any collection of `ReqRecord`s — one
replica's, one pool's, or a whole cluster's stitched records — so
`repro.sim` and `repro.cluster` report the same vocabulary at every level.
Percentile keys and interpolation come from `repro.obs.quantiles`
(`PCTS` = p50/p95/p99/p99.9, numpy linear interpolation), the same
convention the streaming estimators in `repro.obs` reproduce, so offline
trace analysis and in-sim summaries can never disagree on definitions.
"""

from __future__ import annotations

from dataclasses import replace

import numpy as np

from repro.obs.quantiles import PCTS, percentile_summary
from repro.sim.scheduler import SchedConfig, SimResult, simulate

__all__ = ["PCTS", "summarize_records", "summarize", "pareto_sweep",
           "dominates"]


def summarize_records(records, *, span: float | None = None,
                      slo_ttft: float | None = None,
                      slo_tpot: float | None = None) -> dict:
    """SLO metric dict over a bag of `ReqRecord`s. `span` is the makespan
    used to normalize throughput (defaults to the records' own span)."""
    recs = list(records)
    ttft = np.array([r.ttft for r in recs])
    e2e = np.array([r.e2e for r in recs])
    tpot = np.array([r.tpot for r in recs if r.output > 1])
    if span is None:
        span = (max(r.finish for r in recs) - min(r.arrival for r in recs)
                if recs else 0.0)
    out: dict = {"requests": len(recs)}
    for name, xs in (("ttft", ttft), ("tpot", tpot), ("e2e", e2e)):
        out.update(percentile_summary(xs, name))
    total_tokens = sum(r.output for r in recs)
    denom = max(span, 1e-12)
    out["makespan_s"] = span
    out["tokens_per_s"] = total_tokens / denom
    out["requests_per_s"] = len(recs) / denom
    ok = np.ones(len(recs), bool)
    if slo_ttft is not None:
        ok &= ttft <= slo_ttft
    if slo_tpot is not None:
        tpot_all = np.array([r.tpot for r in recs])
        ok &= tpot_all <= slo_tpot
    out["goodput_frac"] = float(ok.mean()) if len(recs) else 0.0
    out["goodput_rps"] = float(ok.sum()) / denom
    return out


def summarize(res: SimResult, *, slo_ttft: float | None = None,
              slo_tpot: float | None = None) -> dict:
    """Aggregate a SimResult into the SLO metric dict the CLI/benchmarks print."""
    out: dict = {
        "policy": res.policy,
        "iterations": res.iterations,
        "decode_steps": res.decode_steps,
        "preemptions": res.preemptions,
        "peak_kv_gb": res.peak_kv / 1e9,
        "kv_capacity_gb": res.kv_capacity / 1e9,
        "busy_s": res.busy_s,
        "kv_waste_gb": res.peak_kv_waste / 1e9,
        "kv_waste_frac": res.peak_kv_waste / res.peak_kv if res.peak_kv else 0.0,
    }
    out.update(summarize_records(res.records, span=res.makespan,
                                 slo_ttft=slo_ttft, slo_tpot=slo_tpot))
    return out


def pareto_sweep(requests, cost, *, policies=("static", "continuous", "chunked"),
                 slot_counts=(1, 2, 4, 8, 16), base: SchedConfig | None = None,
                 slo_ttft: float | None = None,
                 slo_tpot: float | None = None) -> list[dict]:
    """Throughput-latency frontier: simulate each (policy, slots) point on the
    SAME request trace and KV budget; rows carry tokens/s vs p95 e2e plus a
    `pareto` flag (non-dominated within the sweep)."""
    base = base or SchedConfig()
    rows = []
    for policy in policies:
        for slots in slot_counts:
            sc = replace(base, policy=policy, slots=slots,
                         token_budget=max(base.token_budget, slots))
            s = summarize(simulate(requests, cost, sc),
                          slo_ttft=slo_ttft, slo_tpot=slo_tpot)
            s["slots"] = slots
            rows.append(s)
    for row in rows:
        row["pareto"] = not any(dominates(o, row) for o in rows)
    return rows


def dominates(a: dict, b: dict) -> bool:
    """True when summary `a` beats `b` on the throughput-latency plane."""
    return (
        a["tokens_per_s"] >= b["tokens_per_s"]
        and a["e2e_p95"] <= b["e2e_p95"]
        and (a["tokens_per_s"] > b["tokens_per_s"] or a["e2e_p95"] < b["e2e_p95"])
    )
