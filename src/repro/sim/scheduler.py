"""Discrete-event serving scheduler over analytical step costs.

The simulator advances in engine iterations (the unit real continuous-
batching servers schedule at), pricing each iteration with
`ServingCostModel` instead of a wall clock. Three policies:

  * static     — classic static batching: wait for the engine to go idle,
                 admit up to `slots` queued requests, pad prompts to the
                 batch max, decode until the LONGEST request finishes.
  * continuous — slot-based continuous batching (Orca-style): free slots
                 are refilled FCFS every iteration; admitted prompts are
                 prefilled whole, finished requests free their slot (and
                 KV) immediately.
  * chunked    — continuous + chunked prefill under a per-iteration token
                 budget: each iteration spends one budget token per live
                 decoder and the remainder on head-of-line prefill chunks,
                 bounding inter-token stalls behind long prompts.

KV accounting follows §3.5: per-sequence cache bytes at the current
processed context, checked every iteration against the model's KV budget.
When projected growth exceeds capacity the youngest-admitted request is
preempted (KV dropped, request returned to the head of the queue) and
later resumed by re-prefilling prompt + already-emitted tokens — the
recompute-style preemption vLLM uses. The capacity invariant (`peak_kv <=
kv_capacity`) is enforced, not just sampled.

Token semantics mirror `ServeEngine`: completing a prefill yields the
first output token directly from the prefill logits; each decode step
processes the last emitted token and yields the next, so a request with
`output` tokens costs one prefill + `output - 1` decode steps.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass

from repro.sim.costmodel import ServingCostModel
from repro.sim.workload import SimRequest

POLICIES = ("static", "continuous", "chunked")

_MAX_ITERATIONS = 5_000_000  # runaway guard


@dataclass(frozen=True)
class SchedConfig:
    policy: str = "continuous"
    slots: int = 16  # max concurrent sequences (static: batch size)
    token_budget: int = 512  # chunked: tokens processed per iteration
    kv_capacity: float | None = None  # bytes; None -> cost.kv_capacity_bytes


@dataclass
class ReqRecord:
    rid: int
    arrival: float
    prompt: int
    output: int
    admitted: float = -1.0
    first_token: float = -1.0
    finish: float = -1.0
    preemptions: int = 0

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival

    @property
    def tpot(self) -> float:
        """Mean inter-token time after the first (0 for single-token outputs)."""
        if self.output <= 1:
            return 0.0
        return (self.finish - self.first_token) / (self.output - 1)

    @property
    def e2e(self) -> float:
        return self.finish - self.arrival


@dataclass
class SimResult:
    policy: str
    records: list[ReqRecord]
    admit_order: list[int]  # rids in first-admission order (FCFS witness)
    iterations: int = 0
    decode_steps: int = 0
    preemptions: int = 0
    peak_kv: float = 0.0
    kv_capacity: float = 0.0

    @property
    def makespan(self) -> float:
        if not self.records:
            return 0.0
        return max(r.finish for r in self.records) - min(r.arrival for r in self.records)


@dataclass
class _Run:
    """Live request state. `cached` = context tokens materialized in KV;
    deficit = prompt + generated - cached (1 while decoding normally)."""

    req: SimRequest
    rec: ReqRecord
    cached: int = 0
    generated: int = 0
    admit_seq: int = -1

    @property
    def prefill_target(self) -> int:
        """Context tokens the KV must hold before the next logits: the
        prompt plus every already-emitted token (re-built after preemption)."""
        return self.req.prompt + self.generated

    @property
    def deficit(self) -> int:
        return self.prefill_target - self.cached

    @property
    def needs_prefill(self) -> bool:
        return self.cached < self.req.prompt if self.generated == 0 else self.deficit > 1

    @property
    def done(self) -> bool:
        return self.generated >= self.req.output


def simulate(requests: list[SimRequest], cost: ServingCostModel,
             sc: SchedConfig | None = None) -> SimResult:
    sc = sc or SchedConfig()
    if sc.policy not in POLICIES:
        raise ValueError(f"unknown policy {sc.policy!r}; choose from {POLICIES}")
    if sc.slots < 1:
        raise ValueError("slots must be >= 1")
    if sc.policy == "chunked" and sc.token_budget < sc.slots:
        raise ValueError(
            "chunked prefill needs token_budget >= slots "
            "(each live slot consumes one decode token per iteration)")
    cap = sc.kv_capacity if sc.kv_capacity is not None else cost.kv_capacity_bytes
    if len({r.rid for r in requests}) != len(requests):
        raise ValueError("request rids must be unique")
    for r in requests:
        if r.prompt < 1 or r.output < 1:
            raise ValueError(
                f"request {r.rid} has prompt={r.prompt}, output={r.output}; "
                "both must be >= 1")
        need = cost.kv_bytes(r.prompt + r.output)
        if need > cap:
            raise ValueError(
                f"request {r.rid} needs {need / 1e9:.2f} GB KV at full context "
                f"but the budget is {cap / 1e9:.2f} GB — it can never be served")
    ordered = sorted(requests, key=lambda r: (r.arrival, r.rid))
    if sc.policy == "static":
        return _run_static(ordered, cost, sc, cap)
    return _run_continuous(ordered, cost, sc, cap, chunked=sc.policy == "chunked")


# ----------------------------------------------------------- static batching
def _run_static(ordered: list[SimRequest], cost: ServingCostModel,
                sc: SchedConfig, cap: float) -> SimResult:
    res = SimResult(sc.policy, [], [], kv_capacity=cap)
    recs = {r.rid: ReqRecord(r.rid, r.arrival, r.prompt, r.output) for r in ordered}
    res.records = [recs[r.rid] for r in ordered]
    pending = deque(ordered)
    t = 0.0
    while pending:
        if pending[0].arrival > t:
            t = pending[0].arrival
        # form a batch: FCFS up to `slots`, padded-KV projection must fit
        batch: list[SimRequest] = []
        while pending and pending[0].arrival <= t and len(batch) < sc.slots:
            cand = pending[0]
            trial = batch + [cand]
            s_pad = max(r.prompt for r in trial)
            out_max = max(r.output for r in trial)
            if len(trial) * cost.kv_bytes(s_pad + out_max) > cap and batch:
                break  # head-of-line blocks until the current batch drains
            batch.append(pending.popleft())
        B = len(batch)
        s_pad = max(r.prompt for r in batch)
        t_admit = t
        t += cost.prefill_time(s_pad, ctx_end=s_pad, batch=B)
        res.iterations += 1
        res.peak_kv = max(res.peak_kv, B * cost.kv_bytes(s_pad))
        gen = {}
        for r in batch:
            rec = recs[r.rid]
            rec.admitted = t_admit
            rec.first_token = t
            res.admit_order.append(r.rid)
            gen[r.rid] = 1
            if r.output <= 1:
                rec.finish = t
        # decode with the full padded batch until the longest request is done
        k = 0
        while any(gen[r.rid] < r.output for r in batch):
            k += 1
            t += cost.decode_step_time(B, s_pad + k)
            res.iterations += 1
            res.decode_steps += 1
            kv_now = sum(
                cost.kv_bytes(s_pad + min(k, r.output - 1)) for r in batch)
            res.peak_kv = max(res.peak_kv, kv_now)
            for r in batch:
                if gen[r.rid] < r.output:
                    gen[r.rid] += 1
                    if gen[r.rid] >= r.output:
                        recs[r.rid].finish = t
            if res.iterations > _MAX_ITERATIONS:
                raise RuntimeError("static simulation did not converge")
    return res


# ------------------------------------------------- continuous / chunked-prefill
def _run_continuous(ordered: list[SimRequest], cost: ServingCostModel,
                    sc: SchedConfig, cap: float, *, chunked: bool) -> SimResult:
    res = SimResult(sc.policy, [], [], kv_capacity=cap)
    recs = {r.rid: ReqRecord(r.rid, r.arrival, r.prompt, r.output) for r in ordered}
    res.records = [recs[r.rid] for r in ordered]
    pending: deque[_Run] = deque(_Run(r, recs[r.rid]) for r in ordered)
    running: list[_Run] = []
    t = 0.0
    admit_seq = 0

    while pending or running:
        if not running and pending and pending[0].req.arrival > t:
            t = pending[0].req.arrival
        # ---- FCFS admission into free slots (optimistic KV check) ----
        kv_now = sum(cost.kv_bytes(r.cached) for r in running)
        while pending and pending[0].req.arrival <= t and len(running) < sc.slots:
            cand = pending[0]
            need = cost.kv_bytes(cand.req.prompt + cand.generated + 1)
            if kv_now + need > cap:
                break  # FCFS: later arrivals must not jump the queue
            pending.popleft()
            if cand.rec.admitted < 0:
                cand.rec.admitted = t
                res.admit_order.append(cand.req.rid)
            cand.admit_seq = admit_seq
            admit_seq += 1
            running.append(cand)
            kv_now += need  # reserve the projected bytes, not the current 0

        # ---- plan this iteration's work ----
        decoders = [r for r in running if not r.needs_prefill and r.generated >= 1]
        prefills: list[tuple[_Run, int]] = []  # (run, tokens this iteration)
        if chunked:
            budget = sc.token_budget - len(decoders)
            for r in sorted((x for x in running if x.needs_prefill),
                            key=lambda x: x.admit_seq):
                if budget <= 0:
                    break
                take = min(budget, r.prefill_target - r.cached)
                prefills.append((r, take))
                budget -= take
        else:
            for r in running:
                if r.needs_prefill:
                    prefills.append((r, r.prefill_target - r.cached))

        # ---- enforce the KV-capacity invariant by preempting youngest ----
        planned = {id(r): r.cached for r in running}
        for r in decoders:
            planned[id(r)] += 1
        for r, take in prefills:
            planned[id(r)] += take
        projected = sum(cost.kv_bytes(c) for c in planned.values())
        while projected > cap and len(running) > 1:
            victim = max(running, key=lambda r: r.admit_seq)
            running.remove(victim)
            if victim in decoders:
                decoders.remove(victim)
            prefills = [(r, n) for r, n in prefills if r is not victim]
            del planned[id(victim)]
            victim.cached = 0
            victim.rec.preemptions += 1
            res.preemptions += 1
            pending.appendleft(victim)
            projected = sum(cost.kv_bytes(c) for c in planned.values())
        res.peak_kv = max(res.peak_kv, projected)

        # ---- price the iteration ----
        t_iter = 0.0
        if prefills and not chunked:
            # whole-prompt prefills admitted together run as ONE padded batch
            # (what ServeEngine._admit and the static path do); non-chunked
            # prefills always start from cached == 0
            s_pad = max(take for _, take in prefills)
            t_iter += cost.prefill_time(s_pad, ctx_end=s_pad, batch=len(prefills))
        else:
            for r, take in prefills:
                # only the chunk completing the prompt produces sampled logits
                t_iter += cost.prefill_time(
                    take, ctx_end=r.cached + take,
                    with_head=r.cached + take == r.prefill_target)
        if decoders:
            ctx_mean = sum(r.cached + 1 for r in decoders) / len(decoders)
            t_iter += cost.decode_step_time(len(decoders), ctx_mean)
            res.decode_steps += 1
        if t_iter == 0.0 and not pending and not running:
            break
        t += t_iter
        res.iterations += 1

        # ---- apply state transitions at iteration end ----
        for r in decoders:
            r.cached += 1
        for r, take in prefills:
            r.cached += take
        for r in list(running):
            if r.deficit == 0 and not r.done:  # logits available -> emit token
                r.generated += 1
                if r.rec.first_token < 0:
                    r.rec.first_token = t
                if r.done:
                    r.rec.finish = t
                    running.remove(r)
        if res.iterations > _MAX_ITERATIONS:
            raise RuntimeError("simulation did not converge (check token_budget/kv)")
    return res
