"""Discrete-event serving scheduler over analytical step costs.

The simulator advances in engine iterations (the unit real continuous-
batching servers schedule at), pricing each iteration with
`ServingCostModel` instead of a wall clock. Three policies:

  * static     — classic static batching: wait for the engine to go idle,
                 admit up to `slots` queued requests, pad prompts to the
                 batch max, decode until the LONGEST request finishes.
  * continuous — slot-based continuous batching (Orca-style): free slots
                 are refilled every iteration; admitted prompts are
                 prefilled whole, finished requests free their slot (and
                 KV) immediately.
  * chunked    — continuous + chunked prefill under a per-iteration token
                 budget: each iteration spends one budget token per live
                 decoder and the remainder on head-of-line prefill chunks,
                 bounding inter-token stalls behind long prompts.

Admission order is pluggable: `fcfs` (arrival order, head-of-line blocks)
or `edf` (earliest TTFT deadline first, deadline = arrival + slo_ttft with
per-request overrides from `SimRequest.slo_ttft`) — EDF reorders admission
only, never preempts for priority.

KV accounting follows §3.5: per-sequence cache bytes at the current
processed context, checked every iteration against the model's KV budget.
When projected growth exceeds capacity the youngest-admitted request is
preempted (KV dropped, request returned to the head of the queue) and
later resumed by re-prefilling prompt + already-emitted tokens — the
recompute-style preemption vLLM uses. The capacity invariant (`peak_kv <=
kv_capacity`) is enforced, not just sampled. With a page-granular cost
model (`kv_block_tokens > 0`) the same checks run on page allocations and
the internal fragmentation is reported as `SimResult.peak_kv_waste`.

Token semantics mirror `ServeEngine`: completing a prefill yields the
first output token directly from the prefill logits; each decode step
processes the last emitted token and yields the next, so a request with
`output` tokens costs one prefill + `output - 1` decode steps.

`ReplicaSim` is the incremental (steppable) form of the event loop:
`push()` enqueues requests at any time — optionally with pre-materialized
KV (`cached`/`generated`), which is how prefix-cache hits and
disaggregated prefill->decode handoffs enter mid-stream — and `step()`
executes exactly one engine iteration, returning the records that
finished in it. `simulate()` is the run-to-completion driver over one
replica; `repro.cluster` interleaves many replicas on a shared timeline.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass

from repro.obs.tracer import NULL_TRACER
from repro.sim.costmodel import ServingCostModel
from repro.sim.workload import SimRequest

POLICIES = ("static", "continuous", "chunked")
ADMISSIONS = ("fcfs", "edf")
# engine implementations: "vectorized" is the struct-of-arrays fast core
# (repro.sim.engine_vec), "reference" the original object-per-request loop
# kept for differential testing. Both execute the identical schedule.
ENGINES = ("vectorized", "reference")

_MAX_ITERATIONS = 5_000_000  # runaway guard


@dataclass(frozen=True)
class SchedConfig:
    policy: str = "continuous"
    slots: int = 16  # max concurrent sequences (static: batch size)
    token_budget: int = 512  # chunked: tokens processed per iteration
    kv_capacity: float | None = None  # bytes; None -> cost.kv_capacity_bytes
    admission: str = "fcfs"  # fcfs | edf (earliest TTFT deadline first)
    slo_ttft: float = 2.0  # EDF deadline offset for requests without their own


@dataclass
class ReqRecord:
    rid: int
    arrival: float
    prompt: int
    output: int
    admitted: float = -1.0
    first_token: float = -1.0
    finish: float = -1.0
    preemptions: int = 0

    @property
    def ttft(self) -> float:
        """Time to first token, seconds from arrival."""
        return self.first_token - self.arrival

    @property
    def tpot(self) -> float:
        """Mean inter-token time after the first (0 for single-token outputs)."""
        if self.output <= 1:
            return 0.0
        return (self.finish - self.first_token) / (self.output - 1)

    @property
    def e2e(self) -> float:
        """End-to-end latency, seconds from arrival to last token."""
        return self.finish - self.arrival


@dataclass
class SimResult:
    policy: str
    records: list[ReqRecord]
    admit_order: list[int]  # rids in first-admission order (FCFS witness)
    iterations: int = 0
    decode_steps: int = 0
    preemptions: int = 0
    peak_kv: float = 0.0
    kv_capacity: float = 0.0
    busy_s: float = 0.0  # summed iteration time (utilization numerator)
    peak_kv_waste: float = 0.0  # paged-KV internal fragmentation at the peak

    @property
    def makespan(self) -> float:
        """Seconds from the first arrival to the last finish (0 if empty)."""
        if not self.records:
            return 0.0
        return max(r.finish for r in self.records) - min(r.arrival for r in self.records)


@dataclass
class _Run:
    """Live request state. `cached` = context tokens materialized in KV;
    deficit = prompt + generated - cached (1 while decoding normally)."""

    req: SimRequest
    rec: ReqRecord
    cached: int = 0
    generated: int = 0
    admit_seq: int = -1

    @property
    def prefill_target(self) -> int:
        """Context tokens the KV must hold before the next logits: the
        prompt plus every already-emitted token (re-built after preemption)."""
        return self.req.prompt + self.generated

    @property
    def deficit(self) -> int:
        return self.prefill_target - self.cached

    @property
    def needs_prefill(self) -> bool:
        return self.cached < self.req.prompt if self.generated == 0 else self.deficit > 1

    @property
    def done(self) -> bool:
        return self.generated >= self.req.output


class ReplicaSim:
    """One serving replica as a steppable discrete-event simulation."""

    def __init__(self, cost: ServingCostModel, sc: SchedConfig | None = None,
                 *, name: str = "", tracer=None):
        sc = sc or SchedConfig()
        if sc.policy not in POLICIES:
            raise ValueError(f"unknown policy {sc.policy!r}; choose from {POLICIES}")
        if sc.admission not in ADMISSIONS:
            raise ValueError(
                f"unknown admission {sc.admission!r}; choose from {ADMISSIONS}")
        if sc.slots < 1:
            raise ValueError("slots must be >= 1")
        if sc.policy == "chunked" and sc.token_budget < sc.slots:
            raise ValueError(
                "chunked prefill needs token_budget >= slots "
                "(each live slot consumes one decode token per iteration)")
        self.cost = cost
        self.sc = sc
        self.name = name
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # hoisted level gates: the untraced hot path pays one bool test
        self._tr_rep = self.tracer.wants("replica")
        self._tr_req = self.tracer.wants("request")
        self.cap = sc.kv_capacity if sc.kv_capacity is not None else cost.kv_capacity_bytes
        self.now = 0.0
        self.res = SimResult(sc.policy, [], [], kv_capacity=self.cap)
        self._pending: deque[_Run] = deque()
        self._running: list[_Run] = []
        self._admit_seq = 0
        self._rids: set[int] = set()
        self._paged = getattr(cost, "kv_block_tokens", 0) > 0
        # straggler window: iterations priced while `_slow_from <= now <
        # _slow_until` are stretched by `_slow_factor` (chaos injection)
        self._slow_factor = 1.0
        self._slow_from = 0.0
        self._slow_until = 0.0
        # static-batching state
        self._batch: list[_Run] = []
        self._spad = 0
        self._k = 0

    # ------------------------------------------------------------- inspection
    @property
    def has_work(self) -> bool:
        """True while any request is queued, admitted, or mid-batch."""
        return bool(self._pending or self._running or self._batch)

    @property
    def queue_len(self) -> int:
        """Requests waiting for admission (count)."""
        return len(self._pending)

    @property
    def live(self) -> int:
        """Admitted requests currently holding KV (count)."""
        return len(self._running) + len(self._batch)

    @property
    def kv_used(self) -> float:
        """KV-cache bytes held by live requests right now."""
        return sum(self.cost.kv_bytes(r.cached)
                   for r in self._running + self._batch)

    # ---------------------------------------------------------------- enqueue
    def _check_push(self, req: SimRequest, cached: int, generated: int) -> None:
        """Admission-time validation shared by both engine implementations
        (the error messages are part of the contract parity tests pin)."""
        if req.rid in self._rids:
            raise ValueError(f"duplicate rid {req.rid}")
        if req.prompt < 1 or req.output < 1:
            raise ValueError(
                f"request {req.rid} has prompt={req.prompt}, output={req.output}; "
                "both must be >= 1")
        need = self.cost.kv_bytes(req.prompt + req.output)
        if need > self.cap:
            raise ValueError(
                f"request {req.rid} needs {need / 1e9:.2f} GB KV at full context "
                f"but the budget is {self.cap / 1e9:.2f} GB — it can never be served")
        if generated < 0 or generated >= req.output:
            raise ValueError(f"push generated={generated} outside [0, output)")
        if cached < 0 or req.prompt + generated - cached < 1:
            raise ValueError(
                f"push cached={cached} leaves no tokens to process "
                f"(prompt={req.prompt}, generated={generated})")
        if self.sc.policy == "static" and (cached > 0 or generated > 0):
            raise ValueError(
                "static batching cannot enter mid-stream (pre-materialized "
                "cached/generated KV state); use continuous or chunked")

    def push(self, req: SimRequest, *, cached: int = 0, generated: int = 0) -> ReqRecord:
        """Enqueue a request. `cached`/`generated` pre-materialize KV state:
        a prefix-cache hit enters with `cached < prompt`, a disaggregated
        decode handoff with `cached == prompt, generated == 1`."""
        self._check_push(req, cached, generated)
        rec = ReqRecord(req.rid, req.arrival, req.prompt, req.output)
        self.res.records.append(rec)
        self._rids.add(req.rid)
        self._pending.append(_Run(req, rec, cached=cached, generated=generated))
        return rec

    def set_slowdown(self, factor: float, until: float,
                     *, start: float | None = None) -> None:
        """Stretch every iteration priced inside `[start, until)` by
        `factor` — a straggler: the replica keeps serving, just slower
        (thermal throttling, a noisy neighbour, a flaky NIC). Takes
        effect from the next priced iteration; an iteration already in
        flight is not repriced. Overlapping windows merge to the worst
        factor over their union."""
        if factor < 1.0:
            raise ValueError("slowdown factor must be >= 1.0")
        start = self.now if start is None else start
        if start < self._slow_until and until > self._slow_from:
            factor = max(factor, self._slow_factor)
            start = min(start, self._slow_from)
            until = max(until, self._slow_until)
        self._slow_factor, self._slow_from, self._slow_until = factor, start, until

    def _slowed(self, t_iter: float) -> float:
        if self._slow_from <= self.now < self._slow_until:
            return t_iter * self._slow_factor
        return t_iter

    def kill(self) -> list[tuple[SimRequest, int, int, bool]]:
        """Crash the replica: every unfinished request (queued, admitted,
        mid-decode) loses its KV and is withdrawn as if never pushed here
        — records of work in flight are removed, finished records
        survive. Returns `(req, cached, generated, started)` per
        withdrawn request, admitted work first, so the cluster can
        re-dispatch the displaced stream (re-prefilling from scratch or
        restoring from a surviving replica's prefix cache) and account
        the lost tokens. Unlike `evict_pending` this is not graceful:
        admitted work is abandoned too."""
        out: list[tuple[SimRequest, int, int, bool]] = []
        for r in self._running + self._batch + list(self._pending):
            started = r.rec.admitted >= 0 or r.generated > 0
            out.append((r.req, r.cached, r.generated, started))
            self.res.records.remove(r.rec)
            self._rids.discard(r.req.rid)
        self._pending.clear()
        self._running.clear()
        self._batch = []
        return out

    def evict_pending(self, *, include_staged: bool = False) -> list[SimRequest]:
        """Remove and return queued requests that were never admitted (no
        slot, no KV, no emitted tokens here) — the graceful-drain contract:
        a replica leaving the fleet runs out everything it has started
        (including preempted-and-requeued work, which has already emitted
        tokens) but hands untouched arrivals back for re-routing. The
        evicted requests' records are withdrawn as if never pushed here.

        `include_staged` also evicts never-admitted requests that entered
        with pre-materialized KV state (`cached`/`generated` > 0): a
        draining DECODE replica's queued-but-unstarted handoffs, which the
        cluster re-routes to the surviving pool members so the drain does
        not have to wait behind a cold queue. Admitted work is never
        evicted in either mode."""
        keep: deque[_Run] = deque()
        out: list[SimRequest] = []
        for r in self._pending:
            staged = r.cached > 0 or r.generated > 0
            if r.rec.admitted < 0 and (include_staged or not staged):
                out.append(r.req)
                self.res.records.remove(r.rec)
                self._rids.discard(r.req.rid)
            else:
                keep.append(r)
        self._pending = keep
        return out

    # ------------------------------------------------------------- event loop
    def step(self) -> list[ReqRecord]:
        """Execute one engine iteration; returns records that finished."""
        if not self.has_work:
            return []
        if self.sc.policy == "static":
            return self._step_static()
        return self._step_continuous()

    def run_until(self, t: float) -> list[ReqRecord]:
        """Advance while there is work and the clock is behind `t`."""
        out: list[ReqRecord] = []
        while self.has_work and self.now < t:
            out += self.step()
        return out

    def run(self) -> list[ReqRecord]:
        """Drain everything queued (run-to-completion)."""
        out: list[ReqRecord] = []
        while self.has_work:
            out += self.step()
        return out

    def advance_chunk(self, t_limit: float, *, single: bool = False,
                      stop_on_done: bool = False,
                      ) -> list[tuple[float, list[ReqRecord]]]:
        """`run_until` that reports each completing iteration's start
        clock — the batched cluster loop's merge key (see
        `repro.sim.engine_vec.VecReplicaSim.advance_chunk` for the
        accelerated override and the flag semantics). This base version
        steps one iteration at a time, so a reference (or static-policy)
        replica can participate in a vectorized fleet unchanged."""
        out: list[tuple[float, list[ReqRecord]]] = []
        while self.has_work and self.now < t_limit:
            start = self.now
            done = self.step()
            if done:
                out.append((start, done))
                if stop_on_done:
                    break
            if single:
                break
        return out

    # ---------------------------------------------------------------- helpers
    def _deadline(self, req: SimRequest) -> float:
        slo = req.slo_ttft if req.slo_ttft is not None else self.sc.slo_ttft
        return req.arrival + slo

    def _next_candidate(self) -> _Run | None:
        """Head of the admission queue under the configured order, or None
        if nothing eligible (arrival <= now) is waiting. Blocking semantics
        are the caller's: if this candidate does not fit, admission stops."""
        if not self._pending:
            return None
        if self.sc.admission == "fcfs":
            cand = self._pending[0]
            return cand if cand.req.arrival <= self.now else None
        best, bkey = None, None
        for r in self._pending:
            if r.req.arrival > self.now:
                continue
            key = (self._deadline(r.req), r.req.arrival, r.req.rid)
            if best is None or key < bkey:
                best, bkey = r, key
        return best

    def _next_arrival(self) -> float:
        return min(r.req.arrival for r in self._pending)

    def _sample_counters(self) -> None:
        """Replica-level counter timeline, sampled once per priced
        iteration (guarded by the hoisted `_tr_rep` flag at call sites)."""
        tr, t, track = self.tracer, self.now, self.name
        tr.counter("queue", t, len(self._pending), track)
        tr.counter("live", t, self.live, track)
        tr.counter("kv_used", t, self.kv_used, track)
        tr.counter("busy_s", t, self.res.busy_s, track)

    def _note_kv(self, contexts) -> None:
        """Update peak KV (allocation) and, under paging, peak waste."""
        alloc = sum(self.cost.kv_bytes(c) for c in contexts)
        self.res.peak_kv = max(self.res.peak_kv, alloc)
        if self._paged:
            exact = sum(self.cost.kv_bytes(c, exact=True) for c in contexts)
            self.res.peak_kv_waste = max(self.res.peak_kv_waste, alloc - exact)

    # ----------------------------------------------------------- static batching
    def _step_static(self) -> list[ReqRecord]:
        if self._batch:
            return self._static_decode_step()
        if not self._pending:
            return []
        nxt = self._next_arrival()
        if nxt > self.now:
            self.now = nxt
        # form a batch: admission order up to `slots`, padded-KV projection must fit
        batch: list[_Run] = []
        while len(batch) < self.sc.slots:
            cand = self._next_candidate()
            if cand is None:
                break
            trial = [r.req for r in batch] + [cand.req]
            s_pad = max(r.prompt for r in trial)
            out_max = max(r.output for r in trial)
            if len(trial) * self.cost.kv_bytes(s_pad + out_max) > self.cap and batch:
                break  # head-of-line blocks until the current batch drains
            self._pending.remove(cand)
            batch.append(cand)
        if not batch:
            return []
        B = len(batch)
        s_pad = max(r.req.prompt for r in batch)
        t_admit = self.now
        t_iter = self._slowed(self.cost.prefill_time(s_pad, ctx_end=s_pad, batch=B))
        self.now += t_iter
        self.res.iterations += 1
        self.res.busy_s += t_iter
        self._note_kv([s_pad] * B)
        done: list[ReqRecord] = []
        for r in batch:
            r.rec.admitted = t_admit
            r.rec.first_token = self.now
            self.res.admit_order.append(r.req.rid)
            r.generated = 1
            r.cached = s_pad
            if r.req.output <= 1:
                r.rec.finish = self.now
                self._rids.discard(r.req.rid)
                done.append(r.rec)
        if all(r.generated >= r.req.output for r in batch):
            if self._tr_rep:
                self._sample_counters()
            return done  # prefill-only batch; the engine goes idle
        self._batch = batch
        self._spad = s_pad
        self._k = 0
        if self._tr_rep:
            self._sample_counters()
        return done

    def _static_decode_step(self) -> list[ReqRecord]:
        # decode with the full padded batch until the longest request is done
        batch = self._batch
        B = len(batch)
        self._k += 1
        t_iter = self._slowed(self.cost.decode_step_time(B, self._spad + self._k))
        self.now += t_iter
        self.res.iterations += 1
        self.res.decode_steps += 1
        self.res.busy_s += t_iter
        done: list[ReqRecord] = []
        for r in batch:
            if r.generated < r.req.output:
                r.cached += 1  # finished members hold KV at their final context
                r.generated += 1
                if r.generated >= r.req.output:
                    r.rec.finish = self.now
                    self._rids.discard(r.req.rid)
                    done.append(r.rec)
        self._note_kv([r.cached for r in batch])
        if all(r.generated >= r.req.output for r in batch):
            self._batch = []
        if self.res.iterations > _MAX_ITERATIONS:
            raise RuntimeError("static simulation did not converge")
        if self._tr_rep:
            self._sample_counters()
        return done

    # ------------------------------------------------- continuous / chunked-prefill
    def _step_continuous(self) -> list[ReqRecord]:
        cost, sc, cap = self.cost, self.sc, self.cap
        running, pending, res = self._running, self._pending, self.res
        chunked = sc.policy == "chunked"
        if not running and pending:
            nxt = self._next_arrival()
            if nxt > self.now:
                self.now = nxt
        # ---- admission into free slots (optimistic KV check) ----
        kv_now = sum(cost.kv_bytes(r.cached) for r in running)
        while len(running) < sc.slots:
            cand = self._next_candidate()
            if cand is None:
                break
            need = cost.kv_bytes(cand.req.prompt + cand.generated + 1)
            if kv_now + need > cap:
                break  # blocking: later candidates must not jump the queue
            pending.remove(cand)
            if cand.rec.admitted < 0:
                cand.rec.admitted = self.now
                res.admit_order.append(cand.req.rid)
            cand.admit_seq = self._admit_seq
            self._admit_seq += 1
            running.append(cand)
            kv_now += need  # reserve the projected bytes, not the current 0

        # ---- plan this iteration's work ----
        decoders = [r for r in running if not r.needs_prefill and r.generated >= 1]
        prefills: list[tuple[_Run, int]] = []  # (run, tokens this iteration)
        if chunked:
            budget = sc.token_budget - len(decoders)
            for r in sorted((x for x in running if x.needs_prefill),
                            key=lambda x: x.admit_seq):
                if budget <= 0:
                    break
                take = min(budget, r.prefill_target - r.cached)
                prefills.append((r, take))
                budget -= take
        else:
            for r in running:
                if r.needs_prefill:
                    prefills.append((r, r.prefill_target - r.cached))

        # ---- enforce the KV-capacity invariant by preempting youngest ----
        # lint: disable-next=D104 -- identity map: keys are only ever looked
        # up, iteration stays in `running` (admission) order
        planned = {id(r): r.cached for r in running}
        for r in decoders:
            planned[id(r)] += 1  # lint: disable=D104 -- identity lookup
        for r, take in prefills:
            planned[id(r)] += take  # lint: disable=D104 -- identity lookup
        projected = sum(cost.kv_bytes(c) for c in planned.values())
        while projected > cap and len(running) > 1:
            victim = max(running, key=lambda r: r.admit_seq)
            running.remove(victim)
            if victim in decoders:
                decoders.remove(victim)
            prefills = [(r, n) for r, n in prefills if r is not victim]
            del planned[id(victim)]  # lint: disable=D104 -- identity lookup
            victim.cached = 0
            victim.rec.preemptions += 1
            res.preemptions += 1
            if self._tr_req:
                self.tracer.instant("preempt", self.now, self.name,
                                    rid=victim.req.rid,
                                    generated=victim.generated)
            pending.appendleft(victim)
            projected = sum(cost.kv_bytes(c) for c in planned.values())
        self._note_kv(list(planned.values()))

        # ---- price the iteration ----
        t_iter = 0.0
        if prefills and not chunked:
            # whole-prompt prefills admitted together run as ONE padded batch
            # (what ServeEngine._admit and the static path do); the span covers
            # any prefix-cached context the batch resumes from
            s_pad = max(take for _, take in prefills)
            ctx_end = max(r.cached + take for r, take in prefills)
            t_iter += cost.prefill_time(s_pad, ctx_end=ctx_end, batch=len(prefills))
        else:
            for r, take in prefills:
                # only the chunk completing the prompt produces sampled logits
                t_iter += cost.prefill_time(
                    take, ctx_end=r.cached + take,
                    with_head=r.cached + take == r.prefill_target)
        if decoders:
            ctx_mean = sum(r.cached + 1 for r in decoders) / len(decoders)
            t_iter += cost.decode_step_time(len(decoders), ctx_mean)
            res.decode_steps += 1
        # lint: disable-next=U303 -- exact sentinel: a priced iteration is
        # strictly positive; 0.0 means nothing was scheduled
        if t_iter == 0.0 and not pending and not running:
            return []
        t_iter = self._slowed(t_iter)
        self.now += t_iter
        res.iterations += 1
        res.busy_s += t_iter

        # ---- apply state transitions at iteration end ----
        done: list[ReqRecord] = []
        for r in decoders:
            r.cached += 1
        for r, take in prefills:
            r.cached += take
        for r in list(running):
            if r.deficit == 0 and not r.done:  # logits available -> emit token
                r.generated += 1
                if r.rec.first_token < 0:
                    r.rec.first_token = self.now
                if r.done:
                    r.rec.finish = self.now
                    running.remove(r)
                    self._rids.discard(r.req.rid)
                    done.append(r.rec)
        if res.iterations > _MAX_ITERATIONS:
            raise RuntimeError("simulation did not converge (check token_budget/kv)")
        if self._tr_rep:
            self._sample_counters()
        return done


def emit_record_spans(tracer, records, track: str = "") -> None:
    """Emit single-replica lifecycle spans (queued -> prefill -> decode)
    and a `request.complete` terminal for each finished record. The
    cluster engine does NOT use this — it stitches richer disaggregated
    lifecycles (handoff, decode_wait) itself in `_ClusterEngine.result`."""
    for rec in records:
        rid = rec.rid
        if rec.admitted >= 0:
            tracer.span("queued", rec.arrival, rec.admitted, track, rid=rid)
        if rec.first_token >= 0 and rec.admitted >= 0:
            tracer.span("prefill", rec.admitted, rec.first_token, track, rid=rid)
        if rec.finish >= 0 and rec.first_token >= 0:
            tracer.span("decode", rec.first_token, rec.finish, track, rid=rid)
            tracer.instant("request.complete", rec.finish, track, rid=rid,
                           ttft=rec.ttft, tpot=rec.tpot, e2e=rec.e2e)


def make_replica_sim(cost: ServingCostModel, sc: SchedConfig | None = None,
                     *, engine: str = "vectorized", name: str = "",
                     tracer=None) -> ReplicaSim:
    """Instantiate a replica simulation under the chosen engine. The
    vectorized core covers continuous/chunked scheduling; static batching
    (a cold path — whole-batch admission, no mid-stream entry) always runs
    on the reference engine, which is exact by construction."""
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")
    policy = (sc or SchedConfig()).policy
    if engine == "vectorized" and policy != "static":
        from repro.sim.engine_vec import VecReplicaSim  # local: avoid cycle
        return VecReplicaSim(cost, sc, name=name, tracer=tracer)
    return ReplicaSim(cost, sc, name=name, tracer=tracer)


def simulate(requests: list[SimRequest], cost: ServingCostModel,
             sc: SchedConfig | None = None, *, tracer=None,
             slowdown: tuple[float, float, float] | None = None,
             engine: str = "vectorized") -> SimResult:
    """Run one replica to completion over a whole request list.
    `slowdown=(factor, start, duration)` injects a straggler window —
    iterations priced inside `[start, start + duration)` are stretched by
    `factor` (see `ReplicaSim.set_slowdown`). `engine` selects the
    vectorized fast core or the reference loop (identical results; see
    docs/performance.md for the parity contract)."""
    tracer = tracer if tracer is not None else NULL_TRACER
    sim = make_replica_sim(cost, sc, engine=engine, tracer=tracer)
    if slowdown is not None:
        factor, start, duration = slowdown
        sim.set_slowdown(factor, start + duration, start=start)
    for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
        sim.push(r)
    sim.run()
    if tracer.wants("request"):
        emit_record_spans(tracer, sim.res.records)
    if tracer.enabled:
        tracer.meta.setdefault("t0", 0.0)
        tracer.meta["horizon"] = max(tracer.meta.get("horizon", 0.0), sim.now)
    return sim.res
