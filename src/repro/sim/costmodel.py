"""Analytical step costs for the serving simulator.

Every simulator "clock tick" is priced by the same roofline + collective
models that `repro.core.predict.inference_latency` uses (§4.3, Table 4):

  * prefill chunk — `layer_ops(S=chunk, ctx=offset+chunk, decode=False)`
    summed over layers, plus the LM head and 2 TP all-reduces per layer on
    the latency-optimal double binary tree (eq. 4),
  * decode step   — `layer_ops(S=1, ctx, decode=True)` for the live batch,
    plus head, TP all-reduce, and the constant per-step engine overhead.

Costs are memoized on (batch, tokens, ctx-bucket); `ctx_quantum` trades
memoization hit-rate against exactness (use 1 to match `inference_latency`
bit-for-bit in regression tests, 8-32 for large sweeps).

KV admission comes from the paper's §3.5 cache formula (`kv_cache_bytes`,
GQA/sliding-window aware, + recurrent state for SSM/hybrid archs) checked
against the per-device DRAM capacity left after weights. Setting
`kv_block_tokens > 0` switches admission to page-granular (vLLM-style)
accounting: every sequence's context is rounded up to whole pages, so the
scheduler sees allocation (with internal fragmentation) rather than exact
occupancy; `kv_bytes(ctx, exact=True)` still returns the unpaged figure so
the waste is measurable.

Note: this intentionally re-prices the same op graph `inference_latency`
builds rather than refactoring that function onto this class —
`inference_latency` is calibrated against the paper's validation tables
and must not move. The contract between the two is regression-tested to
1% in tests/test_sim.py (single-request simulation vs analytical TTFT/
TPOT); edits to either side that drift the graphs will trip it.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.configs.base import ModelConfig
from repro.core import comm as C
from repro.core.hardware import HardwareSpec
from repro.core.kvcache import kv_cache_bytes, recurrent_state_bytes
from repro.core.operators import embedding_head_ops, layer_ops, total_param_count
from repro.core.predict import _n_ar_layers
from repro.core.roofline import total_time


@dataclass
class ServingCostModel:
    cfg: ModelConfig
    hw: HardwareSpec
    tp: int = 1
    prec: int = 2
    comm_algo: str = "tree"  # inference default (§3.4): latency-optimal tree
    per_token_overhead: float = 300e-6  # per engine step (matches predict.py)
    ctx_quantum: int = 8
    kv_headroom: float = 0.9  # fraction of post-weight DRAM usable for KV
    kv_block_tokens: int = 0  # paged-KV page size in tokens (0 = contiguous)
    _memo: dict = field(default_factory=dict, repr=False)

    # ------------------------------------------------------------------ costs
    def prefill_time(self, tokens: int, *, ctx_end: int | None = None,
                     batch: int = 1, with_head: bool = True) -> float:
        """Seconds to prefill `tokens` new tokens per sequence (batched,
        padded to a common length) whose attention spans `ctx_end` keys.
        `with_head=False` prices a non-final chunk of a chunked prefill —
        only the chunk that completes the prompt produces sampled logits."""
        S = int(tokens)
        ctx = int(ctx_end) if ctx_end is not None else S
        q = max(self.ctx_quantum, 1)
        ctx = max(int(round(ctx / q)) * q, S, 1)  # bucket the span, never below S
        key = ("prefill", batch, S, ctx, with_head)
        hit = self._memo.get(key)
        if hit is not None:
            return hit
        ops = []
        for i in range(self.cfg.num_layers):
            ops += layer_ops(self.cfg, batch, S, ctx, self.tp, i,
                             decode=False, prec=self.prec)
        t, _ = total_time(self.hw, ops)
        if with_head:
            t += self._head_time(batch)
        K = batch * S * self.cfg.d_model * self.prec
        t += 2.0 * _n_ar_layers(self.cfg) * C.allreduce(
            K, self.tp, self.hw.net[0], algo=self.comm_algo)
        self._memo[key] = t
        return t

    def decode_step_time(self, batch: int, ctx: float) -> float:
        """Seconds for one decode iteration of `batch` sequences at (mean)
        context `ctx` — per-op graph + head + TP all-reduce + step overhead."""
        q = max(self.ctx_quantum, 1)
        ctx_q = max(int(round(ctx / q)) * q, 1)
        key = ("decode", batch, ctx_q)
        hit = self._memo.get(key)
        if hit is not None:
            return hit
        ops = []
        for i in range(self.cfg.num_layers):
            ops += layer_ops(self.cfg, batch, 1, ctx_q, self.tp, i,
                             decode=True, prec=self.prec)
        t, _ = total_time(self.hw, ops)
        t += self._head_time(batch)
        K = batch * self.cfg.d_model * self.prec
        t += 2.0 * _n_ar_layers(self.cfg) * C.allreduce(
            K, self.tp, self.hw.net[0], algo=self.comm_algo)
        t += self.per_token_overhead
        self._memo[key] = t
        return t

    def _head_time(self, batch: int) -> float:
        key = ("head", batch)
        hit = self._memo.get(key)
        if hit is None:
            hit, _ = total_time(
                self.hw, embedding_head_ops(self.cfg, batch, 1, self.tp, prec=self.prec))
            self._memo[key] = hit
        return hit

    # --------------------------------------------------------------- capacity
    def kv_bytes(self, ctx: int, *, exact: bool = False) -> float:
        """Per-device cache bytes for ONE sequence holding `ctx` tokens.
        With `kv_block_tokens` set, returns the page-granular *allocation*
        (ctx rounded up to whole pages); `exact=True` bypasses paging."""
        if ctx <= 0:
            return 0.0
        alloc = int(ctx)
        if self.kv_block_tokens > 0 and not exact:
            blk = self.kv_block_tokens
            alloc = -(-alloc // blk) * blk
        b = kv_cache_bytes(self.cfg, 1, alloc, self.prec)
        b += recurrent_state_bytes(self.cfg, 1)
        return b / self.tp

    def kv_handoff_bytes(self, ctx: int) -> float:
        """Total bytes (summed over all tp shards) to migrate one sequence's
        cache to another replica — the prefill->decode KV transfer volume in
        disaggregated serving, priced by `comm.p2p` at the cluster layer."""
        if ctx <= 0:
            return 0.0
        return (kv_cache_bytes(self.cfg, 1, int(ctx), self.prec)
                + recurrent_state_bytes(self.cfg, 1))

    @property
    def weight_bytes(self) -> float:
        """Per-device resident weight bytes."""
        return total_param_count(self.cfg) * self.prec / self.tp

    @property
    def kv_capacity_bytes(self) -> float:
        """Per-device DRAM left for KV after weights, derated by headroom."""
        free = self.hw.dram.capacity - self.weight_bytes
        if free <= 0:
            raise ValueError(
                f"{self.cfg.name} weights ({self.weight_bytes / 1e9:.1f} GB/dev) "
                f"exceed {self.hw.name} DRAM at tp={self.tp}")
        return free * self.kv_headroom
