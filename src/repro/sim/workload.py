"""Seeded workload generation for the serving simulator.

A `Workload` is a declarative spec — arrival process (constant, Poisson,
bursty hyperexponential, diurnal, rate-envelope replay), prompt/output
length distributions (fixed, lognormal), or a JSONL trace replay — that
`generate()` expands into a deterministic list of `SimRequest`s. The same
spec drives both the analytical simulator (`repro.sim.scheduler`) and the
real `ServeEngine` (via `to_engine_requests`), so simulated and executed
schedules are comparable request-for-request.

Time-varying arrivals are a non-homogeneous Poisson process sampled by
Lewis-Shedler thinning of a homogeneous process at the envelope peak:

  * `arrival="diurnal"`  — sinusoidal rate envelope
    `rate(t) = qps * (1 + diurnal_amp * sin(2*pi*(t/diurnal_period +
    diurnal_phase)))`, the compressed day/night cycle autoscaling studies
    are run against (mean rate stays `qps`).
  * `arrival="envelope"` — piecewise-linear rate envelope replayed from a
    JSONL file (`rate_path`) of {"t": seconds, "qps": rate} rows (aliases
    "time"/"rate"), for replaying measured production rate curves.

`rate_at(t)` exposes the envelope so autoscaling policies and plots can
reference the offered load the generator drew from; `peak_rate(t0, t1)`
is its lookahead form — the maximum offered rate over a window, which is
what a predictive autoscaler provisioning capacity that takes `warmup`
seconds to come online must target (pass `Workload.peak_rate` as
`AutoscaleConfig.envelope`).

Trace JSONL rows: {"arrival": s, "prompt": n, "output": m} — the aliases
"arrival_s", "prompt_tokens"/"input_tokens", "output_tokens" are accepted
(the inference-perf trace convention); optional "session" and "slo_ttft"
keys feed affinity routing and EDF admission, and optional
"prefix_group"/"prefix_len" keys mark a shared prompt prefix (system
prompt / few-shot header) for the modeled prefix cache. Rows without
"arrival" get arrivals from the configured arrival process. Synthetic
specs generate shared prefixes via `num_prefix_groups` (each group draws
one prefix length from the `prefix` distribution).

For multi-replica experiments that need *independent* per-replica streams
(rather than one shared stream split by a router), `substreams(n)` shards
the spec through `np.random.SeedSequence.spawn`, avoiding the correlation
artifacts of naive `seed + i` reseeding.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field, replace

import numpy as np


@dataclass(frozen=True)
class SimRequest:
    rid: int
    arrival: float  # seconds from workload start
    prompt: int  # prompt tokens
    output: int  # tokens to generate (>= 1)
    session: int = -1  # session/prefix-affinity key (-1 = none)
    slo_ttft: float | None = None  # per-request TTFT deadline offset (EDF)
    prefix_group: int = -1  # shared-prefix group id (-1 = none); the first
    prefix_len: int = 0  # `prefix_len` prompt tokens are the group's shared
    #                      prefix (system prompt / few-shot header), reusable
    #                      across sessions by the modeled prefix cache


@dataclass(frozen=True)
class LengthDist:
    kind: str = "fixed"  # fixed | lognormal
    mean: float = 512.0
    sigma: float = 0.5  # lognormal shape (log-space std)
    lo: int = 1
    hi: int = 131072

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Draw `n` lengths (tokens), clipped to [lo, hi]."""
        if self.kind == "fixed":
            vals = np.full(n, self.mean)
        elif self.kind == "lognormal":
            # parameterized so E[X] == mean
            mu = np.log(max(self.mean, 1.0)) - 0.5 * self.sigma**2
            vals = rng.lognormal(mu, self.sigma, size=n)
        else:
            raise ValueError(f"unknown length dist {self.kind!r}")
        return np.clip(np.rint(vals), self.lo, self.hi).astype(np.int64)


@dataclass(frozen=True)
class Workload:
    name: str = "synthetic"
    qps: float = 8.0
    num_requests: int = 128
    arrival: str = "poisson"  # constant | poisson | bursty | diurnal | envelope
    prompt: LengthDist = field(default_factory=lambda: LengthDist("lognormal", 512.0))
    output: LengthDist = field(default_factory=lambda: LengthDist("fixed", 128.0))
    seed: int = 0
    # bursty = hyperexponential: `burst_fraction` of gaps drawn at
    # `burst_factor`x the base rate, the rest stretched to keep mean qps
    burst_factor: float = 8.0
    burst_fraction: float = 0.2
    trace_path: str | None = None
    num_sessions: int = 0  # >0: assign each request a session id in [0, n)
    slo_ttft: float | tuple | None = None  # scalar, or tuple sampled per request
    # shared-prefix groups (multi-tenant system prompts / few-shot headers):
    # each request joins a group in [0, n); each GROUP draws one prefix
    # length from `prefix` — the shared head of every member's prompt,
    # reusable across sessions by repro.cluster's modeled prefix cache
    num_prefix_groups: int = 0
    prefix: LengthDist = field(default_factory=lambda: LengthDist("fixed", 256.0))
    # diurnal envelope: mean rate stays `qps`, peak is qps * (1 + amp)
    diurnal_period: float = 240.0  # seconds per (compressed) day
    diurnal_amp: float = 0.8  # relative swing, in [0, 1]
    diurnal_phase: float = 0.0  # cycle offset, fraction of a period
    rate_path: str | None = None  # JSONL rate envelope (arrival="envelope")

    # ------------------------------------------------------------- generation
    def generate(self) -> list[SimRequest]:
        """Materialize the request stream: arrival times in seconds from
        t=0, prompt/output lengths in tokens; pure function of the spec
        (seeded — same spec, same stream)."""
        if self.trace_path is not None:
            return self._replay_trace()
        rng = np.random.default_rng(self.seed)
        gaps = self._gaps(rng, self.num_requests)
        arrivals = np.cumsum(gaps)
        prompts = self.prompt.sample(rng, self.num_requests)
        outputs = self.output.sample(rng, self.num_requests)
        # optional draws come last so specs without them keep the exact
        # request streams earlier PRs generated
        sessions = (rng.integers(0, self.num_sessions, size=self.num_requests)
                    if self.num_sessions > 0 else None)
        slos = self._sample_slos(rng, self.num_requests)
        groups = plens = None
        if self.num_prefix_groups > 0:
            # one prefix length per GROUP (all members share the same
            # header), then a group per request; a request's cacheable
            # prefix is capped at prompt - 1 (the final token always runs)
            group_len = self.prefix.sample(rng, self.num_prefix_groups)
            groups = rng.integers(0, self.num_prefix_groups,
                                  size=self.num_requests)
            plens = np.minimum(group_len[groups],
                               np.maximum(prompts - 1, 0))
        return [
            SimRequest(i, float(arrivals[i]), int(prompts[i]), max(int(outputs[i]), 1),
                       session=int(sessions[i]) if sessions is not None else -1,
                       slo_ttft=slos[i],
                       prefix_group=int(groups[i]) if groups is not None else -1,
                       prefix_len=int(plens[i]) if plens is not None else 0)
            for i in range(self.num_requests)
        ]

    def _sample_slos(self, rng: np.random.Generator, n: int) -> list:
        if self.slo_ttft is None:
            return [None] * n
        if isinstance(self.slo_ttft, (int, float)):
            return [float(self.slo_ttft)] * n
        choices = [float(x) for x in self.slo_ttft]
        return [choices[i] for i in rng.integers(0, len(choices), size=n)]

    def substreams(self, n: int) -> list["Workload"]:
        """Shard into `n` decorrelated sub-workloads (1/n of the rate and
        request count each) via `SeedSequence.spawn` — the spawned child
        seeds are statistically independent, unlike `seed + i` reseeding
        which correlates the low bits of neighbouring streams."""
        if n < 1:
            raise ValueError("substreams needs n >= 1")
        if self.trace_path is not None or self.rate_path is not None:
            raise ValueError("substreams applies to synthetic specs, not "
                             "trace/envelope replays")
        children = np.random.SeedSequence(self.seed).spawn(n)
        counts = [self.num_requests // n + (1 if i < self.num_requests % n else 0)
                  for i in range(n)]
        return [
            replace(self, name=f"{self.name}[{i}/{n}]", qps=self.qps / n,
                    num_requests=counts[i],
                    seed=int(children[i].generate_state(1)[0]))
            for i in range(n)
        ]

    # -------------------------------------------------------- rate envelopes
    def _envelope(self) -> tuple[np.ndarray, np.ndarray]:
        """(times, rates) breakpoints of the piecewise-linear envelope,
        parsed once per spec (frozen dataclass; cached out-of-band)."""
        cached = getattr(self, "_env_cache", None)
        if cached is not None:
            return cached
        if self.rate_path is None:
            raise ValueError('arrival="envelope" needs rate_path=')
        ts, rs = [], []
        with open(self.rate_path) as f:
            for i, line in enumerate(f):
                line = line.strip()
                if not line:
                    continue
                row = json.loads(line)
                t = row.get("t", row.get("time"))
                r = row.get("qps", row.get("rate"))
                if t is None or r is None:
                    raise ValueError(f"rate envelope row {i} needs t/qps: {row}")
                if float(r) < 0:
                    raise ValueError(f"rate envelope row {i} has negative rate")
                ts.append(float(t))
                rs.append(float(r))
        if not ts:
            raise ValueError(f"rate envelope {self.rate_path!r} is empty")
        order = np.argsort(ts, kind="stable")
        ts_a, rs_a = np.asarray(ts)[order], np.asarray(rs)[order]
        if rs_a[-1] <= 0:
            # the envelope is held constant past its last breakpoint, so a
            # zero tail means arrivals stop forever — thinning would spin
            raise ValueError(
                f"rate envelope {self.rate_path!r} ends at rate 0; the tail "
                "rate is held forever and the workload could never finish "
                "generating (end the trace on a positive rate)")
        object.__setattr__(self, "_env_cache", (ts_a, rs_a))
        return ts_a, rs_a

    def rate_at(self, t: float) -> float:
        """Offered arrival rate (requests/s) at time `t` under this spec's
        envelope; constant specs just return `qps`."""
        if self.arrival == "diurnal":
            return self.qps * (1.0 + self.diurnal_amp * np.sin(
                2.0 * np.pi * (t / self.diurnal_period + self.diurnal_phase)))
        if self.arrival == "envelope":
            ts, rs = self._envelope()
            return float(np.interp(t, ts, rs))
        return self.qps

    def peak_rate(self, t0: float, t1: float) -> float:
        """Maximum offered arrival rate (requests/s) over [t0, t1].

        The envelope-lookahead a predictive autoscaler runs on: capacity
        ordered at `t0` that takes `t1 - t0` seconds to warm up must be
        sized for the PEAK rate of the window, not the instantaneous rate
        at either end (on the downslope of a diurnal crest the endpoint
        rates understate the crest still inside the window).

          * diurnal  — closed form: the sinusoid's crest if one falls
            inside the window, else the larger endpoint (the envelope is
            monotonic between extremes).
          * envelope — the max over both endpoints and every breakpoint
            strictly inside the window (the replay is piecewise-linear).
          * constant/poisson/bursty — `qps` (flat envelope).
        """
        if t1 < t0:
            raise ValueError("peak_rate needs t1 >= t0")
        if self.arrival == "diurnal":
            # rate crests where sin(.) == 1: t* = (0.25 - phase + k) * P
            period = self.diurnal_period
            t_star = (0.25 - self.diurnal_phase) * period
            k = math.ceil((t0 - t_star) / period)
            if t_star + k * period <= t1:
                return self.qps * (1.0 + self.diurnal_amp)
            return max(self.rate_at(t0), self.rate_at(t1))
        if self.arrival == "envelope":
            ts, rs = self._envelope()
            inside = rs[(ts > t0) & (ts < t1)]
            peak = max(self.rate_at(t0), self.rate_at(t1))
            return float(max(peak, inside.max())) if inside.size else peak
        return self.qps

    def _thinned_arrivals(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Non-homogeneous Poisson arrivals by Lewis-Shedler thinning: draw a
        homogeneous process at the envelope peak, accept each candidate with
        probability rate(t)/peak. One uniform is drawn per candidate, so the
        stream is deterministic in (seed, envelope)."""
        if self.arrival == "diurnal":
            if not 0.0 <= self.diurnal_amp <= 1.0:
                raise ValueError("diurnal_amp must be in [0, 1]")
            if self.diurnal_period <= 0:
                raise ValueError("diurnal_period must be positive")
            lam_max = self.qps * (1.0 + self.diurnal_amp)
        else:
            lam_max = float(self._envelope()[1].max())
        if lam_max <= 0:
            raise ValueError("rate envelope peak must be positive")
        out = np.empty(n)
        t, i = 0.0, 0
        while i < n:
            t += rng.exponential(1.0 / lam_max)
            if rng.random() * lam_max <= self.rate_at(t):
                out[i] = t
                i += 1
        return out

    def _gaps(self, rng: np.random.Generator, n: int) -> np.ndarray:
        if self.arrival == "envelope":
            return np.diff(self._thinned_arrivals(rng, n), prepend=0.0)
        if self.qps <= 0:
            raise ValueError("qps must be positive")
        if self.arrival == "diurnal":
            return np.diff(self._thinned_arrivals(rng, n), prepend=0.0)
        mean_gap = 1.0 / self.qps
        if self.arrival == "constant":
            return np.full(n, mean_gap)
        if self.arrival == "poisson":
            return rng.exponential(mean_gap, size=n)
        if self.arrival == "bursty":
            bf = min(max(self.burst_fraction, 0.0), 0.95)
            m_burst = mean_gap / self.burst_factor
            m_off = (mean_gap - bf * m_burst) / (1.0 - bf)
            in_burst = rng.random(n) < bf
            gaps = rng.exponential(m_off, size=n)
            gaps[in_burst] = rng.exponential(m_burst, size=int(in_burst.sum()))
            return gaps
        raise ValueError(f"unknown arrival process {self.arrival!r}")

    def _replay_trace(self) -> list[SimRequest]:
        rows = []
        with open(self.trace_path) as f:
            for line in f:
                line = line.strip()
                if line:
                    rows.append(json.loads(line))
        rng = np.random.default_rng(self.seed)
        gaps = self._gaps(rng, len(rows))
        synth_arrivals = np.cumsum(gaps)
        reqs = []
        for i, row in enumerate(rows):
            arrival = row.get("arrival", row.get("arrival_s"))
            if arrival is None:
                arrival = float(synth_arrivals[i])
            prompt = row.get("prompt", row.get("prompt_tokens", row.get("input_tokens")))
            output = row.get("output", row.get("output_tokens"))
            if prompt is None or output is None:
                raise ValueError(f"trace row {i} missing prompt/output tokens: {row}")
            slo = row.get("slo_ttft")
            if slo is None and isinstance(self.slo_ttft, (int, float)):
                slo = float(self.slo_ttft)
            prompt_n = max(int(prompt), 1)
            group = int(row.get("prefix_group", -1))
            plen = min(max(int(row.get("prefix_len", 0)), 0), prompt_n - 1) \
                if group >= 0 else 0
            reqs.append(SimRequest(i, float(arrival), prompt_n,
                                   max(int(output), 1),
                                   session=int(row.get("session", -1)),
                                   slo_ttft=slo,
                                   prefix_group=group, prefix_len=plen))
        reqs.sort(key=lambda r: (r.arrival, r.rid))
        return reqs


def to_engine_requests(reqs: list[SimRequest], vocab_size: int, *, seed: int = 0):
    """Materialize `SimRequest`s as `repro.serve.engine.Request`s (random
    token ids of the spec'd lengths) so the real engine runs the same
    schedule the simulator priced. Imports jax-side code lazily."""
    from repro.serve.engine import Request

    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt=rng.integers(0, vocab_size, size=r.prompt).astype(np.int32),
            max_new_tokens=r.output,
        )
        for r in reqs
    ]
