"""CLI: simulate serving a model config on a hardware target under load.

    PYTHONPATH=src python -m repro.sim --config qwen3_14b --hw h100 --qps 8

Prints TTFT/TPOT/e2e percentiles, goodput, and tokens/s per scheduler
policy, then the static-vs-continuous throughput-latency sweep.
`--trace out.json` records one policy's run (request lifecycle spans +
per-iteration counters) for Perfetto (.json), `repro.obs report`
(.jsonl), or spreadsheets (.csv); `--trace-counter-dt` downsamples the
per-iteration counters.

`--slo-window W` evaluates the SLO monitor over each policy's run
(TTFT p99 <= --slo-ttft, goodput >= --slo-goodput if set, tumbling
W-second windows, burn-rate alerts). The single-replica sim emits
request records after the run, so the monitor replays the recorded
events in time order — same engine, same results as the cluster CLI's
live monitor.

`--slowdown F --slowdown-at T --slowdown-for D` injects a straggler
window: engine iterations priced inside `[T, T + D)` are stretched by
factor F (the single-replica view of the cluster CLI's `--chaos-stragglers`).
"""

from __future__ import annotations

import argparse
import os

from repro.configs import get_config
from repro.core.hardware import get_hardware
from repro.obs import LEVELS, make_slos, make_tracer, replay, write_trace
from repro.sim import (
    ADMISSIONS,
    ENGINES,
    LengthDist,
    POLICIES,
    SchedConfig,
    ServingCostModel,
    Workload,
    pareto_sweep,
    simulate,
    summarize,
)


def build_parser() -> argparse.ArgumentParser:
    """Argparse parser for `python -m repro.sim` (qps = requests/second)."""
    p = argparse.ArgumentParser(prog="python -m repro.sim", description=__doc__)
    p.add_argument("--config", default="qwen3_14b", help="model config id")
    p.add_argument("--hw", default="h100", help="hardware target (see core.hardware)")
    p.add_argument("--tp", type=int, default=1)
    p.add_argument("--prec", type=int, default=2, help="bytes per weight/act element")
    p.add_argument("--qps", type=float, default=8.0)
    p.add_argument("--requests", type=int, default=200)
    p.add_argument("--arrival", default="poisson",
                   choices=["constant", "poisson", "bursty", "diurnal",
                            "envelope"])
    p.add_argument("--diurnal-period", type=float, default=240.0,
                   help="seconds per compressed day (--arrival diurnal)")
    p.add_argument("--diurnal-amp", type=float, default=0.8,
                   help="relative rate swing in [0, 1] (--arrival diurnal)")
    p.add_argument("--rate-path", default=None,
                   help="JSONL rate envelope {t, qps} (--arrival envelope)")
    p.add_argument("--prompt-dist", default="lognormal", choices=["fixed", "lognormal"])
    p.add_argument("--prompt-mean", type=float, default=512)
    p.add_argument("--prompt-sigma", type=float, default=0.4)
    p.add_argument("--output-dist", default="lognormal", choices=["fixed", "lognormal"])
    p.add_argument("--output-mean", type=float, default=128)
    p.add_argument("--output-sigma", type=float, default=0.4)
    p.add_argument("--replay", default=None,
                   help="JSONL workload trace to replay instead of the "
                        "synthetic generator")
    p.add_argument("--trace", default=None,
                   help="record the run to this path: .json = Chrome "
                        "trace-event (Perfetto), .jsonl = event log "
                        "(repro.obs report), .csv = windowed time series; "
                        "with --policy all, the policy is suffixed into "
                        "the filename")
    p.add_argument("--trace-level", default="request", choices=list(LEVELS),
                   help="trace verbosity ceiling (with --trace)")
    p.add_argument("--trace-counter-dt", type=float, default=0.0,
                   help="minimum seconds between per-(track, series) counter "
                        "samples (0 = every iteration)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--policy", default="all", choices=list(POLICIES) + ["all"])
    p.add_argument("--slots", type=int, default=16)
    p.add_argument("--token-budget", type=int, default=512)
    p.add_argument("--admission", default="fcfs", choices=list(ADMISSIONS),
                   help="admission order: fcfs, or edf on TTFT deadlines")
    p.add_argument("--block-tokens", type=int, default=0,
                   help="paged-KV page size in tokens (0 = contiguous)")
    p.add_argument("--kv-gb", type=float, default=None,
                   help="override KV budget (GB); default: DRAM minus weights")
    p.add_argument("--slo-ttft", type=float, default=2.0, help="seconds")
    p.add_argument("--slo-tpot", type=float, default=0.05, help="seconds/token")
    p.add_argument("--slo-goodput", type=float, default=None,
                   help="SLO-monitor goodput objective as a fraction (e.g. "
                        "0.99); needs --slo-window")
    p.add_argument("--slo-window", type=float, default=None,
                   help="evaluate the SLO monitor over the run: tumbling "
                        "compliance window in seconds for TTFT p99 <= "
                        "--slo-ttft (and goodput >= --slo-goodput if set)")
    p.add_argument("--sweep", default="2,4,8,16",
                   help="comma-separated slot counts for the pareto sweep ('' to skip)")
    p.add_argument("--ctx-quantum", type=int, default=16)
    p.add_argument("--slowdown", type=float, default=None,
                   help="straggler factor stretching engine iterations "
                        "inside the injection window (>= 1)")
    p.add_argument("--slowdown-at", type=float, default=0.0,
                   help="straggler window start (s; with --slowdown)")
    p.add_argument("--slowdown-for", type=float, default=10.0,
                   help="straggler window duration (s; with --slowdown)")
    p.add_argument("--engine", default="vectorized", choices=list(ENGINES),
                   help="simulation core: the vectorized fast path or the "
                        "reference event loop (identical results)")
    return p


def main(argv=None) -> None:
    """Run one serving simulation (latencies in seconds) and/or the sweep."""
    args = build_parser().parse_args(argv)
    cfg = get_config(args.config)
    hw = get_hardware(args.hw)
    cost = ServingCostModel(cfg, hw, tp=args.tp, prec=args.prec,
                            ctx_quantum=args.ctx_quantum,
                            kv_block_tokens=args.block_tokens)
    wl = Workload(
        name=args.replay or "synthetic",
        qps=args.qps,
        num_requests=args.requests,
        arrival=args.arrival,
        prompt=LengthDist(args.prompt_dist, args.prompt_mean, args.prompt_sigma),
        output=LengthDist(args.output_dist, args.output_mean, args.output_sigma),
        seed=args.seed,
        trace_path=args.replay,
        diurnal_period=args.diurnal_period,
        diurnal_amp=args.diurnal_amp,
        rate_path=args.rate_path,
    )
    reqs = wl.generate()
    kv_cap = args.kv_gb * 1e9 if args.kv_gb is not None else None

    rate_note = ""
    if args.arrival in ("diurnal", "envelope") and reqs:
        # the peak offered rate is what static provisioning (and a
        # predictive autoscaler's envelope lookahead) must be sized for
        rate_note = f" (envelope peak {wl.peak_rate(0.0, reqs[-1].arrival):g})"
    print(f"# {cfg.name} on {hw.name} tp={args.tp}  |  "
          f"{len(reqs)} requests, {args.arrival} arrivals @ {args.qps} qps"
          f"{rate_note}")
    print(f"# weights {cost.weight_bytes / 1e9:.1f} GB/dev, "
          f"KV budget {(kv_cap or cost.kv_capacity_bytes) / 1e9:.1f} GB/dev")

    policies = list(POLICIES) if args.policy == "all" else [args.policy]
    hdr = (f"{'policy':<11} {'ttft p50/p95/p99 (s)':>22} {'tpot p50/p95/p99 (ms)':>22} "
           f"{'e2e p50/p95/p99 (s)':>21} {'tok/s':>7} {'goodput':>8} {'preempt':>7}")
    print(hdr)
    print("-" * len(hdr))
    slos = make_slos(slo_ttft=args.slo_ttft, slo_goodput=args.slo_goodput,
                     window=args.slo_window or 30.0) \
        if args.slo_window is not None else ()
    if args.slo_goodput is not None and args.slo_window is None:
        raise SystemExit("--slo-goodput needs --slo-window to enable the "
                         "SLO monitor")
    for policy in policies:
        sc = SchedConfig(policy=policy, slots=args.slots,
                         token_budget=args.token_budget, kv_capacity=kv_cap,
                         admission=args.admission, slo_ttft=args.slo_ttft)
        # the monitor consumes request-level events, so monitoring forces
        # the tracer to request level (even without --trace)
        level = args.trace_level if args.trace else "off"
        if slos and level != "request":
            level = "request"
        tracer = make_tracer(level, counter_dt=args.trace_counter_dt)
        slowdown = ((args.slowdown, args.slowdown_at, args.slowdown_for)
                    if args.slowdown is not None else None)
        s = summarize(simulate(reqs, cost, sc, tracer=tracer,
                               slowdown=slowdown, engine=args.engine),
                      slo_ttft=args.slo_ttft, slo_tpot=args.slo_tpot)
        if slos:
            mres = replay(tracer.meta, tracer.events, slos)
            print(f"# slo monitor [{policy}]: "
                  f"time_in_violation={mres['time_in_violation']:g}s, "
                  f"alerts_fired={mres['alerts_fired']}, "
                  f"budget_burn={mres['budget_burn']:.1%}")
        if tracer.enabled and args.trace:
            # the SLO monitor can force the tracer on without
            # --trace; only export when a path was actually given
            path = args.trace
            if len(policies) > 1:
                root, ext = os.path.splitext(path)
                path = f"{root}.{policy}{ext or '.json'}"
            fmt = write_trace(tracer.events, path, tracer.meta)
            print(f"# trace [{fmt}, level={args.trace_level}]: "
                  f"{len(tracer.events)} events -> {path}")
        print(f"{policy:<11} "
              f"{s['ttft_p50']:>6.2f}/{s['ttft_p95']:.2f}/{s['ttft_p99']:.2f}  "
              f"{s['tpot_p50'] * 1e3:>6.1f}/{s['tpot_p95'] * 1e3:.1f}/{s['tpot_p99'] * 1e3:.1f}  "
              f"{s['e2e_p50']:>6.2f}/{s['e2e_p95']:.2f}/{s['e2e_p99']:.2f}  "
              f"{s['tokens_per_s']:>7.0f} {s['goodput_frac']:>7.0%} {s['preemptions']:>7}")

    if args.sweep:
        slot_counts = [int(x) for x in args.sweep.split(",") if x]
        rows = pareto_sweep(reqs, cost, policies=POLICIES,
                            slot_counts=slot_counts,
                            base=SchedConfig(token_budget=args.token_budget,
                                             kv_capacity=kv_cap,
                                             admission=args.admission,
                                             slo_ttft=args.slo_ttft),
                            slo_ttft=args.slo_ttft, slo_tpot=args.slo_tpot)
        print(f"\n# throughput-latency sweep (equal KV budget)")
        print(f"{'policy':<11} {'slots':>5} {'tok/s':>8} {'e2e_p95 (s)':>12} {'pareto':>7}")
        for r in rows:
            print(f"{r['policy']:<11} {r['slots']:>5} {r['tokens_per_s']:>8.0f} "
                  f"{r['e2e_p95']:>12.2f} {'*' if r['pareto'] else '':>7}")


if __name__ == "__main__":
    main()
