"""Rule framework: contexts, pragmas, registration, and the file walker.

Design notes
------------
* One `LintContext` per file: parsed AST, a parent map, the pragma table,
  and cheap classification (`subpackage`, `is_test`) that rules use to
  scope themselves. Rules never re-read the file.
* Rules are small classes with a `check(ctx) -> Iterator[Finding]`; they
  register themselves via the `@register` decorator so adding a rule is
  one class in one module, no central table to edit.
* Suppression is same-line only (`# lint: disable=CODE[,CODE] -- why`) or
  file-level (`# lint: disable-file=CODE`). Findings anchor to the line
  where the offending *statement or expression starts*, so the pragma
  always has a well-defined home even for multi-line calls.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Iterator


@dataclass(frozen=True, order=True)
class Finding:
    """One lint violation, anchored to a source line.

    `line_text` (the stripped source line) is part of the identity used by
    the baseline so findings survive unrelated line-number churn.
    """

    path: str  # POSIX-style path as given to the linter
    line: int  # 1-based
    col: int  # 0-based
    code: str  # e.g. "D103"
    message: str
    line_text: str = field(compare=False, default="")

    def key(self) -> tuple[str, str, str]:
        """Baseline identity, line-number independent (see baseline.py)."""
        return (self.path, self.code, self.line_text)


# repo root (src/repro/lint/framework.py -> three parents above src/):
# finding paths are stored relative to it so baseline fingerprints match
# no matter whether the linter was invoked with absolute or relative paths
_REPO_ROOT = Path(__file__).resolve().parents[3]


def _display_path(p: Path) -> str:
    try:
        return p.resolve().relative_to(_REPO_ROOT).as_posix()
    except ValueError:
        return p.as_posix()


_PRAGMA_RE = re.compile(r"#\s*lint:\s*disable=([A-Z]\d+(?:\s*,\s*[A-Z]\d+)*)")
_PRAGMA_NEXT_RE = re.compile(
    r"#\s*lint:\s*disable-next=([A-Z]\d+(?:\s*,\s*[A-Z]\d+)*)"
)
_PRAGMA_FILE_RE = re.compile(
    r"#\s*lint:\s*disable-file=([A-Z]\d+(?:\s*,\s*[A-Z]\d+)*)"
)


def _parse_pragmas(lines: list[str]) -> tuple[dict[int, set[str]], set[str]]:
    """Return (line -> disabled codes, file-wide disabled codes); 1-based.

    `disable=` suppresses on its own line, `disable-next=` on the next
    non-comment line (for statements too long to carry a trailing
    comment), `disable-file=` everywhere in the file.
    """
    per_line: dict[int, set[str]] = {}
    file_wide: set[str] = set()
    for i, text in enumerate(lines, start=1):
        m = _PRAGMA_RE.search(text)
        if m:
            per_line.setdefault(i, set()).update(
                c.strip() for c in m.group(1).split(","))
        m = _PRAGMA_NEXT_RE.search(text)
        if m:
            j = i + 1  # skip over intervening comment-only lines
            while j <= len(lines) and lines[j - 1].lstrip().startswith("#"):
                j += 1
            per_line.setdefault(j, set()).update(
                c.strip() for c in m.group(1).split(","))
        m = _PRAGMA_FILE_RE.search(text)
        if m:
            file_wide |= {c.strip() for c in m.group(1).split(",")}
    return per_line, file_wide


class LintContext:
    """Everything a rule needs to know about one source file."""

    def __init__(self, path: str | Path, source: str | None = None):
        p = Path(path)
        self.path = _display_path(p)
        if source is None:
            source = p.read_text()
        self.source = source
        self.lines = source.splitlines()
        self.tree: ast.AST | None
        self.syntax_error: SyntaxError | None = None
        try:
            self.tree = ast.parse(source, filename=self.path)
        except SyntaxError as e:  # surfaced as an E001 finding by lint_file
            self.tree = None
            self.syntax_error = e
        self.disabled, self.file_disabled = _parse_pragmas(self.lines)
        parts = p.parts
        # subpackage under repro/ ("sim", "cluster", "obs", ...) or "" when
        # the file is outside the package (tests, scripts, fixtures)
        self.subpackage = ""
        if "repro" in parts:
            rest = parts[parts.index("repro") + 1:]
            if len(rest) > 1:
                self.subpackage = rest[0]
        self.is_test = "tests" in parts or p.name.startswith("test_")
        self._parents: dict[ast.AST, ast.AST] | None = None

    # -- helpers rules share -------------------------------------------------
    @property
    def parents(self) -> dict[ast.AST, ast.AST]:
        """Child -> parent map over the whole tree (built lazily, once)."""
        if self._parents is None:
            self._parents = {}
            assert self.tree is not None
            for parent in ast.walk(self.tree):
                for child in ast.iter_child_nodes(parent):
                    self._parents[child] = parent
        return self._parents

    def walk(self) -> Iterator[ast.AST]:
        if self.tree is None:
            return iter(())
        return ast.walk(self.tree)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, node: ast.AST, code: str, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Finding(self.path, line, col, code, message,
                       line_text=self.line_text(line))

    def suppressed(self, f: Finding) -> bool:
        if f.code in self.file_disabled:
            return True
        return f.code in self.disabled.get(f.line, ())


def dotted_name(node: ast.AST) -> str:
    """Best-effort dotted name of a call target: `np.random.default_rng`
    -> "np.random.default_rng"; unresolvable parts render as "?"."""
    if isinstance(node, ast.Attribute):
        return f"{dotted_name(node.value)}.{node.attr}"
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Call):
        return dotted_name(node.func) + "()"
    return "?"


class Rule:
    """Base class. Subclasses set `code`/`name`/`summary`/`rationale` and
    implement `check`; `applies` scopes the rule to file categories."""

    code: str = "X000"
    name: str = "unnamed"
    summary: str = ""
    rationale: str = ""

    def applies(self, ctx: LintContext) -> bool:
        return not ctx.is_test

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        raise NotImplementedError

    def run(self, ctx: LintContext) -> Iterator[Finding]:
        if ctx.tree is None or not self.applies(ctx):
            return
        yield from self.check(ctx)


_REGISTRY: dict[str, Rule] = {}


def register(cls: type[Rule]) -> type[Rule]:
    """Class decorator: instantiate and index the rule by its code."""
    rule = cls()
    if rule.code in _REGISTRY:
        raise ValueError(f"duplicate rule code {rule.code}")
    _REGISTRY[rule.code] = rule
    return cls


def all_rules() -> list[Rule]:
    return [_REGISTRY[c] for c in sorted(_REGISTRY)]


def _selected(rules: Iterable[Rule], select: str | None,
              ignore: str | None) -> list[Rule]:
    out = list(rules)
    if select:
        pres = [p.strip() for p in select.split(",") if p.strip()]
        out = [r for r in out if any(r.code.startswith(p) for p in pres)]
    if ignore:
        pres = [p.strip() for p in ignore.split(",") if p.strip()]
        out = [r for r in out if not any(r.code.startswith(p) for p in pres)]
    return out


def lint_file(path: str | Path, *, select: str | None = None,
              ignore: str | None = None,
              source: str | None = None) -> list[Finding]:
    """Lint one file; returns findings sorted by (line, col, code)."""
    ctx = LintContext(path, source=source)
    if ctx.syntax_error is not None:
        e = ctx.syntax_error
        return [Finding(ctx.path, e.lineno or 1, (e.offset or 1) - 1, "E001",
                        f"syntax error: {e.msg}",
                        line_text=ctx.line_text(e.lineno or 1))]
    found: list[Finding] = []
    for rule in _selected(all_rules(), select, ignore):
        for f in rule.run(ctx):
            if not ctx.suppressed(f):
                found.append(f)
    return sorted(found, key=lambda f: (f.line, f.col, f.code))


def iter_py_files(paths: Iterable[str | Path]) -> list[Path]:
    """Expand files/directories into a sorted, de-duplicated .py file list."""
    out: set[Path] = set()
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out |= {q for q in p.rglob("*.py")
                    if not any(part.startswith(".") for part in q.parts)}
        elif p.suffix == ".py":
            out.add(p)
    return sorted(out)


def lint_paths(paths: Iterable[str | Path], *, select: str | None = None,
               ignore: str | None = None) -> list[Finding]:
    """Lint every .py file under `paths` (files or directories)."""
    found: list[Finding] = []
    for f in iter_py_files(paths):
        found.extend(lint_file(f, select=select, ignore=ignore))
    return sorted(found, key=lambda f: (f.path, f.line, f.col, f.code))
