"""U-series rules: the public-surface contracts.

The cost math only composes because every public `sim`/`cluster` entry
point states its units (seconds, bytes, $/hr, tokens/s) in its docstring
— the PR 4 convention. Bare `except:` swallows the very assertion errors
the parity suite relies on, and float-literal equality is how "close
enough" bugs hide in non-test code.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.lint.framework import Finding, LintContext, Rule, register

_DOC_SUBPACKAGES = ("sim", "cluster")

# parameter names that carry a physical unit; if a public function takes
# one, its docstring must say what the unit is
_UNIT_PARAM_SUFFIXES = (
    "_s", "_sec", "_secs", "_seconds", "_ns", "_bytes", "_gb", "_gib",
    "_tokens", "_usd", "_hr", "_hrs", "_frac", "_qps", "_bw", "_pct",
)
_UNIT_PARAM_NAMES = {
    "qps", "horizon", "ttl", "seconds", "bytes", "tokens", "usd", "frac",
    "rate", "budget", "lookahead", "warmup", "interval", "period",
}
# unit vocabulary a docstring can use to satisfy the convention
_UNIT_WORDS = (
    "second", "seconds", "sec", "[s]", " s)", " s.", "s)", "byte", "bytes",
    "gb", "gib", "token", "tokens", "$", "usd", "/hr", "per hour", "hour",
    "hours", "fraction", "frac", "qps", "req/s", "requests/s", "hz", "%",
    "tokens/s", "steps/s", "ms", "dollar",
)


def _unit_bearing_params(node: ast.FunctionDef | ast.AsyncFunctionDef):
    a = node.args
    params = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    out = []
    for p in params:
        low = p.lower()
        if low in _UNIT_PARAM_NAMES or low.endswith(_UNIT_PARAM_SUFFIXES):
            out.append(p)
    return out


def _has_unit_word(doc: str) -> bool:
    low = doc.lower()
    return any(w in low for w in _UNIT_WORDS)


@register
class UnitDocstring(Rule):
    code = "U301"
    name = "unit-docstring"
    summary = "public sim/cluster function lacks a unit-annotated docstring"
    rationale = (
        "Cost math composes across layers only because each public entry "
        "point states its units (seconds, bytes, $/hr, tokens/s) — the "
        "PR 4 docstring convention. A public function with unit-bearing "
        "parameters and no unit vocabulary in its docstring is where unit "
        "bugs are born."
    )

    def applies(self, ctx: LintContext) -> bool:
        return (not ctx.is_test
                and ctx.subpackage in _DOC_SUBPACKAGES)

    def _public_functions(self, ctx: LintContext):
        """Module-level public defs + public methods of public classes."""
        assert ctx.tree is not None
        for node in ctx.tree.body:  # type: ignore[attr-defined]
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if not node.name.startswith("_"):
                    yield node
            elif isinstance(node, ast.ClassDef) and not node.name.startswith(
                    "_"):
                for sub in node.body:
                    if (isinstance(sub, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))
                            and not sub.name.startswith("_")):
                        yield sub

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        for fn in self._public_functions(ctx):
            doc = ast.get_docstring(fn)
            units = _unit_bearing_params(fn)
            if doc is None:
                yield ctx.finding(
                    fn, self.code,
                    f"public {fn.name}() has no docstring (unit-annotated "
                    "docstrings are required in sim/cluster)")
            elif units and not _has_unit_word(doc):
                yield ctx.finding(
                    fn, self.code,
                    f"docstring of {fn.name}() never states units, but "
                    f"params look unit-bearing ({', '.join(units[:3])})")


@register
class BareExcept(Rule):
    code = "U302"
    name = "bare-except"
    summary = "bare `except:` swallows everything, including contract errors"
    rationale = (
        "A bare except catches AssertionError and KeyboardInterrupt, "
        "silently eating the exact failures the parity and conservation "
        "tests are designed to surface. Catch the narrowest type that can "
        "actually occur."
    )

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        for node in ctx.walk():
            if isinstance(node, ast.ExceptHandler) and node.type is None:
                yield ctx.finding(
                    node, self.code,
                    "bare except: catches AssertionError/KeyboardInterrupt; "
                    "name the exception type")


@register
class FloatEquality(Rule):
    code = "U303"
    name = "float-equality"
    summary = "==/!= against a float literal in non-test code"
    rationale = (
        "Exact float comparison is either a bug (accumulated values never "
        "hit the literal) or a deliberate sentinel check; the latter is "
        "fine but must say so with a pragma, because the two are "
        "indistinguishable at review time."
    )

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        for node in ctx.walk():
            if not isinstance(node, ast.Compare):
                continue
            operands = [node.left] + list(node.comparators)
            for op, left, right in zip(node.ops, operands, operands[1:]):
                if not isinstance(op, (ast.Eq, ast.NotEq)):
                    continue
                if any(isinstance(o, ast.Constant)
                       and isinstance(o.value, float)
                       for o in (left, right)):
                    yield ctx.finding(
                        node, self.code,
                        "==/!= against a float literal; use a tolerance, or "
                        "pragma if this is an exact sentinel")
                    break
