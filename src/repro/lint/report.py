"""Reporters: human text and machine JSON renderings of findings."""

from __future__ import annotations

import json

from repro.lint.framework import Finding, all_rules


def render_text(findings: list[Finding], *, show_source: bool = True) -> str:
    """One line per finding (`path:line:col: CODE message`), plus a
    per-code tally footer when anything fired."""
    lines = []
    for f in findings:
        lines.append(f"{f.path}:{f.line}:{f.col + 1}: {f.code} {f.message}")
        if show_source and f.line_text:
            lines.append(f"    {f.line_text}")
    if findings:
        tally: dict[str, int] = {}
        for f in findings:
            tally[f.code] = tally.get(f.code, 0) + 1
        summary = ", ".join(f"{c}×{n}" for c, n in sorted(tally.items()))
        lines.append(f"{len(findings)} finding(s): {summary}")
    return "\n".join(lines)


def render_json(findings: list[Finding]) -> str:
    """Stable JSON: a list of finding objects sorted like the text output."""
    return json.dumps(
        [
            {
                "path": f.path,
                "line": f.line,
                "col": f.col,
                "code": f.code,
                "message": f.message,
                "line_text": f.line_text,
            }
            for f in findings
        ],
        indent=2,
    )


def render_rules() -> str:
    """The rule catalog (`--list-rules`): code, name, summary, rationale."""
    blocks = []
    for r in all_rules():
        blocks.append(f"{r.code} {r.name}\n    {r.summary}\n"
                      f"    rationale: {r.rationale}")
    return "\n".join(blocks)
