"""CLI: `python -m repro.lint [paths ...]`.

Exit status is the gate: 0 when every finding is absorbed by the
baseline (or there are none), 1 otherwise — so `python -m repro.lint`
in CI or scripts/verify.sh blocks new violations. `--check` is the same
gate spelled explicitly; `--write-baseline` snapshots current findings
as accepted legacy.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.lint.baseline import (
    DEFAULT_BASELINE,
    load_baseline,
    new_findings,
    write_baseline,
)
from repro.lint.framework import lint_paths
from repro.lint.report import render_json, render_rules, render_text


def _repo_root() -> Path:
    # src/repro/lint/__main__.py -> repo root is three parents above src/
    return Path(__file__).resolve().parents[3]


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description=(
            "Determinism & contract linter: statically enforces the "
            "seeded-determinism, observational-tracing, and unit-docstring "
            "contracts the parity suite can only sample. Exits non-zero on "
            "findings not covered by the checked-in baseline."),
    )
    p.add_argument("paths", nargs="*", default=None,
                   help="files or directories to lint (default: src/repro)")
    p.add_argument("--format", choices=("text", "json"), default="text",
                   help="findings output format")
    p.add_argument("--select", default=None, metavar="CODES",
                   help="comma-separated code prefixes to run (e.g. D,U302)")
    p.add_argument("--ignore", default=None, metavar="CODES",
                   help="comma-separated code prefixes to skip")
    p.add_argument("--baseline", default=None, metavar="PATH",
                   help=f"baseline file (default: <repo>/{DEFAULT_BASELINE})")
    p.add_argument("--no-baseline", action="store_true",
                   help="report every finding, ignoring the baseline")
    p.add_argument("--write-baseline", action="store_true",
                   help="snapshot current findings as the accepted baseline")
    p.add_argument("--check", action="store_true",
                   help="gate mode (explicit alias of the default behavior)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    return p


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        print(render_rules())
        return 0

    root = _repo_root()
    paths = args.paths or [root / "src" / "repro"]
    findings = lint_paths(paths, select=args.select, ignore=args.ignore)

    baseline_path = Path(args.baseline) if args.baseline else (
        root / DEFAULT_BASELINE)
    if args.write_baseline:
        payload = write_baseline(findings, baseline_path)
        print(f"wrote {len(payload['findings'])} fingerprint(s) "
              f"({len(findings)} finding(s)) to {baseline_path}")
        return 0

    if not args.no_baseline:
        findings = new_findings(findings, load_baseline(baseline_path))

    out = (render_json(findings) if args.format == "json"
           else render_text(findings))
    if out:
        print(out)
    if not findings:
        n = len(paths)
        print(f"repro.lint: clean ({n} path(s) checked)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
