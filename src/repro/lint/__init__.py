"""repro.lint — a determinism & contract static linter for this repo.

The parity suite (`tests/test_engine_parity.py`) and the observational
tracing tests can only *sample* the invariants the codebase is built on:
bit-for-bit engine parity, seeded-RNG purity, tracing/monitoring that
never mutates engine state, and unit-consistent cost math. This package
enforces those contracts *statically*, on every file, on every PR:

  * **D-series (determinism)** — unseeded RNG draws, wall-clock reads in
    the deterministic layers (`sim`/`cluster`/`obs`), iteration over
    unordered containers feeding ordering-sensitive constructs, and
    `id()`-derived keys.
  * **P-series (purity)** — mutable default arguments, mutable dataclass
    field defaults, observational modules writing attributes on objects
    they were handed, and in-place mutation of config parameters.
  * **U-series (surface)** — public `sim`/`cluster` functions missing
    unit-annotated docstrings, bare `except:`, and float-literal
    equality in non-test code.

Run it:

    PYTHONPATH=src python -m repro.lint                # lint src/repro
    PYTHONPATH=src python -m repro.lint --list-rules   # rule catalog

Findings are suppressed either by a same-line pragma with a short
justification::

    planned = {id(r): r.cached for r in running}  # lint: disable=D104 -- identity map, never iterated

or by the checked-in baseline (`lint_baseline.json`) for legacy findings
that predate a rule. New findings exit non-zero, so CI blocks them. See
`docs/linting.md` for the full catalog and workflow.
"""

from repro.lint.framework import (
    Finding,
    LintContext,
    Rule,
    all_rules,
    lint_file,
    lint_paths,
    register,
)
from repro.lint.baseline import load_baseline, new_findings, write_baseline
from repro.lint.report import render_json, render_text

# importing the rule modules registers every rule with the framework
from repro.lint import rules_determinism, rules_purity, rules_surface  # noqa: F401

__all__ = [
    "Finding",
    "LintContext",
    "Rule",
    "all_rules",
    "lint_file",
    "lint_paths",
    "register",
    "load_baseline",
    "new_findings",
    "write_baseline",
    "render_json",
    "render_text",
]
