"""P-series rules: the purity / observational contracts.

Configs are value objects shared across runs and worker processes;
mutable defaults and post-construction mutation alias state between
simulations that must be independent. The `obs` layer is *observational
by contract* — the traced-equals-untraced parity tests depend on tracing
and monitoring never writing into engine objects.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.lint.framework import (
    Finding,
    LintContext,
    Rule,
    dotted_name,
    register,
)

_MUTABLE_CALLS = {
    "list", "dict", "set", "bytearray", "deque", "defaultdict",
    "collections.deque", "collections.defaultdict", "collections.Counter",
    "Counter", "OrderedDict", "collections.OrderedDict",
    "np.zeros", "np.ones", "np.empty", "np.array",
    "numpy.zeros", "numpy.ones", "numpy.empty", "numpy.array",
}


def _is_mutable_literal(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        return dotted_name(node.func) in _MUTABLE_CALLS
    return False


def _is_field_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and dotted_name(node.func) in ("field", "dataclasses.field"))


def _is_dataclass_decorated(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if dotted_name(target) in ("dataclass", "dataclasses.dataclass"):
            return True
    return False


@register
class MutableDefaultArg(Rule):
    code = "P201"
    name = "mutable-default-arg"
    summary = "mutable default argument value"
    rationale = (
        "Default values are evaluated once at def time; a mutable default "
        "is shared state across every call — the classic aliasing bug. "
        "Default to None and construct inside the function."
    )

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        for node in ctx.walk():
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            defaults = list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None]
            for d in defaults:
                if _is_mutable_literal(d):
                    yield ctx.finding(
                        d, self.code,
                        f"mutable default in {node.name}(): evaluated once "
                        "and shared across calls; default to None")


@register
class DataclassMutableDefault(Rule):
    code = "P202"
    name = "dataclass-mutable-default"
    summary = "dataclass field holds a mutable default"
    rationale = (
        "A mutable dataclass default is shared by every instance (list/"
        "dict/set even raise at class-definition time). Use "
        "`field(default_factory=...)` so each config owns its value — "
        "configs cross process boundaries in planner sweeps."
    )

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        for node in ctx.walk():
            if not (isinstance(node, ast.ClassDef)
                    and _is_dataclass_decorated(node)):
                continue
            for stmt in node.body:
                if (isinstance(stmt, ast.AnnAssign) and stmt.value is not None
                        and not _is_field_call(stmt.value)
                        and _is_mutable_literal(stmt.value)):
                    yield ctx.finding(
                        stmt.value, self.code,
                        f"mutable default on dataclass {node.name}; use "
                        "field(default_factory=...)")


class _ParamWriteScanner(ast.NodeVisitor):
    """Find attribute writes on names bound as function parameters.

    Walks function bodies with a scope stack so closures over an outer
    function's parameter are still caught; `self`/`cls` are exempt (a
    method owning its instance is not the hazard these rules target).
    """

    def __init__(self, param_filter):
        # param_filter(name, annotation_node) -> bool: is this param suspect
        self.param_filter = param_filter
        self.stack: list[set[str]] = []
        self.hits: list[tuple[ast.AST, str, str]] = []  # (node, obj, attr)

    def _params(self, node) -> dict[str, ast.AST | None]:
        a = node.args
        params = {p.arg: p.annotation
                  for p in a.posonlyargs + a.args + a.kwonlyargs}
        if a.vararg:
            params[a.vararg.arg] = a.vararg.annotation
        if a.kwarg:
            params[a.kwarg.arg] = a.kwarg.annotation
        params.pop("self", None)
        params.pop("cls", None)
        return params

    def visit_FunctionDef(self, node):
        self.stack.append({n for n, ann in self._params(node).items()
                           if self.param_filter(n, ann)})
        self.generic_visit(node)
        self.stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def _check_target(self, node, target):
        if (isinstance(target, ast.Attribute)
                and isinstance(target.value, ast.Name)
                and any(target.value.id in scope for scope in self.stack)):
            self.hits.append((node, target.value.id, target.attr))

    def visit_Assign(self, node):
        for t in node.targets:
            self._check_target(node, t)
            if isinstance(t, (ast.Tuple, ast.List)):
                for elt in t.elts:
                    self._check_target(node, elt)
        self.generic_visit(node)

    def visit_AugAssign(self, node):
        self._check_target(node, node.target)
        self.generic_visit(node)

    def visit_AnnAssign(self, node):
        if node.value is not None:
            self._check_target(node, node.target)
        self.generic_visit(node)


@register
class ObservationalWrite(Rule):
    code = "P203"
    name = "observational-write"
    summary = "obs code writes an attribute on an object it was handed"
    rationale = (
        "Tracing and monitoring are observational by contract: the "
        "traced==untraced and monitored==plain parity tests assume the "
        "obs layer never mutates engine or replica state. Any attribute "
        "write on a parameter inside repro.obs breaks that one-way glass."
    )

    def applies(self, ctx: LintContext) -> bool:
        return not ctx.is_test and ctx.subpackage == "obs"

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        assert ctx.tree is not None
        # params annotated with a type this module itself defines are the
        # module's own state objects (e.g. monitor._SloState), not engine
        # objects handed across the observational boundary
        own_types = {n.name for n in ast.walk(ctx.tree)
                     if isinstance(n, ast.ClassDef)}

        def suspect(name: str, ann) -> bool:
            t = None
            if isinstance(ann, ast.Name):
                t = ann.id
            elif isinstance(ann, ast.Constant) and isinstance(ann.value, str):
                t = ann.value.strip("'\"")
            return t not in own_types

        scanner = _ParamWriteScanner(suspect)
        scanner.visit(ctx.tree)
        for node, obj, attr in scanner.hits:
            yield ctx.finding(
                node, self.code,
                f"writes {obj}.{attr} on a passed-in object; repro.obs must "
                "stay observational (traced == untraced)")


_CONFIG_PARAM = ("cfg", "config", "spec")


def _looks_like_config(name: str, ann) -> bool:
    """Config-ish by name (cfg/config/spec) or by annotated type name
    (`...Config` / `...Spec`, including string annotations)."""
    low = name.lower()
    if low in _CONFIG_PARAM or any(
            low.endswith("_" + s) for s in _CONFIG_PARAM):
        return True
    t = None
    if isinstance(ann, ast.Name):
        t = ann.id
    elif isinstance(ann, ast.Attribute):
        t = ann.attr
    elif isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        t = ann.value.strip("'\"")
    return t is not None and t.endswith(("Config", "Spec"))


@register
class ConfigMutation(Rule):
    code = "P204"
    name = "config-mutation"
    summary = "mutates a config/spec parameter in place"
    rationale = (
        "Configs are value objects: the same instance is reused across "
        "sweep candidates, worker processes, and parity runs. Mutating a "
        "caller's config aliases those runs together. Return a modified "
        "copy (dataclasses.replace) — or pragma an API whose documented "
        "job is in-place seeding."
    )

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        scanner = _ParamWriteScanner(_looks_like_config)
        assert ctx.tree is not None
        scanner.visit(ctx.tree)
        for node, obj, attr in scanner.hits:
            yield ctx.finding(
                node, self.code,
                f"writes {obj}.{attr}: configs are shared value objects; "
                "return dataclasses.replace(...) instead")
