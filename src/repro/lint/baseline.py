"""Baseline: accepted legacy findings that don't fail the gate.

The baseline is a checked-in JSON file mapping finding *fingerprints* to
counts. A fingerprint is `(path, code, stripped source line text)` — NOT
the line number — so unrelated edits that shift lines don't churn the
file; moving or duplicating an offending line past its baselined count
does fail, which is the point.

Workflow: `python -m repro.lint --write-baseline` snapshots today's
findings; the gate (`python -m repro.lint` / `--check`) then fails only
on findings *not covered* by the baseline. The shipped baseline is kept
near-empty on purpose — fix or pragma, don't accumulate.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.lint.framework import Finding

BASELINE_VERSION = 1
DEFAULT_BASELINE = "lint_baseline.json"


def _fp(f: Finding) -> str:
    return f"{f.path}::{f.code}::{f.line_text}"


def write_baseline(findings: list[Finding], path: str | Path) -> dict:
    """Serialize findings to a baseline file; returns the written payload."""
    counts: dict[str, int] = {}
    for f in findings:
        counts[_fp(f)] = counts.get(_fp(f), 0) + 1
    payload = {
        "version": BASELINE_VERSION,
        "findings": dict(sorted(counts.items())),
    }
    Path(path).write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def load_baseline(path: str | Path) -> dict[str, int]:
    """Load fingerprint -> allowed-count; missing file = empty baseline."""
    p = Path(path)
    if not p.exists():
        return {}
    payload = json.loads(p.read_text())
    if payload.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"baseline {p} has version {payload.get('version')!r}, "
            f"expected {BASELINE_VERSION}; regenerate with --write-baseline")
    return dict(payload.get("findings", {}))


def new_findings(findings: list[Finding],
                 baseline: dict[str, int]) -> list[Finding]:
    """Findings not absorbed by the baseline (per-fingerprint counting)."""
    budget = dict(baseline)
    out = []
    for f in findings:
        fp = _fp(f)
        if budget.get(fp, 0) > 0:
            budget[fp] -= 1
        else:
            out.append(f)
    return out
