"""D-series rules: the seeded-determinism contract.

Every simulation result in this repo is a pure function of its configs
and seeds — that is what makes the bit-for-bit engine parity matrix
(`tests/test_engine_parity.py`) and the byte-identical golden traces
possible. These rules reject the three classic ways that contract decays:
ambient entropy (unseeded RNGs, wall clocks), address-dependent state
(`id()` keys), and unordered-container iteration feeding order-sensitive
constructs.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.lint.framework import (
    Finding,
    LintContext,
    Rule,
    dotted_name,
    register,
)

# deterministic-by-contract layers: results must be pure functions of
# (config, seed). train/launch/serve legitimately read wall clocks.
DETERMINISTIC_SUBPACKAGES = ("sim", "cluster", "obs")

_UNSEEDED_SUFFIXES = (
    "os.urandom",
    "uuid.uuid4",
    "secrets.token_bytes",
    "secrets.token_hex",
    "secrets.randbelow",
    "secrets.choice",
)
# module-level (global-state) RNG entry points; the fix is an explicit
# np.random.default_rng(seed) / SeedSequence spawn
_GLOBAL_RNG = {
    "random": {"random", "randint", "randrange", "choice", "choices",
               "shuffle", "sample", "uniform", "gauss", "normalvariate",
               "betavariate", "expovariate", "seed", "getrandbits"},
    "np.random": {"rand", "randn", "randint", "random", "choice", "shuffle",
                  "permutation", "uniform", "normal", "poisson",
                  "exponential", "lognormal", "seed"},
    "numpy.random": {"rand", "randn", "randint", "random", "choice",
                     "shuffle", "permutation", "uniform", "normal",
                     "poisson", "exponential", "lognormal", "seed"},
}

_WALL_CLOCK_SUFFIXES = (
    "time.time",
    "time.time_ns",
    "time.monotonic",
    "time.monotonic_ns",
    "time.perf_counter",
    "time.perf_counter_ns",
    "datetime.now",
    "datetime.utcnow",
    "datetime.today",
    "date.today",
)


def _call_name(node: ast.Call) -> str:
    return dotted_name(node.func)


@register
class UnseededRNG(Rule):
    code = "D101"
    name = "unseeded-rng"
    summary = "RNG draw from ambient entropy or module-level global state"
    rationale = (
        "Results must be pure functions of (config, seed). "
        "`np.random.default_rng()` with no seed pulls OS entropy; the "
        "stdlib `random.*` / legacy `np.random.*` module functions share "
        "hidden global state, so call *order* becomes part of the seed. "
        "Use `np.random.default_rng(seed)` or a `SeedSequence` spawn."
    )

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        for node in ctx.walk():
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            if (name.endswith("default_rng") and not node.args
                    and not node.keywords):
                yield ctx.finding(
                    node, self.code,
                    "default_rng() without a seed draws OS entropy; pass an "
                    "explicit seed or SeedSequence")
                continue
            if any(name == s or name.endswith("." + s)
                   for s in _UNSEEDED_SUFFIXES):
                yield ctx.finding(
                    node, self.code,
                    f"{name}() is ambient entropy; derive randomness from a "
                    "seeded generator")
                continue
            for mod, fns in _GLOBAL_RNG.items():
                head, _, fn = name.rpartition(".")
                if head == mod and fn in fns:
                    yield ctx.finding(
                        node, self.code,
                        f"{name}() uses the shared global RNG stream; use a "
                        "seeded np.random.default_rng instance")
                    break


@register
class WallClock(Rule):
    code = "D102"
    name = "wall-clock"
    summary = "wall-clock read inside a deterministic layer (sim/cluster/obs)"
    rationale = (
        "Simulated time is the only clock the deterministic layers may "
        "observe; a wall-clock read makes replays and the traced/untraced "
        "parity contract machine-dependent. Benchmarks and launch/train "
        "code may time things — the simulator may not."
    )

    def applies(self, ctx: LintContext) -> bool:
        return (not ctx.is_test
                and ctx.subpackage in DETERMINISTIC_SUBPACKAGES)

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        for node in ctx.walk():
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            if any(name == s or name.endswith("." + s)
                   for s in _WALL_CLOCK_SUFFIXES):
                yield ctx.finding(
                    node, self.code,
                    f"{name}() reads the wall clock inside "
                    f"repro.{ctx.subpackage}; use simulated time")


def _is_unordered(node: ast.AST) -> bool:
    """Expression whose iteration order is a set's (unordered) order."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        name = dotted_name(node.func)
        if name in ("set", "frozenset"):
            return True
        # set methods returning sets keep the hazard alive
        if name.endswith((".union", ".intersection", ".difference",
                          ".symmetric_difference")):
            return _is_unordered(node.func.value)  # type: ignore[union-attr]
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
        return _is_unordered(node.left) or _is_unordered(node.right)
    return False


def _is_dict_values(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "values")


@register
class UnorderedIteration(Rule):
    code = "D103"
    name = "unordered-iteration"
    summary = "set iteration / keyed min-max-sorted over unordered values"
    rationale = (
        "Set iteration order is hash- and history-dependent; feeding it "
        "into a loop, list(), or a keyed min/max/sorted (where ties break "
        "by encounter order) makes results run-to-run unstable. Iterate "
        "`sorted(the_set)` or keep an insertion-ordered structure. Keyed "
        "reductions over `.values()` are flagged too: ties there break by "
        "insertion order, which deserves an explicit tie-break or pragma."
    )

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        for node in ctx.walk():
            if isinstance(node, (ast.For, ast.AsyncFor)) and _is_unordered(
                    node.iter):
                yield ctx.finding(
                    node.iter, self.code,
                    "iterating a set: order is unspecified; use sorted(...)")
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                for gen in node.generators:
                    if _is_unordered(gen.iter):
                        yield ctx.finding(
                            gen.iter, self.code,
                            "comprehension over a set: order is unspecified; "
                            "use sorted(...)")
            elif isinstance(node, ast.Call):
                name = dotted_name(node.func)
                if name in ("list", "tuple") and node.args and _is_unordered(
                        node.args[0]):
                    yield ctx.finding(
                        node, self.code,
                        f"{name}(set) freezes an unspecified order; use "
                        "sorted(...)")
                if name in ("min", "max", "sorted") and node.args:
                    has_key = any(k.arg == "key" for k in node.keywords)
                    arg0 = node.args[0]
                    if has_key and (_is_unordered(arg0)
                                    or _is_dict_values(arg0)):
                        src = ("a set" if _is_unordered(arg0)
                               else "dict values")
                        yield ctx.finding(
                            node, self.code,
                            f"{name}(key=...) over {src}: ties break by "
                            "encounter order; add an explicit tie-break")
                if name == "heapq.heappush" or name.endswith(".heappush"):
                    # pushes inside a set-iteration loop inherit its order
                    parent = ctx.parents.get(node)
                    while parent is not None and not isinstance(
                            parent, (ast.For, ast.AsyncFor)):
                        parent = ctx.parents.get(parent)
                    if parent is not None and _is_unordered(parent.iter):
                        yield ctx.finding(
                            node, self.code,
                            "heappush inside set iteration: heap insertion "
                            "order (and equal-key pops) become unstable")


@register
class IdBasedKey(Rule):
    code = "D104"
    name = "id-based-key"
    summary = "id() — object identity is address-dependent state"
    rationale = (
        "`id()` values depend on allocator behavior; keying, ordering, or "
        "hashing on them imports memory layout into results. An identity "
        "map that is only ever *looked up* (never iterated or compared) is "
        "safe — suppress those with a justifying pragma."
    )

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        for node in ctx.walk():
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "id"
                    and len(node.args) == 1):
                yield ctx.finding(
                    node, self.code,
                    "id() is address-dependent; key on a stable field (rid, "
                    "name, admit_seq) or justify with a pragma")
