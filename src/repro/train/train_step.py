"""Training step factory: loss -> grads -> (optional compression) -> AdamW.

Supports microbatch gradient accumulation (lax.scan over microbatches — the
PP-less half of the paper's pipeline analysis; the bubble-bearing half lives in
`repro.core.predict` and `repro.parallel.pipeline`), the paper's three
activation-recomputation policies via remat (`none`/`selective`/`full`,
§3.3 eq. 1-2), and int8 gradient compression with error feedback.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ParallelConfig, TrainConfig
from repro.models.transformer import Model
from repro.parallel.compression import compress_gradients
from repro.train.optimizer import adamw_update


def make_train_step(model: Model, pcfg: ParallelConfig, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", ["grad_error"]}.
    """

    def _zero1_shard_grads(grads):
        """ZeRO gradient sharding: constrain fp32 grads onto the data axes so
        they are reduce-scattered instead of replicated (fp32 grads for a
        480B-param MoE would otherwise not fit per-device HBM)."""
        from jax.sharding import NamedSharding

        from repro.parallel.axes import current_mesh
        from repro.train.optimizer import _zero1_spec

        mesh = current_mesh()
        if mesh is None:
            return grads
        pspecs = model.pspecs()
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, _zero1_spec(s, g.shape))
            ),
            grads,
            pspecs,
        )

    def grads_of(params, batch):
        def loss_fn(p):
            return model.loss(p, batch, remat=pcfg.remat)

        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        if pcfg.zero1:
            grads = _zero1_shard_grads(grads)
        return grads, metrics

    def accumulate(params, batch):
        n = pcfg.microbatches
        if n <= 1:
            return grads_of(params, batch)
        B = jax.tree.leaves(batch)[0].shape[0]
        assert B % n == 0, (B, n)
        micro = jax.tree.map(lambda x: x.reshape(n, B // n, *x.shape[1:]), batch)

        def body(carry, mb):
            g_acc, m_acc = carry
            g, m = grads_of(params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32) / n, g_acc, g)
            m_acc = jax.tree.map(lambda a, b: a + b / n, m_acc, m)
            return (g_acc, m_acc), None

        # seed accumulators with the first microbatch (fixes metric structure)
        g0, met0 = grads_of(params, jax.tree.map(lambda x: x[0], micro))
        init = (
            jax.tree.map(lambda a: a.astype(jnp.float32) / n, g0),
            jax.tree.map(lambda a: a / n, met0),
        )
        (g, m), _ = jax.lax.scan(body, init, jax.tree.map(lambda x: x[1:], micro))
        return g, m

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        grads, metrics = accumulate(params, batch)
        if pcfg.grad_compress:
            grads, new_err = compress_gradients(grads, state.get("grad_error"), pcfg.dp_axes)
        new_params, new_opt, stats = adamw_update(params, grads, opt, tcfg)
        metrics = {**metrics, **stats}
        new_state = {"params": new_params, "opt": new_opt}
        if pcfg.grad_compress:
            new_state["grad_error"] = new_err
        return new_state, metrics

    return train_step
