"""Optimizers: AdamW with fp32 or int8-quantized state, ZeRO-1 sharding.

Distributed-optimization features (DESIGN.md §7, beyond-paper):
  * ZeRO-1: optimizer moments are sharded over the data axes in addition to the
    parameter's own tensor-parallel sharding (the `zero` logical axes); XLA
    inserts the reduce-scatter/all-gather pair this implies.
  * 8-bit state (optimizer="adamw8bit"): m/v stored int8 with per-row fp32
    absmax scales (bitsandbytes-style blockwise quantization, block = last
    dim). Cuts optimizer-state HBM 4x — this is what lets arctic-480b fit a
    single 256-chip v5e pod (see EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import TrainConfig
from repro.parallel.axes import current_rules


# ------------------------------------------------------------------- schedule
def lr_schedule(cfg: TrainConfig, step):
    """Linear warmup -> cosine decay to 10%."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    total = jnp.maximum(cfg.steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / total, 0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.learning_rate * warm * cos


# ------------------------------------------------------- int8 state quantizers
def _quantize(x: jax.Array):
    """int8 + per-row absmax scale. 0/1-D tensors use a per-tensor scale."""
    xf = x.astype(jnp.float32)
    if x.ndim <= 1:
        s = jnp.max(jnp.abs(xf)) + 1e-12
        q = jnp.round(xf / s * 127.0).astype(jnp.int8)
        return {"q": q, "s": s.reshape(())}
    s = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) + 1e-12
    q = jnp.round(xf / s * 127.0).astype(jnp.int8)
    return {"q": q, "s": s}


def _dequantize(qs) -> jax.Array:
    return qs["q"].astype(jnp.float32) * qs["s"] / 127.0


def _is_quant(leaf) -> bool:
    return isinstance(leaf, dict) and set(leaf) == {"q", "s"}


# ----------------------------------------------------------------------- init
def adamw_init(params, cfg: TrainConfig):
    # m and v must be *distinct* buffers (donation would otherwise see the
    # same buffer twice)
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)

    if cfg.optimizer == "adamw8bit":
        m = jax.tree.map(lambda p: _quantize(zeros(p)), params)
        v = jax.tree.map(lambda p: _quantize(zeros(p)), params)
    else:
        m = jax.tree.map(zeros, params)
        v = jax.tree.map(zeros, params)
    return {"m": m, "v": v, "step": jnp.zeros((), jnp.int32)}


# ------------------------------------------------------------------- sharding
def _zero1_spec(spec: P, shape: tuple[int, ...]) -> P:
    """Add the ZeRO axes to the largest unsharded, evenly divisible dim.

    Explicit pjit input shardings require exact divisibility (unlike internal
    sharding constraints, which GSPMD pads), so dims like a 35-layer stack must
    be left alone.
    """
    from repro.parallel.axes import current_mesh

    rules = current_rules()
    mesh = current_mesh()
    zero = rules.resolve("zero")
    if zero is None or mesh is None or len(shape) == 0:
        return spec
    zaxes = zero if isinstance(zero, tuple) else (zero,)
    zsize = 1
    for a in zaxes:
        zsize *= mesh.shape[a]
    entries = list(spec) + [None] * (len(shape) - len(spec))
    # a mesh axis may appear at most once per spec (e.g. arctic expert weights
    # already shard their ffn dim over the data axes)
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a is not None:
                used.add(a)
    if any(a in used for a in zaxes):
        return spec
    cands = [
        i
        for i, e in enumerate(entries)
        if e is None and shape[i] >= zsize and shape[i] % zsize == 0
    ]
    if not cands:
        return spec
    i = max(cands, key=lambda j: shape[j])
    entries[i] = zaxes if len(zaxes) > 1 else zaxes[0]
    return P(*entries)


def opt_state_specs(param_specs_tree, param_shapes_tree, cfg: TrainConfig):
    """PartitionSpec tree matching adamw_init's structure."""

    def moment_spec(spec, shp):
        return _zero1_spec(spec, shp.shape)

    mspec = jax.tree.map(moment_spec, param_specs_tree, param_shapes_tree)
    if cfg.optimizer == "adamw8bit":

        def qspec(spec, shp):
            base = _zero1_spec(spec, shp.shape)
            if len(shp.shape) <= 1:
                return {"q": base, "s": P()}
            entries = list(base) + [None] * (len(shp.shape) - len(base))
            return {"q": base, "s": P(*entries[:-1], None)}

        mspec = jax.tree.map(qspec, param_specs_tree, param_shapes_tree)
        return {"m": mspec, "v": mspec, "step": P()}
    return {"m": mspec, "v": mspec, "step": P()}


# --------------------------------------------------------------------- update
def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, opt_state, cfg: TrainConfig):
    """Returns (new_params, new_opt_state, stats). Grad clip + AdamW + wd."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip > 0 else 1.0

    quant = cfg.optimizer == "adamw8bit"
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mf = _dequantize(m) if quant else m
        vf = _dequantize(v) if quant else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * jnp.square(g)
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return new_p, (_quantize(mf) if quant else mf), (_quantize(vf) if quant else vf)

    is_leaf = _is_quant
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"], is_leaf=is_leaf)
    flat_v = jax.tree.leaves(opt_state["v"], is_leaf=is_leaf)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, stats
