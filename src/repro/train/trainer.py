"""Training driver: jit + shardings, checkpoint/restart, straggler watchdog.

Fault-tolerance model (DESIGN.md §2):
  * checkpoint/restart — CheckpointManager (async, atomic); `resume()` restores
    the latest step under the *current* mesh (elastic: a restarted job with a
    different device count re-shards via NamedSharding device_put).
  * straggler mitigation — per-step wall-time EMA; steps slower than
    `straggler_factor` x EMA are logged and counted (on a real pod this signal
    feeds the job controller to hot-swap the slow host; here it is surfaced as
    a metric and tested by injecting an artificial delay).
  * data determinism — batches are keyed by (seed, step), so a restart resumes
    mid-epoch without data loss/duplication.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import ParallelConfig, TrainConfig
from repro.models.transformer import Model
from repro.parallel.axes import logical_spec, sanitize_spec_tree, use_mesh
from repro.train.optimizer import adamw_init, opt_state_specs
from repro.train.train_step import make_train_step


@dataclass
class StragglerWatchdog:
    factor: float = 2.5
    ema: float | None = None
    alpha: float = 0.2
    slow_steps: int = 0

    def observe(self, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        self.slow_steps += slow
        self.ema = dt if self.ema is None else (1 - self.alpha) * self.ema + self.alpha * dt
        return slow


class Trainer:
    def __init__(self, model: Model, pcfg: ParallelConfig, tcfg: TrainConfig,
                 mesh=None, rules=None):
        self.model = model
        self.pcfg, self.tcfg = pcfg, tcfg
        self.mesh, self.rules = mesh, rules
        self.watchdog = StragglerWatchdog()
        self.ckpt = (
            CheckpointManager(tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints)
            if tcfg.checkpoint_dir
            else None
        )
        self._build()

    def _ctx(self):
        from repro.parallel.axes import ShardingRules

        return use_mesh(self.mesh, self.rules or ShardingRules())

    def _build(self):
        with self._ctx():
            step = make_train_step(self.model, self.pcfg, self.tcfg)
            if self.mesh is None:
                self._step = jax.jit(step, donate_argnums=(0,))
                self._state_shardings = None
                return
            pspecs = self.model.pspecs()
            pshapes = self.model.pshapes()
            ospecs = opt_state_specs(pspecs, pshapes, self.tcfg)
            oshapes = jax.eval_shape(lambda p: adamw_init(p, self.tcfg), pshapes)

            def ns(spec_tree, shape_tree):
                st = sanitize_spec_tree(spec_tree, shape_tree, self.mesh)
                return jax.tree.map(
                    lambda s: NamedSharding(self.mesh, s), st,
                    is_leaf=lambda s: isinstance(s, P),
                )

            self._state_shardings = {
                "params": ns(pspecs, pshapes),
                "opt": ns(ospecs, oshapes),
            }
            if self.pcfg.grad_compress:
                # error-feedback buffers shard like params (fp32)
                self._state_shardings["grad_error"] = ns(pspecs, pshapes)
            self._batch_sharding = NamedSharding(self.mesh, logical_spec("dp", None))
            # out_shardings pinned to the input layouts: the optimizer update
            # runs on ZeRO-sharded grads/moments and XLA would otherwise leave
            # the new params reduce-scattered (step 2 would then reject them);
            # pinning inserts the ZeRO-1 param all-gather explicitly.
            self._step = jax.jit(
                step,
                in_shardings=(self._state_shardings, None),
                out_shardings=(self._state_shardings, None),
                donate_argnums=(0,),
            )

    def init_state(self, seed: int | None = None) -> dict:
        with self._ctx():
            key = jax.random.PRNGKey(self.tcfg.seed if seed is None else seed)
            params = self.model.init(key)
            if self._state_shardings is not None:
                params = jax.device_put(params, self._state_shardings["params"])
            opt = adamw_init(params, self.tcfg)
            if self._state_shardings is not None:
                opt = jax.device_put(opt, self._state_shardings["opt"])
            state = {"params": params, "opt": opt}
            if self.pcfg.grad_compress:
                err = jax.tree.map(
                    lambda p: jax.numpy.zeros(p.shape, jax.numpy.float32), params
                )
                if self._state_shardings is not None:
                    err = jax.device_put(err, self._state_shardings["grad_error"])
                state["grad_error"] = err
            return state

    def resume(self) -> tuple[dict, int]:
        """Restore latest checkpoint under the current mesh (elastic restart)."""
        assert self.ckpt is not None
        target = jax.eval_shape(lambda: self.init_state())
        shardings = self._state_shardings
        state, step = self.ckpt.restore(target, shardings=shardings)
        return state, step

    def fit(self, state: dict, data_iter, *, steps: int, start_step: int = 0,
            log=print) -> tuple[dict, list[dict]]:
        history = []
        with self._ctx():
            for step_i in range(start_step, start_step + steps):
                batch = next(data_iter) if hasattr(data_iter, "__next__") else data_iter.batch(step_i)
                batch = jax.tree.map(jax.numpy.asarray, batch)
                if self.mesh is not None:
                    batch = jax.device_put(batch, self._batch_sharding)
                t0 = time.perf_counter()
                state, metrics = self._step(state, batch)
                metrics = jax.tree.map(float, jax.device_get(metrics))
                dt = time.perf_counter() - t0
                slow = self.watchdog.observe(dt)
                metrics.update(step=step_i, step_time_s=dt,
                               straggler_flag=bool(slow),
                               slow_steps=self.watchdog.slow_steps)
                history.append(metrics)
                if step_i % max(self.tcfg.log_every, 1) == 0:
                    log(
                        f"step {step_i}: loss={metrics['loss']:.4f} "
                        f"gnorm={metrics['grad_norm']:.3f} dt={dt * 1e3:.0f}ms"
                        + (" [STRAGGLER]" if slow else "")
                    )
                if (
                    self.ckpt is not None
                    and self.tcfg.checkpoint_every
                    and (step_i + 1) % self.tcfg.checkpoint_every == 0
                ):
                    self.ckpt.save(step_i + 1, state)
        if self.ckpt is not None:
            self.ckpt.wait()
        return state, history
