from repro.train.optimizer import adamw_init, adamw_update, lr_schedule, opt_state_specs  # noqa: F401
from repro.train.train_step import make_train_step  # noqa: F401
