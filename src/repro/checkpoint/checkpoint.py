"""Fault-tolerant checkpointing: async, atomic, elastic-reshard restore.

Layout: <dir>/step_<N>/ { manifest.json, arrays.npz }. Writes go to a tmp dir
then os.replace() — a crash mid-write can never corrupt the latest checkpoint
(atomic rename is the POSIX guarantee restarts rely on). Saving runs on a
background thread (async) so the train loop isn't stalled by host I/O;
`wait()` joins before the next save or program exit.

Elastic restore: arrays are saved device-agnostic; `restore_pytree` takes an
optional shardings tree and device_put's each leaf under the *new* mesh — this
is how a job restarted on a different slice size resumes (tests cover a
1-device -> 8-device reshard round trip).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out, dtypes = {}, {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        dtypes[key] = str(arr.dtype)
        if arr.dtype.name == "bfloat16":  # npz cannot round-trip ml_dtypes
            arr = arr.view(np.uint16)
        out[key] = arr
    return out, dtypes


def save_pytree(path: str, tree, step: int) -> None:
    tmp = f"{path}.tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    arrays, dtypes = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "keys": sorted(arrays.keys()), "dtypes": dtypes}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)


def restore_pytree(path: str, target_tree, shardings=None):
    """Restore into the structure of `target_tree` (shapes/dtypes validated).

    `shardings`: optional matching tree of jax.sharding.Sharding — leaves are
    device_put under the new mesh (elastic re-shard).
    """
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    with open(os.path.join(path, "manifest.json")) as f:
        dtypes = json.load(f).get("dtypes", {})
    import ml_dtypes

    for k, dt in dtypes.items():
        if dt == "bfloat16" and k in arrays:
            arrays[k] = arrays[k].view(ml_dtypes.bfloat16)

    flat, tdef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = jax.tree.flatten(shardings)[0]
    out = []
    for i, (path_k, leaf) in enumerate(flat):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_k)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.device_put(arr))
    return jax.tree.unflatten(jax.tree.structure(target_tree), out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, *, async_: bool = True) -> None:
        self.wait()
        # materialize on host *before* handing to the thread so the train loop
        # can donate/overwrite device buffers immediately
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_pytree(self._step_dir(step), host_tree, step)
            self._gc()

        if async_:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore(self, target_tree, step: int | None = None, shardings=None):
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return restore_pytree(self._step_dir(step), target_tree, shardings), step

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
