from repro.checkpoint.checkpoint import CheckpointManager, save_pytree, restore_pytree  # noqa: F401
