from repro.data.pipeline import SyntheticLM, MemmapCorpus, Prefetcher, pack_documents  # noqa: F401
