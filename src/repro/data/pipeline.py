"""Data pipeline: deterministic, host-sharded, prefetching.

Sources:
  * SyntheticLM   — deterministic per-step synthetic token stream (Zipf-ish),
                    keyed by (seed, step, host) so restarts and elastic
                    re-sharding reproduce exactly the same global batches.
  * MemmapCorpus  — np.memmap token file; documents packed to seq_len with an
                    EOS separator; block-shuffled per epoch; disjoint per-host
                    shards (proved by tests/test_data.py).

Prefetcher overlaps host data preparation with device compute (one-deep
pipeline via a background thread), the host-side analogue of the paper's
compute/communication overlap.
"""

from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticLM:
    """Deterministic synthetic LM batches: batch[i] identical across runs."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int, *, seed: int = 0,
                 num_hosts: int = 1, host_id: int = 0):
        assert global_batch % num_hosts == 0
        self.vocab, self.seq, self.gb = vocab, seq_len, global_batch
        self.seed, self.num_hosts, self.host_id = seed, num_hosts, host_id
        self.local_batch = global_batch // num_hosts

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id])
        )
        # Zipf-flavored marginal so CE starts near ln(V) but is learnable
        z = rng.zipf(1.3, size=(self.local_batch, self.seq + 1))
        tokens = (z - 1) % self.vocab
        return {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def pack_documents(docs, seq_len: int, eos: int) -> np.ndarray:
    """Pack variable-length docs into (n, seq_len+1) rows with EOS separators."""
    flat: list[int] = []
    for d in docs:
        flat.extend(int(t) for t in d)
        flat.append(eos)
    n = len(flat) // (seq_len + 1)
    if n == 0:
        raise ValueError("not enough tokens to pack a single row")
    arr = np.asarray(flat[: n * (seq_len + 1)], np.int32)
    return arr.reshape(n, seq_len + 1)


class MemmapCorpus:
    """Token-file corpus with deterministic block shuffling + host sharding."""

    def __init__(self, path: str, seq_len: int, global_batch: int, *, seed: int = 0,
                 num_hosts: int = 1, host_id: int = 0, dtype=np.int32):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.seq, self.gb = seq_len, global_batch
        self.seed, self.num_hosts, self.host_id = seed, num_hosts, host_id
        assert global_batch % num_hosts == 0
        self.local_batch = global_batch // num_hosts
        self.rows = len(self.tokens) // (seq_len + 1)
        if self.rows < global_batch:
            raise ValueError(f"corpus too small: {self.rows} rows < batch {global_batch}")

    def _perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, epoch]))
        return rng.permutation(self.rows)

    def batch(self, step: int) -> dict:
        per_epoch = self.rows // self.gb
        epoch, within = divmod(step, per_epoch)
        perm = self._perm(epoch)
        base = within * self.gb + self.host_id * self.local_batch
        rows = perm[base : base + self.local_batch]
        L = self.seq + 1
        out = np.stack([self.tokens[r * L : (r + 1) * L] for r in rows]).astype(np.int32)
        return {"tokens": out[:, :-1], "labels": out[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """One-deep background prefetch of an iterator."""

    _STOP = object()

    def __init__(self, it, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._err = None

        def worker():
            try:
                for item in it:
                    self.q.put(item)
            except Exception as e:  # surfaced on next()
                self._err = e
            finally:
                self.q.put(self._STOP)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is self._STOP:
            if self._err:
                raise self._err
            raise StopIteration
        return item
