"""Serving launcher: batched generation with the slot-based engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --requests 12
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.models.transformer import Model
from repro.serve.engine import Request, ServeEngine

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_len=args.max_len, slots=args.slots)

    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32),
                max_new_tokens=args.max_new)
        for _ in range(args.requests)
    ]
    t0 = time.perf_counter()
    done = engine.serve(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s on CPU, reduced config)")
    assert all(r.done for r in done)


if __name__ == "__main__":
    main()
