"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device query, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax

from repro.configs.base import ShapeSpec
from repro.parallel.axes import ShardingRules, make_rules


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 v5e pod (data, model); multi-pod adds a leading 2-pod DCN axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """jax.make_mesh when it uses all devices; explicit device subset otherwise
    (the dry-run process exposes 512 host devices; the single-pod mesh uses the
    first 256)."""
    import math

    import numpy as np
    from jax.sharding import Mesh

    n = math.prod(shape)
    devices = jax.devices()
    if n == len(devices):
        return jax.make_mesh(shape, axes)
    if n > len(devices):
        raise ValueError(f"mesh {shape} needs {n} devices, have {len(devices)}")
    return Mesh(np.array(devices[:n]).reshape(shape), axes)


def rules_for(mesh, shape: ShapeSpec | None = None, *, sequence_parallel: bool = True,
              zero1: bool = True) -> ShardingRules:
    """Default logical->mesh axis rules for a production mesh.

    Batch shards over ("pod", "data"); weights over "model". For decode shapes
    whose global batch is smaller than the dp axes (long-context B=1), the
    data axis is repurposed for context parallelism over the KV/seq dim.
    """
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    tp = ("model",) if "model" in names else ()
    cp: tuple[str, ...] = ()
    if shape is not None and shape.kind == "decode":
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        if shape.global_batch < dp_size:
            cp = tuple(a for a in ("data",) if a in names)
            dp = tuple(a for a in ("pod",) if a in names)
            if shape.global_batch == 1:
                dp = ()
    return make_rules(dp=dp, tp=tp, sequence_parallel=sequence_parallel,
                      context_parallel=cp, zero1=zero1)
