"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --reduced \
      --steps 50 --checkpoint-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch gpt-175b --auto-plan \
      --chips 1024 --batch 1024   # analytic planning only (no execution)

On this CPU container, execution uses `--reduced` configs; full configs are
exercised via `repro.launch.dryrun` (AOT lower+compile) and `--auto-plan`
(the paper's analytical planner).
"""

from __future__ import annotations

import argparse


from repro.configs import get_config
from repro.configs.base import ParallelConfig, TrainConfig
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.models.transformer import Model
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="selective", choices=["none", "selective", "full"])
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "adamw8bit"])
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default="", help="e.g. 2x4 -> (data, model) axes")
    # analytic planning path
    ap.add_argument("--auto-plan", action="store_true")
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--hardware", default="tpu-v5e")
    args = ap.parse_args()

    if args.auto_plan:
        from repro.core.hardware import get_hardware
        from repro.core.paper_data import GPT_CONFIGS, LLAMA2_CONFIGS
        from repro.core.planner import plan

        cfg = (GPT_CONFIGS.get(args.arch) or LLAMA2_CONFIGS.get(args.arch)
               or get_config(args.arch))
        hw = get_hardware(args.hardware)
        print(f"auto-plan: {cfg.name} on {args.chips} x {hw.name}, batch {args.batch}")
        for p in plan(cfg, hw, args.chips, global_batch=args.batch, seq=args.seq,
                      max_tp=64):
            print(" ", p.describe())
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)

    mesh = rules = None
    pcfg = ParallelConfig(remat=args.remat, microbatches=args.microbatches,
                          grad_compress=args.grad_compress)
    if args.mesh:
        from repro.launch.mesh import make_mesh
        from repro.parallel.axes import make_rules

        shape = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[: len(shape)] if len(shape) == 2 else ("pod", "data", "model")
        mesh = make_mesh(shape, axes)
        rules = make_rules(dp=tuple(a for a in axes if a != "model"), tp=("model",))

    tcfg = TrainConfig(steps=args.steps, optimizer=args.optimizer,
                       checkpoint_dir=args.checkpoint_dir,
                       checkpoint_every=args.checkpoint_every)
    trainer = Trainer(model, pcfg, tcfg, mesh=mesh, rules=rules)

    start = 0
    if args.resume and args.checkpoint_dir:
        try:
            state, start = trainer.resume()
            print(f"resumed from step {start}")
        except FileNotFoundError:
            state = trainer.init_state()
    else:
        state = trainer.init_state()

    data = Prefetcher(iter(SyntheticLM(cfg.vocab_size, args.seq, args.batch)))
    # skip already-consumed steps for deterministic resume
    for _ in range(start):
        next(data)
    state, history = trainer.fit(state, data, steps=args.steps - start, start_step=start)
    print(f"done: final loss {history[-1]['loss']:.4f}, "
          f"straggler steps {history[-1]['slow_steps']}")


if __name__ == "__main__":
    main()
