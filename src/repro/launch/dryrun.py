import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (SPMD partitioning succeeds),
  * the step fits per-device HBM (compiled.memory_analysis()),
  * and extracts cost_analysis + the collective schedule for §Roofline.

Results are cached incrementally to experiments/dryrun/<cell>.json so the full
sweep is resumable. Usage:

  PYTHONPATH=src python -m repro.launch.dryrun [--arch A ...] [--shape S ...]
      [--mesh single|multi|both] [--force]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, SHAPES, applicable, get_config, input_specs  # noqa: E402
from repro.configs.base import ParallelConfig, TrainConfig  # noqa: E402
from repro.core.hlo import collective_summary  # noqa: E402
from repro.launch.mesh import make_production_mesh, rules_for  # noqa: E402
from repro.models.transformer import Model  # noqa: E402
from repro.parallel.axes import logical_spec, sanitize_spec_tree, use_mesh  # noqa: E402
from repro.train.optimizer import adamw_init, opt_state_specs  # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")

# per-arch training policy overrides (recorded in EXPERIMENTS.md):
# arctic-480b needs 8-bit optimizer state to fit a single 256-chip v5e pod;
# rwkv6's intra-layer chunk scan needs full recomputation (selective remat
# saves per-chunk decay matrices -> O(S/Q) blowup).
ARCH_TRAIN_OVERRIDES = {
    # 8-bit optimizer + deep accumulation: 480B of experts leave ~5 GiB HBM
    # headroom for activations/transients per microbatch
    "arctic_480b": {"optimizer": "adamw8bit", "microbatches_floor": 32},
    "rwkv6_7b": {"remat": "full"},
}


def _ns(mesh, spec_tree, shape_tree=None):
    """NamedSharding tree; sanitized against shapes when provided (explicit
    input shardings must divide evenly)."""
    if shape_tree is not None:
        spec_tree = sanitize_spec_tree(spec_tree, shape_tree, mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def batch_pspecs(cfg, shape):
    """PartitionSpec tree for the input batch under current rules."""
    specs = {}
    parts = input_specs(cfg, shape)
    for k, v in parts.items():
        if k == "embeds":
            specs[k] = logical_spec("dp", None, None)
        else:
            specs[k] = logical_spec("dp", None)
    return specs


def pick_train_policy(cfg, shape, over: dict) -> tuple[int, str]:
    """Choose (microbatches, remat) with the paper's memory model (§5.1):
    smallest accumulation count whose predicted footprint fits v5e HBM.
    This is the auto-planner applied to the fixed production mesh."""
    from repro.core.memory import training_memory
    from repro.parallel.axes import axes_size

    if "remat" in over:
        remats = [over["remat"]]
    else:
        remats = ["selective", "full"]
    dp = max(axes_size("dp"), 1)
    per_replica = max(shape.global_batch // dp, 1)
    budget = 16e9 * 0.9
    # engineering floors over the analytic model: MoE dispatch buffers, ssm
    # chunk-scan residuals, and XLA's while-carry copies of fp32 grad
    # accumulators exceed the closed-form activation terms (§Perf iteration 3)
    floor = {"moe": 8, "ssm": 2, "hybrid": 2}.get(cfg.family, 2)
    floor = max(floor, over.get("microbatches_floor", 1))
    for n_micro in (1, 2, 4, 8, 16, 32):
        if n_micro < floor:
            continue
        if per_replica % n_micro or per_replica // n_micro < 1:
            continue
        for remat in remats:
            mem = training_memory(
                cfg, global_batch=shape.global_batch, seq=shape.seq_len, dp=dp,
                tp=max(axes_size("tp"), 1), pp=1, sp=True,
                microbatch=per_replica // n_micro, recompute=remat, zero1=True,
                opt_8bit=over.get("optimizer") == "adamw8bit",
            )
            if mem.total <= budget:
                return n_micro, remat
    return per_replica, remats[-1]


def lower_cell(arch: str, shape_name: str, multi_pod: bool, mesh=None, shape=None):
    cfg = get_config(arch)
    shape = shape or SHAPES[shape_name]
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(mesh, shape)
    model = Model(cfg)
    with use_mesh(mesh, rules):
        pspecs = model.pspecs()
        pshapes = model.pshapes()
        cp = bool(rules.cp)

        if shape.kind == "train":
            over = dict(ARCH_TRAIN_OVERRIDES.get(arch, {}))
            tcfg = TrainConfig(
                **{k: v for k, v in over.items() if k not in ("remat", "microbatches_floor")}
            )
            n_micro, remat = pick_train_policy(cfg, shape, over)
            pcfg = ParallelConfig(remat=remat, microbatches=n_micro, zero1=True)
            print(f"    [policy] {arch}/{shape_name}: microbatches={n_micro} remat={remat}")
            step = make_train_step(model, pcfg, tcfg)
            oshapes = jax.eval_shape(lambda p: adamw_init(p, tcfg), pshapes)
            ospecs = opt_state_specs(pspecs, pshapes, tcfg)
            state_shapes = {"params": pshapes, "opt": oshapes}
            state_shardings = {
                "params": _ns(mesh, pspecs, pshapes),
                "opt": _ns(mesh, ospecs, oshapes),
            }
            bspecs = batch_pspecs(cfg, shape)
            bshapes = input_specs(cfg, shape)
            f = jax.jit(
                step,
                in_shardings=(state_shardings, _ns(mesh, bspecs)),
                donate_argnums=(0,),
            )
            lowered = f.lower(state_shapes, bshapes)
        elif shape.kind == "prefill":
            bspecs = batch_pspecs(cfg, shape)
            bshapes = input_specs(cfg, shape)
            f = jax.jit(
                lambda p, b: model.prefill(p, b, max_len=shape.seq_len, cp=cp),
                in_shardings=(_ns(mesh, pspecs, pshapes), _ns(mesh, bspecs, bshapes)),
            )
            lowered = f.lower(pshapes, bshapes)
        else:  # decode
            cshapes = model.cache_shapes(shape.global_batch, shape.seq_len, cp=cp)
            cspecs = model.cache_pspecs(cp=cp)
            tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            f = jax.jit(
                lambda p, c, t: model.decode_step(p, c, t, cp=cp),
                in_shardings=(
                    _ns(mesh, pspecs, pshapes),
                    _ns(mesh, cspecs, cshapes),
                    NamedSharding(mesh, logical_spec("dp", None)),
                ),
                donate_argnums=(1,),
            )
            lowered = f.lower(pshapes, cshapes, tokens)
    return lowered, mesh, model


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str, force: bool):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(path) and not force:
        with open(path) as fh:
            rec = json.load(fh)
        if rec.get("status") == "ok":
            print(f"[skip cached] {cell_id}")
            return rec

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "devices": 512 if multi_pod else 256,
        "status": "skipped",
        "reason": reason,
    }
    if ok:
        t0 = time.time()
        try:
            lowered, mesh, model = lower_cell(arch, shape_name, multi_pod)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            colls = collective_summary(compiled.as_text())
            rec.update(
                status="ok",
                lower_s=round(t1 - t0, 1),
                compile_s=round(t2 - t1, 1),
                param_count=model.param_count(),
                memory={
                    "argument_bytes": int(mem.argument_size_in_bytes),
                    "output_bytes": int(mem.output_size_in_bytes),
                    "temp_bytes": int(mem.temp_size_in_bytes),
                    "alias_bytes": int(mem.alias_size_in_bytes),
                    "peak_bytes_per_device": int(
                        mem.argument_size_in_bytes
                        + mem.output_size_in_bytes
                        + mem.temp_size_in_bytes
                        - mem.alias_size_in_bytes
                    ),
                    # XLA:CPU does not implement donated-buffer aliasing, so the
                    # raw number double-counts donated state/caches; on TPU the
                    # donated outputs alias their argument buffers:
                    "peak_bytes_tpu_adjusted": int(
                        mem.argument_size_in_bytes + mem.temp_size_in_bytes
                    ),
                },
                cost={
                    "flops_per_device_raw": float(cost.get("flops", -1)),
                    "bytes_accessed_per_device_raw": float(cost.get("bytes accessed", -1)),
                },
                collectives=colls,
            )
            print(
                f"[ok] {cell_id}: compile {rec['compile_s']}s, "
                f"peak/dev {rec['memory']['peak_bytes_per_device']/2**30:.2f} GiB"
            )
        except Exception as e:  # record failure for triage, keep sweeping
            rec.update(status="error", error=f"{type(e).__name__}: {e}",
                       traceback=traceback.format_exc()[-4000:])
            print(f"[FAIL] {cell_id}: {type(e).__name__}: {e}")
    else:
        print(f"[skip n/a] {cell_id}: {reason}")

    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as fh:
        json.dump(rec, fh, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=ARCHS)
    ap.add_argument("--shape", nargs="*", default=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default=os.path.normpath(OUT_DIR))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    n_ok = n_fail = n_skip = 0
    for arch in args.arch:
        for shape_name in args.shape:
            for mp in meshes:
                rec = run_cell(arch, shape_name, mp, args.out, args.force)
                s = rec["status"]
                n_ok += s == "ok"
                n_fail += s == "error"
                n_skip += s == "skipped"
    print(f"dry-run done: {n_ok} ok, {n_fail} failed, {n_skip} skipped (n/a)")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
