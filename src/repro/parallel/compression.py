"""int8 gradient all-reduce with error feedback (beyond-paper optimization).

The paper (§1.2, §5.3) identifies inter-node network bandwidth as the scaling
limiter for distributed training. Quantizing the DP gradient all-reduce to int8
cuts that traffic 4x (bf16->int8 with fp32 scales). Implemented with shard_map
over the data axes: quantize locally -> psum int32 (bit-exact accumulation
across replicas) -> dequantize; the residual (quantization error) is fed back
into the next step's gradients (error-feedback EF21-style, which keeps SGD/Adam
convergence guarantees).

When no mesh is active this degrades to a pure quantize/dequantize round trip
(so unit tests exercise the numerics on one device).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp



def _q8(x: jax.Array):
    s = jnp.max(jnp.abs(x)) + 1e-12
    q = jnp.round(x / s * 127.0).astype(jnp.int8)
    return q, s


def compress_gradients(grads, error_fb=None, dp_axes: tuple[str, ...] = ()):
    """Quantize+psum gradients over `dp_axes`. Returns (grads, new_error_fb).

    Must be called on gradients that are *locally averaged per replica* but not
    yet reduced across dp (i.e. inside shard_map, or — under GSPMD — applied as
    a numerics-equivalent transform: q/dq + the psum XLA already inserts).
    """
    if error_fb is None:
        error_fb = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = _q8(gf)
        deq = q.astype(jnp.float32) * s / 127.0
        new_e = gf - deq  # error feedback
        return deq.astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_fb)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return new_g, new_e
