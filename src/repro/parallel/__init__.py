from repro.parallel.axes import (  # noqa: F401
    ShardingRules,
    use_mesh,
    current_mesh,
    current_rules,
    logical_spec,
    shard,
    named_sharding,
)
