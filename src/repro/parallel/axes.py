"""Logical-axis sharding: a thin indirection between model code and mesh axes.

Model code annotates tensors with *logical* axis names ("dp", "tp", "sp", "ep",
"zero"); a `ShardingRules` instance maps each logical name to zero or more mesh
axis names.  When no mesh is active (unit tests, single-device smoke runs) every
sharding helper is a no-op, so the same model code runs everywhere.

Logical names:
  dp   — data-parallel axes (batch / token dims). Multi-pod: ("pod", "data").
  tp   — tensor-parallel (Megatron) axes for weights and head dims.
  ep   — expert-parallel axes for MoE expert dims (defaults to tp).
  sp   — sequence-parallel axes for activation seq dims (paper §1.3 / [14]).
  cp   — context-parallel axes for long-context KV/seq sharding.
  zero — extra axes for ZeRO-1 optimizer-state sharding (defaults to dp).
"""

from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardingRules:
    dp: tuple[str, ...] = ()
    tp: tuple[str, ...] = ()
    sp: tuple[str, ...] = ()
    ep: tuple[str, ...] = ()
    cp: tuple[str, ...] = ()
    zero: tuple[str, ...] = ()

    def resolve(self, name):
        """Resolve one logical dim annotation to a PartitionSpec entry."""
        if name is None:
            return None
        if isinstance(name, (tuple, list)):  # combination, e.g. ("dp", "tp")
            out: list[str] = []
            for n in name:
                r = self.resolve(n)
                if r is None:
                    continue
                out.extend(r if isinstance(r, tuple) else (r,))
            return tuple(out) if out else None
        axes = getattr(self, name, None)
        if not axes:
            return None
        return tuple(axes) if len(axes) > 1 else axes[0]


def make_rules(
    *,
    dp: tuple[str, ...] = (),
    tp: tuple[str, ...] = (),
    sequence_parallel: bool = False,
    context_parallel: tuple[str, ...] = (),
    zero1: bool = True,
) -> ShardingRules:
    return ShardingRules(
        dp=dp,
        tp=tp,
        sp=tp if sequence_parallel else (),
        ep=tp,
        cp=context_parallel,
        zero=dp if zero1 else (),
    )


_CTX: contextvars.ContextVar[tuple[Mesh | None, ShardingRules]] = contextvars.ContextVar(
    "repro_mesh_ctx", default=(None, ShardingRules())
)


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None, rules: ShardingRules):
    token = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(token)


def current_mesh() -> Mesh | None:
    return _CTX.get()[0]


def current_rules() -> ShardingRules:
    return _CTX.get()[1]


def logical_spec(*names) -> P:
    """Build a PartitionSpec from logical dim names under the current rules."""
    rules = current_rules()
    return P(*[rules.resolve(n) for n in names])


def named_sharding(*names) -> NamedSharding | None:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_spec(*names))


def shard(x, *names):
    """with_sharding_constraint under the active mesh; identity otherwise."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_spec(*names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def axes_size(name: str) -> int:
    """Total device count behind a logical axis name (1 when no mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    resolved = current_rules().resolve(name)
    if resolved is None:
        return 1
    axes = resolved if isinstance(resolved, tuple) else (resolved,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def spec_sharding(spec: P) -> NamedSharding | None:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec)


def sanitize_pspec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes from dims they don't evenly divide.

    Explicit pjit in_shardings require exact divisibility (e.g. a KV cache with
    2 kv-heads cannot be head-sharded 16-way as an *input*); internal
    with_sharding_constraint calls are padded by GSPMD and stay as-is.
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for i, e in enumerate(entries):
        if e is None:
            out.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(e if (shape[i] % size == 0 and shape[i] >= size) else None)
    return P(*out)


def sanitize_spec_tree(spec_tree, shape_tree, mesh: Mesh):
    """Tree-wise sanitize: specs tree must structurally match the shapes tree."""
    return jax.tree.map(
        lambda s, h: sanitize_pspec(s, h.shape, mesh),
        spec_tree,
        shape_tree,
        is_leaf=lambda s: isinstance(s, P),
    )
