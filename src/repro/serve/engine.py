"""Batched serving engine: prefill + autoregressive decode over slot batches.

Implements the paper's two inference phases as separate compiled programs:
  * prefill (summarization) — fat-GEMM, usually compute-bound (§6.1, Table 4),
  * decode (generation)     — skinny GEMM/GEMV over the KV cache, memory-bound.

Slot-based continuous batching (lite): a fixed decode batch of `slots`; each
finished request frees its slot, queued prompts are prefilled into free slots
and their caches spliced in. Cache buffers are donated across decode steps so
the KV cache is updated in place. Limitation (recorded): the cache position is
a single scalar, so admitted prompts are aligned to the current position —
adequate for the near-equal-length request mixes the benchmarks use.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model


@dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    temperature: float = 0.0
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, *, max_len: int, slots: int = 8, seed: int = 0):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.slots = slots
        self.key = jax.random.PRNGKey(seed)
        self.decode_steps = 0  # decode iterations of the last serve() call

        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t), donate_argnums=(1,)
        )

    # ----------------------------------------------------------- single batch
    def generate(self, prompts: list[np.ndarray], max_new_tokens: int,
                 temperature: float = 0.0) -> list[list[int]]:
        """Generate for a batch of equal-priority prompts (padded to one batch)."""
        B = len(prompts)
        S = max(len(p) for p in prompts)
        # left-pad to common length with token 0; positions beyond prompt are
        # attended (simplification: callers pass equal-length prompts in the
        # benchmarks; ragged batching is handled by the slot scheduler below)
        toks = np.zeros((B, S), np.int32)
        for i, p in enumerate(prompts):
            toks[i, S - len(p) :] = p
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        outs: list[list[int]] = [[] for _ in range(B)]
        for _ in range(max_new_tokens):
            nxt = self._sample(logits, temperature)  # (B,)
            for i in range(B):
                outs[i].append(int(nxt[i]))
            logits, cache = self._decode(self.params, cache, nxt[:, None])
        return outs

    def _sample(self, logits: jax.Array, temperature: float) -> jax.Array:
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / temperature, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------ slot-based server
    def serve(self, requests: list[Request], *, eos: int | None = None) -> list[Request]:
        """Continuous-batching-lite scheduler over a fixed slot count.

        `self.decode_steps` reports the decode iterations of the last call."""
        pending = list(requests)
        active: list[Request | None] = [None] * self.slots
        cache = None
        logits_np = None
        self.decode_steps = 0
        while pending or any(a is not None for a in active):
            # fill free slots: batch-prefill all newly admitted requests
            admit = []
            for s in range(self.slots):
                if active[s] is None and pending:
                    active[s] = pending.pop(0)
                    admit.append(s)
            if admit:
                cache, logits_np = self._admit(admit, active, cache, logits_np)
            live = [s for s in range(self.slots) if active[s] is not None]
            if not live:
                break
            nxt = np.zeros((self.slots,), np.int32)
            for s in live:
                r = active[s]
                tok = int(np.argmax(logits_np[s]))
                r.out_tokens.append(tok)
                nxt[s] = tok
                if (eos is not None and tok == eos) or len(r.out_tokens) >= r.max_new_tokens:
                    r.done = True
                    active[s] = None
            if not any(active[s] is not None for s in live):
                # every live slot finished this step: the decode would only
                # produce logits for freed slots (stale by the next admit)
                continue
            logits, cache = self._decode(self.params, cache, jnp.asarray(nxt)[:, None])
            logits_np = np.array(logits)
            self.decode_steps += 1
        return requests

    def _admit(self, slots_to_fill, active, cache, logits_np):
        """Prefill admitted prompts as one padded batch; splice into slot cache."""
        B = self.slots
        S = max(len(active[s].prompt) for s in slots_to_fill)
        toks = np.zeros((B, S), np.int32)
        for s in slots_to_fill:
            toks[s, S - len(active[s].prompt) :] = active[s].prompt
        logits, new_cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        new_logits = np.array(logits)
        if cache is None:
            return new_cache, new_logits
        # splice: batch dim is leading on every cache leaf except "pos"
        mask = np.zeros((B,), bool)
        for s in slots_to_fill:
            mask[s] = True
        m = jnp.asarray(mask)

        def splice(old, new):
            if old.ndim == 0:  # pos: keep max (slots decode in lockstep)
                return jnp.maximum(old, new)
            if old.shape[0] == B:
                sel = m.reshape((B,) + (1,) * (old.ndim - 1))
                return jnp.where(sel, new, old)
            # stacked-layer leaves: (L, B, ...)
            sel = m.reshape((1, B) + (1,) * (old.ndim - 2))
            return jnp.where(sel, new, old)

        cache = jax.tree.map(splice, cache, new_cache)
        if logits_np is not None:
            logits_np[mask] = new_logits[mask]
        return cache, logits_np
