"""Seeded fault injection and overload protection for the cluster engine.

The paper's serving model (and ROADMAP item 2's charter) assumes hardware
behaves; production capacity reviews ask resilience-aware questions —
goodput under replica crashes, stragglers, and degraded links, and how a
fleet sheds load *before* the backend melts. This module supplies both
halves:

  * `ChaosConfig` — a declarative, seeded failure model. `schedule()`
    pre-samples a deterministic event timeline (Poisson arrival of each
    fault kind over `horizon`, magnitudes and victim picks drawn from
    per-kind `SeedSequence` spawns, so adding one fault kind never
    perturbs another's stream). `_ClusterEngine` merges the timeline
    into its event loop and fires each event against live fleet state:

      - `crash`        one replica dies instantly. In-flight KV is lost;
                       displaced requests re-enter dispatch, where they
                       either re-prefill from scratch or restore their
                       prefix from a *surviving* replica's prefix cache
                       (`repro.cluster.prefixcache`).
      - `straggler`    one replica's engine iterations are stretched by a
                       sampled factor for a sampled duration
                       (`ReplicaSim.set_slowdown`).
      - `link`         the prefill->decode KV-handoff interconnect
                       degrades: transfer times are multiplied by a
                       sampled factor for a sampled duration.
      - `node_failure` a correlated failure: one event crashes a sampled
                       group of replicas at the same instant (the
                       shared-node / shared-rack blast radius the
                       planner's N-loss mode sizes for).

    Chaos off (`ChaosConfig` is None or all rates zero) draws zero
    random numbers and adds nothing to the engine's event merge — runs
    stay bit-identical to the chaos-free engine.

  * `AdmissionConfig` — the admission front door, evaluated per arrival
    BEFORE routing/dispatch (the existing shed -> retry -> drop path
    only reacts after a dispatch attempt):

      - `token_bucket`  GCRA (virtual-scheduling token bucket): sustained
                        `rate` admits/s with `burst` tolerance; arrivals
                        beyond the bucket wait in a bounded door queue
                        (`queue_depth` slots, each delayed to its exact
                        conformance time) and overflow is shed at the
                        door — O(1), no RNG, fully deterministic.
      - `breaker`       a circuit breaker over terminal outcomes: when
                        the rolling failure fraction (shed/drop/lost vs
                        complete) exceeds `fail_thresh`, the door OPENs
                        and sheds everything for `cooloff` seconds, then
                        HALF-OPENs `probes` trial admissions — all must
                        complete to CLOSE, one failure re-opens.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.cluster.autoscale import RollingFlagWindow

CHAOS_KINDS = ("crash", "straggler", "link", "node_failure")
ADMISSION_POLICIES = ("token_bucket", "breaker")


@dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault. `picks` are pre-sampled uniforms in [0, 1)
    used to select victims among the replicas alive at fire time (index
    `int(u * len(eligible))`, without replacement) — pre-sampling keeps
    the schedule a pure function of the config while letting the victim
    depend on fleet state. `factor`/`duration` carry the magnitude for
    stragglers and link degradation; `count` the blast radius for
    correlated node failures."""

    t: float
    kind: str
    factor: float = 1.0
    duration: float = 0.0
    count: int = 1
    picks: tuple[float, ...] = ()

    def validate(self) -> "ChaosEvent":
        """Range-check fields (t/duration in seconds); returns self."""
        if self.kind not in CHAOS_KINDS:
            raise ValueError(
                f"unknown chaos kind {self.kind!r}; choose from {CHAOS_KINDS}")
        if self.t < 0.0:
            raise ValueError("chaos event time must be >= 0")
        if self.factor < 1.0:
            raise ValueError("chaos factor must be >= 1.0")
        if self.duration < 0.0 or self.count < 1:
            raise ValueError("chaos duration must be >= 0 and count >= 1")
        return self


@dataclass(frozen=True)
class ChaosConfig:
    """Seeded failure model. Rates are fleet-wide Poisson intensities in
    events per simulated second over `[0, horizon)`; magnitude ranges
    are uniform `(lo, hi)`. `script` appends hand-placed events (used by
    tests and demos that need a failure at an exact instant) after the
    sampled ones — both are merged in time order."""

    seed: int = 0
    horizon: float = 120.0
    crash_rate: float = 0.0  # replica crashes [events/s]
    straggler_rate: float = 0.0  # straggler onsets [events/s]
    straggler_slowdown: tuple[float, float] = (2.0, 6.0)  # step-cost factor
    straggler_duration: tuple[float, float] = (5.0, 20.0)  # [s]
    link_rate: float = 0.0  # KV-handoff degradations [events/s]
    link_slowdown: tuple[float, float] = (2.0, 8.0)  # p2p time factor
    link_duration: tuple[float, float] = (5.0, 20.0)  # [s]
    node_failure_rate: float = 0.0  # correlated failures [events/s]
    node_group: int = 2  # replicas killed per node failure
    script: tuple[ChaosEvent, ...] = ()

    @property
    def enabled(self) -> bool:
        """True when any event rate (events/s) is set or a script exists."""
        return bool(self.script) or any(
            r > 0.0 for r in (self.crash_rate, self.straggler_rate,
                              self.link_rate, self.node_failure_rate))

    def validate(self) -> "ChaosConfig":
        """Range-check rates (events/s) and horizon (s); returns self."""
        for name in ("crash_rate", "straggler_rate", "link_rate",
                     "node_failure_rate"):
            if getattr(self, name) < 0.0:
                raise ValueError(f"{name} must be >= 0")
        if self.horizon <= 0.0 and self.enabled and not self.script:
            raise ValueError("chaos horizon must be positive")
        if self.node_group < 1:
            raise ValueError("node_group must be >= 1")
        for name in ("straggler_slowdown", "link_slowdown"):
            lo, hi = getattr(self, name)
            if not 1.0 <= lo <= hi:
                raise ValueError(f"{name} must satisfy 1 <= lo <= hi")
        for name in ("straggler_duration", "link_duration"):
            lo, hi = getattr(self, name)
            if not 0.0 < lo <= hi:
                raise ValueError(f"{name} must satisfy 0 < lo <= hi")
        for ev in self.script:
            ev.validate()
        return self

    def schedule(self) -> list[ChaosEvent]:
        """Pre-sample the deterministic event timeline. Each fault kind
        draws from its own `SeedSequence` spawn (the `Workload.substreams`
        idiom), so the schedule for one kind is invariant under changes
        to any other's rate."""
        streams = np.random.SeedSequence(self.seed).spawn(len(CHAOS_KINDS))
        events: list[ChaosEvent] = []
        for kind, ss in zip(CHAOS_KINDS, streams):
            rate = {"crash": self.crash_rate,
                    "straggler": self.straggler_rate,
                    "link": self.link_rate,
                    "node_failure": self.node_failure_rate}[kind]
            if rate <= 0.0:
                continue
            rng = np.random.default_rng(ss)
            t = 0.0
            while True:
                t += float(rng.exponential(1.0 / rate))
                if t >= self.horizon:
                    break
                if kind == "crash":
                    events.append(ChaosEvent(
                        t, kind, picks=(float(rng.random()),)))
                elif kind == "straggler":
                    lo, hi = self.straggler_slowdown
                    dlo, dhi = self.straggler_duration
                    events.append(ChaosEvent(
                        t, kind, factor=float(rng.uniform(lo, hi)),
                        duration=float(rng.uniform(dlo, dhi)),
                        picks=(float(rng.random()),)))
                elif kind == "link":
                    lo, hi = self.link_slowdown
                    dlo, dhi = self.link_duration
                    events.append(ChaosEvent(
                        t, kind, factor=float(rng.uniform(lo, hi)),
                        duration=float(rng.uniform(dlo, dhi))))
                else:  # node_failure
                    events.append(ChaosEvent(
                        t, kind, count=self.node_group,
                        picks=tuple(float(rng.random())
                                    for _ in range(self.node_group))))
        events.extend(ev.validate() for ev in self.script)
        events.sort(key=lambda e: (e.t, CHAOS_KINDS.index(e.kind)))
        return events


def pick_victims(picks: tuple[float, ...], eligible: list[int],
                 count: int) -> list[int]:
    """Select up to `count` victims from `eligible` (sorted indices)
    without replacement, one pre-sampled uniform per pick."""
    pool = list(eligible)
    out: list[int] = []
    for u in picks[:count]:
        if not pool:
            break
        out.append(pool.pop(int(u * len(pool))))
    return out


# --------------------------------------------------------------- admission
@dataclass(frozen=True)
class AdmissionConfig:
    """Front-door overload protection, evaluated per arrival before
    routing. `policy="token_bucket"` uses `rate`/`burst`/`queue_depth`;
    `policy="breaker"` uses `window`/`fail_thresh`/`min_samples`/
    `cooloff`/`probes` (see module docstring for the semantics)."""

    policy: str = "token_bucket"
    # token bucket (GCRA)
    rate: float = 0.0  # sustained admits [req/s]
    burst: int = 1  # bucket depth [requests]
    queue_depth: int = 0  # door-queue slots beyond the bucket (0 = shed)
    # circuit breaker
    window: float = 10.0  # rolling terminal-outcome window [s]
    fail_thresh: float = 0.5  # failure fraction that trips the breaker
    min_samples: int = 10  # terminals required before tripping
    cooloff: float = 5.0  # OPEN hold time before probing [s]
    probes: int = 3  # HALF-OPEN trial admissions

    def validate(self) -> "AdmissionConfig":
        """Range-check rate (req/s), burst, window/cooloff (s); returns self."""
        if self.policy not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {self.policy!r}; "
                             f"choose from {ADMISSION_POLICIES}")
        if self.policy == "token_bucket":
            if self.rate <= 0.0:
                raise ValueError("token_bucket needs rate > 0")
            if self.burst < 1 or self.queue_depth < 0:
                raise ValueError("token_bucket needs burst >= 1 and "
                                 "queue_depth >= 0")
        else:
            if not 0.0 < self.fail_thresh <= 1.0:
                raise ValueError("breaker fail_thresh must be in (0, 1]")
            if self.window <= 0.0 or self.cooloff <= 0.0:
                raise ValueError("breaker window and cooloff must be positive")
            if self.min_samples < 1 or self.probes < 1:
                raise ValueError("breaker min_samples and probes must be >= 1")
        return self


class TokenBucket:
    """GCRA virtual scheduling: emission interval `T = 1/rate`, burst
    tolerance `tau = (burst - 1) * T`. An arrival at `t` conforms when
    the theoretical arrival time `TAT <= t + tau` (admit now); a
    non-conforming arrival is delayed to its conformance time `TAT -
    tau` if fewer than `queue_depth` arrivals are already waiting, else
    shed. Equivalent to a token bucket of depth `burst` refilling at
    `rate`, with exact O(1) arithmetic and no sampling."""

    def __init__(self, cfg: AdmissionConfig):
        self.T = 1.0 / cfg.rate
        self.tau = (cfg.burst - 1) * self.T
        self.queue_depth = cfg.queue_depth
        self.tat = 0.0
        self.admitted = 0
        self.delayed = 0
        self.door_shed = 0

    def offer(self, rid: int, t: float) -> float | None:
        """Admit time (== t immediate, > t door-queued) or None (shed)."""
        tat = max(self.tat, t)
        lateness = tat - self.tau - t  # seconds until conformance
        if lateness <= 0.0:
            self.tat = tat + self.T
            self.admitted += 1
            return t
        if lateness > self.queue_depth * self.T:
            self.door_shed += 1
            return None
        self.tat = tat + self.T
        self.admitted += 1
        self.delayed += 1
        return t + lateness

    def observe(self, rid: int, t: float, ok: bool) -> None:
        """Terminal-outcome feedback at time `t` (seconds): ignored."""
        pass  # open-loop: the bucket does not react to outcomes

    def stats(self) -> dict:
        """Door counters (requests): admitted / delayed / shed."""
        return {"policy": "token_bucket", "door_admitted": self.admitted,
                "door_delayed": self.delayed, "door_shed": self.door_shed,
                "breaker_opens": 0}


class CircuitBreaker:
    """CLOSED -> OPEN -> HALF_OPEN state machine over terminal outcomes
    (complete = success; shed/drop/lost = failure). The door never
    delays: it either admits or sheds."""

    def __init__(self, cfg: AdmissionConfig):
        self.cfg = cfg
        self.state = "closed"
        self.open_until = -math.inf
        self.fails = RollingFlagWindow(cfg.window)
        self._probe_rids: set[int] = set()
        self._probe_ok = 0
        self._probes_sent = 0
        self.admitted = 0
        self.door_shed = 0
        self.opens = 0

    def _trip(self, t: float) -> None:
        self.state = "open"
        self.open_until = t + self.cfg.cooloff
        self.opens += 1
        self._probe_rids.clear()
        self._probe_ok = 0
        self._probes_sent = 0

    def offer(self, rid: int, t: float) -> float | None:
        """Offer a request at time `t` (seconds): returns the admission
        time (always `t`; the breaker never delays) or None = shed."""
        cfg = self.cfg
        if self.state == "closed":
            if (self.fails.count(t) >= cfg.min_samples
                    and self.fails.frac(t) >= cfg.fail_thresh):
                self._trip(t)
        if self.state == "open" and t >= self.open_until:
            self.state = "half_open"
        if self.state == "open":
            self.door_shed += 1
            return None
        if self.state == "half_open":
            if self._probes_sent >= cfg.probes:
                self.door_shed += 1  # probes outstanding: hold the door
                return None
            self._probes_sent += 1
            self._probe_rids.add(rid)
        self.admitted += 1
        return t

    def observe(self, rid: int, t: float, ok: bool) -> None:
        """Terminal outcome at time `t` (seconds); failures trip the
        breaker, successful probes close it."""
        if self.state == "half_open" and rid in self._probe_rids:
            self._probe_rids.discard(rid)
            if not ok:
                self._trip(t)
                return
            self._probe_ok += 1
            if self._probe_ok >= self.cfg.probes:
                self.state = "closed"
                self.fails = RollingFlagWindow(self.cfg.window)
            return
        if self.state == "closed":
            self.fails.add(t, not ok)

    def stats(self) -> dict:
        """Door counters (requests) plus breaker opens and current state."""
        return {"policy": "breaker", "door_admitted": self.admitted,
                "door_delayed": 0, "door_shed": self.door_shed,
                "breaker_opens": self.opens, "breaker_state": self.state}


def make_admission(cfg: AdmissionConfig):
    """Build the runtime front door for a validated `AdmissionConfig`."""
    if cfg.policy == "token_bucket":
        return TokenBucket(cfg)
    return CircuitBreaker(cfg)
