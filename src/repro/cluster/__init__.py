"""repro.cluster — multi-replica serving cluster simulator.

The layer above `repro.sim`: where one `ReplicaSim` prices a single device
group's engine iterations, this package co-simulates N of them under a
shared arrival stream and answers the questions production serving is
actually planned against:

  * `router`  — pluggable dispatch policies (round-robin, join-shortest-
    queue, least-KV-load, and session/prefix affinity with a modeled
    prefill-cache hit discount).
  * `cluster` — colocated (data-parallel `mixed` replicas) vs
    disaggregated (`prefill` pools handing KV to `decode` pools over a
    `comm.p2p`-priced transfer sized by §3.5's cache formula), with
    heterogeneous per-replica hardware and scheduler configs.
  * `prefixcache` — the modeled prefix cache behind affinity routing:
    per-replica finite byte budgets carved out of KV capacity, LRU + TTL
    eviction, token-granular prefix groups shared across sessions, and
    drain/retire invalidation. `ClusterSpec.prefix_cache` switches the
    affinity discount from unconditional `hit_frac` to actual residency
    (`PrefixCacheConfig(budget_bytes=math.inf)` reproduces the legacy
    behavior bit-for-bit).
  * `planner` — SLO-driven capacity planning: sweep replica count / pool
    split at a target QPS, price candidates in $/hr, return the cheapest
    plan whose SLO attainment clears the bar; `provisioning_summary`
    prices a dynamic fleet's replica-hours against static peak
    provisioning.
  * `autoscale` — reactive (arrival rate, SLO debt, admission wait, KV +
    TPOT pressure) and predictive (M/G/1 wait estimate over the known
    rate-envelope lookahead) replica add/remove with weight-load warmup,
    graceful drain, and min/max bounds, driving
    `simulate_cluster(..., autoscale=)` under diurnal/bursty traces —
    fleet-wide, or per-pool for disaggregated clusters
    (`autoscale={"prefill": ..., "decode": ...}`).
  * `chaos` — seeded fault injection (replica crashes with KV loss and
    prefix-cache restore, stragglers, KV-link degradation, correlated
    node failures) plus the admission front door (token bucket / circuit
    breaker) that sheds overload BEFORE dispatch; `ClusterSpec.chaos` /
    `ClusterSpec.admission` thread both through the engine, and
    `plan_capacity(..., loss_tolerance=N)` sizes fleets that survive
    N-replica loss.

CLI:

    PYTHONPATH=src python -m repro.cluster --config qwen3_14b --hw h100 \\
        --replicas 4 --qps 32

prints cluster- and pool-level TTFT/TPOT/goodput for the colocated and
disaggregated organizations of the same fleet; `--plan` runs the capacity
sweep instead. `python -m benchmarks.run cluster` emits CSV rows.
"""

from repro.cluster.autoscale import (
    AUTOSCALE_POLICIES,
    AutoscaleConfig,
    Autoscaler,
)
from repro.cluster.chaos import (
    ADMISSION_POLICIES,
    CHAOS_KINDS,
    AdmissionConfig,
    ChaosConfig,
    ChaosEvent,
)
from repro.cluster.cluster import (
    POOLS,
    ClusterResult,
    ClusterSpec,
    ReplicaSpec,
    pool_summaries,
    simulate_cluster,
    summarize_cluster,
)
from repro.cluster.prefixcache import (
    FleetPrefixCache,
    PrefixCacheConfig,
    ReplicaPrefixCache,
)
from repro.cluster.planner import (
    DEFAULT_PRICE_PER_DEV_HR,
    cluster_price_per_hr,
    plan_capacity,
    provisioning_summary,
    replica_price_per_hr,
    seed_predictive,
)
from repro.cluster.router import ROUTERS, ReplicaView, Router, make_router

__all__ = [
    "ADMISSION_POLICIES",
    "AUTOSCALE_POLICIES",
    "AdmissionConfig",
    "AutoscaleConfig",
    "Autoscaler",
    "CHAOS_KINDS",
    "ChaosConfig",
    "ChaosEvent",
    "ClusterResult",
    "ClusterSpec",
    "DEFAULT_PRICE_PER_DEV_HR",
    "FleetPrefixCache",
    "POOLS",
    "PrefixCacheConfig",
    "ROUTERS",
    "ReplicaPrefixCache",
    "ReplicaSpec",
    "ReplicaView",
    "Router",
    "cluster_price_per_hr",
    "make_router",
    "plan_capacity",
    "pool_summaries",
    "provisioning_summary",
    "replica_price_per_hr",
    "seed_predictive",
    "simulate_cluster",
    "summarize_cluster",
]
