"""SLO-driven capacity planner.

Sweeps cluster size (and, for disaggregated clusters, the prefill/decode
pool split) at a target arrival rate, prices every candidate with a
$/device-hour table, and returns the cheapest configuration whose SLO
attainment (fraction of requests meeting BOTH the TTFT and TPOT SLOs —
`goodput_frac`) clears the target. This is the cluster-level question the
paper's per-device-group model (§4.3) exists to inform: how much hardware,
and in what organization, a latency target actually costs.

Prices are public on-demand list-price ballparks (documented assumptions,
overridable via `price_table`); what matters for plan *ranking* is their
ratio, not their absolute level.
"""

from __future__ import annotations

import os
from concurrent.futures import ProcessPoolExecutor
from dataclasses import replace

from repro.configs.base import ModelConfig
from repro.sim.scheduler import SchedConfig
from repro.sim.workload import SimRequest, Workload

from repro.cluster.autoscale import AutoscaleConfig
from repro.cluster.cluster import (
    ClusterSpec,
    ReplicaSpec,
    peak_over_spans,
    simulate_cluster,
    summarize_cluster,
)
from repro.cluster.prefixcache import PrefixCacheConfig

# $/device-hour, on-demand cloud ballparks (ranking inputs, not quotes)
DEFAULT_PRICE_PER_DEV_HR = {
    "a100": 1.8,
    "a100-80g": 1.8,
    "h100": 3.9,
    "h100-sxm": 3.9,
    "h200": 4.5,
    "b200": 6.9,
    "tpu-v5e": 1.2,
    "v5e": 1.2,
}


def replica_price_per_hr(rs: ReplicaSpec, table: dict | None = None) -> float:
    """$/hour to run one replica: the per-device price of its hardware
    times its tensor-parallel device count."""
    table = table or DEFAULT_PRICE_PER_DEV_HR
    name = (rs.hw if isinstance(rs.hw, str) else rs.hw.name).lower()
    if name not in table:
        raise ValueError(
            f"no $/hr price for hardware {name!r}; pass price_table= "
            f"(known: {sorted(table)})")
    return table[name] * rs.tp


def cluster_price_per_hr(spec: ClusterSpec, table: dict | None = None) -> float:
    """$/hour for the whole (static) fleet: sum of its replica prices."""
    return sum(replica_price_per_hr(rs, table) for rs in spec.replicas)


def provisioning_summary(cres, table: dict | None = None, *,
                         shed_cost_usd: float = 0.0) -> dict:
    """Price a (possibly dynamic) cluster run's actual provisioning against
    static peak provisioning of the same trace.

    `replica_hours` bills each replica for its provisioned span (warmup and
    drain tails included); the static-peak counterfactual runs the maximum
    concurrently-provisioned fleet for the whole trace span (`cres.span`,
    origin to the last replica going quiet — the same window the real
    spans are billed over and the same frame an exported trace renders,
    reported back as `t0`/`horizon`) — what you'd have to deploy without
    an autoscaler to survive the trace's peak. The savings fraction is
    the autoscaling headline number on diurnal traces.

    Args:
        cres: a `ClusterResult`.
        table: $/device-hour price table (default
            `DEFAULT_PRICE_PER_DEV_HR`).
        shed_cost_usd: $ each dropped request costs (lost revenue / SLA
            credit). Nonzero makes the shedding-vs-overprovisioning trade
            explicit: a fleet that sheds its way to cheap replica-hours
            pays for it in `shed_cost_usd`, and `cost_usd_total` ranks the
            two honestly.

    Returns dict keys (all costs in $, hours in replica-hours):
        replica_hours / replica_hours_static_peak, cost_usd /
        cost_usd_static_peak, savings_frac, peak_replicas,
        shed / shed_cost_usd / cost_usd_total, and `pools` — a per-pool
        breakdown {pool: {replica_hours, cost_usd, peak_replicas}} so
        pool-aware autoscaling bills prefill and decode separately."""
    prices = [replica_price_per_hr(rs, table) for rs in cres.replica_specs]
    span = cres.span
    cost = sum(p * (e - s) / 3600.0
               for p, (s, e) in zip(prices, cres.replica_spans))
    # static peak $: the max concurrent price rate, held for the whole span
    static_cost = peak_over_spans(cres.replica_spans, prices) * span / 3600.0
    shed_cost = len(cres.shed) * shed_cost_usd
    pools: dict = {}
    for pool in dict.fromkeys(cres.replica_pools):  # stable order
        idxs = [i for i, p in enumerate(cres.replica_pools) if p == pool]
        spans = [cres.replica_spans[i] for i in idxs]
        pools[pool] = {
            "replica_hours": sum(e - s for s, e in spans) / 3600.0,
            "cost_usd": sum(prices[i] * (e - s) / 3600.0
                            for i, (s, e) in zip(idxs, spans)),
            "peak_replicas": int(peak_over_spans(spans)),
        }
    return {
        "replica_hours": cres.replica_hours,
        "replica_hours_static_peak": cres.replica_hours_static_peak,
        "cost_usd": cost,
        "cost_usd_static_peak": static_cost,
        "savings_frac": 1.0 - cost / static_cost if static_cost > 0 else 0.0,
        "peak_replicas": cres.peak_replicas,
        "shed": len(cres.shed),
        "shed_cost_usd": shed_cost,
        "cost_usd_total": cost + shed_cost,
        "t0": cres.t0,
        "horizon": cres.horizon if cres.horizon > cres.t0 else cres.t0 + span,
        "pools": pools,
    }


def seed_predictive(asc: AutoscaleConfig, workload: Workload,
                    requests: list[SimRequest] | None = None
                    ) -> AutoscaleConfig:
    """Seed the predictive policy's envelope and traffic shape from a
    workload spec — the planner-side bridge between what the generator
    KNOWS it will offer and what the control loop provisions for.

    Returns a copy of `asc` with `policy="predictive"`,
    `envelope=workload.peak_rate` (the diurnal closed form or the JSONL
    replay's piecewise-linear lookahead), and `mean_prompt`/`mean_output`
    (tokens) taken from the generated `requests` when given (exact, and
    the only option for trace replays) or from the spec's length
    distributions otherwise."""
    if requests:
        mean_prompt = sum(r.prompt for r in requests) / len(requests)
        mean_output = sum(r.output for r in requests) / len(requests)
    else:
        mean_prompt, mean_output = workload.prompt.mean, workload.output.mean
    return replace(asc, policy="predictive", envelope=workload.peak_rate,
                   mean_prompt=float(mean_prompt),
                   mean_output=float(mean_output))


# ------------------------------------------------------------ sweep internals
# Candidate evaluation lives at module level (not in closures) so the same
# code runs serially and inside `ProcessPoolExecutor` workers. Workers
# receive the sweep context once via the pool initializer (fork + one
# pickle per worker, not one per task) and keep their own cost-model /
# goodput memos — memo hits then land per worker instead of globally,
# which costs some duplicated loss-tolerance evaluations but changes no
# row (every simulation is deterministic in its inputs).
_PLAN_CTX: dict | None = None


def _plan_init(ctx: dict) -> None:
    global _PLAN_CTX
    ctx = dict(ctx)
    ctx["cost_cache"] = {}
    ctx["goodput_memo"] = {}
    _PLAN_CTX = ctx


def _plan_spec(ctx: dict, mode: str, n_prefill: int, n_decode: int,
               pc: PrefixCacheConfig | None) -> ClusterSpec:
    n = n_prefill + n_decode
    pools = (["mixed"] * n if mode == "colocated"
             else ["prefill"] * n_prefill + ["decode"] * n_decode)
    replicas = tuple(
        ReplicaSpec(hw=ctx["hw"], tp=ctx["tp"], prec=ctx["prec"], pool=pool,
                    sched=ctx["sched"], ctx_quantum=ctx["ctx_quantum"],
                    kv_block_tokens=ctx["kv_block_tokens"])
        for pool in pools)
    return ClusterSpec(replicas=replicas, router=ctx["router"],
                       decode_router=ctx["decode_router"],
                       hit_frac=ctx["hit_frac"], prefix_cache=pc)


def _plan_goodput(ctx: dict, mode: str, n_prefill: int, n_decode: int,
                  pc: PrefixCacheConfig | None) -> float:
    """Goodput of one (reduced) fleet on the shared stream, memoized:
    many candidates share the same surviving-fleet evaluations."""
    memo = ctx["goodput_memo"]
    key = (mode, n_prefill, n_decode, pc)
    if key not in memo:
        try:
            cres = simulate_cluster(ctx["reqs"], ctx["cfg"],
                                    _plan_spec(ctx, mode, n_prefill,
                                               n_decode, pc),
                                    engine=ctx["engine"],
                                    _cost_cache=ctx["cost_cache"])
            s = summarize_cluster(cres, slo_ttft=ctx["slo_ttft"],
                                  slo_tpot=ctx["slo_tpot"])
            memo[key] = s["goodput_frac"]
        except ValueError:
            memo[key] = 0.0
    return memo[key]


def _plan_loss_goodput(ctx: dict, mode: str, n_prefill: int, n_decode: int,
                       pc: PrefixCacheConfig | None) -> float:
    """Worst-case goodput after losing `loss_tolerance` replicas."""
    n_loss = ctx["loss_tolerance"]
    if mode == "colocated":
        if n_decode - n_loss < 1:
            return 0.0  # the loss empties the fleet
        return _plan_goodput(ctx, mode, 0, n_decode - n_loss, pc)
    if n_prefill <= n_loss or n_decode <= n_loss:
        return 0.0  # the adversary can empty one pool outright
    return min(_plan_goodput(ctx, mode, n_prefill - dp,
                             n_decode - (n_loss - dp), pc)
               for dp in range(n_loss + 1))


def _plan_candidate(ctx: dict, mode: str, n_prefill: int, n_decode: int,
                    pc: PrefixCacheConfig | None) -> dict:
    n = n_prefill + n_decode
    spec = _plan_spec(ctx, mode, n_prefill, n_decode, pc)
    row = {"mode": mode, "replicas": n,
           "prefill": n_prefill if mode == "disaggregated" else 0,
           "decode": n_decode if mode == "disaggregated" else 0,
           "cache_frac": (None if pc is None or pc.budget_bytes is not None
                          else pc.budget_frac),
           "cost_per_hr": cluster_price_per_hr(spec, ctx["price_table"])}
    try:
        cres = simulate_cluster(ctx["reqs"], ctx["cfg"], spec,
                                engine=ctx["engine"],
                                _cost_cache=ctx["cost_cache"])
    except ValueError as e:  # e.g. model KV footprint exceeds a pool budget
        row.update(feasible=False, error=str(e), goodput_frac=0.0)
        return row
    s = summarize_cluster(cres, slo_ttft=ctx["slo_ttft"],
                          slo_tpot=ctx["slo_tpot"])
    row.update(
        goodput_frac=s["goodput_frac"], goodput_rps=s["goodput_rps"],
        ttft_p95=s["ttft_p95"], tpot_p95=s["tpot_p95"],
        tokens_per_s=s["tokens_per_s"], xfer_share=s["xfer_share"],
        preemptions=s["preemptions"],
        util_mean=sum(s["replica_util"]) / len(s["replica_util"]),
        feasible=s["goodput_frac"] >= ctx["attainment"])
    if cres.cache_stats is not None:
        row["cache_hit_tokens"] = s["cache_hit_tokens"]
        row["cache_evictions"] = s["cache_evictions"]
    if ctx["loss_tolerance"] > 0:
        gl = _plan_loss_goodput(ctx, mode, n_prefill, n_decode, pc)
        row["goodput_frac_loss"] = gl
        row["feasible"] = row["feasible"] and gl >= ctx["attainment"]
    return row


def _plan_eval(task: tuple) -> dict:
    return _plan_candidate(_PLAN_CTX, *task)


def _plan_pool(ctx: dict, workers: int) -> ProcessPoolExecutor | None:
    """Fork-based worker pool, or None when unavailable (serial fallback).
    Fork is required so workers inherit the imported modules cheaply; the
    context is shipped once per worker through the initializer."""
    try:
        import multiprocessing as mp
        return ProcessPoolExecutor(max_workers=workers,
                                   mp_context=mp.get_context("fork"),
                                   initializer=_plan_init, initargs=(ctx,))
    except (ValueError, OSError):
        return None


def plan_capacity(cfg: ModelConfig, workload: Workload, *, qps: float,
                  slo_ttft: float, slo_tpot: float, attainment: float = 0.95,
                  hw: str = "h100", tp: int = 1, prec: int = 2,
                  sched: SchedConfig | None = None, router: str = "jsq",
                  decode_router: str = "least_kv", hit_frac: float = 0.5,
                  kv_block_tokens: int = 0, ctx_quantum: int = 16,
                  min_replicas: int = 1, max_replicas: int = 8,
                  modes=("colocated", "disaggregated"),
                  price_table: dict | None = None,
                  prefix_cache: PrefixCacheConfig | None = None,
                  cache_fracs: tuple | None = None,
                  cache_ttl: float | None = None,
                  early_stop: bool = True,
                  loss_tolerance: int = 0,
                  engine: str = "vectorized",
                  sweep_workers: int = 0) -> dict:
    """Sweep replica count / pool split at `qps`; return {"rows", "best"}.

    Every candidate serves the SAME request stream (`workload` regenerated
    at the target rate), so rows are comparable point-for-point. A row is
    feasible when its `goodput_frac >= attainment`. With `early_stop`,
    each mode stops growing the cluster once a feasible size is found —
    larger clusters of the same hardware only cost more.

    The prefix-cache budget share is a CAPACITY DIMENSION of the sweep:
    pass `cache_fracs=(0.05, 0.1, 0.2)` and every topology is evaluated
    once per budget share (`PrefixCacheConfig(budget_frac=f,
    ttl=cache_ttl)`), with `cache_frac` recorded on the row — more cache
    means more prefill skipped but less KV for live sequences, and the
    sweep finds where that trade clears the SLO cheapest. Alternatively
    `prefix_cache=` fixes one explicit config for all candidates; the
    default (both None) keeps the legacy unconditional-discount model.

    `loss_tolerance=N` sizes for FAILURE instead of steady state: a
    candidate is feasible only if, additionally, every fleet obtainable
    by removing N replicas (the worst case over prefill/decode split
    assignments for disaggregated fleets — an adversary kills where it
    hurts most) still clears `attainment` on the same stream. A pool the
    adversary can empty outright scores 0. The surviving-fleet goodput
    lands on the row as `goodput_frac_loss` — the resilience margin the
    chaos engine's correlated `node_failure` events then stress-test.

    `engine` selects the replica-simulation core for every candidate run
    (see `simulate_cluster`). `sweep_workers` > 1 evaluates each fleet
    size's candidate batch in parallel OS processes (fork; `-1` means all
    cores): rows, their order, and `early_stop` behavior are identical to
    the serial sweep — per-`n` batches are the early-stop granularity in
    both — because every candidate simulation is deterministic."""
    if loss_tolerance < 0:
        raise ValueError("loss_tolerance must be >= 0")
    sched = sched or SchedConfig()
    reqs = replace(workload, qps=qps).generate()
    rows: list[dict] = []
    if cache_fracs:  # empty/None both fall back to the single-config path
        cache_cfgs = [PrefixCacheConfig(budget_frac=float(f), ttl=cache_ttl)
                      for f in cache_fracs]
    else:
        cache_cfgs = [prefix_cache]  # may be None: legacy model

    base_ctx = dict(cfg=cfg, reqs=reqs, sched=sched, hw=hw, tp=tp, prec=prec,
                    router=router, decode_router=decode_router,
                    hit_frac=hit_frac, kv_block_tokens=kv_block_tokens,
                    ctx_quantum=ctx_quantum, slo_ttft=slo_ttft,
                    slo_tpot=slo_tpot, attainment=attainment,
                    price_table=price_table, loss_tolerance=loss_tolerance,
                    engine=engine)
    ctx = dict(base_ctx, cost_cache={}, goodput_memo={})
    workers = os.cpu_count() or 1 if sweep_workers < 0 else sweep_workers
    pool = _plan_pool(base_ctx, workers) if workers > 1 else None
    try:
        for mode in modes:
            lo = max(min_replicas, 2) if mode == "disaggregated" else min_replicas
            for n in range(lo, max_replicas + 1):
                splits = ([(p, n - p) for p in range(1, n)]
                          if mode == "disaggregated" else [(0, n)])
                tasks = [(mode, n_p, n_d, pc)
                         for n_p, n_d in splits for pc in cache_cfgs]
                if pool is not None:
                    batch = list(pool.map(_plan_eval, tasks))
                else:
                    batch = [_plan_candidate(ctx, *t) for t in tasks]
                rows.extend(batch)
                if early_stop and any(r["feasible"] for r in batch):
                    break
    finally:
        if pool is not None:
            pool.shutdown()

    feasible = [r for r in rows if r["feasible"]]
    best = min(feasible, key=lambda r: (r["cost_per_hr"], -r["goodput_frac"]),
               default=None)
    return {"rows": rows, "best": best, "qps": qps, "attainment": attainment,
            "loss_tolerance": loss_tolerance}
