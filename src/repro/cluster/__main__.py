"""CLI: simulate a multi-replica serving cluster under load.

    PYTHONPATH=src python -m repro.cluster --config qwen3_14b --hw h100 \\
        --replicas 4 --qps 32

Runs the same fleet as a colocated (data-parallel) cluster and as a
disaggregated prefill/decode cluster, printing cluster- and pool-level
TTFT/TPOT/goodput/SLO-attainment plus the KV-transfer overhead of the
disaggregated organization. `--hw` accepts a comma-separated list cycled
across replicas for heterogeneous fleets; `--plan` runs the SLO-driven
capacity sweep instead of a fixed-size comparison; `--autoscale` makes
the fleet dynamic (replica add/remove with warmup and graceful drain —
pair with `--arrival diurnal` and `--max-replicas`), reporting
replica-hours against static peak provisioning.
`--autoscale-policy predictive` provisions ahead of the known rate
envelope through an M/G/1 wait estimate (scale-ups lead the ramp by the
warmup); `--pool-autoscale` scales a disaggregated fleet's prefill and
decode pools independently on their own signals (admission wait vs
KV + TPOT pressure) instead of the template ratio.

`--prefix-cache` replaces the affinity router's unconditional `hit_frac`
discount with a modeled per-replica prefix cache: a finite byte budget
(`--cache-frac` of KV capacity, carved out of it, or `--cache-gb`
absolute), LRU + TTL eviction (`--cache-ttl`), and cross-session sharing
of the workload's prefix groups (`--prefix-groups`/`--prefix-len`
generate multi-tenant system prompts). `--plan-cache-fracs` sweeps the
budget share as a capacity dimension of `--plan`.

`--trace out.json` records the run: request lifecycle spans, per-replica
counter timelines, and explainable autoscale decisions, exported by
suffix (.json = Chrome trace-event for Perfetto, .jsonl = event log for
`python -m repro.obs report`, .csv = windowed time series); verbosity via
`--trace-level`, per-iteration counter downsampling via
`--trace-counter-dt`. With `--mode both` the mode is suffixed into the
filename (out.colocated.json, out.disaggregated.json).

`--chaos-crashes/--chaos-stragglers/--chaos-links/--chaos-nodes R` inject
seeded faults at rate R events/s over `--chaos-horizon` (crashes lose
in-flight KV; displaced requests re-prefill or restore from a surviving
replica's prefix cache), with `--chaos-node-group` replicas killed per
correlated node failure; the summary gains requests-lost, re-prefill,
and recovery-time columns. `--admission-policy token_bucket|breaker`
puts an overload front door ahead of dispatch (`--admission-rate/
--admission-burst/--admission-queue` for GCRA, `--breaker-*` for the
circuit breaker). `--retry-backoff/--retry-jitter` shape the seeded
exponential shed-retry backoff; `--spare` holds N+k redundancy above the
autoscale policy's ask; `--plan-loss N` makes `--plan` size fleets that
still clear the attainment bar after losing N replicas.

`--slo-window W` turns on the live SLO monitor: TTFT p99 <= `--slo-ttft`
and (if given) goodput >= `--slo-goodput`, judged over tumbling
W-second windows at sim time, with SRE-style fast/slow burn-rate alerts
and EWMA anomaly detection; `alert.*`/`anomaly.*`/`slo.window` instants
land in the trace and the summary gains time-in-violation, alerts-fired,
and budget-burn columns.
"""

from __future__ import annotations

import argparse
import os
from dataclasses import replace

from repro.configs import get_config
from repro.obs import LEVELS, SLOMonitor, make_slos, make_tracer, write_trace
from repro.sim import ADMISSIONS, ENGINES, LengthDist, SchedConfig, Workload
from repro.cluster import (
    ADMISSION_POLICIES,
    AUTOSCALE_POLICIES,
    ROUTERS,
    AdmissionConfig,
    AutoscaleConfig,
    ChaosConfig,
    ClusterSpec,
    PrefixCacheConfig,
    ReplicaSpec,
    cluster_price_per_hr,
    plan_capacity,
    pool_summaries,
    provisioning_summary,
    seed_predictive,
    simulate_cluster,
    summarize_cluster,
)


def build_parser() -> argparse.ArgumentParser:
    """Argparse parser for `python -m repro.cluster` (qps = requests/s,
    latency SLOs in seconds, prices in $/hr)."""
    p = argparse.ArgumentParser(prog="python -m repro.cluster", description=__doc__)
    p.add_argument("--config", default="qwen3_14b", help="model config id")
    p.add_argument("--hw", default="h100",
                   help="hardware target(s); comma-separated list cycles "
                        "across replicas for heterogeneous fleets")
    p.add_argument("--tp", type=int, default=1)
    p.add_argument("--prec", type=int, default=2)
    p.add_argument("--replicas", type=int, default=4)
    p.add_argument("--prefill-replicas", type=int, default=None,
                   help="disaggregated pool split (default: replicas // 2)")
    p.add_argument("--mode", default="both",
                   choices=["both", "colocated", "disaggregated"])
    p.add_argument("--router", default="jsq", choices=list(ROUTERS))
    p.add_argument("--decode-router", default="least_kv", choices=list(ROUTERS))
    p.add_argument("--hit-frac", type=float, default=0.5,
                   help="affinity router's prefix-cache discount")
    p.add_argument("--policy", default="continuous",
                   choices=["static", "continuous", "chunked"])
    p.add_argument("--slots", type=int, default=16)
    p.add_argument("--token-budget", type=int, default=512)
    p.add_argument("--admission", default="fcfs", choices=list(ADMISSIONS))
    p.add_argument("--block-tokens", type=int, default=0,
                   help="paged-KV page size in tokens (0 = contiguous)")
    p.add_argument("--qps", type=float, default=32.0)
    p.add_argument("--requests", type=int, default=200)
    p.add_argument("--arrival", default="poisson",
                   choices=["constant", "poisson", "bursty", "diurnal",
                            "envelope"])
    p.add_argument("--diurnal-period", type=float, default=240.0,
                   help="seconds per compressed day (--arrival diurnal)")
    p.add_argument("--diurnal-amp", type=float, default=0.8,
                   help="relative rate swing in [0, 1] (--arrival diurnal)")
    p.add_argument("--rate-path", default=None,
                   help="JSONL rate envelope {t, qps} (--arrival envelope)")
    p.add_argument("--prompt-dist", default="lognormal", choices=["fixed", "lognormal"])
    p.add_argument("--prompt-mean", type=float, default=512)
    p.add_argument("--prompt-sigma", type=float, default=0.4)
    p.add_argument("--output-dist", default="lognormal", choices=["fixed", "lognormal"])
    p.add_argument("--output-mean", type=float, default=128)
    p.add_argument("--output-sigma", type=float, default=0.4)
    p.add_argument("--sessions", type=int, default=0,
                   help="session count for affinity routing (0 = none)")
    p.add_argument("--prefix-groups", type=int, default=0,
                   help="shared-prefix groups (multi-tenant system prompts) "
                        "in the workload (0 = none)")
    p.add_argument("--prefix-len", type=float, default=256,
                   help="tokens per shared group prefix (--prefix-groups)")
    p.add_argument("--replay", default=None,
                   help="JSONL workload trace to replay instead of the "
                        "synthetic generator")
    p.add_argument("--trace", default=None,
                   help="record the run to this path: .json = Chrome "
                        "trace-event (Perfetto), .jsonl = event log "
                        "(repro.obs report), .csv = windowed time series")
    p.add_argument("--trace-level", default="request", choices=list(LEVELS),
                   help="trace verbosity ceiling (with --trace): summary = "
                        "scaling/shed events, replica = + per-replica spans "
                        "and counters, request = + per-request lifecycle")
    p.add_argument("--trace-counter-dt", type=float, default=0.0,
                   help="minimum seconds between per-(track, series) counter "
                        "samples (0 = every iteration); trims trace size on "
                        "long runs")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--slo-ttft", type=float, default=2.0, help="seconds")
    p.add_argument("--slo-tpot", type=float, default=0.05, help="seconds/token")
    p.add_argument("--slo-goodput", type=float, default=None,
                   help="live-monitor goodput objective as a fraction (e.g. "
                        "0.99); needs --slo-window")
    p.add_argument("--slo-window", type=float, default=None,
                   help="enable the live SLO monitor: tumbling compliance "
                        "window in seconds for TTFT p99 <= --slo-ttft (and "
                        "goodput >= --slo-goodput if set), with burn-rate "
                        "alerts and anomaly detection")
    p.add_argument("--ctx-quantum", type=int, default=16)
    # modeled prefix cache (default: legacy unconditional affinity discount)
    p.add_argument("--prefix-cache", action="store_true",
                   help="model the prefix cache: finite per-replica budget, "
                        "LRU+TTL eviction, cross-session prefix sharing")
    p.add_argument("--cache-frac", type=float, default=0.1,
                   help="prefix-cache budget as a fraction of replica KV "
                        "capacity (carved out of it)")
    p.add_argument("--cache-gb", type=float, default=None,
                   help="absolute prefix-cache budget in GB (overrides "
                        "--cache-frac; 'inf' = legacy free-infinite cache)")
    p.add_argument("--cache-ttl", type=float, default=None,
                   help="prefix-cache entry TTL in idle seconds (default: "
                        "no expiry)")
    p.add_argument("--plan", action="store_true",
                   help="run the SLO-driven capacity sweep instead")
    p.add_argument("--plan-max-replicas", type=int, default=6)
    p.add_argument("--plan-cache-fracs", default=None,
                   help="comma-separated cache budget shares to sweep as a "
                        "capacity dimension of --plan (e.g. 0.05,0.1,0.2)")
    p.add_argument("--sweep-workers", type=int, default=0,
                   help="--plan: evaluate each fleet size's candidates in "
                        "this many parallel processes (-1 = all cores, "
                        "0/1 = serial; identical rows either way)")
    p.add_argument("--engine", default="vectorized", choices=list(ENGINES),
                   help="simulation core: the vectorized fast path or the "
                        "reference event loop (identical results)")
    p.add_argument("--attainment", type=float, default=0.95)
    # dynamic fleet
    p.add_argument("--autoscale", action="store_true",
                   help="scale the fleet at runtime (--replicas = t=0 fleet)")
    p.add_argument("--autoscale-policy", default="rate",
                   choices=list(AUTOSCALE_POLICIES))
    p.add_argument("--min-replicas", type=int, default=1)
    p.add_argument("--max-replicas", type=int, default=8)
    p.add_argument("--scale-interval", type=float, default=5.0,
                   help="control-loop period (s)")
    p.add_argument("--scale-window", type=float, default=15.0,
                   help="rolling observation window (s)")
    p.add_argument("--target-qps", type=float, default=8.0,
                   help="rate policy: target qps per replica")
    p.add_argument("--warmup", type=float, default=None,
                   help="replica warmup (s); default prices weight loading")
    p.add_argument("--lookahead", type=float, default=None,
                   help="predictive policy: envelope horizon (s); "
                        "default warmup + interval")
    p.add_argument("--target-wait", type=float, default=None,
                   help="predictive policy: M/G/1 wait budget (s); "
                        "default slo_ttft / 2")
    p.add_argument("--pool-autoscale", action="store_true",
                   help="disaggregated only: scale prefill and decode "
                        "pools independently on their own signals")
    p.add_argument("--prefill-policy", default="queue_wait",
                   choices=list(AUTOSCALE_POLICIES),
                   help="pool-autoscale: prefill pool policy")
    p.add_argument("--decode-policy", default="kv_tpot",
                   choices=list(AUTOSCALE_POLICIES),
                   help="pool-autoscale: decode pool policy")
    p.add_argument("--shed-cost", type=float, default=0.0,
                   help="$ per dropped request in the provisioning summary")
    p.add_argument("--shed-depth", type=int, default=None,
                   help="shed arrivals when every replica's depth >= this")
    p.add_argument("--retry-after", type=float, default=0.5)
    p.add_argument("--max-retries", type=int, default=2)
    p.add_argument("--retry-backoff", type=float, default=2.0,
                   help="exponential shed-retry backoff base (1 = legacy "
                        "fixed delay)")
    p.add_argument("--retry-jitter", type=float, default=0.5,
                   help="seeded retry jitter amplitude (0 = none)")
    p.add_argument("--spare", type=int, default=0,
                   help="autoscale N+k redundancy: replicas held above the "
                        "policy's ask to absorb a crash")
    p.add_argument("--plan-loss", type=int, default=0,
                   help="--plan: require candidates to clear the attainment "
                        "bar even after losing this many replicas "
                        "(worst-case pool split)")
    # seeded fault injection (repro.cluster.chaos)
    p.add_argument("--chaos-seed", type=int, default=0)
    p.add_argument("--chaos-horizon", type=float, default=120.0,
                   help="seconds of simulated time chaos events are "
                        "sampled over")
    p.add_argument("--chaos-crashes", type=float, default=0.0,
                   help="replica crash rate (events/s; 0 = off)")
    p.add_argument("--chaos-stragglers", type=float, default=0.0,
                   help="straggler onset rate (events/s; 0 = off)")
    p.add_argument("--chaos-links", type=float, default=0.0,
                   help="KV-handoff link degradation rate (events/s)")
    p.add_argument("--chaos-nodes", type=float, default=0.0,
                   help="correlated node-failure rate (events/s)")
    p.add_argument("--chaos-node-group", type=int, default=2,
                   help="replicas killed per correlated node failure")
    # admission front door (evaluated before dispatch)
    p.add_argument("--admission-policy", default=None,
                   choices=list(ADMISSION_POLICIES),
                   help="overload front door ahead of dispatch "
                        "(default: none)")
    p.add_argument("--admission-rate", type=float, default=0.0,
                   help="token_bucket: sustained admits/s")
    p.add_argument("--admission-burst", type=int, default=1,
                   help="token_bucket: burst depth in requests")
    p.add_argument("--admission-queue", type=int, default=0,
                   help="token_bucket: door-queue slots beyond the bucket")
    p.add_argument("--breaker-threshold", type=float, default=0.5,
                   help="breaker: rolling failure fraction that trips OPEN")
    p.add_argument("--breaker-window", type=float, default=10.0,
                   help="breaker: rolling terminal-outcome window (s)")
    p.add_argument("--breaker-cooloff", type=float, default=5.0,
                   help="breaker: OPEN hold before HALF_OPEN probing (s)")
    p.add_argument("--breaker-probes", type=int, default=3,
                   help="breaker: HALF_OPEN trial admissions")
    return p


def _replicas(args, n: int, pools: list[str]) -> tuple[ReplicaSpec, ...]:
    hws = [h.strip() for h in args.hw.split(",") if h.strip()]
    sched = SchedConfig(policy=args.policy, slots=args.slots,
                        token_budget=args.token_budget,
                        admission=args.admission, slo_ttft=args.slo_ttft)
    return tuple(
        ReplicaSpec(hw=hws[i % len(hws)], tp=args.tp, prec=args.prec,
                    pool=pools[i], sched=sched, ctx_quantum=args.ctx_quantum,
                    kv_block_tokens=args.block_tokens)
        for i in range(n))


def _fmt_row(label: str, s: dict, extra: str = "") -> str:
    return (f"{label:<14} "
            f"{s['ttft_p50']:>6.2f}/{s['ttft_p95']:.2f}  "
            f"{s['tpot_p50'] * 1e3:>6.1f}/{s['tpot_p95'] * 1e3:.1f}  "
            f"{s['e2e_p95']:>7.2f}  {s['tokens_per_s']:>7.0f} "
            f"{s['goodput_frac']:>7.0%}{extra}")


def main(argv=None) -> None:
    """Simulate (or `--plan`) the configured fleet and print per-pool
    latency (seconds) / goodput / $-per-hour summaries."""
    args = build_parser().parse_args(argv)
    cfg = get_config(args.config)
    wl = Workload(
        name=args.replay or "synthetic", qps=args.qps, num_requests=args.requests,
        arrival=args.arrival,
        prompt=LengthDist(args.prompt_dist, args.prompt_mean, args.prompt_sigma),
        output=LengthDist(args.output_dist, args.output_mean, args.output_sigma),
        seed=args.seed, trace_path=args.replay, num_sessions=args.sessions,
        diurnal_period=args.diurnal_period, diurnal_amp=args.diurnal_amp,
        rate_path=args.rate_path, num_prefix_groups=args.prefix_groups,
        prefix=LengthDist("fixed", args.prefix_len))
    reqs = wl.generate()
    pcache = None
    if args.prefix_cache:
        pcache = PrefixCacheConfig(
            budget_frac=args.cache_frac,
            budget_bytes=args.cache_gb * 1e9 if args.cache_gb is not None
            else None,
            ttl=args.cache_ttl)
    chaos = None
    if any(r > 0 for r in (args.chaos_crashes, args.chaos_stragglers,
                           args.chaos_links, args.chaos_nodes)):
        chaos = ChaosConfig(
            seed=args.chaos_seed, horizon=args.chaos_horizon,
            crash_rate=args.chaos_crashes,
            straggler_rate=args.chaos_stragglers,
            link_rate=args.chaos_links,
            node_failure_rate=args.chaos_nodes,
            node_group=args.chaos_node_group)
    admission = None
    if args.admission_policy is not None:
        admission = AdmissionConfig(
            policy=args.admission_policy, rate=args.admission_rate,
            burst=args.admission_burst, queue_depth=args.admission_queue,
            window=args.breaker_window, fail_thresh=args.breaker_threshold,
            cooloff=args.breaker_cooloff, probes=args.breaker_probes)
    autoscale = None
    if args.autoscale or args.pool_autoscale:
        base = AutoscaleConfig(
            policy=args.autoscale_policy, min_replicas=args.min_replicas,
            max_replicas=args.max_replicas, interval=args.scale_interval,
            window=args.scale_window, target_qps_per_replica=args.target_qps,
            slo_ttft=args.slo_ttft, slo_tpot=args.slo_tpot,
            warmup=args.warmup, lookahead=args.lookahead,
            target_wait=args.target_wait, spare=args.spare)

        def _pool_cfg(policy: str) -> AutoscaleConfig:
            asc = replace(base, policy=policy)
            # the predictive policy needs the generator's rate envelope
            # and traffic shape; reactive policies are self-contained
            return seed_predictive(asc, wl, reqs) if policy == "predictive" \
                else asc

        if args.pool_autoscale:
            if args.mode != "disaggregated":
                raise SystemExit(
                    "--pool-autoscale scales prefill/decode pools "
                    "independently; pair it with --mode disaggregated")
            autoscale = {"prefill": _pool_cfg(args.prefill_policy),
                         "decode": _pool_cfg(args.decode_policy)}
        else:
            autoscale = _pool_cfg(args.autoscale_policy)

    if args.plan:
        if args.trace:
            print("# note: --trace records single runs; the --plan sweep "
                  "is untraced")
        hws = [h.strip() for h in args.hw.split(",") if h.strip()]
        if len(hws) > 1:
            print(f"# note: --plan sweeps homogeneous fleets; using {hws[0]!r} "
                  f"(ignoring {', '.join(hws[1:])})")
        if autoscale is not None or args.shed_depth is not None \
                or chaos is not None or admission is not None:
            print("# note: --plan sizes STATIC fault-free fleets; "
                  "--autoscale/--shed-*/--chaos-*/--admission-* flags are "
                  "ignored by the sweep (drop --plan to run the dynamic "
                  "fleet; --plan-loss sizes for N-replica loss)")
        sched = SchedConfig(policy=args.policy, slots=args.slots,
                            token_budget=args.token_budget,
                            admission=args.admission, slo_ttft=args.slo_ttft)
        cache_fracs = None
        if args.plan_cache_fracs:
            cache_fracs = tuple(float(x) for x in
                                args.plan_cache_fracs.split(",") if x.strip())
        plan = plan_capacity(
            cfg, wl, qps=args.qps, slo_ttft=args.slo_ttft, slo_tpot=args.slo_tpot,
            attainment=args.attainment, hw=hws[0], tp=args.tp,
            prec=args.prec, sched=sched, router=args.router,
            decode_router=args.decode_router, hit_frac=args.hit_frac,
            kv_block_tokens=args.block_tokens, ctx_quantum=args.ctx_quantum,
            max_replicas=args.plan_max_replicas,
            prefix_cache=None if cache_fracs else pcache,
            cache_fracs=cache_fracs, cache_ttl=args.cache_ttl,
            loss_tolerance=args.plan_loss, engine=args.engine,
            sweep_workers=args.sweep_workers)
        print(f"# capacity plan: {cfg.name} @ {args.qps:g} qps, "
              f"SLO ttft<={args.slo_ttft:g}s tpot<={args.slo_tpot:g}s, "
              f"attainment>={args.attainment:.0%}"
              + (f", survives loss of {args.plan_loss}"
                 if args.plan_loss else ""))
        loss_col = f" {'-' + str(args.plan_loss) + 'rep':>7}" \
            if args.plan_loss else ""
        hdr = (f"{'mode':<14} {'repl':>4} {'P/D':>5} {'cache':>6} {'$/hr':>7} "
               f"{'attain':>7}{loss_col} {'ttft_p95':>9} {'tpot_p95':>9} "
               f"{'feasible':>9}")
        print(hdr)
        print("-" * len(hdr))
        for r in plan["rows"]:
            pd = (f"{r['prefill']}/{r['decode']}"
                  if r["mode"] == "disaggregated" else "-")
            cf = ("-" if r.get("cache_frac") is None
                  else f"{r['cache_frac']:.2f}")
            if "error" in r:
                print(f"{r['mode']:<14} {r['replicas']:>4} {pd:>5} {cf:>6} "
                      f"{r['cost_per_hr']:>7.2f} {'-':>7}"
                      + (f" {'-':>7}" if args.plan_loss else "")
                      + f" {'-':>9} {'-':>9} {'no (kv)':>9}")
                continue
            loss = (f" {r['goodput_frac_loss']:>7.0%}"
                    if args.plan_loss else "")
            print(f"{r['mode']:<14} {r['replicas']:>4} {pd:>5} {cf:>6} "
                  f"{r['cost_per_hr']:>7.2f} {r['goodput_frac']:>7.0%}{loss} "
                  f"{r['ttft_p95']:>8.2f}s {r['tpot_p95'] * 1e3:>7.1f}ms "
                  f"{'YES' if r['feasible'] else 'no':>9}")
        best = plan["best"]
        if best is None:
            print("# no feasible plan within the sweep — raise "
                  "--plan-max-replicas or relax the SLOs")
        else:
            pd = (f" ({best['prefill']}P/{best['decode']}D)"
                  if best["mode"] == "disaggregated" else "")
            cache = (f", cache={best['cache_frac']:.0%} of KV"
                     if best.get("cache_frac") is not None else "")
            print(f"# cheapest feasible: {best['mode']}{pd} x{best['replicas']} "
                  f"at ${best['cost_per_hr']:.2f}/hr "
                  f"({best['goodput_frac']:.0%} attainment{cache})")
        return

    modes = (["colocated", "disaggregated"] if args.mode == "both"
             else [args.mode])
    n = args.replicas
    n_p = args.prefill_replicas if args.prefill_replicas is not None else n // 2
    print(f"# {cfg.name} cluster | {n} replicas [{args.hw}] tp={args.tp} | "
          f"{len(reqs)} requests, {args.arrival} arrivals @ {args.qps:g} qps | "
          f"router={args.router}")
    hdr = (f"{'mode':<14} {'ttft p50/p95(s)':>15} {'tpot p50/p95(ms)':>16} "
           f"{'e2e_p95':>8} {'tok/s':>7} {'goodput':>8}")
    print(hdr)
    print("-" * len(hdr))
    results = {}
    for mode in modes:
        if mode == "disaggregated":
            if n < 2:
                print("disaggregated   (skipped: needs >= 2 replicas)")
                continue
            if not 1 <= n_p <= n - 1:
                raise SystemExit(f"--prefill-replicas must be in [1, {n - 1}]")
            pools = ["prefill"] * n_p + ["decode"] * (n - n_p)
        else:
            pools = ["mixed"] * n
        spec = ClusterSpec(replicas=_replicas(args, n, pools),
                           router=args.router, decode_router=args.decode_router,
                           hit_frac=args.hit_frac,
                           router_slo_ttft=args.slo_ttft,
                           shed_depth=args.shed_depth,
                           retry_after=args.retry_after,
                           max_retries=args.max_retries,
                           retry_backoff=args.retry_backoff,
                           retry_jitter=args.retry_jitter,
                           retry_seed=args.seed,
                           prefix_cache=pcache,
                           chaos=chaos, admission=admission)
        tracer = make_tracer(args.trace_level if args.trace else "off",
                             counter_dt=args.trace_counter_dt)
        monitor = None
        if args.slo_window is not None:
            monitor = SLOMonitor(make_slos(
                slo_ttft=args.slo_ttft, slo_goodput=args.slo_goodput,
                window=args.slo_window))
        elif args.slo_goodput is not None:
            raise SystemExit("--slo-goodput needs --slo-window to enable "
                             "the live SLO monitor")
        try:
            cres = simulate_cluster(reqs, cfg, spec, autoscale=autoscale,
                                    tracer=tracer, monitor=monitor,
                                    engine=args.engine)
        except ValueError as e:
            print(f"{mode:<14} (skipped: {e})")
            continue
        s = summarize_cluster(cres, slo_ttft=args.slo_ttft, slo_tpot=args.slo_tpot)
        results[mode] = (spec, cres, s)
        label = mode if mode == "colocated" else f"disagg {n_p}P/{n - n_p}D"
        print(_fmt_row(label, s))
        if tracer.enabled and args.trace:
            # the SLO monitor can force the tracer on without
            # --trace; only export when a path was actually given
            path = args.trace
            if len(modes) > 1:
                root, ext = os.path.splitext(path)
                path = f"{root}.{mode}{ext or '.json'}"
            fmt = write_trace(tracer.events, path, tracer.meta)
            print(f"# trace [{fmt}, level={args.trace_level}]: "
                  f"{len(tracer.events)} events -> {path}")

    for mode, (spec, cres, s) in results.items():
        dynamic = autoscale is not None
        if dynamic:
            # a dynamic fleet has no single $/hr: bill the actual spans
            prov = provisioning_summary(cres, shed_cost_usd=args.shed_cost)
            hours = max(cres.makespan / 3600.0, 1e-12)
            price = f"${prov['cost_usd'] / hours:.2f}/hr avg (dynamic)"
        else:
            price = f"${cluster_price_per_hr(spec):.2f}/hr"
        print(f"\n# {mode}: {price}, "
              f"preemptions={s['preemptions']}, "
              f"util=[{', '.join(f'{u:.0%}' for u in s['replica_util'])}]"
              + (f", kv-transfer: {s['xfer_count']} moves, {s['xfer_gb']:.2f} GB, "
                 f"{s['xfer_s_mean'] * 1e3:.2f} ms mean (p2p), "
                 f"{s['xfer_share']:.2%} of e2e"
                 if cres.mode == "disaggregated" else "")
              + (f", prefix_hits={s['prefix_hits']}"
                 if args.router == "affinity" or args.prefix_cache else "")
              + (f", shed={s['shed']} ({s['shed_frac']:.1%}), "
                 f"retries={s['retries']}"
                 if args.shed_depth is not None else ""))
        if cres.chaos_stats is not None:
            ch = cres.chaos_stats
            print(f"  chaos: {ch['crashes']} crashes, "
                  f"{ch['stragglers']} stragglers, "
                  f"{ch['link_degrades']} link degradations | "
                  f"lost={s['requests_lost']} requests, "
                  f"displaced={ch['displaced']} "
                  f"(re-prefill {ch['re_prefill_tokens']} tok, "
                  f"restored {ch['restored_tokens']} tok), "
                  f"recovery mean/max "
                  f"{ch['recovery_s_mean']:.2f}/{ch['recovery_s_max']:.2f}s")
        if cres.admission_stats is not None:
            ad = cres.admission_stats
            print(f"  door [{ad['policy']}]: {ad['door_admitted']} admitted, "
                  f"{ad['door_delayed']} delayed, {ad['door_shed']} shed"
                  + (f", {ad['breaker_opens']} opens "
                     f"(final state {ad['breaker_state']})"
                     if ad["policy"] == "breaker" else ""))
        if cres.slo is not None:
            print(f"  slo monitor: time_in_violation="
                  f"{s['time_in_violation']:g}s, "
                  f"alerts_fired={s['alerts_fired']}, "
                  f"budget_burn={s['budget_burn']:.1%}, "
                  f"anomalies={s['anomalies']}")
            for a in cres.slo["alerts"]:
                if a["state"] in ("firing", "resolved"):
                    print(f"    t={a['t']:7.2f}s {a['state']:<9} "
                          f"{a['rule']} [{a['slo']}] "
                          f"burn={a['burn_long']:.1f}/{a['burn_short']:.1f} "
                          f"(>= {a['burn_threshold']:g})")
        if args.prefix_cache:
            print(f"  prefix cache: {s['cache_hit_rate']:.0%} hit rate, "
                  f"{s['cache_hit_tokens']} prompt tokens skipped, "
                  f"{s['cache_evictions']} evictions, "
                  f"peak resident {s['cache_resident_gb']:.2f} GB/replica, "
                  f"{s['cache_invalidations']} invalidations")
        if dynamic:
            label = (f"pool-aware {args.prefill_policy}/{args.decode_policy}"
                     if args.pool_autoscale else args.autoscale_policy)
            print(f"  autoscale [{label}]: "
                  f"{s['scale_events']} scale events, "
                  f"peak {s['peak_replicas']} replicas, "
                  f"{prov['replica_hours'] * 3600:.1f} replica-s vs "
                  f"{prov['replica_hours_static_peak'] * 3600:.1f} static-peak "
                  f"(${prov['cost_usd']:.4f} vs "
                  f"${prov['cost_usd_static_peak']:.4f}, "
                  f"{prov['savings_frac']:.0%} saved)")
            if args.shed_cost > 0:
                print(f"  shed cost: {prov['shed']} dropped x "
                      f"${args.shed_cost:.4f} = ${prov['shed_cost_usd']:.4f} "
                      f"-> total ${prov['cost_usd_total']:.4f}")
            if args.pool_autoscale:
                for pool, pp in prov["pools"].items():
                    print(f"  pool {pool:<8} billing: "
                          f"{pp['replica_hours'] * 3600:.1f} replica-s, "
                          f"${pp['cost_usd']:.4f}, "
                          f"peak {pp['peak_replicas']} replicas")
            for ev in cres.scale_events:
                print(f"    t={ev['t']:7.2f}s {ev['action']:<7} "
                      f"r{ev['replica']} [{ev['pool']}]"
                      + (f" ready t={ev['ready']:.2f}s"
                         if ev["action"] == "add" else ""))
        for pool, ps in pool_summaries(cres, slo_ttft=args.slo_ttft,
                                       slo_tpot=args.slo_tpot).items():
            print(f"  pool {pool:<8} x{ps['replicas']}: "
                  f"ttft p95 {ps['ttft_p95']:.2f}s, "
                  f"tpot p95 {ps['tpot_p95'] * 1e3:.1f}ms, "
                  f"goodput {ps['goodput_frac']:.0%}, "
                  f"util {ps['util_mean']:.0%}, "
                  f"peak KV {ps['peak_kv_gb']:.1f} GB, "
                  f"preempt {ps['preemptions']}")


if __name__ == "__main__":
    main()
