"""Multi-replica serving cluster simulator.

Composes N `repro.sim.ReplicaSim` replicas under one shared arrival
stream. Requests are dispatched by a pluggable router at their arrival
instant (replicas are co-simulated event-by-event, so the router observes
replica state at the dispatch time); each replica then prices its own
engine iterations with its own `ServingCostModel`, so heterogeneous
hardware / parallelism / scheduler mixes are first-class.

Two cluster organizations:

  * colocated     — every replica is a `mixed` pool member serving whole
                    requests (prefill + decode), the classic data-parallel
                    deployment.
  * disaggregated — `prefill` replicas run prompt processing only (the
                    first token streams out of the prefill logits), then
                    hand the sequence's KV cache to a `decode` replica
                    over a `comm.p2p`-priced transfer (volume from §3.5's
                    `kv_cache_bytes` via `kv_handoff_bytes`); the decode
                    replica resumes mid-stream via `ReplicaSim.push(
                    cached=prompt, generated=1)`. The transfer sits
                    between the first and second token, where it belongs
                    in the TPOT accounting.

Cluster-level records stitch the per-stage records back into one
`ReqRecord` per request (arrival at the cluster, TTFT from the prefill
stage, finish from the decode stage), so `summarize_records` reports the
same SLO vocabulary at replica, pool, and cluster level.
"""

from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field, replace

from repro.configs.base import ModelConfig
from repro.core import comm as C
from repro.core.hardware import HardwareSpec, NetLevel, get_hardware
from repro.sim.costmodel import ServingCostModel
from repro.sim.metrics import summarize_records
from repro.sim.scheduler import ReplicaSim, ReqRecord, SchedConfig, SimResult
from repro.sim.workload import SimRequest

from repro.cluster.router import AffinityRouter, ReplicaView, make_router

POOLS = ("mixed", "prefill", "decode")
_INF = float("inf")


@dataclass(frozen=True)
class ReplicaSpec:
    """One replica: a device group running its own serving engine."""

    hw: HardwareSpec | str = "h100"
    tp: int = 1
    prec: int = 2
    pool: str = "mixed"  # mixed | prefill | decode
    sched: SchedConfig = field(default_factory=SchedConfig)
    ctx_quantum: int = 16
    kv_block_tokens: int = 0

    def resolve_hw(self) -> HardwareSpec:
        return get_hardware(self.hw) if isinstance(self.hw, str) else self.hw

    def cost_key(self) -> tuple:
        return (self.resolve_hw().name, self.tp, self.prec,
                self.ctx_quantum, self.kv_block_tokens)

    def build_cost(self, cfg: ModelConfig) -> ServingCostModel:
        return ServingCostModel(cfg, self.resolve_hw(), tp=self.tp, prec=self.prec,
                                ctx_quantum=self.ctx_quantum,
                                kv_block_tokens=self.kv_block_tokens)


@dataclass(frozen=True)
class ClusterSpec:
    replicas: tuple[ReplicaSpec, ...]
    router: str = "jsq"  # arrival routing (mixed / prefill pool)
    decode_router: str = "least_kv"  # KV-handoff routing (decode pool)
    hit_frac: float = 0.5  # affinity router's prefill-cache discount
    xfer_net: NetLevel | None = None  # None -> decode replica's top net level

    @property
    def disaggregated(self) -> bool:
        return any(r.pool != "mixed" for r in self.replicas)

    def pool_indices(self, pool: str) -> list[int]:
        return [i for i, r in enumerate(self.replicas) if r.pool == pool]

    def validate(self) -> None:
        if not self.replicas:
            raise ValueError("cluster needs at least one replica")
        for r in self.replicas:
            if r.pool not in POOLS:
                raise ValueError(f"unknown pool {r.pool!r}; choose from {POOLS}")
        if self.disaggregated:
            if self.pool_indices("mixed"):
                raise ValueError(
                    "mixed replicas cannot coexist with prefill/decode pools")
            if not self.pool_indices("prefill") or not self.pool_indices("decode"):
                raise ValueError(
                    "disaggregated cluster needs >= 1 prefill AND >= 1 decode replica")
        # mid-stream entry (KV handoffs, prefix-cache hits) needs a policy
        # that can resume from cached state — static batching cannot
        static = [i for i, r in enumerate(self.replicas)
                  if r.sched.policy == "static"]
        if static and self.disaggregated:
            raise ValueError(
                "static-policy replicas cannot accept disaggregated KV "
                f"handoffs (replicas {static}); use continuous or chunked")
        if static and self.router == "affinity" and self.hit_frac > 0:
            raise ValueError(
                "affinity prefix-cache discounts cannot apply to static-policy "
                f"replicas (replicas {static}); use continuous/chunked or "
                "hit_frac=0")


@dataclass
class ClusterResult:
    mode: str  # colocated | disaggregated
    records: list[ReqRecord]  # cluster-level (stitched across stages)
    replica_results: list[SimResult]
    replica_pools: list[str]
    assignments: dict  # rid -> (serving/prefill replica, decode replica | -1)
    xfer_count: int = 0
    xfer_bytes: float = 0.0
    xfer_seconds: float = 0.0
    prefix_hits: int = 0

    @property
    def makespan(self) -> float:
        if not self.records:
            return 0.0
        return (max(r.finish for r in self.records)
                - min(r.arrival for r in self.records))


def _views(sims: list[ReplicaSim], idxs: list[int]) -> list[ReplicaView]:
    return [ReplicaView(i, sims[i].now, sims[i].queue_len, sims[i].live,
                        sims[i].kv_used, sims[i].cap) for i in idxs]


def simulate_cluster(requests: list[SimRequest], cfg: ModelConfig,
                     spec: ClusterSpec, *,
                     _cost_cache: dict | None = None) -> ClusterResult:
    """Co-simulate the cluster over one shared arrival stream.

    `_cost_cache` lets sweeps (the capacity planner) share memoized
    `ServingCostModel`s across many cluster candidates."""
    spec.validate()
    cache = _cost_cache if _cost_cache is not None else {}
    costs = []
    for rs in spec.replicas:
        key = rs.cost_key()
        if key not in cache:
            cache[key] = rs.build_cost(cfg)
        costs.append(cache[key])
    sims = [ReplicaSim(cost, rs.sched, name=f"r{i}:{rs.pool}")
            for i, (rs, cost) in enumerate(zip(spec.replicas, costs))]
    ordered = sorted(requests, key=lambda r: (r.arrival, r.rid))
    if spec.disaggregated:
        return _run_disaggregated(ordered, spec, sims, costs)
    return _run_colocated(ordered, spec, sims)


# ---------------------------------------------------------------- colocated
def _run_colocated(ordered, spec, sims) -> ClusterResult:
    router = make_router(spec.router, hit_frac=spec.hit_frac)
    idxs = list(range(len(sims)))
    assignments = {}
    for req in ordered:
        for s in sims:
            s.run_until(req.arrival)
        i, cached = router.pick(req, _views(sims, idxs))
        sims[i].push(req, cached=cached)
        assignments[req.rid] = (i, -1)
    for s in sims:
        s.run()
    records = sorted((rec for s in sims for rec in s.res.records),
                     key=lambda r: r.rid)
    return ClusterResult(
        mode="colocated", records=records,
        replica_results=[s.res for s in sims],
        replica_pools=[r.pool for r in spec.replicas],
        assignments=assignments,
        prefix_hits=router.hits if isinstance(router, AffinityRouter) else 0)


# ------------------------------------------------------------- disaggregated
def _run_disaggregated(ordered, spec, sims, costs) -> ClusterResult:
    p_idx = spec.pool_indices("prefill")
    d_idx = spec.pool_indices("decode")
    p_set = set(p_idx)
    p_router = make_router(spec.router, hit_frac=spec.hit_frac)
    d_router = make_router(spec.decode_router)
    net = spec.xfer_net or costs[d_idx[0]].hw.net[-1]

    arrivals = deque(ordered)
    orig = {r.rid: r for r in ordered}
    xfers: list[tuple[float, int, SimRequest]] = []  # heap: (ready, seq, req)
    seq = 0
    prefill_recs: dict[int, ReqRecord] = {}
    decode_recs: dict[int, ReqRecord] = {}
    assignments: dict[int, list[int]] = {}
    xfer_count, xfer_bytes, xfer_seconds = 0, 0.0, 0.0

    def harvest(i: int, done: list[ReqRecord]) -> None:
        """Prefill completions become KV transfers to the decode pool."""
        nonlocal seq, xfer_count, xfer_bytes, xfer_seconds
        if i not in p_set:
            return
        for rec in done:
            req = orig[rec.rid]
            if req.output <= 1:
                continue  # single-token request: served entirely by prefill
            nbytes = costs[i].kv_handoff_bytes(req.prompt)
            dt = C.p2p(nbytes, net)
            heapq.heappush(xfers, (rec.finish + dt, seq, req))
            seq += 1
            xfer_count += 1
            xfer_bytes += nbytes
            xfer_seconds += dt

    def advance_all(t: float) -> None:
        for i, s in enumerate(sims):
            while s.has_work and s.now < t:
                harvest(i, s.step())

    while True:
        t_arr = arrivals[0].arrival if arrivals else _INF
        t_xfer = xfers[0][0] if xfers else _INF
        if t_arr == _INF and t_xfer == _INF:
            progressed = False
            for i, s in enumerate(sims):
                if s.has_work:
                    progressed = True
                    harvest(i, s.step())
            if arrivals or xfers:
                continue
            if not progressed:
                break
            continue
        t_evt = min(t_arr, t_xfer)
        advance_all(t_evt)
        # a harvest during the advance can surface an earlier transfer;
        # re-resolve so events are always dispatched in global time order
        t_arr = arrivals[0].arrival if arrivals else _INF
        t_xfer = xfers[0][0] if xfers else _INF
        if min(t_arr, t_xfer) < t_evt:
            continue
        if t_arr <= t_xfer:
            req = arrivals.popleft()
            i, cached = p_router.pick(req, _views(sims, p_idx))
            # prefill stage ends at the first token; decode happens elsewhere
            prefill_recs[req.rid] = sims[i].push(replace(req, output=1),
                                                cached=cached)
            assignments[req.rid] = [i, -1]
        else:
            ready, _, req = heapq.heappop(xfers)
            j, _ = d_router.pick(req, _views(sims, d_idx))
            decode_recs[req.rid] = sims[j].push(
                replace(req, arrival=ready), cached=req.prompt, generated=1)
            assignments[req.rid][1] = j

    records = []
    for req in ordered:
        pre = prefill_recs[req.rid]
        dec = decode_recs.get(req.rid)
        records.append(ReqRecord(
            req.rid, req.arrival, req.prompt, req.output,
            admitted=pre.admitted, first_token=pre.first_token,
            finish=dec.finish if dec is not None else pre.finish,
            preemptions=pre.preemptions + (dec.preemptions if dec else 0)))
    return ClusterResult(
        mode="disaggregated", records=records,
        replica_results=[s.res for s in sims],
        replica_pools=[r.pool for r in spec.replicas],
        assignments={k: tuple(v) for k, v in assignments.items()},
        xfer_count=xfer_count, xfer_bytes=xfer_bytes, xfer_seconds=xfer_seconds,
        prefix_hits=p_router.hits if isinstance(p_router, AffinityRouter) else 0)


# ------------------------------------------------------------------ metrics
def summarize_cluster(cres: ClusterResult, *, slo_ttft: float | None = None,
                      slo_tpot: float | None = None) -> dict:
    """Cluster-level SLO metric dict over the stitched records, plus
    aggregate counters and the KV-transfer overhead share."""
    span = cres.makespan
    out: dict = {"mode": cres.mode, "replicas": len(cres.replica_results)}
    out.update(summarize_records(cres.records, span=span,
                                 slo_ttft=slo_ttft, slo_tpot=slo_tpot))
    out["iterations"] = sum(r.iterations for r in cres.replica_results)
    out["preemptions"] = sum(r.preemptions for r in cres.replica_results)
    out["prefix_hits"] = cres.prefix_hits
    out["xfer_count"] = cres.xfer_count
    out["xfer_gb"] = cres.xfer_bytes / 1e9
    out["xfer_s_mean"] = (cres.xfer_seconds / cres.xfer_count
                          if cres.xfer_count else 0.0)
    e2e_total = sum(r.e2e for r in cres.records)
    out["xfer_share"] = cres.xfer_seconds / e2e_total if e2e_total > 0 else 0.0
    denom = max(span, 1e-12)
    out["replica_util"] = [r.busy_s / denom for r in cres.replica_results]
    return out


def pool_summaries(cres: ClusterResult, *, slo_ttft: float | None = None,
                   slo_tpot: float | None = None) -> dict:
    """Per-pool SLO metrics (over the pool replicas' own stage records)
    plus pool utilization against the cluster makespan."""
    span = max(cres.makespan, 1e-12)
    out = {}
    for pool in dict.fromkeys(cres.replica_pools):  # stable order
        idxs = [i for i, p in enumerate(cres.replica_pools) if p == pool]
        recs = [rec for i in idxs for rec in cres.replica_results[i].records]
        s = summarize_records(recs, span=cres.makespan,
                              slo_ttft=slo_ttft, slo_tpot=slo_tpot)
        s["replicas"] = len(idxs)
        s["util_mean"] = (sum(cres.replica_results[i].busy_s for i in idxs)
                          / (len(idxs) * span))
        s["preemptions"] = sum(cres.replica_results[i].preemptions for i in idxs)
        s["peak_kv_gb"] = max(cres.replica_results[i].peak_kv for i in idxs) / 1e9
        out[pool] = s
    return out
