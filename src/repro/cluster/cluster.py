"""Multi-replica serving cluster simulator with dynamic fleet membership.

Composes N `repro.sim.ReplicaSim` replicas under one shared arrival
stream. Requests are dispatched by a pluggable router at their arrival
instant (replicas are co-simulated event-by-event, so the router observes
replica state at the dispatch time); each replica then prices its own
engine iterations with its own `ServingCostModel`, so heterogeneous
hardware / parallelism / scheduler mixes are first-class.

Two cluster organizations:

  * colocated     — every replica is a `mixed` pool member serving whole
                    requests (prefill + decode), the classic data-parallel
                    deployment.
  * disaggregated — `prefill` replicas run prompt processing only (the
                    first token streams out of the prefill logits), then
                    hand the sequence's KV cache to a `decode` replica
                    over a `comm.p2p`-priced transfer (volume from §3.5's
                    `kv_cache_bytes` via `kv_handoff_bytes`); the decode
                    replica resumes mid-stream via `ReplicaSim.push(
                    cached=prompt, generated=1)`. The transfer sits
                    between the first and second token, where it belongs
                    in the TPOT accounting.

The fleet itself is dynamic when `simulate_cluster(..., autoscale=)` is
given an `AutoscaleConfig`: a control loop fires every `interval` seconds,
evaluates the policy (reactive rate/SLO-debt tracking, or the predictive
M/G/1 envelope policy — see `repro.cluster.autoscale`), and replicas join
(after a weight-loading warmup priced from the cost model) or leave
(graceful drain: no new admissions, in-flight work runs out, untouched
queued arrivals are re-routed) mid-stream. Per-replica provisioning spans
are billed so diurnal fleets report replica-hours against the
static-peak-provisioned fleet that serves the same trace.

Disaggregated fleets can scale their pools INDEPENDENTLY: pass
`autoscale={"prefill": asc_p, "decode": asc_d}` and each pool runs its
own control loop on its own signal (prefill on admission-queue wait,
decode on KV pressure + TPOT debt are the natural pairings) with its own
bounds and interval, instead of growing both pools by the spec's template
ratio even when only one is the bottleneck. Handoff routing tolerates
mid-stream pool-size changes: transfers are routed among the decode
replicas accepting at the instant the KV arrives, and a draining decode
replica's queued-but-unstarted handoffs are re-routed to the survivors.

With `ClusterSpec.prefix_cache` set, prompt-prefix reuse is MODELED
rather than assumed: each prefilling replica runs a finite-byte LRU/TTL
prefix cache (`repro.cluster.prefixcache`) carved out of its KV
capacity, requests' shared prefixes (explicit `prefix_group`s shared
across sessions, or per-session conversation history) become resident at
dispatch and expire/evict under pressure, and every prefill discount is
computed from the tokens ACTUALLY resident at the dispatch instant.
Draining or retiring a replica invalidates its cache, so autoscale churn
pays a measurable re-warm cost.

Optionally the cluster sheds load instead of queueing without bound:
when every eligible replica's depth is at `shed_depth`, the arrival is
retried after a seeded exponential backoff with jitter (base
`retry_after`, up to `max_retries` times) and then dropped. Every
generated request is therefore exactly once completed or shed — an
invariant the tests pin, and that survives fault injection: with
`ClusterSpec.chaos` set, seeded replica crashes, stragglers, link
degradations, and correlated node failures (`repro.cluster.chaos`) are
merged into the event loop, crash-displaced requests re-enter dispatch
(re-prefilling or restoring from a surviving replica's prefix cache),
and anything parked when a pool dies is a counted loss, never a silent
disappearance. `ClusterSpec.admission` adds an overload front door
(token bucket or circuit breaker) that sheds or delays arrivals BEFORE
routing. Chaos off and no door leave the engine bit-identical to the
fault-free path.

Cluster-level records stitch the per-stage records back into one
`ReqRecord` per request (arrival at the cluster, TTFT from the prefill
stage, finish from the decode stage), so `summarize_records` reports the
same SLO vocabulary at replica, pool, and cluster level.
"""

from __future__ import annotations

import bisect
import heapq
from collections import deque
from dataclasses import dataclass, field, replace

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import comm as C
from repro.core.hardware import HardwareSpec, NetLevel, get_hardware
from repro.obs.tracer import NULL_TRACER, Tracer
from repro.sim.costmodel import ServingCostModel
from repro.sim.metrics import summarize_records
from repro.sim.scheduler import (
    ENGINES,
    ReplicaSim,
    ReqRecord,
    SchedConfig,
    SimResult,
    make_replica_sim,
)
from repro.sim.workload import SimRequest

from repro.cluster.autoscale import AutoscaleConfig, Autoscaler
from repro.cluster.chaos import (
    AdmissionConfig,
    ChaosConfig,
    make_admission,
    pick_victims,
)
from repro.cluster.prefixcache import (
    FleetPrefixCache,
    PrefixCacheConfig,
    prefix_key,
)
from repro.cluster.router import (
    AffinityRouter,
    JoinShortestQueueRouter,
    LeastKVLoadRouter,
    ReplicaView,
    RoundRobinRouter,
    make_router,
)

# routers whose pick is a pure (depth, kv) argmin over the eligible set:
# the vectorized engine computes it from its O(1) per-replica counters
# instead of materializing `ReplicaView` snapshots (affinity and slo_debt
# read per-request / windowed state and keep the view-based path)
_FAST_ROUTERS = (JoinShortestQueueRouter, RoundRobinRouter, LeastKVLoadRouter)

POOLS = ("mixed", "prefill", "decode")
_INF = float("inf")


@dataclass(frozen=True)
class ReplicaSpec:
    """One replica: a device group running its own serving engine."""

    hw: HardwareSpec | str = "h100"
    tp: int = 1
    prec: int = 2
    pool: str = "mixed"  # mixed | prefill | decode
    sched: SchedConfig = field(default_factory=SchedConfig)
    ctx_quantum: int = 16
    kv_block_tokens: int = 0

    def resolve_hw(self) -> HardwareSpec:
        """The concrete hardware spec (string names are looked up)."""
        return get_hardware(self.hw) if isinstance(self.hw, str) else self.hw

    def cost_key(self) -> tuple:
        """Memoization key: replicas with equal keys share one
        `ServingCostModel` (and its step-cost memo) across the fleet."""
        return (self.resolve_hw().name, self.tp, self.prec,
                self.ctx_quantum, self.kv_block_tokens)

    def build_cost(self, cfg: ModelConfig) -> ServingCostModel:
        """Price `cfg` on this replica's hardware/parallelism/precision."""
        return ServingCostModel(cfg, self.resolve_hw(), tp=self.tp, prec=self.prec,
                                ctx_quantum=self.ctx_quantum,
                                kv_block_tokens=self.kv_block_tokens)


@dataclass(frozen=True)
class ClusterSpec:
    replicas: tuple[ReplicaSpec, ...]
    router: str = "jsq"  # arrival routing (mixed / prefill pool)
    decode_router: str = "least_kv"  # KV-handoff routing (decode pool)
    hit_frac: float = 0.5  # affinity router's prefill-cache discount
    xfer_net: NetLevel | None = None  # None -> decode replica's top net level
    router_slo_ttft: float = 2.0  # slo_debt router's TTFT deadline
    debt_window: float = 30.0  # slo_debt router's rolling window (s)
    # cross-replica load shedding (None = queue without bound)
    shed_depth: int | None = None  # shed when EVERY eligible depth >= this
    retry_after: float = 0.5  # base backoff before a shed arrival is retried
    max_retries: int = 2  # retries before the request is dropped
    # exponential backoff with seeded jitter: retry k (0-based) waits
    # `retry_after * retry_backoff**k * (1 + retry_jitter * U[0,1))` —
    # jitter de-synchronizes a burst that shed together so it does not
    # retry together forever (the thundering-herd fix). The jitter stream
    # is a dedicated `SeedSequence(retry_seed)` spawn, so workload
    # streams are unperturbed; `retry_backoff=1, retry_jitter=0` recovers
    # the legacy fixed delay exactly (and draws no random numbers).
    retry_backoff: float = 2.0
    retry_jitter: float = 0.5
    retry_seed: int = 0
    # modeled prefix cache (None = the legacy unconditional hit_frac
    # discount for the affinity router, no discount for other routers)
    prefix_cache: PrefixCacheConfig | None = None
    # seeded fault injection (None / all-zero rates = chaos off: the
    # engine schedule is bit-identical to the chaos-free engine)
    chaos: ChaosConfig | None = None
    # admission front door (None = every arrival goes straight to routing)
    admission: AdmissionConfig | None = None

    @property
    def disaggregated(self) -> bool:
        """True when the spec separates prefill and decode pools."""
        return any(r.pool != "mixed" for r in self.replicas)

    def pool_indices(self, pool: str) -> list[int]:
        """Template indices of the replicas declared in `pool`."""
        return [i for i, r in enumerate(self.replicas) if r.pool == pool]

    def make_router(self, name: str):
        """Instantiate a dispatch router with this spec's routing knobs."""
        return make_router(name, hit_frac=self.hit_frac,
                           slo_ttft=self.router_slo_ttft,
                           debt_window=self.debt_window)

    def validate(self) -> None:
        """Raise ValueError on inconsistent topology/shedding settings."""
        if not self.replicas:
            raise ValueError("cluster needs at least one replica")
        for r in self.replicas:
            if r.pool not in POOLS:
                raise ValueError(f"unknown pool {r.pool!r}; choose from {POOLS}")
        if self.shed_depth is not None:
            if self.shed_depth < 1:
                raise ValueError("shed_depth must be >= 1")
            if self.retry_after <= 0 or self.max_retries < 0:
                raise ValueError("need retry_after > 0 and max_retries >= 0")
        if self.retry_backoff < 1.0:
            raise ValueError("retry_backoff must be >= 1.0")
        if self.retry_jitter < 0.0:
            raise ValueError("retry_jitter must be >= 0")
        if self.chaos is not None:
            self.chaos.validate()
        if self.admission is not None:
            self.admission.validate()
        if self.disaggregated:
            if self.pool_indices("mixed"):
                raise ValueError(
                    "mixed replicas cannot coexist with prefill/decode pools")
            if not self.pool_indices("prefill") or not self.pool_indices("decode"):
                raise ValueError(
                    "disaggregated cluster needs >= 1 prefill AND >= 1 decode replica")
        # mid-stream entry (KV handoffs, prefix-cache hits) needs a policy
        # that can resume from cached state — static batching cannot
        static = [i for i, r in enumerate(self.replicas)
                  if r.sched.policy == "static"]
        if static and self.disaggregated:
            raise ValueError(
                "static-policy replicas cannot accept disaggregated KV "
                f"handoffs (replicas {static}); use continuous or chunked")
        if static and self.router == "affinity" and self.hit_frac > 0:
            raise ValueError(
                "affinity prefix-cache discounts cannot apply to static-policy "
                f"replicas (replicas {static}); use continuous/chunked or "
                "hit_frac=0")
        if self.prefix_cache is not None:
            self.prefix_cache.validate()
            if static:
                raise ValueError(
                    "prefix-cache hits enter replicas mid-stream, which "
                    f"static-policy replicas (replicas {static}) cannot "
                    "accept; use continuous or chunked")


@dataclass
class ClusterResult:
    mode: str  # colocated | disaggregated
    records: list[ReqRecord]  # cluster-level (stitched across stages)
    replica_results: list[SimResult]
    replica_pools: list[str]
    assignments: dict  # rid -> (serving/prefill replica, decode replica | -1)
    xfer_count: int = 0
    xfer_bytes: float = 0.0
    xfer_seconds: float = 0.0
    prefix_hits: int = 0
    # dynamic-fleet accounting (static clusters: one full-span row each)
    replica_specs: list[ReplicaSpec] = field(default_factory=list)
    replica_spans: list[tuple[float, float]] = field(default_factory=list)
    scale_events: list[dict] = field(default_factory=list)
    shed: list[SimRequest] = field(default_factory=list)
    retries: int = 0
    # requests terminally lost to outages (no accepting replica could
    # ever serve them, or work parked past the horizon) — a subset of
    # `shed` attributable to availability, not overload
    requests_lost: int = 0
    # fault-injection counters (None when chaos is off; see
    # `repro.cluster.chaos`)
    chaos_stats: dict | None = None
    # admission front-door counters (None when no door is configured)
    admission_stats: dict | None = None
    # modeled-prefix-cache counters (None when the cache is not modeled)
    cache_stats: dict | None = None
    # online SLO monitor result (`SLOMonitor.result()`; None unmonitored)
    slo: dict | None = None
    # the trace's time frame: simulation origin and the instant the last
    # replica went quiet — the same end that clamps `replica_spans`, so
    # billing windows and exported trace tracks share one clock
    t0: float = 0.0
    horizon: float = 0.0

    @property
    def makespan(self) -> float:
        """Seconds from the first arrival to the last finish (0 if empty)."""
        if not self.records:
            return 0.0
        return (max(r.finish for r in self.records)
                - min(r.arrival for r in self.records))

    @property
    def span(self) -> float:
        """Billable wall span: `horizon - t0`. Unlike `makespan` (first
        arrival to last finish, a records-only view) this covers the whole
        provisioned timeline, including drains that outlive the last
        completion, and matches the trace's track extents exactly."""
        if self.horizon > self.t0:
            return self.horizon - self.t0
        return self.makespan  # hand-built results without a horizon

    @property
    def replica_hours(self) -> float:
        """Provisioned replica-hours actually billed (warmup included)."""
        return sum(e - s for s, e in self.replica_spans) / 3600.0

    @property
    def replica_hours_static_peak(self) -> float:
        """The counterfactual bill: the peak-concurrency fleet held for
        the whole trace span (what static provisioning for this trace
        costs). Billed over `span`, the same origin->horizon window the
        real `replica_spans` are billed over — pricing the counterfactual
        over the shorter records-makespan used to understate it, skewing
        `savings_frac` for fleets whose drains outlive the last finish."""
        return self.peak_replicas * self.span / 3600.0

    @property
    def peak_replicas(self) -> int:
        """Max concurrently-provisioned replicas — what a static fleet
        sized for this trace's peak would have to run the whole time."""
        return int(peak_over_spans(self.replica_spans))


def peak_over_spans(spans, weights=None) -> float:
    """Sweep-line peak of `sum(weight)` over overlapping (start, end)
    spans — replica counts with unit weights, $/hr rates with prices. At
    equal times releases sort before acquires (negative deltas first), so
    back-to-back spans never count as overlapping."""
    if weights is None:
        weights = [1.0] * len(spans)
    events = sorted((t, d * w) for (s, e), w in zip(spans, weights)
                    for t, d in ((s, 1), (e, -1)))
    cur = peak = 0.0
    for _, d in events:
        cur += d
        peak = max(peak, cur)
    return peak


# --------------------------------------------------------- dynamic fleet state
@dataclass
class _Rep:
    """One replica's lifecycle inside the engine."""

    sim: ReplicaSim
    spec: ReplicaSpec
    cost: ServingCostModel
    pool: str
    started: float  # provisioning (billing) begins
    ready: float  # accepting traffic from here (started + warmup)
    drain_start: float = -1.0  # >= 0: no new admissions
    retired: float = -1.0  # drained; billing ends
    crashed: bool = False  # retired by fault injection, not a drain

    @property
    def draining(self) -> bool:
        return self.drain_start >= 0.0

    @property
    def provisioned(self) -> bool:
        return self.retired < 0.0 and not self.draining

    def accepting(self, now: float) -> bool:
        return self.provisioned and self.ready <= now


def _views(reps: list[_Rep], idxs: list[int], *,
           at: float = 0.0) -> list[ReplicaView]:
    """Router-facing snapshots. `at` is the dispatch instant: an idle
    replica's own clock stops at its last event, so the view clock must be
    clamped up to the observation time (time-windowed policies like
    slo_debt would otherwise never expire old observations across gaps)."""
    return [ReplicaView(i, max(reps[i].sim.now, at), reps[i].sim.queue_len,
                        reps[i].sim.live, reps[i].sim.kv_used, reps[i].sim.cap)
            for i in idxs]


class _ClusterEngine:
    """Shared event loop for colocated and disaggregated clusters, with
    optional autoscaling and fault injection. Events, in tie-break order
    at equal times: request arrivals, shed-retry re-arrivals, KV-handoff
    completions, autoscaler control ticks, chaos events. Between events
    every replica is advanced to the event time, harvesting completions
    (prefill handoffs, TTFT feedback to the router and autoscaler, drain
    progress)."""

    def __init__(self, spec: ClusterSpec, cfg: ModelConfig,
                 autoscale: AutoscaleConfig | dict | None, cache: dict,
                 tracer=None, monitor=None, engine: str = "vectorized"):
        self.spec = spec
        self.cfg = cfg
        self.cache = cache
        self.engine = engine
        self._vec = engine == "vectorized"
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.monitor = monitor
        if monitor is not None:
            if not self.tracer.enabled:
                # monitor without recording: a sink-only tracer feeds the
                # monitor live and discards the event list
                self.tracer = Tracer("request", keep_events=False)
            self.tracer.add_sink(monitor)
        # hoisted level gates (tracing is purely observational: a traced
        # run executes the identical schedule as an untraced one)
        self._tr_sum = self.tracer.wants("summary")
        self._tr_rep = self.tracer.wants("replica")
        self._tr_req = self.tracer.wants("request")
        self._handoff_log: dict[int, list[tuple[float, float, float]]] = {}
        self.disagg = spec.disaggregated
        self.arrival_pool = "prefill" if self.disagg else "mixed"
        self.router = spec.make_router(spec.router)
        self.d_router = spec.make_router(spec.decode_router)
        # the modeled prefix cache lives on the replicas that prefill;
        # with it bound, the affinity router places by residency and the
        # engine computes every discount from actually resident tokens
        self.pcache: FleetPrefixCache | None = None
        self._counted: dict[int, tuple[int, int]] = {}  # rid -> (replica, hit)
        if spec.prefix_cache is not None:
            self.pcache = FleetPrefixCache(spec.prefix_cache, spec.hit_frac)
            if isinstance(self.router, AffinityRouter):
                self.router.bind_cache(self.pcache)

        # vectorized-engine bookkeeping. A traced run must interleave
        # per-iteration events across replicas exactly as the reference
        # loop does, so tracing forces single-step advances (the batched
        # loop stays on, but every chunk is one iteration).
        self._lockstep = self._vec and self.tracer.enabled
        self._rheap: list[tuple[float, int]] = []  # (clock, idx), lazy
        self._pheap: list[tuple[float, int]] = []  # prefill-pool subset
        self._use_pheap = self._vec and self.disagg and not self._lockstep
        self._hbuf: list = []  # (start, idx, seq, recs) harvest buffer
        self._hseq = 0
        self._depth: list[int] = []  # queued + live, per replica
        self._members: dict[str, list[int]] = {}  # pool -> accepting idxs
        self._warming: dict[str, list[tuple[float, int]]] = {}
        self._draining: set[int] = set()  # drain started, not yet retired

        self.reps: list[_Rep] = []
        for rs in spec.replicas:
            self._add_rep(rs, rs.pool, started=0.0, ready=0.0)
        # KV handoffs price over one fixed link for the whole run: the
        # explicit override, or the first decode replica's top net level
        self.xfer_net = spec.xfer_net
        if self.disagg and self.xfer_net is None:
            d0 = spec.pool_indices("decode")[0]
            self.xfer_net = self.reps[d0].cost.hw.net[-1]
        # scale-up templates cycle over the spec's replicas of each pool
        self._templates = {p: [rs for rs in spec.replicas if rs.pool == p]
                           for p in dict.fromkeys(r.pool for r in spec.replicas)}
        self._tmpl_i = {p: 0 for p in self._templates}

        # autoscaling: one fleet-wide scaler (template-ratio split for
        # disaggregated fleets) or one independent scaler per pool. Each
        # scaler prices its predictive service time / warmup lookahead
        # from its own pool's first template replica.
        self.scaler: Autoscaler | None = None  # fleet-wide mode
        self.pool_scalers: dict[str, Autoscaler] = {}  # pool-aware mode
        if isinstance(autoscale, AutoscaleConfig):
            # the fleet-wide loop sizes the TOTAL count (split by template
            # ratio for disaggregated fleets), so its predictive server
            # model is a whole-request "mixed" replica
            self.scaler = self._make_scaler(autoscale, self.arrival_pool,
                                            service_pool="mixed")
        elif autoscale:
            self.pool_scalers = {pool: self._make_scaler(asc, pool)
                                 for pool, asc in autoscale.items()}
        self._signal_scalers = ([self.scaler] if self.scaler is not None
                                else list(self.pool_scalers.values()))

        self.orig: dict[int, SimRequest] = {}
        self.assignments: dict[int, list[int]] = {}
        self.prefill_recs: dict[int, ReqRecord] = {}
        self.decode_recs: dict[int, ReqRecord] = {}
        self.retry_heap: list[tuple[float, int, int, SimRequest]] = []
        self.xfers: list[tuple[float, int, SimRequest]] = []
        self.seq = 0
        self.shed: list[SimRequest] = []
        self.retries = 0
        self.scale_events: list[dict] = []
        self.xfer_count, self.xfer_bytes, self.xfer_seconds = 0, 0.0, 0.0
        # seeded backoff jitter: a dedicated stream, created lazily so a
        # run that never sheds (or sets retry_jitter=0) draws nothing
        self._retry_rng = None
        # fault injection: chaos off contributes an empty deque, zero RNG
        # draws, and nothing to the event merge (bit-identical runs)
        ch = spec.chaos
        self.chaos_on = ch is not None and ch.enabled
        self.chaos_events = deque(ch.schedule()) if self.chaos_on else deque()
        self._link_windows: list[tuple[float, float, float]] = []
        self.crashes = self.stragglers = self.link_degrades = 0
        self.n_displaced = self.requests_lost = self.stalls = 0
        self.lost_kv_tokens = 0
        self.re_prefill_tokens = self.restored_tokens = 0
        self._displaced: set[int] = set()  # crash-displaced, awaiting re-dispatch
        self._watches: list[dict] = []  # per-crash recovery tracking
        self._watch_by_rid: dict[int, list[dict]] = {}
        # admission front door (evaluated per arrival BEFORE routing; the
        # shed/retry path still applies after dispatch)
        self.door = (make_admission(spec.admission)
                     if spec.admission is not None else None)

    # ----------------------------------------------------------- fleet changes
    def _cost_for(self, rs: ReplicaSpec) -> ServingCostModel:
        key = rs.cost_key()
        if key not in self.cache:
            self.cache[key] = rs.build_cost(self.cfg)
        return self.cache[key]

    def _make_scaler(self, asc: AutoscaleConfig, pool: str,
                     service_pool: str | None = None) -> Autoscaler:
        """Build a control loop priced from `pool`'s first template
        replica; `service_pool` overrides which pool variant the
        predictive E[S] models (defaults to the pool itself)."""
        tmpl = self._templates[pool][0]
        return Autoscaler(asc, cost=self._cost_for(tmpl), sched=tmpl.sched,
                          pool=service_pool or pool)

    def _asc_for(self, pool: str) -> AutoscaleConfig:
        if pool in self.pool_scalers:
            return self.pool_scalers[pool].asc
        return self.scaler.asc

    def _add_rep(self, rs: ReplicaSpec, pool: str, *, started: float,
                 ready: float) -> _Rep:
        cost = self._cost_for(rs)
        sched = rs.sched
        if self.pcache is not None and pool != "decode":
            # carve the cache budget out of the replica's KV capacity:
            # cache warmth and live sequences compete for the same DRAM.
            # The infinite budget (the legacy free-cache assumption) does
            # not carve — that is the bit-for-bit parity anchor.
            full = (sched.kv_capacity if sched.kv_capacity is not None
                    else cost.kv_capacity_bytes)
            budget = self.pcache.pc.budget_for(full)
            if budget > 0 and not self.pcache.pc.infinite:
                seq_cap = full - budget
                if seq_cap <= 0:
                    raise ValueError(
                        f"prefix-cache budget ({budget / 1e9:.2f} GB) leaves "
                        f"no KV capacity for live sequences "
                        f"(replica budget {full / 1e9:.2f} GB)")
                sched = replace(sched, kv_capacity=seq_cap)
            self.pcache.register(len(self.reps), budget, cost)
        rep = _Rep(sim=make_replica_sim(cost, sched, engine=self.engine,
                                        name=f"r{len(self.reps)}:{pool}",
                                        tracer=self.tracer),
                   spec=rs, cost=cost, pool=pool, started=started, ready=ready)
        idx = len(self.reps)
        self.reps.append(rep)
        self._depth.append(0)
        if ready <= started:
            bisect.insort(self._members.setdefault(pool, []), idx)
        else:
            heapq.heappush(self._warming.setdefault(pool, []), (ready, idx))
        return rep

    def _promote(self, pool: str, t: float) -> None:
        """Move replicas whose warmup has elapsed by `t` from the warming
        heap into the pool's accepting set (cancelled/crashed ones are
        skipped lazily — they stopped being provisioned while warming)."""
        wh = self._warming.get(pool)
        if not wh:
            return
        lst = self._members.setdefault(pool, [])
        while wh and wh[0][0] <= t:
            _, i = heapq.heappop(wh)
            if self.reps[i].provisioned:
                bisect.insort(lst, i)

    def _member_remove(self, i: int) -> None:
        lst = self._members.get(self.reps[i].pool)
        if lst:
            k = bisect.bisect_left(lst, i)
            if k < len(lst) and lst[k] == i:
                del lst[k]

    def _push_req(self, i: int, staged: SimRequest, *, cached: int = 0,
                  generated: int = 0) -> ReqRecord:
        """Push one request onto replica `i`, keeping the engine's O(1)
        depth counter current and waking the replica in the vectorized
        advance heap if it was idle (a working replica already has a live
        heap entry at its current clock)."""
        sim = self.reps[i].sim
        idle = not sim.has_work
        rec = sim.push(staged, cached=cached, generated=generated)
        self._depth[i] += 1
        if self._vec and idle:
            heapq.heappush(self._rheap, (sim.now, i))
            if self._use_pheap and self.reps[i].pool == "prefill":
                heapq.heappush(self._pheap, (sim.now, i))
        return rec

    def _pick_fast(self, router, elig: list[int]) -> tuple[int, int]:
        """`router.pick` over the eligible set without building views:
        identical argmin (depth, kv, idx) semantics from the engine's own
        counters. Only called for `_FAST_ROUTERS` policies."""
        depth = self._depth
        reps = self.reps
        if type(router) is JoinShortestQueueRouter:
            # depth 0 means no outstanding work, hence kv_used == 0.0:
            # the first idle index is the exact (depth, kv, idx) argmin,
            # so a lightly loaded fleet picks in O(1) instead of O(fleet)
            best = -1
            bd = -1
            for i in elig:
                d = depth[i]
                if d == 0:
                    best, bd = i, 0
                    break
                if bd < 0 or d < bd:
                    bd = d
            if best < 0:
                bkv = 0.0  # kv_used only breaks depth ties
                for i in elig:
                    if depth[i] == bd:
                        kv = reps[i].sim.kv_used
                        if best < 0 or kv < bkv:
                            best, bkv = i, kv
            router.last_pick = {"router": router.name, "depth": bd}
            return best, 0
        if type(router) is RoundRobinRouter:
            i = elig[router._i % len(elig)]
            router._i += 1
            router.last_pick = {"router": router.name, "slot": router._i - 1}
            return i, 0
        # LeastKVLoadRouter
        best = -1
        bkey = None
        for i in elig:
            sim = reps[i].sim
            frac = sim.kv_used / sim.cap if sim.cap > 0 else 0.0
            key = (frac, depth[i])
            if bkey is None or key < bkey:
                best, bkey = i, key
        router.last_pick = {"router": router.name, "kv_frac": bkey[0],
                            "depth": bkey[1]}
        return best, 0

    def _spawn(self, pool: str, t: float) -> None:
        tmpls = self._templates[pool]
        rs = tmpls[self._tmpl_i[pool] % len(tmpls)]
        self._tmpl_i[pool] += 1
        warm = self._asc_for(pool).warmup_seconds(self._cost_for(rs))
        rep = self._add_rep(rs, pool, started=t, ready=t + warm)
        self.scale_events.append(
            {"t": t, "action": "add", "replica": self.reps.index(rep),
             "pool": pool, "ready": rep.ready})
        if self._tr_sum:
            self.tracer.instant("scale.up", t, rep.sim.name, pool=pool,
                                replica=self.reps.index(rep), ready=rep.ready)

    def _on_retired(self, i: int) -> None:
        """Replica `i` has left the fleet for good: routers prune their
        per-replica state (session pins, debt windows) and the cache model
        drops anything still marked resident there. Indices are never
        reused, so pruning is behavior-neutral — it bounds state growth
        across joins/leaves on long traces."""
        self.router.on_retire(i)
        self.d_router.on_retire(i)
        if self.pcache is not None:
            self.pcache.invalidate(i)

    def _retire(self, i: int, t: float) -> None:
        """Cancel a still-warming replica: it never took traffic; billing
        stops now (the partial warmup was still paid for)."""
        rep = self.reps[i]
        rep.retired = t
        self.scale_events.append(
            {"t": t, "action": "cancel", "replica": i, "pool": rep.pool})
        if self._tr_sum:
            self.tracer.instant("scale.cancel", t, rep.sim.name,
                                pool=rep.pool, replica=i)
        self._on_retired(i)

    def _drain(self, i: int, t: float) -> None:
        rep = self.reps[i]
        rep.drain_start = t
        self._member_remove(i)
        self._draining.add(i)
        self.scale_events.append(
            {"t": t, "action": "drain", "replica": i, "pool": rep.pool})
        if self._tr_sum:
            self.tracer.instant("scale.down", t, rep.sim.name,
                                pool=rep.pool, replica=i)
        if self.pcache is not None:
            # the cache dies with the replica: a draining replica admits
            # nothing new, so its warmth is unreachable from here on and
            # the re-warm cost lands on whichever replicas inherit the
            # traffic (autoscale churn is no longer free)
            if self._tr_sum and i in self.pcache.caches:
                self.tracer.instant(
                    "cache.invalidate", t, rep.sim.name, pool=rep.pool,
                    replica=i,
                    dropped_bytes=self.pcache.caches[i].used_bytes)
            self.pcache.invalidate(i)
        if rep.pool == "decode":
            # queued-but-unstarted KV handoffs re-route to the surviving
            # decode replicas; the cache sits on the draining replica, so
            # the re-route pays a second p2p hop and re-enters the punctual
            # transfer queue (the decode router picks the target when the
            # KV lands, so mid-stream pool changes are tolerated)
            evicted = rep.sim.evict_pending(include_staged=True)
            self._depth[i] -= len(evicted)
            for req in evicted:
                orig = self.orig[req.rid]
                nbytes = rep.cost.kv_handoff_bytes(orig.prompt)
                dt = self._xfer_dt(nbytes, t)
                heapq.heappush(self.xfers, (t + dt, self.seq, orig))
                self.seq += 1
                self.xfer_count += 1
                self.xfer_bytes += nbytes
                self.xfer_seconds += dt
                if self._tr_req:
                    self._handoff_log.setdefault(orig.rid, []).append(
                        (t, t + dt, nbytes))
            return
        evicted = rep.sim.evict_pending()
        self._depth[i] -= len(evicted)
        for req in evicted:
            # stage requests (disagg prefill pushes output=1) map back to
            # the original arrival before re-routing
            self._dispatch(self.orig[req.rid], t, attempt=0)

    def _pool_counts(self, pool: str) -> list[int]:
        return [i for i, r in enumerate(self.reps)
                if r.pool == pool and r.provisioned]

    def _scale_pool(self, pool: str, want: int, t: float) -> None:
        alive = self._pool_counts(pool)
        for _ in range(max(0, want - len(alive))):
            self._spawn(pool, t)
        excess = len(alive) - want
        if excess <= 0:
            return
        # cancel warming replicas first (newest first) — they hold no work
        warming = [i for i in alive if self.reps[i].ready > t]
        for i in sorted(warming, reverse=True)[:excess]:
            self._retire(i, t)
        excess -= min(excess, len(warming))
        if excess <= 0:
            return
        # then drain the emptiest accepting replicas (newest breaks ties),
        # always leaving at least one accepting replica in the pool
        accepting = [i for i in alive if self.reps[i].ready <= t]
        order = sorted(accepting,
                       key=lambda i: (self.reps[i].sim.queue_len
                                      + self.reps[i].sim.live,
                                      self.reps[i].sim.kv_used, -i))
        for i in order[:excess]:
            if len([j for j in accepting if not self.reps[j].draining]) <= 1:
                break
            self._drain(i, t)

    def _pool_kv_frac(self, pool: str, t: float) -> float:
        """Mean KV-occupancy fraction over the pool's accepting replicas —
        the instantaneous half of the `kv_tpot` scaling signal."""
        fracs = [rep.sim.kv_used / rep.sim.cap
                 for rep in self.reps
                 if rep.pool == pool and rep.accepting(t) and rep.sim.cap > 0]
        return sum(fracs) / len(fracs) if fracs else 0.0

    def _tick(self, t: float) -> None:
        """Fleet-wide control tick: one desired count, split across pools
        by the spec's template ratio for disaggregated fleets."""
        provisioned = [r for r in self.reps if r.provisioned]
        # KV pressure lives where the cache is resident: the decode pool
        # on disaggregated fleets (prefill holds KV only transiently)
        kv_pool = "decode" if self.disagg else self.arrival_pool
        want = self.scaler.desired(t, len(provisioned),
                                   kv_frac=self._pool_kv_frac(kv_pool, t))
        if self._tr_sum:
            self.tracer.instant("autoscale.decision", t,
                                pool=self.arrival_pool if not self.disagg
                                else "fleet",
                                **self.scaler.last_decision)
        if self.disagg:
            base_p = len(self.spec.pool_indices("prefill"))
            base_d = len(self.spec.pool_indices("decode"))
            want = max(want, 2)  # structural floor: >= 1 per pool
            want_p = max(1, min(want - 1,
                                round(want * base_p / (base_p + base_d))))
            self._scale_pool("prefill", want_p, t)
            self._scale_pool("decode", want - want_p, t)
        else:
            self._scale_pool("mixed", want, t)

    def _tick_pool(self, pool: str, t: float) -> None:
        """Pool-aware control tick: this pool's scaler alone decides this
        pool's size, on this pool's signals (the other pool is untouched)."""
        scaler = self.pool_scalers[pool]
        provisioned = len(self._pool_counts(pool))
        want = scaler.desired(t, provisioned,
                              kv_frac=self._pool_kv_frac(pool, t))
        if self._tr_sum:
            self.tracer.instant("autoscale.decision", t, pool=pool,
                                **scaler.last_decision)
        self._scale_pool(pool, want, t)

    # ------------------------------------------------------------ resilience
    def _retry_delay(self, attempt: int) -> float:
        """Exponential backoff with seeded jitter for retry `attempt`
        (0-based). Jitter spreads a burst that shed together UPWARD from
        the deterministic base, so existing lower bounds (a retry never
        lands before `retry_after`) keep holding."""
        d = self.spec.retry_after * self.spec.retry_backoff ** attempt
        if self.spec.retry_jitter > 0.0:
            if self._retry_rng is None:
                self._retry_rng = np.random.default_rng(
                    np.random.SeedSequence(self.spec.retry_seed).spawn(1)[0])
            d *= 1.0 + self.spec.retry_jitter * float(self._retry_rng.random())
        return d

    def _pool_recoverable(self, pool: str) -> bool:
        """Can `pool` ever accept traffic again? True while any member is
        provisioned (a warming replica starts accepting at `ready`) or a
        control loop exists that will respawn one (scalers always restore
        their pool to >= min_replicas while work is pending)."""
        if any(r.pool == pool and r.provisioned for r in self.reps):
            return True
        return self.scaler is not None or pool in self.pool_scalers

    def _stall(self, req: SimRequest, t: float, attempt: int) -> None:
        """No accepting replica in the arrival pool right now (all
        warming or draining mid-scale-down, or killed by chaos): park the
        request and retry once capacity can exist — or shed it when
        nothing can ever accept again. Stalls do not consume retry
        budget; an outage is not overload."""
        if self._pool_recoverable(self.arrival_pool):
            self.stalls += 1
            heapq.heappush(self.retry_heap,
                           (t + self._retry_delay(attempt), self.seq,
                            attempt, req))
            self.seq += 1
            if self._tr_sum:
                self.tracer.instant("request.stall", t, rid=req.rid,
                                    pool=self.arrival_pool)
        else:
            self._lose(req, t, reason="no_capacity", attempts=attempt)

    def _lose(self, req: SimRequest, t: float, *, reason: str,
              attempts: int = 0) -> None:
        """Terminal availability loss (dead pool, work parked past the
        horizon): counted in `shed` for the exactly-once conservation
        invariant AND in `requests_lost` for the resilience columns."""
        self.shed.append(req)
        self.requests_lost += 1
        self._note_terminal(req.rid, t, ok=False)
        if self._tr_sum:
            self.tracer.instant("request.shed", t, rid=req.rid,
                                reason=reason, attempts=attempts)

    def _note_terminal(self, rid: int, t: float, ok: bool) -> None:
        """Feed a request's terminal outcome to the admission door (the
        circuit breaker's failure signal) and close any crash-recovery
        watches it was displaced into."""
        if self.door is not None:
            self.door.observe(rid, t, ok)
        ws = self._watch_by_rid.pop(rid, None)
        if ws:
            for w in ws:
                w["open"].discard(rid)
                if not w["open"] and w["dt"] is None:
                    w["dt"] = t - w["t0"]
        if not ok:
            self._displaced.discard(rid)

    def _xfer_dt(self, nbytes: float, t: float) -> float:
        """KV-handoff transfer time at `t` — the p2p price stretched by
        any chaos link-degradation window active at the departure."""
        dt = C.p2p(nbytes, self.xfer_net)
        if self._link_windows:
            f = 1.0
            for t0, t1, factor in self._link_windows:
                if t0 <= t < t1:
                    f = max(f, factor)
            dt *= f
        return dt

    def _fire_chaos(self, ev) -> None:
        """Apply one scheduled fault against live fleet state. Victims
        are selected among the replicas alive at the fire instant via the
        event's pre-sampled uniforms; an event with no eligible victim is
        a no-op (the fleet is already dead or fully degraded)."""
        t = ev.t
        if ev.kind == "crash" or ev.kind == "node_failure":
            elig = [i for i, r in enumerate(self.reps) if r.retired < 0]
            victims = pick_victims(ev.picks, elig, ev.count)
            if ev.kind == "node_failure" and victims and self._tr_sum:
                self.tracer.instant("chaos.node_failure", t,
                                    count=len(victims),
                                    replicas=list(victims))
            for i in victims:
                self._crash(i, t)
        elif ev.kind == "straggler":
            elig = [i for i, r in enumerate(self.reps) if r.accepting(t)]
            for i in pick_victims(ev.picks, elig, 1):
                self.reps[i].sim.set_slowdown(ev.factor, t + ev.duration,
                                              start=t)
                self.stragglers += 1
                if self._tr_sum:
                    self.tracer.instant("chaos.straggler", t,
                                        self.reps[i].sim.name, replica=i,
                                        factor=ev.factor,
                                        until=t + ev.duration)
        else:  # link degradation: cluster-wide handoff-interconnect event
            self._link_windows.append((t, t + ev.duration, ev.factor))
            self.link_degrades += 1
            if self._tr_sum:
                self.tracer.instant("chaos.link_degrade", t,
                                    factor=ev.factor, until=t + ev.duration)

    def _crash(self, i: int, t: float) -> None:
        """Kill replica `i` instantly: billing stops now, in-flight KV is
        lost, and every unfinished request re-enters dispatch — where it
        re-prefills from scratch or restores its prefix from a surviving
        replica's cache (`_dispatch` consults the fleet prefix cache as
        usual; `re_prefill_tokens`/`restored_tokens` account the split)."""
        rep = self.reps[i]
        if rep.retired >= 0:
            return
        displaced = rep.sim.kill()
        rep.retired = t
        rep.crashed = True
        self._member_remove(i)
        self._draining.discard(i)
        self._depth[i] = 0
        self.crashes += 1
        self.scale_events.append(
            {"t": t, "action": "crash", "replica": i, "pool": rep.pool})
        if self._tr_sum:
            self.tracer.instant("replica.crash", t, rep.sim.name,
                                pool=rep.pool, replica=i,
                                displaced=len(displaced))
        self._on_retired(i)
        if not displaced:
            return
        watch: set[int] = set()
        for req, cached, generated, started in displaced:
            rid = req.rid
            watch.add(rid)
            if started:
                # work this replica had begun is lost; the re-dispatch
                # below accounts what must be re-processed vs restored
                self._displaced.add(rid)
                self.n_displaced += 1
                self.lost_kv_tokens += cached
            # the dead attempt's handoff spans would disorder the final
            # attempt's lifecycle in the trace: only the serving attempt
            # is kept (the crash instant records the disruption)
            self._handoff_log.pop(rid, None)
        w = {"t0": t, "open": set(watch), "dt": None}
        self._watches.append(w)
        for rid in watch:
            self._watch_by_rid.setdefault(rid, []).append(w)
        for req, _, _, _ in displaced:
            self._dispatch(self.orig[req.rid], t, attempt=0)

    # -------------------------------------------------------------- dispatch
    def _dispatch(self, req: SimRequest, t: float, attempt: int) -> None:
        if self.pcache is not None:
            # a request re-entering dispatch (drain requeue, shed retry)
            # may carry hit/miss accounting from a dispatch whose prefill
            # never ran — retract it; only the dispatch that actually
            # serves the request counts
            prev = self._counted.pop(req.rid, None)
            if prev is not None:
                self.pcache.uncount(*prev)
        if self._vec:
            self._promote(self.arrival_pool, t)
            elig = self._members.get(self.arrival_pool) or []
        else:
            elig = [i for i, r in enumerate(self.reps)
                    if r.pool == self.arrival_pool and r.accepting(t)]
        if not elig:
            # zero accepting replicas (all warming/draining during an
            # aggressive scale-down, or killed by chaos): park and retry
            # instead of crashing on the empty pool
            self._stall(req, t, attempt)
            return
        fast = self._vec and type(self.router) in _FAST_ROUTERS
        views = None if fast else _views(self.reps, elig, at=t)
        if self.spec.shed_depth is not None and (
                min(self._depth[i] for i in elig) if fast
                else min(v.depth for v in views)) >= self.spec.shed_depth:
            if attempt < self.spec.max_retries:
                self.retries += 1
                retry_at = t + self._retry_delay(attempt)
                heapq.heappush(self.retry_heap,
                               (retry_at, self.seq, attempt + 1, req))
                self.seq += 1
                if self._tr_sum:
                    self.tracer.instant("request.retry", t, rid=req.rid,
                                        attempt=attempt + 1,
                                        retry_at=retry_at)
            else:
                self.shed.append(req)
                self._note_terminal(req.rid, t, ok=False)
                if self._tr_sum:
                    # terminal: shed outright, or dropped after retries
                    self.tracer.instant(
                        "request.drop" if attempt > 0 else "request.shed",
                        t, rid=req.rid, reason="queue_full", attempts=attempt)
            return
        i, cached = (self._pick_fast(self.router, elig) if fast
                     else self.router.pick(req, views))
        if self.pcache is not None:
            # modeled residency overrides any router-side discount: the
            # lookup counts the hit, then reserves this request's own
            # prefix on the replica (the prefill that materializes it is
            # now scheduled there), evicting LRU entries to fit
            cached = self.pcache.use(i, req, t)
            if prefix_key(req) is not None:
                self._counted[req.rid] = (i, cached)
            if self._tr_rep:
                self.tracer.counter("cache_bytes", t,
                                    self.pcache.caches[i].used_bytes,
                                    self.reps[i].sim.name)
        if self._displaced and req.rid in self._displaced:
            # crash-displaced work lands again: whatever prefix survives
            # on the chosen replica's cache is restored, the rest of the
            # prompt is re-prefilled from scratch
            self._displaced.discard(req.rid)
            self.re_prefill_tokens += max(0, req.prompt - cached)
            self.restored_tokens += cached
        if self._tr_req:
            self.tracer.instant("dispatch", t, self.reps[i].sim.name,
                                rid=req.rid, replica=i, attempt=attempt,
                                cached=cached, **self.router.last_pick)
        # retried / drain-requeued requests re-enter at the dispatch time
        # (a replica's clock may lag global time when idle, and admission
        # must not predate the re-dispatch); cluster records are stitched
        # back onto the original arrival so TTFT keeps the backoff paid
        staged = replace(req, arrival=t, output=1) if self.disagg \
            else replace(req, arrival=t)
        rec = self._push_req(i, staged, cached=cached)
        if self.disagg:
            # prefill stage ends at the first token; decode happens elsewhere
            self.prefill_recs[req.rid] = rec
        self.assignments[req.rid] = [i, -1]

    def _dispatch_xfer(self, ready: float, req: SimRequest) -> None:
        if self._vec:
            self._promote("decode", ready)
            elig = self._members.get("decode") or []
        else:
            elig = [i for i, r in enumerate(self.reps)
                    if r.pool == "decode" and r.accepting(ready)]
        if not elig:
            # the KV landed but no decode replica can take it (all
            # warming, or killed by chaos): park the transfer until one
            # can — or shed when the pool can never recover
            if self._pool_recoverable("decode"):
                self.stalls += 1
                heapq.heappush(self.xfers,
                               (ready + self.spec.retry_after, self.seq, req))
                self.seq += 1
                if self._tr_sum:
                    self.tracer.instant("request.stall", ready, rid=req.rid,
                                        pool="decode")
            else:
                self._lose(req, ready, reason="no_capacity")
            return
        if self._vec and type(self.d_router) in _FAST_ROUTERS:
            j, _ = self._pick_fast(self.d_router, elig)
        else:
            j, _ = self.d_router.pick(req, _views(self.reps, elig, at=ready))
        self.decode_recs[req.rid] = self._push_req(
            j, replace(req, arrival=ready), cached=req.prompt, generated=1)
        self.assignments[req.rid][1] = j

    # --------------------------------------------------------------- advance
    def _harvest(self, i: int, done: list[ReqRecord]) -> None:
        rep = self.reps[i]
        pool_scaler = self.pool_scalers.get(rep.pool) or self.scaler
        for rec in done:
            if self._tr_sum:
                self._emit_terminal(rep, rec)
            if (self.door is not None or self._watch_by_rid) and (
                    rep.pool != "prefill"
                    or self.orig[rec.rid].output <= 1):
                # last stage of this request finished: feed the admission
                # door's breaker and close any crash-recovery watches
                self._note_terminal(rec.rid, rec.finish, ok=True)
            if rep.pool in ("mixed", "prefill") and rec.first_token >= 0:
                # end-to-end TTFT, from the ORIGINAL arrival: shed-retry
                # backoff counts as debt (the user waited through it), so
                # the signals see the same SLO breach the stitched records
                # report instead of the replica-local staged wait
                ttft = rec.first_token - self.orig[rec.rid].arrival
                self.router.observe(i, rec.finish, ttft)
                if self.pcache is not None:
                    # the prefill completed at the FIRST token (decode
                    # continues after, but the prefix KV became resident
                    # then): refresh recency at that instant so colocated
                    # and disaggregated pools age entries identically
                    self.pcache.commit(i, self.orig[rec.rid], rec.first_token)
                    if self._tr_rep and i in self.pcache.caches:
                        self.tracer.counter(
                            "cache_bytes", rec.first_token,
                            self.pcache.caches[i].used_bytes, rep.sim.name)
                for sc in self._signal_scalers:
                    sc.observe_ttft(rec.finish, ttft)
            if pool_scaler is not None and rec.admitted >= 0:
                # pool-local signals: the admission wait a prefill (or
                # mixed) pool queues prompts behind — end-to-end, so shed
                # backoff counts — and the stage-local handoff wait on a
                # decode pool; TPOT debt from any pool that decodes
                if rep.pool == "decode":
                    wait = rec.admitted - rec.arrival
                    # a FLEET-wide queue_wait signal must see only the
                    # user-facing admission wait; blending near-zero
                    # handoff waits into the same mean would halve it
                    feed_wait = rep.pool in self.pool_scalers
                    # the decode stage's TPOT debt is charged from the
                    # instant the KV landed: queueing behind a full pool
                    # stretches the stitched record's inter-token gap, so
                    # the signal must see it too, not just the post-
                    # admission decode cadence
                    if rec.output > 1:
                        pool_scaler.observe_tpot(
                            rec.finish,
                            (rec.finish - rec.arrival) / (rec.output - 1))
                else:
                    wait = rec.admitted - self.orig[rec.rid].arrival
                    feed_wait = True
                    if rec.output > 1 and rep.pool == "mixed" \
                            and rec.first_token >= 0:
                        pool_scaler.observe_tpot(rec.finish, rec.tpot)
                if feed_wait:
                    pool_scaler.observe_wait(rec.finish, wait)
            if rep.pool != "prefill":
                continue
            req = self.orig[rec.rid]
            if req.output <= 1:
                continue  # single-token request: served entirely by prefill
            nbytes = rep.cost.kv_handoff_bytes(req.prompt)
            dt = self._xfer_dt(nbytes, rec.finish)
            heapq.heappush(self.xfers, (rec.finish + dt, self.seq, req))
            self.seq += 1
            self.xfer_count += 1
            self.xfer_bytes += nbytes
            self.xfer_seconds += dt
            if self._tr_req:
                self._handoff_log.setdefault(req.rid, []).append(
                    (rec.finish, rec.finish + dt, nbytes))

    def _emit_terminal(self, rep: _Rep, rec: ReqRecord) -> None:
        """LIVE `request.complete` emission, at the moment the request's
        last stage finishes — what lets the SLO monitor see completions at
        sim time instead of after the run. Values are end-to-end, stitched
        against the ORIGINAL arrival, identical to the post-run records
        (`result()` builds the same numbers from the same fields)."""
        rid = rec.rid
        orig = self.orig[rid]
        if rep.pool == "mixed":
            ttft = rec.first_token - orig.arrival if rec.first_token >= 0 else 0.0
            tpot = ((rec.finish - rec.first_token) / (rec.output - 1)
                    if rec.output > 1 and rec.first_token >= 0 else 0.0)
        elif rep.pool == "decode":
            pre = self.prefill_recs[rid]
            ttft = pre.first_token - orig.arrival
            tpot = ((rec.finish - pre.first_token) / (rec.output - 1)
                    if rec.output > 1 else 0.0)
        else:  # prefill pool: terminal only for single-token requests
            if orig.output > 1:
                return  # hands off to decode; that stage emits the terminal
            ttft = rec.first_token - orig.arrival if rec.first_token >= 0 else 0.0
            tpot = 0.0
        self.tracer.instant("request.complete", rec.finish, rep.sim.name,
                            rid=rid, ttft=ttft, tpot=tpot,
                            e2e=rec.finish - orig.arrival)

    def _check_drained(self) -> None:
        # `_draining` holds exactly the drain-started, not-yet-retired
        # indices, so this is O(active drains) per event — not O(fleet) —
        # and visiting it in index order matches the reference full scan.
        if not self._draining:
            return
        for i in sorted(self._draining):
            rep = self.reps[i]
            if rep.draining and rep.retired < 0 and not rep.sim.has_work:
                rep.retired = max(rep.sim.now, rep.drain_start)
                self._draining.discard(i)
                self._on_retired(i)
                if self._tr_sum:
                    self.tracer.instant("replica.retired", rep.retired,
                                        rep.sim.name, pool=rep.pool,
                                        replica=i)

    def _advance_all(self, t: float) -> None:
        """Advance every replica to `t` in lockstep (least-clock first),
        dispatching KV handoffs punctually the moment they become ready.

        Each pending handoff's ready time is a sub-target: all replicas
        are stepped up to it BEFORE the handoff is routed, so the decode
        router always observes the fleet as of the dispatch instant. The
        resulting step/dispatch sequence is a global merge ordered by
        (sim clock, handoff ready) and therefore invariant to the
        advance's intermediate targets — advancing to t' then t equals
        advancing straight to t — which is what lets autoscaler control
        ticks observe the fleet without perturbing the schedule (the
        pinned-bounds parity contract).

        The vectorized engine reproduces this exact merge without the
        per-step O(replicas) candidate scan — see `_advance_all_vec`."""
        if self._vec:
            self._advance_all_vec(t)
            return
        while True:
            t_sub = min(t, self.xfers[0][0]) if self.xfers else t
            cands = [(rep.sim.now, i) for i, rep in enumerate(self.reps)
                     if rep.sim.has_work and rep.sim.now < t_sub]
            if cands:
                _, i = min(cands)
                self._harvest(i, self.reps[i].sim.step())
                continue
            if self.xfers and self.xfers[0][0] <= t:
                ready, _, req = heapq.heappop(self.xfers)
                self._dispatch_xfer(ready, req)
                continue
            break
        self._check_drained()

    def _rheap_top(self) -> tuple[float, int] | None:
        """Least (clock, idx) replica that still has work, or None. Stale
        entries (the replica stepped, finished, or was killed since the
        push) are discarded lazily; every working replica always owns one
        live entry at exactly its current clock."""
        h = self._rheap
        while h:
            c, i = h[0]
            sim = self.reps[i].sim
            if sim.has_work and sim.now == c:
                return h[0]
            heapq.heappop(h)
        return None

    def _pheap_top(self, skip: int) -> float:
        """Least clock among the prefill replicas with work, excluding
        `skip` (the replica about to advance; its entry is dropped here
        and re-pushed after the chunk). Bounds how far any replica may
        batch ahead: a new KV handoff's ready time can only be created at
        or after this clock."""
        h = self._pheap
        while h:
            c, i = h[0]
            sim = self.reps[i].sim
            if i == skip or not (sim.has_work and sim.now == c):
                heapq.heappop(h)
                continue
            return c
        return _INF

    def _flush_hbuf(self, bound: tuple[float, int] | None) -> None:
        """Harvest buffered completion batches in global (step start,
        replica idx) order — the exact order the reference loop's merge
        harvests them in — up to (not including) `bound`. `None` flushes
        everything."""
        hb = self._hbuf
        while hb and (bound is None or (hb[0][0], hb[0][1]) < bound):
            _, i, _, recs = heapq.heappop(hb)
            self._depth[i] -= len(recs)
            self._harvest(i, recs)

    def _advance_all_vec(self, t: float) -> None:
        """`_advance_all`, batched: replicas advance in multi-iteration
        chunks instead of one globally-merged step at a time, and
        completions buffer in `_hbuf` until every step that the reference
        merge orders before them has run. Chunk caps keep the merge
        exact:

          * `t_sub` (next handoff ready): same sub-target as the
            reference loop.
          * the least prefill-pool clock: a NEW handoff's ready time is
            `completion + dt`, so it can only appear at or after that
            clock — no other replica may batch past it. Prefill replicas
            additionally stop at their own completions (`stop_on_done`),
            re-evaluating caps once the handoff is on the heap.
          * equal clocks fall back to single steps, preserving the
            reference tie order (idx).

        Colocated fleets have no handoffs: every working replica advances
        straight to `t` in one chunk and the buffer is drained sorted."""
        reps = self.reps
        heap = self._rheap
        while True:
            top = self._rheap_top()
            self._flush_hbuf(top)
            # flushed harvests may have pushed new handoffs: re-read
            t_x = self.xfers[0][0] if self.xfers else _INF
            t_sub = t if t <= t_x else t_x
            if top is None or top[0] >= t_sub:
                if self.xfers and t_x <= t:
                    ready, _, req = heapq.heappop(self.xfers)
                    self._dispatch_xfer(ready, req)
                    continue
                break
            c1, i = heapq.heappop(heap)
            rep = reps[i]
            sim = rep.sim
            stop_done = False
            if self._lockstep:
                cap, single = t_sub, True
            elif not self.disagg:
                cap, single = t, False  # no handoffs: t_sub == t
            else:
                if rep.pool == "prefill":
                    stop_done = True
                c_p = self._pheap_top(i)
                cap = min(t_sub, c_p)
                single = cap <= c1
                if single:
                    cap = t_sub
            for start, recs in sim.advance_chunk(cap, single=single,
                                                 stop_on_done=stop_done):
                heapq.heappush(self._hbuf, (start, i, self._hseq, recs))
                self._hseq += 1
            if sim.has_work:
                heapq.heappush(heap, (sim.now, i))
                if self._use_pheap and rep.pool == "prefill":
                    heapq.heappush(self._pheap, (sim.now, i))
        self._check_drained()

    @property
    def _sim_work(self) -> bool:
        if self._vec:
            return self._rheap_top() is not None
        return any(r.sim.has_work for r in self.reps)

    # -------------------------------------------------------------- main loop
    def run(self, ordered: list[SimRequest]) -> None:
        self.orig = {r.rid: r for r in ordered}
        arrivals = deque(ordered)
        # one tick stream per control loop: the fleet-wide scaler (key
        # None) or each pool's scaler, on its own interval; at equal times
        # pools tick in the spec's pool order (deterministic)
        if self.scaler is not None:
            intervals: dict = {None: self.scaler.asc.interval}
        else:
            intervals = {p: self.pool_scalers[p].asc.interval
                         for p in self._templates if p in self.pool_scalers}
        next_tick = dict(intervals)
        while True:
            t_arr = arrivals[0].arrival if arrivals else _INF
            t_rty = self.retry_heap[0][0] if self.retry_heap else _INF
            t_xfr = self.xfers[0][0] if self.xfers else _INF
            # ticks stop once nothing is pending anywhere (else they'd
            # fire forever); pending work keeps the control loop honest
            pending = bool(arrivals or self.retry_heap or self.xfers
                           or self._sim_work)
            t_tck = min(next_tick.values()) if next_tick and pending else _INF
            # chaos events, like control ticks, fire only while work is
            # pending: faults against a finished fleet change nothing
            t_chs = (self.chaos_events[0].t
                     if self.chaos_events and pending else _INF)
            t_evt = min(t_arr, t_rty, t_xfr, t_tck, t_chs)
            if t_evt == _INF:
                if self._sim_work or self.xfers:
                    self._advance_all(_INF)  # final drain (punctual handoffs)
                    continue
                break
            self._advance_all(t_evt)  # handoffs ready <= t_evt dispatch inside
            if t_arr == t_evt:
                req = arrivals.popleft()
                for sc in self._signal_scalers:
                    sc.observe_arrival(req.arrival)
                if self.door is not None:
                    admit_at = self.door.offer(req.rid, req.arrival)
                    if admit_at is None:
                        # shed at the front door, before any dispatch
                        # attempt: counted in `shed` for conservation but
                        # attributed to the door, not `requests_lost`
                        self.shed.append(req)
                        self._note_terminal(req.rid, req.arrival, ok=False)
                        if self._tr_sum:
                            self.tracer.instant("request.shed", req.arrival,
                                                rid=req.rid,
                                                reason="admission")
                        continue
                    if admit_at > req.arrival:
                        # door-queued: dispatch at the exact conformance
                        # time, through the same heap retries use
                        heapq.heappush(self.retry_heap,
                                       (admit_at, self.seq, 0, req))
                        self.seq += 1
                        continue
                self._dispatch(req, req.arrival, attempt=0)
            elif t_rty == t_evt:
                t, _, attempt, req = heapq.heappop(self.retry_heap)
                self._dispatch(req, t, attempt)
            elif t_tck == t_evt:
                # the advance may have finished the last pending work this
                # tick was gated on; scaling an idle, finished fleet would
                # spawn replicas that never serve (and bill phantom spans)
                still_pending = bool(arrivals or self.retry_heap or self.xfers
                                     or self._sim_work)
                for key in list(next_tick):
                    if next_tick[key] != t_evt:
                        continue
                    if still_pending:
                        if key is None:
                            self._tick(t_evt)
                        else:
                            self._tick_pool(key, t_evt)
                    next_tick[key] += intervals[key]
            elif t_chs == t_evt:
                self._fire_chaos(self.chaos_events.popleft())
            # else: the event was a transfer, consumed by the advance
        # conservation sweep: anything still parked when the run drains
        # (a retry scheduled past the last completion on a dead pool, a
        # handoff stalled forever) is a terminal loss, never a silent
        # disappearance — completed + shed == generated must hold
        while self.retry_heap:
            t, _, attempt, req = heapq.heappop(self.retry_heap)
            self._lose(req, t, reason="horizon", attempts=attempt)
        while self.xfers:
            t, _, req = heapq.heappop(self.xfers)
            self._lose(req, t, reason="horizon")

    # ----------------------------------------------------------------- result
    def result(self) -> ClusterResult:
        shed_rids = {r.rid for r in self.shed}
        if self.disagg:
            records = []
            for req in self.orig.values():
                if req.rid in shed_rids:
                    continue
                pre = self.prefill_recs[req.rid]
                dec = self.decode_recs.get(req.rid)
                records.append(ReqRecord(
                    req.rid, req.arrival, req.prompt, req.output,
                    admitted=pre.admitted, first_token=pre.first_token,
                    finish=dec.finish if dec is not None else pre.finish,
                    preemptions=pre.preemptions
                    + (dec.preemptions if dec else 0)))
            mode = "disaggregated"
        else:
            # stitch back onto the original arrivals (retried requests were
            # re-pushed at their re-dispatch time)
            records = sorted(
                (ReqRecord(rec.rid, self.orig[rec.rid].arrival, rec.prompt,
                           rec.output, admitted=rec.admitted,
                           first_token=rec.first_token, finish=rec.finish,
                           preemptions=rec.preemptions)
                 for rep in self.reps for rec in rep.sim.res.records),
                key=lambda r: r.rid)
            mode = "colocated"
        end = max([rep.sim.now for rep in self.reps]
                  + [rep.retired for rep in self.reps] + [0.0])
        # clamp: a replica spawned near the end of the run (e.g. for a
        # retry that was ultimately shed) must never bill a negative span
        spans = [(rep.started,
                  max(rep.started, rep.retired if rep.retired >= 0 else end))
                 for rep in self.reps]
        if self.tracer.enabled:
            self._emit_trace(records, spans, end, mode)
        slo = None
        if self.monitor is not None:
            self.monitor.finish(end)
            slo = self.monitor.result()
        chaos_stats = None
        if self.chaos_on:
            rec_times = [w["dt"] for w in self._watches if w["dt"] is not None]
            chaos_stats = {
                "crashes": self.crashes,
                "stragglers": self.stragglers,
                "link_degrades": self.link_degrades,
                "displaced": self.n_displaced,
                "lost_kv_tokens": self.lost_kv_tokens,
                "re_prefill_tokens": self.re_prefill_tokens,
                "restored_tokens": self.restored_tokens,
                "stalls": self.stalls,
                "recovery_s_mean": (sum(rec_times) / len(rec_times)
                                    if rec_times else 0.0),
                "recovery_s_max": max(rec_times) if rec_times else 0.0,
            }
        return ClusterResult(
            mode=mode, records=records,
            replica_results=[rep.sim.res for rep in self.reps],
            replica_pools=[rep.pool for rep in self.reps],
            assignments={k: tuple(v) for k, v in self.assignments.items()},
            xfer_count=self.xfer_count, xfer_bytes=self.xfer_bytes,
            xfer_seconds=self.xfer_seconds,
            prefix_hits=(self.pcache.hits if self.pcache is not None
                         else self.router.hits
                         if isinstance(self.router, AffinityRouter) else 0),
            replica_specs=[rep.spec for rep in self.reps],
            replica_spans=spans, scale_events=self.scale_events,
            shed=list(self.shed), retries=self.retries,
            cache_stats=(self.pcache.stats() if self.pcache is not None
                         else None),
            slo=slo, t0=0.0, horizon=end,
            requests_lost=self.requests_lost, chaos_stats=chaos_stats,
            admission_stats=(self.door.stats() if self.door is not None
                             else None))

    def _emit_trace(self, records, spans, end: float, mode: str) -> None:
        """Post-run trace emission: replica structural spans (billing
        tracks, identical to `replica_spans`) and stitched per-request
        lifecycle spans (every rid's single terminal instant was already
        emitted live — `_emit_terminal`/`_dispatch`)."""
        tr = self.tracer
        tr.meta.update(t0=0.0, horizon=end, mode=mode)
        if self._tr_rep:
            for rep, (s, e) in zip(self.reps, spans):
                track = rep.sim.name
                tr.span("provisioned", s, e, track, pool=rep.pool)
                if rep.ready > s:
                    tr.span("warmup", s, min(rep.ready, e), track)
                if rep.draining:
                    drain0 = min(rep.drain_start, e)
                    tr.span("drain", drain0, e, track)
        if not self._tr_req:
            return
        by_rid = {rec.rid: rec for rec in records}
        for req in self.orig.values():
            rec = by_rid.get(req.rid)
            if rec is None:
                continue  # shed/dropped: terminal already emitted live
            rid = req.rid
            serve_i, dec_i = self.assignments.get(rid, (-1, -1))
            track = self.reps[serve_i].sim.name if serve_i >= 0 else ""
            if rec.admitted >= 0:
                tr.span("queued", req.arrival, rec.admitted, track, rid=rid)
            if rec.first_token >= 0 and rec.admitted >= 0:
                tr.span("prefill", rec.admitted, rec.first_token, track,
                        rid=rid)
            dec = self.decode_recs.get(rid) if self.disagg else None
            if dec is not None:
                dtrack = self.reps[dec_i].sim.name if dec_i >= 0 else ""
                for h0, h1, nbytes in self._handoff_log.get(rid, ()):
                    tr.span("handoff", h0, h1, dtrack, rid=rid, bytes=nbytes)
                if dec.admitted >= 0:
                    tr.span("decode_wait", dec.arrival, dec.admitted, dtrack,
                            rid=rid)
                    tr.span("decode", dec.admitted, dec.finish, dtrack,
                            rid=rid)
                track = dtrack
            elif not self.disagg and rec.finish >= 0 and rec.first_token >= 0:
                tr.span("decode", rec.first_token, rec.finish, track, rid=rid)
            # `request.complete` terminals are emitted LIVE in `_harvest`
            # (summary level), so the online monitor sees them at sim time


def simulate_cluster(requests: list[SimRequest], cfg: ModelConfig,
                     spec: ClusterSpec, *,
                     autoscale: AutoscaleConfig | dict | None = None,
                     tracer=None, monitor=None, engine: str = "vectorized",
                     _cost_cache: dict | None = None) -> ClusterResult:
    """Co-simulate the cluster over one shared arrival stream.

    Args:
        requests: the shared arrival stream (any order; sorted internally
            by (arrival, rid)).
        cfg: model config every replica serves.
        spec: fleet topology, routing, and shedding policy.
        autoscale: `None` for a fixed fleet; an `AutoscaleConfig` for one
            fleet-wide control loop (disaggregated fleets split the
            desired count by the spec's template pool ratio); or a
            `{pool: AutoscaleConfig}` dict to scale pools INDEPENDENTLY —
            each listed pool runs its own control loop on its own signals
            and bounds (pools not listed stay at their template size).
            With `autoscale`, `spec.replicas` is the fleet at t=0
            (already warm). A pinned control loop (`min == max == N`)
            reproduces the static cluster step-for-step — in fleet-wide
            AND pool-aware mode (regression-tested).
        tracer: a `repro.obs.Tracer` to record the run (None = untraced;
            tracing is purely observational and never changes the
            schedule — also regression-tested).
        engine: `"vectorized"` (default) advances replicas in batched
            multi-iteration chunks with struct-of-arrays replica state;
            `"reference"` is the original one-globally-merged-step-at-a-
            time loop. Both produce the same schedule (differentially
            tested, see `tests/test_engine_parity.py`); the reference
            engine exists as the oracle for that harness and as a
            fallback while debugging.
        monitor: a `repro.obs.SLOMonitor` to evaluate SLO compliance,
            burn-rate alerts, and anomaly detection ONLINE as the run
            executes. Attached as a tracer sink (a sink-only tracer is
            created when `tracer` is None), equally observational; the
            result lands in `ClusterResult.slo` and alert instants in
            the trace.
        _cost_cache: lets sweeps (the capacity planner) share memoized
            `ServingCostModel`s across many cluster candidates.

    Returns:
        `ClusterResult` with stitched cluster-level records, per-replica
        stage results, billing spans (seconds), and scale events.
    """
    spec.validate()
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")
    if isinstance(autoscale, AutoscaleConfig):
        autoscale.validate()
        if spec.disaggregated and autoscale.max_replicas < 2:
            raise ValueError(
                "disaggregated autoscaling needs max_replicas >= 2 "
                "(>= 1 prefill AND >= 1 decode replica at all times)")
    elif autoscale is not None:
        pools_present = {r.pool for r in spec.replicas}
        for pool, asc in autoscale.items():
            if pool not in pools_present:
                raise ValueError(
                    f"pool-aware autoscale names pool {pool!r} but the "
                    f"spec only has {sorted(pools_present)}")
            if not isinstance(asc, AutoscaleConfig):
                raise ValueError(
                    f"pool-aware autoscale values must be AutoscaleConfig, "
                    f"got {type(asc).__name__} for pool {pool!r}")
            asc.validate()
    cache = _cost_cache if _cost_cache is not None else {}
    eng = _ClusterEngine(spec, cfg, autoscale, cache, tracer, monitor,
                         engine=engine)
    eng.run(sorted(requests, key=lambda r: (r.arrival, r.rid)))
    return eng.result()


# ------------------------------------------------------------------ metrics
def summarize_cluster(cres: ClusterResult, *, slo_ttft: float | None = None,
                      slo_tpot: float | None = None) -> dict:
    """Cluster-level SLO metric dict over the stitched records, plus
    aggregate counters, the KV-transfer overhead share, and the dynamic-
    fleet provisioning economics (replica-hours vs static peak)."""
    span = cres.makespan
    out: dict = {"mode": cres.mode, "replicas": len(cres.replica_results)}
    out.update(summarize_records(cres.records, span=span,
                                 slo_ttft=slo_ttft, slo_tpot=slo_tpot))
    out["iterations"] = sum(r.iterations for r in cres.replica_results)
    out["preemptions"] = sum(r.preemptions for r in cres.replica_results)
    out["prefix_hits"] = cres.prefix_hits
    out["xfer_count"] = cres.xfer_count
    out["xfer_gb"] = cres.xfer_bytes / 1e9
    out["xfer_s_mean"] = (cres.xfer_seconds / cres.xfer_count
                          if cres.xfer_count else 0.0)
    e2e_total = sum(r.e2e for r in cres.records)
    out["xfer_share"] = cres.xfer_seconds / e2e_total if e2e_total > 0 else 0.0
    denom = max(span, 1e-12)
    out["replica_util"] = [r.busy_s / denom for r in cres.replica_results]
    out["shed"] = len(cres.shed)
    total = len(cres.records) + len(cres.shed)
    out["shed_frac"] = len(cres.shed) / total if total else 0.0
    out["retries"] = cres.retries
    out["requests_lost"] = cres.requests_lost
    if cres.chaos_stats is not None:
        ch = cres.chaos_stats
        out["chaos_crashes"] = ch["crashes"]
        out["chaos_stragglers"] = ch["stragglers"]
        out["chaos_link_degrades"] = ch["link_degrades"]
        out["displaced"] = ch["displaced"]
        out["re_prefill_tokens"] = ch["re_prefill_tokens"]
        out["restored_tokens"] = ch["restored_tokens"]
        out["recovery_s_mean"] = ch["recovery_s_mean"]
        out["recovery_s_max"] = ch["recovery_s_max"]
    if cres.admission_stats is not None:
        ad = cres.admission_stats
        out["door_admitted"] = ad["door_admitted"]
        out["door_delayed"] = ad["door_delayed"]
        out["door_shed"] = ad["door_shed"]
        out["breaker_opens"] = ad["breaker_opens"]
    if cres.cache_stats is not None:
        cs = cres.cache_stats
        looked = cs["hits"] + cs["misses"]
        out["cache_hit_tokens"] = cs["hit_tokens"]
        out["cache_hit_rate"] = cs["hits"] / looked if looked else 0.0
        out["cache_resident_gb"] = cs["peak_resident_bytes"] / 1e9
        out["cache_evictions"] = cs["evictions_lru"] + cs["evictions_ttl"]
        out["cache_invalidations"] = cs["invalidations"]
    if cres.slo is not None:
        # online-monitor roll-up (simulated seconds / counts; see
        # `repro.obs.monitor` for the burn-rate semantics)
        out["time_in_violation"] = cres.slo["time_in_violation"]
        out["alerts_fired"] = cres.slo["alerts_fired"]
        out["budget_burn"] = cres.slo["budget_burn"]
        out["anomalies"] = len(cres.slo["anomalies"])
    out["scale_events"] = len(cres.scale_events)
    out["peak_replicas"] = cres.peak_replicas
    out["replica_hours"] = cres.replica_hours
    out["replica_hours_static_peak"] = cres.replica_hours_static_peak
    # the trace frame: exported timelines, billing spans, and this summary
    # all share one clock (origin t0, last-replica-quiet horizon)
    out["t0"] = cres.t0
    out["horizon"] = cres.horizon
    return out


def pool_summaries(cres: ClusterResult, *, slo_ttft: float | None = None,
                   slo_tpot: float | None = None) -> dict:
    """Per-pool SLO metrics (over the pool replicas' own stage records)
    plus pool utilization against the cluster makespan."""
    span = max(cres.makespan, 1e-12)
    out = {}
    for pool in dict.fromkeys(cres.replica_pools):  # stable order
        idxs = [i for i, p in enumerate(cres.replica_pools) if p == pool]
        recs = [rec for i in idxs for rec in cres.replica_results[i].records]
        s = summarize_records(recs, span=cres.makespan,
                              slo_ttft=slo_ttft, slo_tpot=slo_tpot)
        s["replicas"] = len(idxs)
        s["util_mean"] = (sum(cres.replica_results[i].busy_s for i in idxs)
                          / (len(idxs) * span))
        s["preemptions"] = sum(cres.replica_results[i].preemptions for i in idxs)
        s["peak_kv_gb"] = max(cres.replica_results[i].peak_kv for i in idxs) / 1e9
        out[pool] = s
    return out
