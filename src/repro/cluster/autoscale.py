"""Target-tracking autoscaling policies for the dynamic cluster simulator.

An `Autoscaler` is the control loop of `simulate_cluster(..., autoscale=)`:
every `interval` seconds it observes the recent past through a rolling
window and returns the replica count the fleet should converge to.

Two signals:

  * `rate`     — track the observed arrival rate: desired replicas =
                 ceil(rate / target_qps_per_replica), the classic
                 requests-per-replica target-tracking policy.
  * `slo_debt` — track the rolling TTFT-violation fraction of completed
                 requests: scale up while debt exceeds `debt_hi`, scale
                 down (one replica per tick) once it falls under
                 `debt_lo`. Reactive, workload-shape-agnostic, but pays
                 the debt before correcting it.

Scale-up is not free: a replica spends `warmup` seconds loading weights
before it can accept traffic. When `warmup` is None it is priced from the
serving cost model — per-device resident weight bytes over the host
weight-load link (`host_bw`) — so bigger models genuinely take longer to
join, which is exactly the lag that makes diurnal provisioning hard.
Scale-down is graceful: the cluster engine first cancels replicas still
warming, then drains live ones (no new admissions, in-flight work runs
out) — see `repro.cluster.cluster`.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass

from repro.sim.costmodel import ServingCostModel

AUTOSCALE_POLICIES = ("rate", "slo_debt")

# PCIe gen5 x16 ballpark: the host-to-device link each device's weight
# shard streams over while a replica warms up
DEFAULT_HOST_BW = 64e9


class RollingFlagWindow:
    """(timestamp, flag) observations over a trailing time window; the one
    rolling-violation-fraction implementation shared by the autoscaler's
    SLO-debt signal and the `slo_debt` router (so their window semantics
    cannot drift apart)."""

    def __init__(self, window: float):
        if window <= 0:
            raise ValueError("window must be positive")
        self.window = float(window)
        self._q: deque[tuple[float, bool]] = deque()

    def add(self, t: float, flag: bool) -> None:
        self._q.append((t, bool(flag)))

    def frac(self, now: float) -> float:
        """Fraction of set flags among observations in [now - window, now]
        (0 when the window is empty)."""
        q = self._q
        horizon = now - self.window
        while q and q[0][0] < horizon:
            q.popleft()
        if not q:
            return 0.0
        return sum(1 for _, f in q if f) / len(q)


@dataclass(frozen=True)
class AutoscaleConfig:
    policy: str = "rate"  # rate | slo_debt
    min_replicas: int = 1
    max_replicas: int = 8
    interval: float = 5.0  # control-loop period (s)
    window: float = 15.0  # rolling observation window (s)
    target_qps_per_replica: float = 8.0  # rate policy setpoint
    slo_ttft: float = 2.0  # TTFT deadline the debt signal scores against
    debt_hi: float = 0.10  # scale up while violation fraction exceeds this
    debt_lo: float = 0.02  # scale down once it falls below this
    warmup: float | None = None  # s; None -> weight bytes over host_bw
    host_bw: float = DEFAULT_HOST_BW  # bytes/s per device for weight loading

    def validate(self) -> None:
        if self.policy not in AUTOSCALE_POLICIES:
            raise ValueError(f"unknown autoscale policy {self.policy!r}; "
                             f"choose from {AUTOSCALE_POLICIES}")
        if not 1 <= self.min_replicas <= self.max_replicas:
            raise ValueError("need 1 <= min_replicas <= max_replicas")
        if self.interval <= 0 or self.window <= 0:
            raise ValueError("interval and window must be positive")
        if self.target_qps_per_replica <= 0:
            raise ValueError("target_qps_per_replica must be positive")
        if not 0.0 <= self.debt_lo <= self.debt_hi <= 1.0:
            raise ValueError("need 0 <= debt_lo <= debt_hi <= 1")
        if self.warmup is not None and self.warmup < 0:
            raise ValueError("warmup must be >= 0")
        if self.host_bw <= 0:
            raise ValueError("host_bw must be positive")

    def warmup_seconds(self, cost: ServingCostModel) -> float:
        """Replica activation delay: explicit override, or the time to
        stream each device's resident weight shard over the host link
        (shards load in parallel across the replica's devices)."""
        if self.warmup is not None:
            return self.warmup
        return cost.weight_bytes / self.host_bw


class Autoscaler:
    """Rolling-window signal tracker + desired-count policy. The cluster
    engine feeds it arrivals and completed-request TTFTs; `desired()` is
    evaluated at each control tick and clamped to [min, max]."""

    def __init__(self, asc: AutoscaleConfig):
        asc.validate()
        self.asc = asc
        self._arrivals: deque[float] = deque()
        self._debt = RollingFlagWindow(asc.window)

    # ------------------------------------------------------------ observation
    def observe_arrival(self, t: float) -> None:
        self._arrivals.append(t)

    def observe_ttft(self, t: float, ttft: float) -> None:
        self._debt.add(t, ttft > self.asc.slo_ttft)

    def observed_rate(self, now: float) -> float:
        """Arrival rate over the (possibly still-filling) window."""
        horizon = now - self.asc.window
        while self._arrivals and self._arrivals[0] < horizon:
            self._arrivals.popleft()
        denom = max(min(now, self.asc.window), 1e-9)
        return len(self._arrivals) / denom

    def slo_debt(self, now: float) -> float:
        """Rolling TTFT-violation fraction (0 with no completions yet)."""
        return self._debt.frac(now)

    # ---------------------------------------------------------------- policy
    def desired(self, now: float, provisioned: int) -> int:
        """Replica count to converge to, given `provisioned` replicas
        currently active or warming (draining ones are already gone)."""
        if self.asc.policy == "rate":
            want = math.ceil(self.observed_rate(now)
                             / self.asc.target_qps_per_replica)
        else:  # slo_debt
            debt = self.slo_debt(now)
            if debt > self.asc.debt_hi:
                want = provisioned + 1
            elif debt < self.asc.debt_lo:
                want = provisioned - 1
            else:
                want = provisioned
        return max(self.asc.min_replicas, min(self.asc.max_replicas, want))
