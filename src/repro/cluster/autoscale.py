"""Autoscaling policies for the dynamic cluster simulator.

An `Autoscaler` is the control loop of `simulate_cluster(..., autoscale=)`:
every `interval` seconds it observes the fleet and returns the replica
count it should converge to. Policies fall into two families:

Reactive (track what already happened through a rolling window):

  * `rate`       — track the observed arrival rate: desired replicas =
                   ceil(rate / target_qps_per_replica), the classic
                   requests-per-replica target-tracking policy.
  * `slo_debt`   — track the rolling TTFT-violation fraction of completed
                   requests: scale up while debt exceeds `debt_hi`, scale
                   down (one replica per tick) once it falls under
                   `debt_lo`. Workload-shape-agnostic, but pays the debt
                   before correcting it.
  * `queue_wait` — track the rolling mean admission-queue wait (seconds a
                   request sat queued before a slot opened): up above
                   `wait_hi`, down below `wait_lo`. The natural signal for
                   a disaggregated PREFILL pool, whose backlog is queued
                   prompts rather than resident KV.
  * `kv_tpot`    — track KV-cache pressure (mean occupancy fraction of
                   the pool's accepting replicas) plus the rolling
                   TPOT-violation fraction: up when either `kv_hi` /
                   `debt_hi` is breached, down when both are under
                   `kv_lo` / `debt_lo`. The natural signal for a DECODE
                   pool, which saturates on resident cache and inter-token
                   latency, not on arrival rate.

Predictive (provision for what is about to happen):

  * `predictive` — feed the KNOWN rate envelope (`AutoscaleConfig.
                   envelope`, e.g. `Workload.peak_rate` for the diurnal
                   closed form or a JSONL rate replay) and an M/G/1-style
                   per-replica wait estimate into `desired()`. At each
                   tick the policy provisions for the PEAK offered rate
                   over the next `lookahead` seconds (default: warmup +
                   interval), choosing the smallest replica count whose
                   Pollaczek-Khinchine queueing wait stays under
                   `target_wait`. Because the horizon covers the warmup,
                   scale-ups LEAD the ramp instead of trailing it by
                   warmup + window — the paper's analytical-foresight
                   thesis applied to fleet control. Without an envelope it
                   degrades gracefully to the observed rate (still gaining
                   the queueing-theoretic sizing). The per-request service
                   time E[S] is priced from `ServingCostModel` step costs
                   (`AutoscaleConfig.effective_service_time`).

Scale-up is not free: a replica spends `warmup` seconds loading weights
before it can accept traffic. When `warmup` is None it is priced from the
serving cost model — per-device resident weight bytes over the host
weight-load link (`host_bw`) — so bigger models genuinely take longer to
join, which is exactly the lag that makes diurnal provisioning hard.
Scale-down is graceful: the cluster engine first cancels replicas still
warming, then drains live ones (no new admissions, in-flight work runs
out) — see `repro.cluster.cluster`.

Units throughout: times/waits in seconds, rates in requests/second,
bandwidths in bytes/second, token counts in tokens.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Callable

from repro.sim.costmodel import ServingCostModel
from repro.sim.scheduler import SchedConfig

AUTOSCALE_POLICIES = ("rate", "slo_debt", "predictive", "queue_wait",
                      "kv_tpot")

# PCIe gen5 x16 ballpark: the host-to-device link each device's weight
# shard streams over while a replica warms up
DEFAULT_HOST_BW = 64e9

_INF = float("inf")


class RollingMeanWindow:
    """(timestamp, value) observations over a trailing time window with a
    rolling mean — the admission-wait signal behind `queue_wait`, and the
    base of every rolling signal here. Entries are pruned both on `add`
    (so windows that are written but never read — a policy that ignores
    them — stay O(window x rate), not O(run length)) and on `mean` (the
    read time may be later than the last write)."""

    def __init__(self, window: float):
        if window <= 0:
            raise ValueError("window must be positive")
        self.window = float(window)
        self._q: deque[tuple[float, float]] = deque()

    def add(self, t: float, value: float) -> None:
        """Record `value` observed at time `t` (seconds); drops samples
        older than the window."""
        q = self._q
        q.append((t, float(value)))
        horizon = t - self.window
        while q and q[0][0] < horizon:
            q.popleft()

    def mean(self, now: float) -> float:
        """Mean of the values observed in [now - window, now] (0.0 when
        the window is empty)."""
        q = self._q
        horizon = now - self.window
        while q and q[0][0] < horizon:
            q.popleft()
        if not q:
            return 0.0
        return sum(v for _, v in q) / len(q)

    def count(self, now: float) -> int:
        """Number of observations still inside [now - window, now] — the
        sample-size gate the admission circuit breaker trips on."""
        q = self._q
        horizon = now - self.window
        while q and q[0][0] < horizon:
            q.popleft()
        return len(q)


class RollingFlagWindow(RollingMeanWindow):
    """Rolling violation fraction: a `RollingMeanWindow` over 0/1 flags —
    the one implementation shared by the autoscaler's SLO-debt signals
    and the `slo_debt` router (so their window semantics cannot drift
    apart)."""

    def add(self, t: float, flag: bool) -> None:
        """Record a violation flag observed at time `t` (seconds)."""
        super().add(t, 1.0 if flag else 0.0)

    def frac(self, now: float) -> float:
        """Fraction of set flags among observations in [now - window, now]
        (0 when the window is empty)."""
        return self.mean(now)


@dataclass(frozen=True)
class AutoscaleConfig:
    """Declarative autoscaling spec for one fleet (or, pool-aware, one
    pool — pass `{"prefill": asc_p, "decode": asc_d}` to
    `simulate_cluster(..., autoscale=)` to scale pools independently).

    Fields (units in brackets; only the fields of the chosen `policy`
    matter, the rest are ignored):

      policy                  one of `AUTOSCALE_POLICIES`.
      min_replicas /
      max_replicas            clamp on `desired()` [replicas].
      interval                control-loop period [s].
      window                  rolling observation window [s].
      target_qps_per_replica  `rate` policy setpoint [req/s per replica].
      slo_ttft                TTFT deadline the `slo_debt` signal scores
                              against [s].
      debt_hi / debt_lo       `slo_debt` + `kv_tpot` hysteresis band on
                              the rolling violation fraction [0..1].
      warmup                  replica activation delay [s]; None prices
                              weight loading from the cost model.
      host_bw                 weight-load link [bytes/s per device].
      envelope                `predictive`: peak offered rate over a
                              window, `envelope(t0, t1) -> req/s` — pass
                              `Workload.peak_rate` (see `repro.sim`).
      lookahead               `predictive` horizon [s]; None -> warmup +
                              interval (capacity ordered now is ready
                              exactly when the horizon arrives).
      target_wait             `predictive`: admission-wait budget the
                              M/G/1 estimate must clear [s]; None ->
                              0.5 * slo_ttft.
      service_time            `predictive`: per-request effective service
                              time E[S] override [s]; None -> priced from
                              the cost model via `effective_service_time`.
      service_cv2             `predictive`: squared coefficient of
                              variation of the service time (1.0 = M/M/1;
                              lognormal token lengths push it above 1).
      mean_prompt /
      mean_output             traffic shape for pricing E[S] [tokens].
      wait_hi / wait_lo       `queue_wait` hysteresis band on the rolling
                              mean admission wait [s].
      slo_tpot                TPOT deadline the `kv_tpot` debt scores
                              against [s/token].
      kv_hi / kv_lo           `kv_tpot` hysteresis band on mean KV
                              occupancy fraction [0..1].
      spare                   N+k redundancy: replicas held above every
                              policy's ask (within the clamp), absorbing
                              a crash while the replacement warms
                              [replicas].
    """

    policy: str = "rate"
    min_replicas: int = 1
    max_replicas: int = 8
    interval: float = 5.0  # control-loop period (s)
    window: float = 15.0  # rolling observation window (s)
    target_qps_per_replica: float = 8.0  # rate policy setpoint
    slo_ttft: float = 2.0  # TTFT deadline the debt signal scores against
    debt_hi: float = 0.10  # scale up while violation fraction exceeds this
    debt_lo: float = 0.02  # scale down once it falls below this
    warmup: float | None = None  # s; None -> weight bytes over host_bw
    host_bw: float = DEFAULT_HOST_BW  # bytes/s per device for weight loading
    # predictive policy
    envelope: Callable[[float, float], float] | None = None  # peak qps fn
    lookahead: float | None = None  # s; None -> warmup + interval
    target_wait: float | None = None  # s; None -> 0.5 * slo_ttft
    service_time: float | None = None  # s; None -> priced from cost model
    service_cv2: float = 1.0  # squared CV of service time (1.0 = M/M/1)
    mean_prompt: float = 512.0  # tokens, for pricing E[S]
    mean_output: float = 128.0  # tokens, for pricing E[S]
    # queue_wait policy (prefill pools)
    wait_hi: float = 0.5  # s: scale up while mean admission wait exceeds
    wait_lo: float = 0.1  # s: scale down once it falls below
    # kv_tpot policy (decode pools)
    slo_tpot: float = 0.05  # s/token TPOT deadline for the debt signal
    kv_hi: float = 0.85  # KV occupancy fraction: scale up above
    kv_lo: float = 0.40  # KV occupancy fraction: scale down below
    # N+k redundancy: replicas held ABOVE what the policy asks for, so a
    # crash (repro.cluster.chaos) leaves the policy's desired capacity
    # intact while the replacement warms (0 = size for steady state)
    spare: int = 0

    def validate(self) -> None:
        """Raise ValueError on any out-of-domain field combination."""
        if self.policy not in AUTOSCALE_POLICIES:
            raise ValueError(f"unknown autoscale policy {self.policy!r}; "
                             f"choose from {AUTOSCALE_POLICIES}")
        if not 1 <= self.min_replicas <= self.max_replicas:
            raise ValueError("need 1 <= min_replicas <= max_replicas")
        if self.interval <= 0 or self.window <= 0:
            raise ValueError("interval and window must be positive")
        if self.target_qps_per_replica <= 0:
            raise ValueError("target_qps_per_replica must be positive")
        if not 0.0 <= self.debt_lo <= self.debt_hi <= 1.0:
            raise ValueError("need 0 <= debt_lo <= debt_hi <= 1")
        if self.warmup is not None and self.warmup < 0:
            raise ValueError("warmup must be >= 0")
        if self.host_bw <= 0:
            raise ValueError("host_bw must be positive")
        if self.lookahead is not None and self.lookahead <= 0:
            raise ValueError("lookahead must be positive")
        if self.target_wait is not None and self.target_wait <= 0:
            raise ValueError("target_wait must be positive")
        if self.service_time is not None and self.service_time <= 0:
            raise ValueError("service_time must be positive")
        if self.service_cv2 < 0:
            raise ValueError("service_cv2 must be >= 0")
        if self.mean_prompt < 1 or self.mean_output < 1:
            raise ValueError("mean_prompt and mean_output must be >= 1")
        if self.spare < 0:
            raise ValueError("spare must be >= 0")
        if not 0.0 <= self.wait_lo <= self.wait_hi:
            raise ValueError("need 0 <= wait_lo <= wait_hi")
        if self.slo_tpot <= 0:
            raise ValueError("slo_tpot must be positive")
        if not 0.0 <= self.kv_lo <= self.kv_hi <= 1.0:
            raise ValueError("need 0 <= kv_lo <= kv_hi <= 1")

    def warmup_seconds(self, cost: ServingCostModel) -> float:
        """Replica activation delay in seconds: the explicit override, or
        the time to stream each device's resident weight shard over the
        host link (shards load in parallel across the replica's devices)."""
        if self.warmup is not None:
            return self.warmup
        return cost.weight_bytes / self.host_bw

    def effective_service_time(self, cost: ServingCostModel,
                               sched: SchedConfig | None = None,
                               pool: str = "mixed") -> float:
        """Per-request effective service time E[S] in seconds, priced from
        the cost model's step costs for the configured traffic shape
        (`mean_prompt` / `mean_output` tokens).

        The replica is modeled at its batch-saturated throughput: a batch
        of `sched.slots` requests completes one request per
        t_request / slots, where t_request = prefill(mean_prompt) +
        (mean_output - 1) decode steps at the mean context. Pool variants:

          * "prefill" — prompts are compute-bound and process serially, so
            E[S] is the whole-prompt prefill time (no batching discount).
          * "decode"  — decode steps only, amortized over the batch.
          * "mixed"   — prefill + decode amortized over the batch.

        This is the single-number server model the `predictive` policy's
        M/G/1 estimate runs on; `service_time` on the config overrides it.
        """
        if self.service_time is not None:
            return self.service_time
        slots = max(sched.slots if sched is not None else 16, 1)
        prompt = max(int(round(self.mean_prompt)), 1)
        output = max(int(round(self.mean_output)), 1)
        ctx = prompt + output // 2  # mean resident context while decoding
        prefill = cost.prefill_time(prompt)
        decode = max(output - 1, 0) * cost.decode_step_time(slots, ctx)
        if pool == "prefill":
            return prefill
        if pool == "decode":
            return max(decode, cost.decode_step_time(slots, ctx)) / slots
        return (prefill + decode) / slots


class Autoscaler:
    """Signal tracker + desired-count policy for one fleet or pool.

    The cluster engine feeds it arrivals (`observe_arrival`), completed
    requests' TTFTs (`observe_ttft`), admission waits (`observe_wait`),
    and per-token latencies (`observe_tpot`); `desired()` is evaluated at
    each control tick and clamped to [min_replicas, max_replicas].

    `cost` / `sched` / `pool` resolve the predictive policy's derived
    quantities at construction: the effective service time E[S] (from
    `AutoscaleConfig.effective_service_time`) and the lookahead horizon
    (warmup + interval when the config leaves `lookahead` unset). Reactive
    policies need neither and may construct with `Autoscaler(asc)` alone.
    """

    def __init__(self, asc: AutoscaleConfig, *,
                 cost: ServingCostModel | None = None,
                 sched: SchedConfig | None = None, pool: str = "mixed"):
        asc.validate()
        self.asc = asc
        self._arrivals: deque[float] = deque()
        self._debt = RollingFlagWindow(asc.window)
        self._tpot_debt = RollingFlagWindow(asc.window)
        self._wait = RollingMeanWindow(asc.window)
        self.service_time = asc.service_time
        if self.service_time is None and cost is not None:
            self.service_time = asc.effective_service_time(cost, sched, pool)
        if asc.lookahead is not None:
            self.lookahead = asc.lookahead
        else:
            warm = (asc.warmup_seconds(cost) if cost is not None
                    else (asc.warmup or 0.0))
            self.lookahead = warm + asc.interval
        if asc.policy == "predictive" and self.service_time is None:
            raise ValueError(
                "predictive policy needs service_time= on the config or a "
                "cost model at Autoscaler construction")
        # the inputs behind the most recent desired() call — what the
        # tracer's autoscale.decision events record, so every scale-up/
        # down in a trace is explainable from the policy's own signals
        self.last_decision: dict = {}

    # ------------------------------------------------------------ observation
    def observe_arrival(self, t: float) -> None:
        """Record one request arrival at time `t` (s). Arrivals older
        than the window are pruned here too, so the deque stays bounded
        even under policies that never read the rate."""
        self._arrivals.append(t)
        horizon = t - self.asc.window
        while self._arrivals and self._arrivals[0] < horizon:
            self._arrivals.popleft()

    def observe_ttft(self, t: float, ttft: float) -> None:
        """Record a completed request's end-to-end TTFT (s), observed at
        completion time `t` — the `slo_debt` policy's input."""
        self._debt.add(t, ttft > self.asc.slo_ttft)

    def observe_wait(self, t: float, wait: float) -> None:
        """Record a completed request's admission-queue wait (s) — the
        `queue_wait` policy's input."""
        self._wait.add(t, wait)

    def observe_tpot(self, t: float, tpot: float) -> None:
        """Record a completed request's mean inter-token time (s/token) —
        half of the `kv_tpot` policy's input."""
        self._tpot_debt.add(t, tpot > self.asc.slo_tpot)

    def observed_rate(self, now: float) -> float:
        """Arrival rate (req/s) over the (possibly still-filling) window."""
        horizon = now - self.asc.window
        while self._arrivals and self._arrivals[0] < horizon:
            self._arrivals.popleft()
        denom = max(min(now, self.asc.window), 1e-9)
        return len(self._arrivals) / denom

    def slo_debt(self, now: float) -> float:
        """Rolling TTFT-violation fraction (0 with no completions yet)."""
        return self._debt.frac(now)

    def tpot_debt(self, now: float) -> float:
        """Rolling TPOT-violation fraction (0 with no completions yet)."""
        return self._tpot_debt.frac(now)

    def queue_wait(self, now: float) -> float:
        """Rolling mean admission wait in seconds (0 when the window is
        empty)."""
        return self._wait.mean(now)

    # ---------------------------------------------------------------- policy
    def predicted_wait(self, rate: float, n: int) -> float:
        """Pollaczek-Khinchine M/G/1 queueing-wait estimate in seconds for
        `n` replicas sharing `rate` req/s of arrivals.

        Each replica is an M/G/1 server at rate/n arrivals with service
        time E[S] = `self.service_time` and squared CV `service_cv2`:

            rho = (rate / n) * E[S]
            Wq  = rho * (1 + cv^2) / 2 * E[S] / (1 - rho)

        Returns inf at or beyond saturation (rho >= 1)."""
        if n < 1 or self.service_time is None:
            return _INF
        rho = rate * self.service_time / n
        if rho >= 1.0:
            return _INF
        return (rho * (1.0 + self.asc.service_cv2) / 2.0
                * self.service_time / (1.0 - rho))

    def desired(self, now: float, provisioned: int, *,
                kv_frac: float = 0.0) -> int:
        """Replica count to converge to, clamped to [min, max].

        `provisioned` is the number of replicas currently active or
        warming (draining ones are already gone); `kv_frac` is the mean
        KV-occupancy fraction of the pool's accepting replicas at `now`
        (only the `kv_tpot` policy reads it)."""
        asc = self.asc
        inputs: dict = {}
        if asc.policy == "rate":
            rate = self.observed_rate(now)
            want = math.ceil(rate / asc.target_qps_per_replica)
            inputs = {"rate": rate,
                      "target_qps_per_replica": asc.target_qps_per_replica}
        elif asc.policy == "predictive":
            if asc.envelope is not None:
                rate = asc.envelope(now, now + self.lookahead)
            else:
                rate = self.observed_rate(now)
            budget = (asc.target_wait if asc.target_wait is not None
                      else 0.5 * asc.slo_ttft)
            want = asc.max_replicas
            for n in range(asc.min_replicas, asc.max_replicas + 1):
                if self.predicted_wait(rate, n) <= budget:
                    want = n
                    break
            pw = self.predicted_wait(rate, want)
            inputs = {"predicted_rate": rate, "wait_budget": budget,
                      "predicted_wait": pw if pw != _INF else -1.0,
                      "lookahead": self.lookahead}
        elif asc.policy == "queue_wait":
            wait = self.queue_wait(now)
            if wait > asc.wait_hi:
                want = provisioned + 1
            elif wait < asc.wait_lo:
                want = provisioned - 1
            else:
                want = provisioned
            inputs = {"queue_wait": wait, "wait_hi": asc.wait_hi,
                      "wait_lo": asc.wait_lo}
        elif asc.policy == "kv_tpot":
            debt = self.tpot_debt(now)
            if kv_frac > asc.kv_hi or debt > asc.debt_hi:
                want = provisioned + 1
            elif kv_frac < asc.kv_lo and debt < asc.debt_lo:
                want = provisioned - 1
            else:
                want = provisioned
            inputs = {"kv_frac": kv_frac, "tpot_debt": debt,
                      "kv_hi": asc.kv_hi, "debt_hi": asc.debt_hi}
        else:  # slo_debt
            debt = self.slo_debt(now)
            if debt > asc.debt_hi:
                want = provisioned + 1
            elif debt < asc.debt_lo:
                want = provisioned - 1
            else:
                want = provisioned
            inputs = {"slo_debt": debt, "debt_hi": asc.debt_hi,
                      "debt_lo": asc.debt_lo}
        # N+k redundancy rides on top of every policy's ask (still inside
        # the [min, max] clamp: spares never exceed the fleet's ceiling)
        clamped = max(asc.min_replicas,
                      min(asc.max_replicas, want + asc.spare))
        self.last_decision = {"policy": asc.policy, "provisioned": provisioned,
                              **inputs, "want_raw": want, "want": clamped}
        return clamped
