"""Pluggable request routers for the cluster simulator.

A router sees lightweight `ReplicaView` snapshots (clock, queue depth,
live sequences, KV occupancy) of the replicas in one pool and picks the
replica a request is dispatched to. All policies are deterministic
functions of the views and the router's own state, so a fixed workload
seed yields a fixed assignment.

`affinity` additionally models the prefix/session cache that affinity
routing exists to exploit: a request landing on the replica that last
served its session skips `hit_frac` of its prompt prefill (the prefix is
already resident), entering the replica with `cached` tokens. With
`ClusterSpec.prefix_cache` set, the discount is no longer unconditional:
the cluster engine binds a `FleetPrefixCache` to the router
(`bind_cache`), placement becomes residency-aware (explicit prefix
groups are steered to the warmest replica), and the cached-token count
is computed by the ENGINE from actually resident prefix bytes under a
finite budget with LRU + TTL eviction — see
`repro.cluster.prefixcache`.

Routers are notified when a replica retires (`on_retire`): affinity
drops the session pins homed on it and slo_debt drops its observation
window, so long autoscaled runs don't accrete state for dead replicas.
Chaos crashes (`repro.cluster.chaos`) flow through the same hook — a
crashed replica is pruned exactly like a drained one, and its displaced
requests re-enter `pick()` as fresh dispatches.

`slo_debt` closes the loop on outcomes instead of state: the cluster
engine feeds completed requests' TTFTs back via `observe()`, and the
router sends new work to the replica with the lowest rolling TTFT-SLO
violation fraction — instantaneous queue depth only breaks ties. This is
the "route on SLO debt, not queue length" feedback policy; it reacts to
what replicas actually delivered (useful under heterogeneous hardware,
where equal depths hide unequal speeds).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.sim.workload import SimRequest

from repro.cluster.autoscale import RollingFlagWindow

ROUTERS = ("round_robin", "jsq", "least_kv", "affinity", "slo_debt")


@dataclass(frozen=True)
class ReplicaView:
    """Read-only snapshot of one replica, as the router observes it at
    the dispatch instant."""

    idx: int  # global replica index
    now: float  # view clock (s): max(replica clock, dispatch time)
    queue_len: int  # requests queued, not yet admitted
    live: int  # sequences holding slots
    kv_used: float  # KV bytes currently materialized on the replica
    kv_capacity: float  # KV budget (bytes)

    @property
    def depth(self) -> int:
        """Total requests on the replica (queued + live) — the JSQ load."""
        return self.queue_len + self.live

    @property
    def kv_frac(self) -> float:
        """KV occupancy fraction in [0, 1] (0 for an empty/∞ budget)."""
        return self.kv_used / self.kv_capacity if self.kv_capacity > 0 else 0.0


class Router:
    """Dispatch policy interface.

    `pick(req, views)` chooses among the eligible replicas and returns
    `(chosen replica idx, prefix-cached prompt tokens)` — the cached
    count is nonzero only for affinity hits, and the replica resumes the
    request with that many prompt tokens already materialized.

    `observe(idx, t, ttft)` is the cluster engine's outcome feedback
    channel: replica `idx` completed a request at time `t` (s) with the
    given end-to-end TTFT (s). Stateless policies ignore it.

    `on_retire(idx)` is the lifecycle hook: replica `idx` left the fleet
    for good (drained or cancelled) and will never appear in `views`
    again, so any per-replica router state keyed on it can be pruned.
    Replica indices are never reused within a run.

    `last_pick` holds a flat dict explaining the most recent `pick()` —
    the policy's name plus whatever drove the choice (queue depth, KV
    fraction, session home, SLO debt). The cluster tracer attaches it to
    dispatch events so every placement in a trace is explainable."""

    name = "base"
    last_pick: dict = {}

    def pick(self, req: SimRequest, views: list[ReplicaView]) -> tuple[int, int]:
        """Place `req`: returns (replica idx, cached prompt tokens)."""
        raise NotImplementedError

    def observe(self, idx: int, t: float, ttft: float) -> None:
        """Completion feedback: replica `idx` served with `ttft` seconds
        at time `t` (seconds). Default: ignored."""
        pass

    def on_retire(self, idx: int) -> None:
        """Replica `idx` left the fleet: drop any per-replica state."""
        pass


class RoundRobinRouter(Router):
    name = "round_robin"

    def __init__(self):
        self._i = 0

    def pick(self, req, views):
        """Next replica in rotation, ignoring load."""
        v = views[self._i % len(views)]
        self._i += 1
        self.last_pick = {"router": self.name, "slot": self._i - 1}
        return v.idx, 0


class JoinShortestQueueRouter(Router):
    name = "jsq"

    def pick(self, req, views):
        """Fewest outstanding requests; KV bytes then index break ties."""
        v = min(views, key=lambda v: (v.depth, v.kv_used, v.idx))
        self.last_pick = {"router": self.name, "depth": v.depth}
        return v.idx, 0


class LeastKVLoadRouter(Router):
    name = "least_kv"

    def pick(self, req, views):
        """Lowest KV occupancy (fraction of capacity); depth breaks ties."""
        v = min(views, key=lambda v: (v.kv_frac, v.depth, v.idx))
        self.last_pick = {"router": self.name, "kv_frac": v.kv_frac,
                          "depth": v.depth}
        return v.idx, 0


class AffinityRouter(Router):
    """Session/prefix affinity with a modeled prefill-cache hit discount.

    First request of a session is placed join-shortest-queue and pins the
    session to that replica; subsequent requests follow it and enter with
    `hit_frac` of their prompt already cached (capped at prompt - 1: the
    final prompt token always runs, it produces the first logits).
    Following the home replica with a 0-token discount (e.g. a 1-token
    prompt, or `int(prompt * hit_frac) == 0`) counts as a MISS — the hit
    counter reports realized discounts, not placement affinity.

    With a bound `FleetPrefixCache` (`bind_cache`, set by the cluster
    engine when `ClusterSpec.prefix_cache` is given) the router only does
    PLACEMENT — session home first, then the replica holding the most
    resident tokens of the request's explicit prefix group, then
    join-shortest-queue — and returns 0 cached tokens: the engine
    computes the discount from actual residency (and keeps the hit/miss
    stats on the cache)."""

    name = "affinity"

    def __init__(self, hit_frac: float = 0.5):
        if not 0.0 <= hit_frac < 1.0:
            raise ValueError("hit_frac must be in [0, 1)")
        self.hit_frac = float(hit_frac)
        self._home: dict[int, int] = {}
        self.cache = None  # FleetPrefixCache, bound by the cluster engine
        self.hits = 0
        self.misses = 0

    def bind_cache(self, cache) -> None:
        """Switch from the unconditional discount to modeled residency:
        `cache` informs placement; the engine computes the hit sizes."""
        self.cache = cache

    def _warmest(self, req, views):
        """The view holding the most resident tokens of `req`'s explicit
        prefix group (ties: shallowest queue, least KV, lowest idx), or
        None when the group is cold everywhere eligible OR the warm
        replica is already loaded well past the JSQ choice — popular
        prefixes must not herd the whole fleet's traffic onto one replica
        (re-prefilling the prefix elsewhere is cheaper than the queueing
        tail, and the re-prefill warms a second copy)."""
        scored = [(self.cache.resident_tokens(v.idx, req, v.now), v)
                  for v in views]
        tokens, v = max(scored,
                        key=lambda tv: (tv[0], -tv[1].depth, -tv[1].kv_used,
                                        -tv[1].idx))
        if tokens <= 0:
            return None
        jsq = min(views, key=lambda v: (v.depth, v.kv_used, v.idx))
        return v if v.depth <= jsq.depth + 1 else None

    def pick(self, req, views):
        """Session home if alive, else warmest prefix-cache replica, else
        JSQ; returns (idx, modeled cached tokens — 0 when the engine
        computes residency itself)."""
        eligible = {v.idx for v in views}
        home = self._home.get(req.session, -1) if req.session >= 0 else -1
        if home in eligible:
            if self.cache is not None:
                self.last_pick = {"router": self.name, "why": "session_home"}
                return home, 0  # discount computed by the engine
            cached = max(min(int(req.prompt * self.hit_frac), req.prompt - 1), 0)
            if cached > 0:
                self.hits += 1
            else:
                self.misses += 1
            self.last_pick = {"router": self.name, "why": "session_home",
                              "hit_tokens": cached}
            return home, cached
        v = None
        why = "jsq_fallback"
        if self.cache is not None and req.prefix_group >= 0:
            v = self._warmest(req, views)
            if v is not None:
                why = "warmest_prefix"
        if v is None:
            v = min(views, key=lambda v: (v.depth, v.kv_used, v.idx))
        if req.session >= 0:
            self._home[req.session] = v.idx
        if self.cache is None:
            self.misses += 1
        self.last_pick = {"router": self.name, "why": why, "depth": v.depth}
        return v.idx, 0

    def on_retire(self, idx):
        """Unpin every session homed on the retired replica."""
        self._home = {s: r for s, r in self._home.items() if r != idx}


class SLODebtRouter(Router):
    """Route to the replica with the lowest rolling TTFT-SLO debt.

    Debt is the violation fraction (ttft > slo_ttft) over the completions
    observed in the trailing `window` seconds; replicas with no recent
    completions carry zero debt (they are safe bets). Queue depth, then KV
    load, then index break ties, so a cold fleet degenerates to JSQ."""

    name = "slo_debt"

    def __init__(self, slo_ttft: float = 2.0, window: float = 30.0):
        if slo_ttft <= 0 or window <= 0:
            raise ValueError("slo_ttft and window must be positive")
        self.slo_ttft = float(slo_ttft)
        self.window = float(window)
        self._obs: dict[int, RollingFlagWindow] = {}  # per-replica debt

    def observe(self, idx, t, ttft):
        """Record whether `ttft` (seconds) at time `t` violated the SLO."""
        if idx not in self._obs:
            self._obs[idx] = RollingFlagWindow(self.window)
        self._obs[idx].add(t, ttft > self.slo_ttft)

    def debt(self, idx: int, now: float) -> float:
        """Rolling TTFT-violation fraction for replica `idx` at `now` (s)."""
        w = self._obs.get(idx)
        return w.frac(now) if w is not None else 0.0

    def on_retire(self, idx):
        """Drop the retired replica's debt window (unbounded otherwise)."""
        # a retired replica never reappears in views: its window would
        # otherwise sit in _obs forever (unbounded growth on long diurnal
        # traces with many joins/leaves)
        self._obs.pop(idx, None)

    def pick(self, req, views):
        """Lowest debt fraction; depth, KV bytes, then index break ties."""
        now = max(v.now for v in views)
        v = min(views, key=lambda v: (self.debt(v.idx, now), v.depth,
                                      v.kv_used, v.idx))
        self.last_pick = {"router": self.name, "debt": self.debt(v.idx, now),
                          "depth": v.depth}
        return v.idx, 0


def make_router(name: str, *, hit_frac: float = 0.5, slo_ttft: float = 2.0,
                debt_window: float = 30.0) -> Router:
    """Build a router by name (one of `ROUTERS`). `hit_frac` is the
    affinity router's prefix-cache discount in [0, 1); `slo_ttft` (s) and
    `debt_window` (s) parameterize the slo_debt router's rolling
    violation window. The extra knobs are ignored by policies that don't
    use them, so one call site serves every policy."""
    if name == "round_robin":
        return RoundRobinRouter()
    if name == "jsq":
        return JoinShortestQueueRouter()
    if name == "least_kv":
        return LeastKVLoadRouter()
    if name == "affinity":
        return AffinityRouter(hit_frac)
    if name == "slo_debt":
        return SLODebtRouter(slo_ttft, debt_window)
    raise ValueError(f"unknown router {name!r}; choose from {ROUTERS}")
