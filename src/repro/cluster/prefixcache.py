"""Modeled prefix cache: finite capacity, LRU + TTL eviction, and
cross-session sharing of prompt prefixes.

The affinity router of PRs 2-4 granted an *unconditional* per-session
prefill discount: any request landing on its session's home replica
skipped `hit_frac` of its prompt, free of charge. Real prefix caches are
neither free nor unconditional — the cached KV occupies the same HBM the
live sequences need (§3.5 prices KV bytes as the dominant inference
memory term), entries are evicted when the budget fills or when they go
idle, and system-prompt / few-shot prefixes are shared *across* sessions,
not pinned per conversation.

This module models exactly that, per replica:

  * a finite **byte budget** — `PrefixCacheConfig.budget_frac` carves the
    budget out of the replica's KV capacity, so cache warmth and live
    sequences compete for the same DRAM (the carve-out shrinks the
    scheduler's admission budget). `budget_bytes=math.inf` reproduces the
    old "cache is free and infinite" assumption and is the parity anchor:
    an infinite-budget, no-TTL cache with per-session prefix groups is
    bit-identical to the unconditional `hit_frac` discount
    (regression-tested).
  * **token-granular prefix groups** — a request carries either an
    explicit `prefix_group` (a shared system prompt / few-shot header of
    `prefix_len` tokens, reusable by EVERY session that lands on a warm
    replica) or falls back to its `session` (conversation history, of
    which `hit_frac` of each turn's prompt is the modeled reusable part).
  * **LRU + TTL eviction** — least-recently-used entries are evicted when
    an insertion would overflow the budget; entries idle longer than
    `ttl` seconds expire. Both are counted in the stats the cluster
    reports (`cache_evictions`, `cache_hit_tokens`, ...).
  * **two-phase residency** — a prefix is *reserved* at dispatch (the
    prefill that will materialize it is now scheduled on that replica, so
    requests queued behind it already benefit) and *committed* (recency
    refresh) when the prefill completes. Draining or retiring a replica
    invalidates its whole cache — autoscale churn destroys warmth, and
    the re-warm cost is measurable instead of assumed away.

Hits are computed from *actually resident* tokens: a request's discount
is `min(resident prefix tokens, its own cacheable prefix, prompt - 1)` —
the final prompt token always runs (it produces the first logits).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.sim.workload import SimRequest


@dataclass(frozen=True)
class PrefixCacheConfig:
    """Per-replica prefix-cache budget and eviction policy.

    Attributes:
        budget_frac: fraction of the replica's KV capacity carved out for
            the prefix cache (the scheduler's live-sequence budget shrinks
            by the same bytes). Ignored when `budget_bytes` is given.
        budget_bytes: absolute cache budget in bytes. `math.inf` models
            the legacy free-infinite cache (no carve-out, nothing ever
            evicted) — the bit-for-bit parity anchor with the
            unconditional `hit_frac` discount.
        ttl: idle seconds before an entry expires (None = never).
    """

    budget_frac: float = 0.1
    budget_bytes: float | None = None
    ttl: float | None = None

    def validate(self) -> None:
        """Range-check budget (bytes / fraction of KV) and ttl (seconds)."""
        if self.budget_bytes is not None:
            if self.budget_bytes < 0:
                raise ValueError("prefix-cache budget_bytes must be >= 0")
        elif not 0.0 <= self.budget_frac < 1.0:
            raise ValueError(
                "prefix-cache budget_frac must be in [0, 1) — the carve-out "
                "must leave KV capacity for live sequences")
        if self.ttl is not None and self.ttl <= 0:
            raise ValueError("prefix-cache ttl must be positive (or None)")

    @property
    def infinite(self) -> bool:
        """True for the legacy free-infinite cache (no carve-out)."""
        return self.budget_bytes is not None and math.isinf(self.budget_bytes)

    def budget_for(self, kv_capacity: float) -> float:
        """Cache budget (bytes) on a replica with `kv_capacity` KV bytes."""
        if self.budget_bytes is not None:
            return self.budget_bytes
        return self.budget_frac * kv_capacity


def prefix_key(req: SimRequest):
    """The cache key a request's reusable prefix lives under: its explicit
    prefix group when it has one (shared across sessions), else its
    session (conversation history), else None (nothing reusable)."""
    if req.prefix_group >= 0:
        return ("g", req.prefix_group)
    if req.session >= 0:
        return ("s", req.session)
    return None


def prefix_cap(req: SimRequest, hit_frac: float) -> int:
    """Cacheable prefix tokens of THIS request: the shared group prefix
    (explicit), or the modeled reusable share of a session turn's prompt
    (`hit_frac`), never the final prompt token (it must run to produce
    the first logits)."""
    if req.prefix_group >= 0:
        cap = min(req.prefix_len, req.prompt - 1)
    elif req.session >= 0:
        cap = min(int(req.prompt * hit_frac), req.prompt - 1)
    else:
        cap = 0
    return max(cap, 0)


@dataclass
class _Entry:
    """One resident prefix. `tokens=None` marks a session pin: the whole
    conversation context is resident, and a follow-up's hit is capped only
    by its own cacheable prefix (what makes the infinite-budget cache
    reduce exactly to the unconditional `hit_frac` discount)."""

    tokens: int | None
    bytes: float
    last_used: float
    seq: int


class ReplicaPrefixCache:
    """One replica's prefix cache: a byte-budgeted LRU/TTL map from
    prefix keys to resident token counts. All operations are deterministic
    functions of (call order, timestamps), so cluster runs stay seeded."""

    def __init__(self, budget: float, ttl: float | None, cost):
        self.budget = budget
        self.ttl = ttl
        self.cost = cost  # ServingCostModel: prices resident tokens in bytes
        self.entries: dict[tuple, _Entry] = {}
        self.used_bytes = 0.0
        self.peak_bytes = 0.0
        self._seq = 0
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.insertions = 0
        self.evictions_lru = 0
        self.evictions_ttl = 0
        self.rejected = 0  # prefixes larger than the whole budget
        self.invalidations = 0

    # ----------------------------------------------------------------- reads
    def _expired(self, e: _Entry, now: float) -> bool:
        return self.ttl is not None and now - e.last_used > self.ttl

    def resident_tokens(self, req: SimRequest, now: float,
                        hit_frac: float) -> int:
        """Read-only hit size in tokens for `req` at `now` (seconds; 0
        when absent/expired), capped by `hit_frac` (fraction of prompt).
        Never mutates, so routers may probe freely during placement."""
        key = prefix_key(req)
        e = self.entries.get(key) if key is not None else None
        if e is None or self._expired(e, now):
            return 0
        cap = prefix_cap(req, hit_frac)
        return cap if e.tokens is None else min(e.tokens, cap)

    # ------------------------------------------------------------- mutations
    def _sweep(self, now: float) -> None:
        dead = [k for k, e in self.entries.items() if self._expired(e, now)]
        for k in dead:
            self.used_bytes -= self.entries.pop(k).bytes
            self.evictions_ttl += 1

    def _evict_until(self, need: float, keep: tuple) -> None:
        while self.used_bytes + need > self.budget and self.entries:
            victims = [(e.last_used, e.seq, k)
                       for k, e in self.entries.items() if k != keep]
            if not victims:
                break
            _, _, k = min(victims)
            self.used_bytes -= self.entries.pop(k).bytes
            self.evictions_lru += 1

    def use(self, req: SimRequest, now: float, hit_frac: float) -> int:
        """Dispatch-time lookup + reservation. Returns the hit tokens (the
        prompt prefix the replica skips), then reserves/refreshes the
        request's own prefix so work queued behind it benefits — the
        prefill that materializes it is now scheduled here. Charges bytes,
        LRU-evicting colder prefixes to fit."""
        self._sweep(now)
        key = prefix_key(req)
        if key is None:
            return 0
        cap = prefix_cap(req, hit_frac)
        e = self.entries.get(key)
        hit = 0
        if e is not None:
            hit = cap if e.tokens is None else min(e.tokens, cap)
        if hit > 0:
            self.hits += 1
            self.hit_tokens += hit
        else:
            self.misses += 1
        # reserve: sessions pin their whole (growing) context; groups pin
        # the largest prefix any member has materialized so far
        if key[0] == "s":
            tokens_new: int | None = None
            bytes_new = self.cost.kv_bytes(req.prompt + req.output)
        else:
            tokens_new = max(cap, e.tokens if e is not None else 0)
            bytes_new = self.cost.kv_bytes(tokens_new)
            if tokens_new == 0:
                return hit  # nothing cacheable (e.g. 1-token prompt)
        if bytes_new > self.budget:
            # can't fit even alone: drop any stale entry and move on
            if e is not None:
                self.used_bytes -= e.bytes
                del self.entries[key]
            self.rejected += 1
            return hit
        delta = bytes_new - (e.bytes if e is not None else 0.0)
        if delta > 0:
            self._evict_until(delta, keep=key)
        self.used_bytes += delta
        self.peak_bytes = max(self.peak_bytes, self.used_bytes)
        self._seq += 1
        if e is None:
            self.insertions += 1
        self.entries[key] = _Entry(tokens_new, bytes_new, now, self._seq)
        return hit

    def uncount(self, hit: int) -> None:
        """Retract one `use()`'s hit/miss accounting: the dispatch it was
        counted for was evicted before its prefill ever ran (the replica
        drained), so the discount was never realized. The re-dispatch
        counts fresh on whichever replica actually serves the request."""
        if hit > 0:
            self.hits -= 1
            self.hit_tokens -= hit
        else:
            self.misses -= 1

    def commit(self, req: SimRequest, now: float) -> None:
        """Prefill-completion confirmation: refresh the entry's recency at
        the instant its KV actually became resident. No-op if the entry
        was evicted/invalidated while the prefill ran."""
        key = prefix_key(req)
        e = self.entries.get(key) if key is not None else None
        if e is None:
            return
        self._seq += 1
        e.last_used = now
        e.seq = self._seq

    def invalidate(self) -> None:
        """Drop everything — the replica is draining/retiring and its HBM
        (cache included) goes away with it."""
        if self.entries:
            self.invalidations += 1
        self.entries.clear()
        self.used_bytes = 0.0


class FleetPrefixCache:
    """The cluster engine's view: one `ReplicaPrefixCache` per replica
    that prefills (mixed/prefill pools), plus fleet-level stats."""

    def __init__(self, pc: PrefixCacheConfig, hit_frac: float):
        pc.validate()
        self.pc = pc
        self.hit_frac = float(hit_frac)
        self.caches: dict[int, ReplicaPrefixCache] = {}

    def register(self, idx: int, budget: float, cost) -> None:
        """Attach a cache with `budget` bytes to replica `idx`."""
        self.caches[idx] = ReplicaPrefixCache(budget, self.pc.ttl, cost)

    def resident_tokens(self, idx: int, req: SimRequest, now: float) -> int:
        """Read-only resident-prefix tokens on replica `idx` at `now` (s)."""
        c = self.caches.get(idx)
        return c.resident_tokens(req, now, self.hit_frac) if c else 0

    def use(self, idx: int, req: SimRequest, now: float) -> int:
        """Dispatch-time reserve: count + touch the hit; returns tokens."""
        c = self.caches.get(idx)
        return c.use(req, now, self.hit_frac) if c else 0

    def uncount(self, idx: int, hit: int) -> None:
        """Roll back a reserved hit of `hit` tokens (dispatch aborted)."""
        c = self.caches.get(idx)
        if c is not None:
            c.uncount(hit)

    def commit(self, idx: int, req: SimRequest, now: float) -> None:
        """Prefill finished on `idx` at `now` (s): make the prefix resident."""
        c = self.caches.get(idx)
        if c is not None:
            c.commit(req, now)

    def invalidate(self, idx: int) -> None:
        """Drop replica `idx`'s cache contents (drain/retire/crash)."""
        c = self.caches.get(idx)
        if c is not None:
            c.invalidate()

    @property
    def hits(self) -> int:
        """Fleet-wide cache-hit count (requests with a nonzero hit)."""
        return sum(c.hits for c in self.caches.values())

    def stats(self) -> dict:
        """Fleet-aggregate cache counters for `ClusterResult.cache_stats`."""
        cs = list(self.caches.values())
        return {
            "hits": sum(c.hits for c in cs),
            "misses": sum(c.misses for c in cs),
            "hit_tokens": sum(c.hit_tokens for c in cs),
            "insertions": sum(c.insertions for c in cs),
            "evictions_lru": sum(c.evictions_lru for c in cs),
            "evictions_ttl": sum(c.evictions_ttl for c in cs),
            "rejected": sum(c.rejected for c in cs),
            "invalidations": sum(c.invalidations for c in cs),
            "resident_bytes": sum(c.used_bytes for c in cs),
            # the budget is a PER-REPLICA invariant, so the headline peak
            # is the max over replicas, not a fleet sum
            "peak_resident_bytes": max((c.peak_bytes for c in cs), default=0.0),
            "budget_bytes": sum(c.budget for c in cs),
            "per_replica": {i: {"peak_resident_bytes": c.peak_bytes,
                                "resident_bytes": c.used_bytes,
                                "budget_bytes": c.budget}
                            for i, c in sorted(self.caches.items())},
        }
