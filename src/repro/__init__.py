"""repro — Optimus-JAX: performance-model-driven distributed LLM training/inference.

Reproduction of "Performance Modeling and Workload Analysis of Distributed Large
Language Model Training and Inference" (Kundu et al., 2024) as a production-style
JAX framework. See DESIGN.md for the architecture.
"""

__version__ = "0.1.0"
