"""arctic-480b [moe]: 128 experts top-2 with a parallel dense residual branch.

35L d_model=7168 56H (GQA kv=8) expert d_ff=4864 vocab=32000.
[hf Snowflake/snowflake-arctic-base]
Dense-MoE hybrid: every layer computes dense MLP (residual) + routed MoE.
"""

from repro.configs.base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    rope_theta=10000.0,
    moe=MoECfg(
        num_experts=128,
        top_k=2,
        d_ff=4864,
        dense_residual=True,
        dense_d_ff=4864,
        capacity_factor=1.25,
        # 960 GB of bf16 expert weights cannot fit 16-way TP alone on 16 GiB
        # v5e chips: shard expert ffn dims over the data axes too (DESIGN.md §6)
        shard_ff_dp=True,
    ),
)
