"""Config system: model / parallelism / shape descriptors.

Every assigned architecture gets a `ModelConfig` in `repro/configs/<id>.py` with
the exact published numbers, plus `reduced()` variants for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Any

import jax.numpy as jnp


@dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_ff: int  # per-expert ffn width
    num_shared_experts: int = 0  # deepseek: always-on shared experts (each d_ff wide)
    dense_residual: bool = False  # arctic: parallel dense MLP residual branch
    dense_d_ff: int = 0  # width of dense residual / leading dense layers
    first_k_dense: int = 0  # leading dense layers (deepseek layer 0)
    capacity_factor: float = 1.25
    norm_topk: bool = True  # renormalize top-k gate weights
    aux_loss_coef: float = 0.01
    # FSDP-style extra sharding of expert ffn dims over the data axes — needed
    # when total expert bytes exceed HBM*tp (arctic-480b: 960 GB bf16 vs
    # 16 GiB x 16-way TP). XLA all-gathers one layer's experts transiently.
    shard_ff_dp: bool = False


@dataclass(frozen=True)
class SSMCfg:
    kind: str  # "mamba2" | "rwkv6"
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    # rwkv6
    mix_dim: int = 32
    decay_lora: int = 64


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu | gelu | relu2
    gated_mlp: bool = True
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: int | None = None  # SWA window (h2o-danube)
    moe: MoECfg | None = None
    ssm: SSMCfg | None = None
    attn_every: int | None = None  # hybrid: shared attn+mlp block period (zamba2)
    input_mode: str = "tokens"  # tokens | embeds (audio/vlm stub frontends)
    tie_embeddings: bool = False
    dtype: str = "bfloat16"  # activation compute dtype
    param_dtype: str = "bfloat16"
    # loss
    loss_chunk: int = 2048  # sequence-chunked CE to bound logits memory
    # attention impl: dense | chunked | pallas (chunked = flash-style jnp loops)
    attn_impl: str = "chunked"
    attn_chunk: int = 1024

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def sub_quadratic(self) -> bool:
        """True when long-context decode is O(1)/O(window) per token."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        small: dict[str, Any] = dict(
            num_layers=min(self.num_layers, 2 if self.family != "hybrid" else 4),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            sliding_window=16 if self.sliding_window else None,
            param_dtype="float32",
            dtype="float32",
            attn_impl="dense",
            attn_chunk=16,
            loss_chunk=32,
        )
        if self.moe is not None:
            small["moe"] = replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 8),
                top_k=min(self.moe.top_k, 2),
                d_ff=64,
                dense_d_ff=128 if self.moe.dense_d_ff else 0,
                capacity_factor=2.0,
            )
        if self.ssm is not None:
            small["ssm"] = replace(
                self.ssm,
                d_state=16,
                head_dim=16,
                n_groups=1,
                mix_dim=8,
                decay_lora=8,
            )
        if self.attn_every is not None:
            small["attn_every"] = 2
        small.update(overrides)
        return replace(self, **small)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Reduced shapes used by smoke tests (same kinds, tiny extents).
SMOKE_SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 64, 2, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 64, 2, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 64, 2, "decode"),
    "long_500k": ShapeSpec("long_500k", 128, 1, "decode"),
}


@dataclass(frozen=True)
class ParallelConfig:
    """Distribution settings: mesh layout + policies."""

    mesh_shape: tuple[int, ...] = ()
    mesh_axes: tuple[str, ...] = ()
    dp_axes: tuple[str, ...] = ("data",)
    tp_axes: tuple[str, ...] = ("model",)
    sequence_parallel: bool = True
    context_parallel_axes: tuple[str, ...] = ()  # long-context decode KV sharding
    remat: str = "selective"  # none | selective | full  (paper §3.3)
    zero1: bool = True  # ZeRO-1 optimizer-state sharding over dp
    grad_compress: bool = False  # int8 gradient all-reduce (beyond-paper)
    microbatches: int = 1  # gradient accumulation
    pp_stages: int = 1  # executable pipeline stages (parallel/pipeline.py)


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    learning_rate: float = 3e-4
    warmup_steps: int = 10
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    optimizer: str = "adamw"  # adamw | adamw8bit
    seed: int = 0
    log_every: int = 10
    checkpoint_every: int = 0  # 0 = disabled
    checkpoint_dir: str = ""
    keep_checkpoints: int = 3


def config_to_dict(cfg) -> dict:
    return dataclasses.asdict(cfg)
