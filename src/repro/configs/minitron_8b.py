"""minitron-8b [dense]: pruned Nemotron — squared-ReLU MLP, huge vocab.

32L d_model=4096 32H (GQA kv=8, head_dim 128) d_ff=16384 vocab=256000.
[arXiv:2407.14679; hf nvidia/Minitron-8B-Base]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    norm="layernorm",
    act="relu2",
    gated_mlp=False,
    rope_theta=10000.0,
)
