"""deepseek-moe-16b [moe]: fine-grained 64 routed top-6 + 2 shared experts.

28L d_model=2048 16H (MHA kv=16, head_dim 128) expert d_ff=1408 vocab=102400;
layer 0 is dense with d_ff=10944 (per HF config).
[arXiv:2401.06066; hf deepseek-ai/deepseek-moe-16b-base]
"""

from repro.configs.base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    rope_theta=10000.0,
    moe=MoECfg(
        num_experts=64,
        top_k=6,
        d_ff=1408,
        num_shared_experts=2,
        first_k_dense=1,
        dense_d_ff=10944,
        capacity_factor=1.25,
    ),
)
