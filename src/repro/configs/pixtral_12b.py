"""pixtral-12b [vlm]: pixtral-ViT frontend (stub) + Mistral-Nemo-style decoder.

40L d_model=5120 32H (GQA kv=8, head_dim 128 — attn inner dim 4096 != d_model)
d_ff=14336 vocab=131072. [hf mistralai/Pixtral-12B-2409; unverified]
Vision frontend stub per assignment: input_specs() provides patch embeddings.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    rope_theta=1000000000.0,
    input_mode="embeds",
)
