"""Architecture registry + input specs for every (arch x shape) cell."""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import (  # noqa: F401
    ModelConfig,
    MoECfg,
    ParallelConfig,
    ShapeSpec,
    SHAPES,
    SMOKE_SHAPES,
    SSMCfg,
    TrainConfig,
)

ARCHS = [
    "zamba2_1p2b",
    "rwkv6_7b",
    "qwen3_14b",
    "starcoder2_3b",
    "h2o_danube_1p8b",
    "minitron_8b",
    "arctic_480b",
    "deepseek_moe_16b",
    "musicgen_large",
    "pixtral_12b",
]

# CLI ids (assignment spelling) -> module names
ALIASES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "rwkv6-7b": "rwkv6_7b",
    "qwen3-14b": "qwen3_14b",
    "starcoder2-3b": "starcoder2_3b",
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "minitron-8b": "minitron_8b",
    "arctic-480b": "arctic_480b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "musicgen-large": "musicgen_large",
    "pixtral-12b": "pixtral_12b",
}


def get_config(name: str) -> ModelConfig:
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    module = importlib.import_module(f"repro.configs.{mod}")
    return module.CONFIG


def list_archs() -> list[str]:
    return list(ARCHS)


def input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStruct stand-ins for the model-input part of a step.

    train  -> {tokens|embeds, labels}
    prefill-> {tokens|embeds}
    decode -> {tokens (B, 1)}  (the KV/state cache specs come from
              Model.cache_shapes and are composed by the caller)
    """
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    if cfg.input_mode == "embeds":
        inputs = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.compute_dtype)}
    else:
        inputs = {"tokens": tok}
    if shape.kind == "train":
        inputs["labels"] = tok
    return inputs


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether an (arch, shape) cell runs; reason recorded when skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "skipped: pure full-attention arch — 524k dense-KV decode is excluded "
            "by the assignment (sub-quadratic attention required); see DESIGN.md §5"
        )
    return True, ""
