"""rwkv6-7b [ssm]: RWKV6 "Finch" — attention-free, data-dependent decay.

32L d_model=4096 (64 heads x 64), channel-mix d_ff=14336, vocab 65536.
[arXiv:2404.05892; hf RWKV/rwkv-6-world-7b]
"""

from repro.configs.base import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,   # d_model / ssm.head_dim (informational for cost model)
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    norm="layernorm",
    act="relu2",
    gated_mlp=False,
    ssm=SSMCfg(kind="rwkv6", head_dim=64, mix_dim=32, decay_lora=64),
)
