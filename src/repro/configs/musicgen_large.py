"""musicgen-large [audio]: decoder-only over EnCodec tokens (backbone only).

48L d_model=2048 32H (MHA kv=32, head_dim 64) d_ff=8192 vocab=2048.
[arXiv:2306.05284; hf facebook/musicgen-large]
Frontend stub per assignment: input_specs() provides precomputed frame
embeddings; single-codebook-stream simplification (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    rope_theta=10000.0,
    input_mode="embeds",
)
